"""Layer-2 correctness: the JAX model (ell/dense step, fused power) against
NumPy power iteration and against each other."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def random_graph(rng, n, max_deg):
    """Random simple digraph where every vertex has >= 1 out-edge (keeps the
    ELL weights well-defined: no dangling out-degrees in these tests)."""
    edges = set()
    for v in range(n):
        deg = rng.integers(1, max_deg + 1)
        before = len(edges)
        for u in rng.choice(n, size=deg, replace=False):
            if u != v:
                edges.add((v, int(u)))
        if len(edges) == before:
            # every pick was the self-loop: force one out-edge so the
            # graph has no dangling vertices (tests rely on that)
            edges.add((v, (v + 1) % n))
    return sorted(edges)


def run_ell_power(indices, weights, n, base, iters):
    pr = np.full(n, 1.0 / n, dtype=np.float32)
    b = np.array([base], dtype=np.float32)
    for _ in range(iters):
        (pr,) = model.ell_step(indices, weights, pr, b)
        pr = np.asarray(pr)
    return pr


@pytest.mark.parametrize("n,max_deg,seed", [(16, 3, 0), (64, 5, 1), (128, 8, 2)])
def test_ell_step_iterates_to_numpy_fixed_point(n, max_deg, seed):
    rng = np.random.default_rng(seed)
    edges = random_graph(rng, n, max_deg)
    max_k = max(sum(1 for v, u in edges if u == t) for t in range(n))
    indices, weights = ref.ell_arrays(n, edges, k=max_k + 1)
    base = (1.0 - 0.85) / n
    got = run_ell_power(indices, weights, n, base, iters=60)
    want, _ = ref.pagerank_power_ref(n, edges, iters=60)
    np.testing.assert_allclose(got, want.astype(np.float32), rtol=5e-4, atol=1e-6)


def test_dense_step_matches_ell_step():
    rng = np.random.default_rng(5)
    n = 32
    edges = random_graph(rng, n, 4)
    max_k = max(sum(1 for v, u in edges if u == t) for t in range(n)) + 1
    indices, weights = ref.ell_arrays(n, edges, k=max_k)
    mat = ref.dense_matrix(n, edges)
    pr = rng.uniform(size=(n,)).astype(np.float32)
    b = np.array([0.01], dtype=np.float32)
    (dense,) = model.dense_step(mat, pr, b)
    (ell,) = model.ell_step(indices, weights, pr, b)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(ell), rtol=1e-4, atol=1e-6)


def test_dense_power_equals_repeated_dense_step():
    rng = np.random.default_rng(9)
    n = 16
    edges = random_graph(rng, n, 3)
    mat = ref.dense_matrix(n, edges)
    b = np.array([(1 - 0.85) / n], dtype=np.float32)
    pr = np.full(n, 1.0 / n, dtype=np.float32)
    (fused,) = model.dense_power(mat, pr, b, steps=8)
    manual = pr
    for _ in range(8):
        (manual,) = model.dense_step(mat, manual, b)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(manual), rtol=1e-5, atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_rank_mass_conserved_without_dangling(seed):
    """Σ pr stays 1 when every vertex has out-links (no dangling leak)."""
    rng = np.random.default_rng(seed)
    n = 32
    edges = random_graph(rng, n, 4)
    max_k = max(sum(1 for v, u in edges if u == t) for t in range(n)) + 1
    indices, weights = ref.ell_arrays(n, edges, k=max_k)
    base = (1.0 - 0.85) / n
    pr = run_ell_power(indices, weights, n, base, iters=40)
    assert abs(float(pr.sum()) - 1.0) < 1e-3


def test_ell_shapes_helpers():
    idx, w, pr, base = model.ell_shapes(256, 16)
    assert idx.shape == (256, 16) and w.shape == (256, 16)
    assert pr.shape == (256,) and base.shape == (1,)
    m, pr2, b2 = model.dense_shapes(64)
    assert m.shape == (64, 64) and pr2.shape == (64,) and b2.shape == (1,)
