"""Layer-1 correctness: the Pallas ELL gather kernel vs the pure-jnp oracle.

The deterministic grid covers the artifact buckets; the hypothesis section
sweeps random shapes/values — the CORE correctness signal for everything
the Rust runtime will execute.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import pagerank_step, ref


def random_case(rng, n, k):
    indices = rng.integers(0, n, size=(n, k), dtype=np.int32)
    weights = rng.uniform(0.0, 1.0, size=(n, k)).astype(np.float32)
    # zero out a random padding suffix per row, like real ELL layouts
    for row in range(n):
        pad = rng.integers(0, k + 1)
        if pad:
            weights[row, k - pad:] = 0.0
            indices[row, k - pad:] = 0
    pr = rng.uniform(0.0, 1.0, size=(n,)).astype(np.float32)
    return indices, weights, pr


@pytest.mark.parametrize("n,k", [(8, 2), (64, 4), (128, 8), (256, 16), (512, 32)])
def test_kernel_matches_ref_grid(n, k):
    rng = np.random.default_rng(n * 1000 + k)
    indices, weights, pr = random_case(rng, n, k)
    got = pagerank_step.ell_contributions(indices, weights, pr)
    want = ref.ell_contributions_ref(indices, weights, pr)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("tile", [1, 2, 64, 128, 999])
def test_tile_size_does_not_change_result(tile):
    rng = np.random.default_rng(7)
    indices, weights, pr = random_case(rng, 128, 8)
    want = ref.ell_contributions_ref(indices, weights, pr)
    got = pagerank_step.ell_contributions(indices, weights, pr, tile_rows=tile)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_zero_weights_give_zero():
    n, k = 32, 4
    indices = np.zeros((n, k), dtype=np.int32)
    weights = np.zeros((n, k), dtype=np.float32)
    pr = np.ones(n, dtype=np.float32)
    got = pagerank_step.ell_contributions(indices, weights, pr)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(n, dtype=np.float32))


def test_single_lane_is_gather():
    # K=1: the kernel is exactly w * pr[idx].
    n = 16
    rng = np.random.default_rng(3)
    indices = rng.integers(0, n, size=(n, 1), dtype=np.int32)
    weights = rng.uniform(size=(n, 1)).astype(np.float32)
    pr = rng.uniform(size=(n,)).astype(np.float32)
    got = np.asarray(pagerank_step.ell_contributions(indices, weights, pr))
    want = weights[:, 0] * pr[indices[:, 0]]
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    n_exp=st.integers(min_value=1, max_value=7),
    k=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(n_exp, k, seed):
    """Shape/value sweep: any (2^n_exp, k) ELL instance matches the oracle."""
    n = 2 ** n_exp
    rng = np.random.default_rng(seed)
    indices, weights, pr = random_case(rng, n, k)
    got = pagerank_step.ell_contributions(indices, weights, pr)
    want = ref.ell_contributions_ref(indices, weights, pr)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_kernel_linear_in_pr(seed):
    """Algebraic property: contributions are linear in the rank vector."""
    rng = np.random.default_rng(seed)
    n, k = 64, 4
    indices, weights, pr = random_case(rng, n, k)
    a = np.float32(rng.uniform(0.5, 2.0))
    got_scaled = np.asarray(pagerank_step.ell_contributions(indices, weights, a * pr))
    got = np.asarray(pagerank_step.ell_contributions(indices, weights, pr))
    np.testing.assert_allclose(got_scaled, a * got, rtol=1e-5, atol=1e-6)


def test_vmem_estimate_monotone():
    small = pagerank_step.vmem_bytes_per_step(256, 16)
    large = pagerank_step.vmem_bytes_per_step(4096, 64)
    assert 0 < small < large
