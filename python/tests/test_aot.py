"""AOT pipeline: lowering produces non-empty, well-formed HLO text whose
entry computation carries the expected parameter shapes."""

import os

import pytest

from compile import aot, model

import jax
import jax.numpy as jnp
import numpy as np


def test_lower_ell_small_bucket_mentions_shapes():
    text = aot.lower_ell(256, 16)
    assert "HloModule" in text
    assert "s32[256,16]" in text  # indices
    assert "f32[256,16]" in text  # weights
    assert "f32[256]" in text  # pr
    assert "f32[1]" in text  # base


def test_lower_dense_power_contains_loop_or_unroll():
    text = aot.lower_dense_power(64, 4)
    assert "HloModule" in text
    assert "f32[64,64]" in text


def test_build_all_writes_every_bucket(tmp_path):
    # Monkeypatch the ladders down so the test is quick but the path is real.
    old_ell, old_dense, old_power = aot.ELL_BUCKETS, aot.DENSE_BUCKETS, aot.POWER_BUCKETS
    aot.ELL_BUCKETS, aot.DENSE_BUCKETS, aot.POWER_BUCKETS = [(64, 4)], [16], [(16, 2)]
    try:
        written = aot.build_all(str(tmp_path))
    finally:
        aot.ELL_BUCKETS, aot.DENSE_BUCKETS, aot.POWER_BUCKETS = old_ell, old_dense, old_power
    names = sorted(os.path.basename(p) for p in written)
    assert names == ["dense_n16.hlo.txt", "dense_power_n16_t2.hlo.txt", "ell_n64_k4.hlo.txt"]
    for p in written:
        text = open(p).read()
        assert text.startswith("HloModule"), p
        assert len(text) > 200, p


def test_lowered_ell_executes_like_eager():
    """Compile the lowered StableHLO back through jax and compare with the
    eager model — guards against lowering-time shape/layout bugs."""
    n, k = 64, 4
    rng = np.random.default_rng(11)
    indices = rng.integers(0, n, size=(n, k), dtype=np.int32)
    weights = rng.uniform(size=(n, k)).astype(np.float32)
    pr = rng.uniform(size=(n,)).astype(np.float32)
    base = np.array([0.002], dtype=np.float32)
    compiled = jax.jit(model.ell_step).lower(indices, weights, pr, base).compile()
    (got,) = compiled(indices, weights, pr, base)
    (want,) = model.ell_step(indices, weights, pr, base)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
