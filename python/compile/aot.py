"""AOT pipeline: lower the Layer-2 model (with its Layer-1 Pallas kernel)
to HLO **text** artifacts the Rust runtime compiles through PJRT.

Run once per build (`make artifacts`):

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange is HLO text, NOT a serialized ``HloModuleProto``: jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 (the version the
published ``xla`` crate binds) rejects; the text parser reassigns ids. See
/opt/xla-example/README.md.

Artifact naming encodes the shape bucket (parsed by
``rust/src/runtime/artifacts.rs``):

    ell_n{N}_k{K}.hlo.txt         ELL step buckets
    dense_n{N}.hlo.txt            dense step buckets
    dense_power_n{N}_t{T}.hlo.txt fused power iteration
"""

import argparse
import functools
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# Shape buckets. The ELL ladder covers the graphs the XlaBlock variant and
# the integration tests use; extend the list and re-run `make artifacts`
# to serve bigger graphs.
ELL_BUCKETS = [(256, 16), (1024, 32), (1024, 128), (4096, 64), (4096, 256)]
DENSE_BUCKETS = [64, 256]
POWER_BUCKETS = [(256, 8)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_ell(n: int, k: int) -> str:
    lowered = jax.jit(model.ell_step).lower(*model.ell_shapes(n, k))
    return to_hlo_text(lowered)


def lower_dense(n: int) -> str:
    lowered = jax.jit(model.dense_step).lower(*model.dense_shapes(n))
    return to_hlo_text(lowered)


def lower_dense_power(n: int, steps: int) -> str:
    fn = functools.partial(model.dense_power, steps=steps)
    lowered = jax.jit(fn).lower(*model.dense_shapes(n))
    return to_hlo_text(lowered)


def build_all(out_dir: str) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    written = []

    def emit(name: str, text: str):
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        written.append(path)
        print(f"  {name}: {len(text)} chars")

    for n, k in ELL_BUCKETS:
        emit(f"ell_n{n}_k{k}.hlo.txt", lower_ell(n, k))
    for n in DENSE_BUCKETS:
        emit(f"dense_n{n}.hlo.txt", lower_dense(n))
    for n, t in POWER_BUCKETS:
        emit(f"dense_power_n{n}_t{t}.hlo.txt", lower_dense_power(n, t))
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    print(f"AOT-lowering artifacts to {args.out_dir}")
    written = build_all(args.out_dir)
    print(f"wrote {len(written)} artifacts")


if __name__ == "__main__":
    main()
