"""Layer 1 — the PageRank gather/accumulate hot-spot as a Pallas kernel.

The paper's inner loop (Eq. 1) is, per vertex,

    contrib(u) = sum_{(v,u) in E} pr(v) / outdeg(v)

which in the padded-CSR (ELL) layout the Rust coordinator builds
(`rust/src/pagerank/xla_block.rs`) becomes a dense, tileable gather:

    contrib[u] = sum_k weights[u, k] * pr[indices[u, k]]

with `weights[u, k] = d / outdeg(v_k)` and zero-weight padding.

TPU mapping (DESIGN.md §Hardware-Adaptation): each grid step streams one
``(TILE_ROWS, K)`` tile of `indices`/`weights` HBM→VMEM while the full rank
vector stays VMEM-resident (N ≤ 4096 f32 = 16 KiB, far under the ~16 MiB
VMEM budget); the gather + multiply-accumulate is VPU work — the op is
memory-bound, so the roofline target is HBM bandwidth, not the MXU. The
dense variant in `model.py` (`jnp.matmul`) covers the MXU path for small
blocks.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers the kernel to plain HLO so the
AOT-compiled artifact runs on the Rust CPU client while keeping the same
BlockSpec structure a real TPU lowering would use.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 128 divides every artifact bucket (smallest is 256)
# and keeps the tile square-ish relative to K ∈ {16, 32, 64}: the
# (128, 64) f32 tile is 32 KiB of weights + 32 KiB of indices per step.
DEFAULT_TILE_ROWS = 128


def _ell_tile_kernel(idx_ref, w_ref, pr_ref, o_ref):
    """One (TILE_ROWS, K) tile: gather ranks, weight, reduce over K."""
    idx = idx_ref[...]  # (T, K) int32
    w = w_ref[...]  # (T, K) f32
    pr = pr_ref[...]  # (N,)  f32 — full vector, VMEM-resident
    gathered = jnp.take(pr, idx, axis=0)  # (T, K)
    o_ref[...] = jnp.sum(w * gathered, axis=1)


@partial(jax.jit, static_argnames=("tile_rows",))
def ell_contributions(indices, weights, pr, tile_rows=DEFAULT_TILE_ROWS):
    """Weighted-gather contributions, tiled over rows.

    Args:
      indices: ``(N, K) int32`` — in-neighbour ids, 0-padded.
      weights: ``(N, K) float32`` — ``d / outdeg``, 0-padded.
      pr:      ``(N,) float32`` — current ranks.
      tile_rows: rows per grid step; must divide N.

    Returns:
      ``(N,) float32`` — ``sum_k weights[u,k] * pr[indices[u,k]]``.
    """
    n, k = indices.shape
    if n % tile_rows != 0:
        # bucket sizes are powers of two ≥ 256; smaller test shapes fall
        # back to a single whole-array tile.
        tile_rows = n
    grid = (n // tile_rows,)
    return pl.pallas_call(
        _ell_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(indices, weights, pr)


def vmem_bytes_per_step(n, k, tile_rows=DEFAULT_TILE_ROWS):
    """Estimated VMEM footprint of one grid step (profiling aid; see
    EXPERIMENTS.md §Perf L1). indices + weights tiles, the resident rank
    vector, and the output slice."""
    t = min(tile_rows, n)
    return 4 * (t * k  # indices tile (int32)
                + t * k  # weights tile (f32)
                + n  # rank vector (f32)
                + t)  # output slice (f32)
