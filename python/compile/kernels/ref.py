"""Pure-jnp oracles for the Layer-1 kernel and the Layer-2 model.

These are the correctness ground truth: `python/tests/` asserts the Pallas
kernel and the lowered model match these to float32 tolerance, and the Rust
integration test compares the AOT artifact against the Rust sequential
solver on the same graphs.
"""

import jax.numpy as jnp
import numpy as np


def ell_contributions_ref(indices, weights, pr):
    """Reference for `pagerank_step.ell_contributions`."""
    return jnp.sum(weights * pr[indices], axis=1)


def ell_step_ref(indices, weights, pr, base):
    """One full PageRank step in ELL form: ``base + contributions``."""
    return base + ell_contributions_ref(indices, weights, pr)


def dense_matrix(n, edges, damping=0.85, dtype=np.float32):
    """Dense PageRank matrix M with damping folded in:
    ``M[u, v] = damping / outdeg(v)`` for each edge ``v -> u``."""
    out_deg = np.zeros(n, dtype=np.int64)
    for v, _u in edges:
        out_deg[v] += 1
    m = np.zeros((n, n), dtype=dtype)
    for v, u in edges:
        m[u, v] += damping / out_deg[v]
    return m


def ell_arrays(n, edges, k, damping=0.85):
    """Build the padded ELL arrays the Rust coordinator builds
    (`EllLayout::build`), for cross-checking layouts in tests."""
    out_deg = np.zeros(n, dtype=np.int64)
    for v, _u in edges:
        out_deg[v] += 1
    indices = np.zeros((n, k), dtype=np.int32)
    weights = np.zeros((n, k), dtype=np.float32)
    fill = np.zeros(n, dtype=np.int64)
    for v, u in edges:
        j = fill[u]
        assert j < k, f"vertex {u} in-degree exceeds K={k}"
        indices[u, j] = v
        weights[u, j] = damping / out_deg[v]
        fill[u] += 1
    return indices, weights


def pagerank_power_ref(n, edges, damping=0.85, iters=100, tol=1e-10):
    """Double-precision NumPy power iteration (Eq. 1, no dangling
    redistribution — the paper's formulation). Returns (ranks, iterations)."""
    out_deg = np.zeros(n, dtype=np.int64)
    for v, _u in edges:
        out_deg[v] += 1
    pr = np.full(n, 1.0 / n)
    base = (1.0 - damping) / n
    for it in range(1, iters + 1):
        nxt = np.full(n, base)
        for v, u in edges:
            nxt[u] += damping * pr[v] / out_deg[v]
        err = np.abs(nxt - pr).max()
        pr = nxt
        if err <= tol:
            return pr, it
    return pr, iters
