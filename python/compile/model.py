"""Layer 2 — the PageRank step as a JAX computation.

Wraps the Layer-1 Pallas kernel (`kernels.pagerank_step`) into the full
Eq.-1 update the Rust coordinator drives:

    pr' = base + sum_k weights[u, k] * pr[indices[u, k]]

plus a dense-matmul variant (MXU path for small blocks) and a fused
`lax.scan` power iteration used by the runtime bench to amortize dispatch.

All functions return 1-tuples: `aot.py` lowers with ``return_tuple=True``
and the Rust side unwraps with ``to_tuple1()`` (see
/opt/xla-example/load_hlo).
"""

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels import pagerank_step


def ell_step(indices, weights, pr, base):
    """One ELL PageRank step through the Pallas kernel.

    Args:
      indices: ``(N, K) int32``; weights: ``(N, K) float32``;
      pr: ``(N,) float32``; base: ``(1,) float32`` = ``(1-d)/n_actual``.
    """
    contrib = pagerank_step.ell_contributions(indices, weights, pr)
    return (contrib + base[0],)


def dense_step(matrix, pr, base):
    """One dense step: ``base + M @ pr`` (damping folded into ``M``)."""
    return (matrix @ pr + base[0],)


def dense_power(matrix, pr, base, steps: int):
    """``steps`` fused dense iterations (single dispatch from Rust)."""

    def body(p, _):
        return matrix @ p + base[0], None

    out, _ = lax.scan(body, pr, None, length=steps)
    return (out,)


def ell_shapes(n: int, k: int):
    """Example args for lowering an (n, k) ELL bucket."""
    return (
        jax.ShapeDtypeStruct((n, k), jnp.int32),
        jax.ShapeDtypeStruct((n, k), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )


def dense_shapes(n: int):
    """Example args for lowering an n-vertex dense bucket."""
    return (
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )
