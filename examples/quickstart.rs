//! Quickstart: build a graph, run the paper's lock-free PageRank, inspect
//! the result.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pagerank_nb::graph::synthetic;
use pagerank_nb::pagerank::{self, PrConfig, Variant};
use pagerank_nb::util::fmt;

fn main() -> anyhow::Result<()> {
    // 1. A scale-free "web" graph: 20k pages, ~8 links each.
    let graph = synthetic::web_replica(20_000, 8, 42);
    println!(
        "graph: {} vertices, {} edges",
        fmt::count(graph.num_vertices() as u64),
        fmt::count(graph.num_edges() as u64)
    );

    // 2. Configure: 4 threads, default damping 0.85 / threshold 1e-10.
    let cfg = PrConfig { threads: 4, ..PrConfig::default() };

    // 3. The paper's headline algorithm: No-Sync (lock-free, no barriers).
    let result = pagerank::run(&graph, Variant::NoSync, &cfg)?;
    println!(
        "No-Sync: converged={} in {} ({} iterations, per-thread {:?})",
        result.converged,
        fmt::duration(result.elapsed.as_secs_f64()),
        result.iterations,
        result.per_thread_iterations,
    );

    // 4. Compare with the sequential baseline: same ranks, Lemma 2.
    let seq = pagerank::run(&graph, Variant::Sequential, &cfg)?;
    println!(
        "sequential: {} ({} iterations); L1 distance = {}",
        fmt::duration(seq.elapsed.as_secs_f64()),
        seq.iterations,
        fmt::sci(result.l1_norm(&seq.ranks))
    );

    // 5. Most important pages.
    println!("top pages:");
    for (i, (u, score)) in result.top_k(5).into_iter().enumerate() {
        println!("  #{} vertex {:<8} pr={}", i + 1, u, fmt::sci(score));
    }
    Ok(())
}
