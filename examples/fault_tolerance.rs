//! Fault-tolerance demo — the paper's sleeping/failing case studies
//! (Figs 8–9) as a narrative walkthrough.
//!
//! ```bash
//! cargo run --release --example fault_tolerance
//! ```

use pagerank_nb::coordinator::faults::FaultPlan;
use pagerank_nb::graph::synthetic;
use pagerank_nb::pagerank::{self, PrConfig, Variant};
use pagerank_nb::util::fmt;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let graph = synthetic::web_replica(6_000, 6, 7);
    println!(
        "graph: {} vertices, {} edges, 4 threads\n",
        graph.num_vertices(),
        graph.num_edges()
    );
    let base = PrConfig {
        threads: 4,
        dnf_timeout: Some(Duration::from_secs(15)),
        ..PrConfig::default()
    };

    println!("── scenario 1: one thread naps 500 ms at iteration 1 (Fig 8) ──");
    let nap = FaultPlan::none().sleep_at(0, 1, Duration::from_millis(500));
    for v in [Variant::Barrier, Variant::NoSync, Variant::WaitFree] {
        let cfg = PrConfig { faults: nap.clone(), ..base.clone() };
        let r = pagerank::run(&graph, v, &cfg)?;
        println!(
            "  {:<12} {:>10}  (converged: {})",
            v.name(),
            fmt::duration(r.elapsed.as_secs_f64()),
            r.converged
        );
    }
    println!("  → Barrier & No-Sync absorb the nap; Wait-Free helpers route around it.\n");

    println!("── scenario 2: one thread crashes at iteration 1 (Fig 9) ──");
    let crash = FaultPlan::none().fail_at(0, 1);
    for v in [Variant::Barrier, Variant::NoSync, Variant::WaitFree] {
        let cfg = PrConfig { faults: crash.clone(), ..base.clone() };
        let r = pagerank::run(&graph, v, &cfg)?;
        if r.dnf {
            println!("  {:<12}        DNF  (watchdog cut a wedged run)", v.name());
        } else {
            println!(
                "  {:<12} {:>10}  (converged: {})",
                v.name(),
                fmt::duration(r.elapsed.as_secs_f64()),
                r.converged
            );
        }
    }
    println!("  → only the Wait-Free (Barrier-Helper) algorithm completes.\n");

    println!("── scenario 3: escalating failures, Wait-Free only ──");
    for k in 0..=3 {
        let cfg = PrConfig {
            faults: FaultPlan::fail_first_k(k),
            dnf_timeout: Some(Duration::from_secs(60)),
            ..base.clone()
        };
        let r = pagerank::run(&graph, Variant::WaitFree, &cfg)?;
        println!(
            "  {k} failed: {:>10}  (converged: {})",
            fmt::duration(r.elapsed.as_secs_f64()),
            r.converged
        );
    }
    println!("  → time grows as fewer live threads carry the work — Fig 9's shape.");
    Ok(())
}
