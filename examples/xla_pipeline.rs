//! Three-layer pipeline demo: Rust coordinator → AOT-compiled JAX model →
//! Pallas ELL kernel, all through PJRT with Python nowhere at runtime.
//!
//! Requires `make artifacts` first.
//!
//! ```bash
//! make artifacts && cargo run --release --example xla_pipeline
//! ```

use pagerank_nb::graph::synthetic;
use pagerank_nb::pagerank::{self, PrConfig, Variant};
use pagerank_nb::runtime::{artifacts, ArtifactSpec, Engine};
use pagerank_nb::util::fmt;

fn main() -> anyhow::Result<()> {
    let dir = artifacts::default_dir();
    let specs = ArtifactSpec::discover(&dir)?;
    if specs.is_empty() {
        eprintln!("no artifacts in {} — run `make artifacts` first", dir.display());
        std::process::exit(2);
    }
    println!("discovered {} artifacts:", specs.len());
    for s in &specs {
        println!("  {:?} n={} k={} t={} ({})", s.kind, s.n, s.k, s.t, s.path.display());
    }

    let engine = Engine::cpu()?;
    println!("PJRT platform: {}\n", engine.platform());

    let cfg = PrConfig { threads: 1, threshold: 1e-7, ..PrConfig::default() };
    for graph in [
        synthetic::cycle(64),
        synthetic::star(200),
        synthetic::web_replica(800, 6, 99),
        synthetic::road_replica(2_500, 99),
    ] {
        let xla = pagerank::run_with_engine(&graph, Variant::XlaBlock, &cfg, &engine)?;
        let seq = pagerank::run(&graph, Variant::Sequential, &cfg)?;
        println!(
            "{:<22} n={:<6} xla: {:>9} ({} iters)   seq: {:>9}   L1 = {}",
            graph.name,
            graph.num_vertices(),
            fmt::duration(xla.elapsed.as_secs_f64()),
            xla.iterations,
            fmt::duration(seq.elapsed.as_secs_f64()),
            fmt::sci(xla.l1_norm(&seq.ranks)),
        );
    }
    println!("\n(Python ran once at `make artifacts`; this binary only loaded HLO text.)");
    Ok(())
}
