//! Thread-scaling sweep (Figs 3–4 miniature): speedup of each
//! synchronization family as the thread count grows.
//!
//! ```bash
//! cargo run --release --example scaling [vertices]
//! ```

use pagerank_nb::coordinator::host::HostInfo;
use pagerank_nb::graph::synthetic;
use pagerank_nb::pagerank::{self, PrConfig, Variant};
use pagerank_nb::util::report::Table;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8_000);
    let host = HostInfo::detect();
    let graph = synthetic::web_replica(n, 8, 13);
    eprintln!(
        "{} vertices, {} edges · host parallelism {}",
        graph.num_vertices(),
        graph.num_edges(),
        host.available_parallelism
    );

    let seq = pagerank::run(&graph, Variant::Sequential, &PrConfig::default())?;
    let seq_secs = seq.elapsed.as_secs_f64();

    let variants = [Variant::Barrier, Variant::BarrierEdge, Variant::NoSync, Variant::WaitFree];
    let mut headers = vec!["threads".to_string()];
    headers.extend(variants.iter().map(|v| format!("{v} (x)")));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new("Speed-up vs threads", &hdr);

    for threads in host.thread_sweep() {
        let cfg = PrConfig { threads, ..PrConfig::default() };
        let mut row: Vec<pagerank_nb::util::report::Cell> = vec![threads.into()];
        for v in variants {
            let r = pagerank::run(&graph, v, &cfg)?;
            row.push((seq_secs / r.elapsed.as_secs_f64()).into());
        }
        table.push_row(row);
    }
    table.note(host.describe());
    table.note("paper shape (56-core Xeon): No-Sync keeps climbing, Barrier flattens as wait time grows");
    println!("{}", table.to_markdown());
    Ok(())
}
