//! End-to-end driver: the full system on a realistic workload.
//!
//! Builds the webStanford-class replica, runs **every** variant of the
//! paper across the synchronization spectrum, and reports the paper's
//! headline metrics (speedup over sequential, iterations, L1-norm) — a
//! miniature of Figs 1, 5 and 7 in one binary. This is the run recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! cargo run --release --example web_ranking [divisor] [threads]
//! ```

use pagerank_nb::coordinator::host::HostInfo;
use pagerank_nb::graph::synthetic;
use pagerank_nb::pagerank::{self, PrConfig, Variant};
use pagerank_nb::util::report::Table;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let divisor: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(40);
    let host = HostInfo::detect();
    let threads: usize = args
        .get(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| host.default_threads());

    // webStanford-class replica (Table 1: 281,903 vertices / 2,312,497
    // edges at full scale).
    let graph = synthetic::web_replica(281_903 / divisor, 8, 42);
    eprintln!(
        "webStanford replica at 1/{divisor}: {} vertices, {} edges · {} threads",
        graph.num_vertices(),
        graph.num_edges(),
        threads
    );

    let cfg = PrConfig {
        threads,
        dnf_timeout: Some(std::time::Duration::from_secs(120)),
        ..PrConfig::default()
    };
    let seq = pagerank::run(&graph, Variant::Sequential, &cfg)?;
    let seq_secs = seq.elapsed.as_secs_f64();

    let mut table = Table::new(
        "Web ranking — all programs (Figs 1/5/7 miniature)",
        &["program", "time (s)", "speedup (x)", "iterations", "L1 vs seq", "converged"],
    );
    table.push_row(vec![
        "Sequential".into(),
        seq_secs.into(),
        1.0.into(),
        (seq.iterations as i64).into(),
        0.0.into(),
        "yes".into(),
    ]);
    for v in Variant::parallel_cpu() {
        let r = pagerank::run(&graph, v, &cfg)?;
        let secs = r.elapsed.as_secs_f64();
        table.push_row(vec![
            v.name().into(),
            secs.into(),
            (seq_secs / secs).into(),
            (r.iterations as i64).into(),
            r.l1_norm(&seq.ranks).into(),
            if r.converged { "yes" } else { "NO" }.into(),
        ]);
    }
    table.note(host.describe());
    println!("{}", table.to_markdown());
    Ok(())
}
