//! End-to-end driver: the full system on a realistic, *evolving* workload.
//!
//! Three acts on a webStanford-class replica (Table 1: 281,903 vertices /
//! 2,312,497 edges at full scale):
//!
//! 1. **Cold ranking** — every variant of the paper across the
//!    synchronization spectrum, with the headline metrics (speedup over
//!    sequential, iterations, L1-norm): a miniature of Figs 1, 5 and 7.
//!    This is the run recorded in EXPERIMENTS.md §End-to-end.
//! 2. **Evolve-query-reconverge** — the graph mutates in random edge
//!    batches; after each batch the frontier kernel reconverges
//!    *incrementally* from the previous ranks and publishes an epoch
//!    snapshot, while reader threads keep answering `rank`/`top_k`
//!    queries against the last published epoch throughout.
//! 3. **Incremental vs cold** — the final epoch's cost in `vertex_updates`
//!    against a cold Barrier recompute of the same mutated graph.
//!
//! ```bash
//! cargo run --release --example web_ranking [divisor] [threads]
//! ```

use pagerank_nb::coordinator::host::HostInfo;
use pagerank_nb::graph::{synthetic, GraphDelta};
use pagerank_nb::pagerank::{self, PrConfig, Variant};
use pagerank_nb::serving::ServingEngine;
use pagerank_nb::util::report::Table;
use pagerank_nb::util::{fmt, rng::Xoshiro256pp};
use std::sync::atomic::{AtomicBool, Ordering};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let divisor: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(40);
    let host = HostInfo::detect();
    let threads: usize = args
        .get(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| host.default_threads());

    let graph = synthetic::web_replica(281_903 / divisor, 8, 42);
    eprintln!(
        "webStanford replica at 1/{divisor}: {} vertices, {} edges · {} threads",
        graph.num_vertices(),
        graph.num_edges(),
        threads
    );

    let cfg = PrConfig {
        threads,
        dnf_timeout: Some(std::time::Duration::from_secs(120)),
        ..PrConfig::default()
    };

    // ── Act 1: cold ranking, every program ─────────────────────────────
    let seq = pagerank::run(&graph, Variant::Sequential, &cfg)?;
    let seq_secs = seq.elapsed.as_secs_f64();

    let mut table = Table::new(
        "Web ranking — all programs (Figs 1/5/7 miniature)",
        &["program", "time (s)", "speedup (x)", "iterations", "L1 vs seq", "converged"],
    );
    table.push_row(vec![
        "Sequential".into(),
        seq_secs.into(),
        1.0.into(),
        (seq.iterations as i64).into(),
        0.0.into(),
        "yes".into(),
    ]);
    for v in Variant::parallel_cpu() {
        let r = pagerank::run(&graph, v, &cfg)?;
        let secs = r.elapsed.as_secs_f64();
        table.push_row(vec![
            v.name().into(),
            secs.into(),
            (seq_secs / secs).into(),
            (r.iterations as i64).into(),
            r.l1_norm(&seq.ranks).into(),
            if r.converged { "yes" } else { "NO" }.into(),
        ]);
    }
    table.note(host.describe());
    println!("{}", table.to_markdown());

    // ── Act 2: the graph evolves while queries keep flowing ────────────
    let epochs = 4u64;
    let batch = (graph.num_edges() / 100).clamp(4, 256);
    eprintln!(
        "\nserving: {epochs} mutation epochs of +{batch}/-{} edges each, \
         2 readers querying throughout",
        batch / 2
    );
    let mut engine = ServingEngine::bootstrap(graph, Variant::Frontier, cfg.clone())?;
    let server = engine.server();
    let done = AtomicBool::new(false);
    let outcome: anyhow::Result<u64> = std::thread::scope(|s| {
        for r in 0..2u64 {
            let server = engine.server();
            let done = &done;
            s.spawn(move || {
                let mut rng = Xoshiro256pp::seed_from_u64(7 + r);
                while !done.load(Ordering::Acquire) {
                    let snap = server.snapshot();
                    assert!(snap.verify(), "reader observed a torn snapshot");
                    if !snap.is_empty() {
                        server.rank(rng.next_below(snap.len() as u64) as u32);
                    }
                    server.top_k(3);
                    std::thread::yield_now();
                }
            });
        }
        let run = (|| -> anyhow::Result<u64> {
            let mut last_updates = 0;
            for e in 0..epochs {
                let delta = GraphDelta::random(engine.graph(), batch, batch / 2, 100 + e);
                let stats = engine.apply(&delta)?;
                println!(
                    "epoch {}: {} touched · {} iters · {} vertex updates · {}{}",
                    stats.epoch,
                    stats.touched,
                    stats.iterations,
                    fmt::count(stats.vertex_updates),
                    fmt::duration(stats.elapsed_secs),
                    if stats.converged { "" } else { " [NOT converged]" }
                );
                last_updates = stats.vertex_updates;
            }
            Ok(last_updates)
        })();
        done.store(true, Ordering::Release);
        run
    });
    let last_updates = outcome?;
    println!(
        "served {} queries across {} epochs",
        fmt::count(server.queries_served()),
        engine.epoch()
    );

    // ── Act 3: what did incrementality buy? ────────────────────────────
    let cold = pagerank::run(engine.graph(), Variant::Barrier, &cfg)?;
    let snap = server.snapshot();
    let l1 = pagerank_nb::pagerank::convergence::l1_norm(snap.ranks(), &cold.ranks);
    println!(
        "final epoch: {} incremental vertex updates vs {} cold (Barrier, \
         {} iters × {} vertices) · L1 vs cold recompute {}",
        fmt::count(last_updates),
        fmt::count(cold.vertex_updates),
        cold.iterations,
        fmt::count(engine.graph().num_vertices() as u64),
        fmt::sci(l1)
    );
    Ok(())
}
