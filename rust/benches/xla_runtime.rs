//! `cargo bench --bench xla_runtime` — regenerates: XLA artifact runtime comparison.
//!
//! Thin wrapper over `harness::experiments::run_experiment("xla")`; the
//! same table is produced by `pagerank-nb bench xla`. Reports land in
//! `reports/` (markdown + CSV + JSON). Knobs: PAGERANK_NB_SCALE,
//! PAGERANK_NB_BENCH_SAMPLES, PAGERANK_NB_BENCH_WARMUP.

use pagerank_nb::harness::experiments::{run_experiment, Ctx};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::default();
    let tables = run_experiment("xla", &ctx)?;
    let out = std::path::Path::new("reports");
    for (i, t) in tables.iter().enumerate() {
        println!("{}", t.to_markdown());
        let stem = if tables.len() == 1 {
            "xla".to_string()
        } else {
            format!("{}_{}", "xla", (b'a' + i as u8) as char)
        };
        t.write_all(out, &stem)?;
    }
    Ok(())
}
