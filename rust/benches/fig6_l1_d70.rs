//! `cargo bench --bench fig6_l1_d70` — regenerates: Fig 6 speedup + L1-norm (D70).
//!
//! Thin wrapper over `harness::experiments::run_experiment("fig6")`; the
//! same table is produced by `pagerank-nb bench fig6`. Reports land in
//! `reports/` (markdown + CSV + JSON). Knobs: PAGERANK_NB_SCALE,
//! PAGERANK_NB_BENCH_SAMPLES, PAGERANK_NB_BENCH_WARMUP.

use pagerank_nb::harness::experiments::{run_experiment, Ctx};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::default();
    let tables = run_experiment("fig6", &ctx)?;
    let out = std::path::Path::new("reports");
    for (i, t) in tables.iter().enumerate() {
        println!("{}", t.to_markdown());
        let stem = if tables.len() == 1 {
            "fig6".to_string()
        } else {
            format!("{}_{}", "fig6", (b'a' + i as u8) as char)
        };
        t.write_all(out, &stem)?;
    }
    Ok(())
}
