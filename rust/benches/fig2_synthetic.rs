//! `cargo bench --bench fig2_synthetic` — regenerates: Fig 2 speedup vs programs (synthetic datasets).
//!
//! Thin wrapper over `harness::experiments::run_experiment("fig2")`; the
//! same table is produced by `pagerank-nb bench fig2`. Reports land in
//! `reports/` (markdown + CSV + JSON). Knobs: PAGERANK_NB_SCALE,
//! PAGERANK_NB_BENCH_SAMPLES, PAGERANK_NB_BENCH_WARMUP.

use pagerank_nb::harness::experiments::{run_experiment, Ctx};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::default();
    let tables = run_experiment("fig2", &ctx)?;
    let out = std::path::Path::new("reports");
    for (i, t) in tables.iter().enumerate() {
        println!("{}", t.to_markdown());
        let stem = if tables.len() == 1 {
            "fig2".to_string()
        } else {
            format!("{}_{}", "fig2", (b'a' + i as u8) as char)
        };
        t.write_all(out, &stem)?;
    }
    Ok(())
}
