//! Fault-injection integration — the paper's §5.3 case studies end-to-end:
//! sleeping threads (Fig 8) and failing threads (Fig 9) across the three
//! synchronization families.

use pagerank_nb::coordinator::faults::FaultPlan;
use pagerank_nb::graph::synthetic;
use pagerank_nb::pagerank::{self, seq, PrConfig, Variant};
use std::time::Duration;

fn cfg(threads: usize) -> PrConfig {
    PrConfig {
        threads,
        threshold: 1e-10,
        max_iterations: 2_000,
        dnf_timeout: Some(Duration::from_secs(30)),
        ..PrConfig::default()
    }
}

/// Fig 9 core claim: a crashed thread wedges Barrier *and* No-Sync (DNF via
/// watchdog), while Wait-Free completes and still gets the right answer.
#[test]
fn failure_matrix_matches_paper() {
    let g = synthetic::web_replica(500, 6, 201);
    let faults = FaultPlan::none().fail_at(0, 1);
    let c = PrConfig {
        faults,
        dnf_timeout: Some(Duration::from_secs(5)),
        ..cfg(4)
    };

    let barrier = pagerank::run(&g, Variant::Barrier, &c).unwrap();
    assert!(barrier.dnf, "Barrier must wedge when a thread dies");
    assert!(!barrier.converged);

    // No-Sync: the dead thread's error slot never clears, so live threads
    // either spin to the watchdog (dnf) or burn out the iteration cap —
    // both are "fails to handle thread failure" per the paper.
    let nosync = pagerank::run(&g, Variant::NoSync, &c).unwrap();
    assert!(
        nosync.dnf || !nosync.converged,
        "No-Sync must not complete under a dead thread"
    );

    let c_wf = PrConfig { dnf_timeout: Some(Duration::from_secs(60)), ..c.clone() };
    let waitfree = pagerank::run(&g, Variant::WaitFree, &c_wf).unwrap();
    assert!(!waitfree.dnf, "Wait-Free must complete");
    assert!(waitfree.converged);
    let (sr, _, _) = seq::solve(&g, &c_wf);
    assert!(waitfree.l1_norm(&sr) < 1e-6, "l1 {}", waitfree.l1_norm(&sr));
}

/// Sleeping threads delay Barrier and No-Sync by roughly the nap length;
/// Wait-Free's algorithmic completion stays flat (helpers absorb the work).
#[test]
fn sleep_delays_blocking_but_not_waitfree() {
    let g = synthetic::web_replica(300, 5, 202);
    let nap = Duration::from_millis(600);
    let with_sleep = |v: Variant| {
        let c = PrConfig {
            faults: FaultPlan::none().sleep_at(0, 1, nap),
            dnf_timeout: Some(Duration::from_secs(60)),
            // No-Sync's live threads keep sweeping while the sleeper naps
            // (the paper's Fig-8 behaviour); the cap must not cut that off.
            max_iterations: 5_000_000,
            ..cfg(4)
        };
        pagerank::run(&g, v, &c).unwrap()
    };
    let baseline = |v: Variant| pagerank::run(&g, v, &cfg(4)).unwrap();

    for v in [Variant::Barrier, Variant::NoSync] {
        let slow = with_sleep(v);
        let fast = baseline(v);
        assert!(slow.converged && fast.converged);
        assert!(
            slow.elapsed >= fast.elapsed + nap / 2,
            "{v}: sleep did not propagate ({:?} vs {:?})",
            slow.elapsed,
            fast.elapsed
        );
    }
    let wf = with_sleep(Variant::WaitFree);
    assert!(wf.converged);
    assert!(
        wf.elapsed < nap,
        "Wait-Free should finish before the sleeper wakes ({:?})",
        wf.elapsed
    );
}

/// Increasing failure counts: Wait-Free keeps completing down to a single
/// live thread.
#[test]
fn waitfree_survives_escalating_failures() {
    let g = synthetic::cycle(120);
    for k in 1..=3 {
        let c = PrConfig {
            faults: FaultPlan::fail_first_k(k),
            dnf_timeout: Some(Duration::from_secs(60)),
            ..cfg(4)
        };
        let r = pagerank::run(&g, Variant::WaitFree, &c).unwrap();
        assert!(r.converged, "k={k}");
        for &x in &r.ranks {
            assert!((x - 1.0 / 120.0).abs() < 1e-8, "k={k}");
        }
    }
}

/// A sleep scheduled for a never-reached iteration is a no-op.
#[test]
fn sleep_beyond_convergence_is_noop() {
    let g = synthetic::star(60);
    let c = PrConfig {
        faults: FaultPlan::none().sleep_at(0, 100_000, Duration::from_secs(30)),
        ..cfg(2)
    };
    let t0 = std::time::Instant::now();
    let r = pagerank::run(&g, Variant::Barrier, &c).unwrap();
    assert!(r.converged);
    assert!(t0.elapsed() < Duration::from_secs(10));
}

/// Failures on the *other* variants of the family behave like Barrier.
#[test]
fn edge_and_identical_variants_also_wedge_on_failure() {
    let g = synthetic::web_replica(300, 5, 203);
    let c = PrConfig {
        faults: FaultPlan::none().fail_at(1, 1),
        dnf_timeout: Some(Duration::from_secs(5)),
        ..cfg(3)
    };
    for v in [Variant::BarrierEdge, Variant::BarrierIdentical, Variant::NoSyncIdentical] {
        let r = pagerank::run(&g, v, &c).unwrap();
        assert!(r.dnf || !r.converged, "{v} should not complete under failure");
    }
}
