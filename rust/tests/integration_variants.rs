//! Cross-variant integration: every algorithm against the sequential
//! oracle on every dataset family, plus the paper's comparative claims
//! (Lemma 2 agreement, Fig 7 iteration ordering, No-Sync-Edge caveat).

use pagerank_nb::graph::{synthetic, Csr, PartitionPolicy};
use pagerank_nb::pagerank::{self, convergence, seq, PrConfig, Variant};

fn cfg(threads: usize) -> PrConfig {
    PrConfig {
        threads,
        threshold: 1e-11,
        max_iterations: 3_000,
        ..PrConfig::default()
    }
}

fn families() -> Vec<Csr> {
    vec![
        synthetic::cycle(200),
        synthetic::chain(200),
        synthetic::star(150),
        synthetic::web_replica(1_200, 6, 101),
        synthetic::social_replica(800, 7, 102),
        synthetic::road_replica(900, 103),
        synthetic::d_series(1, 400, 104),
    ]
}

/// Exact (non-approximate) parallel variants must match sequential ranks.
#[test]
fn exact_variants_match_sequential_everywhere() {
    let c = cfg(4);
    for g in families() {
        let (sr, _, _) = seq::solve(&g, &c);
        for v in [
            Variant::Barrier,
            Variant::BarrierIdentical,
            Variant::BarrierEdge,
            Variant::WaitFree,
            Variant::NoSync,
            Variant::NoSyncIdentical,
        ] {
            let r = pagerank::run(&g, v, &c).unwrap();
            assert!(r.converged, "{v} did not converge on {}", g.name);
            let l1 = r.l1_norm(&sr);
            assert!(l1 < 1e-6, "{v} on {}: L1 {l1}", g.name);
        }
    }
}

/// Approximate (perforated) variants stay within a loose L1 budget.
#[test]
fn approximate_variants_bounded_error() {
    let c = PrConfig { threshold: 1e-8, ..cfg(4) };
    for g in families() {
        let (sr, _, _) = seq::solve(&g, &c);
        for v in [Variant::BarrierOpt, Variant::NoSyncOpt, Variant::NoSyncOptIdentical] {
            let r = pagerank::run(&g, v, &c).unwrap();
            assert!(r.converged, "{v} did not converge on {}", g.name);
            let l1 = r.l1_norm(&sr);
            assert!(l1 < 1e-2, "{v} on {}: L1 {l1}", g.name);
        }
    }
}

/// Thread-count sweep: results do not depend on parallelism degree.
#[test]
fn results_invariant_across_thread_counts() {
    let g = synthetic::web_replica(900, 6, 105);
    let reference = pagerank::run(&g, Variant::NoSync, &cfg(1)).unwrap();
    for threads in [2, 3, 5, 8] {
        for v in [Variant::NoSync, Variant::Barrier, Variant::WaitFree] {
            let r = pagerank::run(&g, v, &cfg(threads)).unwrap();
            assert!(r.converged);
            let l1 = r.l1_norm(&reference.ranks);
            assert!(l1 < 1e-6, "{v}@{threads}: L1 {l1}");
        }
    }
}

/// Both partition policies give the same fixed point.
#[test]
fn partition_policy_does_not_change_ranks() {
    let g = synthetic::web_replica(800, 7, 106);
    let c = cfg(4);
    let vb = pagerank::run(&g, Variant::NoSync, &c).unwrap();
    let eb = pagerank::run(
        &g,
        Variant::NoSync,
        &PrConfig { partition: PartitionPolicy::EdgeBalanced, ..c },
    )
    .unwrap();
    assert!(convergence::l1_norm(&vb.ranks, &eb.ranks) < 1e-6);
}

/// Fig 7's claim: non-blocking variants need no more iterations than the
/// barrier schedule on the synthetic datasets.
#[test]
fn nosync_iterations_at_most_barrier() {
    let c = cfg(4);
    for i in [1u32, 3] {
        let g = synthetic::d_series(i, 1_000, 107);
        let ns = pagerank::run(&g, Variant::NoSync, &c).unwrap();
        let ba = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        // +2 covers the confirmation sweeps (see nosync.rs)
        assert!(
            ns.iterations <= ba.iterations + 2,
            "D{i}0: No-Sync {} vs Barrier {}",
            ns.iterations,
            ba.iterations
        );
    }
}

/// §4.4: No-Sync-Edge must terminate (cap) even where it does not
/// converge, and must never produce non-finite ranks.
#[test]
fn nosync_edge_terminates_and_stays_finite() {
    let c = PrConfig { max_iterations: 200, ..cfg(4) };
    for g in families() {
        let r = pagerank::run(&g, Variant::NoSyncEdge, &c).unwrap();
        assert!(r.iterations <= 200, "{}", g.name);
        assert!(
            r.ranks.iter().all(|x| x.is_finite()),
            "{}: non-finite ranks",
            g.name
        );
    }
}

/// Rank sums: ≈1 without dangling vertices, < 1 with them (Eq. 1 has no
/// dangling-mass correction — paper-faithful).
#[test]
fn rank_mass_accounting() {
    let c = cfg(3);
    let closed = synthetic::cycle(100); // no dangling
    let r = pagerank::run(&closed, Variant::NoSync, &c).unwrap();
    let sum: f64 = r.ranks.iter().sum();
    assert!((sum - 1.0).abs() < 1e-6, "closed-graph mass {sum}");

    let leaky = synthetic::chain(100); // one dangling tail
    let r = pagerank::run(&leaky, Variant::Barrier, &c).unwrap();
    let sum: f64 = r.ranks.iter().sum();
    assert!(sum < 1.0 && sum > 0.1, "chain mass {sum}");
}

/// Top-k ordering agrees between sequential and the lock-free variant
/// (what a downstream ranking consumer actually cares about).
#[test]
fn top_ranking_stable_across_variants() {
    let g = synthetic::web_replica(1_000, 8, 108);
    let c = cfg(4);
    let s = pagerank::run(&g, Variant::Sequential, &c).unwrap();
    let p = pagerank::run(&g, Variant::NoSync, &c).unwrap();
    let top_s: Vec<u32> = s.top_k(10).into_iter().map(|(u, _)| u).collect();
    let top_p: Vec<u32> = p.top_k(10).into_iter().map(|(u, _)| u).collect();
    assert_eq!(top_s, top_p);
}

/// Work amplification changes timing, never numerics.
#[test]
fn work_amplification_is_numerically_neutral() {
    let g = synthetic::star(80);
    let plain = pagerank::run(&g, Variant::Barrier, &cfg(2)).unwrap();
    let amp = pagerank::run(
        &g,
        Variant::Barrier,
        &PrConfig { work_amplify: 50, ..cfg(2) },
    )
    .unwrap();
    assert_eq!(plain.iterations, amp.iterations);
    assert!(convergence::linf_norm(&plain.ranks, &amp.ranks) == 0.0);
}
