//! Property-based invariants over the whole stack, driven by the in-tree
//! `testkit` mini-framework (the image has no proptest — see DESIGN.md
//! §Substitutions). Each property runs over dozens of generated graphs and
//! shrinks failures to small edge lists.

use pagerank_nb::graph::identical::IdenticalClasses;
use pagerank_nb::graph::{GraphBuilder, PartitionPolicy, Partitions};
use pagerank_nb::pagerank::{self, convergence, seq, xla_block, PrConfig, Variant};
use pagerank_nb::testkit::{check, Config, EdgeList, Gen, IntRange};
use pagerank_nb::util::rng::Xoshiro256pp;

fn build(n: usize, edges: &[(u32, u32)]) -> pagerank_nb::graph::Csr {
    GraphBuilder::new(n).dedup(true).edges(edges).build("prop")
}

fn cases() -> Config {
    Config::default().cases(60)
}

/// CSR structural invariants hold for arbitrary edge lists.
#[test]
fn prop_csr_always_validates() {
    check(cases(), EdgeList { max_n: 60, max_m: 300 }, |(n, edges)| {
        build(*n, edges).validate().is_ok()
    });
}

/// The transpose is an exact mirror of the forward adjacency.
#[test]
fn prop_transpose_mirrors_forward() {
    check(cases(), EdgeList { max_n: 40, max_m: 200 }, |(n, edges)| {
        let g = build(*n, edges);
        let mut fwd = Vec::new();
        let mut rev = Vec::new();
        for u in 0..g.num_vertices() as u32 {
            for &v in g.out_neighbors(u) {
                fwd.push((u, v));
            }
            for &v in g.in_neighbors(u) {
                rev.push((v, u));
            }
        }
        fwd.sort_unstable();
        rev.sort_unstable();
        fwd == rev
    });
}

/// Partitions cover every vertex exactly once, for both policies and any
/// thread count.
#[test]
fn prop_partitions_cover_exactly_once() {
    let gen = EdgeList { max_n: 50, max_m: 250 };
    check(cases(), gen, |(n, edges)| {
        let g = build(*n, edges);
        for p in 1..=9usize {
            for policy in [PartitionPolicy::VertexBalanced, PartitionPolicy::EdgeBalanced] {
                let parts = Partitions::new(&g, p, policy);
                let mut seen = vec![0u8; g.num_vertices()];
                for i in 0..parts.count() {
                    for u in parts.range(i) {
                        seen[u as usize] += 1;
                    }
                }
                if seen.iter().any(|&c| c != 1) {
                    return false;
                }
            }
        }
        true
    });
}

/// Identical-class detection is sound on arbitrary graphs.
#[test]
fn prop_identical_classes_sound() {
    check(cases(), EdgeList { max_n: 40, max_m: 250 }, |(n, edges)| {
        let g = build(*n, edges);
        IdenticalClasses::compute(&g).verify(&g).is_ok()
    });
}

/// Sequential PageRank: ranks are positive, bounded by 1, and the total
/// mass never exceeds 1 (Eq. 1 without dangling redistribution).
#[test]
fn prop_seq_ranks_well_formed() {
    check(cases(), EdgeList { max_n: 40, max_m: 200 }, |(n, edges)| {
        let g = build(*n, edges);
        let cfg = PrConfig { threshold: 1e-10, ..PrConfig::default() };
        let (ranks, _, _) = seq::solve(&g, &cfg);
        let sum: f64 = ranks.iter().sum();
        ranks.iter().all(|&x| x > 0.0 && x <= 1.0 + 1e-12) && sum <= 1.0 + 1e-9
    });
}

/// The parallel No-Sync fixed point matches sequential on random graphs
/// (Lemma 2, property form).
#[test]
fn prop_nosync_matches_sequential() {
    check(
        Config::default().cases(25),
        EdgeList { max_n: 40, max_m: 160 },
        |(n, edges)| {
            let g = build(*n, edges);
            let cfg = PrConfig { threads: 3, threshold: 1e-11, ..PrConfig::default() };
            let (sr, _, _) = seq::solve(&g, &cfg);
            let r = pagerank::run(&g, Variant::NoSync, &cfg).unwrap();
            r.converged && convergence::l1_norm(&r.ranks, &sr) < 1e-6
        },
    );
}

/// Wait-Free matches Barrier on random graphs — two completely different
/// synchronization protocols, same fixed point.
#[test]
fn prop_waitfree_matches_barrier() {
    check(
        Config::default().cases(20),
        EdgeList { max_n: 30, max_m: 120 },
        |(n, edges)| {
            let g = build(*n, edges);
            let cfg = PrConfig { threads: 3, threshold: 1e-11, ..PrConfig::default() };
            let wf = pagerank::run(&g, Variant::WaitFree, &cfg).unwrap();
            let ba = pagerank::run(&g, Variant::Barrier, &cfg).unwrap();
            wf.converged
                && ba.converged
                && convergence::l1_norm(&wf.ranks, &ba.ranks) < 1e-6
        },
    );
}

/// The ELL layout is a lossless encoding: decoding it recovers exactly the
/// in-edge structure with the right weights.
#[test]
fn prop_ell_layout_roundtrip() {
    check(cases(), EdgeList { max_n: 30, max_m: 150 }, |(n, edges)| {
        let g = build(*n, edges);
        let nn = g.num_vertices();
        let maxk = (0..nn as u32).map(|u| g.in_degree(u)).max().unwrap_or(0).max(1);
        let l = xla_block::EllLayout::build(&g, 0.85, nn.max(1), maxk).unwrap();
        for u in 0..nn as u32 {
            let row = u as usize * l.k_bucket;
            let mut decoded: Vec<u32> = (0..l.k_bucket)
                .filter(|&j| l.weights[row + j] != 0.0)
                .map(|j| l.indices[row + j] as u32)
                .collect();
            decoded.sort_unstable();
            let mut expect: Vec<u32> = g
                .in_neighbors(u)
                .iter()
                .copied()
                .filter(|&v| g.out_degree(v) > 0)
                .collect();
            expect.sort_unstable();
            if decoded != expect {
                return false;
            }
        }
        true
    });
}

/// Binary graph serialization round-trips arbitrary graphs.
#[test]
fn prop_binary_io_roundtrip() {
    let dir = std::env::temp_dir().join("pagerank_nb_prop_io");
    std::fs::create_dir_all(&dir).unwrap();
    let counter = std::sync::atomic::AtomicU64::new(0);
    check(Config::default().cases(30), EdgeList { max_n: 40, max_m: 150 }, |(n, edges)| {
        let c = counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let g = build(*n, edges);
        let path = dir.join(format!("g{c}.bin"));
        pagerank_nb::graph::io::save_binary(&g, &path).unwrap();
        let g2 = pagerank_nb::graph::io::load_binary(&path).unwrap();
        std::fs::remove_file(&path).ok();
        g == g2
    });
}

/// RMAT generation is deterministic in its seed (reproducible figures).
#[test]
fn prop_rmat_deterministic() {
    check(Config::default().cases(10), IntRange::new(0, 1_000_000), |&seed| {
        let a = pagerank_nb::graph::rmat::generate(
            8,
            600,
            pagerank_nb::graph::rmat::RmatParams::default(),
            seed as u64,
        );
        let b = pagerank_nb::graph::rmat::generate(
            8,
            600,
            pagerank_nb::graph::rmat::RmatParams::default(),
            seed as u64,
        );
        a == b
    });
}

/// EdgeList shrinking really does produce smaller cases (framework
/// self-check at the integration level).
#[test]
fn prop_edge_list_shrink_shrinks() {
    let gen = EdgeList { max_n: 20, max_m: 50 };
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    for _ in 0..50 {
        let v = gen.generate(&mut rng);
        for s in gen.shrink(&v) {
            assert!(s.1.len() < v.1.len().max(1));
        }
    }
}
