//! Model-checked concurrency tests for `pagerank_nb::sync`.
//!
//! Every test here runs a small closure under `model_lite::check`, which
//! executes it once per *distinct interleaving* — exhaustive DFS over the
//! schedule tree, bounded to two preemptions per execution
//! (Musuvathi/Qadeer: almost all real interleaving bugs need at most two).
//! The shim atomics additionally let `Relaxed` loads return any store a
//! real weak-memory machine could return, so an assertion that survives
//! `check` holds in every schedule *and* under stale reads — not just the
//! ones the host CPU happened to produce, which is what the plain stress
//! tests in `src/sync/*` sample.
//!
//! Keep closures tiny: tree size is exponential in schedule points. Two to
//! three model threads and a handful of atomic operations each is the
//! sweet spot; the `max_executions` guard in [`model_lite::Options`] fails
//! the test if a closure grows past what exhaustive exploration can cover.

pub mod barrier;
pub mod cas;
pub mod dirty;
pub mod regressions;
pub mod worklist;

use pagerank_nb::sync::DirtyFlags;
use std::sync::Arc;

/// Acceptance gate for the checker itself: the exploration is a pure
/// function of the program — two runs of the same closure must walk the
/// same schedule tree (same execution and decision counts). Flakiness here
/// means a decision leaked out of the replay log (e.g. an un-shimmed
/// synchronization primitive), which would make every counterexample
/// non-reproducible.
#[test]
fn exploration_is_deterministic_across_runs() {
    let run = || {
        model_lite::check(|| {
            let d = Arc::new(DirtyFlags::new_clear(64));
            let d2 = Arc::clone(&d);
            let t = model_lite::thread::spawn(move || {
                d2.set(3);
            });
            d.set(7);
            t.join().unwrap();
            let mut seen = Vec::new();
            d.drain_range(0..64, |v| seen.push(v));
            assert_eq!(seen, vec![3, 7], "both marks must survive every schedule");
        })
    };
    let r1 = run();
    let r2 = run();
    assert_eq!(r1, r2, "schedule exploration must be reproducible");
    assert!(r1.executions > 1, "two racing setters must fork more than one schedule");
}
