//! Model checks for the Vyukov ring ([`WorkList`]) and its bitmap-guarded
//! frontier protocol (docs/concurrency.md §WorkList).

use model_lite::thread;
use pagerank_nb::sync::{DirtyFlags, WorkList};
use std::sync::Arc;

/// Two consumers racing over a two-entry ring: the head CAS hands each
/// entry to exactly one popper, and nothing is lost, in every interleaving.
#[test]
fn concurrent_pops_are_exclusive() {
    model_lite::check(|| {
        let q = Arc::new(WorkList::with_capacity(4));
        assert!(q.push(1) && q.push(2));
        let q2 = Arc::clone(&q);
        let other = thread::spawn(move || q2.pop());
        let mine = q.pop();
        let theirs = other.join().unwrap();
        let mut got: Vec<u32> = [mine, theirs].into_iter().flatten().collect();
        while let Some(v) = q.pop() {
            got.push(v);
        }
        got.sort_unstable();
        assert_eq!(got, vec![1, 2], "every id must pop exactly once");
        assert_eq!(q.pop(), None);
    });
}

/// Single producer, single consumer, racing: the sequence-number protocol
/// must deliver ids in FIFO order and the `Release` publish of `seq` must
/// carry the payload — a consumer observing the bumped sequence can never
/// read a stale slot value (the model's relaxed-load machinery would hand
/// it the slot's previous content if the `Acquire`/`Release` pairing were
/// wrong, and the assertion below would see a hole in the sequence).
#[test]
fn racing_push_pop_is_fifo_and_publishes_payloads() {
    model_lite::check(|| {
        let q = Arc::new(WorkList::with_capacity(2));
        let q2 = Arc::clone(&q);
        let producer = thread::spawn(move || {
            for v in [1u32, 2] {
                while !q2.push(v) {
                    thread::yield_now();
                }
            }
        });
        let mut got = Vec::new();
        while got.len() < 2 {
            match q.pop() {
                Some(v) => got.push(v),
                None => thread::yield_now(),
            }
        }
        producer.join().unwrap();
        assert_eq!(got, vec![1, 2], "FIFO violated or stale payload observed");
        assert_eq!(q.pop(), None);
    });
}

/// The overflow degrade path: a full ring rejects the push, but the bitmap
/// mark that preceded it keeps the vertex recoverable — pops re-validated
/// with `claim` plus a final bitmap sweep gather every marked vertex
/// exactly once, whether or not its enqueue succeeded.
#[test]
fn overflow_degrades_to_the_bitmap_without_loss() {
    model_lite::check(|| {
        let d = Arc::new(DirtyFlags::new_clear(64));
        let q = Arc::new(WorkList::with_capacity(2));
        let (d2, q2) = (Arc::clone(&d), Arc::clone(&q));
        let producer = thread::spawn(move || {
            for v in [1u32, 2, 3] {
                if d2.set(v) {
                    // A failed push is not a loss: the bit stays set and
                    // the bitmap remains the ground truth.
                    let _ = q2.push(v);
                }
            }
        });
        let mut gathered = Vec::new();
        while let Some(v) = q.pop() {
            if d.claim(v) {
                gathered.push(v);
            }
        }
        producer.join().unwrap();
        while let Some(v) = q.pop() {
            if d.claim(v) {
                gathered.push(v);
            }
        }
        d.drain_range(0..64, |v| gathered.push(v));
        gathered.sort_unstable();
        assert_eq!(gathered, vec![1, 2, 3], "overflow must degrade, never lose or duplicate");
    });
}
