//! Model-checked regression tests for the two interleaving bugs this repo
//! has actually shipped and fixed. Each test drives the *real* primitive
//! through the schedule (and stale-read) neighborhood of the historical
//! bug, so reverting either fix fails this suite deterministically —
//! instead of intermittently, which is how both bugs originally survived
//! the stress tests.

use model_lite::atomic::{AtomicU64, Ordering};
use model_lite::thread;
use pagerank_nb::sync::{DirtyFlags, WorkList};
use std::sync::Arc;

/// PR 5 regression — the `DirtyFlags::set` TTAS lost update.
///
/// The buggy version prefixed the `fetch_or` with a relaxed load and
/// early-returned when the bit already read as set. Under a concurrent
/// `drain_range` that load can observe a *stale* "set" word from before the
/// drain claimed it, skipping a mark whose bit is actually clear — and if
/// the drain gathered the vertex before the publisher stored its rank, the
/// final update is never propagated.
///
/// The scenario: a stale mark is already pending, the publisher stores a
/// new rank and marks again, a drainer races the whole thing. In every
/// interleaving, *some* drain must observe the final published value.
/// With the unconditional `fetch_or` this holds; with the TTAS fast path
/// the checker finds the lost-update schedule and this test fails.
#[test]
fn pr5_final_mark_is_never_lost_to_a_stale_ttas_read() {
    model_lite::check(|| {
        let d = Arc::new(DirtyFlags::new_clear(64));
        let published = Arc::new(AtomicU64::new(0));
        d.set(7); // stale mark pending from the previous round
        let (d2, p2) = (Arc::clone(&d), Arc::clone(&published));
        let drainer = thread::spawn(move || {
            let mut got = 0;
            d2.drain_range(0..64, |v| {
                assert_eq!(v, 7);
                got = p2.load(Ordering::Acquire);
            });
            got
        });
        published.store(42, Ordering::Release);
        d.set(7); // the final mark — must never be skipped
        let early = drainer.join().unwrap();
        let mut late = 0;
        d.drain_range(0..64, |v| {
            assert_eq!(v, 7);
            late = published.load(Ordering::Acquire);
        });
        assert!(
            early == 42 || late == 42,
            "final mark lost (early={early}, late={late}): rank update unpropagated"
        );
    });
}

/// PR 8 regression — the frontier double-gather.
///
/// A vertex sits both in the ring (enqueued on its mark transition) and in
/// the bitmap. An overflow-degraded sweep scans the bitmap directly while
/// the ring consumer pops the same id; before the fix the consumer gathered
/// every pop unconditionally, so the vertex was processed twice in one
/// sweep (double-counting its contribution). The fix re-validates each pop
/// with `DirtyFlags::claim`. In every interleaving the claim/drain
/// `fetch_and` pair admits exactly one gatherer; drop the `claim` guard and
/// the checker immediately finds a two-gather schedule.
#[test]
fn pr8_popped_entry_racing_an_overflow_scan_gathers_once() {
    model_lite::check(|| {
        let d = Arc::new(DirtyFlags::new_clear(64));
        let q = Arc::new(WorkList::with_capacity(4));
        d.set(5);
        assert!(q.push(5)); // marked and enqueued, as the frontier does
        let d2 = Arc::clone(&d);
        let scanner = thread::spawn(move || {
            // overflow-degraded sweep: claims straight off the bitmap
            d2.drain_range(0..64, |v| assert_eq!(v, 5))
        });
        let mut gathered = 0u64;
        while let Some(v) = q.pop() {
            if d.claim(v) {
                gathered += 1; // the PR 8 fix: pop-side re-validation
            }
        }
        let scanned = scanner.join().unwrap();
        assert_eq!(
            scanned + gathered,
            1,
            "vertex 5 gathered {} times in one sweep",
            scanned + gathered
        );
    });
}
