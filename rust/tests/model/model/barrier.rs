//! Model checks for [`SenseBarrier`] — phase rotation and the abort
//! protocol the fault-injection harness depends on
//! (docs/concurrency.md §SenseBarrier).

use model_lite::thread;
use pagerank_nb::sync::barrier::{BarrierWait, SenseBarrier};
use std::sync::Arc;

/// Two parties, two phases: every phase completes (the model's deadlock
/// detection fails any interleaving where both spin forever), exactly one
/// party is the leader per phase, and the sense flip rotates correctly into
/// the second phase.
#[test]
fn rotation_has_exactly_one_leader_per_phase() {
    model_lite::check(|| {
        let b = Arc::new(SenseBarrier::new(2));
        let b2 = Arc::clone(&b);
        let child = thread::spawn(move || {
            let mut w = b2.waiter();
            [w.wait(), w.wait()]
        });
        let mut w = b.waiter();
        let mine = [w.wait(), w.wait()];
        let theirs = child.join().unwrap();
        for p in 0..2 {
            let outcomes = [mine[p], theirs[p]];
            assert!(outcomes.iter().all(|r| !r.is_aborted()), "phase {p} aborted");
            let leaders = outcomes.iter().filter(|r| **r == BarrierWait::Leader).count();
            assert_eq!(leaders, 1, "phase {p}: exactly one leader, got {leaders}");
        }
    });
}

/// A party dies before arriving. The executor's panic guard turns a worker
/// panic into `abort()` before unwinding (a raw panic inside `check` would
/// itself be reported as a counterexample, so the fault is modeled by its
/// observable effect); the surviving waiter must unblock with `Aborted` in
/// every interleaving — this is the "sleeping/failed thread" experiment of
/// the paper's Figs 8–9, minus the wall-clock stall.
#[test]
fn abort_unblocks_the_survivor_in_every_interleaving() {
    model_lite::check(|| {
        let b = Arc::new(SenseBarrier::new(2));
        let b2 = Arc::clone(&b);
        let faulty = thread::spawn(move || b2.abort());
        let mut w = b.waiter();
        assert_eq!(w.wait(), BarrierWait::Aborted, "survivor must not wedge");
        faulty.join().unwrap();
        assert_eq!(w.wait(), BarrierWait::Aborted, "aborts are forever");
    });
}

/// Abort racing a phase that is completing anyway: outcomes may mix, but
/// never incoherently — at most one leader, and a `Member` implies some
/// leader flipped the sense. Implicitly also a liveness check: no
/// interleaving may leave a waiter spinning (the checker bounds stale
/// reads, so an unbounded spin fails the execution).
#[test]
fn abort_racing_a_completing_phase_stays_coherent() {
    model_lite::check(|| {
        let b = Arc::new(SenseBarrier::new(2));
        let (b2, b3) = (Arc::clone(&b), Arc::clone(&b));
        let w1 = thread::spawn(move || {
            let mut w = b2.waiter();
            w.wait()
        });
        let w2 = thread::spawn(move || {
            let mut w = b3.waiter();
            w.wait()
        });
        b.abort();
        let outcomes = [w1.join().unwrap(), w2.join().unwrap()];
        let leaders = outcomes.iter().filter(|r| **r == BarrierWait::Leader).count();
        let members = outcomes.iter().filter(|r| **r == BarrierWait::Member).count();
        assert!(leaders <= 1, "two leaders in one phase: {outcomes:?}");
        assert!(members == 0 || leaders == 1, "member without a leader: {outcomes:?}");
    });
}
