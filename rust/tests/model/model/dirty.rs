//! Model checks for [`DirtyFlags`] — the bitmap frontier's mark/claim/drain
//! protocol (docs/concurrency.md §DirtyFlags).

use model_lite::atomic::{AtomicU64, Ordering};
use model_lite::{hb, thread};
use pagerank_nb::sync::DirtyFlags;
use std::sync::Arc;

/// Two drainers over one word: the `fetch_and` claim hands every set bit to
/// exactly one of them, in every interleaving. This is the exclusivity the
/// sharded sweep owners rely on when ranges share a word boundary.
#[test]
fn concurrent_drains_claim_each_bit_exactly_once() {
    model_lite::check(|| {
        let d = Arc::new(DirtyFlags::new_set(8));
        let d2 = Arc::clone(&d);
        let other = thread::spawn(move || {
            let mut mine = Vec::new();
            d2.drain_range(0..8, |v| mine.push(v));
            mine
        });
        let mut mine = Vec::new();
        d.drain_range(0..8, |v| mine.push(v));
        let theirs = other.join().unwrap();
        let mut all: Vec<u32> = mine.iter().chain(theirs.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0u32..8).collect::<Vec<_>>(), "lost or double-claimed bit");
        assert_eq!(d.count_set(), 0);
    });
}

/// The publication contract from the module docs: rank stores issued before
/// a `set` are visible to whoever `claim`s the bit, because both ends are
/// `AcqRel` RMWs. The payload read below is deliberately `Relaxed` — under
/// the model checker a relaxed load may return *any* store not yet ordered
/// before the reader, so the assertion only survives if the mark/claim pair
/// really is a release/acquire edge. The vector-clock check then pins the
/// same fact in happens-before terms.
#[test]
fn set_claim_is_a_release_acquire_publication_edge() {
    model_lite::check(|| {
        let d = Arc::new(DirtyFlags::new_clear(64));
        let payload = Arc::new(AtomicU64::new(0));
        let (d2, p2) = (Arc::clone(&d), Arc::clone(&payload));
        let publisher = thread::spawn(move || {
            p2.store(42, Ordering::Relaxed);
            let before_set = hb::now();
            d2.set(7);
            before_set
        });
        while !d.claim(7) {
            thread::yield_now();
        }
        let after_claim = hb::now();
        assert_eq!(payload.load(Ordering::Relaxed), 42, "claim must acquire the mark");
        let before_set = publisher.join().unwrap();
        assert!(
            before_set.happens_before(&after_claim),
            "pre-mark writes must happen-before the successful claim"
        );
    });
}

/// A mark racing a drain of the same word is never lost: either the drain
/// claims it (and gathers the vertex this sweep) or the bit survives into
/// the next sweep — `set`'s unconditional `fetch_or` operates on the latest
/// word value, so there is no window where the mark lands on a stale view.
#[test]
fn mark_racing_a_drain_survives_or_is_gathered() {
    model_lite::check(|| {
        let d = Arc::new(DirtyFlags::new_clear(64));
        let d2 = Arc::clone(&d);
        let marker = thread::spawn(move || {
            d2.set(5);
        });
        let mut gathered = d.drain_range(0..64, |v| assert_eq!(v, 5));
        marker.join().unwrap();
        gathered += d.drain_range(0..64, |v| assert_eq!(v, 5));
        assert_eq!(gathered, 1, "the mark must be gathered exactly once");
        assert_eq!(d.count_set(), 0);
    });
}
