//! Model checks for the Algorithm 6 CAS-object cells
//! ([`VersionedCell`], [`PackedProgress`]) — single-winner commits and the
//! seqlock read protocol (docs/concurrency.md §cas_cell).

use model_lite::thread;
use pagerank_nb::sync::cas_cell::{PackedProgress, VersionedCell};
use std::sync::Arc;

/// Two helpers race to commit iteration 1 while a reader runs concurrently:
/// the version CAS admits exactly one winner, and the reader never observes
/// a torn `(iteration, value)` pair — including in interleavings where the
/// read lands inside the two-store commit window (the seqlock must spin
/// there, and the model proves the spin terminates).
#[test]
fn versioned_cell_has_one_winner_and_no_torn_reads() {
    model_lite::check(|| {
        let c = Arc::new(VersionedCell::new(0.0));
        let (c1, c2) = (Arc::clone(&c), Arc::clone(&c));
        let a = thread::spawn(move || c1.try_advance(0, 42.0));
        let b = thread::spawn(move || c2.try_advance(0, 42.0));
        let (it, val) = c.read();
        assert!(
            (it == 0 && val == 0.0) || (it == 1 && val == 42.0),
            "torn read: ({it}, {val})"
        );
        let (wa, wb) = (a.join().unwrap(), b.join().unwrap());
        assert!(wa ^ wb, "exactly one commit winner, got a={wa} b={wb}");
        assert_eq!(c.read(), (1, 42.0));
    });
}

/// Helpers racing a stalled thread's progress word: each node is claimed by
/// exactly one CAS winner, and the word never goes backwards — the
/// exclusivity the Barrier-Helper work-stealing protocol rests on.
#[test]
fn packed_progress_claims_each_node_exactly_once() {
    model_lite::check(|| {
        let p = Arc::new(PackedProgress::new(0, 0));
        let claim_all = |p: Arc<PackedProgress>| {
            let mut mine = Vec::new();
            loop {
                let (iter, node) = p.load();
                assert_eq!(iter, 0, "iteration must not move");
                if node >= 2 {
                    break;
                }
                if p.try_advance((iter, node), (iter, node + 1)) {
                    mine.push(node);
                }
            }
            mine
        };
        let p2 = Arc::clone(&p);
        let helper = thread::spawn(move || claim_all(p2));
        let mut all = claim_all(Arc::clone(&p));
        all.extend(helper.join().unwrap());
        all.sort_unstable();
        assert_eq!(all, vec![0, 1], "each node claimed exactly once");
        assert_eq!(p.load(), (0, 2));
    });
}
