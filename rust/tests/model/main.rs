//! Deterministic model-checking suite for the non-blocking sync layer.
//!
//! Built (and meaningful) only with `--features pallas-model`, which routes
//! `sync/shim.rs` to the vendored `model-lite` checker; without the feature
//! this target compiles to nothing. The directory layout nests a `model`
//! module so every test name carries the `model::` prefix CI filters on:
//!
//! ```text
//! cargo test -p pagerank_nb --features pallas-model model::
//! ```

#![cfg(feature = "pallas-model")]

mod model;
