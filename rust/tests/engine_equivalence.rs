//! Engine equivalence suite: after the kernel refactor, every CPU variant
//! (old per-module behavior, now dispatched through `engine::REGISTRY`)
//! must still land on the sequential fixed point — property-tested over
//! random edge lists plus RMAT and chain fixtures, including the
//! `XlaBlock`-excluded dispatch error path.

use pagerank_nb::graph::{rmat, synthetic, Csr, GraphBuilder};
use pagerank_nb::pagerank::{self, seq, FrontierSched, PcpmLayout, PrConfig, Variant};
use pagerank_nb::testkit::{check, Config, EdgeList};

fn build(n: usize, edges: &[(u32, u32)]) -> Csr {
    GraphBuilder::new(n).dedup(true).edges(edges).build("prop")
}

/// Exact engine modes: converged ranks must match sequential tightly.
/// (No-Sync-Edge is excluded — §4.4: it may legitimately not converge.)
fn exact_modes() -> Vec<Variant> {
    vec![
        Variant::Barrier,
        Variant::BarrierIdentical,
        Variant::BarrierEdge,
        Variant::WaitFree,
        Variant::NoSync,
        Variant::NoSyncIdentical,
        Variant::Pcpm,
        Variant::Frontier,
        Variant::FrontierPcpm,
    ]
}

fn approximate_modes() -> Vec<Variant> {
    vec![Variant::BarrierOpt, Variant::NoSyncOpt, Variant::NoSyncOptIdentical]
}

/// Property: on arbitrary random graphs, every exact kernel converges to
/// the sequential ranks and every approximate kernel stays within its
/// loose L1 budget.
#[test]
fn prop_all_kernels_match_sequential_on_random_graphs() {
    check(
        Config::default().cases(12),
        EdgeList { max_n: 30, max_m: 120 },
        |(n, edges)| {
            let g = build(*n, edges);
            let cfg = PrConfig { threads: 3, threshold: 1e-11, ..PrConfig::default() };
            let (sr, _, _) = seq::solve(&g, &cfg);
            for v in exact_modes() {
                let r = pagerank::run(&g, v, &cfg).unwrap();
                if !r.converged || r.l1_norm(&sr) >= 1e-6 {
                    eprintln!("{v}: converged={} l1={}", r.converged, r.l1_norm(&sr));
                    return false;
                }
            }
            let acfg = PrConfig { threshold: 1e-8, ..cfg };
            let (asr, _, _) = seq::solve(&g, &acfg);
            for v in approximate_modes() {
                let r = pagerank::run(&g, v, &acfg).unwrap();
                if !r.converged || r.l1_norm(&asr) >= 1e-2 {
                    eprintln!("{v}: converged={} l1={}", r.converged, r.l1_norm(&asr));
                    return false;
                }
            }
            true
        },
    );
}

/// All twelve engine modes on RMAT and chain fixtures: exact ones match
/// sequential; approximate ones stay bounded; No-Sync-Edge must at least
/// terminate with finite ranks (its documented §4.4 caveat).
#[test]
fn every_engine_mode_runs_on_rmat_and_chain() {
    let graphs = vec![
        rmat::generate(6, 250, rmat::RmatParams::default(), 11),
        rmat::generate(7, 500, rmat::RmatParams::default(), 12),
        synthetic::chain(80),
    ];
    let cfg = PrConfig { threads: 4, threshold: 1e-10, ..PrConfig::default() };
    for g in &graphs {
        let (sr, _, _) = seq::solve(g, &cfg);
        for v in Variant::ALL_MODES {
            let r = pagerank::run(g, v, &cfg).unwrap();
            assert!(
                r.ranks.iter().all(|x| x.is_finite()),
                "{v} on {}: non-finite ranks",
                g.name
            );
            if v == Variant::NoSyncEdge {
                continue; // may legitimately hit the cap on skewed graphs
            }
            assert!(r.converged, "{v} on {} did not converge", g.name);
            let bound = if v.is_approximate() { 1e-2 } else { 1e-6 };
            let l1 = r.l1_norm(&sr);
            assert!(l1 < bound, "{v} on {}: L1 {l1} >= {bound}", g.name);
        }
    }
}

/// PCPM is a synchronous schedule: same iteration count as Barrier and
/// well within threshold L1 distance of Sequential on the testkit graphs.
#[test]
fn pcpm_matches_barrier_schedule_on_random_graphs() {
    check(
        Config::default().cases(15),
        EdgeList { max_n: 40, max_m: 200 },
        |(n, edges)| {
            let g = build(*n, edges);
            let cfg = PrConfig { threads: 3, threshold: 1e-11, ..PrConfig::default() };
            let pcpm = pagerank::run(&g, Variant::Pcpm, &cfg).unwrap();
            let barrier = pagerank::run(&g, Variant::Barrier, &cfg).unwrap();
            pcpm.converged
                && barrier.converged
                && pcpm.iterations == barrier.iterations
                && pagerank_nb::pagerank::convergence::linf_norm(&pcpm.ranks, &barrier.ranks)
                    < 1e-12
        },
    );
}

/// The acceptance criterion of the frontier/delta schedule: on a web-class
/// dataset the frontier kernel must land within 1e-6 L1 of the Barrier
/// schedule's ranks while computing strictly fewer vertex updates than
/// No-Sync's gather-everything sweeps.
#[test]
fn frontier_matches_barrier_with_fewer_vertex_updates() {
    let g = synthetic::web_replica(2_000, 6, 42);
    let cfg = PrConfig { threads: 4, threshold: 1e-10, ..PrConfig::default() };
    let barrier = pagerank::run(&g, Variant::Barrier, &cfg).unwrap();
    let nosync = pagerank::run(&g, Variant::NoSync, &cfg).unwrap();
    assert!(barrier.converged && nosync.converged);
    assert!(nosync.vertex_updates > 0, "No-Sync must be instrumented");
    for v in [Variant::Frontier, Variant::FrontierPcpm] {
        let r = pagerank::run(&g, v, &cfg).unwrap();
        assert!(r.converged, "{v} did not converge");
        let l1 = r.l1_norm(&barrier.ranks);
        assert!(l1 < 1e-6, "{v}: L1 vs barrier {l1}");
        assert!(
            r.vertex_updates < nosync.vertex_updates,
            "{v} gathered {} vertex updates, No-Sync {}",
            r.vertex_updates,
            nosync.vertex_updates
        );
    }
}

/// The compressed-bin acceptance criterion: on the web replica, both PCPM
/// kernels running the compressed (dest-index, value) stream land within
/// 1e-6 L1 of the Barrier schedule, and the compressed layout reports
/// *identical* work telemetry (vertex updates, iterations) to the
/// uncompressed per-edge layout — compression changes memory traffic, not
/// the schedule.
#[test]
fn compressed_pcpm_matches_barrier_with_identical_work() {
    let g = synthetic::web_replica(2_000, 6, 42);
    let cfg = PrConfig { threads: 4, threshold: 1e-10, ..PrConfig::default() };
    let barrier = pagerank::run(&g, Variant::Barrier, &cfg).unwrap();
    assert!(barrier.converged);
    let mut compressed_pcpm = None;
    for v in [Variant::Pcpm, Variant::FrontierPcpm] {
        let r = pagerank::run(&g, v, &cfg).unwrap();
        assert!(r.converged, "{v} (compressed) did not converge");
        let l1 = r.l1_norm(&barrier.ranks);
        assert!(l1 < 1e-6, "{v} (compressed): L1 vs barrier {l1}");
        if v == Variant::Pcpm {
            compressed_pcpm = Some(r);
        }
    }
    let slots_cfg = PrConfig { pcpm_layout: PcpmLayout::Slots, ..cfg.clone() };
    let compressed = compressed_pcpm.expect("loop ran Variant::Pcpm");
    let slots = pagerank::run(&g, Variant::Pcpm, &slots_cfg).unwrap();
    assert!(compressed.converged && slots.converged);
    assert_eq!(compressed.iterations, slots.iterations);
    assert_eq!(
        compressed.vertex_updates, slots.vertex_updates,
        "bin layout must not change the vertex-update count"
    );
    assert_eq!(compressed.ranks, slots.ranks, "layouts must be bit-identical");
}

/// Property: on arbitrary random graphs, every PCPM configuration —
/// layouts × batch sizes — is bit-identical to the default and converges
/// with the Barrier iteration count (the synchronous-Jacobi contract).
#[test]
fn prop_pcpm_layouts_and_batches_agree_on_random_graphs() {
    check(
        Config::default().cases(10),
        EdgeList { max_n: 40, max_m: 200 },
        |(n, edges)| {
            let g = build(*n, edges);
            let base = PrConfig { threads: 3, threshold: 1e-11, ..PrConfig::default() };
            let reference = pagerank::run(&g, Variant::Pcpm, &base).unwrap();
            for (layout, batch) in [
                (PcpmLayout::Slots, 1),
                (PcpmLayout::Compressed, 2),
                (PcpmLayout::Slots, 3),
            ] {
                let cfg =
                    PrConfig { pcpm_layout: layout, pcpm_batch: batch, ..base.clone() };
                let r = pagerank::run(&g, Variant::Pcpm, &cfg).unwrap();
                if r.ranks != reference.ranks
                    || r.iterations != reference.iterations
                    || r.converged != reference.converged
                {
                    eprintln!(
                        "layout={layout} batch={batch}: iter {} vs {}, converged {} vs {}",
                        r.iterations, reference.iterations, r.converged, reference.converged
                    );
                    return false;
                }
            }
            true
        },
    );
}

/// The out-of-core acceptance criterion: a graph whose CSR arrays exceed
/// the memory budget is spilled to the v2 binary cache, mapped back
/// zero-copy, and swept shard-by-shard through the coordinator — and the
/// resulting ranks land within 1e-6 L1 of the in-memory Barrier schedule.
#[test]
fn out_of_core_mmap_sharded_matches_in_memory_barrier() {
    use pagerank_nb::engine::ooc;
    use pagerank_nb::graph::io;

    let g = synthetic::web_replica(4_000, 6, 42);
    let cfg = PrConfig { threads: 4, threshold: 1e-10, ..PrConfig::default() };
    let barrier = pagerank::run(&g, Variant::Barrier, &cfg).unwrap();
    assert!(barrier.converged);

    let dir = std::env::temp_dir().join("pagerank_nb_equiv_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let spill = dir.join(format!("ooc-{}.bin", std::process::id()));
    io::save_binary(&g, &spill).unwrap();
    let mapped = io::map_binary(&spill).unwrap();
    assert!(mapped.is_mapped());

    // a budget of a quarter of the graph forces a multi-shard schedule
    let budget = g.memory_bytes() / 4;
    let derived = ooc::shards_for_budget(&mapped, budget, 1).unwrap();
    assert!(derived >= 4, "quarter budget must derive >= 4 shards, got {derived}");

    for shards in [4usize, derived] {
        let r = ooc::run_sharded(&mapped, &cfg, shards).unwrap();
        assert!(r.converged, "shards={shards} did not converge");
        let l1 = r.l1_norm(&barrier.ranks);
        assert!(l1 < 1e-6, "shards={shards}: L1 vs barrier {l1}");
        assert!(r.vertex_updates > 0, "shards={shards}: coordinator not instrumented");
    }
}

/// The parallel out-of-core acceptance criterion: `--ooc-workers 4` over a
/// 4-shard mmap schedule (K workers claiming dirty shards off the shared
/// ring, sweeps racing through one shared kernel) must stay within 1e-6 L1
/// of the in-memory Barrier schedule, and `--ooc-workers 1` must stay
/// bit-identical to the sequential coordinator — the determinism ladder the
/// tentpole promises.
#[test]
fn out_of_core_parallel_workers_match_barrier_and_k1_is_sequential() {
    use pagerank_nb::engine::ooc;
    use pagerank_nb::graph::io;

    let g = synthetic::web_replica(4_000, 6, 42);
    let cfg = PrConfig { threads: 4, threshold: 1e-10, ..PrConfig::default() };
    let barrier = pagerank::run(&g, Variant::Barrier, &cfg).unwrap();
    assert!(barrier.converged);

    let dir = std::env::temp_dir().join("pagerank_nb_equiv_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let spill = dir.join(format!("ooc-par-{}.bin", std::process::id()));
    io::save_binary(&g, &spill).unwrap();
    let mapped = io::map_binary(&spill).unwrap();
    assert!(mapped.is_mapped());

    // the budget must now hold K resident shards, so the derived shard
    // count grows with the worker count
    let budget = g.memory_bytes() / 2;
    let s1 = ooc::shards_for_budget(&mapped, budget, 1).unwrap();
    let s4 = ooc::shards_for_budget(&mapped, budget, 4).unwrap();
    // a half-graph budget is ~2 shards sequentially and ~8 once four must
    // be resident together (integer division keeps exact 4x off by one)
    assert!(s4 >= 8 && s4 >= s1 * 2, "4 resident shards must divide the budget: {s1} -> {s4}");

    for workers in [2usize, 4] {
        let r = ooc::run_sharded_workers(&mapped, &cfg, 4, workers).unwrap();
        assert!(r.converged, "workers={workers} did not converge");
        let l1 = r.l1_norm(&barrier.ranks);
        assert!(l1 < 1e-6, "workers={workers}: L1 vs barrier {l1}");
        assert!(r.vertex_updates > 0, "workers={workers}: not instrumented");
    }

    // K=1 through the worker entry point is the sequential schedule, bit
    // for bit, on mapped storage
    let seq_run = ooc::run_sharded(&mapped, &cfg, 4).unwrap();
    let k1 = ooc::run_sharded_workers(&mapped, &cfg, 4, 1).unwrap();
    assert_eq!(k1.ranks, seq_run.ranks, "K=1 must be bit-identical to sequential");
    assert_eq!(k1.iterations, seq_run.iterations);
    assert_eq!(k1.converged, seq_run.converged);
}

/// The scheduling acceptance criterion: `--frontier-sched worklist|hybrid`
/// must agree with the default bitmap scan — bit-identically at one thread
/// (the two-phase sweep makes the gather set schedule-independent there),
/// within 1e-6 L1 of the Barrier ranks at four — and the `PrResult`
/// telemetry must tell the modes apart.
#[test]
fn frontier_scheduler_modes_agree_with_bitmap() {
    let g = synthetic::web_replica(2_000, 6, 42);
    for threads in [1usize, 4] {
        let base = PrConfig { threads, threshold: 1e-10, ..PrConfig::default() };
        let barrier = pagerank::run(&g, Variant::Barrier, &base).unwrap();
        assert!(barrier.converged);
        for v in [Variant::Frontier, Variant::FrontierPcpm] {
            let bitmap = pagerank::run(&g, v, &base).unwrap();
            assert!(bitmap.converged, "{v} t{threads}");
            assert_eq!(bitmap.worklist_peak, 0, "{v}: bitmap mode has no rings");
            for sched in [FrontierSched::Worklist, FrontierSched::Hybrid] {
                let cfg = PrConfig { frontier_sched: sched, ..base.clone() };
                let r = pagerank::run(&g, v, &cfg).unwrap();
                assert!(r.converged, "{v}/{sched} t{threads} did not converge");
                if threads == 1 {
                    // single worker: every mode snapshots the same dirty
                    // set each sweep, so the runs are indistinguishable
                    assert_eq!(r.ranks, bitmap.ranks, "{v}/{sched}: not bit-identical");
                    assert_eq!(r.vertex_updates, bitmap.vertex_updates, "{v}/{sched}");
                } else {
                    let l1 = r.l1_norm(&barrier.ranks);
                    assert!(l1 < 1e-6, "{v}/{sched}: L1 vs barrier {l1}");
                }
                assert!(r.worklist_peak > 0, "{v}/{sched} t{threads}: rings never used");
                assert!(r.frontier_switches >= 1, "{v}/{sched} t{threads}: no telemetry");
            }
        }
    }
}

/// `--delta-threshold auto`: the residual-driven tuner must keep the
/// 1e-6-vs-Barrier equivalence while gathering no more vertex updates than
/// No-Sync's gather-everything sweeps.
#[test]
fn auto_delta_matches_barrier_with_no_more_work_than_nosync() {
    let g = synthetic::web_replica(2_000, 6, 42);
    let cfg = PrConfig {
        threads: 4,
        threshold: 1e-10,
        delta_auto: true,
        ..PrConfig::default()
    };
    let barrier = pagerank::run(&g, Variant::Barrier, &cfg).unwrap();
    let nosync = pagerank::run(&g, Variant::NoSync, &cfg).unwrap();
    assert!(barrier.converged && nosync.converged);
    assert!(nosync.vertex_updates > 0, "No-Sync must be instrumented");
    for v in [Variant::Frontier, Variant::FrontierPcpm] {
        let r = pagerank::run(&g, v, &cfg).unwrap();
        assert!(r.converged, "{v} (auto) did not converge");
        let l1 = r.l1_norm(&barrier.ranks);
        assert!(l1 < 1e-6, "{v} (auto): L1 vs barrier {l1}");
        assert!(
            r.vertex_updates <= nosync.vertex_updates,
            "{v} (auto) gathered {} vertex updates, No-Sync {}",
            r.vertex_updates,
            nosync.vertex_updates
        );
    }
}

/// `--numa pin|interleave` is worker placement only: on any host —
/// including single-node CI machines, where the sysfs detection falls back
/// to one node holding every CPU — the placed runs land on the same fixed
/// point as `--numa off`.
#[test]
fn numa_placement_does_not_change_the_fixed_point() {
    use pagerank_nb::engine::topology::Placement;
    let g = synthetic::web_replica(2_000, 6, 42);
    let base = PrConfig { threads: 2, threshold: 1e-10, ..PrConfig::default() };
    let off = pagerank::run(&g, Variant::Frontier, &base).unwrap();
    assert!(off.converged);
    for numa in [Placement::Pin, Placement::Interleave] {
        for v in [Variant::Frontier, Variant::Barrier] {
            let cfg = PrConfig { numa, ..base.clone() };
            let r = pagerank::run(&g, v, &cfg).unwrap();
            assert!(r.converged, "{v}/{numa} did not converge");
            let l1 = r.l1_norm(&off.ranks);
            assert!(l1 < 1e-6, "{v}/{numa}: L1 vs --numa off {l1}");
        }
    }
}

/// The XlaBlock-excluded dispatch path: the engine registry rejects it with
/// a pointer at `run_with_engine` instead of panicking or hanging.
#[test]
fn xla_block_dispatch_error_path() {
    let g = synthetic::chain(8);
    let err = pagerank::run(&g, Variant::XlaBlock, &PrConfig::default());
    assert!(err.is_err());
    let msg = err.unwrap_err().to_string();
    assert!(msg.contains("run_with_engine"), "unexpected message: {msg}");
}
