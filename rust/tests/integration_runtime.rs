//! Three-layer integration: execute the AOT-compiled JAX/Pallas artifacts
//! through PJRT and compare against the Rust sequential solver.
//!
//! Requires `make artifacts`; each test skips (with a loud message) when the
//! artifact directory is absent so `cargo test` stays runnable pre-build.

use pagerank_nb::graph::synthetic;
use pagerank_nb::pagerank::{self, seq, xla_block, PrConfig, Variant};
use pagerank_nb::runtime::{artifacts, ArtifactKind, ArtifactSpec, Engine};

fn artifacts_ready() -> bool {
    let dir = artifacts::default_dir();
    match ArtifactSpec::discover(&dir) {
        Ok(specs) if !specs.is_empty() => true,
        _ => {
            eprintln!(
                "SKIP: no artifacts in {} — run `make artifacts`",
                dir.display()
            );
            false
        }
    }
}

fn cfg() -> PrConfig {
    PrConfig { threads: 1, threshold: 1e-7, ..PrConfig::default() }
}

#[test]
fn discovers_expected_buckets() {
    if !artifacts_ready() {
        return;
    }
    let specs = ArtifactSpec::discover(&artifacts::default_dir()).unwrap();
    assert!(specs.iter().any(|s| s.kind == ArtifactKind::EllStep && s.n == 256 && s.k == 16));
    assert!(specs.iter().any(|s| s.kind == ArtifactKind::EllStep && s.n == 4096 && s.k == 64));
    assert!(specs.iter().any(|s| s.kind == ArtifactKind::DenseStep && s.n == 64));
    assert!(specs.iter().any(|s| s.kind == ArtifactKind::DensePower));
}

#[test]
fn ell_step_executes_and_matches_manual_math() {
    if !artifacts_ready() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let step = engine.load_best_ell(&artifacts::default_dir(), 256, 16).unwrap();
    let (n, k) = (step.spec.n, step.spec.k);
    // Hand-built instance: row u gathers vertex (u+1) % n with weight 0.5.
    let mut indices = vec![0i32; n * k];
    let mut weights = vec![0f32; n * k];
    for u in 0..n {
        indices[u * k] = ((u + 1) % n) as i32;
        weights[u * k] = 0.5;
    }
    let pr: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let base = 1.0f32;
    let out = step.run_ell(&indices, &weights, &pr, base).unwrap();
    for u in 0..n {
        let want = 1.0 + 0.5 * (((u + 1) % n) as f32);
        assert!((out[u] - want).abs() < 1e-5, "row {u}: {} vs {want}", out[u]);
    }
}

#[test]
fn xla_block_matches_sequential_on_cycle() {
    if !artifacts_ready() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let g = synthetic::cycle(64);
    let r = pagerank::run_with_engine(&g, Variant::XlaBlock, &cfg(), &engine).unwrap();
    assert!(r.converged);
    for &x in &r.ranks {
        assert!((x - 1.0 / 64.0).abs() < 1e-5, "rank {x}");
    }
}

#[test]
fn xla_block_matches_sequential_on_web_replica() {
    if !artifacts_ready() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let g = synthetic::web_replica(600, 6, 301);
    let c = cfg();
    let r = pagerank::run_with_engine(&g, Variant::XlaBlock, &c, &engine).unwrap();
    assert!(r.converged);
    let (sr, _, _) = seq::solve(&g, &c);
    let l1 = r.l1_norm(&sr);
    // f32 artifact: per-vertex error ~1e-7 · n vertices
    assert!(l1 < 1e-3, "L1 vs sequential: {l1}");
    // ranking order must agree at the top
    let top_xla: Vec<u32> = r.top_k(5).into_iter().map(|(u, _)| u).collect();
    let mut idx: Vec<u32> = (0..sr.len() as u32).collect();
    idx.sort_by(|&a, &b| sr[b as usize].partial_cmp(&sr[a as usize]).unwrap().then(a.cmp(&b)));
    assert_eq!(top_xla, idx[..5].to_vec());
}

#[test]
fn xla_block_larger_bucket_path() {
    if !artifacts_ready() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    // road replica: low degree, needs the n=1024 or 4096 bucket by size
    let g = synthetic::road_replica(900, 302);
    let c = cfg();
    let r = pagerank::run_with_engine(&g, Variant::XlaBlock, &c, &engine).unwrap();
    assert!(r.converged);
    let (sr, _, _) = seq::solve(&g, &c);
    assert!(r.l1_norm(&sr) < 1e-3, "L1 {}", r.l1_norm(&sr));
}

#[test]
fn xla_block_errors_when_graph_exceeds_buckets() {
    if !artifacts_ready() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let g = synthetic::cycle(100_000); // far beyond the 4096 bucket
    let err = xla_block::run(&g, &cfg(), &engine);
    assert!(err.is_err());
}

#[test]
fn dense_step_executes() {
    if !artifacts_ready() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let specs = ArtifactSpec::discover(&artifacts::default_dir()).unwrap();
    let dense = ArtifactSpec::best_dense(&specs, 64).expect("dense_n64");
    let step = engine.load(dense).unwrap();
    let n = step.spec.n;
    // M = 0 → result is uniformly `base`.
    let matrix = vec![0f32; n * n];
    let pr = vec![1.0f32 / n as f32; n];
    let out = step.run_dense(&matrix, &pr, 0.25).unwrap();
    for &x in &out {
        assert!((x - 0.25).abs() < 1e-6);
    }
}

#[test]
fn engine_caches_compiled_modules() {
    if !artifacts_ready() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let dir = artifacts::default_dir();
    let a = engine.load_best_ell(&dir, 100, 8).unwrap();
    let b = engine.load_best_ell(&dir, 100, 8).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b), "second load must hit the cache");
}
