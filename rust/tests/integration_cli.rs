//! CLI integration: drive `cli::dispatch` end-to-end (no subprocess —
//! dispatch is the same code path `main` uses).

use pagerank_nb::cli;

fn argv(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|s| s.to_string()).collect()
}

#[test]
fn run_on_generated_graph() {
    cli::dispatch(&argv(&[
        "run", "--graph", "web:800:6", "--algo", "no-sync", "--threads", "3", "--top", "3",
    ]))
    .expect("run should succeed");
}

#[test]
fn run_all_variant_names_parse_via_cli() {
    for algo in [
        "sequential",
        "barrier",
        "barrier-identical",
        "barrier-edge",
        "barrier-opt",
        "wait-free",
        "no-sync",
        "no-sync-identical",
        "no-sync-opt",
        "no-sync-opt-identical",
        "pcpm",
        "partition-centric",
        "frontier",
        "frontier-pcpm",
    ] {
        cli::dispatch(&argv(&[
            "run", "--graph", "cycle:60", "--algo", algo, "--threads", "2",
        ]))
        .unwrap_or_else(|e| panic!("algo {algo}: {e}"));
    }
}

#[test]
fn mode_flag_runs_partition_centric() {
    cli::dispatch(&argv(&[
        "run", "--graph", "web:600:5", "--mode", "pcpm", "--threads", "3", "--top", "3",
    ]))
    .expect("--mode pcpm should run");
}

#[test]
fn mode_flag_runs_frontier_with_delta_threshold() {
    cli::dispatch(&argv(&[
        "run", "--graph", "web:600:5", "--mode", "frontier", "--threads", "3",
        "--delta-threshold", "1e-9", "--top", "3",
    ]))
    .expect("--mode frontier should run");
    cli::dispatch(&argv(&[
        "run", "--graph", "web:600:5", "--mode", "frontier-pcpm", "--threads", "3",
    ]))
    .expect("--mode frontier-pcpm should run");
    cli::dispatch(&argv(&[
        "run", "--graph", "cycle:20", "--mode", "frontier", "--delta-threshold", "-1",
    ]))
    .expect_err("negative delta threshold must be rejected");
}

#[test]
fn bench_ci_writes_report_and_gates_against_itself() {
    // per-process dir: concurrent `cargo test` runs must not race on files
    let dir = std::env::temp_dir()
        .join(format!("pagerank_nb_cli_bench_ci_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out = dir.join("BENCH_ci.json");
    let base = dir.join("BENCH_baseline.json");
    let _ = std::fs::remove_file(&out);
    let _ = std::fs::remove_file(&base);
    // bootstrap: no baseline yet — must still write the report and pass
    cli::dispatch(&argv(&[
        "bench-ci", "--scale", "20000", "--threads", "2", "--samples", "1",
        "--out", out.to_str().unwrap(), "--baseline", base.to_str().unwrap(),
    ]))
    .expect("bench-ci bootstrap run");
    let text = std::fs::read_to_string(&out).expect("report written");
    assert!(text.contains("\"Frontier\""), "report must cover the frontier variant");
    assert!(text.contains("\"PCPM\""));
    // Gate a fresh run against the first run's report. Tiny-graph timings
    // jitter (thread spawn dominates), so give the gate a wide budget —
    // this asserts the comparison machinery runs, not timing stability.
    std::fs::copy(&out, &base).unwrap();
    cli::dispatch(&argv(&[
        "bench-ci", "--scale", "20000", "--threads", "2", "--samples", "1",
        "--max-regress", "25",
        "--out", out.to_str().unwrap(), "--baseline", base.to_str().unwrap(),
    ]))
    .expect("bench-ci gate vs own baseline");
}

#[test]
fn info_and_validate() {
    cli::dispatch(&argv(&["info", "--graph", "star:50"])).expect("info");
    cli::dispatch(&argv(&[
        "validate", "--graph", "web:500:5", "--threads", "3",
    ]))
    .expect("validate should pass on a healthy build");
}

#[test]
fn gen_writes_datasets() {
    let out = std::env::temp_dir().join("pagerank_nb_cli_gen");
    std::fs::remove_dir_all(&out).ok();
    cli::dispatch(&argv(&[
        "gen",
        "--dataset",
        "webStanford",
        "--out",
        out.to_str().unwrap(),
        "--scale",
        "2000",
    ]))
    .expect("gen");
    assert!(out.join("webStanford.bin").exists());
    // and the generated file loads back through `info`
    cli::dispatch(&argv(&[
        "info",
        "--graph",
        out.join("webStanford.bin").to_str().unwrap(),
    ]))
    .expect("info on generated dataset");
}

#[test]
fn errors_are_reported_not_panicked() {
    assert!(cli::dispatch(&argv(&[])).is_err());
    assert!(cli::dispatch(&argv(&["frobnicate"])).is_err());
    assert!(cli::dispatch(&argv(&["run"])).is_err()); // missing --graph
    assert!(cli::dispatch(&argv(&["run", "--graph", "nope:1"])).is_err());
    assert!(cli::dispatch(&argv(&["run", "--graph", "cycle:10", "--algo", "bogus"])).is_err());
    assert!(cli::dispatch(&argv(&["gen", "--out", "/tmp/x"])).is_err()); // no --all/--dataset
}

#[test]
fn bench_table1_writes_reports() {
    let out = std::env::temp_dir().join("pagerank_nb_cli_bench");
    std::fs::remove_dir_all(&out).ok();
    cli::dispatch(&argv(&[
        "bench",
        "table1",
        "--out",
        out.to_str().unwrap(),
        "--scale",
        "5000",
        "--samples",
        "1",
    ]))
    .expect("bench table1");
    for ext in ["md", "csv", "json"] {
        assert!(out.join(format!("table1.{ext}")).exists(), "missing table1.{ext}");
    }
}
