//! CLI integration: drive `cli::dispatch` end-to-end (no subprocess —
//! dispatch is the same code path `main` uses).

use pagerank_nb::cli;

fn argv(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|s| s.to_string()).collect()
}

#[test]
fn run_on_generated_graph() {
    cli::dispatch(&argv(&[
        "run", "--graph", "web:800:6", "--algo", "no-sync", "--threads", "3", "--top", "3",
    ]))
    .expect("run should succeed");
}

#[test]
fn run_all_variant_names_parse_via_cli() {
    for algo in [
        "sequential",
        "barrier",
        "barrier-identical",
        "barrier-edge",
        "barrier-opt",
        "wait-free",
        "no-sync",
        "no-sync-identical",
        "no-sync-opt",
        "no-sync-opt-identical",
        "pcpm",
        "partition-centric",
    ] {
        cli::dispatch(&argv(&[
            "run", "--graph", "cycle:60", "--algo", algo, "--threads", "2",
        ]))
        .unwrap_or_else(|e| panic!("algo {algo}: {e}"));
    }
}

#[test]
fn mode_flag_runs_partition_centric() {
    cli::dispatch(&argv(&[
        "run", "--graph", "web:600:5", "--mode", "pcpm", "--threads", "3", "--top", "3",
    ]))
    .expect("--mode pcpm should run");
}

#[test]
fn info_and_validate() {
    cli::dispatch(&argv(&["info", "--graph", "star:50"])).expect("info");
    cli::dispatch(&argv(&[
        "validate", "--graph", "web:500:5", "--threads", "3",
    ]))
    .expect("validate should pass on a healthy build");
}

#[test]
fn gen_writes_datasets() {
    let out = std::env::temp_dir().join("pagerank_nb_cli_gen");
    std::fs::remove_dir_all(&out).ok();
    cli::dispatch(&argv(&[
        "gen",
        "--dataset",
        "webStanford",
        "--out",
        out.to_str().unwrap(),
        "--scale",
        "2000",
    ]))
    .expect("gen");
    assert!(out.join("webStanford.bin").exists());
    // and the generated file loads back through `info`
    cli::dispatch(&argv(&[
        "info",
        "--graph",
        out.join("webStanford.bin").to_str().unwrap(),
    ]))
    .expect("info on generated dataset");
}

#[test]
fn errors_are_reported_not_panicked() {
    assert!(cli::dispatch(&argv(&[])).is_err());
    assert!(cli::dispatch(&argv(&["frobnicate"])).is_err());
    assert!(cli::dispatch(&argv(&["run"])).is_err()); // missing --graph
    assert!(cli::dispatch(&argv(&["run", "--graph", "nope:1"])).is_err());
    assert!(cli::dispatch(&argv(&["run", "--graph", "cycle:10", "--algo", "bogus"])).is_err());
    assert!(cli::dispatch(&argv(&["gen", "--out", "/tmp/x"])).is_err()); // no --all/--dataset
}

#[test]
fn bench_table1_writes_reports() {
    let out = std::env::temp_dir().join("pagerank_nb_cli_bench");
    std::fs::remove_dir_all(&out).ok();
    cli::dispatch(&argv(&[
        "bench",
        "table1",
        "--out",
        out.to_str().unwrap(),
        "--scale",
        "5000",
        "--samples",
        "1",
    ]))
    .expect("bench table1");
    for ext in ["md", "csv", "json"] {
        assert!(out.join(format!("table1.{ext}")).exists(), "missing table1.{ext}");
    }
}
