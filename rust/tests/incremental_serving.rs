//! Integration suite for the incremental path and the epoch-snapshotted
//! serving layer (ISSUE 6 acceptance criteria):
//!
//! * **Property**: edge-batch insert/delete followed by incremental
//!   reconvergence matches a cold Barrier recompute of the mutated graph
//!   within `1e-6` L1 — with strictly fewer `vertex_updates`.
//! * **Edge cases**: delete-to-dangling, insert into an edgeless graph,
//!   mutation during an in-flight epoch snapshot read.
//! * **Stress**: concurrent `rank`/`top_k` readers observe only
//!   fully-published, internally-consistent epoch snapshots.

use pagerank_nb::cli;
use pagerank_nb::engine::incremental::{self, mutate_and_reconverge};
use pagerank_nb::graph::{synthetic, GraphBuilder, GraphDelta};
use pagerank_nb::pagerank::{self, convergence, PrConfig, Variant};
use pagerank_nb::serving::ServingEngine;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const INCREMENTAL: [Variant; 2] = [Variant::Frontier, Variant::FrontierPcpm];

fn cfg(threads: usize) -> PrConfig {
    PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
}

/// The headline property, across several seeds and batch mixes: after a
/// mutation batch, warm reconvergence lands within 1e-6 L1 of a cold
/// Barrier run on the mutated graph while doing strictly less work. The
/// cold run's `vertex_updates` is `iterations × n` (every Blocking sweep
/// gathers every vertex), so "strictly fewer" has a wide, stable margin.
#[test]
fn incremental_matches_cold_barrier_with_strictly_fewer_updates() {
    let c = cfg(4);
    for (seed, inserts, deletes) in
        [(3u64, 12usize, 6usize), (17, 40, 0), (29, 0, 25), (51, 8, 8)]
    {
        let base = synthetic::web_replica(1_200, 6, seed);
        let warm = pagerank::run(&base, Variant::Frontier, &c).expect("cold frontier");
        let delta = GraphDelta::random(&base, inserts, deletes, seed ^ 0xBEEF);
        assert!(!delta.is_empty());
        let cold = {
            let applied = base.apply_delta(&delta).expect("delta applies");
            pagerank::run(&applied.graph, Variant::Barrier, &c).expect("cold barrier")
        };
        assert!(cold.converged);
        assert!(cold.vertex_updates > 0, "Barrier instruments its gather");
        for v in INCREMENTAL {
            let inc = mutate_and_reconverge(&base, &delta, v, &c, &warm.ranks)
                .unwrap_or_else(|e| panic!("{v} seed {seed}: {e}"));
            assert!(inc.result.converged, "{v} seed {seed}");
            let l1 = inc.result.l1_norm(&cold.ranks);
            assert!(l1 < 1e-6, "{v} seed {seed}: l1 {l1}");
            assert!(
                inc.result.vertex_updates < cold.vertex_updates,
                "{v} seed {seed}: incremental {} >= cold {}",
                inc.result.vertex_updates,
                cold.vertex_updates
            );
        }
    }
}

/// Deleting a vertex's only out-edge makes it dangling; the incremental
/// path must pick up the degree flip (its former target loses mass, the
/// uniform base term redistributes) and still match the cold oracle.
#[test]
fn delete_to_dangling_reconverges_correctly() {
    let c = cfg(3);
    let base = synthetic::web_replica(500, 5, 7);
    // find a vertex with exactly one out-edge
    let u = (0..500u32)
        .find(|&u| base.out_degree(u) == 1)
        .expect("web replica has degree-1 vertices");
    let target = base.out_neighbors(u)[0];
    let warm = pagerank::run(&base, Variant::Frontier, &c).unwrap();
    let mut delta = GraphDelta::new();
    delta.delete(u, target);
    for v in INCREMENTAL {
        let inc = mutate_and_reconverge(&base, &delta, v, &c, &warm.ranks).unwrap();
        assert_eq!(
            inc.graph.dangling_count(),
            base.dangling_count() + 1,
            "{v}: vertex {u} should now dangle"
        );
        let cold = pagerank::run(&inc.graph, Variant::Barrier, &c).unwrap();
        let l1 = inc.result.l1_norm(&cold.ranks);
        assert!(l1 < 1e-6, "{v}: l1 {l1}");
    }
}

/// Inserting into a graph with no edges at all: every vertex starts
/// dangling at the uniform rank, and the first inserts must wake exactly
/// the touched neighbourhoods.
#[test]
fn insert_into_edgeless_graph_reconverges() {
    let c = cfg(2);
    let base = GraphBuilder::new(40).build("blank");
    let warm = pagerank::run(&base, Variant::Frontier, &c).unwrap();
    let mut delta = GraphDelta::new();
    delta.insert(0, 1).insert(1, 2).insert(2, 0).insert(3, 0);
    for v in INCREMENTAL {
        let inc = mutate_and_reconverge(&base, &delta, v, &c, &warm.ranks).unwrap();
        assert!(inc.result.converged, "{v}");
        let cold = pagerank::run(&inc.graph, Variant::Barrier, &c).unwrap();
        let l1 = inc.result.l1_norm(&cold.ranks);
        assert!(l1 < 1e-6, "{v}: l1 {l1}");
        // untouched vertices keep a rank consistent with the oracle too
        let linf = convergence::linf_norm(&inc.result.ranks, &cold.ranks);
        assert!(linf < 1e-6, "{v}: linf {linf}");
    }
}

/// A mutation epoch must never disturb a snapshot a reader is holding:
/// the old `Arc` stays frozen at its epoch and scores while the server
/// moves on.
#[test]
fn mutation_during_in_flight_snapshot_read() {
    let g = synthetic::web_replica(300, 5, 11);
    let mut engine = ServingEngine::bootstrap(g, Variant::Frontier, cfg(2)).unwrap();
    let server = engine.server();
    let held = server.snapshot();
    assert_eq!(held.epoch(), 1);
    let held_ranks = held.ranks().to_vec();
    let held_top = held.top_k(5);

    let delta = GraphDelta::random(engine.graph(), 20, 10, 77);
    let stats = engine.apply(&delta).unwrap();
    assert_eq!(stats.epoch, 2, "publish bumps the epoch by one");
    assert_eq!(server.epoch(), 2);

    // the in-flight snapshot is bit-identical to what it was pre-mutation
    assert_eq!(held.epoch(), 1);
    assert_eq!(held.ranks(), held_ranks.as_slice());
    assert_eq!(held.top_k(5), held_top);
    assert!(held.verify(), "held snapshot must stay internally consistent");
    // while new readers see the reconverged scores
    assert!(server.snapshot().verify());
}

/// Readers hammering the server while a writer applies a stream of deltas
/// must only ever observe fully-published snapshots: checksums verify,
/// epochs never run backwards, and `top_k` is internally consistent with
/// `rank` on the same snapshot.
#[test]
fn concurrent_readers_only_see_published_epochs() {
    let g = synthetic::web_replica(400, 5, 19);
    let mut engine = ServingEngine::bootstrap(g, Variant::Frontier, cfg(2)).unwrap();
    let server = engine.server();
    let done = AtomicBool::new(false);
    std::thread::scope(|s| {
        for _ in 0..4 {
            let server = Arc::clone(&server);
            let done = &done;
            s.spawn(move || {
                let mut last_epoch = 0u64;
                while !done.load(Ordering::Acquire) {
                    let snap = server.snapshot();
                    assert!(snap.verify(), "torn snapshot observed");
                    let e = snap.epoch();
                    assert!(e >= last_epoch, "epoch ran backwards: {e} < {last_epoch}");
                    last_epoch = e;
                    let top = snap.top_k(3);
                    for &(v, score) in &top {
                        assert_eq!(
                            snap.rank(v),
                            Some(score),
                            "top_k and rank disagree inside one snapshot"
                        );
                    }
                    std::thread::yield_now();
                }
            });
        }
        for step in 0..5u64 {
            let delta = GraphDelta::random(engine.graph(), 10, 5, 1_000 + step);
            let stats = engine.apply(&delta).unwrap();
            assert_eq!(stats.epoch, 2 + step);
        }
        done.store(true, Ordering::Release);
    });
    assert_eq!(server.epoch(), 6);
    assert!(server.queries_served() > 0);
}

/// `seed_frontier` is what makes the reconvergence *sound*: it must cover
/// the touched vertices and their out-neighbourhoods. (Correctness of the
/// covering set is exercised end-to-end above; this pins the contract.)
#[test]
fn seed_frontier_covers_out_neighbourhoods() {
    let g = synthetic::star(8); // hub 0 ↔ leaves 1..8
    let dirty = incremental::seed_frontier(&g, &[0]);
    for v in 0..8u32 {
        assert!(dirty.is_set(v), "hub seed must cover every leaf (vertex {v})");
    }
    let leaf_only = incremental::seed_frontier(&g, &[3]);
    assert!(leaf_only.is_set(3));
    assert!(leaf_only.is_set(0), "leaf 3 points at the hub");
    assert!(!leaf_only.is_set(4), "unrelated leaf must stay clean");
}

/// The CLI `serve` subcommand runs the whole evolve-query-reconverge loop
/// end-to-end (same code path as `main`).
#[test]
fn cli_serve_smoke() {
    let argv: Vec<String> = [
        "serve", "--graph", "web:400:5", "--epochs", "2", "--batch", "8", "--readers", "1",
        "--threads", "2", "--top", "3", "--seed", "5",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    cli::dispatch(&argv).expect("serve should succeed");
    // non-incremental modes are rejected with a clear error
    let bad: Vec<String> = ["serve", "--graph", "cycle:20", "--mode", "barrier"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let err = cli::dispatch(&bad).unwrap_err();
    assert!(err.to_string().contains("frontier"), "{err}");
}
