//! Minimal CPU-affinity shim — pin the calling thread to a set of CPUs.
//!
//! Vendored beside `mmap-lite` for the same reason that crate exists: the
//! offline image carries no `libc`/`nix`, and all the engine needs is one
//! raw syscall wrapper. On Linux, [`pin_to_cpus`] calls the C library's
//! `sched_setaffinity(2)` for the calling thread (pid 0); the symbol is
//! already in every Linux process image, so declaring it `extern "C"` adds
//! no dependency. Everywhere else the call is a successful no-op, so
//! callers never need a `cfg` of their own.

#![warn(missing_docs)]
#![deny(unsafe_op_in_unsafe_fn)]

/// Pin the calling thread to the given CPU ids.
///
/// Best-effort: returns `Ok(())` on success (including the no-op non-Linux
/// fallback and the empty-slice "no constraint requested" case) and
/// `Err(rc)` with the raw nonzero return code when the kernel rejects the
/// mask — e.g. every listed CPU is offline, or a container seccomp policy
/// denies the syscall. Callers treat failure as "placement unavailable",
/// never as fatal.
pub fn pin_to_cpus(cpus: &[usize]) -> Result<(), i32> {
    if cpus.is_empty() {
        return Ok(());
    }
    imp::pin(cpus)
}

#[cfg(target_os = "linux")]
mod imp {
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }

    pub fn pin(cpus: &[usize]) -> Result<(), i32> {
        let max = cpus.iter().copied().max().unwrap_or(0);
        let mut mask = vec![0u64; max / 64 + 1];
        for &c in cpus {
            mask[c / 64] |= 1u64 << (c % 64);
        }
        // SAFETY: `mask` outlives the call and `cpusetsize` is exactly the
        // buffer's byte length, so the kernel reads only initialized memory;
        // pid 0 addresses the calling thread (sched_setaffinity(2)), which
        // cannot invalidate any Rust-side state. The symbol is provided by
        // the C library every Linux process links.
        let rc = unsafe { sched_setaffinity(0, mask.len() * 8, mask.as_ptr()) };
        if rc == 0 {
            Ok(())
        } else {
            Err(rc)
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod imp {
    pub fn pin(_cpus: &[usize]) -> Result<(), i32> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_set_is_a_noop() {
        assert_eq!(pin_to_cpus(&[]), Ok(()));
    }

    #[test]
    fn pinning_to_cpu_zero_succeeds_or_reports_a_code() {
        // CPU 0 exists on every host this crate targets; a sandbox may still
        // deny the syscall, which must surface as Err, never UB or a panic.
        match pin_to_cpus(&[0]) {
            Ok(()) => {}
            Err(rc) => assert_ne!(rc, 0),
        }
    }

    #[test]
    fn wide_masks_cover_high_cpu_ids() {
        // CPU 130 forces a 3-word mask; the call must not index out of
        // bounds even when the host has far fewer CPUs (EINVAL is fine).
        let _ = pin_to_cpus(&[0, 130]);
    }
}
