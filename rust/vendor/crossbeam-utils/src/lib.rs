//! Offline stand-in for `crossbeam-utils`: only [`CachePadded`], which is
//! all this project uses. Vendored because the build image has no crates.io
//! registry access.

#![deny(unsafe_op_in_unsafe_fn)]

use std::ops::{Deref, DerefMut};

/// Pads and aligns a value to 128 bytes so adjacent values never share a
/// cache line (128 covers the 2-line prefetcher on modern x86 and the
/// 128-byte lines on some aarch64 parts — same choice as upstream).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    /// Wrap a value.
    pub const fn new(value: T) -> Self {
        Self { value }
    }

    /// Unwrap back into the inner value.
    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> Deref for CachePadded<T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

impl<T> From<T> for CachePadded<T> {
    fn from(value: T) -> Self {
        Self::new(value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_is_128() {
        assert_eq!(std::mem::align_of::<CachePadded<u64>>(), 128);
        assert!(std::mem::size_of::<CachePadded<u64>>() >= 128);
    }

    #[test]
    fn deref_roundtrip() {
        let mut c = CachePadded::new(5u32);
        assert_eq!(*c, 5);
        *c = 9;
        assert_eq!(c.into_inner(), 9);
    }
}
