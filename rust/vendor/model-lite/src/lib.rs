//! # model-lite — vendored deterministic concurrency model checker
//!
//! A loom-style checker, small enough to vendor (no dependencies, offline
//! build image), for the non-blocking synchronization layer in
//! `rust/src/sync/`. [`check`] runs a closure under **exhaustive DFS over
//! thread interleavings with bounded preemptions**; the closure uses the
//! shim types in [`atomic`], [`thread`], and [`hint`] instead of their
//! `std` counterparts (normal builds get `std` back through the
//! `sync::shim` facade in the main crate, so production code is
//! unchanged).
//!
//! What makes this stronger than a stress test:
//!
//! * **Determinism.** Every scheduling (and stale-read) choice is a logged
//!   decision; the DFS replays prefixes exactly, so two [`check`] calls
//!   over the same closure explore the same tree and report the same
//!   [`Report`]. A failure prints a counterexample depth and re-raises the
//!   original panic.
//! * **Relaxed-memory modeling.** `Relaxed` loads may observe stale stores
//!   (bounded-staleness approximation of the C11 model, see [`atomic`]),
//!   so ordering bugs that only manifest on weak hardware — or only under
//!   compiler reordering — become reachable interleavings on any host.
//! * **Happens-before tracking.** Threads carry vector clocks joined by
//!   release/acquire pairs, spawn, and join; [`hb`] exposes snapshots so
//!   tests can assert that a publication protocol actually orders what it
//!   claims to order, not merely that the observed values were right.
//!
//! Scope bounds (deliberate, documented in [`atomic`] and [`exec`]): at
//! most [`Options::preemption_bound`] preemptive switches per execution
//! (Musuvathi–Qadeer), a bounded stale-store window, `SeqCst` modeled as
//! `AcqRel`, and no spurious `compare_exchange_weak` failure. Within those
//! bounds the exploration is exhaustive — "no counterexample" means *no
//! reachable interleaving violates the invariant*, not "we didn't happen
//! to see one".

#![deny(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(missing_docs)]

pub mod atomic;
mod clock;
mod exec;
pub mod hint;
pub mod thread;

pub use exec::{check, check_with, Options, Report};

pub mod hb {
    //! Happens-before snapshots for model tests.
    //!
    //! Capture [`now`] at the point that *should* be ordered (e.g. right
    //! after writing payload data), carry the snapshot through the join,
    //! and assert [`Clock::happens_before`] a snapshot taken where the
    //! ordering is relied upon. If a `Release`/`Acquire` pair is demoted
    //! to `Relaxed`, the sync clock stops flowing and the assertion fails
    //! in every interleaving — even ones where the observed *values*
    //! happened to look right.

    /// An opaque snapshot of the calling model thread's vector clock
    /// (empty outside a [`crate::check`] execution).
    #[derive(Clone, Debug)]
    pub struct Clock(pub(crate) crate::clock::VClock);

    /// Snapshot the calling thread's current clock.
    pub fn now() -> Clock {
        Clock(crate::exec::clock_snapshot())
    }

    impl Clock {
        /// Is everything up to `self` ordered before `other`?
        pub fn happens_before(&self, other: &Clock) -> bool {
            self.0.le(&other.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    #[test]
    fn single_thread_runs_once() {
        let r = crate::check(|| {
            let a = AtomicU64::new(1);
            a.store(2, Ordering::Relaxed);
            assert_eq!(a.load(Ordering::Relaxed), 2);
        });
        assert_eq!(r.executions, 1, "no concurrency, no branching");
    }

    #[test]
    fn spawn_join_passes_values_and_clocks() {
        crate::check(|| {
            let a = Arc::new(AtomicU64::new(0));
            let a2 = Arc::clone(&a);
            let h = crate::thread::spawn(move || {
                a2.store(7, Ordering::Relaxed);
                crate::hb::now()
            });
            let child_clock = h.join().unwrap();
            // Join edge: the child's writes happen-before us, so even a
            // Relaxed load must observe them.
            assert!(child_clock.happens_before(&crate::hb::now()));
            assert_eq!(a.load(Ordering::Relaxed), 7);
        });
    }

    #[test]
    fn release_acquire_message_passing_holds() {
        crate::check(|| {
            let data = Arc::new(AtomicU64::new(0));
            let ready = Arc::new(AtomicU64::new(0));
            let (d, r) = (Arc::clone(&data), Arc::clone(&ready));
            let h = crate::thread::spawn(move || {
                d.store(42, Ordering::Relaxed);
                r.store(1, Ordering::Release);
            });
            if ready.load(Ordering::Acquire) == 1 {
                assert_eq!(data.load(Ordering::Relaxed), 42, "acquire must order the payload");
            }
            h.join().unwrap();
        });
    }

    #[test]
    #[should_panic(expected = "payload")]
    fn relaxed_message_passing_is_caught() {
        crate::check(|| {
            let data = Arc::new(AtomicU64::new(0));
            let ready = Arc::new(AtomicU64::new(0));
            let (d, r) = (Arc::clone(&data), Arc::clone(&ready));
            let h = crate::thread::spawn(move || {
                d.store(42, Ordering::Relaxed);
                r.store(1, Ordering::Relaxed); // bug: demoted Release
            });
            if ready.load(Ordering::Relaxed) == 1 {
                // Some interleaving observes the flag but a stale payload.
                assert_eq!(data.load(Ordering::Relaxed), 42, "payload");
            }
            h.join().unwrap();
        });
    }

    #[test]
    #[should_panic(expected = "mark lost")]
    fn ttas_lost_update_is_caught() {
        // The PR 5 `DirtyFlags::set` bug in miniature: a relaxed
        // test-and-test-and-set pre-load can observe a *stale* set bit
        // from before a concurrent drain's claim, skip the fetch_or, and
        // lose the mark. The unconditional fetch_or fix passes this
        // closure; the TTAS version must not.
        crate::check(|| {
            let data = Arc::new(AtomicU64::new(0));
            let flag = Arc::new(AtomicU64::new(1)); // stale mark, prior round
            let (d, f) = (Arc::clone(&data), Arc::clone(&flag));
            let drainer = crate::thread::spawn(move || {
                if f.fetch_and(0, Ordering::AcqRel) & 1 != 0 {
                    d.load(Ordering::Acquire)
                } else {
                    0
                }
            });
            data.store(42, Ordering::Release);
            if flag.load(Ordering::Relaxed) & 1 == 0 {
                flag.fetch_or(1, Ordering::AcqRel);
            }
            let seen_early = drainer.join().unwrap();
            let seen_late = if flag.load(Ordering::Acquire) & 1 != 0 {
                data.load(Ordering::Acquire)
            } else {
                0
            };
            assert!(seen_early == 42 || seen_late == 42, "mark lost");
        });
    }

    #[test]
    fn unconditional_fetch_or_mark_never_lost() {
        // Same protocol with the fix: fetch_or unconditionally.
        crate::check(|| {
            let data = Arc::new(AtomicU64::new(0));
            let flag = Arc::new(AtomicU64::new(1));
            let (d, f) = (Arc::clone(&data), Arc::clone(&flag));
            let drainer = crate::thread::spawn(move || {
                if f.fetch_and(0, Ordering::AcqRel) & 1 != 0 {
                    d.load(Ordering::Acquire)
                } else {
                    0
                }
            });
            data.store(42, Ordering::Release);
            flag.fetch_or(1, Ordering::AcqRel);
            let seen_early = drainer.join().unwrap();
            let seen_late = if flag.load(Ordering::Acquire) & 1 != 0 {
                data.load(Ordering::Acquire)
            } else {
                0
            };
            assert!(seen_early == 42 || seen_late == 42);
        });
    }

    #[test]
    fn spin_wait_terminates() {
        // consume-staleness + yield promotion: a spinner must observe the
        // writer's store in finitely many schedule points.
        crate::check(|| {
            let ready = Arc::new(AtomicU64::new(0));
            let r = Arc::clone(&ready);
            let h = crate::thread::spawn(move || r.store(1, Ordering::Release));
            while ready.load(Ordering::Acquire) == 0 {
                crate::hint::spin_loop();
            }
            h.join().unwrap();
        });
    }

    #[test]
    fn exploration_is_deterministic() {
        fn body() {
            let a = Arc::new(AtomicU64::new(0));
            let (a1, a2) = (Arc::clone(&a), Arc::clone(&a));
            let h1 = crate::thread::spawn(move || a1.fetch_add(1, Ordering::AcqRel));
            let h2 = crate::thread::spawn(move || a2.fetch_add(1, Ordering::AcqRel));
            h1.join().unwrap();
            h2.join().unwrap();
            assert_eq!(a.load(Ordering::Acquire), 2);
        }
        let r1 = crate::check(body);
        let r2 = crate::check(body);
        assert_eq!(r1, r2, "same closure, same tree");
        assert!(r1.executions > 1, "two racing increments must branch");
    }

    #[test]
    fn cas_contention_is_exclusive() {
        crate::check(|| {
            let a = Arc::new(AtomicU64::new(0));
            let (a1, a2) = (Arc::clone(&a), Arc::clone(&a));
            let h1 = crate::thread::spawn(move || {
                a1.compare_exchange(0, 1, Ordering::AcqRel, Ordering::Acquire).is_ok()
            });
            let h2 = crate::thread::spawn(move || {
                a2.compare_exchange(0, 2, Ordering::AcqRel, Ordering::Acquire).is_ok()
            });
            let (w1, w2) = (h1.join().unwrap(), h2.join().unwrap());
            assert!(w1 ^ w2, "exactly one CAS wins");
        });
    }

    #[test]
    fn fallback_outside_check_uses_real_atomics() {
        let a = AtomicU64::new(5);
        assert_eq!(a.fetch_add(3, Ordering::SeqCst), 5);
        assert_eq!(a.load(Ordering::SeqCst), 8);
        assert_eq!(a.compare_exchange(8, 9, Ordering::SeqCst, Ordering::SeqCst), Ok(8));
        let h = crate::thread::spawn(|| 11u32);
        assert_eq!(h.join().unwrap(), 11);
        crate::hint::spin_loop();
    }
}
