//! Model-checked drop-ins for `std::sync::atomic`.
//!
//! Inside a [`crate::check`] execution every operation is a schedule point,
//! and the memory semantics are a bounded approximation of the C11 model:
//!
//! * Each atomic keeps its whole-execution **modification order** (the list
//!   of stores), each store stamped with the writer's vector clock and the
//!   **release-sequence sync clock** (the clock an acquire load joining the
//!   sequence must inherit; RMWs extend the sequence, plain stores restart
//!   it).
//! * A **`Relaxed`/`Acquire` load** may observe any store that is not
//!   happens-before-overwritten for the loading thread — so `Relaxed`
//!   readers see genuinely stale values, which is how demoting an
//!   `Acquire`/`Release` pair to `Relaxed` becomes a *reachable* bug
//!   instead of an x86 accident. Acquire loads additionally join the
//!   observed store's sync clock (synchronizes-with); relaxed loads do not.
//! * **RMWs** (`fetch_*`, `swap`, `compare_exchange*`) always operate on
//!   the newest store — atomicity — which is exactly why `fetch_or` fixes
//!   a test-and-test-and-set race that a relaxed pre-load reintroduces.
//!
//! Bounds that keep the DFS tree finite (documented approximations):
//! each thread may observe a given stale store **once** (its next load of
//! that variable is forced at least one store newer, so spin loops always
//! progress); the staleness window is the last [`MAX_HIST`] stores;
//! `compare_exchange_weak` never fails spuriously; `SeqCst` is modeled as
//! `AcqRel` (no single total order beyond per-variable modification
//! order).
//!
//! Outside a `check` execution every type falls back to the real
//! `std::sync::atomic` operation with the caller's ordering, so a build
//! with the model feature enabled still runs ordinary code correctly.

use crate::clock::VClock;
use crate::exec;
use std::sync::atomic::AtomicU64 as StdAtomicU64;
use std::sync::Mutex;

pub use std::sync::atomic::Ordering;

/// Staleness window: loads may reach back at most this many stores.
const MAX_HIST: usize = 6;

/// One store in a variable's modification order.
struct StoreEv {
    val: u64,
    /// The writer's full happens-before clock at the store — bounds which
    /// loads may still legally observe *earlier* stores.
    clock: VClock,
    /// Clock joined into acquire loads that observe this store (empty for
    /// a relaxed plain store: nothing synchronizes).
    sync: VClock,
}

/// Per-execution model state of one atomic, rebuilt lazily whenever the
/// owning execution changes (atomics may outlive or predate an execution).
struct VarState {
    exec_id: u64,
    stores: Vec<StoreEv>,
    /// Per-thread floor into `stores`: the oldest index that thread may
    /// still observe (coherence + the observe-a-stale-store-once bound).
    seen: Vec<usize>,
}

impl VarState {
    fn fresh(exec_id: u64, val: u64) -> Self {
        // The initial value carries the zero clock: visible to everyone,
        // staler than every in-execution store.
        Self {
            exec_id,
            stores: vec![StoreEv { val, clock: VClock::new(), sync: VClock::new() }],
            seen: Vec::new(),
        }
    }

    fn floor_of(&self, tid: usize) -> usize {
        self.seen.get(tid).copied().unwrap_or(0)
    }

    fn note_seen(&mut self, tid: usize, idx: usize) {
        if self.seen.len() <= tid {
            self.seen.resize(tid + 1, 0);
        }
        if idx > self.seen[tid] {
            self.seen[tid] = idx;
        }
    }
}

fn acquires(o: Ordering) -> bool {
    matches!(o, Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst)
}

fn releases(o: Ordering) -> bool {
    matches!(o, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst)
}

/// Shared machinery behind every typed wrapper: a real `AtomicU64` (the
/// fallback path and the mirror of the newest modeled value) plus the lazy
/// per-execution model state.
pub(crate) struct Core {
    real: StdAtomicU64,
    model: Mutex<Option<VarState>>,
}

impl Core {
    pub(crate) const fn new(v: u64) -> Self {
        Self { real: StdAtomicU64::new(v), model: Mutex::new(None) }
    }

    /// Newest value without scheduling (Debug formatting).
    pub(crate) fn peek(&self) -> u64 {
        self.real.load(Ordering::SeqCst)
    }

    fn var<'a>(slot: &'a mut Option<VarState>, exec_id: u64, cur: u64) -> &'a mut VarState {
        let stale = slot.as_ref().map(|s| s.exec_id) != Some(exec_id);
        if stale {
            *slot = Some(VarState::fresh(exec_id, cur));
        }
        slot.as_mut().expect("var state just ensured")
    }

    pub(crate) fn load(&self, order: Ordering) -> u64 {
        let Some((ex, tid)) = exec::current() else {
            return self.real.load(order);
        };
        if std::thread::panicking() {
            return self.real.load(Ordering::SeqCst);
        }
        exec::reschedule(&ex, tid, false);
        let mut g = ex.lock();
        let mut vg = self.model.lock().unwrap_or_else(|e| e.into_inner());
        let st = Self::var(&mut vg, g.id, self.real.load(Ordering::SeqCst));
        let n = st.stores.len();
        let hb_floor = {
            let my = g.clock_of(tid);
            (0..n).rev().find(|&i| st.stores[i].clock.le(my)).unwrap_or(0)
        };
        let lo = hb_floor.max(st.floor_of(tid)).max(n.saturating_sub(MAX_HIST));
        let choice = g.choose(n - lo); // choice 0 = the newest store
        let idx = n - 1 - choice;
        // Bounded staleness: each stale store is observable once per
        // thread, so spinning readers always progress toward the newest
        // value and the decision tree stays finite.
        st.note_seen(tid, if idx + 1 < n { idx + 1 } else { idx });
        if acquires(order) {
            let sync = st.stores[idx].sync.clone();
            g.clock_of_mut(tid).join(&sync);
        }
        st.stores[idx].val
    }

    pub(crate) fn store(&self, val: u64, order: Ordering) {
        let Some((ex, tid)) = exec::current() else {
            self.real.store(val, order);
            return;
        };
        if std::thread::panicking() {
            self.real.store(val, Ordering::SeqCst);
            return;
        }
        exec::reschedule(&ex, tid, false);
        let mut g = ex.lock();
        let mut vg = self.model.lock().unwrap_or_else(|e| e.into_inner());
        let st = Self::var(&mut vg, g.id, self.real.load(Ordering::SeqCst));
        let clock = g.clock_of(tid).clone();
        // A plain store starts a fresh release sequence (or none at all).
        let sync = if releases(order) { clock.clone() } else { VClock::new() };
        st.stores.push(StoreEv { val, clock, sync });
        let newest = st.stores.len() - 1;
        st.note_seen(tid, newest);
        self.real.store(val, Ordering::SeqCst);
    }

    pub(crate) fn rmw(&self, order: Ordering, f: impl Fn(u64) -> u64) -> u64 {
        let Some((ex, tid)) = exec::current() else {
            // Fallback: a CAS loop is observationally identical to the
            // native read-modify-write for these pure operator closures.
            let mut cur = self.real.load(Ordering::Relaxed);
            loop {
                match self.real.compare_exchange_weak(cur, f(cur), order, Ordering::Relaxed) {
                    Ok(prev) => return prev,
                    Err(actual) => cur = actual,
                }
            }
        };
        if std::thread::panicking() {
            let mut cur = self.real.load(Ordering::SeqCst);
            loop {
                match self.real.compare_exchange_weak(
                    cur,
                    f(cur),
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                ) {
                    Ok(prev) => return prev,
                    Err(actual) => cur = actual,
                }
            }
        }
        exec::reschedule(&ex, tid, false);
        let mut g = ex.lock();
        let mut vg = self.model.lock().unwrap_or_else(|e| e.into_inner());
        let st = Self::var(&mut vg, g.id, self.real.load(Ordering::SeqCst));
        let n = st.stores.len();
        let old = st.stores[n - 1].val; // RMWs are atomic: newest, always
        let prev_sync = st.stores[n - 1].sync.clone();
        if acquires(order) {
            g.clock_of_mut(tid).join(&prev_sync);
        }
        let newv = f(old);
        let clock = g.clock_of(tid).clone();
        // An RMW extends the release sequence it read from.
        let mut sync = prev_sync;
        if releases(order) {
            sync.join(&clock);
        }
        st.stores.push(StoreEv { val: newv, clock, sync });
        st.note_seen(tid, n);
        self.real.store(newv, Ordering::SeqCst);
        old
    }

    pub(crate) fn cas(
        &self,
        current: u64,
        new: u64,
        success: Ordering,
        failure: Ordering,
    ) -> Result<u64, u64> {
        let Some((ex, tid)) = exec::current() else {
            return self.real.compare_exchange(current, new, success, failure);
        };
        if std::thread::panicking() {
            return self.real.compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst);
        }
        exec::reschedule(&ex, tid, false);
        let mut g = ex.lock();
        let mut vg = self.model.lock().unwrap_or_else(|e| e.into_inner());
        let st = Self::var(&mut vg, g.id, self.real.load(Ordering::SeqCst));
        let n = st.stores.len();
        let old = st.stores[n - 1].val;
        if old == current {
            let prev_sync = st.stores[n - 1].sync.clone();
            if acquires(success) {
                g.clock_of_mut(tid).join(&prev_sync);
            }
            let clock = g.clock_of(tid).clone();
            let mut sync = prev_sync;
            if releases(success) {
                sync.join(&clock);
            }
            st.stores.push(StoreEv { val: new, clock, sync });
            st.note_seen(tid, n);
            self.real.store(new, Ordering::SeqCst);
            Ok(old)
        } else {
            // A failed CAS is a load of the newest value.
            if acquires(failure) {
                let sync = st.stores[n - 1].sync.clone();
                g.clock_of_mut(tid).join(&sync);
            }
            st.note_seen(tid, n - 1);
            Err(old)
        }
    }
}

macro_rules! int_atomic {
    ($name:ident, $ty:ty) => {
        #[doc = concat!(
            "Model-checked drop-in for `std::sync::atomic::",
            stringify!($name),
            "`: schedule point + modification-order semantics inside \
             [`crate::check`], the real atomic outside."
        )]
        pub struct $name(Core);

        impl $name {
            /// A new atomic holding `v`.
            pub const fn new(v: $ty) -> Self {
                Self(Core::new(v as u64))
            }

            /// Atomic load with `order` (stale observations possible for
            /// non-acquire loads inside the model).
            pub fn load(&self, order: Ordering) -> $ty {
                self.0.load(order) as $ty
            }

            /// Atomic store with `order`.
            pub fn store(&self, val: $ty, order: Ordering) {
                self.0.store(val as u64, order)
            }

            /// Atomic exchange; returns the previous value.
            pub fn swap(&self, val: $ty, order: Ordering) -> $ty {
                self.0.rmw(order, |_| val as u64) as $ty
            }

            /// Strong compare-and-swap; `Ok`/`Err` carry the previous value.
            pub fn compare_exchange(
                &self,
                current: $ty,
                new: $ty,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$ty, $ty> {
                self.0
                    .cas(current as u64, new as u64, success, failure)
                    .map(|v| v as $ty)
                    .map_err(|v| v as $ty)
            }

            /// Weak compare-and-swap (modeled without spurious failure).
            pub fn compare_exchange_weak(
                &self,
                current: $ty,
                new: $ty,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$ty, $ty> {
                self.compare_exchange(current, new, success, failure)
            }

            /// Atomic wrapping add; returns the previous value.
            pub fn fetch_add(&self, val: $ty, order: Ordering) -> $ty {
                self.0.rmw(order, |o| (o as $ty).wrapping_add(val) as u64) as $ty
            }

            /// Atomic wrapping subtract; returns the previous value.
            pub fn fetch_sub(&self, val: $ty, order: Ordering) -> $ty {
                self.0.rmw(order, |o| (o as $ty).wrapping_sub(val) as u64) as $ty
            }

            /// Atomic bitwise OR; returns the previous value.
            pub fn fetch_or(&self, val: $ty, order: Ordering) -> $ty {
                self.0.rmw(order, |o| ((o as $ty) | val) as u64) as $ty
            }

            /// Atomic bitwise AND; returns the previous value.
            pub fn fetch_and(&self, val: $ty, order: Ordering) -> $ty {
                self.0.rmw(order, |o| ((o as $ty) & val) as u64) as $ty
            }

            /// Atomic maximum; returns the previous value.
            pub fn fetch_max(&self, val: $ty, order: Ordering) -> $ty {
                self.0.rmw(order, |o| (o as $ty).max(val) as u64) as $ty
            }

            /// Atomic minimum; returns the previous value.
            pub fn fetch_min(&self, val: $ty, order: Ordering) -> $ty {
                self.0.rmw(order, |o| (o as $ty).min(val) as u64) as $ty
            }
        }

        impl Default for $name {
            fn default() -> Self {
                Self::new(0)
            }
        }

        impl std::fmt::Debug for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, concat!(stringify!($name), "({})"), self.0.peek() as $ty)
            }
        }
    };
}

int_atomic!(AtomicU8, u8);
int_atomic!(AtomicU32, u32);
int_atomic!(AtomicU64, u64);
int_atomic!(AtomicUsize, usize);

/// Model-checked drop-in for `std::sync::atomic::AtomicBool`.
pub struct AtomicBool(Core);

impl AtomicBool {
    /// A new atomic holding `v`.
    pub const fn new(v: bool) -> Self {
        Self(Core::new(v as u64))
    }

    /// Atomic load with `order`.
    pub fn load(&self, order: Ordering) -> bool {
        self.0.load(order) != 0
    }

    /// Atomic store with `order`.
    pub fn store(&self, val: bool, order: Ordering) {
        self.0.store(val as u64, order)
    }

    /// Atomic exchange; returns the previous value.
    pub fn swap(&self, val: bool, order: Ordering) -> bool {
        self.0.rmw(order, |_| val as u64) != 0
    }

    /// Strong compare-and-swap; `Ok`/`Err` carry the previous value.
    pub fn compare_exchange(
        &self,
        current: bool,
        new: bool,
        success: Ordering,
        failure: Ordering,
    ) -> Result<bool, bool> {
        self.0
            .cas(current as u64, new as u64, success, failure)
            .map(|v| v != 0)
            .map_err(|v| v != 0)
    }

    /// Weak compare-and-swap (modeled without spurious failure).
    pub fn compare_exchange_weak(
        &self,
        current: bool,
        new: bool,
        success: Ordering,
        failure: Ordering,
    ) -> Result<bool, bool> {
        self.compare_exchange(current, new, success, failure)
    }

    /// Atomic logical OR; returns the previous value.
    pub fn fetch_or(&self, val: bool, order: Ordering) -> bool {
        self.0.rmw(order, |o| o | (val as u64)) != 0
    }

    /// Atomic logical AND; returns the previous value.
    pub fn fetch_and(&self, val: bool, order: Ordering) -> bool {
        self.0.rmw(order, |o| if val { o } else { 0 }) != 0
    }
}

impl Default for AtomicBool {
    fn default() -> Self {
        Self::new(false)
    }
}

impl std::fmt::Debug for AtomicBool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AtomicBool({})", self.0.peek() != 0)
    }
}
