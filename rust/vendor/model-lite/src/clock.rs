//! Vector clocks — the happens-before substrate of the checker.
//!
//! Every model thread carries a [`VClock`]; every schedule point bumps the
//! thread's own component. Synchronizing operations (release stores read by
//! acquire loads, spawn, join) join clocks, so `a.le(&b)` is exactly
//! "everything thread A had done at snapshot `a` is visible at snapshot
//! `b`" — the happens-before partial order of the execution.

/// A vector clock over model-thread ids. Missing components read as zero,
/// so clocks of different lengths compare correctly.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub(crate) struct VClock(Vec<u64>);

impl VClock {
    /// The zero clock (happens-before everything).
    pub(crate) fn new() -> Self {
        Self(Vec::new())
    }

    /// Advance this thread's own component by one event.
    pub(crate) fn bump(&mut self, tid: usize) {
        if self.0.len() <= tid {
            self.0.resize(tid + 1, 0);
        }
        self.0[tid] += 1;
    }

    /// Pointwise maximum: after `self.join(o)`, everything ordered before
    /// either input is ordered before `self`.
    pub(crate) fn join(&mut self, o: &VClock) {
        if self.0.len() < o.0.len() {
            self.0.resize(o.0.len(), 0);
        }
        for (a, b) in self.0.iter_mut().zip(o.0.iter()) {
            if *b > *a {
                *a = *b;
            }
        }
    }

    /// Pointwise `<=`: does everything up to `self` happen before `o`?
    pub(crate) fn le(&self, o: &VClock) -> bool {
        self.0
            .iter()
            .enumerate()
            .all(|(i, &v)| v == 0 || o.0.get(i).copied().unwrap_or(0) >= v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_clock_precedes_everything() {
        let z = VClock::new();
        let mut c = VClock::new();
        c.bump(3);
        assert!(z.le(&c));
        assert!(z.le(&z));
        assert!(!c.le(&z));
    }

    #[test]
    fn join_is_pointwise_max() {
        let mut a = VClock::new();
        a.bump(0);
        a.bump(0);
        let mut b = VClock::new();
        b.bump(1);
        let mut j = a.clone();
        j.join(&b);
        assert!(a.le(&j));
        assert!(b.le(&j));
        assert!(!j.le(&a));
        assert!(!j.le(&b));
    }

    #[test]
    fn concurrent_clocks_are_unordered() {
        let mut a = VClock::new();
        a.bump(0);
        let mut b = VClock::new();
        b.bump(1);
        assert!(!a.le(&b));
        assert!(!b.le(&a));
    }
}
