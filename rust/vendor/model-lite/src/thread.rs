//! Model-checked drop-ins for `std::thread::spawn` / `yield_now` /
//! `JoinHandle`.
//!
//! Inside a [`crate::check`] execution, `spawn` registers a model thread
//! (its clock seeded from the parent: the spawn happens-before edge) backed
//! by a real OS thread that only ever runs while holding the execution
//! token, and `join` blocks the caller in the model scheduler and joins the
//! child's final clock (the join edge). Outside an execution both are thin
//! wrappers over `std::thread`.

use crate::exec;
use std::sync::Arc;

/// Handle returned by [`spawn`]; join semantics match `std::thread`.
pub struct JoinHandle<T>(Repr<T>);

enum Repr<T> {
    Std(std::thread::JoinHandle<T>),
    Model { real: std::thread::JoinHandle<Option<T>>, child: usize, ex: Arc<exec::Execution> },
}

impl<T> JoinHandle<T> {
    /// Wait for the thread to finish and return its result, panicking if
    /// the thread panicked (mirroring the common `handle.join().unwrap()`
    /// test idiom; the model checker has already recorded the real payload
    /// as the execution's failure).
    pub fn join(self) -> std::thread::Result<T> {
        match self.0 {
            Repr::Std(h) => h.join(),
            Repr::Model { real, child, ex } => {
                let (_, tid) = exec::current().expect(
                    "model-lite: JoinHandle::join called outside the model \
                     execution that spawned the thread",
                );
                exec::join_thread(&ex, tid, child);
                match real.join() {
                    Ok(Some(v)) => Ok(v),
                    // The child panicked (or unwound out of an aborted
                    // execution); surface it as a join error exactly like a
                    // real panicked thread.
                    Ok(None) => Err(Box::new(
                        "model thread panicked; see the recorded counterexample".to_string(),
                    )),
                    Err(p) => Err(p),
                }
            }
        }
    }
}

/// Spawn a thread. Model-scheduled inside [`crate::check`], a real
/// `std::thread::spawn` outside.
pub fn spawn<T: Send + 'static>(f: impl FnOnce() -> T + Send + 'static) -> JoinHandle<T> {
    match exec::current() {
        None => JoinHandle(Repr::Std(std::thread::spawn(f))),
        Some((ex, tid)) => {
            // The spawn itself is a visible event: give the scheduler a
            // chance to interleave before the child exists.
            exec::reschedule(&ex, tid, false);
            let child = exec::register_thread(&ex, tid);
            let ex2 = Arc::clone(&ex);
            let real = std::thread::Builder::new()
                .name(format!("model-{child}"))
                .spawn(move || exec::run_thread(ex2, child, f))
                .expect("spawn model thread");
            JoinHandle(Repr::Model { real, child, ex })
        }
    }
}

/// Cooperatively yield. In the model this deprioritizes the caller until no
/// other thread can run — the deterministic analogue of spin-loop backoff —
/// and the forced switch costs no preemption budget.
pub fn yield_now() {
    match exec::current() {
        None => std::thread::yield_now(),
        Some((ex, tid)) => exec::reschedule(&ex, tid, true),
    }
}
