//! The deterministic executor: serialized threads, a replayable decision
//! log, and DFS exploration with bounded preemptions.
//!
//! Exactly one model thread runs at a time; every visible operation (atomic
//! access, yield, spawn, join) is a *schedule point* where the executor may
//! hand the single execution token to another thread. Which thread (and,
//! for relaxed loads, which store a load observes) is a *decision*: the
//! first execution takes the default at every decision (run-on without
//! preempting, read the newest store), the decision log is recorded, and
//! the driver then backtracks depth-first — re-running the closure with the
//! longest prefix of decisions replayed and the last branchable decision
//! advanced — until the tree is exhausted or a panic surfaces. Replays are
//! exact because user closures are deterministic given the decisions, so
//! two `check` calls over the same closure explore identical schedule
//! counts.
//!
//! Preemption bounding: switching away from a thread that could have kept
//! running costs one unit of the [`Options::preemption_bound`] budget;
//! switches forced by a yield, a block, or an exit are free. Bounded search
//! is the standard Musuvathi–Qadeer result: almost all real concurrency
//! bugs manifest within two preemptions, while the full tree is
//! astronomically larger.

use crate::clock::VClock;
use std::any::Any;
use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Panic payload used to unwind model threads out of an aborted execution;
/// swallowed at each model thread's root, never reported as a failure.
pub(crate) struct Abort;

/// Tuning knobs for [`check_with`](crate::check_with).
#[derive(Clone, Copy, Debug)]
pub struct Options {
    /// How many times the scheduler may switch away from a runnable thread
    /// per execution. Two preemptions reach the overwhelming majority of
    /// real interleaving bugs at a tiny fraction of the full tree.
    pub preemption_bound: usize,
    /// Schedule points allowed in one execution before the run is declared
    /// a livelock and failed.
    pub max_steps: usize,
    /// Executions allowed before the exploration is declared too large and
    /// failed (a guard against unbounded trees, not a sampling knob —
    /// hitting it means the test must shrink, because coverage below the
    /// bound is not exhaustive).
    pub max_executions: usize,
    /// Maximum live model threads per execution.
    pub max_threads: usize,
}

impl Default for Options {
    fn default() -> Self {
        Self { preemption_bound: 2, max_steps: 10_000, max_executions: 200_000, max_threads: 8 }
    }
}

/// What an exhausted exploration did, returned by [`check`](crate::check).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Report {
    /// Number of distinct interleavings executed to completion.
    pub executions: usize,
    /// Total decisions taken across all executions (tree size telemetry).
    pub decisions: usize,
}

/// One recorded choice: which of `options` branches this execution took.
#[derive(Clone, Copy, Debug)]
struct Decision {
    chosen: u32,
    options: u32,
}

#[derive(Clone, Debug, PartialEq, Eq)]
enum Status {
    Runnable,
    /// Deprioritized until no other thread can run (spin-loop fairness).
    Yielded,
    /// Waiting for the thread with this id to finish.
    Blocked(usize),
    Finished,
}

struct ThreadState {
    status: Status,
    clock: VClock,
    /// Clock at exit, joined into whoever joins this thread.
    final_clock: Option<VClock>,
}

pub(crate) struct Inner {
    /// Distinguishes executions so per-atomic model state from a previous
    /// run is discarded lazily.
    pub(crate) id: u64,
    threads: Vec<ThreadState>,
    /// The thread currently holding the execution token.
    cur: usize,
    /// Decisions replayed from the previous execution's advanced prefix.
    replay: Vec<usize>,
    /// Decisions taken so far in this execution (replayed ones included).
    log: Vec<Decision>,
    preemptions: usize,
    steps: usize,
    live: usize,
    aborted: bool,
    failure: Option<Box<dyn Any + Send>>,
    opts: Options,
}

pub(crate) struct Execution {
    mx: Mutex<Inner>,
    cv: Condvar,
}

static EXEC_ID: AtomicU64 = AtomicU64::new(1);

impl Execution {
    fn new(opts: Options, replay: Vec<usize>) -> Self {
        let mut clock = VClock::new();
        clock.bump(0);
        Self {
            mx: Mutex::new(Inner {
                id: EXEC_ID.fetch_add(1, Ordering::Relaxed),
                threads: vec![ThreadState { status: Status::Runnable, clock, final_clock: None }],
                cur: 0,
                replay,
                log: Vec::new(),
                preemptions: 0,
                steps: 0,
                live: 1,
                aborted: false,
                failure: None,
                opts,
            }),
            cv: Condvar::new(),
        }
    }

    pub(crate) fn lock(&self) -> MutexGuard<'_, Inner> {
        // A model thread that panicked (legitimately: that is how the
        // checker reports counterexamples) poisons this mutex; the state is
        // still consistent because every mutation completes under the lock.
        self.mx.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl Inner {
    /// Take the next decision: replayed if still on the recorded prefix,
    /// default (0) otherwise. Single-option points are not decisions.
    fn decide(&mut self, options: usize) -> usize {
        if options <= 1 {
            return 0;
        }
        let idx = self.log.len();
        let chosen = if idx < self.replay.len() { self.replay[idx] } else { 0 };
        if chosen >= options {
            self.fail(format!(
                "model-lite internal error: nondeterministic replay \
                 (decision {idx} chose {chosen} of {options} options) — \
                 the checked closure must be deterministic apart from \
                 scheduling"
            ));
            return 0;
        }
        self.log.push(Decision { chosen: chosen as u32, options: options as u32 });
        chosen
    }

    /// Record a failure (first one wins) and put the execution into abort
    /// mode so every thread unwinds at its next schedule point.
    fn fail(&mut self, msg: String) {
        if self.failure.is_none() {
            self.failure = Some(Box::new(msg));
        }
        self.aborted = true;
    }

    /// Runnable threads, current thread first (when eligible) so that
    /// decision 0 is always "keep running".
    fn runnable(&self, tid: usize, include_self: bool) -> Vec<usize> {
        let mut c = Vec::new();
        if include_self && self.threads[tid].status == Status::Runnable {
            c.push(tid);
        }
        for (i, t) in self.threads.iter().enumerate() {
            if i != tid && t.status == Status::Runnable {
                c.push(i);
            }
        }
        c
    }

    /// Pick the next holder of the execution token. Returns `None` when the
    /// execution cannot continue (deadlock was recorded as the failure).
    ///
    /// `free_switch` means the caller cannot or should not keep the token —
    /// it yielded, blocked, or finished — so switching costs no preemption
    /// budget. Otherwise the caller is candidate 0 and switching away from
    /// it spends one preemption.
    fn pick_next(&mut self, tid: usize, free_switch: bool) -> Option<usize> {
        let mut cands = self.runnable(tid, !free_switch);
        if cands.is_empty() {
            // Everyone left has yielded: promote the spinners (including a
            // caller that just yielded — with nobody else to run, it must
            // continue), else a spin loop would look like deadlock.
            let mut promoted = false;
            for t in self.threads.iter_mut() {
                if t.status == Status::Yielded {
                    t.status = Status::Runnable;
                    promoted = true;
                }
            }
            if promoted {
                cands = self.runnable(tid, true);
            }
        }
        if cands.is_empty() {
            if self.live > 0 {
                let blocked: Vec<usize> = self
                    .threads
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| matches!(t.status, Status::Blocked(_)))
                    .map(|(i, _)| i)
                    .collect();
                self.fail(format!(
                    "model-lite: deadlock — every live thread is blocked \
                     (blocked threads: {blocked:?})"
                ));
            }
            return None;
        }
        let preemptible = !free_switch && cands[0] == tid && cands.len() > 1;
        let options = if preemptible && self.preemptions >= self.opts.preemption_bound {
            1 // budget spent: the current thread is forced to continue
        } else {
            cands.len()
        };
        let choice = self.decide(options);
        if preemptible && choice != 0 {
            self.preemptions += 1;
        }
        Some(cands[choice])
    }
}

// ---------------------------------------------------------------------------
// Thread-local execution context
// ---------------------------------------------------------------------------

struct Ctx {
    ex: Arc<Execution>,
    tid: usize,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = RefCell::new(None);
}

/// The executing model context of the calling thread, if any. `None` means
/// the caller runs outside `check` and shim types fall back to real
/// `std::sync::atomic` behaviour.
pub(crate) fn current() -> Option<(Arc<Execution>, usize)> {
    CTX.with(|c| c.borrow().as_ref().map(|x| (Arc::clone(&x.ex), x.tid)))
}

fn set_ctx(ex: Arc<Execution>, tid: usize) {
    CTX.with(|c| *c.borrow_mut() = Some(Ctx { ex, tid }));
}

fn clear_ctx() {
    CTX.with(|c| *c.borrow_mut() = None);
}

/// Snapshot of the calling model thread's vector clock (empty outside a
/// model execution). See [`crate::hb`].
pub(crate) fn clock_snapshot() -> VClock {
    match current() {
        None => VClock::new(),
        Some((ex, tid)) => {
            let g = ex.lock();
            g.threads[tid].clock.clone()
        }
    }
}

// ---------------------------------------------------------------------------
// Schedule points
// ---------------------------------------------------------------------------

/// A schedule point for thread `tid`: possibly hand the token to another
/// thread, blocking until it comes back. `yielding` deprioritizes the
/// caller (spin-loop backoff); the switch away is then free of preemption
/// cost.
///
/// Never schedules while the calling thread is unwinding: drop guards that
/// touch atomics during a panic must run to completion, and a nested panic
/// would abort the process.
pub(crate) fn reschedule(ex: &Arc<Execution>, tid: usize, yielding: bool) {
    if std::thread::panicking() {
        return;
    }
    let mut g = ex.lock();
    if g.aborted {
        drop(g);
        std::panic::panic_any(Abort);
    }
    g.steps += 1;
    if g.steps > g.opts.max_steps {
        let max = g.opts.max_steps;
        g.fail(format!(
            "model-lite: execution exceeded {max} schedule points — \
             likely a livelock (a spin loop that cannot observe progress), \
             or raise Options::max_steps"
        ));
        ex.cv.notify_all();
        drop(g);
        std::panic::panic_any(Abort);
    }
    g.threads[tid].clock.bump(tid);
    if yielding {
        g.threads[tid].status = Status::Yielded;
    }
    let next = match g.pick_next(tid, yielding) {
        Some(n) => n,
        None => {
            // Deadlock was recorded; unwind this thread too.
            ex.cv.notify_all();
            drop(g);
            std::panic::panic_any(Abort);
        }
    };
    g.cur = next;
    if next != tid {
        ex.cv.notify_all();
        while g.cur != tid && !g.aborted {
            g = ex.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
        if g.aborted {
            drop(g);
            std::panic::panic_any(Abort);
        }
    }
    g.threads[tid].status = Status::Runnable;
}

/// Register a freshly spawned model thread; returns its tid. The child's
/// clock starts at the parent's (the spawn edge of happens-before).
pub(crate) fn register_thread(ex: &Arc<Execution>, parent: usize) -> usize {
    let mut g = ex.lock();
    if g.threads.len() >= g.opts.max_threads {
        let max = g.opts.max_threads;
        g.fail(format!("model-lite: more than {max} model threads (Options::max_threads)"));
        ex.cv.notify_all();
        drop(g);
        std::panic::panic_any(Abort);
    }
    let child = g.threads.len();
    let mut clock = g.threads[parent].clock.clone();
    clock.bump(child);
    g.threads.push(ThreadState { status: Status::Runnable, clock, final_clock: None });
    g.live += 1;
    child
}

/// Body run by every model thread's real OS thread: wait for the first
/// turn, run the closure under `catch_unwind`, then retire.
pub(crate) fn run_thread<T>(
    ex: Arc<Execution>,
    tid: usize,
    f: impl FnOnce() -> T,
) -> Option<T> {
    set_ctx(Arc::clone(&ex), tid);
    {
        let mut g = ex.lock();
        while g.cur != tid && !g.aborted {
            g = ex.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
        if g.aborted {
            drop(g);
            finish_thread(&ex, tid, None);
            clear_ctx();
            return None;
        }
    }
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(v) => {
            finish_thread(&ex, tid, None);
            clear_ctx();
            Some(v)
        }
        Err(payload) => {
            let benign = payload.is::<Abort>();
            finish_thread(&ex, tid, if benign { None } else { Some(payload) });
            clear_ctx();
            None
        }
    }
}

/// Retire thread `tid`: record its final clock, wake joiners, report its
/// panic (if any) as the execution's failure, and pass the token on.
fn finish_thread(ex: &Arc<Execution>, tid: usize, panic_payload: Option<Box<dyn Any + Send>>) {
    let mut g = ex.lock();
    g.threads[tid].status = Status::Finished;
    let final_clock = g.threads[tid].clock.clone();
    g.threads[tid].final_clock = Some(final_clock);
    g.live -= 1;
    for t in g.threads.iter_mut() {
        if t.status == Status::Blocked(tid) {
            t.status = Status::Runnable;
        }
    }
    if let Some(p) = panic_payload {
        if g.failure.is_none() {
            g.failure = Some(p);
        }
        g.aborted = true;
    }
    if g.live > 0 && !g.aborted {
        if let Some(next) = g.pick_next(tid, true) {
            g.cur = next;
        }
    }
    ex.cv.notify_all();
}

/// Block the calling thread until model thread `child` finishes, then join
/// the child's final clock into the caller (the join edge). Must run on a
/// model thread.
pub(crate) fn join_thread(ex: &Arc<Execution>, tid: usize, child: usize) {
    reschedule(ex, tid, false);
    let mut g = ex.lock();
    if g.threads[child].status != Status::Finished {
        g.threads[tid].status = Status::Blocked(child);
        let next = match g.pick_next(tid, true) {
            Some(n) => n,
            None => {
                ex.cv.notify_all();
                drop(g);
                std::panic::panic_any(Abort);
            }
        };
        g.cur = next;
        ex.cv.notify_all();
        while g.cur != tid && !g.aborted {
            g = ex.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
        if g.aborted {
            drop(g);
            std::panic::panic_any(Abort);
        }
        g.threads[tid].status = Status::Runnable;
    }
    let child_clock =
        g.threads[child].final_clock.clone().expect("finished thread has a final clock");
    g.threads[tid].clock.join(&child_clock);
}

// Accessors used by the atomics module (kept here so Inner's fields stay
// private to the executor).
impl Inner {
    pub(crate) fn clock_of(&self, tid: usize) -> &VClock {
        &self.threads[tid].clock
    }

    pub(crate) fn clock_of_mut(&mut self, tid: usize) -> &mut VClock {
        &mut self.threads[tid].clock
    }

    pub(crate) fn choose(&mut self, options: usize) -> usize {
        self.decide(options)
    }
}

// ---------------------------------------------------------------------------
// The check driver
// ---------------------------------------------------------------------------

/// Exhaustively explore `f` under every interleaving reachable within the
/// preemption bound, with default [`Options`]. Panics (forwarding the
/// original panic) on the first failing execution.
pub fn check(f: impl Fn() + Send + Sync + 'static) -> Report {
    check_with(Options::default(), f)
}

/// [`check`] with explicit [`Options`].
///
/// The closure runs once per explored interleaving and must be
/// deterministic apart from scheduling: no wall-clock branching, no
/// randomness not derived from the schedule. On a failing interleaving the
/// original panic is re-raised after an `eprintln` describing how deep the
/// exploration got.
pub fn check_with(opts: Options, f: impl Fn() + Send + Sync + 'static) -> Report {
    let f = Arc::new(f);
    let mut path: Vec<Decision> = Vec::new();
    let mut executions = 0usize;
    let mut decisions = 0usize;
    loop {
        executions += 1;
        if executions > opts.max_executions {
            panic!(
                "model-lite: exploration exceeded max_executions={} — the \
                 interleaving tree is larger than the test budget; shrink \
                 the closure or raise Options::max_executions",
                opts.max_executions
            );
        }
        let replay: Vec<usize> = path.iter().map(|d| d.chosen as usize).collect();
        let ex = Arc::new(Execution::new(opts, replay));
        let root = {
            let (fx, ex2) = (Arc::clone(&f), Arc::clone(&ex));
            std::thread::Builder::new()
                .name("model-0".into())
                .spawn(move || {
                    run_thread(ex2, 0, move || fx());
                })
                .expect("spawn model root thread")
        };
        {
            let mut g = ex.lock();
            while g.live > 0 {
                g = ex.cv.wait(g).unwrap_or_else(|e| e.into_inner());
            }
        }
        let _ = root.join();
        let mut g = ex.lock();
        if let Some(p) = g.failure.take() {
            eprintln!(
                "model-lite: counterexample on execution {executions} \
                 ({} decisions deep); replay is deterministic",
                g.log.len()
            );
            drop(g);
            std::panic::resume_unwind(p);
        }
        decisions += g.log.len();
        path = std::mem::take(&mut g.log);
        drop(g);
        // Depth-first advance: bump the deepest decision with an untaken
        // branch, drop everything below it.
        let mut advanced = false;
        while let Some(d) = path.last_mut() {
            if d.chosen + 1 < d.options {
                d.chosen += 1;
                advanced = true;
                break;
            }
            path.pop();
        }
        if !advanced {
            return Report { executions, decisions };
        }
    }
}
