//! Model-checked drop-in for `std::hint::spin_loop`.

/// Spin-loop hint. Inside a [`crate::check`] execution this is identical to
/// [`crate::thread::yield_now`]: a busy-wait iteration must be a yielding
/// schedule point, or the deterministic scheduler would re-run the spinner
/// forever instead of letting the writer it is waiting on make progress.
pub fn spin_loop() {
    match crate::exec::current() {
        None => std::hint::spin_loop(),
        Some((ex, tid)) => crate::exec::reschedule(&ex, tid, true),
    }
}
