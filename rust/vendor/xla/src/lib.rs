//! Offline stub of the PJRT/XLA bindings the runtime layer links against.
//!
//! The real crate wraps a native PJRT plugin; this build image has neither
//! the plugin nor registry access, so the stub keeps the whole Layer-3 code
//! path *compiling and testable*:
//!
//! * client creation ([`PjRtClient::cpu`]) and HLO-text loading succeed, so
//!   artifact discovery, bucket selection, and all error paths exercise for
//!   real;
//! * [`PjRtClient::compile`] / execution return a descriptive
//!   "runtime unavailable" error — exactly what a missing `make artifacts`
//!   host should report. With the genuine crate substituted in, nothing in
//!   the callers changes.

#![deny(unsafe_op_in_unsafe_fn)]

use std::fmt;
use std::path::Path;

/// Stub error type (implements `std::error::Error`, unlike the coordinator's
/// `anyhow::Error`, so `?` conversions work in the callers).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(op: &str) -> Error {
    Error(format!(
        "{op}: PJRT runtime is not linked in this build (offline `xla` stub); swap in the real xla crate to execute compiled artifacts"
    ))
}

/// PJRT client handle.
pub struct PjRtClient {
    platform: String,
}

impl PjRtClient {
    /// Create the CPU client (always succeeds in the stub).
    pub fn cpu() -> Result<Self> {
        Ok(Self { platform: "cpu".to_string() })
    }

    pub fn platform_name(&self) -> String {
        self.platform.clone()
    }

    /// Compile an HLO computation — unavailable in the stub.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Load HLO text from disk (real I/O, so missing-file errors are real).
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            Error(format!("reading HLO text {}: {e}", path.as_ref().display()))
        })?;
        Ok(Self { text })
    }
}

/// An XLA computation built from an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A compiled executable (never constructible through the stub client).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

/// A device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

/// A host literal.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_platform() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu");
    }

    #[test]
    fn compile_reports_unavailable() {
        let c = PjRtClient::cpu().unwrap();
        let err = c.compile(&XlaComputation).unwrap_err();
        assert!(err.to_string().contains("PJRT runtime"));
    }

    #[test]
    fn hlo_loading_reads_real_files() {
        let dir = std::env::temp_dir().join("xla_stub_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.hlo.txt");
        std::fs::write(&p, "HloModule m").unwrap();
        assert!(HloModuleProto::from_text_file(&p).is_ok());
        assert!(HloModuleProto::from_text_file(dir.join("missing.hlo.txt")).is_err());
    }
}
