//! Offline minimal read-only memory map.
//!
//! The build image has no crates.io registry, so the out-of-core graph
//! storage ([`pagerank_nb::graph`]'s mmap-backed CSR) vendors this tiny
//! wrapper instead of depending on `memmap2`. It supports exactly what the
//! project needs:
//!
//! * [`Mmap::map`] — map an open file read-only, private;
//! * [`Deref`] to `&[u8]` — the mapped bytes as a slice;
//! * automatic `munmap` on drop.
//!
//! On unix targets this calls `mmap`/`munmap` directly through `extern "C"`
//! declarations (the constants below match Linux and the BSD family for the
//! read-only private case). On non-unix targets — and for zero-length files,
//! which `mmap(2)` rejects with `EINVAL` — it falls back to reading the file
//! into the heap, so callers get the same `&[u8]` view everywhere; only the
//! paging behaviour differs.
//!
//! The kernel maps page-aligned memory, so a mapping's base address is
//! always at least 4 KiB-aligned — callers may rely on that when
//! reinterpreting section bytes at 64-byte-aligned offsets.

#![deny(unsafe_op_in_unsafe_fn)]

use std::fs::File;
use std::io;
use std::ops::Deref;

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;

    /// `PROT_READ` — pages may be read.
    pub const PROT_READ: i32 = 1;
    /// `MAP_PRIVATE` — copy-on-write private mapping (we never write).
    pub const MAP_PRIVATE: i32 = 2;
    /// `mmap(2)` error sentinel (`(void *) -1`).
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

    /// `MADV_WILLNEED` — expect access in the near future; start read-ahead.
    pub const MADV_WILLNEED: i32 = 3;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
        pub fn madvise(addr: *mut c_void, len: usize, advice: i32) -> i32;
        pub fn getpagesize() -> i32;
    }

    /// The kernel's page size, queried once (`getpagesize(2)`, which every
    /// unix we target exports — unlike the `_SC_PAGESIZE` constant, whose
    /// value differs per platform). Hardcoding 4096 would hand `madvise` a
    /// misaligned address on 16K/64K-page kernels (e.g. many aarch64
    /// hosts), turning the hint into a silent `EINVAL`. An absurd answer
    /// falls back to 4096: a wrong-but-sane alignment degrades the hint,
    /// never safety.
    pub fn page_size() -> usize {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static PAGE: AtomicUsize = AtomicUsize::new(0);
        let cached = PAGE.load(Ordering::Relaxed);
        if cached != 0 {
            return cached;
        }
        // SAFETY: getpagesize takes no arguments and only reads kernel
        // self-description; it cannot fail in a way that touches memory.
        let raw = unsafe { getpagesize() };
        let page = if raw > 0 && (raw as usize).is_power_of_two() {
            raw as usize
        } else {
            4096
        };
        PAGE.store(page, Ordering::Relaxed);
        page
    }
}

/// A read-only view of a file's bytes: a kernel memory map on unix, a heap
/// copy elsewhere. Deref's to `&[u8]`.
pub struct Mmap {
    inner: Inner,
}

enum Inner {
    /// A live `mmap(2)` mapping; unmapped on drop.
    #[cfg(unix)]
    Mapped { ptr: *const u8, len: usize },
    /// Heap-backed fallback (non-unix targets, zero-length files).
    Owned(Vec<u8>),
}

// SAFETY: the mapping is immutable (PROT_READ, MAP_PRIVATE) and the pointer
// refers to pages owned by this value for its whole lifetime, so shared
// access from any thread is a plain read of immutable memory.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `file` read-only. The returned view covers the file's length at
    /// call time; the caller must not truncate the file while the map is
    /// live (on unix that would turn reads past the new end into `SIGBUS`,
    /// exactly as with any mmap).
    pub fn map(file: &File) -> io::Result<Mmap> {
        let len = file.metadata()?.len();
        if len > usize::MAX as u64 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "file larger than the address space",
            ));
        }
        Self::map_len(file, len as usize)
    }

    #[cfg(unix)]
    fn map_len(file: &File, len: usize) -> io::Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            // mmap(2) rejects zero-length maps with EINVAL.
            return Ok(Mmap { inner: Inner::Owned(Vec::new()) });
        }
        // SAFETY: the fd is valid for the duration of the call; a PROT_READ
        // + MAP_PRIVATE mapping of `len` bytes at offset 0 has no aliasing
        // requirements on our side. The result is checked against
        // MAP_FAILED before use.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::MAP_FAILED {
            return Err(io::Error::last_os_error());
        }
        Ok(Mmap { inner: Inner::Mapped { ptr: ptr as *const u8, len } })
    }

    #[cfg(not(unix))]
    fn map_len(file: &File, len: usize) -> io::Result<Mmap> {
        use std::io::Read;
        let mut buf = Vec::with_capacity(len);
        let mut f = file;
        f.read_to_end(&mut buf)?;
        Ok(Mmap { inner: Inner::Owned(buf) })
    }

    /// The mapped bytes.
    pub fn as_slice(&self) -> &[u8] {
        match &self.inner {
            // SAFETY: `ptr` points at `len` mapped read-only bytes that stay
            // mapped until drop (see `Inner::Mapped`).
            #[cfg(unix)]
            Inner::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            Inner::Owned(v) => v,
        }
    }

    /// Length of the view in bytes.
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    /// True when the view is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hint the kernel that `offset..offset + len` will be read soon
    /// (`madvise(MADV_WILLNEED)`), so read-ahead can overlap with whatever
    /// the caller does in the meantime. Purely advisory: the range is
    /// clamped to the mapping, the address is aligned down to the page, a
    /// failing syscall is ignored, and heap-backed views (non-unix targets,
    /// zero-length files) are already resident — so this is a no-op
    /// everywhere it cannot help.
    ///
    /// Safe to call from any number of threads concurrently (including
    /// overlapping ranges, and concurrently with reads of the mapped
    /// bytes): it takes `&self`, touches no mutable state beyond the
    /// one-time page-size cache, and `madvise(2)` itself only updates
    /// kernel-side read-ahead bookkeeping — the parallel out-of-core
    /// coordinator issues these from K workers at once.
    pub fn advise_willneed(&self, offset: usize, len: usize) {
        #[cfg(unix)]
        if let Inner::Mapped { ptr, len: map_len } = self.inner {
            let page = sys::page_size();
            let start = offset.min(map_len);
            let end = offset.saturating_add(len).min(map_len);
            if start >= end {
                return;
            }
            // Align the start down to a page boundary — madvise(2) demands
            // a page-aligned address, and the mapping base itself is
            // page-aligned (see the module docs).
            let aligned = start - (start % page);
            // SAFETY: `ptr + aligned` and the clamped length lie inside
            // this live mapping; MADV_WILLNEED never mutates page contents.
            unsafe {
                sys::madvise(
                    ptr.add(aligned) as *mut std::ffi::c_void,
                    end - aligned,
                    sys::MADV_WILLNEED,
                );
            }
        }
        #[cfg(not(unix))]
        let _ = (offset, len);
    }
}

impl Deref for Mmap {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mmap").field("len", &self.len()).finish()
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Inner::Mapped { ptr, len } = self.inner {
            // SAFETY: `ptr`/`len` came from a successful mmap of exactly
            // this extent and are unmapped exactly once (drop).
            unsafe {
                sys::munmap(ptr as *mut std::ffi::c_void, len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmpfile(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("mmap_lite_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        let mut f = File::create(&p).unwrap();
        f.write_all(bytes).unwrap();
        p
    }

    #[test]
    fn maps_file_contents() {
        let payload: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let p = tmpfile("payload.bin", &payload);
        let m = Mmap::map(&File::open(&p).unwrap()).unwrap();
        assert_eq!(m.len(), payload.len());
        assert_eq!(&m[..], &payload[..]);
    }

    #[test]
    fn empty_file_maps_to_empty_slice() {
        let p = tmpfile("empty.bin", b"");
        let m = Mmap::map(&File::open(&p).unwrap()).unwrap();
        assert!(m.is_empty());
        assert_eq!(&m[..], b"");
    }

    #[cfg(unix)]
    #[test]
    fn map_is_page_aligned() {
        let p = tmpfile("aligned.bin", &[7u8; 4096]);
        let m = Mmap::map(&File::open(&p).unwrap()).unwrap();
        // A real kernel mapping is page-aligned, which is what lets callers
        // reinterpret 64-byte-aligned sections inside it.
        assert_eq!(m.as_slice().as_ptr() as usize % 4096, 0);
    }

    #[cfg(unix)]
    #[test]
    fn queried_page_size_is_sane() {
        let p = super::sys::page_size();
        // every supported kernel pages at a power-of-two ≥ 4 KiB, and the
        // fallback guarantees the same — alignment math relies on it
        assert!(p.is_power_of_two() && p >= 4096, "page size {p}");
        assert_eq!(p, super::sys::page_size(), "cached answer must be stable");
    }

    #[test]
    fn advise_willneed_is_safe_everywhere() {
        let p = tmpfile("advise.bin", &[9u8; 20_000]);
        let m = Mmap::map(&File::open(&p).unwrap()).unwrap();
        m.advise_willneed(0, m.len());
        m.advise_willneed(5_000, 1_000); // unaligned interior range
        m.advise_willneed(19_999, 50_000); // clamped past the end
        m.advise_willneed(usize::MAX, 1); // degenerate offset
        m.advise_willneed(100, 0); // empty range
        assert!(m.iter().all(|&b| b == 9), "advice must not disturb contents");
        let p = tmpfile("advise_empty.bin", b"");
        let empty = Mmap::map(&File::open(&p).unwrap()).unwrap();
        empty.advise_willneed(0, 10); // heap-backed fallback: no-op
    }

    #[test]
    fn concurrent_advise_from_many_threads_is_safe() {
        // The parallel out-of-core coordinator has K workers advising
        // overlapping windows while others read the same pages; the hint
        // must stay a hint — no crash, no content change, any interleaving.
        let p = tmpfile("advise_par.bin", &[5u8; 1 << 18]);
        let m = std::sync::Arc::new(Mmap::map(&File::open(&p).unwrap()).unwrap());
        std::thread::scope(|s| {
            for t in 0..8usize {
                let m = std::sync::Arc::clone(&m);
                s.spawn(move || {
                    let chunk = m.len() / 8;
                    for lap in 0..50 {
                        // rotate each thread's window so ranges overlap
                        let start = ((t + lap) % 8) * chunk;
                        m.advise_willneed(start, chunk * 2);
                        assert!(m[start..start + chunk].iter().all(|&b| b == 5));
                    }
                });
            }
        });
        assert!(m.iter().all(|&b| b == 5), "advice must never disturb contents");
    }

    #[test]
    fn shared_across_threads() {
        let p = tmpfile("shared.bin", &[42u8; 1 << 16]);
        let m = std::sync::Arc::new(Mmap::map(&File::open(&p).unwrap()).unwrap());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = std::sync::Arc::clone(&m);
                s.spawn(move || {
                    assert!(m.iter().all(|&b| b == 42));
                });
            }
        });
    }
}
