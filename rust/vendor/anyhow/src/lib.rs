//! Offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io registry, so the workspace vendors the
//! subset of `anyhow` this project actually uses:
//!
//! * [`Error`] — an error value carrying a context chain (outermost message
//!   first, root cause last);
//! * [`Result`] — `Result<T, Error>` with the usual default parameter;
//! * [`anyhow!`] / [`bail!`] / [`ensure!`] — formatted construction macros;
//! * [`Context`] — the extension trait adding `.context(..)` and
//!   `.with_context(..)` to `Result` and `Option`.
//!
//! Semantics match upstream where this project can observe them: `Display`
//! prints the outermost message, `{:#}` prints the whole chain joined by
//! `": "`, `Debug` prints the message plus a `Caused by:` list, and — as in
//! upstream — `Error` deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket `From` conversion
//! coherent.

#![deny(unsafe_op_in_unsafe_fn)]

use std::fmt;

/// An error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    fn wrap(mut self, context: String) -> Self {
        self.chain.insert(0, context);
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate the context chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to `Result` and `Option`.
pub trait Context<T>: Sized {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fail() -> Result<()> {
        bail!("root {}", 42)
    }

    #[test]
    fn display_shows_outermost_and_alternate_shows_chain() {
        let e = fail().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert_eq!(e.root_cause(), "root 42");
    }

    #[test]
    fn debug_lists_causes() {
        let e = fail().with_context(|| format!("step {}", 1)).unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("step 1"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root 42"));
    }

    #[test]
    fn std_errors_convert_via_question_mark() {
        fn parse() -> Result<i32> {
            let n: i32 = "not a number".parse()?;
            Ok(n)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(7).context("unused").unwrap(), 7);
    }

    #[test]
    fn ensure_macro() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(check(1).is_ok());
        assert!(check(-1).unwrap_err().to_string().contains("-1"));
    }
}
