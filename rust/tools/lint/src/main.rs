//! Concurrency-hygiene audit for the `pagerank_nb` tree — the Rust
//! implementation of the rules `scripts/audit-unsafe.sh` enforces (the
//! script falls back to an awk implementation of the same rules on hosts
//! without a toolchain; keep the two in sync, they are line-for-line the
//! same checks).
//!
//! Rules (documented in docs/concurrency.md §Static audit):
//!
//! 1. **`unsafe` without `// SAFETY:`** — every line of code containing the
//!    `unsafe` keyword (in `rust/src` and `rust/vendor/*/src`) must have a
//!    `// SAFETY:` comment on the same line or within the 8 lines above it.
//! 2. **Unjustified `Ordering::Relaxed`** — outside `rust/src/sync/` (where
//!    the primitives' module docs carry the ordering contracts), every
//!    `Ordering::Relaxed` needs a `// relaxed: <why>` comment on the same
//!    line or within the 3 lines above it.
//! 3. **Atomic-import funnel** — no file in `rust/src` other than
//!    `sync/shim.rs` may name `std::sync::atomic`: all atomics flow through
//!    the shim so the `pallas-model` feature can interpose the model
//!    checker on the whole crate at once.
//!
//! Exit status: 0 when clean, 1 with one diagnostic per offending line on
//! stderr otherwise. Line-based heuristics, deliberately: the goal is a
//! zero-dependency gate that fails loudly and is trivial to appease, not a
//! parser. Usage: `pagerank-lint [repo-root]` (default: cwd).

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Recursively collect `.rs` files under `dir`, sorted for stable output.
fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    let mut entries: Vec<_> = entries.flatten().map(|e| e.path()).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Is this a whole-line comment (`//`, `///`, `//!`)?
fn is_comment_line(line: &str) -> bool {
    line.trim_start().starts_with("//")
}

/// The line with any trailing `//` comment stripped.
fn code_part(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

/// Does `hay` contain `needle` as a whole word (no `[A-Za-z0-9_]` on
/// either side)?
fn has_word(hay: &str, needle: &str) -> bool {
    let isw = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    let bytes = hay.as_bytes();
    let mut from = 0;
    while let Some(i) = hay[from..].find(needle).map(|i| i + from) {
        let before_ok = i == 0 || !isw(bytes[i - 1]);
        let end = i + needle.len();
        let after_ok = end == bytes.len() || !isw(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        from = i + 1;
    }
    false
}

/// Any line in `lines[lo..=hi]` (saturating at 0) matching `pred`?
fn lookback(lines: &[&str], hi: usize, window: usize, pred: impl Fn(&str) -> bool) -> bool {
    let lo = hi.saturating_sub(window);
    lines[lo..=hi].iter().any(|l| pred(l))
}

struct Audit {
    root: PathBuf,
    violations: usize,
}

impl Audit {
    fn flag(&mut self, path: &Path, line_no: usize, msg: &str) {
        let rel = path.strip_prefix(&self.root).unwrap_or(path);
        eprintln!("{}:{}: {msg}", rel.display(), line_no);
        self.violations += 1;
    }

    /// Rule 1 over one file.
    fn check_unsafe(&mut self, path: &Path, lines: &[&str]) {
        for (i, line) in lines.iter().enumerate() {
            if is_comment_line(line) || !has_word(code_part(line), "unsafe") {
                continue;
            }
            // Lint-control attributes talk *about* unsafe, they are not it.
            if line.contains("unsafe_op_in_unsafe_fn")
                || line.contains("unsafe_code")
                || line.contains("forbid(unsafe")
            {
                continue;
            }
            if !lookback(lines, i, 8, |l| l.contains("SAFETY:")) {
                self.flag(path, i + 1, "`unsafe` without a `// SAFETY:` comment within 8 lines");
            }
        }
    }

    /// Rule 2 over one file.
    fn check_relaxed(&mut self, path: &Path, lines: &[&str]) {
        for (i, line) in lines.iter().enumerate() {
            if is_comment_line(line) || !code_part(line).contains("Ordering::Relaxed") {
                continue;
            }
            if !lookback(lines, i, 3, |l| l.contains("// relaxed:")) {
                self.flag(
                    path,
                    i + 1,
                    "`Ordering::Relaxed` outside sync/ without a `// relaxed: <why>` comment \
                     within 3 lines",
                );
            }
        }
    }

    /// Rule 3 over one file.
    fn check_atomic_funnel(&mut self, path: &Path, lines: &[&str]) {
        for (i, line) in lines.iter().enumerate() {
            if is_comment_line(line) || !code_part(line).contains("std::sync::atomic") {
                continue;
            }
            self.flag(
                path,
                i + 1,
                "direct `std::sync::atomic` use — route atomics through `crate::sync::shim` \
                 so the model checker can interpose them",
            );
        }
    }
}

fn main() -> ExitCode {
    let root = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("."));
    let src = root.join("rust/src");
    let vendor = root.join("rust/vendor");
    if !src.is_dir() {
        eprintln!("pagerank-lint: {} is not a repo root (no rust/src)", root.display());
        return ExitCode::FAILURE;
    }

    let mut audit = Audit { root: root.clone(), violations: 0 };
    let mut files = Vec::new();
    rs_files(&src, &mut files);
    let first_vendor = files.len();
    rs_files(&vendor, &mut files);

    for (idx, path) in files.iter().enumerate() {
        let Ok(text) = fs::read_to_string(path) else {
            eprintln!("pagerank-lint: unreadable file {}", path.display());
            audit.violations += 1;
            continue;
        };
        let lines: Vec<&str> = text.lines().collect();
        let in_vendor = idx >= first_vendor;
        audit.check_unsafe(path, &lines);
        if in_vendor {
            continue; // vendor crates: SAFETY hygiene only
        }
        let rel = path.strip_prefix(&src).unwrap_or(path);
        if !rel.starts_with("sync") {
            audit.check_relaxed(path, &lines);
        }
        if rel != Path::new("sync/shim.rs") {
            audit.check_atomic_funnel(path, &lines);
        }
    }

    if audit.violations == 0 {
        println!("pagerank-lint: clean ({} files)", files.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("pagerank-lint: {} violation(s)", audit.violations);
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_matching_respects_boundaries() {
        assert!(has_word("let x = unsafe { y };", "unsafe"));
        assert!(has_word("unsafe impl Send for T {}", "unsafe"));
        assert!(!has_word("make_unsafe_name()", "unsafe"));
        assert!(!has_word("unsafely()", "unsafe"));
    }

    #[test]
    fn comment_stripping() {
        assert_eq!(code_part("x(); // unsafe in prose"), "x(); ");
        assert!(!has_word(code_part("// just talking about unsafe"), "unsafe"));
        assert!(is_comment_line("   /// docs mention unsafe"));
        assert!(!is_comment_line("let a = 1; // trailing"));
    }

    #[test]
    fn lookback_window_is_inclusive_and_saturating() {
        let lines = ["// SAFETY: fine", "a", "b", "unsafe {"];
        assert!(lookback(&lines, 3, 8, |l| l.contains("SAFETY:")));
        assert!(!lookback(&lines, 3, 2, |l| l.contains("SAFETY:")));
        assert!(lookback(&lines, 0, 8, |l| l.contains("SAFETY:")));
    }
}
