//! NUMA topology detection and worker placement.
//!
//! The paper's machine-scale numbers (56-core dual-socket Xeon) depend on
//! workers not bouncing across sockets mid-run. This module detects the
//! node layout from `/sys/devices/system/node/node*/cpulist` (falling back
//! to a single synthetic node when the tree is absent, unreadable, or the
//! host is not Linux), and turns a [`Placement`] policy into a per-worker
//! CPU mask applied via the vendored `affinity-lite` `sched_setaffinity`
//! shim at the top of each worker closure
//! ([`crate::engine::driver::execute`]).
//!
//! Placement interacts with partitioning: worker tids map to contiguous
//! vertex ranges ([`crate::graph::Partitions`]), so `Placement::Pin`'s
//! node-contiguous worker blocks make each node own a contiguous vertex
//! range — its rank/`last_pushed`/value-stream pages are first-touched from
//! an on-node worker before iteration 0 ([`crate::engine::Kernel::first_touch`]),
//! and each per-partition `CompressedBins` stream is produced and consumed
//! node-locally, so cross-socket traffic degenerates to one compacted
//! stream per (node, partition) pair.

use anyhow::{bail, Result};
use std::path::Path;

/// Worker-placement policy (CLI: `--numa off|pin|interleave`).
///
/// Placement is a pure scheduling hint: pinned and unpinned runs execute
/// the same kernel schedule, so results stay within the usual equivalence
/// bounds (bit-identical for deterministic schedules).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// No pinning — threads float wherever the OS scheduler puts them.
    Off,
    /// Node-contiguous blocks: with `k` nodes and `p` workers, worker `t`
    /// is bound to node `t·k/p` — contiguous tids (and therefore contiguous
    /// partition vertex ranges) share a node.
    Pin,
    /// Round-robin: worker `t` is bound to node `t mod k`, spreading memory
    /// bandwidth demand evenly across controllers.
    Interleave,
}

impl std::fmt::Display for Placement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Placement::Off => f.write_str("off"),
            Placement::Pin => f.write_str("pin"),
            Placement::Interleave => f.write_str("interleave"),
        }
    }
}

impl Placement {
    /// Parse a `--numa` value.
    pub fn parse(s: &str) -> Result<Placement> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "none" => Ok(Placement::Off),
            "pin" | "bind" | "local" => Ok(Placement::Pin),
            "interleave" | "spread" => Ok(Placement::Interleave),
            other => bail!("--numa must be off|pin|interleave, got '{other}'"),
        }
    }
}

/// One NUMA node as detected from sysfs (or the single-node fallback).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NumaNode {
    /// Kernel node id (`/sys/devices/system/node/node<id>`).
    pub id: usize,
    /// The CPUs this node owns, ascending and deduplicated.
    pub cpus: Vec<usize>,
}

/// The machine topology a placement plan is derived from. Never empty:
/// detection that finds nothing yields the single-node fallback.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    /// Detected nodes with at least one CPU each, sorted by node id.
    pub nodes: Vec<NumaNode>,
}

/// Largest CPU id the cpulist parser accepts — guards a corrupt sysfs
/// entry from driving a huge allocation.
const MAX_CPU_ID: usize = 1 << 20;

/// Parse a kernel cpulist string (`"0-3,8-11"`, `"0"`, `"0,2-4,7"`; an
/// empty or whitespace-only string is an empty list, as sysfs reports for
/// memory-only nodes). Returns ascending, deduplicated CPU ids.
pub fn parse_cpulist(s: &str) -> Result<Vec<usize>> {
    let trimmed = s.trim();
    let mut cpus = Vec::new();
    if trimmed.is_empty() {
        return Ok(cpus);
    }
    for part in trimmed.split(',') {
        let part = part.trim();
        match part.split_once('-') {
            Some((a, b)) => {
                let (Ok(lo), Ok(hi)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>())
                else {
                    bail!("bad cpu range '{part}' in cpulist '{trimmed}'");
                };
                if lo > hi {
                    bail!("descending cpu range '{part}' in cpulist '{trimmed}'");
                }
                if hi >= MAX_CPU_ID {
                    bail!("cpu id {hi} out of range in cpulist '{trimmed}'");
                }
                cpus.extend(lo..=hi);
            }
            None => {
                let Ok(id) = part.parse::<usize>() else {
                    bail!("bad cpu id '{part}' in cpulist '{trimmed}'");
                };
                if id >= MAX_CPU_ID {
                    bail!("cpu id {id} out of range in cpulist '{trimmed}'");
                }
                cpus.push(id);
            }
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    Ok(cpus)
}

impl Topology {
    /// Read `node<k>/cpulist` entries under `root` (the layout of
    /// `/sys/devices/system/node`). Entries that are not `node<digits>`,
    /// have no readable `cpulist`, or own zero CPUs (memory-only nodes) are
    /// skipped. Returns `None` when nothing usable is found — the caller
    /// falls back to [`Topology::single_node`].
    pub fn from_sysfs(root: &Path) -> Option<Topology> {
        let entries = std::fs::read_dir(root).ok()?;
        let mut nodes = Vec::new();
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let Some(idx) = name.strip_prefix("node") else { continue };
            let Ok(id) = idx.parse::<usize>() else { continue };
            let Ok(raw) = std::fs::read_to_string(entry.path().join("cpulist")) else {
                continue;
            };
            let Ok(cpus) = parse_cpulist(&raw) else { continue };
            if !cpus.is_empty() {
                nodes.push(NumaNode { id, cpus });
            }
        }
        nodes.sort_by_key(|n| n.id);
        if nodes.is_empty() {
            None
        } else {
            Some(Topology { nodes })
        }
    }

    /// The graceful fallback: one node owning CPUs
    /// `0..available_parallelism` — non-NUMA hosts, non-Linux platforms,
    /// and containers that hide sysfs all land here, so every placement
    /// policy runs end-to-end anywhere.
    pub fn single_node() -> Topology {
        let n = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        Topology { nodes: vec![NumaNode { id: 0, cpus: (0..n).collect() }] }
    }

    /// Detect the host topology: the sysfs node tree when present and
    /// parseable, the single-node fallback otherwise. Never panics, never
    /// returns zero nodes.
    pub fn detect() -> Topology {
        Self::from_sysfs(Path::new("/sys/devices/system/node"))
            .unwrap_or_else(Self::single_node)
    }
}

/// A resolved placement: for each worker tid, the CPU set to pin to.
#[derive(Debug, Clone)]
pub struct Plan {
    cpus_per_worker: Vec<Vec<usize>>,
    nodes: usize,
}

impl Plan {
    /// Build the placement plan for `threads` workers on the detected host
    /// topology. `None` when `placement` is [`Placement::Off`] — the driver
    /// then skips pinning and first-touch entirely.
    pub fn new(placement: Placement, threads: usize) -> Option<Plan> {
        if placement == Placement::Off {
            return None;
        }
        Some(Self::from_topology(&Topology::detect(), placement, threads))
    }

    /// Deterministic plan construction from an explicit topology (unit
    /// tests drive this with canned fixtures).
    pub fn from_topology(topo: &Topology, placement: Placement, threads: usize) -> Plan {
        let k = topo.nodes.len().max(1);
        let cpus_per_worker = (0..threads)
            .map(|tid| {
                let node = match placement {
                    Placement::Off => return Vec::new(),
                    Placement::Pin => tid * k / threads.max(1),
                    Placement::Interleave => tid % k,
                };
                topo.nodes[node].cpus.clone()
            })
            .collect();
        Plan { cpus_per_worker, nodes: k }
    }

    /// CPU set worker `tid` is bound to (empty = unconstrained).
    pub fn cpus(&self, tid: usize) -> &[usize] {
        self.cpus_per_worker.get(tid).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Number of NUMA nodes the plan spreads workers across.
    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Pin the calling worker thread to its planned CPU set. Best-effort:
    /// a container seccomp policy may deny `sched_setaffinity`, and
    /// correctness never depends on the pin landing — results are
    /// placement-independent by construction.
    pub fn apply(&self, tid: usize) {
        let _ = affinity_lite::pin_to_cpus(self.cpus(tid));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_parses_kernel_formats() {
        assert_eq!(parse_cpulist("0-3,8-11").unwrap(), vec![0, 1, 2, 3, 8, 9, 10, 11]);
        assert_eq!(parse_cpulist("0\n").unwrap(), vec![0]);
        assert_eq!(parse_cpulist("5-5").unwrap(), vec![5]);
        assert_eq!(parse_cpulist("0,2-4,7").unwrap(), vec![0, 2, 3, 4, 7]);
        assert_eq!(parse_cpulist("3,1,3").unwrap(), vec![1, 3], "sorted + deduped");
        assert_eq!(parse_cpulist("").unwrap(), Vec::<usize>::new());
        assert_eq!(parse_cpulist(" \n").unwrap(), Vec::<usize>::new());
        assert!(parse_cpulist("3-1").is_err(), "descending range");
        assert!(parse_cpulist("a-b").is_err());
        assert!(parse_cpulist("1,,2").is_err());
        assert!(parse_cpulist("0-99999999").is_err(), "absurd range is rejected");
    }

    /// Canned `/sys/devices/system/node` fixture: two CPU-bearing nodes, a
    /// memory-only node (empty cpulist), a node directory without a
    /// cpulist, and stray non-node entries — only the real nodes survive,
    /// sorted by id.
    #[test]
    fn sysfs_fixture_detects_two_nodes() {
        let root = std::env::temp_dir()
            .join(format!("pagerank_nb_topology_fixture_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        for (dir, cpulist) in
            [("node1", Some("4-7\n")), ("node0", Some("0-3\n")), ("node2", Some(" \n"))]
        {
            let d = root.join(dir);
            std::fs::create_dir_all(&d).unwrap();
            if let Some(list) = cpulist {
                std::fs::write(d.join("cpulist"), list).unwrap();
            }
        }
        std::fs::create_dir_all(root.join("node3")).unwrap(); // no cpulist
        std::fs::create_dir_all(root.join("nodeX")).unwrap(); // not a node id
        std::fs::write(root.join("possible"), "0-3\n").unwrap(); // stray file

        let topo = Topology::from_sysfs(&root).expect("fixture must parse");
        assert_eq!(topo.nodes.len(), 2);
        assert_eq!(topo.nodes[0], NumaNode { id: 0, cpus: vec![0, 1, 2, 3] });
        assert_eq!(topo.nodes[1], NumaNode { id: 1, cpus: vec![4, 5, 6, 7] });
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn missing_sysfs_falls_back_to_single_node() {
        let bogus = std::env::temp_dir().join("pagerank_nb_topology_no_such_dir");
        assert!(Topology::from_sysfs(&bogus).is_none());
        let topo = Topology::single_node();
        assert_eq!(topo.nodes.len(), 1);
        assert!(!topo.nodes[0].cpus.is_empty());
        // detect() must always produce a usable topology, whatever the host
        let detected = Topology::detect();
        assert!(!detected.nodes.is_empty());
        assert!(detected.nodes.iter().all(|n| !n.cpus.is_empty()));
    }

    #[test]
    fn pin_is_node_contiguous_and_interleave_round_robins() {
        let topo = Topology {
            nodes: vec![
                NumaNode { id: 0, cpus: vec![0, 1] },
                NumaNode { id: 1, cpus: vec![2, 3] },
            ],
        };
        let pin = Plan::from_topology(&topo, Placement::Pin, 4);
        assert_eq!(pin.nodes(), 2);
        assert_eq!(pin.cpus(0), &[0, 1]);
        assert_eq!(pin.cpus(1), &[0, 1]);
        assert_eq!(pin.cpus(2), &[2, 3]);
        assert_eq!(pin.cpus(3), &[2, 3]);
        let il = Plan::from_topology(&topo, Placement::Interleave, 4);
        assert_eq!(il.cpus(0), &[0, 1]);
        assert_eq!(il.cpus(1), &[2, 3]);
        assert_eq!(il.cpus(2), &[0, 1]);
        assert_eq!(il.cpus(3), &[2, 3]);
        // odd worker counts still cover both nodes contiguously
        let pin3 = Plan::from_topology(&topo, Placement::Pin, 3);
        assert_eq!(pin3.cpus(0), &[0, 1]);
        assert_eq!(pin3.cpus(1), &[0, 1]);
        assert_eq!(pin3.cpus(2), &[2, 3]);
        // out-of-range tid is unconstrained, not a panic
        assert!(pin.cpus(99).is_empty());
    }

    #[test]
    fn off_yields_no_plan_and_single_node_pins_everywhere() {
        assert!(Plan::new(Placement::Off, 4).is_none());
        let topo = Topology { nodes: vec![NumaNode { id: 0, cpus: vec![0] }] };
        for placement in [Placement::Pin, Placement::Interleave] {
            let plan = Plan::from_topology(&topo, placement, 3);
            assert_eq!(plan.nodes(), 1);
            for tid in 0..3 {
                assert_eq!(plan.cpus(tid), &[0], "{placement} tid {tid}");
            }
        }
    }
}
