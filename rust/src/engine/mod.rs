//! The unified non-blocking PageRank engine.
//!
//! Every CPU variant used to be a standalone module re-implementing the same
//! orchestration: spawn `p` workers, pin each to a partition, apply the
//! fault plan, watch for DNF, detect termination at the right level, and
//! assemble a [`PrResult`]. This subsystem owns all of that once; the
//! variants shrink to [`Kernel`] implementations — the per-iteration math —
//! plus a [`SyncMode`] descriptor telling the engine how to schedule them:
//!
//! * [`SyncMode::Sequential`] — the oracle baseline, run on the caller;
//! * [`SyncMode::Blocking`] — barrier-separated phases with algorithm-level
//!   convergence (Algorithms 1, 2, 5-blocking, and the PCPM mode);
//! * [`SyncMode::NonBlocking`] — barrier-free sweeps with thread-level
//!   convergence and confirmation sweeps (Algorithms 3, 4, 5-non-blocking);
//! * [`SyncMode::Helping`] — the CAS-helping wait-free protocol with
//!   engine-owned termination detection (Algorithm 6, see [`helping`]).
//!
//! A kernel supplies up to three hooks per iteration: `scatter` (publish
//! phase — the edge-centric push, the PCPM bin write), `gather` (the main
//! sweep, returning the local max delta), and `commit` (the blocking
//! `prev ← pr` hand-off). Termination is decided by the engine from the
//! shared [`ErrorBoard`](crate::pagerank::convergence::ErrorBoard) and the
//! kernel's [`Kernel::converged`] predicate.
//!
//! Kernels register in [`REGISTRY`] — a single dispatch table that replaced
//! the per-variant `match` in `pagerank::run`. Adding an execution mode is
//! now one kernel file plus one table row.

pub mod driver;
pub mod frontier;
pub mod helping;
pub mod incremental;
pub mod ooc;
pub mod pcpm;
pub mod topology;

use crate::coordinator::metrics::RunMetrics;
use crate::graph::{Csr, Partitions, VertexId};
use crate::pagerank::{PrConfig, PrResult, Variant};
use anyhow::{bail, Result};
use std::time::Instant;

/// How the engine schedules a kernel's workers and detects termination.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncMode {
    /// Run on the calling thread; the kernel provides [`Kernel::solve`].
    Sequential,
    /// Barrier-separated phases, algorithm-level convergence. When
    /// `pre_scatter` is set the engine runs `scatter` + a barrier before
    /// every `gather` (the edge-centric push / PCPM bin-write phase).
    Blocking { pre_scatter: bool },
    /// No barriers: every worker sweeps at its own pace and exits on two
    /// consecutive calm observations of the merged error (thread-level
    /// convergence; see `driver` for the confirmation-sweep rationale).
    NonBlocking,
    /// CAS-helping wait-free protocol; the engine drives the kernel's
    /// [`helping::HelpingState`] and takes termination from it.
    Helping,
}

/// Per-worker context handed to kernel hooks.
pub struct WorkerCtx<'a> {
    /// Worker index in `0..cfg.threads` (also the partition this worker
    /// owns under static load allocation).
    pub tid: usize,
    /// Shared telemetry counters (edges processed, vertices skipped).
    pub metrics: &'a RunMetrics,
}

/// One PageRank program, reduced to its per-iteration math.
///
/// All hooks take `&self`: rank storage lives in atomic cells (see
/// [`crate::sync`]) so workers share the kernel immutably.
pub trait Kernel: Sync {
    /// How the engine should schedule this kernel.
    fn sync_mode(&self) -> SyncMode;

    /// Publish phase, run before `gather` when the mode requests it
    /// (blocking: behind its own barrier; non-blocking: immediately after
    /// the error merge — the Algorithm 4 push).
    fn scatter(&self, _ctx: &WorkerCtx<'_>) {}

    /// The main sweep for this worker's share: compute new ranks and return
    /// the local max per-vertex delta.
    fn gather(&self, ctx: &WorkerCtx<'_>) -> f64;

    /// Blocking-mode hand-off after the global error merge (`prev ← pr`).
    fn commit(&self, _ctx: &WorkerCtx<'_>) {}

    /// Termination predicate on the merged error. The default is the
    /// paper's threshold test; kernels may tighten or loosen it.
    fn converged(&self, global_err: f64, threshold: f64) -> bool {
        global_err <= threshold
    }

    /// Does this kernel schedule work through a frontier (a sweep may
    /// legitimately process zero vertices)? The NonBlocking driver exempts
    /// such empty sweeps from the iteration cap and parks the worker
    /// briefly instead of hot-spinning (see `driver::run_nonblocking`).
    fn frontier_scheduled(&self) -> bool {
        false
    }

    /// First-touch pre-pass for NUMA placement: the driver calls this from
    /// worker `tid` (after pinning, before iteration 0) so the kernel can
    /// walk the rank/`last_pushed`/value-stream entries of `tid`'s
    /// partition and pull their pages node-local. Must be free of side
    /// effects on the schedule — loads only. Default: nothing to touch.
    fn first_touch(&self, _tid: usize) {}

    /// Frontier-scheduler telemetry `(mode switches, peak work-list
    /// occupancy)`, surfaced as [`PrResult::frontier_switches`] /
    /// [`PrResult::worklist_peak`]. Default: no scheduler, all zeros.
    fn frontier_stats(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Snapshot the final rank vector.
    fn ranks(&self) -> Vec<f64>;

    /// [`SyncMode::Sequential`] kernels implement the whole solve here and
    /// return `(ranks, iterations, converged)`.
    fn solve(&self) -> Option<(Vec<f64>, u64, bool)> {
        None
    }

    /// [`SyncMode::Helping`] kernels expose their engine-owned protocol
    /// state here.
    fn helping(&self) -> Option<&helping::HelpingState<'_>> {
        None
    }
}

/// Builder signature for registry entries.
pub type KernelBuilder =
    for<'g> fn(&'g Csr, &PrConfig, &Partitions) -> Result<Box<dyn Kernel + 'g>>;

/// One row of the dispatch table.
pub struct KernelEntry {
    /// The variant this row serves.
    pub variant: Variant,
    /// Cold-start kernel constructor for the variant.
    pub build: KernelBuilder,
}

/// The dispatch table: every CPU variant (and the partition-centric mode)
/// maps to its kernel builder. `XlaBlock` is deliberately absent — it needs
/// a loaded PJRT engine and dispatches through
/// [`crate::pagerank::run_with_engine`].
pub static REGISTRY: &[KernelEntry] = &[
    KernelEntry { variant: Variant::Sequential, build: crate::pagerank::seq::kernel },
    KernelEntry { variant: Variant::Barrier, build: crate::pagerank::barrier::kernel },
    KernelEntry {
        variant: Variant::BarrierIdentical,
        build: crate::pagerank::identical::barrier_kernel,
    },
    KernelEntry { variant: Variant::BarrierEdge, build: crate::pagerank::barrier_edge::kernel },
    KernelEntry {
        variant: Variant::BarrierOpt,
        build: crate::pagerank::perforation::barrier_opt_kernel,
    },
    KernelEntry { variant: Variant::WaitFree, build: crate::pagerank::waitfree::kernel },
    KernelEntry { variant: Variant::NoSync, build: crate::pagerank::nosync::kernel },
    KernelEntry {
        variant: Variant::NoSyncIdentical,
        build: crate::pagerank::identical::nosync_kernel,
    },
    KernelEntry { variant: Variant::NoSyncEdge, build: crate::pagerank::nosync_edge::kernel },
    KernelEntry {
        variant: Variant::NoSyncOpt,
        build: crate::pagerank::perforation::nosync_opt_kernel,
    },
    KernelEntry {
        variant: Variant::NoSyncOptIdentical,
        build: crate::pagerank::perforation::nosync_opt_identical_kernel,
    },
    KernelEntry { variant: Variant::Pcpm, build: pcpm::kernel },
    KernelEntry { variant: Variant::Frontier, build: frontier::kernel },
    KernelEntry { variant: Variant::FrontierPcpm, build: frontier::pcpm_kernel },
];

/// Look up a variant's kernel builder.
pub fn lookup(variant: Variant) -> Option<&'static KernelEntry> {
    REGISTRY.iter().find(|e| e.variant == variant)
}

/// Run `variant` on `g` through the unified engine.
pub fn run(g: &Csr, variant: Variant, cfg: &PrConfig) -> Result<PrResult> {
    cfg.validate()?;
    let Some(entry) = lookup(variant) else {
        bail!("{variant} has no CPU kernel; XlaBlock needs an engine — use run_with_engine");
    };
    if g.num_vertices() == 0 {
        return Ok(PrResult::empty(variant, cfg.threads));
    }
    let parts = Partitions::new(g, cfg.threads, cfg.partition);
    // The clock starts before kernel construction so preprocessing (STIC-D
    // identical classes, PCPM bin layout) counts toward the reported wall
    // time, as in the source papers.
    let start = Instant::now();
    let kernel = (entry.build)(g, cfg, &parts)?;
    driver::execute(variant, cfg, kernel.as_ref(), start)
}

/// Reciprocal out-degrees — shared by every kernel's inner loop (hoists the
/// per-edge division out of Eq. 1).
pub fn inv_out_degrees(g: &Csr) -> Vec<f64> {
    (0..g.num_vertices() as VertexId)
        .map(|v| {
            let od = g.out_degree(v);
            if od == 0 {
                0.0
            } else {
                1.0 / od as f64
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic;

    #[test]
    fn registry_covers_every_cpu_variant_and_pcpm() {
        for v in Variant::ALL_MODES {
            assert!(lookup(v).is_some(), "{v} missing from REGISTRY");
        }
        assert!(lookup(Variant::XlaBlock).is_none());
        assert_eq!(REGISTRY.len(), Variant::ALL_MODES.len());
    }

    #[test]
    fn xla_block_dispatch_is_an_error() {
        let g = synthetic::cycle(4);
        let err = run(&g, Variant::XlaBlock, &PrConfig::default());
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("run_with_engine"));
    }

    #[test]
    fn empty_graph_short_circuits_for_every_mode() {
        let g = crate::graph::GraphBuilder::new(0).build("nil");
        for v in Variant::ALL_MODES {
            let r = run(&g, v, &PrConfig::default()).unwrap();
            assert!(r.converged, "{v}");
            assert!(r.ranks.is_empty(), "{v}");
        }
    }

    #[test]
    fn inv_out_degrees_handles_dangling() {
        let g = synthetic::chain(3); // 0→1→2, vertex 2 dangles
        let inv = inv_out_degrees(&g);
        assert_eq!(inv, vec![1.0, 1.0, 0.0]);
    }
}
