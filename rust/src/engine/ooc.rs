//! Out-of-core execution: shard-rotating PageRank over an mmap-backed CSR.
//!
//! The in-memory engine assumes the whole graph (and the PCPM value stream)
//! is resident. For graphs near or past physical RAM that assumption turns
//! every sweep into a page-fault storm with no locality: `p` workers touch
//! `p` disjoint vertex ranges *concurrently*, so the page cache thrashes
//! across the whole file. This module trades that for a classic
//! semi-external schedule in the spirit of GraphChi's shards (Kyrola et al.,
//! OSDI'12) built from pieces the engine already has:
//!
//! * **storage** — the CSR arrays stay on disk in the v2 binary cache and
//!   are borrowed zero-copy through [`crate::graph::io::map_binary`]; the
//!   OS pages a shard's slice of the arrays in as the sweep streams it and
//!   can evict cold shards under pressure (`MAP_PRIVATE` read-only, so
//!   nothing is ever written back); while one shard gathers, the
//!   coordinator issues a `madvise(MADV_WILLNEED)` read-ahead
//!   ([`Csr::prefetch_vertex_range`]) for the *next dirty* shard so its
//!   page-ins overlap with compute;
//! * **compute** — vertices are split into `S` contiguous shards by the
//!   standard [`Partitions`] policies, and the coordinator rotates through
//!   them *one at a time* on the calling thread, replaying each shard
//!   through the [`FrontierPcpm`](crate::pagerank::Variant::FrontierPcpm)
//!   kernel's gather: contributions are read from the compressed
//!   [`CompressedBins`](crate::graph::CompressedBins) value stream (dense,
//!   grouped by destination partition — sequential page-ins), and changed
//!   vertices push back through the same stream;
//! * **scheduling** — the kernel's dirty bitmap is shared with the
//!   coordinator ([`warm_pcpm_kernel_shared`]), whose non-destructive
//!   [`DirtyFlags::any_in_range`] probe skips shards with no pending work
//!   entirely — they are never paged in. The run terminates when a full
//!   rotation leaves the bitmap empty.
//!
//! Because exactly one shard is active at a time, the resident working set
//! is one shard's arrays plus the O(n) rank/value vectors, not the whole
//! edge set — that is what `--mem-budget` sizes the shard count against
//! ([`shards_for_budget`]). The schedule is sequential over shards, so the
//! result is deterministic for a fixed shard count and matches the paper's
//! fixed point to the same delta-bounded accuracy as the frontier family
//! (the equivalence test pins L1 ≤ 1e-6 against Barrier).

use crate::coordinator::metrics::RunMetrics;
use crate::engine::frontier::warm_pcpm_kernel_shared;
use crate::engine::WorkerCtx;
use crate::graph::{Csr, Partitions};
use crate::pagerank::{PrConfig, PrResult, Variant};
use crate::sync::dirty::DirtyFlags;
use anyhow::{ensure, Result};
use std::sync::Arc;
use std::time::Instant;

/// Derive a shard count from a memory budget: enough shards that one
/// shard's slice of the CSR arrays fits the budget. The O(n) resident state
/// (ranks, last-pushed, value stream) is not shardable — it stays in RAM
/// regardless — so the budget only has to cover the edge-heavy arrays,
/// which is exactly what sharding divides. Clamped to `[1, n]`.
pub fn shards_for_budget(g: &Csr, mem_budget_bytes: u64) -> usize {
    let n = g.num_vertices();
    if n == 0 || mem_budget_bytes == 0 {
        return 1;
    }
    let per_shard_target = mem_budget_bytes.max(1);
    let shards = g.memory_bytes().div_ceil(per_shard_target).max(1);
    usize::try_from(shards).unwrap_or(n).min(n)
}

/// Run PageRank out-of-core: `shards` vertex ranges swept one at a time on
/// the calling thread through the frontier-PCPM kernel, clean shards
/// skipped via the shared dirty bitmap. Works on any [`Csr`] but is built
/// for mapped ones ([`Csr::is_mapped`]) — an owned graph gains nothing from
/// the rotation except the skip telemetry.
///
/// `cfg.threads` is ignored (the coordinator is single-threaded by design —
/// one shard resident at a time *is* the memory bound); `cfg.max_iterations`
/// caps full rotations.
pub fn run_sharded(g: &Csr, cfg: &PrConfig, shards: usize) -> Result<PrResult> {
    cfg.validate()?;
    ensure!(shards >= 1, "need at least one shard");
    let n = g.num_vertices();
    if n == 0 {
        return Ok(PrResult::empty(Variant::FrontierPcpm, shards));
    }
    let shards = shards.min(n);
    let parts = Partitions::new(g, shards, cfg.partition);
    let dirty = Arc::new(DirtyFlags::new_set(n));
    let warm = vec![1.0 / n as f64; n];
    // Clock starts before kernel construction (bin layout, value seeding)
    // to match the in-memory engine's accounting.
    let start = Instant::now();
    let kernel = warm_pcpm_kernel_shared(g, cfg, &parts, &warm, Arc::clone(&dirty))?;
    let metrics = RunMetrics::new(shards);
    let mut converged = false;
    let mut skipped_shards = 0u64;
    for _rotation in 0..cfg.max_iterations {
        for shard in 0..shards {
            if !dirty.any_in_range(parts.range(shard)) {
                // nothing pending: the shard's pages are never touched
                skipped_shards += 1;
                continue;
            }
            // Read-ahead: while this shard gathers, the kernel can stream
            // in the pages of the *next dirty* shard
            // (`madvise(MADV_WILLNEED)` under the hood — a no-op on owned
            // graphs). Probe-gated, so a clean shard is never advised in.
            if let Some(next) =
                (shard + 1..shards).find(|&s| dirty.any_in_range(parts.range(s)))
            {
                g.prefetch_vertex_range(parts.range(next));
            }
            kernel.gather(&WorkerCtx { tid: shard, metrics: &metrics });
            metrics.bump_iteration(shard);
        }
        // Single-threaded schedule: after a rotation no sweep is in flight,
        // so an empty bitmap is definitive — every vertex has absorbed
        // every push, and nothing moved enough to push again. No
        // confirmation sweeps needed (those exist to close the concurrent
        // mark-vs-drain window in the multi-worker driver).
        if dirty.count_set() == 0 {
            converged = true;
            break;
        }
    }
    metrics.add_skipped(0, skipped_shards);
    let (frontier_switches, worklist_peak) = kernel.frontier_stats();
    Ok(PrResult {
        variant: Variant::FrontierPcpm,
        ranks: kernel.ranks(),
        iterations: metrics.max_iterations(),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed: start.elapsed(),
        converged,
        barrier_wait_secs: 0.0,
        vertex_updates: metrics.total_gathered(),
        frontier_switches,
        worklist_peak,
        dnf: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{io, synthetic, GraphBuilder};
    use crate::pagerank::seq;

    fn cfg() -> PrConfig {
        PrConfig { threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn sharded_matches_sequential_across_shard_counts() {
        let c = cfg();
        for g in [
            synthetic::cycle(60),
            synthetic::chain(120),
            synthetic::star(60),
            synthetic::web_replica(800, 6, 11),
        ] {
            let (sr, _, _) = seq::solve(&g, &c);
            for shards in [1usize, 3, 8] {
                let r = run_sharded(&g, &c, shards).unwrap();
                assert!(r.converged, "{} shards={shards}", g.name);
                let l1 = r.l1_norm(&sr);
                assert!(l1 < 1e-7, "{} shards={shards}: l1 {l1}", g.name);
            }
        }
    }

    #[test]
    fn sharded_run_on_mapped_graph_matches_owned() {
        let g = synthetic::web_replica(600, 5, 29);
        let dir = std::env::temp_dir().join("pagerank_nb_ooc_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("ooc-{}.bin", std::process::id()));
        io::save_binary(&g, &p).unwrap();
        let mapped = io::map_binary(&p).unwrap();
        assert!(mapped.is_mapped());
        let c = cfg();
        let owned_r = run_sharded(&g, &c, 4).unwrap();
        let mapped_r = run_sharded(&mapped, &c, 4).unwrap();
        assert!(mapped_r.converged);
        // identical schedule on identical graphs: bitwise-equal ranks
        assert_eq!(owned_r.ranks, mapped_r.ranks);
        assert_eq!(owned_r.iterations, mapped_r.iterations);
    }

    #[test]
    fn empty_graph_and_degenerate_shard_counts() {
        let g = GraphBuilder::new(0).build("nil");
        let r = run_sharded(&g, &cfg(), 7).unwrap();
        assert!(r.converged);
        assert!(r.ranks.is_empty());
        assert!(run_sharded(&g, &cfg(), 0).is_err(), "zero shards rejected");
        // more shards than vertices: clamped, still correct
        let g = synthetic::cycle(3);
        let r = run_sharded(&g, &cfg(), 64).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &cfg());
        assert!(r.l1_norm(&sr) < 1e-9);
    }

    #[test]
    fn clean_shards_are_skipped() {
        // A reversed chain confined to vertices 0..31 (edges i+1 → i, so
        // rank mass crawls down one hop per rotation — many rotations) plus
        // isolated vertices 31..400. After the first rotation only shard 0
        // ever has dirty vertices; the other seven must be probe-skipped,
        // not swept.
        let edges: Vec<(u32, u32)> = (0..30u32).map(|i| (i + 1, i)).collect();
        let g = GraphBuilder::new(400).edges(&edges).build("rev-chain");
        let c = cfg();
        let r = run_sharded(&g, &c, 8).unwrap();
        assert!(r.converged);
        let rotations = r.iterations;
        assert!(rotations > 3, "fixture must need several rotations, got {rotations}");
        for (shard, &sweeps) in r.per_thread_iterations.iter().enumerate().skip(1) {
            assert!(
                sweeps <= 1,
                "shard {shard} swept {sweeps} times — clean shards must be skipped"
            );
        }
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-7);
    }

    #[test]
    fn budget_derivation_is_monotone_and_clamped() {
        let g = synthetic::web_replica(2000, 6, 17);
        let bytes = g.memory_bytes();
        assert_eq!(shards_for_budget(&g, bytes), 1, "whole graph fits");
        assert_eq!(shards_for_budget(&g, bytes * 2), 1);
        let half = shards_for_budget(&g, bytes / 2);
        let quarter = shards_for_budget(&g, bytes / 4);
        assert!(half >= 2, "half budget must shard: {half}");
        assert!(quarter >= half, "smaller budget, more shards");
        assert_eq!(shards_for_budget(&g, 0), 1, "zero budget is clamped");
        assert!(shards_for_budget(&g, 1) <= g.num_vertices(), "clamped to n");
        let empty = GraphBuilder::new(0).build("nil");
        assert_eq!(shards_for_budget(&empty, 1024), 1);
    }

    #[test]
    fn rotation_cap_reports_unconverged() {
        let g = synthetic::web_replica(400, 6, 8);
        let c = PrConfig { max_iterations: 2, ..cfg() };
        let r = run_sharded(&g, &c, 4).unwrap();
        assert!(!r.converged);
        assert!(r.iterations <= 2);
    }
}
