//! Out-of-core execution: shard-rotating PageRank over an mmap-backed CSR.
//!
//! The in-memory engine assumes the whole graph (and the PCPM value stream)
//! is resident. For graphs near or past physical RAM that assumption turns
//! every sweep into a page-fault storm with no locality: `p` workers touch
//! `p` disjoint vertex ranges *concurrently*, so the page cache thrashes
//! across the whole file. This module trades that for a classic
//! semi-external schedule in the spirit of GraphChi's parallel sliding
//! windows (Kyrola et al., OSDI'12) built from pieces the engine already
//! has:
//!
//! * **storage** — the CSR arrays stay on disk in the v2 binary cache and
//!   are borrowed zero-copy through [`crate::graph::io::map_binary`]; the
//!   OS pages a shard's slice of the arrays in as the sweep streams it and
//!   can evict cold shards under pressure (`MAP_PRIVATE` read-only, so
//!   nothing is ever written back); while resident shards gather, the
//!   coordinator issues `madvise(MADV_WILLNEED)` read-ahead
//!   ([`Csr::prefetch_vertex_range`]) for the shards about to be claimed so
//!   their page-ins overlap with compute;
//! * **compute** — vertices are split into `S` contiguous shards by the
//!   standard [`Partitions`] policies and replayed through the
//!   [`FrontierPcpm`](crate::pagerank::Variant::FrontierPcpm) kernel's
//!   gather: contributions are read from the compressed
//!   [`CompressedBins`](crate::graph::CompressedBins) value stream (dense,
//!   grouped by destination partition — sequential page-ins), and changed
//!   vertices push back through the same stream. With `--ooc-workers 1`
//!   (the default of [`run_sharded`]) the coordinator rotates shards *one
//!   at a time* on the calling thread; with `K > 1`
//!   ([`run_sharded_workers`]) K workers claim dirty shards from a shared
//!   [`WorkList`] ring and sweep them concurrently — cross-shard writes
//!   already flow through the atomic value stream and the lock-free dirty
//!   bitmap, and each worker's sweep stays inside its claimed shard's
//!   vertex range (see the concurrency contract on
//!   [`warm_pcpm_kernel_shared`]);
//! * **scheduling** — the kernel's dirty bitmap is shared with the
//!   coordinator ([`warm_pcpm_kernel_shared`]), whose non-destructive
//!   [`DirtyFlags::any_in_range`] probe skips shards with no pending work
//!   entirely — they are never paged in. A *rotation* is one full pass over
//!   the shards; between rotations no sweep is in flight (a sense-reversing
//!   barrier closes each rotation), so the probe pass is exact and the run
//!   terminates when it finds the bitmap empty — the same
//!   calm-observation-with-no-writers-in-flight reasoning the non-blocking
//!   driver's confirmation sweeps implement, collapsed to one observation
//!   because the barrier removes the in-flight writers.
//!
//! The parallel rotation (`K > 1`) looks like this:
//!
//! ```text
//!   coordinator                    claim ring              K workers
//!   ───────────                    ──────────              ─────────
//!   probe shards 0..S              ┌───────────┐
//!   (any_in_range; skip clean) ──▶ │ 2 5 6 9 … │ ◀── pop: claim shard
//!   advise first K shards          └───────────┘         advise shard K
//!   (MADV_WILLNEED)                                       ahead of claim
//!        │                                                sweep shard
//!        ├───────── barrier: rotation starts ───────────────┤
//!        │                                                  │
//!        ├───────── barrier: ring drained, sweeps done ─────┤
//!   bitmap empty? ── yes ─▶ converged
//!        └── no: next rotation
//! ```
//!
//! Exactly `K` shards are being swept at any instant and at most `K` more
//! are being advised in, so the resident working set is `≤ K` shards'
//! arrays (plus read-ahead) and the O(n) rank/value vectors — that is what
//! `--mem-budget` sizes the shard count against: [`shards_for_budget`]
//! divides the budget by `K` so K resident shards still fit. The `K = 1`
//! schedule is sequential over shards and therefore deterministic for a
//! fixed shard count (bit-identical across runs and storage backends,
//! pinned by tests); `K > 1` interleaves shard sweeps nondeterministically
//! but stays within the same delta-bounded envelope as the frontier family
//! (the equivalence test pins L1 ≤ 1e-6 against Barrier).

use crate::coordinator::metrics::RunMetrics;
use crate::engine::frontier::warm_pcpm_kernel_shared;
use crate::engine::WorkerCtx;
use crate::graph::{Csr, Partitions};
use crate::pagerank::{PrConfig, PrResult, Variant};
use crate::sync::barrier::SenseBarrier;
use crate::sync::dirty::DirtyFlags;
use crate::sync::worklist::WorkList;
use anyhow::{bail, ensure, Result};
use crate::sync::shim::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Derive a shard count from a memory budget: enough shards that the
/// `workers` concurrently-resident shards' slices of the CSR arrays fit the
/// budget together. The O(n) resident state (ranks, last-pushed, value
/// stream) is not shardable — it stays in RAM regardless — so the budget
/// only has to cover the edge-heavy arrays, which is exactly what sharding
/// divides.
///
/// A zero budget means "no budget": the graph stays in one shard (the CLI
/// rejects `--mem-budget 0` before it gets here). Errors when the budget
/// cannot hold even one average shard at the finest sharding (one vertex
/// per shard) — silently clamping there would hand back a schedule that
/// blows the budget on every rotation.
pub fn shards_for_budget(g: &Csr, mem_budget_bytes: u64, workers: usize) -> Result<usize> {
    let n = g.num_vertices();
    if n == 0 || mem_budget_bytes == 0 {
        return Ok(1);
    }
    let workers = workers.max(1) as u64;
    let total = g.memory_bytes();
    // K shards are resident at once, so each may use budget / K.
    let per_shard_budget = mem_budget_bytes / workers;
    ensure!(
        per_shard_budget > 0,
        "--mem-budget of {mem_budget_bytes} bytes split across {workers} \
         resident shard(s) leaves no room per shard — raise --mem-budget or \
         lower --ooc-workers"
    );
    let shards = total.div_ceil(per_shard_budget).max(1);
    if shards > n as u64 {
        bail!(
            "--mem-budget too small: {per_shard_budget} bytes per resident \
             shard ({mem_budget_bytes} across {workers} worker(s)) cannot hold \
             one shard of this graph even at one vertex per shard \
             (~{} bytes each) — raise --mem-budget or lower --ooc-workers",
            total.div_ceil(n as u64).max(1)
        );
    }
    Ok(shards as usize)
}

/// Run PageRank out-of-core with the sequential rotation: `shards` vertex
/// ranges swept one at a time on the calling thread through the
/// frontier-PCPM kernel, clean shards skipped via the shared dirty bitmap.
/// Works on any [`Csr`] but is built for mapped ones ([`Csr::is_mapped`]) —
/// an owned graph gains nothing from the rotation except the skip
/// telemetry.
///
/// Equivalent to [`run_sharded_workers`] with one worker — and kept
/// bit-identical to it (the tests pin this), so `--ooc-workers 1` *is* the
/// deterministic schedule this function has always produced.
/// `cfg.max_iterations` caps full rotations.
pub fn run_sharded(g: &Csr, cfg: &PrConfig, shards: usize) -> Result<PrResult> {
    run_sharded_workers(g, cfg, shards, 1)
}

/// Run PageRank out-of-core with `workers` parallel shard sweeps
/// (`--ooc-workers K`).
///
/// Per rotation the coordinator probes every shard with the non-destructive
/// [`DirtyFlags::any_in_range`], pushes the dirty ones (ascending) onto a
/// shared [`WorkList`] claim ring, and advises the first K in
/// (`madvise(MADV_WILLNEED)`); the K workers then pop shard ids until the
/// ring drains, each advising the shard K claims ahead before sweeping its
/// own through the kernel's gather. A sense-reversing barrier closes the
/// rotation, so the coordinator's empty-bitmap convergence probe never
/// races an in-flight sweep. `workers` is clamped to the shard count
/// (more workers than shards cannot claim anything); `workers == 1` takes
/// the sequential rotation path of [`run_sharded`], bit for bit.
///
/// `cfg.threads` is ignored — out-of-core parallelism is `workers`, sized
/// by the memory budget, not by `--threads`.
pub fn run_sharded_workers(
    g: &Csr,
    cfg: &PrConfig,
    shards: usize,
    workers: usize,
) -> Result<PrResult> {
    cfg.validate()?;
    ensure!(shards >= 1, "need at least one shard");
    ensure!(workers >= 1, "need at least one out-of-core worker");
    let n = g.num_vertices();
    if n == 0 {
        return Ok(PrResult::empty(Variant::FrontierPcpm, shards));
    }
    let shards = shards.min(n);
    let workers = workers.min(shards);
    let parts = Partitions::new(g, shards, cfg.partition);
    let dirty = Arc::new(DirtyFlags::new_set(n));
    let warm = vec![1.0 / n as f64; n];
    // Clock starts before kernel construction (bin layout, value seeding)
    // to match the in-memory engine's accounting.
    let start = Instant::now();
    let kernel = warm_pcpm_kernel_shared(g, cfg, &parts, &warm, Arc::clone(&dirty))?;
    let metrics = RunMetrics::new(shards);
    let mut converged = false;
    let mut skipped_shards = 0u64;
    if workers == 1 {
        // Sequential rotation: probe each shard lazily just before its slot
        // in the pass, so work marked by an *earlier* sweep of the same
        // rotation is still picked up this rotation. This is the historical
        // deterministic schedule `--ooc-workers 1` promises to preserve.
        for _rotation in 0..cfg.max_iterations {
            for shard in 0..shards {
                if !dirty.any_in_range(parts.range(shard)) {
                    // nothing pending: the shard's pages are never touched
                    skipped_shards += 1;
                    continue;
                }
                // Read-ahead: while this shard gathers, the kernel can
                // stream in the pages of the *next dirty* shard
                // (`madvise(MADV_WILLNEED)` under the hood — a no-op on
                // owned graphs). Probe-gated, so a clean shard is never
                // advised in.
                if let Some(next) =
                    (shard + 1..shards).find(|&s| dirty.any_in_range(parts.range(s)))
                {
                    g.prefetch_vertex_range(parts.range(next));
                }
                kernel.gather(&WorkerCtx { tid: shard, metrics: &metrics });
                metrics.bump_iteration(shard);
            }
            // Single-threaded schedule: after a rotation no sweep is in
            // flight, so an empty bitmap is definitive — every vertex has
            // absorbed every push, and nothing moved enough to push again.
            if dirty.count_set() == 0 {
                converged = true;
                break;
            }
        }
    } else {
        // Parallel rotation: claim ring + per-rotation barrier (see the
        // module diagram). The ring is sized to hold every shard, so a
        // rotation's fill can never overflow it.
        let queue = WorkList::with_capacity(shards);
        // This rotation's dirty shards, ascending — read by workers only
        // for the prefetch lookahead. Refilled by the coordinator while the
        // workers sit at the rotation barrier, so the lock is uncontended.
        let order: Mutex<Vec<u32>> = Mutex::new(Vec::with_capacity(shards));
        let claims = AtomicUsize::new(0);
        let done = AtomicBool::new(false);
        let barrier = SenseBarrier::new(workers + 1);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let queue = &queue;
                let order = &order;
                let claims = &claims;
                let done = &done;
                let barrier = &barrier;
                let kernel = &kernel;
                let metrics = &metrics;
                let parts = &parts;
                scope.spawn(move || {
                    // A worker that unwinds mid-sweep would leave the
                    // coordinator spinning at the barrier forever; abort it
                    // so everyone unblocks and the scope can propagate the
                    // panic.
                    let _guard = AbortOnPanic(barrier);
                    let mut waiter = barrier.waiter();
                    loop {
                        if waiter.wait().is_aborted() || done.load(Ordering::Acquire) {
                            return;
                        }
                        while let Some(shard) = queue.pop() {
                            // relaxed: prefetch-window cursor only; shard
                            // exclusivity comes from the ring pop itself
                            let claim = claims.fetch_add(1, Ordering::Relaxed);
                            // Read-ahead for the shard `workers` claims
                            // ahead of this one: by the time a worker gets
                            // to it, its page-ins have overlapped with the
                            // `workers` sweeps in between.
                            if let Some(&ahead) =
                                order.lock().unwrap().get(claim + workers)
                            {
                                g.prefetch_vertex_range(parts.range(ahead as usize));
                            }
                            let shard = shard as usize;
                            kernel.gather(&WorkerCtx { tid: shard, metrics });
                            metrics.bump_iteration(shard);
                        }
                        if waiter.wait().is_aborted() {
                            return;
                        }
                    }
                });
            }
            let mut waiter = barrier.waiter();
            for _rotation in 0..cfg.max_iterations {
                {
                    // Workers are parked at the rotation barrier here: the
                    // probe pass sees a quiescent bitmap and the ring/order
                    // refill cannot race a pop.
                    let mut order = order.lock().unwrap();
                    order.clear();
                    for shard in 0..shards {
                        if dirty.any_in_range(parts.range(shard)) {
                            order.push(shard as u32);
                        } else {
                            skipped_shards += 1;
                        }
                    }
                    // relaxed: workers are parked at the barrier (see above),
                    // so this reset cannot race a fetch_add
                    claims.store(0, Ordering::Relaxed);
                    for &shard in order.iter() {
                        let pushed = queue.push(shard);
                        debug_assert!(pushed, "claim ring sized to hold every shard");
                    }
                    // Warm the first claim window before the rotation
                    // starts; workers keep the window K ahead from here.
                    for &shard in order.iter().take(workers) {
                        g.prefetch_vertex_range(parts.range(shard as usize));
                    }
                    if order.is_empty() {
                        converged = true;
                    }
                }
                if converged {
                    break;
                }
                if waiter.wait().is_aborted() {
                    break; // a worker panicked; the scope will re-raise
                }
                if waiter.wait().is_aborted() {
                    break;
                }
                // Rotation closed: no sweep in flight, so an empty bitmap
                // is definitive — one calm observation suffices (the
                // barrier plays the role of the non-blocking driver's
                // confirmation sweeps).
                if dirty.count_set() == 0 {
                    converged = true;
                    break;
                }
            }
            done.store(true, Ordering::Release);
            // Release the workers parked at the rotation barrier so they
            // observe `done` and exit; under abort this is a no-op wait.
            waiter.wait();
        });
    }
    metrics.add_skipped(0, skipped_shards);
    let (frontier_switches, worklist_peak) = kernel.frontier_stats();
    Ok(PrResult {
        variant: Variant::FrontierPcpm,
        ranks: kernel.ranks(),
        iterations: metrics.max_iterations(),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed: start.elapsed(),
        converged,
        barrier_wait_secs: 0.0,
        vertex_updates: metrics.total_gathered(),
        frontier_switches,
        worklist_peak,
        dnf: false,
    })
}

/// Aborts the rotation barrier when the holding thread unwinds, so a
/// panicking worker cannot strand its peers (they all observe
/// `BarrierWait::Aborted` and return, letting the scope propagate the
/// original panic).
struct AbortOnPanic<'b>(&'b SenseBarrier);

impl Drop for AbortOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.abort();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{io, synthetic, GraphBuilder};
    use crate::pagerank::seq;

    fn cfg() -> PrConfig {
        PrConfig { threshold: 1e-12, ..PrConfig::default() }
    }

    /// The pre-parallel sequential schedule, spelled out by hand: lazy
    /// per-shard probe, next-dirty prefetch, empty-bitmap convergence
    /// check after each full rotation. [`run_sharded`] (and
    /// [`run_sharded_workers`] at K=1) must reproduce it bit for bit —
    /// this is the reference the determinism property test compares
    /// against, independent of the claim-ring machinery.
    fn reference_sequential_ranks(g: &Csr, cfg: &PrConfig, shards: usize) -> (Vec<f64>, bool) {
        let n = g.num_vertices();
        let shards = shards.min(n).max(1);
        let parts = Partitions::new(g, shards, cfg.partition);
        let dirty = Arc::new(DirtyFlags::new_set(n));
        let warm = vec![1.0 / n as f64; n];
        let kernel =
            warm_pcpm_kernel_shared(g, cfg, &parts, &warm, Arc::clone(&dirty)).unwrap();
        let metrics = RunMetrics::new(shards);
        let mut converged = false;
        for _ in 0..cfg.max_iterations {
            for shard in 0..shards {
                if !dirty.any_in_range(parts.range(shard)) {
                    continue;
                }
                kernel.gather(&WorkerCtx { tid: shard, metrics: &metrics });
            }
            if dirty.count_set() == 0 {
                converged = true;
                break;
            }
        }
        (kernel.ranks(), converged)
    }

    #[test]
    fn sharded_matches_sequential_across_shard_counts() {
        let c = cfg();
        for g in [
            synthetic::cycle(60),
            synthetic::chain(120),
            synthetic::star(60),
            synthetic::web_replica(800, 6, 11),
        ] {
            let (sr, _, _) = seq::solve(&g, &c);
            for shards in [1usize, 3, 8] {
                let r = run_sharded(&g, &c, shards).unwrap();
                assert!(r.converged, "{} shards={shards}", g.name);
                let l1 = r.l1_norm(&sr);
                assert!(l1 < 1e-7, "{} shards={shards}: l1 {l1}", g.name);
            }
        }
    }

    #[test]
    fn parallel_workers_match_sequential_across_worker_counts() {
        let c = cfg();
        for g in [
            synthetic::cycle(60),
            synthetic::chain(120),
            synthetic::web_replica(800, 6, 11),
        ] {
            let (sr, _, _) = seq::solve(&g, &c);
            for (shards, workers) in [(4usize, 2usize), (8, 4), (8, 3)] {
                let r = run_sharded_workers(&g, &c, shards, workers).unwrap();
                assert!(r.converged, "{} s={shards} k={workers}", g.name);
                let l1 = r.l1_norm(&sr);
                assert!(l1 < 1e-7, "{} s={shards} k={workers}: l1 {l1}", g.name);
                assert!(r.vertex_updates > 0, "{} parallel path uninstrumented", g.name);
            }
        }
    }

    #[test]
    fn one_worker_is_bitwise_identical_to_the_sequential_schedule() {
        // The determinism pin, on owned AND mapped storage: K=1 through the
        // public entry points must equal the hand-rolled pre-parallel
        // rotation bit for bit, across shard counts and graph shapes.
        let dir = std::env::temp_dir().join("pagerank_nb_ooc_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let c = cfg();
        for (i, g) in [
            synthetic::web_replica(700, 5, 23),
            synthetic::chain(200),
            synthetic::star(90),
        ]
        .iter()
        .enumerate()
        {
            let p = dir.join(format!("seq-ref-{}-{i}.bin", std::process::id()));
            io::save_binary(g, &p).unwrap();
            let mapped = io::map_binary(&p).unwrap();
            assert!(mapped.is_mapped());
            for shards in [1usize, 3, 5] {
                let (reference, ref_conv) = reference_sequential_ranks(g, &c, shards);
                for storage in [g, &mapped] {
                    let a = run_sharded(storage, &c, shards).unwrap();
                    let b = run_sharded_workers(storage, &c, shards, 1).unwrap();
                    assert_eq!(a.ranks, reference, "{} shards={shards}", g.name);
                    assert_eq!(b.ranks, reference, "{} shards={shards} (K=1)", g.name);
                    assert_eq!(a.converged, ref_conv);
                    assert_eq!(b.converged, ref_conv);
                }
            }
        }
    }

    #[test]
    fn worker_count_clamps_to_shard_count() {
        // More workers than shards: clamped (surplus workers could never
        // claim anything), still converges to the right fixed point.
        let g = synthetic::web_replica(500, 5, 7);
        let c = cfg();
        let (sr, _, _) = seq::solve(&g, &c);
        let r = run_sharded_workers(&g, &c, 3, 64).unwrap();
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-7);
        // and a clamp all the way to one worker is the sequential schedule
        let clamped = run_sharded_workers(&g, &c, 1, 8).unwrap();
        let seq_run = run_sharded(&g, &c, 1).unwrap();
        assert_eq!(clamped.ranks, seq_run.ranks, "K clamped to 1 shard must be sequential");
    }

    #[test]
    fn sharded_run_on_mapped_graph_matches_owned() {
        let g = synthetic::web_replica(600, 5, 29);
        let dir = std::env::temp_dir().join("pagerank_nb_ooc_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("ooc-{}.bin", std::process::id()));
        io::save_binary(&g, &p).unwrap();
        let mapped = io::map_binary(&p).unwrap();
        assert!(mapped.is_mapped());
        let c = cfg();
        let owned_r = run_sharded(&g, &c, 4).unwrap();
        let mapped_r = run_sharded(&mapped, &c, 4).unwrap();
        assert!(mapped_r.converged);
        // identical schedule on identical graphs: bitwise-equal ranks
        assert_eq!(owned_r.ranks, mapped_r.ranks);
        assert_eq!(owned_r.iterations, mapped_r.iterations);
    }

    #[test]
    fn empty_graph_and_degenerate_shard_counts() {
        let g = GraphBuilder::new(0).build("nil");
        let r = run_sharded(&g, &cfg(), 7).unwrap();
        assert!(r.converged);
        assert!(r.ranks.is_empty());
        assert!(run_sharded(&g, &cfg(), 0).is_err(), "zero shards rejected");
        assert!(
            run_sharded_workers(&g, &cfg(), 4, 0).is_err(),
            "zero workers rejected"
        );
        let empty_par = run_sharded_workers(&g, &cfg(), 4, 4).unwrap();
        assert!(empty_par.converged && empty_par.ranks.is_empty());
        // more shards than vertices: clamped, still correct
        let g = synthetic::cycle(3);
        let r = run_sharded(&g, &cfg(), 64).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &cfg());
        assert!(r.l1_norm(&sr) < 1e-9);
    }

    #[test]
    fn clean_shards_are_skipped() {
        // A reversed chain confined to vertices 0..31 (edges i+1 → i, so
        // rank mass crawls down one hop per rotation — many rotations) plus
        // isolated vertices 31..400. After the first rotation only shard 0
        // ever has dirty vertices; the other seven must be probe-skipped,
        // not swept.
        let edges: Vec<(u32, u32)> = (0..30u32).map(|i| (i + 1, i)).collect();
        let g = GraphBuilder::new(400).edges(&edges).build("rev-chain");
        let c = cfg();
        for r in [
            run_sharded(&g, &c, 8).unwrap(),
            run_sharded_workers(&g, &c, 8, 4).unwrap(),
        ] {
            assert!(r.converged);
            let rotations = r.iterations;
            assert!(rotations > 3, "fixture must need several rotations, got {rotations}");
            for (shard, &sweeps) in r.per_thread_iterations.iter().enumerate().skip(1) {
                assert!(
                    sweeps <= 1,
                    "shard {shard} swept {sweeps} times — clean shards must be skipped"
                );
            }
            let (sr, _, _) = seq::solve(&g, &c);
            assert!(r.l1_norm(&sr) < 1e-7);
        }
    }

    #[test]
    fn budget_derivation_is_monotone_and_clamped() {
        let g = synthetic::web_replica(2000, 6, 17);
        let bytes = g.memory_bytes();
        assert_eq!(shards_for_budget(&g, bytes, 1).unwrap(), 1, "whole graph fits");
        assert_eq!(shards_for_budget(&g, bytes * 2, 1).unwrap(), 1);
        let half = shards_for_budget(&g, bytes / 2, 1).unwrap();
        let quarter = shards_for_budget(&g, bytes / 4, 1).unwrap();
        assert!(half >= 2, "half budget must shard: {half}");
        assert!(quarter >= half, "smaller budget, more shards");
        assert_eq!(shards_for_budget(&g, 0, 1).unwrap(), 1, "zero budget means no budget");
        let empty = GraphBuilder::new(0).build("nil");
        assert_eq!(shards_for_budget(&empty, 1024, 4).unwrap(), 1);
    }

    #[test]
    fn budget_is_divided_across_resident_workers() {
        // K resident shards must fit the same budget together, so the
        // derived shard count scales with K: twice the workers, (at least)
        // twice the shards for a budget the whole graph fits in once.
        let g = synthetic::web_replica(2000, 6, 17);
        let bytes = g.memory_bytes();
        let k1 = shards_for_budget(&g, bytes, 1).unwrap();
        let k2 = shards_for_budget(&g, bytes, 2).unwrap();
        let k4 = shards_for_budget(&g, bytes, 4).unwrap();
        assert_eq!(k1, 1);
        assert!(k2 >= 2, "two resident shards must halve the shard size: {k2}");
        assert!(k4 >= k2, "more workers, finer shards: {k4} vs {k2}");
        // worker count never changes the "no budget" escape hatch
        assert_eq!(shards_for_budget(&g, 0, 4).unwrap(), 1);
    }

    #[test]
    fn budget_below_one_shard_errors_with_a_hint() {
        let g = synthetic::web_replica(2000, 6, 17);
        // One byte per resident shard cannot hold even single-vertex shards.
        let err = shards_for_budget(&g, 1, 1).unwrap_err().to_string();
        assert!(err.contains("--mem-budget"), "hint names the budget flag: {err}");
        assert!(err.contains("--ooc-workers"), "hint names the worker flag: {err}");
        // A budget that fits sequentially can stop fitting once it is split
        // across workers — the error must surface rather than clamp.
        let per_vertex = g.memory_bytes().div_ceil(g.num_vertices() as u64);
        assert!(shards_for_budget(&g, per_vertex * 2, 1).is_ok());
        let split = shards_for_budget(&g, per_vertex * 2, 64);
        assert!(split.is_err(), "64-way split of a 2-vertex budget must error");
        // workers so large the integer division zeroes the per-shard budget
        let zeroed = shards_for_budget(&g, 3, 8).unwrap_err().to_string();
        assert!(zeroed.contains("no room"), "{zeroed}");
    }

    #[test]
    fn rotation_cap_reports_unconverged() {
        let g = synthetic::web_replica(400, 6, 8);
        let c = PrConfig { max_iterations: 2, ..cfg() };
        let r = run_sharded(&g, &c, 4).unwrap();
        assert!(!r.converged);
        assert!(r.iterations <= 2);
        let rp = run_sharded_workers(&g, &c, 4, 2).unwrap();
        assert!(!rp.converged, "parallel rotation cap must also report unconverged");
        assert!(rp.iterations <= 2);
    }
}
