//! Frontier/delta-scheduled non-blocking PageRank — the first kernel family
//! that changes *what* work a sweep does, not just how sweeps synchronize.
//!
//! The paper's non-blocking variants (Algorithms 3–5) still gather every
//! vertex of a partition on every sweep, long after most ranks have gone
//! quiet. Blanco et al. (*Delayed Asynchronous Iterative Graph Algorithms*,
//! arXiv:2110.01409) observe that asynchronous PageRank converges with the
//! same fixed point when only vertices whose in-neighbourhood changed are
//! re-gathered; Kollias et al. (arXiv:cs/0606047) supply the convergence
//! theory for such partially-updated sweeps. This module implements that
//! schedule on the unified engine:
//!
//! * a lock-free per-vertex dirty bitmap ([`crate::sync::dirty::DirtyFlags`])
//!   holds the active frontier — every vertex starts dirty;
//! * a sweep gathers only the dirty vertices of the worker's partition;
//! * after recomputing `pr(u)`, the worker re-marks `u`'s out-neighbours
//!   only when the rank moved more than the delta threshold since the last
//!   push ([`crate::pagerank::PrConfig::resolved_delta_threshold`]) — the
//!   accumulated-delta test, so many sub-threshold moves cannot silently
//!   drift past the cutoff;
//! * termination reuses the NonBlocking driver's two-consecutive-calm
//!   confirmation machinery: an empty frontier publishes a zero error, and
//!   the run ends only after a confirmation sweep re-validates that every
//!   peer's merged error is calm too (see `engine::driver`).
//!
//! On top of the bitmap substrate sit three scheduling upgrades, all owned
//! by the private [`FrontierScheduler`]:
//!
//! * **Two-phase sweeps** — every sweep first *snapshots* the partition's
//!   dirty set (claiming the bits), then gathers exactly that snapshot in
//!   ascending vertex order; marks generated mid-sweep land in the *next*
//!   sweep. All discovery modes therefore process identical sets in
//!   identical order, which makes a single-threaded run bit-identical
//!   across `--frontier-sched bitmap|worklist|hybrid`.
//! * **Claim-based work-list**
//!   ([`FrontierSched::Worklist`](crate::pagerank::FrontierSched)) — a
//!   marked vertex is also enqueued on its owner partition's lock-free MPMC
//!   ring ([`crate::sync::WorkList`]), and the owner pops instead of
//!   scanning O(n/64) bitmap words. The bitmap stays the ground truth:
//!   enqueue happens only on a clear→set transition, every pop re-validates
//!   with [`DirtyFlags::claim`], and a full ring merely sets an overflow
//!   flag that forces the next sweep back to a bitmap scan. The `hybrid`
//!   mode picks per sweep: scan while the frontier is dense (≥ one vertex
//!   per bitmap word), pop once it is sparse.
//! * **Residual-driven delta autotuning** (`--delta-threshold auto`, the
//!   [`DeltaTuner`]) — the push cutoff starts at the resolved delta
//!   threshold and is retuned geometrically from the observed decay of the
//!   merged residual: a stalling residual tightens the cutoff (more
//!   propagation), fast decay loosens it (less work), clamped to
//!   `[threshold/100, threshold*10]` so the un-propagated residual bound
//!   `delta / (1 - d)` stays far inside the 1e-6-vs-Barrier budget.
//!
//! Two kernels share the schedule:
//!
//! * [`Variant::Frontier`](crate::pagerank::Variant::Frontier) — pull model:
//!   a dirty vertex re-reads its in-neighbours' ranks directly;
//! * [`Variant::FrontierPcpm`](crate::pagerank::Variant::FrontierPcpm) —
//!   PCPM propagation: a changed vertex scatters its contribution into the
//!   compressed [`CompressedBins`] value stream — one streaming store per
//!   `(vertex, destination partition)` group, not per edge — and a dirty
//!   vertex gathers by summing the value slots its in-edges map to
//!   ([`CompressedBins::in_value_slots`]). Unlike `Variant::Pcpm`, which
//!   rescatters every contribution every iteration, only *changed* vertices
//!   write — the delta schedule applied to the scatter phase. The per-edge
//!   baseline layout (`--pcpm-layout slots`) runs through the same code
//!   path with a one-slot-per-edge value stream.

use crate::engine::{inv_out_degrees, Kernel, SyncMode, WorkerCtx};
use crate::graph::{CompressedBins, Csr, Partitions, VertexId};
use crate::pagerank::{amplify_work, FrontierSched, PcpmLayout, PrConfig};
use crate::sync::atomics::{atomic_vec, atomic_vec_from, snapshot, AtomicF64};
use crate::sync::dirty::DirtyFlags;
use crate::sync::WorkList;
use anyhow::{ensure, Result};
use crate::sync::shim::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

/// `last_mode` sentinel values for the per-partition switch telemetry.
const MODE_SCAN: u8 = 0;
const MODE_QUEUE: u8 = 1;
const MODE_UNSET: u8 = 2;

/// Frontier discovery for one run: the dirty bitmap (ground truth), the
/// optional per-partition work-list rings, and the per-sweep mode choice.
///
/// Every sweep is two-phase: `sweep` first collects the start-of-sweep
/// snapshot of the partition's dirty vertices (claiming their bits), sorts
/// it ascending, and only then hands each vertex to the kernel's gather
/// body. Marks issued during the sweep — including marks into the sweeping
/// partition itself — land in the *next* sweep. That invariant is what
/// makes the three discovery modes interchangeable: they may differ in how
/// the snapshot is *found* (scan vs pop) but never in which vertices it
/// contains or in what order they are gathered.
///
/// **Concurrency contract.** `sweep(tid, …)` may be called from different
/// threads *concurrently for distinct `tid`s* — this is how both the
/// non-blocking driver (one worker per partition) and the parallel
/// out-of-core coordinator (K workers claiming disjoint shards,
/// [`crate::engine::ooc`]) share one kernel. Everything a sweep touches is
/// either owned per-`tid` (the scratch buffer behind its own mutex, the
/// partition's ring, its overflow/mode slots) or lock-free and shared (the
/// dirty bitmap's claim/drain, `mark` into any partition's ring). Two
/// concurrent sweeps of the *same* `tid` are serialized by the scratch
/// mutex but would split the partition's snapshot between them — callers
/// must not do that, and none do.
struct FrontierScheduler {
    sched: FrontierSched,
    /// Shared so an external scheduler (the out-of-core coordinator) can
    /// probe the frontier without owning the kernel.
    dirty: Arc<DirtyFlags>,
    parts: Partitions,
    /// One ring per partition; empty in bitmap mode.
    queues: Vec<WorkList>,
    /// Sticky per-partition "scan next sweep" flags. Initialized `true` so
    /// the first sweep always scans — that is how externally seeded bits
    /// (cold start's `new_set`, the incremental path's `seed_frontier`)
    /// enter the schedule without ever having been enqueued.
    overflow: Vec<AtomicBool>,
    /// Last discovery mode per partition (scan/queue/unset), for the
    /// mode-switch telemetry.
    last_mode: Vec<AtomicU8>,
    switches: AtomicU64,
    /// Per-partition snapshot buffers, reused across sweeps. Each worker
    /// only ever locks its own slot, so the mutexes are uncontended.
    scratch: Vec<Mutex<Vec<VertexId>>>,
}

impl FrontierScheduler {
    fn new(sched: FrontierSched, dirty: Arc<DirtyFlags>, parts: Partitions) -> Self {
        let p = parts.count();
        let queues = if sched == FrontierSched::Bitmap {
            Vec::new()
        } else {
            (0..p)
                .map(|i| {
                    let r = parts.range(i);
                    let len = (r.end - r.start) as usize;
                    // Deliberately undersized (a quarter of the partition):
                    // a dense frontier overflows into the bitmap scan —
                    // which is cheaper than popping most of the partition
                    // through a ring anyway — and the ring serves the
                    // sparse tail it exists for.
                    WorkList::with_capacity((len / 4).max(1).next_power_of_two().clamp(64, 65_536))
                })
                .collect()
        };
        Self {
            sched,
            dirty,
            parts,
            queues,
            overflow: (0..p).map(|_| AtomicBool::new(true)).collect(),
            last_mode: (0..p).map(|_| AtomicU8::new(MODE_UNSET)).collect(),
            switches: AtomicU64::new(0),
            scratch: (0..p).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    /// Mark `w` dirty. The bitmap transition is the dedup guard: only the
    /// marker that flips the bit clear→set may enqueue, so a vertex sits in
    /// its owner's ring at most once per sweep. A full ring degrades to a
    /// sticky scan flag — the bit is already set, nothing is lost.
    fn mark(&self, w: VertexId) {
        if self.dirty.set(w) && self.sched != FrontierSched::Bitmap {
            let p = self.parts.owner(w);
            if !self.queues[p].push(w) {
                // relaxed: sticky flag; the AcqRel bitmap set() above is the
                // publication edge, the flag only biases the next sweep's mode
                self.overflow[p].store(true, Ordering::Relaxed);
            }
        }
    }

    /// One two-phase sweep of partition `tid`: snapshot the dirty set,
    /// gather it in ascending order through `f`, return the gather count.
    fn sweep(&self, tid: usize, mut f: impl FnMut(VertexId)) -> u64 {
        let range = self.parts.range(tid);
        let mut batch = self.scratch[tid].lock().unwrap();
        batch.clear();
        let mut scanned = self.sched == FrontierSched::Bitmap;
        if scanned {
            self.dirty.drain_range(range, |v| batch.push(v));
        } else {
            let q = &self.queues[tid];
            // Entries pushed after this point belong to the next sweep; the
            // ring is FIFO, so bounding the pop count by the start-of-sweep
            // occupancy leaves them untouched.
            let occupancy = q.len();
            let part_len = (range.end - range.start) as usize;
            // relaxed: mode hint only; a missed flag is recovered by the
            // empty-batch any_in_range safety net below
            scanned = self.overflow[tid].swap(false, Ordering::Relaxed)
                || (self.sched == FrontierSched::Hybrid
                    && occupancy * 64 >= part_len.max(1));
            for _ in 0..occupancy {
                let Some(v) = q.pop() else { break };
                // Re-validate against the bitmap: a stale entry (its bit
                // already claimed by an overflow scan) is skipped, never
                // double-gathered.
                if self.dirty.claim(v) {
                    batch.push(v);
                }
            }
            if scanned {
                self.dirty.drain_range(range.clone(), |v| batch.push(v));
            } else if batch.is_empty() && self.dirty.any_in_range(range.clone()) {
                // Safety net: bits the rings lost track of (marks racing an
                // overflow hand-off) are recovered by a full scan, so a
                // dirty vertex can never be starved past this sweep.
                scanned = true;
                self.dirty.drain_range(range, |v| batch.push(v));
            }
            batch.sort_unstable();
            // A vertex claimed off the ring can be re-marked by a racing
            // worker and then claimed again by a same-sweep overflow drain.
            // The re-mark's delta is still covered by this sweep's single
            // gather, so collapse the duplicate to keep the once-per-sweep
            // invariant (and `vertex_updates`) honest.
            batch.dedup();
        }
        let mode = if scanned { MODE_SCAN } else { MODE_QUEUE };
        // relaxed: telemetry only (mode-switch counter)
        if self.last_mode[tid].swap(mode, Ordering::Relaxed) != mode {
            self.switches.fetch_add(1, Ordering::Relaxed);
        }
        for &v in batch.iter() {
            f(v);
        }
        batch.len() as u64
    }

    /// Telemetry: `(mode switches, peak ring occupancy)`. The switch count
    /// includes each partition's initial entry into its first mode.
    fn stats(&self) -> (u64, u64) {
        let peak = self.queues.iter().map(WorkList::peak).max().unwrap_or(0);
        // relaxed: telemetry only
        (self.switches.load(Ordering::Relaxed), peak)
    }
}

/// The frontier push cutoff: either the fixed resolved threshold or the
/// residual-driven autotuner behind `--delta-threshold auto`.
enum DeltaCutoff {
    Fixed(f64),
    Auto(DeltaTuner),
}

impl DeltaCutoff {
    fn from_cfg(cfg: &PrConfig) -> Self {
        if cfg.delta_auto {
            DeltaCutoff::Auto(DeltaTuner::new(cfg))
        } else {
            DeltaCutoff::Fixed(cfg.resolved_delta_threshold())
        }
    }

    /// Cutoff to use for the current sweep (read once per sweep so one
    /// sweep applies one consistent cutoff).
    fn get(&self) -> f64 {
        match self {
            DeltaCutoff::Fixed(d) => *d,
            DeltaCutoff::Auto(t) => t.current(),
        }
    }

    /// Feed one merged-residual observation to the autotuner (no-op for a
    /// fixed cutoff).
    fn observe(&self, err: f64) {
        if let DeltaCutoff::Auto(t) = self {
            t.observe(err);
        }
    }
}

/// Residual-decay-driven retuning of the push cutoff (Blanco et al.'s
/// delayed-async schedule, applied to the accumulated-delta test).
///
/// The driver feeds every worker's view of the *merged* error through
/// [`Kernel::converged`] once per sweep; the tuner samples one observation
/// per round (`period` = worker count) and compares it with the previous
/// sample. A residual that failed to decay by at least 10% means the
/// schedule is starving propagation — the cutoff halves. A decaying
/// residual earns a 1.25× loosening. Both moves are clamped to
/// `[threshold/100, threshold*10]`: the upper bound keeps the per-vertex
/// un-propagated residual below `10·threshold / (1 - d)`, comfortably
/// inside the 1e-6-vs-Barrier equivalence budget at the default
/// threshold, and the lower bound stops the schedule degenerating into
/// plain NoSync. With one thread the sampling is deterministic.
struct DeltaTuner {
    /// Current cutoff, as `f64::to_bits` (atomically retuned).
    delta_bits: AtomicU64,
    /// Previous sampled residual (`f64::to_bits`; +inf until first sample).
    prev_err_bits: AtomicU64,
    calls: AtomicU64,
    period: u64,
    lo: f64,
    hi: f64,
}

impl DeltaTuner {
    fn new(cfg: &PrConfig) -> Self {
        let lo = cfg.threshold * 0.01;
        let hi = cfg.threshold * 10.0;
        let start = cfg.resolved_delta_threshold().clamp(lo, hi);
        Self {
            delta_bits: AtomicU64::new(start.to_bits()),
            prev_err_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            calls: AtomicU64::new(0),
            period: cfg.threads.max(1) as u64,
            lo,
            hi,
        }
    }

    fn current(&self) -> f64 {
        // relaxed: any recent cutoff is valid; sweeps read it once
        f64::from_bits(self.delta_bits.load(Ordering::Relaxed))
    }

    fn observe(&self, err: f64) {
        if !err.is_finite() {
            return;
        }
        // relaxed: the tuner is a heuristic — a torn-ordering observation at
        // worst delays one retune step, never affects convergence tests
        let tick = self.calls.fetch_add(1, Ordering::Relaxed);
        if tick % self.period != 0 {
            return;
        }
        // relaxed: heuristic state only, same contract as `calls` above
        let prev = f64::from_bits(self.prev_err_bits.swap(err.to_bits(), Ordering::Relaxed));
        if !prev.is_finite() || prev <= 0.0 || err <= 0.0 {
            // Zero residuals are confirmation sweeps — nothing to learn.
            return;
        }
        let cur = self.current();
        let next = if err >= prev * 0.9 {
            (cur * 0.5).max(self.lo) // stalled: push harder
        } else {
            (cur * 1.25).min(self.hi) // decaying: prune harder
        };
        // relaxed: see observe() header — heuristic state only
        self.delta_bits.store(next.to_bits(), Ordering::Relaxed);
    }
}

/// Pull-model frontier kernel: a dirty vertex re-reads its in-neighbours'
/// ranks directly. See the module docs for the schedule.
pub struct FrontierKernel<'g> {
    g: &'g Csr,
    inv_out: Vec<f64>,
    pr: Vec<AtomicF64>,
    /// Rank value each vertex last propagated to its out-neighbours; the
    /// push test compares against this (not the previous gather) so that
    /// many sub-delta moves accumulate into a push instead of drifting.
    last_pushed: Vec<AtomicF64>,
    sched: FrontierScheduler,
    delta: DeltaCutoff,
    base: f64,
    d: f64,
    work_amplify: u32,
}

/// Registry builder for [`Variant::Frontier`](crate::pagerank::Variant):
/// cold start — uniform ranks, every vertex dirty.
pub fn kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    let n = g.num_vertices();
    let init = vec![1.0 / n as f64; n];
    warm_kernel(g, cfg, parts, &init, DirtyFlags::new_set(n))
}

/// Warm-start builder for the incremental path
/// ([`crate::engine::incremental`]): ranks resume from `warm` and only the
/// vertices set in `dirty` are re-gathered. `last_pushed` is seeded from
/// `warm` too — an undisturbed vertex has, by construction, already
/// propagated its warm value, so it must not re-push until its rank
/// actually moves past the delta threshold.
pub fn warm_kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
    warm: &[f64],
    dirty: DirtyFlags,
) -> Result<Box<dyn Kernel + 'g>> {
    let n = g.num_vertices();
    ensure!(warm.len() == n, "warm rank vector length {} != n {}", warm.len(), n);
    ensure!(dirty.len() == n, "dirty bitmap length {} != n {}", dirty.len(), n);
    Ok(Box::new(FrontierKernel {
        g,
        inv_out: inv_out_degrees(g),
        pr: atomic_vec_from(warm),
        last_pushed: atomic_vec_from(warm),
        sched: FrontierScheduler::new(cfg.frontier_sched, Arc::new(dirty), parts.clone()),
        delta: DeltaCutoff::from_cfg(cfg),
        base: (1.0 - cfg.damping) / n as f64,
        d: cfg.damping,
        work_amplify: cfg.work_amplify,
    }))
}

impl Kernel for FrontierKernel<'_> {
    fn sync_mode(&self) -> SyncMode {
        SyncMode::NonBlocking
    }

    fn frontier_scheduled(&self) -> bool {
        true
    }

    /// One two-phase sweep over this partition's *dirty* vertices only.
    fn gather(&self, ctx: &WorkerCtx<'_>) -> f64 {
        let delta = self.delta.get();
        let mut local_err: f64 = 0.0;
        let mut edges = 0u64;
        let gathered = self.sched.sweep(ctx.tid, |u| {
            let ui = u as usize;
            let previous = self.pr[ui].load();
            let mut tmp = 0.0;
            for &v in self.g.in_neighbors(u) {
                // SAFETY: CSR validation bounds every endpoint by n
                // (= pr.len() = inv_out.len()), as in the NoSync kernel.
                tmp += unsafe {
                    self.pr.get_unchecked(v as usize).load()
                        * self.inv_out.get_unchecked(v as usize)
                };
                amplify_work(self.work_amplify);
            }
            edges += self.g.in_degree(u) as u64;
            let new = self.base + self.d * tmp;
            self.pr[ui].store(new);
            local_err = local_err.max((new - previous).abs());
            if (new - self.last_pushed[ui].load()).abs() > delta {
                self.last_pushed[ui].store(new);
                for &w in self.g.out_neighbors(u) {
                    self.sched.mark(w);
                }
            }
        });
        if gathered > 0 {
            ctx.metrics.add_gathered(ctx.tid, gathered);
            ctx.metrics.add_edges(ctx.tid, edges);
        }
        local_err
    }

    fn converged(&self, global_err: f64, threshold: f64) -> bool {
        self.delta.observe(global_err);
        global_err <= threshold
    }

    fn first_touch(&self, tid: usize) {
        let mut acc = 0.0;
        for u in self.sched.parts.range(tid) {
            let ui = u as usize;
            acc += self.pr[ui].load() + self.last_pushed[ui].load() + self.inv_out[ui];
        }
        std::hint::black_box(acc);
    }

    fn frontier_stats(&self) -> (u64, u64) {
        self.sched.stats()
    }

    fn ranks(&self) -> Vec<f64> {
        snapshot(&self.pr)
    }
}

/// PCPM-propagation frontier kernel: changed vertices scatter their
/// contribution into the compressed value stream; dirty vertices gather
/// from it. See the module docs for the schedule.
pub struct FrontierPcpmKernel<'g> {
    g: &'g Csr,
    bins: CompressedBins,
    /// In-edge slot (index into the CSR in-edge array) → value-stream slot,
    /// so a dirty vertex can gather its in-contributions straight from the
    /// value stream.
    in_slots: Vec<usize>,
    inv_out: Vec<f64>,
    pr: Vec<AtomicF64>,
    /// Contribution value stream, grouped by (src, dst) partition — one
    /// slot per value group (per edge under the `slots` baseline layout).
    values: Vec<AtomicF64>,
    last_pushed: Vec<AtomicF64>,
    sched: FrontierScheduler,
    delta: DeltaCutoff,
    base: f64,
    d: f64,
    work_amplify: u32,
}

/// Registry builder for
/// [`Variant::FrontierPcpm`](crate::pagerank::Variant::FrontierPcpm):
/// cold start — uniform ranks, every vertex dirty.
pub fn pcpm_kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    let n = g.num_vertices();
    let init = vec![1.0 / n as f64; n];
    warm_pcpm_kernel(g, cfg, parts, &init, DirtyFlags::new_set(n))
}

/// Warm-start builder for the PCPM frontier kernel. The
/// [`CompressedBins`] scatter plan is rebuilt against the (possibly
/// mutated) CSR, and **every** value slot is re-seeded with its source's
/// warm contribution `warm[u] / outdeg(u)` — vertices outside the seeded
/// frontier never re-scatter, so the whole grid must already be consistent
/// with the warm ranks before the first sweep.
pub fn warm_pcpm_kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
    warm: &[f64],
    dirty: DirtyFlags,
) -> Result<Box<dyn Kernel + 'g>> {
    warm_pcpm_kernel_shared(g, cfg, parts, warm, Arc::new(dirty))
}

/// Like [`warm_pcpm_kernel`], but the dirty bitmap arrives pre-wrapped in an
/// [`Arc`] and the caller keeps a clone. This is the out-of-core
/// coordinator's hook ([`crate::engine::ooc`]): it probes the shared bitmap
/// with [`DirtyFlags::any_in_range`] to decide which shard to sweep next and
/// when the run has drained, while the kernel drains and re-marks through
/// the very same bits.
///
/// The returned kernel is safe to *share across concurrently sweeping
/// threads* as long as no two threads gather the same partition index at
/// once (the scheduler's concurrency contract): `gather(ctx)` writes ranks
/// and `last_pushed` only inside partition `ctx.tid`'s vertex range, every
/// cross-partition effect goes through the atomic value stream and the
/// lock-free bitmap/ring `mark`, and the per-partition scratch is behind
/// its own mutex. The parallel out-of-core coordinator relies on exactly
/// this to sweep K disjoint shards at a time through one kernel.
pub fn warm_pcpm_kernel_shared<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
    warm: &[f64],
    dirty: Arc<DirtyFlags>,
) -> Result<Box<dyn Kernel + 'g>> {
    let n = g.num_vertices();
    ensure!(warm.len() == n, "warm rank vector length {} != n {}", warm.len(), n);
    ensure!(dirty.len() == n, "dirty bitmap length {} != n {}", dirty.len(), n);
    let inv_out = inv_out_degrees(g);
    let bins = match cfg.pcpm_layout {
        PcpmLayout::Compressed => CompressedBins::new(g, parts),
        PcpmLayout::Slots => CompressedBins::new_per_edge(g, parts),
    };
    let in_slots = bins.in_value_slots(g, parts);
    let values = atomic_vec(bins.num_values(), 0.0);
    for u in 0..n as VertexId {
        let contribution = warm[u as usize] * inv_out[u as usize];
        for &slot in bins.push_slots(u) {
            values[slot].store(contribution);
        }
    }
    Ok(Box::new(FrontierPcpmKernel {
        g,
        in_slots,
        inv_out,
        pr: atomic_vec_from(warm),
        values,
        bins,
        last_pushed: atomic_vec_from(warm),
        sched: FrontierScheduler::new(cfg.frontier_sched, dirty, parts.clone()),
        delta: DeltaCutoff::from_cfg(cfg),
        base: (1.0 - cfg.damping) / n as f64,
        d: cfg.damping,
        work_amplify: cfg.work_amplify,
    }))
}

impl Kernel for FrontierPcpmKernel<'_> {
    fn sync_mode(&self) -> SyncMode {
        SyncMode::NonBlocking
    }

    fn frontier_scheduled(&self) -> bool {
        true
    }

    /// One two-phase sweep over the partition's dirty vertices, gathering
    /// from the value stream and scattering changed contributions back
    /// through it (one store per value group — the compressed delta push).
    fn gather(&self, ctx: &WorkerCtx<'_>) -> f64 {
        let delta = self.delta.get();
        let mut local_err: f64 = 0.0;
        let mut edges = 0u64;
        let gathered = self.sched.sweep(ctx.tid, |u| {
            let ui = u as usize;
            let previous = self.pr[ui].load();
            let mut tmp = 0.0;
            for s in self.g.in_slot_range(u) {
                tmp += self.values[self.in_slots[s]].load();
                amplify_work(self.work_amplify);
            }
            edges += self.g.in_degree(u) as u64;
            let new = self.base + self.d * tmp;
            self.pr[ui].store(new);
            local_err = local_err.max((new - previous).abs());
            if (new - self.last_pushed[ui].load()).abs() > delta
                && self.g.out_degree(u) > 0
            {
                self.last_pushed[ui].store(new);
                let contribution = new * self.inv_out[ui];
                for &slot in self.bins.push_slots(u) {
                    self.values[slot].store(contribution);
                }
                for &w in self.g.out_neighbors(u) {
                    self.sched.mark(w);
                }
            }
        });
        if gathered > 0 {
            ctx.metrics.add_gathered(ctx.tid, gathered);
            ctx.metrics.add_edges(ctx.tid, edges);
        }
        local_err
    }

    fn converged(&self, global_err: f64, threshold: f64) -> bool {
        self.delta.observe(global_err);
        global_err <= threshold
    }

    fn first_touch(&self, tid: usize) {
        let mut acc = 0.0;
        for u in self.sched.parts.range(tid) {
            let ui = u as usize;
            acc += self.pr[ui].load() + self.last_pushed[ui].load() + self.inv_out[ui];
            for &slot in self.bins.push_slots(u) {
                acc += self.values[slot].load();
            }
        }
        std::hint::black_box(acc);
    }

    fn frontier_stats(&self) -> (u64, u64) {
        self.sched.stats()
    }

    fn ranks(&self) -> Vec<f64> {
        snapshot(&self.pr)
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::{synthetic, GraphBuilder, PartitionPolicy};
    use crate::pagerank::{
        self, convergence, seq, FrontierSched, PcpmLayout, PrConfig, Variant,
    };

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    const BOTH: [Variant; 2] = [Variant::Frontier, Variant::FrontierPcpm];

    #[test]
    fn matches_sequential_on_fixture_families() {
        let c = cfg(3);
        for g in [
            synthetic::cycle(60),
            synthetic::chain(60),
            synthetic::star(60),
            synthetic::complete(20),
            synthetic::web_replica(700, 6, 19),
        ] {
            let (sr, _, _) = seq::solve(&g, &c);
            for v in BOTH {
                let r = pagerank::run(&g, v, &c).unwrap();
                assert!(r.converged, "{v} on {} did not converge", g.name);
                let l1 = r.l1_norm(&sr);
                assert!(l1 < 1e-7, "{v} on {}: l1 {l1}", g.name);
            }
        }
    }

    #[test]
    fn empty_graph_terminates_immediately() {
        let g = GraphBuilder::new(0).build("nil");
        for v in BOTH {
            let r = pagerank::run(&g, v, &cfg(4)).unwrap();
            assert!(r.converged, "{v}");
            assert!(r.ranks.is_empty(), "{v}");
            assert_eq!(r.vertex_updates, 0, "{v}");
        }
    }

    #[test]
    fn single_dangling_vertex_converges_in_one_update() {
        // One vertex, no edges: pr = (1-d)/1 after a single gather; the
        // frontier is empty afterwards and only the confirmation sweeps
        // remain.
        let g = synthetic::chain(1);
        for v in BOTH {
            let r = pagerank::run(&g, v, &cfg(2)).unwrap();
            assert!(r.converged, "{v}");
            assert!((r.ranks[0] - 0.15).abs() < 1e-12, "{v}: {}", r.ranks[0]);
            assert_eq!(r.vertex_updates, 1, "{v} must gather exactly once");
        }
    }

    /// The confirmation-sweep edge case: on a long chain the downstream
    /// partitions' frontiers drain long before rank mass has propagated from
    /// upstream. Workers must keep re-validating (empty frontier ⇒ calm
    /// sweep, but the merged error stays hot) instead of exiting early with
    /// stale ranks.
    #[test]
    fn drained_frontier_waits_for_global_convergence() {
        let g = synthetic::chain(400);
        let c = cfg(4);
        let (sr, _, _) = seq::solve(&g, &c);
        for v in BOTH {
            let r = pagerank::run(&g, v, &c).unwrap();
            assert!(r.converged, "{v}");
            let linf = convergence::linf_norm(&r.ranks, &sr);
            assert!(linf < 1e-10, "{v} exited before the chain settled: linf {linf}");
        }
    }

    #[test]
    fn more_threads_than_vertices() {
        let g = synthetic::cycle(3);
        for v in BOTH {
            let r = pagerank::run(&g, v, &cfg(8)).unwrap();
            assert!(r.converged, "{v}");
            let (sr, _, _) = seq::solve(&g, &cfg(8));
            assert!(r.l1_norm(&sr) < 1e-9, "{v}");
        }
    }

    #[test]
    fn edge_balanced_partitioning_also_correct() {
        let g = synthetic::web_replica(600, 7, 5);
        let c = PrConfig { partition: PartitionPolicy::EdgeBalanced, ..cfg(4) };
        let (sr, _, _) = seq::solve(&g, &c);
        for v in BOTH {
            let r = pagerank::run(&g, v, &c).unwrap();
            assert!(r.converged, "{v}");
            assert!(r.l1_norm(&sr) < 1e-7, "{v}: l1 {}", r.l1_norm(&sr));
        }
    }

    /// A coarser delta threshold trades accuracy for fewer vertex updates —
    /// the ablation knob behind `--delta-threshold`.
    #[test]
    fn coarse_delta_threshold_gathers_less() {
        let g = synthetic::web_replica(900, 6, 23);
        let tight = PrConfig { threshold: 1e-10, ..cfg(4) };
        let coarse = PrConfig { delta_threshold: 1e-6, ..tight.clone() };
        let fine = pagerank::run(&g, Variant::Frontier, &tight).unwrap();
        let rough = pagerank::run(&g, Variant::Frontier, &coarse).unwrap();
        assert!(fine.converged && rough.converged);
        assert!(
            rough.vertex_updates <= fine.vertex_updates,
            "coarse delta did more work: {} > {}",
            rough.vertex_updates,
            fine.vertex_updates
        );
        // still a sane approximation: un-pushed residual is bounded by
        // delta / (1 - d) per vertex
        let (sr, _, _) = seq::solve(&g, &tight);
        assert!(rough.l1_norm(&sr) < 1e-1, "l1 {}", rough.l1_norm(&sr));
    }

    /// Both value-stream layouts (compressed groups and the per-edge
    /// baseline) must land on the sequential fixed point — the delta
    /// schedule only changes how many stores a push issues, not what a
    /// gather sums.
    #[test]
    fn pcpm_layouts_both_converge() {
        let g = synthetic::web_replica(700, 6, 31);
        let base = cfg(4);
        let (sr, _, _) = seq::solve(&g, &base);
        for layout in [PcpmLayout::Compressed, PcpmLayout::Slots] {
            let c = PrConfig { pcpm_layout: layout, ..base.clone() };
            let r = pagerank::run(&g, Variant::FrontierPcpm, &c).unwrap();
            assert!(r.converged, "{layout}");
            let l1 = r.l1_norm(&sr);
            assert!(l1 < 1e-7, "{layout}: l1 {l1}");
        }
    }

    #[test]
    fn iteration_cap_reports_unconverged() {
        let g = synthetic::web_replica(400, 6, 8);
        let c = PrConfig { max_iterations: 2, ..cfg(2) };
        for v in BOTH {
            let r = pagerank::run(&g, v, &c).unwrap();
            assert!(!r.converged, "{v}");
        }
    }

    /// The two-phase invariant made concrete: with one thread, every
    /// discovery mode must gather identical vertex sets in identical order
    /// — bit-identical ranks and exactly equal update counts.
    #[test]
    fn scheduler_modes_are_bit_identical_single_threaded() {
        let g = synthetic::web_replica(500, 6, 11);
        let base = cfg(1);
        for v in BOTH {
            let bitmap = pagerank::run(&g, v, &base).unwrap();
            assert!(bitmap.converged, "{v}/bitmap");
            for sched in [FrontierSched::Worklist, FrontierSched::Hybrid] {
                let c = PrConfig { frontier_sched: sched, ..base.clone() };
                let r = pagerank::run(&g, v, &c).unwrap();
                assert!(r.converged, "{v}/{sched}");
                assert_eq!(r.ranks, bitmap.ranks, "{v}/{sched}: ranks diverged");
                assert_eq!(
                    r.vertex_updates, bitmap.vertex_updates,
                    "{v}/{sched}: update counts diverged"
                );
            }
        }
    }

    /// Multi-threaded work-list and hybrid runs stay on the fixed point.
    #[test]
    fn scheduler_modes_converge_multi_threaded() {
        let g = synthetic::web_replica(800, 6, 29);
        let base = cfg(4);
        let (sr, _, _) = seq::solve(&g, &base);
        for v in BOTH {
            for sched in [FrontierSched::Worklist, FrontierSched::Hybrid] {
                let c = PrConfig { frontier_sched: sched, ..base.clone() };
                let r = pagerank::run(&g, v, &c).unwrap();
                assert!(r.converged, "{v}/{sched}");
                assert!(r.l1_norm(&sr) < 1e-7, "{v}/{sched}: l1 {}", r.l1_norm(&sr));
            }
        }
    }

    /// `--delta-threshold auto`: the tuner must stay inside its clamp band,
    /// converge, and land on the same fixed point.
    #[test]
    fn auto_delta_converges_on_the_fixed_point() {
        let g = synthetic::web_replica(800, 6, 17);
        let base = cfg(4);
        let (sr, _, _) = seq::solve(&g, &base);
        for v in BOTH {
            let c = PrConfig { delta_auto: true, ..base.clone() };
            let r = pagerank::run(&g, v, &c).unwrap();
            assert!(r.converged, "{v}/auto");
            assert!(r.l1_norm(&sr) < 1e-7, "{v}/auto: l1 {}", r.l1_norm(&sr));
        }
    }

    /// A ring far smaller than the frontier must degrade to bitmap scans,
    /// never lose marks: tiny partitions on a dense graph exercise the
    /// overflow flag and the claim re-validation path.
    #[test]
    fn ring_overflow_degrades_to_scan_without_losing_marks() {
        // 3000 vertices on 2 threads: partitions of 1500, rings of 512 —
        // the dense early frontiers overflow every sweep, the sparse tail
        // flows through the rings, and the claim re-validation has to drop
        // entries a scan already gathered.
        let g = synthetic::web_replica(3_000, 8, 41);
        let c = PrConfig { frontier_sched: FrontierSched::Worklist, ..cfg(2) };
        let (sr, _, _) = seq::solve(&g, &c);
        let r = pagerank::run(&g, Variant::Frontier, &c).unwrap();
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-7, "l1 {}", r.l1_norm(&sr));
    }

    /// The kernel-sharing contract the parallel out-of-core coordinator
    /// leans on: one `warm_pcpm_kernel_shared` kernel, gathered concurrently
    /// by one thread per *distinct* partition, must drain the frontier and
    /// land on the sequential fixed point — no lost marks, no torn state.
    #[test]
    fn shared_kernel_survives_concurrent_disjoint_sweeps() {
        use super::warm_pcpm_kernel_shared;
        use crate::coordinator::metrics::RunMetrics;
        use crate::engine::WorkerCtx;
        use crate::graph::Partitions;
        use crate::sync::dirty::DirtyFlags;
        use std::sync::Arc;

        let g = synthetic::web_replica(900, 5, 33);
        let c = cfg(4);
        let (sr, _, _) = seq::solve(&g, &c);
        let n = g.num_vertices();
        let shards = 4usize;
        let parts = Partitions::new(&g, shards, c.partition);
        let dirty = Arc::new(DirtyFlags::new_set(n));
        let warm = vec![1.0 / n as f64; n];
        let kernel =
            warm_pcpm_kernel_shared(&g, &c, &parts, &warm, Arc::clone(&dirty)).unwrap();
        let metrics = RunMetrics::new(shards);
        let mut converged = false;
        for _ in 0..c.max_iterations {
            // one rotation: every shard swept concurrently, then a
            // quiescent probe (no sweep in flight once the scope joins)
            std::thread::scope(|s| {
                for tid in 0..shards {
                    let kernel = &kernel;
                    let metrics = &metrics;
                    s.spawn(move || {
                        kernel.gather(&WorkerCtx { tid, metrics });
                    });
                }
            });
            if dirty.count_set() == 0 {
                converged = true;
                break;
            }
        }
        assert!(converged, "concurrent disjoint sweeps must drain the frontier");
        let ranks = kernel.ranks();
        let l1: f64 = ranks.iter().zip(&sr).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 < 1e-7, "l1 vs sequential {l1}");
        assert!(metrics.total_gathered() > 0);
    }
}
