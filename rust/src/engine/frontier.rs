//! Frontier/delta-scheduled non-blocking PageRank — the first kernel family
//! that changes *what* work a sweep does, not just how sweeps synchronize.
//!
//! The paper's non-blocking variants (Algorithms 3–5) still gather every
//! vertex of a partition on every sweep, long after most ranks have gone
//! quiet. Blanco et al. (*Delayed Asynchronous Iterative Graph Algorithms*,
//! arXiv:2110.01409) observe that asynchronous PageRank converges with the
//! same fixed point when only vertices whose in-neighbourhood changed are
//! re-gathered; Kollias et al. (arXiv:cs/0606047) supply the convergence
//! theory for such partially-updated sweeps. This module implements that
//! schedule on the unified engine:
//!
//! * a lock-free per-vertex dirty bitmap ([`crate::sync::dirty::DirtyFlags`])
//!   holds the active frontier — every vertex starts dirty;
//! * a sweep drains only the dirty vertices of the worker's partition
//!   (claim-per-word `fetch_and`, so concurrent re-marks are never lost);
//! * after recomputing `pr(u)`, the worker re-marks `u`'s out-neighbours
//!   only when the rank moved more than the delta threshold since the last
//!   push ([`crate::pagerank::PrConfig::resolved_delta_threshold`]) — the
//!   accumulated-delta test, so many sub-threshold moves cannot silently
//!   drift past the cutoff;
//! * termination reuses the NonBlocking driver's two-consecutive-calm
//!   confirmation machinery: an empty frontier publishes a zero error, and
//!   the run ends only after a confirmation sweep re-validates that every
//!   peer's merged error is calm too (see `engine::driver`).
//!
//! Two kernels share the schedule:
//!
//! * [`Variant::Frontier`](crate::pagerank::Variant::Frontier) — pull model:
//!   a dirty vertex re-reads its in-neighbours' ranks directly;
//! * [`Variant::FrontierPcpm`](crate::pagerank::Variant::FrontierPcpm) —
//!   PCPM propagation: a changed vertex scatters its contribution into the
//!   compressed [`CompressedBins`] value stream — one streaming store per
//!   `(vertex, destination partition)` group, not per edge — and a dirty
//!   vertex gathers by summing the value slots its in-edges map to
//!   ([`CompressedBins::in_value_slots`]). Unlike `Variant::Pcpm`, which
//!   rescatters every contribution every iteration, only *changed* vertices
//!   write — the delta schedule applied to the scatter phase. The per-edge
//!   baseline layout (`--pcpm-layout slots`) runs through the same code
//!   path with a one-slot-per-edge value stream.

use crate::engine::{inv_out_degrees, Kernel, SyncMode, WorkerCtx};
use crate::graph::{CompressedBins, Csr, Partitions, VertexId};
use crate::pagerank::{amplify_work, PcpmLayout, PrConfig};
use crate::sync::atomics::{atomic_vec, atomic_vec_from, snapshot, AtomicF64};
use crate::sync::dirty::DirtyFlags;
use anyhow::{ensure, Result};
use std::sync::Arc;

/// Pull-model frontier kernel: a dirty vertex re-reads its in-neighbours'
/// ranks directly. See the module docs for the schedule.
pub struct FrontierKernel<'g> {
    g: &'g Csr,
    parts: Partitions,
    inv_out: Vec<f64>,
    pr: Vec<AtomicF64>,
    /// Rank value each vertex last propagated to its out-neighbours; the
    /// push test compares against this (not the previous gather) so that
    /// many sub-delta moves accumulate into a push instead of drifting.
    last_pushed: Vec<AtomicF64>,
    /// Shared so an external scheduler (the out-of-core coordinator) can
    /// probe the frontier without owning the kernel.
    dirty: Arc<DirtyFlags>,
    delta: f64,
    base: f64,
    d: f64,
    work_amplify: u32,
}

/// Registry builder for [`Variant::Frontier`](crate::pagerank::Variant):
/// cold start — uniform ranks, every vertex dirty.
pub fn kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    let n = g.num_vertices();
    let init = vec![1.0 / n as f64; n];
    warm_kernel(g, cfg, parts, &init, DirtyFlags::new_set(n))
}

/// Warm-start builder for the incremental path
/// ([`crate::engine::incremental`]): ranks resume from `warm` and only the
/// vertices set in `dirty` are re-gathered. `last_pushed` is seeded from
/// `warm` too — an undisturbed vertex has, by construction, already
/// propagated its warm value, so it must not re-push until its rank
/// actually moves past the delta threshold.
pub fn warm_kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
    warm: &[f64],
    dirty: DirtyFlags,
) -> Result<Box<dyn Kernel + 'g>> {
    let n = g.num_vertices();
    ensure!(warm.len() == n, "warm rank vector length {} != n {}", warm.len(), n);
    ensure!(dirty.len() == n, "dirty bitmap length {} != n {}", dirty.len(), n);
    Ok(Box::new(FrontierKernel {
        g,
        parts: parts.clone(),
        inv_out: inv_out_degrees(g),
        pr: atomic_vec_from(warm),
        last_pushed: atomic_vec_from(warm),
        dirty: Arc::new(dirty),
        delta: cfg.resolved_delta_threshold(),
        base: (1.0 - cfg.damping) / n as f64,
        d: cfg.damping,
        work_amplify: cfg.work_amplify,
    }))
}

impl Kernel for FrontierKernel<'_> {
    fn sync_mode(&self) -> SyncMode {
        SyncMode::NonBlocking
    }

    fn frontier_scheduled(&self) -> bool {
        true
    }

    /// One sweep over this partition's *dirty* vertices only.
    fn gather(&self, ctx: &WorkerCtx<'_>) -> f64 {
        let mut local_err: f64 = 0.0;
        let mut edges = 0u64;
        let gathered = self.dirty.drain_range(self.parts.range(ctx.tid), |u| {
            let ui = u as usize;
            let previous = self.pr[ui].load();
            let mut tmp = 0.0;
            for &v in self.g.in_neighbors(u) {
                // SAFETY: CSR validation bounds every endpoint by n
                // (= pr.len() = inv_out.len()), as in the NoSync kernel.
                tmp += unsafe {
                    self.pr.get_unchecked(v as usize).load()
                        * self.inv_out.get_unchecked(v as usize)
                };
                amplify_work(self.work_amplify);
            }
            edges += self.g.in_degree(u) as u64;
            let new = self.base + self.d * tmp;
            self.pr[ui].store(new);
            local_err = local_err.max((new - previous).abs());
            if (new - self.last_pushed[ui].load()).abs() > self.delta {
                self.last_pushed[ui].store(new);
                for &w in self.g.out_neighbors(u) {
                    self.dirty.set(w);
                }
            }
        });
        if gathered > 0 {
            ctx.metrics.add_gathered(ctx.tid, gathered);
            ctx.metrics.add_edges(ctx.tid, edges);
        }
        local_err
    }

    fn ranks(&self) -> Vec<f64> {
        snapshot(&self.pr)
    }
}

/// PCPM-propagation frontier kernel: changed vertices scatter their
/// contribution into the compressed value stream; dirty vertices gather
/// from it. See the module docs for the schedule.
pub struct FrontierPcpmKernel<'g> {
    g: &'g Csr,
    parts: Partitions,
    bins: CompressedBins,
    /// In-edge slot (index into the CSR in-edge array) → value-stream slot,
    /// so a dirty vertex can gather its in-contributions straight from the
    /// value stream.
    in_slots: Vec<usize>,
    inv_out: Vec<f64>,
    pr: Vec<AtomicF64>,
    /// Contribution value stream, grouped by (src, dst) partition — one
    /// slot per value group (per edge under the `slots` baseline layout).
    values: Vec<AtomicF64>,
    last_pushed: Vec<AtomicF64>,
    /// Shared with the out-of-core coordinator (see
    /// [`warm_pcpm_kernel_shared`]), which probes shard ranges to skip
    /// clean shards.
    dirty: Arc<DirtyFlags>,
    delta: f64,
    base: f64,
    d: f64,
    work_amplify: u32,
}

/// Registry builder for
/// [`Variant::FrontierPcpm`](crate::pagerank::Variant::FrontierPcpm):
/// cold start — uniform ranks, every vertex dirty.
pub fn pcpm_kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    let n = g.num_vertices();
    let init = vec![1.0 / n as f64; n];
    warm_pcpm_kernel(g, cfg, parts, &init, DirtyFlags::new_set(n))
}

/// Warm-start builder for the PCPM frontier kernel. The
/// [`CompressedBins`] scatter plan is rebuilt against the (possibly
/// mutated) CSR, and **every** value slot is re-seeded with its source's
/// warm contribution `warm[u] / outdeg(u)` — vertices outside the seeded
/// frontier never re-scatter, so the whole grid must already be consistent
/// with the warm ranks before the first sweep.
pub fn warm_pcpm_kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
    warm: &[f64],
    dirty: DirtyFlags,
) -> Result<Box<dyn Kernel + 'g>> {
    warm_pcpm_kernel_shared(g, cfg, parts, warm, Arc::new(dirty))
}

/// Like [`warm_pcpm_kernel`], but the dirty bitmap arrives pre-wrapped in an
/// [`Arc`] and the caller keeps a clone. This is the out-of-core
/// coordinator's hook ([`crate::engine::ooc`]): it probes the shared bitmap
/// with [`DirtyFlags::any_in_range`] to decide which shard to sweep next and
/// when the run has drained, while the kernel drains and re-marks through
/// the very same bits.
pub fn warm_pcpm_kernel_shared<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
    warm: &[f64],
    dirty: Arc<DirtyFlags>,
) -> Result<Box<dyn Kernel + 'g>> {
    let n = g.num_vertices();
    ensure!(warm.len() == n, "warm rank vector length {} != n {}", warm.len(), n);
    ensure!(dirty.len() == n, "dirty bitmap length {} != n {}", dirty.len(), n);
    let inv_out = inv_out_degrees(g);
    let bins = match cfg.pcpm_layout {
        PcpmLayout::Compressed => CompressedBins::new(g, parts),
        PcpmLayout::Slots => CompressedBins::new_per_edge(g, parts),
    };
    let in_slots = bins.in_value_slots(g, parts);
    let values = atomic_vec(bins.num_values(), 0.0);
    for u in 0..n as VertexId {
        let contribution = warm[u as usize] * inv_out[u as usize];
        for &slot in bins.push_slots(u) {
            values[slot].store(contribution);
        }
    }
    Ok(Box::new(FrontierPcpmKernel {
        g,
        parts: parts.clone(),
        in_slots,
        inv_out,
        pr: atomic_vec_from(warm),
        values,
        bins,
        last_pushed: atomic_vec_from(warm),
        dirty,
        delta: cfg.resolved_delta_threshold(),
        base: (1.0 - cfg.damping) / n as f64,
        d: cfg.damping,
        work_amplify: cfg.work_amplify,
    }))
}

impl Kernel for FrontierPcpmKernel<'_> {
    fn sync_mode(&self) -> SyncMode {
        SyncMode::NonBlocking
    }

    fn frontier_scheduled(&self) -> bool {
        true
    }

    /// One sweep over the partition's dirty vertices, gathering from the
    /// value stream and scattering changed contributions back through it
    /// (one store per value group — the compressed delta push).
    fn gather(&self, ctx: &WorkerCtx<'_>) -> f64 {
        let mut local_err: f64 = 0.0;
        let mut edges = 0u64;
        let gathered = self.dirty.drain_range(self.parts.range(ctx.tid), |u| {
            let ui = u as usize;
            let previous = self.pr[ui].load();
            let mut tmp = 0.0;
            for s in self.g.in_slot_range(u) {
                tmp += self.values[self.in_slots[s]].load();
                amplify_work(self.work_amplify);
            }
            edges += self.g.in_degree(u) as u64;
            let new = self.base + self.d * tmp;
            self.pr[ui].store(new);
            local_err = local_err.max((new - previous).abs());
            if (new - self.last_pushed[ui].load()).abs() > self.delta
                && self.g.out_degree(u) > 0
            {
                self.last_pushed[ui].store(new);
                let contribution = new * self.inv_out[ui];
                for &slot in self.bins.push_slots(u) {
                    self.values[slot].store(contribution);
                }
                for &w in self.g.out_neighbors(u) {
                    self.dirty.set(w);
                }
            }
        });
        if gathered > 0 {
            ctx.metrics.add_gathered(ctx.tid, gathered);
            ctx.metrics.add_edges(ctx.tid, edges);
        }
        local_err
    }

    fn ranks(&self) -> Vec<f64> {
        snapshot(&self.pr)
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::{synthetic, GraphBuilder, PartitionPolicy};
    use crate::pagerank::{self, convergence, seq, PcpmLayout, PrConfig, Variant};

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    const BOTH: [Variant; 2] = [Variant::Frontier, Variant::FrontierPcpm];

    #[test]
    fn matches_sequential_on_fixture_families() {
        let c = cfg(3);
        for g in [
            synthetic::cycle(60),
            synthetic::chain(60),
            synthetic::star(60),
            synthetic::complete(20),
            synthetic::web_replica(700, 6, 19),
        ] {
            let (sr, _, _) = seq::solve(&g, &c);
            for v in BOTH {
                let r = pagerank::run(&g, v, &c).unwrap();
                assert!(r.converged, "{v} on {} did not converge", g.name);
                let l1 = r.l1_norm(&sr);
                assert!(l1 < 1e-7, "{v} on {}: l1 {l1}", g.name);
            }
        }
    }

    #[test]
    fn empty_graph_terminates_immediately() {
        let g = GraphBuilder::new(0).build("nil");
        for v in BOTH {
            let r = pagerank::run(&g, v, &cfg(4)).unwrap();
            assert!(r.converged, "{v}");
            assert!(r.ranks.is_empty(), "{v}");
            assert_eq!(r.vertex_updates, 0, "{v}");
        }
    }

    #[test]
    fn single_dangling_vertex_converges_in_one_update() {
        // One vertex, no edges: pr = (1-d)/1 after a single gather; the
        // frontier is empty afterwards and only the confirmation sweeps
        // remain.
        let g = synthetic::chain(1);
        for v in BOTH {
            let r = pagerank::run(&g, v, &cfg(2)).unwrap();
            assert!(r.converged, "{v}");
            assert!((r.ranks[0] - 0.15).abs() < 1e-12, "{v}: {}", r.ranks[0]);
            assert_eq!(r.vertex_updates, 1, "{v} must gather exactly once");
        }
    }

    /// The confirmation-sweep edge case: on a long chain the downstream
    /// partitions' frontiers drain long before rank mass has propagated from
    /// upstream. Workers must keep re-validating (empty frontier ⇒ calm
    /// sweep, but the merged error stays hot) instead of exiting early with
    /// stale ranks.
    #[test]
    fn drained_frontier_waits_for_global_convergence() {
        let g = synthetic::chain(400);
        let c = cfg(4);
        let (sr, _, _) = seq::solve(&g, &c);
        for v in BOTH {
            let r = pagerank::run(&g, v, &c).unwrap();
            assert!(r.converged, "{v}");
            let linf = convergence::linf_norm(&r.ranks, &sr);
            assert!(linf < 1e-10, "{v} exited before the chain settled: linf {linf}");
        }
    }

    #[test]
    fn more_threads_than_vertices() {
        let g = synthetic::cycle(3);
        for v in BOTH {
            let r = pagerank::run(&g, v, &cfg(8)).unwrap();
            assert!(r.converged, "{v}");
            let (sr, _, _) = seq::solve(&g, &cfg(8));
            assert!(r.l1_norm(&sr) < 1e-9, "{v}");
        }
    }

    #[test]
    fn edge_balanced_partitioning_also_correct() {
        let g = synthetic::web_replica(600, 7, 5);
        let c = PrConfig { partition: PartitionPolicy::EdgeBalanced, ..cfg(4) };
        let (sr, _, _) = seq::solve(&g, &c);
        for v in BOTH {
            let r = pagerank::run(&g, v, &c).unwrap();
            assert!(r.converged, "{v}");
            assert!(r.l1_norm(&sr) < 1e-7, "{v}: l1 {}", r.l1_norm(&sr));
        }
    }

    /// A coarser delta threshold trades accuracy for fewer vertex updates —
    /// the ablation knob behind `--delta-threshold`.
    #[test]
    fn coarse_delta_threshold_gathers_less() {
        let g = synthetic::web_replica(900, 6, 23);
        let tight = PrConfig { threshold: 1e-10, ..cfg(4) };
        let coarse = PrConfig { delta_threshold: 1e-6, ..tight.clone() };
        let fine = pagerank::run(&g, Variant::Frontier, &tight).unwrap();
        let rough = pagerank::run(&g, Variant::Frontier, &coarse).unwrap();
        assert!(fine.converged && rough.converged);
        assert!(
            rough.vertex_updates <= fine.vertex_updates,
            "coarse delta did more work: {} > {}",
            rough.vertex_updates,
            fine.vertex_updates
        );
        // still a sane approximation: un-pushed residual is bounded by
        // delta / (1 - d) per vertex
        let (sr, _, _) = seq::solve(&g, &tight);
        assert!(rough.l1_norm(&sr) < 1e-1, "l1 {}", rough.l1_norm(&sr));
    }

    /// Both value-stream layouts (compressed groups and the per-edge
    /// baseline) must land on the sequential fixed point — the delta
    /// schedule only changes how many stores a push issues, not what a
    /// gather sums.
    #[test]
    fn pcpm_layouts_both_converge() {
        let g = synthetic::web_replica(700, 6, 31);
        let base = cfg(4);
        let (sr, _, _) = seq::solve(&g, &base);
        for layout in [PcpmLayout::Compressed, PcpmLayout::Slots] {
            let c = PrConfig { pcpm_layout: layout, ..base.clone() };
            let r = pagerank::run(&g, Variant::FrontierPcpm, &c).unwrap();
            assert!(r.converged, "{layout}");
            let l1 = r.l1_norm(&sr);
            assert!(l1 < 1e-7, "{layout}: l1 {l1}");
        }
    }

    #[test]
    fn iteration_cap_reports_unconverged() {
        let g = synthetic::web_replica(400, 6, 8);
        let c = PrConfig { max_iterations: 2, ..cfg(2) };
        for v in BOTH {
            let r = pagerank::run(&g, v, &c).unwrap();
            assert!(!r.converged, "{v}");
        }
    }
}
