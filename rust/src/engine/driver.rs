//! Engine drivers — one scheduling loop per [`SyncMode`], shared by every
//! kernel.
//!
//! This file is the single home of the orchestration the variant modules
//! used to duplicate: worker spawn (through
//! [`run_workers`](crate::coordinator::executor::run_workers), which owns
//! the DNF watchdog), fault-plan application at iteration boundaries,
//! barrier phasing, thread-level confirmation sweeps, and [`PrResult`]
//! assembly with barrier-wait telemetry.
//!
//! ## Confirmation sweeps (non-blocking modes)
//!
//! The paper's Algorithm 3 exits on the first observation of a calm merged
//! error. On hosts with fewer cores than threads a descheduled peer can
//! hold a stale-calm slot, so the driver demands **two consecutive** calm
//! iterations — the second sweep re-validates the partition against any
//! updates that landed in between. See DESIGN.md §Substitutions.
//!
//! ## NUMA placement
//!
//! When `--numa pin|interleave` resolves to a [`topology::Plan`], every
//! parallel driver pins worker `tid` to its planned CPU set and then runs
//! the kernel's [`Kernel::first_touch`] pre-pass before iteration 0, so the
//! pages of that partition's rank/`last_pushed`/value-stream entries fault
//! in node-local. Pinning is best-effort: on hosts without the syscall (or
//! without NUMA at all) the plan degrades to a no-op and the numerics are
//! untouched.

use crate::engine::topology::Plan;
use crate::engine::{Kernel, SyncMode, WorkerCtx};
use crate::coordinator::executor::run_workers;
use crate::coordinator::metrics::RunMetrics;
use crate::pagerank::convergence::ErrorBoard;
use crate::pagerank::{PrConfig, PrResult, Variant};
use crate::sync::barrier::SenseBarrier;
use crate::sync::PhaseBarrier;
use anyhow::{bail, Result};
use crate::sync::shim::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Execute a built kernel under its declared [`SyncMode`].
pub fn execute(
    variant: Variant,
    cfg: &PrConfig,
    kernel: &dyn Kernel,
    start: Instant,
) -> Result<PrResult> {
    match kernel.sync_mode() {
        SyncMode::Sequential => run_sequential(variant, kernel, start),
        SyncMode::Blocking { pre_scatter } => {
            Ok(run_blocking(variant, cfg, kernel, start, pre_scatter))
        }
        SyncMode::NonBlocking => Ok(run_nonblocking(variant, cfg, kernel, start)),
        SyncMode::Helping => run_helping(variant, cfg, kernel, start),
    }
}

fn run_sequential(variant: Variant, kernel: &dyn Kernel, start: Instant) -> Result<PrResult> {
    let Some((ranks, iterations, converged)) = kernel.solve() else {
        bail!("{variant} declares SyncMode::Sequential but implements no solve()");
    };
    // A sequential power-iteration sweep updates every vertex once.
    let vertex_updates = iterations * ranks.len() as u64;
    Ok(PrResult {
        variant,
        ranks,
        iterations,
        per_thread_iterations: vec![iterations],
        elapsed: start.elapsed(),
        converged,
        barrier_wait_secs: 0.0,
        vertex_updates,
        frontier_switches: 0,
        worklist_peak: 0,
        dnf: false,
    })
}

/// Pin worker `tid` per the placement plan (if any) and run the kernel's
/// first-touch pre-pass so its pages fault in on the pinned node.
fn place_worker(plan: &Option<Plan>, kernel: &dyn Kernel, tid: usize) {
    if let Some(p) = plan {
        p.apply(tid);
        kernel.first_touch(tid);
    }
}

/// Barrier-separated phases, algorithm-level convergence (Algorithms 1/2/5
/// and PCPM). Per iteration:
///
/// 1. optional `scatter` + barrier (edge-centric push / PCPM bin write);
/// 2. `gather`, publish the local error, barrier;
/// 3. merge the global error, `commit` (`prev ← pr`), barrier;
/// 4. decide: converged / iteration cap / next iteration.
fn run_blocking(
    variant: Variant,
    cfg: &PrConfig,
    kernel: &dyn Kernel,
    start: Instant,
    pre_scatter: bool,
) -> PrResult {
    let threads = cfg.threads;
    let board = ErrorBoard::new(threads);
    let barrier = SenseBarrier::new(threads);
    let metrics = RunMetrics::new(threads);
    let converged = AtomicBool::new(false);
    let plan = Plan::new(cfg.numa, threads);

    let outcome = run_workers(threads, cfg.dnf_timeout, &[&barrier], |tid, stop| {
        place_worker(&plan, kernel, tid);
        let ctx = WorkerCtx { tid, metrics: &metrics };
        let mut waiter = barrier.waiter();
        let mut iter = 0u64;
        loop {
            if stop.load(Ordering::Acquire) {
                return;
            }
            if cfg.faults.apply(tid, iter) {
                return; // injected crash: never arrives at the barrier again
            }
            if pre_scatter {
                kernel.scatter(&ctx);
                if waiter.wait().is_aborted() {
                    return; // ── Barrier Sync Checkpoint (scatter)
                }
            }
            let err = kernel.gather(&ctx);
            board.publish(tid, err);
            if waiter.wait().is_aborted() {
                return; // ── Barrier Sync Checkpoint (gather)
            }
            // Every thread computes the same max — cheaper than electing a
            // leader and barriering again.
            let global_err = board.global_max();
            kernel.commit(&ctx);
            if waiter.wait().is_aborted() {
                return; // ── Barrier Sync Checkpoint (commit)
            }
            iter += 1;
            metrics.bump_iteration(tid);
            if kernel.converged(global_err, cfg.threshold) {
                converged.store(true, Ordering::Release);
                return;
            }
            if iter >= cfg.max_iterations {
                return;
            }
        }
    });

    let (frontier_switches, worklist_peak) = kernel.frontier_stats();
    PrResult {
        variant,
        ranks: kernel.ranks(),
        iterations: metrics.max_iterations(),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed: start.elapsed(),
        converged: converged.load(Ordering::Acquire) && !outcome.dnf,
        barrier_wait_secs: PhaseBarrier::total_wait_secs(&barrier),
        vertex_updates: metrics.total_gathered(),
        frontier_switches,
        worklist_peak,
        dnf: outcome.dnf,
    }
}

/// How long a frontier worker parks per empty sweep. Long enough not to
/// burn a core while peers converge, short enough that re-activation (a
/// peer pushing into this partition) is picked up promptly.
const FRONTIER_IDLE_PARK: Duration = Duration::from_micros(20);

/// Barrier-free sweeps, thread-level convergence (Algorithms 3/4/5). Each
/// worker runs `gather` → error merge → `scatter` (the Algorithm 4 push;
/// a no-op for vertex-centric kernels) and exits on two consecutive calm
/// observations or the iteration cap.
///
/// Frontier-scheduled kernels ([`Kernel::frontier_scheduled`]) add one
/// wrinkle: a sweep that drained nothing is not *work*, so it neither
/// counts toward the iteration cap nor hot-spins — the worker parks
/// briefly and re-checks. Two exits keep that from livelocking. A peer
/// that hits the cap sets the shared flag (everyone gives up — the run is
/// non-converged either way). And when the merged error is hot but every
/// thread whose error slot is still above the threshold has *exited*
/// (crashed under the fault plan, or gave up), no live thread can ever
/// lower those slots, so waiting is hopeless and the worker exits
/// non-converged. The check is exact liveness, not a timeout: a live peer
/// mid-long-sweep never trips it, no matter how slow its sweeps are.
fn run_nonblocking(
    variant: Variant,
    cfg: &PrConfig,
    kernel: &dyn Kernel,
    start: Instant,
) -> PrResult {
    let threads = cfg.threads;
    let board = ErrorBoard::new(threads);
    let metrics = RunMetrics::new(threads);
    let capped = AtomicBool::new(false);
    let frontier = kernel.frontier_scheduled();
    // Which workers have returned (any reason) — the hopeless-wait check.
    let exited: Vec<AtomicBool> = (0..threads).map(|_| AtomicBool::new(false)).collect();
    let plan = Plan::new(cfg.numa, threads);

    let outcome = run_workers(threads, cfg.dnf_timeout, &[], |tid, stop| {
        place_worker(&plan, kernel, tid);
        let ctx = WorkerCtx { tid, metrics: &metrics };
        let mut iter = 0u64;
        // Consecutive iterations with every visible error ≤ threshold (the
        // confirmation sweep — see the module docs).
        let mut calm = 0u32;
        'work: loop {
            if stop.load(Ordering::Acquire) {
                break 'work;
            }
            if cfg.faults.apply(tid, iter) {
                break 'work; // crash: error slot stays stale
            }
            let drained_before = metrics.gathered_by(tid);
            let err = kernel.gather(&ctx);
            // An empty frontier sweep is a termination probe, not work.
            let worked = !frontier || metrics.gathered_by(tid) != drained_before;
            if worked {
                iter += 1;
                metrics.bump_iteration(tid);
            }
            board.publish(tid, err);
            // Thread-level convergence: merge own error with the freshest
            // visible values from every peer (Alg 3 lines 16-19). Peers may
            // still be mid-iteration — that partial view is the point.
            let merged = board.global_max();
            kernel.scatter(&ctx);
            // A calm observation needs the merged error under the threshold
            // AND — for frontier kernels — an empty own frontier this
            // sweep: exiting with pending dirty vertices would leave them
            // un-gathered forever. Sub-delta pushes decay geometrically,
            // so a near-converged frontier does drain in bounded time.
            if kernel.converged(merged, cfg.threshold) && (!frontier || !worked) {
                calm += 1;
                if calm >= 2 {
                    break 'work;
                }
            } else {
                calm = 0;
                if frontier && !worked {
                    // Nothing to gather, yet the merged error was hot: if
                    // every hot slot belongs to an exited worker, nobody
                    // can ever calm it — give up (non-converged). The
                    // slots are re-read here and may all have calmed since
                    // the merge, so also demand at least one slot that is
                    // still hot AND abandoned; otherwise this is just the
                    // convergence tail and the calm path will end the run.
                    let mut dead_hot = false;
                    let covered = (0..threads).all(|t| {
                        // Order matters: acquire `exited` first. Seeing it
                        // true synchronizes with the worker's final error
                        // publish, so the slot read below cannot be a
                        // stale-hot value from before a calm exit.
                        let dead = exited[t].load(Ordering::Acquire);
                        let calm_slot = board.read(t) <= cfg.threshold;
                        dead_hot |= dead && !calm_slot;
                        calm_slot || dead
                    });
                    if covered && dead_hot {
                        capped.store(true, Ordering::Release);
                        break 'work;
                    }
                }
            }
            if frontier && capped.load(Ordering::Acquire) {
                break 'work; // a peer gave up — the run is non-converged anyway
            }
            if iter >= cfg.max_iterations {
                capped.store(true, Ordering::Release);
                break 'work;
            }
            // Cooperative fairness: on oversubscribed hosts a spinning
            // thread can starve its peers for whole timeslices, inflating
            // staleness far beyond what the paper's 56 hardware threads
            // ever see. One yield per sweep keeps sweeps interleaved; an
            // idle frontier worker parks longer.
            if worked {
                std::thread::yield_now();
            } else {
                std::thread::sleep(FRONTIER_IDLE_PARK);
            }
        }
        exited[tid].store(true, Ordering::Release);
    });

    let (frontier_switches, worklist_peak) = kernel.frontier_stats();
    PrResult {
        variant,
        ranks: kernel.ranks(),
        iterations: metrics.max_iterations(),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed: start.elapsed(),
        converged: !capped.load(Ordering::Acquire) && !outcome.dnf,
        barrier_wait_secs: 0.0,
        vertex_updates: metrics.total_gathered(),
        frontier_switches,
        worklist_peak,
        dnf: outcome.dnf,
    }
}

/// Wait-free helping (Algorithm 6): workers drive their own partition, then
/// help every partition behind the frontier; termination is decided by the
/// engine-owned [`crate::engine::helping::HelpingState`].
fn run_helping(
    variant: Variant,
    cfg: &PrConfig,
    kernel: &dyn Kernel,
    start: Instant,
) -> Result<PrResult> {
    let Some(state) = kernel.helping() else {
        bail!("{variant} declares SyncMode::Helping but exposes no HelpingState");
    };
    let threads = cfg.threads;
    let metrics = RunMetrics::new(threads);
    let plan = Plan::new(cfg.numa, threads);
    let outcome = run_workers(threads, cfg.dnf_timeout, &[], |tid, stop| {
        place_worker(&plan, kernel, tid);
        state.drive_worker(tid, stop, &cfg.faults, &metrics);
    });
    // Algorithmic completion time when recorded; wall-clock join otherwise
    // (Fig 8 measures completion, not the last sleeper's wake-up).
    let elapsed = state.completion().unwrap_or_else(|| start.elapsed());
    Ok(PrResult {
        variant,
        ranks: kernel.ranks(),
        iterations: state.system_iteration(),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed,
        converged: state.is_converged() && !outcome.dnf,
        barrier_wait_secs: 0.0,
        vertex_updates: metrics.total_gathered(),
        frontier_switches: 0,
        worklist_peak: 0,
        dnf: outcome.dnf,
    })
}
