//! Engine drivers — one scheduling loop per [`SyncMode`], shared by every
//! kernel.
//!
//! This file is the single home of the orchestration the variant modules
//! used to duplicate: worker spawn (through
//! [`run_workers`](crate::coordinator::executor::run_workers), which owns
//! the DNF watchdog), fault-plan application at iteration boundaries,
//! barrier phasing, thread-level confirmation sweeps, and [`PrResult`]
//! assembly with barrier-wait telemetry.
//!
//! ## Confirmation sweeps (non-blocking modes)
//!
//! The paper's Algorithm 3 exits on the first observation of a calm merged
//! error. On hosts with fewer cores than threads a descheduled peer can
//! hold a stale-calm slot, so the driver demands **two consecutive** calm
//! iterations — the second sweep re-validates the partition against any
//! updates that landed in between. See DESIGN.md §Substitutions.

use crate::engine::{Kernel, SyncMode, WorkerCtx};
use crate::coordinator::executor::run_workers;
use crate::coordinator::metrics::RunMetrics;
use crate::pagerank::convergence::ErrorBoard;
use crate::pagerank::{PrConfig, PrResult, Variant};
use crate::sync::barrier::SenseBarrier;
use crate::sync::PhaseBarrier;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Execute a built kernel under its declared [`SyncMode`].
pub fn execute(
    variant: Variant,
    cfg: &PrConfig,
    kernel: &dyn Kernel,
    start: Instant,
) -> Result<PrResult> {
    match kernel.sync_mode() {
        SyncMode::Sequential => run_sequential(variant, kernel, start),
        SyncMode::Blocking { pre_scatter } => {
            Ok(run_blocking(variant, cfg, kernel, start, pre_scatter))
        }
        SyncMode::NonBlocking => Ok(run_nonblocking(variant, cfg, kernel, start)),
        SyncMode::Helping => run_helping(variant, cfg, kernel, start),
    }
}

fn run_sequential(variant: Variant, kernel: &dyn Kernel, start: Instant) -> Result<PrResult> {
    let Some((ranks, iterations, converged)) = kernel.solve() else {
        bail!("{variant} declares SyncMode::Sequential but implements no solve()");
    };
    Ok(PrResult {
        variant,
        ranks,
        iterations,
        per_thread_iterations: vec![iterations],
        elapsed: start.elapsed(),
        converged,
        barrier_wait_secs: 0.0,
        dnf: false,
    })
}

/// Barrier-separated phases, algorithm-level convergence (Algorithms 1/2/5
/// and PCPM). Per iteration:
///
/// 1. optional `scatter` + barrier (edge-centric push / PCPM bin write);
/// 2. `gather`, publish the local error, barrier;
/// 3. merge the global error, `commit` (`prev ← pr`), barrier;
/// 4. decide: converged / iteration cap / next iteration.
fn run_blocking(
    variant: Variant,
    cfg: &PrConfig,
    kernel: &dyn Kernel,
    start: Instant,
    pre_scatter: bool,
) -> PrResult {
    let threads = cfg.threads;
    let board = ErrorBoard::new(threads);
    let barrier = SenseBarrier::new(threads);
    let metrics = RunMetrics::new(threads);
    let converged = AtomicBool::new(false);

    let outcome = run_workers(threads, cfg.dnf_timeout, &[&barrier], |tid, stop| {
        let ctx = WorkerCtx { tid, metrics: &metrics };
        let mut waiter = barrier.waiter();
        let mut iter = 0u64;
        loop {
            if stop.load(Ordering::Acquire) {
                return;
            }
            if cfg.faults.apply(tid, iter) {
                return; // injected crash: never arrives at the barrier again
            }
            if pre_scatter {
                kernel.scatter(&ctx);
                if waiter.wait().is_aborted() {
                    return; // ── Barrier Sync Checkpoint (scatter)
                }
            }
            let err = kernel.gather(&ctx);
            board.publish(tid, err);
            if waiter.wait().is_aborted() {
                return; // ── Barrier Sync Checkpoint (gather)
            }
            // Every thread computes the same max — cheaper than electing a
            // leader and barriering again.
            let global_err = board.global_max();
            kernel.commit(&ctx);
            if waiter.wait().is_aborted() {
                return; // ── Barrier Sync Checkpoint (commit)
            }
            iter += 1;
            metrics.bump_iteration(tid);
            if kernel.converged(global_err, cfg.threshold) {
                converged.store(true, Ordering::Release);
                return;
            }
            if iter >= cfg.max_iterations {
                return;
            }
        }
    });

    PrResult {
        variant,
        ranks: kernel.ranks(),
        iterations: metrics.max_iterations(),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed: start.elapsed(),
        converged: converged.load(Ordering::Acquire) && !outcome.dnf,
        barrier_wait_secs: PhaseBarrier::total_wait_secs(&barrier),
        dnf: outcome.dnf,
    }
}

/// Barrier-free sweeps, thread-level convergence (Algorithms 3/4/5). Each
/// worker runs `gather` → error merge → `scatter` (the Algorithm 4 push;
/// a no-op for vertex-centric kernels) and exits on two consecutive calm
/// observations or the iteration cap.
fn run_nonblocking(
    variant: Variant,
    cfg: &PrConfig,
    kernel: &dyn Kernel,
    start: Instant,
) -> PrResult {
    let threads = cfg.threads;
    let board = ErrorBoard::new(threads);
    let metrics = RunMetrics::new(threads);
    let capped = AtomicBool::new(false);

    let outcome = run_workers(threads, cfg.dnf_timeout, &[], |tid, stop| {
        let ctx = WorkerCtx { tid, metrics: &metrics };
        let mut iter = 0u64;
        // Consecutive iterations with every visible error ≤ threshold (the
        // confirmation sweep — see the module docs).
        let mut calm = 0u32;
        loop {
            if stop.load(Ordering::Acquire) {
                return;
            }
            if cfg.faults.apply(tid, iter) {
                return; // crash: error slot stays stale, peers keep spinning
            }
            let err = kernel.gather(&ctx);
            iter += 1;
            metrics.bump_iteration(tid);
            board.publish(tid, err);
            // Thread-level convergence: merge own error with the freshest
            // visible values from every peer (Alg 3 lines 16-19). Peers may
            // still be mid-iteration — that partial view is the point.
            let merged = board.global_max();
            kernel.scatter(&ctx);
            if kernel.converged(merged, cfg.threshold) {
                calm += 1;
                if calm >= 2 {
                    return;
                }
            } else {
                calm = 0;
            }
            if iter >= cfg.max_iterations {
                capped.store(true, Ordering::Release);
                return;
            }
            // Cooperative fairness: on oversubscribed hosts a spinning
            // thread can starve its peers for whole timeslices, inflating
            // staleness far beyond what the paper's 56 hardware threads
            // ever see. One yield per sweep keeps sweeps interleaved.
            std::thread::yield_now();
        }
    });

    PrResult {
        variant,
        ranks: kernel.ranks(),
        iterations: metrics.max_iterations(),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed: start.elapsed(),
        converged: !capped.load(Ordering::Acquire) && !outcome.dnf,
        barrier_wait_secs: 0.0,
        dnf: outcome.dnf,
    }
}

/// Wait-free helping (Algorithm 6): workers drive their own partition, then
/// help every partition behind the frontier; termination is decided by the
/// engine-owned [`crate::engine::helping::HelpingState`].
fn run_helping(
    variant: Variant,
    cfg: &PrConfig,
    kernel: &dyn Kernel,
    start: Instant,
) -> Result<PrResult> {
    let Some(state) = kernel.helping() else {
        bail!("{variant} declares SyncMode::Helping but exposes no HelpingState");
    };
    let threads = cfg.threads;
    let metrics = RunMetrics::new(threads);
    let outcome = run_workers(threads, cfg.dnf_timeout, &[], |tid, stop| {
        state.drive_worker(tid, stop, &cfg.faults, &metrics);
    });
    // Algorithmic completion time when recorded; wall-clock join otherwise
    // (Fig 8 measures completion, not the last sleeper's wake-up).
    let elapsed = state.completion().unwrap_or_else(|| start.elapsed());
    Ok(PrResult {
        variant,
        ranks: kernel.ranks(),
        iterations: state.system_iteration(),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed,
        converged: state.is_converged() && !outcome.dnf,
        barrier_wait_secs: 0.0,
        dnf: outcome.dnf,
    })
}
