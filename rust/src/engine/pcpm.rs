//! Partition-centric scatter-gather PageRank (PCPM), after Lakhotia et al.,
//! *"Accelerating PageRank using Partition-Centric Processing"*.
//!
//! The vertex-centric pull (Algorithms 1/3) reads `pr[v]` for every in-edge
//! — a random-access stream over the whole rank array. PCPM restructures an
//! iteration around the partition grid instead:
//!
//! * **Scatter** — each thread streams its own partition's vertices once and
//!   writes each contribution `pr(u)/outdeg(u)` into *update bins* grouped
//!   by destination partition ([`PartitionBins`]); writes into one bin are
//!   sequential, so the phase is insert-only streaming.
//! * **Gather** — each thread merges exactly the bins destined for its
//!   partition: the bin reads are sequential and the accumulator writes land
//!   only inside its own (cache-resident) partition slice.
//!
//! Both phases are single-writer by construction, separated by engine
//! barriers, so the iteration is synchronous Jacobi — the same schedule (and
//! iteration count) as the Barrier variants, with the locality profile of
//! the edge-centric model but without its shared `m`-sized random writes.
//!
//! Registered as [`Variant::Pcpm`](crate::pagerank::Variant::Pcpm), exposed
//! as `--mode pcpm` (or `--algo pcpm` / `partition-centric`) on the CLI.

use crate::engine::{inv_out_degrees, Kernel, SyncMode, WorkerCtx};
use crate::graph::partition::PartitionBins;
use crate::graph::{Csr, Partitions};
use crate::pagerank::{amplify_work, PrConfig};
use crate::sync::atomics::{atomic_vec, snapshot, AtomicF64};
use anyhow::Result;

pub struct PcpmKernel<'g> {
    g: &'g Csr,
    parts: Partitions,
    bins: PartitionBins,
    inv_out: Vec<f64>,
    pr: Vec<AtomicF64>,
    /// One slot per edge, grouped by (source partition, destination
    /// partition) — the update bins.
    bin_values: Vec<AtomicF64>,
    /// Per-vertex gather accumulator; vertex `u` is only ever touched by the
    /// thread owning `u`'s partition.
    acc: Vec<AtomicF64>,
    base: f64,
    d: f64,
    work_amplify: u32,
}

/// Registry builder for [`Variant::Pcpm`](crate::pagerank::Variant::Pcpm).
pub fn kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    let n = g.num_vertices();
    let bins = PartitionBins::new(g, parts);
    Ok(Box::new(PcpmKernel {
        g,
        parts: parts.clone(),
        inv_out: inv_out_degrees(g),
        pr: atomic_vec(n, 1.0 / n as f64),
        bin_values: atomic_vec(bins.num_slots(), 0.0),
        acc: atomic_vec(n, 0.0),
        bins,
        base: (1.0 - cfg.damping) / n as f64,
        d: cfg.damping,
        work_amplify: cfg.work_amplify,
    }))
}

impl Kernel for PcpmKernel<'_> {
    fn sync_mode(&self) -> SyncMode {
        SyncMode::Blocking { pre_scatter: true }
    }

    /// Scatter phase: stream this partition's contributions into its bins.
    fn scatter(&self, ctx: &WorkerCtx<'_>) {
        for u in self.parts.range(ctx.tid) {
            if self.g.out_degree(u) == 0 {
                continue;
            }
            let contribution = self.pr[u as usize].load() * self.inv_out[u as usize];
            for e in self.g.out_slot_range(u) {
                self.bin_values[self.bins.scatter_slot(e)].store(contribution);
            }
        }
    }

    /// Gather phase: merge every source partition's bin for this partition,
    /// then apply Eq. 1 per destination vertex.
    fn gather(&self, ctx: &WorkerCtx<'_>) -> f64 {
        let tid = ctx.tid;
        for u in self.parts.range(tid) {
            self.acc[u as usize].store(0.0);
        }
        let mut edges = 0u64;
        for src in 0..self.bins.num_partitions() {
            let range = self.bins.range(src, tid);
            edges += range.len() as u64;
            for slot in range {
                let v = self.bins.dst(slot) as usize;
                // single-writer: every destination in this bin is owned by
                // partition `tid`
                self.acc[v].store(self.acc[v].load() + self.bin_values[slot].load());
                amplify_work(self.work_amplify);
            }
        }
        let mut thr_err: f64 = 0.0;
        for u in self.parts.range(tid) {
            let previous = self.pr[u as usize].load();
            let new = self.base + self.d * self.acc[u as usize].load();
            self.pr[u as usize].store(new);
            thr_err = thr_err.max((new - previous).abs());
        }
        ctx.metrics.add_edges(tid, edges);
        ctx.metrics.add_gathered(tid, self.parts.range(tid).len() as u64);
        thr_err
    }

    fn ranks(&self) -> Vec<f64> {
        snapshot(&self.pr)
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::{synthetic, PartitionPolicy};
    use crate::pagerank::{self, seq, PrConfig, Variant};

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn matches_sequential_on_cycle() {
        let g = synthetic::cycle(40);
        let c = cfg(4);
        let r = pagerank::run(&g, Variant::Pcpm, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-10, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn matches_sequential_on_web_replica() {
        let g = synthetic::web_replica(800, 6, 17);
        let c = cfg(3);
        let r = pagerank::run(&g, Variant::Pcpm, &c).unwrap();
        assert!(r.converged);
        let (sr, seq_iters, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-9, "l1 {}", r.l1_norm(&sr));
        // synchronous Jacobi schedule: iteration count equals sequential
        assert_eq!(r.iterations, seq_iters);
    }

    #[test]
    fn handles_dangling_vertices() {
        let g = synthetic::chain(20); // tail vertex has outdeg 0
        let c = cfg(2);
        let r = pagerank::run(&g, Variant::Pcpm, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-10);
    }

    #[test]
    fn agrees_with_barrier_schedule() {
        let g = synthetic::social_replica(400, 6, 9);
        let c = cfg(2);
        let pcpm = pagerank::run(&g, Variant::Pcpm, &c).unwrap();
        let barrier = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        assert_eq!(pcpm.iterations, barrier.iterations);
        assert!(
            crate::pagerank::convergence::linf_norm(&pcpm.ranks, &barrier.ranks) < 1e-12
        );
    }

    #[test]
    fn edge_balanced_partitioning_also_correct() {
        let g = synthetic::web_replica(600, 7, 5);
        let c = PrConfig { partition: PartitionPolicy::EdgeBalanced, ..cfg(4) };
        let r = pagerank::run(&g, Variant::Pcpm, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-9);
    }

    #[test]
    fn more_threads_than_vertices() {
        let g = synthetic::cycle(3);
        let c = cfg(8);
        let r = pagerank::run(&g, Variant::Pcpm, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-10);
    }
}
