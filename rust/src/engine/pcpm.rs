//! Partition-centric scatter-gather PageRank (PCPM), after Lakhotia et al.,
//! *"Accelerating PageRank using Partition-Centric Processing"*.
//!
//! The vertex-centric pull (Algorithms 1/3) reads `pr[v]` for every in-edge
//! — a random-access stream over the whole rank array. PCPM restructures an
//! iteration around the partition grid instead:
//!
//! * **Scatter** — each thread streams its own partitions' vertices once
//!   and writes each contribution `pr(u)/outdeg(u)` into the *compressed
//!   update bins* ([`CompressedBins`]): the destination indices are a
//!   static `u32` stream built once from the CSR, so the runtime writes are
//!   only the dense value stream — one streaming store per `(vertex,
//!   destination partition)` group, no per-edge slots and no atomics
//!   contended on the scatter side.
//! * **Gather** — each thread merges exactly the bins destined for its
//!   partitions: a sequential `(dest, value)` replay of the destination
//!   stream against the value stream, with accumulator writes landing only
//!   inside its own (cache-resident) partition slice.
//!
//! Two tuning knobs ride on top (both from
//! [`PrConfig`](crate::pagerank::PrConfig)):
//!
//! * `pcpm_batch` — the graph is cut into `threads × batch` partitions and
//!   each worker scatters its `batch` source partitions before switching to
//!   gather, so each gather accumulator covers a partition small enough to
//!   stay cache-resident;
//! * `pcpm_layout` — [`PcpmLayout::Slots`] rebuilds the pre-compression
//!   one-value-per-edge layout in stream form, kept as the ablation
//!   baseline for the compressed stream.
//!
//! Both phases are single-writer by construction, separated by engine
//! barriers, so the iteration is synchronous Jacobi — the same schedule
//! (and iteration count) as the Barrier variants, with the locality profile
//! of the edge-centric model but without its shared `m`-sized random
//! writes. Within a bin, entries follow ascending source order, so every
//! layout and batch size accumulates bit-identically.
//!
//! Registered as [`Variant::Pcpm`](crate::pagerank::Variant::Pcpm), exposed
//! as `--mode pcpm` (or `--algo pcpm` / `partition-centric`) on the CLI.

use crate::engine::{inv_out_degrees, Kernel, SyncMode, WorkerCtx};
use crate::graph::{CompressedBins, Csr, Partitions};
use crate::pagerank::{amplify_work, PcpmLayout, PrConfig};
use crate::sync::atomics::{atomic_vec, snapshot, AtomicF64};
use anyhow::{bail, Result};

/// Partition-centric scatter-gather kernel on the compressed bin streams.
pub struct PcpmKernel<'g> {
    g: &'g Csr,
    /// Fine partitions: `threads × batch` contiguous ranges; worker `t`
    /// owns partitions `t*batch .. (t+1)*batch`.
    parts: Partitions,
    batch: usize,
    bins: CompressedBins,
    inv_out: Vec<f64>,
    pr: Vec<AtomicF64>,
    /// Dense value stream, grouped by (src, dst) partition bin — one slot
    /// per value group ([`CompressedBins::num_values`]).
    values: Vec<AtomicF64>,
    /// Per-vertex gather accumulator; vertex `u` is only ever touched by
    /// the thread owning `u`'s partition.
    acc: Vec<AtomicF64>,
    base: f64,
    d: f64,
    work_amplify: u32,
}

/// Registry builder for [`Variant::Pcpm`](crate::pagerank::Variant::Pcpm).
pub fn kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    let n = g.num_vertices();
    let batch = cfg.pcpm_batch.max(1);
    if cfg.threads.saturating_mul(batch) > 1024 {
        // The bin grid is (threads × batch)² ranges; bound it before the
        // layout allocation grows past the graph it serves. Enforced here
        // (not in PrConfig::validate) because only this kernel reads the
        // knob.
        bail!("threads × pcpm-batch must not exceed 1024");
    }
    // One partition per worker is exactly the partitioning the engine
    // already built; a batch > 1 re-cuts the graph finer under the same
    // policy.
    let fine = if batch == 1 {
        parts.clone()
    } else {
        Partitions::new(g, cfg.threads * batch, cfg.partition)
    };
    let bins = match cfg.pcpm_layout {
        PcpmLayout::Compressed => CompressedBins::new(g, &fine),
        PcpmLayout::Slots => CompressedBins::new_per_edge(g, &fine),
    };
    Ok(Box::new(PcpmKernel {
        g,
        inv_out: inv_out_degrees(g),
        pr: atomic_vec(n, 1.0 / n as f64),
        values: atomic_vec(bins.num_values(), 0.0),
        acc: atomic_vec(n, 0.0),
        parts: fine,
        batch,
        bins,
        base: (1.0 - cfg.damping) / n as f64,
        d: cfg.damping,
        work_amplify: cfg.work_amplify,
    }))
}

impl PcpmKernel<'_> {
    /// Fine-partition indices owned by worker `tid`.
    #[inline]
    fn owned(&self, tid: usize) -> std::ops::Range<usize> {
        tid * self.batch..(tid + 1) * self.batch
    }
}

impl Kernel for PcpmKernel<'_> {
    fn sync_mode(&self) -> SyncMode {
        SyncMode::Blocking { pre_scatter: true }
    }

    /// Scatter phase: stream this worker's `batch` source partitions'
    /// contributions into their value slots (the destination stream is
    /// static — only values are written).
    fn scatter(&self, ctx: &WorkerCtx<'_>) {
        for fp in self.owned(ctx.tid) {
            for u in self.parts.range(fp) {
                let slots = self.bins.push_slots(u);
                if slots.is_empty() {
                    continue; // dangling vertex
                }
                let contribution = self.pr[u as usize].load() * self.inv_out[u as usize];
                for &slot in slots {
                    self.values[slot].store(contribution);
                }
            }
        }
    }

    /// Gather phase: for each owned destination partition, merge every
    /// source partition's bin as a sequential (dest, value) replay, then
    /// apply Eq. 1 per destination vertex.
    fn gather(&self, ctx: &WorkerCtx<'_>) -> f64 {
        let tid = ctx.tid;
        let p = self.parts.count();
        let mut edges = 0u64;
        let mut gathered = 0u64;
        let mut thr_err: f64 = 0.0;
        for fp in self.owned(tid) {
            let range = self.parts.range(fp);
            for u in range.clone() {
                self.acc[u as usize].store(0.0);
            }
            for src in 0..p {
                let vr = self.bins.value_range(src, fp);
                let mut vi = vr.start;
                let mut val = 0.0;
                let entries = self.bins.entries(src, fp);
                edges += entries.len() as u64;
                for &e in entries {
                    let (v, fresh) = CompressedBins::decode(e);
                    if fresh {
                        val = self.values[vi].load();
                        vi += 1;
                    }
                    let vu = v as usize;
                    // single-writer: every destination in this bin is owned
                    // by partition `fp`, which only this worker gathers
                    self.acc[vu].store(self.acc[vu].load() + val);
                    amplify_work(self.work_amplify);
                }
                debug_assert_eq!(vi, vr.end, "bin ({src},{fp}) value walk");
            }
            for u in range.clone() {
                let previous = self.pr[u as usize].load();
                let new = self.base + self.d * self.acc[u as usize].load();
                self.pr[u as usize].store(new);
                thr_err = thr_err.max((new - previous).abs());
            }
            gathered += range.len() as u64;
        }
        ctx.metrics.add_edges(tid, edges);
        ctx.metrics.add_gathered(tid, gathered);
        thr_err
    }

    fn ranks(&self) -> Vec<f64> {
        snapshot(&self.pr)
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::{synthetic, PartitionPolicy};
    use crate::pagerank::{self, seq, PcpmLayout, PrConfig, Variant};

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn matches_sequential_on_cycle() {
        let g = synthetic::cycle(40);
        let c = cfg(4);
        let r = pagerank::run(&g, Variant::Pcpm, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-10, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn matches_sequential_on_web_replica() {
        let g = synthetic::web_replica(800, 6, 17);
        let c = cfg(3);
        let r = pagerank::run(&g, Variant::Pcpm, &c).unwrap();
        assert!(r.converged);
        let (sr, seq_iters, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-9, "l1 {}", r.l1_norm(&sr));
        // synchronous Jacobi schedule: iteration count equals sequential
        assert_eq!(r.iterations, seq_iters);
    }

    #[test]
    fn handles_dangling_vertices() {
        let g = synthetic::chain(20); // tail vertex has outdeg 0
        let c = cfg(2);
        let r = pagerank::run(&g, Variant::Pcpm, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-10);
    }

    #[test]
    fn agrees_with_barrier_schedule() {
        let g = synthetic::social_replica(400, 6, 9);
        let c = cfg(2);
        let pcpm = pagerank::run(&g, Variant::Pcpm, &c).unwrap();
        let barrier = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        assert_eq!(pcpm.iterations, barrier.iterations);
        assert!(
            crate::pagerank::convergence::linf_norm(&pcpm.ranks, &barrier.ranks) < 1e-12
        );
    }

    #[test]
    fn edge_balanced_partitioning_also_correct() {
        let g = synthetic::web_replica(600, 7, 5);
        let c = PrConfig { partition: PartitionPolicy::EdgeBalanced, ..cfg(4) };
        let r = pagerank::run(&g, Variant::Pcpm, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-9);
    }

    #[test]
    fn more_threads_than_vertices() {
        let g = synthetic::cycle(3);
        let c = cfg(8);
        let r = pagerank::run(&g, Variant::Pcpm, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-10);
    }

    /// Within a bin, entries follow ascending source order regardless of
    /// layout or partition count, so every batch size and both layouts
    /// accumulate the exact same float sequence per destination:
    /// bit-identical ranks, identical iteration counts, identical
    /// vertex-update telemetry.
    #[test]
    fn batch_and_layout_are_bit_identical() {
        let g = synthetic::web_replica(700, 6, 23);
        let base = cfg(3);
        let reference = pagerank::run(&g, Variant::Pcpm, &base).unwrap();
        assert!(reference.converged);
        for (batch, layout) in [
            (1, PcpmLayout::Slots),
            (2, PcpmLayout::Compressed),
            (2, PcpmLayout::Slots),
            (5, PcpmLayout::Compressed),
        ] {
            let c = PrConfig { pcpm_batch: batch, pcpm_layout: layout, ..base.clone() };
            let r = pagerank::run(&g, Variant::Pcpm, &c).unwrap();
            assert!(r.converged, "batch={batch} layout={layout}");
            assert_eq!(
                r.iterations, reference.iterations,
                "batch={batch} layout={layout}"
            );
            assert_eq!(
                r.vertex_updates, reference.vertex_updates,
                "batch={batch} layout={layout}: vertex_updates must not depend on layout"
            );
            assert_eq!(
                r.ranks, reference.ranks,
                "batch={batch} layout={layout}: ranks must be bit-identical"
            );
        }
    }

    /// The bin grid is (threads × batch)² ranges, so the kernel (the only
    /// reader of the knob) rejects oversized grids; other variants accept
    /// the same config untouched.
    #[test]
    fn oversized_bin_grid_is_rejected_by_pcpm_only() {
        let g = synthetic::cycle(10);
        let c = PrConfig { pcpm_batch: 200, ..cfg(8) }; // 1600 partitions
        assert!(c.validate().is_ok(), "the knob is legal config in general");
        let err = pagerank::run(&g, Variant::Pcpm, &c);
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("pcpm-batch"));
        // a variant that ignores the knob runs fine
        assert!(pagerank::run(&g, Variant::Barrier, &c).unwrap().converged);
    }

    /// Batching with edge-balanced fine partitions still covers every
    /// vertex exactly once (the fine cut is rebuilt under the same policy).
    #[test]
    fn batched_edge_balanced_matches_sequential() {
        let g = synthetic::web_replica(600, 7, 5);
        let c = PrConfig {
            partition: PartitionPolicy::EdgeBalanced,
            pcpm_batch: 3,
            ..cfg(4)
        };
        let r = pagerank::run(&g, Variant::Pcpm, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-9, "l1 {}", r.l1_norm(&sr));
    }
}
