//! Engine-owned wait-free helping protocol (Algorithm 6's termination and
//! progress machinery).
//!
//! Threads that finish their own partition **help** stalled peers instead of
//! waiting: every vertex of every partition is eventually computed by
//! *someone*, so a sleeping thread costs nothing (Fig 8) and a crashed
//! thread cannot prevent completion (Fig 9).
//!
//! ## Protocol (adapted from the paper's CAS objects; see
//! [`crate::sync::cas_cell`] for the 64-bit reconstruction)
//!
//! * Each vertex is a [`VersionedCell`] whose version *is* its iteration
//!   count (the paper's `PrCASObj`). Any thread may compute a vertex's next
//!   value; `try_advance(iter, value)` admits exactly one winner per
//!   iteration, so duplicated helper work is harmless.
//! * Each partition has a [`PackedProgress`] descriptor `(iter, offset)`
//!   (the paper's `ThreadCASObj`). Helpers **compute first, then CAS the
//!   cursor forward** — a stalled claimer can never strand a vertex.
//! * Per-iteration errors live in a preallocated `err_by_iter` array
//!   (`fetch_max`-merged, idempotent — the paper's `GlobalCASObj.err`
//!   without any reset race).
//! * The iteration of the *system* is the minimum over partition
//!   descriptors; termination is decided from the completed iteration's
//!   error and published through a `done` flag (the paper's
//!   `GlobalCASObj.check` completion set, reformulated so helpers can
//!   finish the bookkeeping of dead threads too).
//!
//! Like the paper's No-Sync (and unlike its Alg 6), ranks are updated in
//! place: all contenders for a vertex in iteration `i` read neighbours that
//! are at iteration `i-1` or `i`, the same relaxation Lemma 1 covers, and
//! the cell CAS keeps exactly one committed value per (vertex, iteration).

use crate::coordinator::faults::FaultPlan;
use crate::coordinator::metrics::RunMetrics;
use crate::engine::inv_out_degrees;
use crate::graph::{Csr, Partitions, VertexId};
use crate::pagerank::{amplify_work, PrConfig};
use crate::sync::atomics::AtomicF64;
use crate::sync::cas_cell::{PackedProgress, VersionedCell};
use crate::sync::snapshot_cells;
use crate::sync::shim::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Shared state of one wait-free run. Construct with [`HelpingState::new`];
/// the engine's Helping driver calls [`HelpingState::drive_worker`] from
/// every worker and reads the outcome accessors afterwards.
pub struct HelpingState<'g> {
    g: &'g Csr,
    inv_out: Vec<f64>,
    cells: Vec<VersionedCell>,
    progress: Vec<PackedProgress>,
    ranges: Vec<std::ops::Range<VertexId>>,
    err_by_iter: Vec<AtomicF64>,
    done: AtomicBool,
    converged: AtomicBool,
    /// Nanoseconds from construction to the `done` decision. Fig 8 measures
    /// *algorithmic* completion: a thread that is still napping after
    /// helpers finished its work must not count against the variant.
    completion_nanos: AtomicU64,
    started: Instant,
    base: f64,
    d: f64,
    threshold: f64,
    max_iterations: u64,
    work_amplify: u32,
}

impl<'g> HelpingState<'g> {
    /// Build the protocol state (the clock starts before preprocessing).
    pub fn new(g: &'g Csr, cfg: &PrConfig, parts: &Partitions) -> Self {
        // Clock starts before the O(n+m) preprocessing below so the
        // algorithmic-completion time includes it, like every other
        // variant's wall time (the engine starts its clock pre-build).
        let started = Instant::now();
        let n = g.num_vertices();
        let threads = cfg.threads;
        // err_by_iter is preallocated (one slot per iteration, no reset
        // races), so the effective cap is clamped: 100k iterations is far
        // beyond any practical convergence and keeps the allocation under
        // 1 MiB.
        let max_iterations = cfg.max_iterations.min(100_000);
        Self {
            g,
            inv_out: inv_out_degrees(g),
            cells: (0..n).map(|_| VersionedCell::new(1.0 / n as f64)).collect(),
            progress: (0..threads).map(|_| PackedProgress::new(0, 0)).collect(),
            ranges: (0..threads).map(|t| parts.range(t)).collect(),
            err_by_iter: (0..=max_iterations as usize)
                .map(|_| AtomicF64::new(0.0))
                .collect(),
            done: AtomicBool::new(false),
            converged: AtomicBool::new(false),
            completion_nanos: AtomicU64::new(0),
            started,
            base: (1.0 - cfg.damping) / n as f64,
            d: cfg.damping,
            threshold: cfg.threshold,
            max_iterations,
            work_amplify: cfg.work_amplify,
        }
    }

    /// One worker's outer loop: own partition first, then help every
    /// partition behind the frontier, then global bookkeeping — the
    /// paper's `computePR` / `UpdateGlobalVariable` sequence.
    pub fn drive_worker(
        &self,
        tid: usize,
        stop: &AtomicBool,
        faults: &FaultPlan,
        metrics: &RunMetrics,
    ) {
        let threads = self.progress.len();
        let mut iter = 0u64;
        while !self.done.load(Ordering::Acquire) && !stop.load(Ordering::Acquire) {
            if faults.apply(tid, iter) {
                return; // crash — helpers will absorb this partition
            }
            // 1. Own partition first (computePR(threadId, threadId, …)).
            self.drive_partition(tid, stop);
            metrics.bump_iteration(tid);
            // 2. Help every partition still behind the frontier
            //    (computePR(thr, threadId, …) for notCompletePR(thr)).
            let my_iter = self.progress[tid].load().0;
            for t in 0..threads {
                if t != tid && self.progress[t].load().0 < my_iter {
                    self.drive_partition(t, stop);
                }
            }
            // 3. Global bookkeeping: advance/terminate if the frontier moved
            //    (UpdateGlobalVariable for self and for lagging peers).
            self.try_finish();
            iter = u64::from(self.progress[tid].load().0);
        }
    }

    /// Compute-and-commit one vertex for iteration `iter` (0-based: the
    /// transition from version `iter` to `iter+1`). Safe to call from any
    /// thread, any number of times.
    fn process_vertex(&self, u: VertexId) {
        let cell = &self.cells[u as usize];
        let (iter, previous) = cell.read();
        let mut sum = 0.0;
        for &v in self.g.in_neighbors(u) {
            sum += self.cells[v as usize].read_value() * self.inv_out[v as usize];
            amplify_work(self.work_amplify);
        }
        let new = self.base + self.d * sum;
        // Publish the delta before committing the cell so a completed
        // iteration always has its full error on record.
        let delta = (new - previous).abs();
        self.err_by_iter[iter as usize].fetch_max(delta);
        cell.try_advance(iter, new); // losing means someone else committed
    }

    /// Drive partition `t` through its current iteration (helping-safe).
    /// Returns when the partition's descriptor has moved past it.
    fn drive_partition(&self, t: usize, stop: &AtomicBool) {
        let range = &self.ranges[t];
        let len = range.len() as u32;
        loop {
            if self.done.load(Ordering::Acquire) || stop.load(Ordering::Acquire) {
                return;
            }
            let (iter, off) = self.progress[t].load();
            if u64::from(iter) >= self.max_iterations {
                return; // cap: also bounds the err_by_iter index space
            }
            if off >= len {
                // partition finished its current iteration; roll the
                // descriptor to the next one
                self.progress[t].try_advance((iter, off), (iter + 1, 0));
                return;
            }
            let u = range.start + off;
            // Compute first (idempotent), then claim the cursor step. If the
            // CAS fails another helper advanced it — retry from the fresh
            // descriptor.
            if self.cells[u as usize].iteration() <= u64::from(iter) {
                self.process_vertex(u);
            }
            self.progress[t].try_advance((iter, off), (iter, off + 1));
        }
    }

    /// System iteration = min over partition descriptors.
    fn min_iter(&self) -> u32 {
        (0..self.progress.len())
            .map(|t| self.progress[t].load().0)
            .min()
            .unwrap_or(0)
    }

    /// Check termination after an iteration finished everywhere.
    fn try_finish(&self) {
        let min = self.min_iter();
        if min == 0 {
            return;
        }
        let completed = min - 1;
        let err = self.err_by_iter[completed as usize].load_acquire();
        if err <= self.threshold {
            self.converged.store(true, Ordering::Release);
            self.finish();
        } else if u64::from(min) >= self.max_iterations {
            self.finish();
        }
    }

    fn finish(&self) {
        if !self.done.swap(true, Ordering::AcqRel) {
            let nanos = self.started.elapsed().as_nanos() as u64;
            self.completion_nanos.store(nanos.max(1), Ordering::Release);
        }
    }

    // ── outcome accessors (read by the engine driver after the join) ──

    /// Completed system iterations.
    pub fn system_iteration(&self) -> u64 {
        u64::from(self.min_iter())
    }

    /// Did the run terminate below the threshold (vs. hitting the cap)?
    pub fn is_converged(&self) -> bool {
        self.converged.load(Ordering::Acquire)
    }

    /// Algorithmic completion time, when the `done` decision was recorded.
    pub fn completion(&self) -> Option<Duration> {
        let nanos = self.completion_nanos.load(Ordering::Acquire);
        (nanos > 0).then(|| Duration::from_nanos(nanos))
    }

    /// Snapshot the rank cells.
    pub fn ranks(&self) -> Vec<f64> {
        snapshot_cells(&self.cells)
    }
}
