//! Incremental PageRank over graph mutations.
//!
//! Asynchronous iteration converges to the same fixed point from *any*
//! starting vector (Kollias et al., arXiv:cs/0606047), so after an edge
//! batch mutates the graph there is no need to recompute from the uniform
//! vector: resume from the previous ranks and re-gather only the vertices
//! the mutation could have disturbed. The frontier kernels
//! ([`crate::engine::frontier`]) already schedule exactly that way — this
//! module supplies the warm-started entry points that connect them to
//! [`crate::graph::GraphDelta`]:
//!
//! 1. [`seed_frontier`] turns the touched-vertex set of an applied delta
//!    into a [`DirtyFlags`] seed: each touched vertex (its in-list, degree,
//!    or both may have changed, so its rank must be re-gathered) plus its
//!    out-neighbourhood (a source's degree change rescales the
//!    `pr(v)/outdeg(v)` contribution every out-neighbour reads).
//! 2. [`reconverge`] runs a frontier kernel warm-started from the previous
//!    ranks with that seed, through the ordinary NonBlocking driver —
//!    termination, confirmation sweeps, and DNF handling are unchanged.
//! 3. [`mutate_and_reconverge`] is the one-call bundle the serving layer
//!    and CLI use: apply the delta, seed, reconverge.
//!
//! The returned [`PrResult`] reports `vertex_updates` for the delta
//! convergence only, so the incremental saving is directly measurable
//! against a cold run (the property suite asserts it is strictly cheaper;
//! `bench-ci` tracks it as ablation rows).

use crate::engine::{driver, frontier};
use crate::graph::{Csr, GraphDelta, Partitions, VertexId};
use crate::pagerank::{PrConfig, PrResult, Variant};
use crate::sync::dirty::DirtyFlags;
use anyhow::{bail, Result};
use std::time::Instant;

/// Build the dirty-bitmap seed for an incremental reconvergence: every
/// vertex in `touched` plus its out-neighbours. `touched` holds the
/// endpoints of all mutated edges (see
/// [`AppliedDelta::touched`](crate::graph::AppliedDelta)); the
/// out-neighbour closure covers the contribution rescale when a source's
/// out-degree changed.
pub fn seed_frontier(g: &Csr, touched: &[VertexId]) -> DirtyFlags {
    let dirty = DirtyFlags::new_clear(g.num_vertices());
    // `touched` arrives sorted+deduped (AppliedDelta builds it that way),
    // so consecutive ids collapse into word-wide `set_range` bulk marks —
    // an edge batch hitting a dense id range seeds in O(range/64) instead
    // of one CAS per vertex. Out-neighbour closures stay per-vertex (their
    // adjacency lists are arbitrary sets).
    let mut i = 0;
    while i < touched.len() {
        let mut j = i + 1;
        while j < touched.len() && touched[j] == touched[j - 1] + 1 {
            j += 1;
        }
        dirty.set_range(touched[i]..touched[j - 1] + 1);
        for &u in &touched[i..j] {
            for &w in g.out_neighbors(u) {
                dirty.set(w);
            }
        }
        i = j;
    }
    dirty
}

/// Reconverge `g` from the `warm` rank vector after a mutation that
/// disturbed `touched`, using a frontier-scheduled kernel. Only
/// [`Variant::Frontier`] and [`Variant::FrontierPcpm`] support warm starts
/// (the full-sweep kernels would re-gather everything anyway); other
/// variants are an error. The reported wall time covers seeding, kernel
/// construction (including the PCPM scatter-plan rebuild), and the solve.
pub fn reconverge(
    g: &Csr,
    variant: Variant,
    cfg: &PrConfig,
    warm: &[f64],
    touched: &[VertexId],
) -> Result<PrResult> {
    cfg.validate()?;
    if g.num_vertices() == 0 {
        return Ok(PrResult::empty(variant, cfg.threads));
    }
    let parts = Partitions::new(g, cfg.threads, cfg.partition);
    let start = Instant::now();
    let dirty = seed_frontier(g, touched);
    let kernel = match variant {
        Variant::Frontier => frontier::warm_kernel(g, cfg, &parts, warm, dirty)?,
        Variant::FrontierPcpm => frontier::warm_pcpm_kernel(g, cfg, &parts, warm, dirty)?,
        other => bail!("{other} does not support incremental reconvergence; \
                        use frontier or frontier-pcpm"),
    };
    driver::execute(variant, cfg, kernel.as_ref(), start)
}

/// Outcome of [`mutate_and_reconverge`]: the mutated graph and the
/// reconverged ranks.
#[derive(Debug)]
pub struct IncrementalRun {
    /// The graph after the delta was applied.
    pub graph: Csr,
    /// The reconverged solve (ranks, iterations, `vertex_updates`, …).
    pub result: PrResult,
    /// Number of touched vertices the frontier was seeded from.
    pub touched: usize,
}

/// Apply `delta` to `base` and reconverge from the `warm` ranks in one
/// call — the serving layer's epoch step.
pub fn mutate_and_reconverge(
    base: &Csr,
    delta: &GraphDelta,
    variant: Variant,
    cfg: &PrConfig,
    warm: &[f64],
) -> Result<IncrementalRun> {
    let applied = base.apply_delta(delta)?;
    let result = reconverge(&applied.graph, variant, cfg, warm, &applied.touched)?;
    Ok(IncrementalRun { graph: applied.graph, result, touched: applied.touched.len() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic;
    use crate::pagerank;

    fn cfg() -> PrConfig {
        PrConfig { threads: 3, threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn seed_covers_touched_and_out_neighbourhoods() {
        let g = synthetic::cycle(10); // u → u+1
        let dirty = seed_frontier(&g, &[3, 7]);
        for v in 0..10u32 {
            assert_eq!(
                dirty.is_set(v),
                matches!(v, 3 | 4 | 7 | 8),
                "vertex {v}"
            );
        }
    }

    /// The `set_range` fast path: maximal consecutive runs in the sorted
    /// touched list must mark exactly the same bits as per-vertex sets.
    #[test]
    fn seed_bulk_marks_consecutive_runs() {
        let g = synthetic::cycle(130); // u → u+1 (mod 130)
        let touched: Vec<u32> = (10..80).chain([100, 101, 120]).collect();
        let dirty = seed_frontier(&g, &touched);
        for v in 0..130u32 {
            let expect = (10..=80).contains(&v)
                || (100..=102).contains(&v)
                || v == 120
                || v == 121;
            assert_eq!(dirty.is_set(v), expect, "vertex {v}");
        }
    }

    #[test]
    fn empty_seed_converges_immediately_from_fixed_point() {
        let g = synthetic::web_replica(300, 5, 17);
        let c = cfg();
        let cold = pagerank::run(&g, Variant::Frontier, &c).unwrap();
        // No mutation, no touched set: the warm ranks are already the fixed
        // point and the frontier is empty — only confirmation sweeps run.
        let warm = reconverge(&g, Variant::Frontier, &c, &cold.ranks, &[]).unwrap();
        assert!(warm.converged);
        assert!(warm.l1_norm(&cold.ranks) < 1e-12);
        assert_eq!(warm.vertex_updates, 0, "nothing was dirty");
    }

    #[test]
    fn non_frontier_variant_is_rejected() {
        let g = synthetic::cycle(6);
        let warm = vec![1.0 / 6.0; 6];
        let err = reconverge(&g, Variant::Barrier, &cfg(), &warm, &[0]);
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("frontier"));
    }

    #[test]
    fn empty_graph_short_circuits() {
        let g = crate::graph::GraphBuilder::new(0).build("nil");
        let r = reconverge(&g, Variant::Frontier, &cfg(), &[], &[]).unwrap();
        assert!(r.converged);
        assert!(r.ranks.is_empty());
    }

    #[test]
    fn mutate_and_reconverge_tracks_cold_recompute() {
        let base = synthetic::web_replica(400, 5, 29);
        let c = cfg();
        let cold_base = pagerank::run(&base, Variant::Frontier, &c).unwrap();
        let delta = GraphDelta::random(&base, 6, 3, 99);
        for v in [Variant::Frontier, Variant::FrontierPcpm] {
            let inc = mutate_and_reconverge(&base, &delta, v, &c, &cold_base.ranks).unwrap();
            assert!(inc.result.converged, "{v}");
            assert!(inc.touched > 0, "{v}");
            let oracle = pagerank::run(&inc.graph, Variant::Barrier, &c).unwrap();
            let l1 = inc.result.l1_norm(&oracle.ranks);
            assert!(l1 < 1e-6, "{v}: l1 {l1}");
        }
    }
}
