//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them from the Rust hot path.
//!
//! The interchange format is **HLO text** (not a serialized
//! `HloModuleProto`): jax ≥ 0.5 emits protos with 64-bit instruction ids
//! that the crate's XLA (xla_extension 0.5.1) rejects, while the text
//! parser reassigns ids — see `/opt/xla-example/README.md` and
//! DESIGN.md §Hardware-Adaptation.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire request-path dependency surface: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`.

pub mod artifacts;
pub mod executable;

pub use artifacts::{ArtifactKind, ArtifactSpec};
pub use executable::{Engine, LoadedStep};
