//! Artifact discovery: the AOT pipeline writes one HLO-text file per
//! (kernel, shape-bucket) pair with the parameters encoded in the filename,
//! so the Rust side needs no side-channel manifest:
//!
//! * `ell_n{N}_k{K}.hlo.txt`   — Pallas ELL gather step for ≤N vertices
//!   with in-degree ≤K (the Layer-1 kernel lowered through the Layer-2
//!   model);
//! * `dense_n{N}.hlo.txt`      — dense matmul step for ≤N vertices;
//! * `dense_power_n{N}_t{T}.hlo.txt` — T fused power iterations
//!   (`lax.scan`), used by the runtime bench to amortize dispatch.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// What computation an artifact implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// One ELL-format PageRank step: `(indices i32[N,K], weights f32[N,K],
    /// pr f32[N], base f32[1]) -> f32[N]`.
    EllStep,
    /// One dense step: `(matrix f32[N,N], pr f32[N], base f32[1]) -> f32[N]`.
    DenseStep,
    /// `T` fused dense steps.
    DensePower,
}

/// A discovered artifact and its shape bucket.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    /// The computation this artifact implements.
    pub kind: ArtifactKind,
    /// Location of the HLO text file.
    pub path: PathBuf,
    /// Max vertices.
    pub n: usize,
    /// Max in-degree (ELL only; 0 otherwise).
    pub k: usize,
    /// Fused steps (DensePower only; 1 otherwise).
    pub t: usize,
}

impl ArtifactSpec {
    /// Parse a filename like `ell_n1024_k32.hlo.txt`.
    pub fn from_path(path: &Path) -> Result<Self> {
        let stem = path
            .file_name()
            .and_then(|s| s.to_str())
            .context("non-utf8 artifact name")?
            .strip_suffix(".hlo.txt")
            .context("artifact must end in .hlo.txt")?;
        let mut parts = stem.split('_');
        let kind = match parts.next() {
            Some("ell") => ArtifactKind::EllStep,
            Some("dense") => {
                // `dense_n64` or `dense_power_n256_t8`
                ArtifactKind::DenseStep
            }
            other => bail!("unknown artifact kind {other:?} in {stem}"),
        };
        let rest: Vec<&str> = parts.collect();
        let (kind, fields) = if kind == ArtifactKind::DenseStep && rest.first() == Some(&"power") {
            (ArtifactKind::DensePower, &rest[1..])
        } else {
            (kind, &rest[..])
        };
        let mut n = 0usize;
        let mut k = 0usize;
        let mut t = 1usize;
        for f in fields {
            if let Some(v) = f.strip_prefix('n') {
                n = v.parse().with_context(|| format!("bad n in {stem}"))?;
            } else if let Some(v) = f.strip_prefix('k') {
                k = v.parse().with_context(|| format!("bad k in {stem}"))?;
            } else if let Some(v) = f.strip_prefix('t') {
                t = v.parse().with_context(|| format!("bad t in {stem}"))?;
            } else {
                bail!("unknown field '{f}' in artifact {stem}");
            }
        }
        if n == 0 {
            bail!("artifact {stem} missing n");
        }
        if kind == ArtifactKind::EllStep && k == 0 {
            bail!("ELL artifact {stem} missing k");
        }
        Ok(Self { kind, path: path.to_path_buf(), n, k, t })
    }

    /// Scan a directory for artifacts (ignores unknown files).
    pub fn discover(dir: &Path) -> Result<Vec<ArtifactSpec>> {
        let mut specs = Vec::new();
        if !dir.exists() {
            return Ok(specs);
        }
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("txt") {
                continue;
            }
            if let Ok(spec) = ArtifactSpec::from_path(&path) {
                specs.push(spec);
            }
        }
        specs.sort_by_key(|s| (s.n, s.k, s.t));
        Ok(specs)
    }

    /// Smallest ELL bucket that fits a graph with `n` vertices and max
    /// in-degree `k`.
    pub fn best_ell(specs: &[ArtifactSpec], n: usize, k: usize) -> Option<&ArtifactSpec> {
        specs
            .iter()
            .filter(|s| s.kind == ArtifactKind::EllStep && s.n >= n && s.k >= k)
            .min_by_key(|s| (s.n, s.k))
    }

    /// Smallest dense bucket that fits `n` vertices.
    pub fn best_dense(specs: &[ArtifactSpec], n: usize) -> Option<&ArtifactSpec> {
        specs
            .iter()
            .filter(|s| s.kind == ArtifactKind::DenseStep && s.n >= n)
            .min_by_key(|s| s.n)
    }
}

/// Default artifact directory: `$PAGERANK_NB_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("PAGERANK_NB_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_ell() {
        let s = ArtifactSpec::from_path(Path::new("artifacts/ell_n1024_k32.hlo.txt")).unwrap();
        assert_eq!(s.kind, ArtifactKind::EllStep);
        assert_eq!((s.n, s.k, s.t), (1024, 32, 1));
    }

    #[test]
    fn parse_dense_and_power() {
        let s = ArtifactSpec::from_path(Path::new("dense_n64.hlo.txt")).unwrap();
        assert_eq!(s.kind, ArtifactKind::DenseStep);
        assert_eq!((s.n, s.k, s.t), (64, 0, 1));
        let p = ArtifactSpec::from_path(Path::new("dense_power_n256_t8.hlo.txt")).unwrap();
        assert_eq!(p.kind, ArtifactKind::DensePower);
        assert_eq!((p.n, p.t), (256, 8));
    }

    #[test]
    fn rejects_malformed() {
        assert!(ArtifactSpec::from_path(Path::new("bogus.hlo.txt")).is_err());
        assert!(ArtifactSpec::from_path(Path::new("ell_n16.hlo.txt")).is_err()); // no k
        assert!(ArtifactSpec::from_path(Path::new("ell_k8.hlo.txt")).is_err()); // no n
        assert!(ArtifactSpec::from_path(Path::new("model.bin")).is_err());
    }

    #[test]
    fn bucket_selection_prefers_smallest_fit() {
        let mk = |n, k| ArtifactSpec {
            kind: ArtifactKind::EllStep,
            path: PathBuf::new(),
            n,
            k,
            t: 1,
        };
        let specs = vec![mk(256, 16), mk(1024, 32), mk(4096, 64)];
        assert_eq!(ArtifactSpec::best_ell(&specs, 200, 10).unwrap().n, 256);
        assert_eq!(ArtifactSpec::best_ell(&specs, 300, 10).unwrap().n, 1024);
        assert_eq!(ArtifactSpec::best_ell(&specs, 200, 20).unwrap().n, 1024);
        assert!(ArtifactSpec::best_ell(&specs, 5000, 10).is_none());
        assert!(ArtifactSpec::best_ell(&specs, 100, 100).is_none());
    }

    #[test]
    fn discover_ignores_junk() {
        let dir = std::env::temp_dir().join("pagerank_nb_artifact_tests");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("ell_n256_k16.hlo.txt"), "hlo").unwrap();
        std::fs::write(dir.join("README.txt"), "not an artifact").unwrap();
        std::fs::write(dir.join("notes.md"), "junk").unwrap();
        let specs = ArtifactSpec::discover(&dir).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].n, 256);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn discover_missing_dir_is_empty() {
        let specs = ArtifactSpec::discover(Path::new("/nonexistent/x9q")).unwrap();
        assert!(specs.is_empty());
    }
}
