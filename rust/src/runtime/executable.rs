//! The PJRT engine: compile HLO-text artifacts once, execute many times.
//!
//! Mirrors `/opt/xla-example/src/bin/load_hlo.rs`, wrapped for the
//! coordinator: an [`Engine`] owns the CPU `PjRtClient` and a compile cache
//! keyed by artifact path; a [`LoadedStep`] is one compiled PageRank step
//! executable with typed `run_*` entry points.

use crate::runtime::artifacts::{ArtifactKind, ArtifactSpec};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// PJRT client + compile cache. One per process is plenty (CPU platform).
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<LoadedStep>>>,
}

impl Engine {
    /// Create a CPU engine.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, cache: Mutex::new(HashMap::new()) })
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached by path).
    pub fn load(&self, spec: &ArtifactSpec) -> Result<Arc<LoadedStep>> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(m) = cache.get(&spec.path) {
                return Ok(Arc::clone(m));
            }
        }
        let loaded = Arc::new(LoadedStep::compile(&self.client, spec)?);
        self.cache
            .lock()
            .unwrap()
            .insert(spec.path.clone(), Arc::clone(&loaded));
        Ok(loaded)
    }

    /// Convenience: discover artifacts in `dir` and load the best ELL
    /// bucket for an (n, max-in-degree) workload.
    pub fn load_best_ell(&self, dir: &Path, n: usize, k: usize) -> Result<Arc<LoadedStep>> {
        let specs = ArtifactSpec::discover(dir)?;
        let spec = ArtifactSpec::best_ell(&specs, n, k).with_context(|| {
            format!(
                "no ELL artifact for n={n}, k={k} in {} ({} artifacts found) — run `make artifacts`",
                dir.display(),
                specs.len()
            )
        })?;
        self.load(spec)
    }
}

/// One compiled PageRank-step executable.
pub struct LoadedStep {
    exe: xla::PjRtLoadedExecutable,
    /// The artifact this executable was compiled from.
    pub spec: ArtifactSpec,
}

impl LoadedStep {
    fn compile(client: &xla::PjRtClient, spec: &ArtifactSpec) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(&spec.path).with_context(|| {
            format!("parsing HLO text {} (re-run `make artifacts`?)", spec.path.display())
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.path.display()))?;
        Ok(Self { exe, spec: spec.clone() })
    }

    fn execute(&self, args: &[xla::Literal]) -> Result<Vec<f32>> {
        let result = self.exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Run one ELL step: `pr' = base + Σ_k weights[u,k] · pr[indices[u,k]]`.
    ///
    /// `indices`/`weights` are row-major `[n, k]` for this artifact's
    /// bucket; `pr` has length `n`; `base` is `(1-d)/n_actual`.
    pub fn run_ell(
        &self,
        indices: &[i32],
        weights: &[f32],
        pr: &[f32],
        base: f32,
    ) -> Result<Vec<f32>> {
        if self.spec.kind != ArtifactKind::EllStep {
            bail!("artifact {} is not an ELL step", self.spec.path.display());
        }
        let (n, k) = (self.spec.n, self.spec.k);
        if indices.len() != n * k || weights.len() != n * k || pr.len() != n {
            bail!(
                "shape mismatch: bucket ({n},{k}), got idx {}, w {}, pr {}",
                indices.len(),
                weights.len(),
                pr.len()
            );
        }
        let idx = xla::Literal::vec1(indices).reshape(&[n as i64, k as i64])?;
        let w = xla::Literal::vec1(weights).reshape(&[n as i64, k as i64])?;
        let p = xla::Literal::vec1(pr);
        let b = xla::Literal::vec1(&[base]);
        self.execute(&[idx, w, p, b])
    }

    /// Run one dense step: `pr' = base + M · pr`.
    pub fn run_dense(&self, matrix: &[f32], pr: &[f32], base: f32) -> Result<Vec<f32>> {
        if !matches!(self.spec.kind, ArtifactKind::DenseStep | ArtifactKind::DensePower) {
            bail!("artifact {} is not a dense step", self.spec.path.display());
        }
        let n = self.spec.n;
        if matrix.len() != n * n || pr.len() != n {
            bail!("shape mismatch: bucket {n}, got m {}, pr {}", matrix.len(), pr.len());
        }
        let m = xla::Literal::vec1(matrix).reshape(&[n as i64, n as i64])?;
        let p = xla::Literal::vec1(pr);
        let b = xla::Literal::vec1(&[base]);
        self.execute(&[m, p, b])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full engine tests need `make artifacts` and live in
    // rust/tests/integration_runtime.rs; here we only cover cheap pieces.

    #[test]
    fn engine_creates_cpu_client() {
        let e = Engine::cpu().expect("PJRT CPU client");
        assert_eq!(e.platform(), "cpu");
    }

    #[test]
    fn load_best_ell_errors_without_artifacts() {
        let e = Engine::cpu().unwrap();
        let err = match e.load_best_ell(Path::new("/nonexistent/artifacts"), 10, 4) {
            Err(e) => e,
            Ok(_) => panic!("expected artifact-discovery error"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
