//! Execution coordination: the worker pool all parallel variants run on,
//! deterministic fault injection (the paper's sleeping/failing case
//! studies), run metrics, and host introspection.

pub mod executor;
pub mod faults;
pub mod host;
pub mod metrics;

pub use executor::run_workers;
pub use faults::{FaultAction, FaultPlan};
