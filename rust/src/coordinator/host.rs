//! Host introspection for reproducibility records: every report in
//! EXPERIMENTS.md carries the parallelism and platform it was measured on,
//! because the paper's absolute numbers come from a 56-core Xeon and ours
//! come from whatever this container gives us.

/// Host description embedded in report notes.
#[derive(Debug, Clone)]
pub struct HostInfo {
    /// Hardware threads reported by the OS.
    pub available_parallelism: usize,
    /// Operating system name (`std::env::consts::OS`).
    pub os: String,
    /// CPU architecture (`std::env::consts::ARCH`).
    pub arch: String,
}

impl HostInfo {
    /// Probe the current host.
    pub fn detect() -> Self {
        Self {
            available_parallelism: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            os: std::env::consts::OS.to_string(),
            arch: std::env::consts::ARCH.to_string(),
        }
    }

    /// Thread counts to sweep in the Fig 3/4 reproduction: powers of two up
    /// to 2× the host parallelism (the paper sweeps 1..56; oversubscribing
    /// 2× shows the same flattening shape on small hosts).
    pub fn thread_sweep(&self) -> Vec<usize> {
        let mut v = vec![1usize];
        let cap = (self.available_parallelism * 2).max(8).min(64);
        let mut t = 2;
        while t <= cap {
            v.push(t);
            t *= 2;
        }
        v
    }

    /// Default thread count for fixed-thread figures (the paper pins 56).
    pub fn default_threads(&self) -> usize {
        self.available_parallelism.clamp(1, 64)
    }

    /// One-line host summary for report notes.
    pub fn describe(&self) -> String {
        format!(
            "host: {} {}, {} hardware threads (paper: 56-core Xeon E5-2660 v4)",
            self.os, self.arch, self.available_parallelism
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detect_gives_sane_values() {
        let h = HostInfo::detect();
        assert!(h.available_parallelism >= 1);
        assert!(!h.os.is_empty());
        assert!(!h.arch.is_empty());
    }

    #[test]
    fn sweep_starts_at_one_and_is_increasing() {
        let h = HostInfo { available_parallelism: 4, os: "t".into(), arch: "t".into() };
        let s = h.thread_sweep();
        assert_eq!(s[0], 1);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(s.contains(&8));
    }

    #[test]
    fn describe_mentions_paper_testbed() {
        assert!(HostInfo::detect().describe().contains("56-core"));
    }
}
