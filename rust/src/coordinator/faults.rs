//! Deterministic fault injection — the substrate behind the paper's two
//! case studies (§5.3):
//!
//! * **Sleeping variants** (Fig 8): "predetermined steps of calling sleep
//!   function to threads during selected iterations" — model a straggler.
//! * **Failing variants** (Fig 9): "failures to the threads were added
//!   deterministically during the end of the initial iteration" — model a
//!   crashed thread.
//!
//! Workers consult [`FaultPlan::action`] at the top of every outer
//! iteration; faults therefore land at iteration boundaries, matching the
//! paper's methodology (and the commit-window caveat documented in
//! [`crate::sync::cas_cell`]).

use std::time::Duration;

/// What a worker must do at an iteration boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Keep computing.
    None,
    /// Sleep for the given duration, then continue (straggler).
    Sleep(Duration),
    /// Stop participating immediately (crash).
    Fail,
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct SleepSpec {
    thread: usize,
    iteration: u64,
    duration: Duration,
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct FailSpec {
    thread: usize,
    iteration: u64,
}

/// A deterministic schedule of sleeps and failures.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    sleeps: Vec<SleepSpec>,
    failures: Vec<FailSpec>,
}

impl FaultPlan {
    /// The empty plan (no faults).
    pub fn none() -> Self {
        Self::default()
    }

    /// Add a sleep for `thread` at the start of `iteration`.
    pub fn sleep_at(mut self, thread: usize, iteration: u64, duration: Duration) -> Self {
        self.sleeps.push(SleepSpec { thread, iteration, duration });
        self
    }

    /// Add a crash for `thread` at the start of `iteration` (iteration 1 =
    /// "end of the initial iteration" in the paper's phrasing).
    pub fn fail_at(mut self, thread: usize, iteration: u64) -> Self {
        self.failures.push(FailSpec { thread, iteration });
        self
    }

    /// Crash the first `k` worker threads at the end of iteration 0 —
    /// exactly the Fig 9 scenario.
    pub fn fail_first_k(k: usize) -> Self {
        let mut plan = Self::none();
        for t in 0..k {
            plan = plan.fail_at(t, 1);
        }
        plan
    }

    /// No sleeps and no failures scheduled?
    pub fn is_empty(&self) -> bool {
        self.sleeps.is_empty() && self.failures.is_empty()
    }

    /// Is at least one crash scheduled?
    pub fn has_failures(&self) -> bool {
        !self.failures.is_empty()
    }

    /// Number of distinct threads scheduled to fail.
    pub fn failing_threads(&self) -> usize {
        let mut t: Vec<usize> = self.failures.iter().map(|f| f.thread).collect();
        t.sort_unstable();
        t.dedup();
        t.len()
    }

    /// Decide the action for `thread` entering `iteration`. Failure wins
    /// over sleep if both are scheduled at the same point.
    pub fn action(&self, thread: usize, iteration: u64) -> FaultAction {
        if self
            .failures
            .iter()
            .any(|f| f.thread == thread && f.iteration == iteration)
        {
            return FaultAction::Fail;
        }
        if let Some(s) = self
            .sleeps
            .iter()
            .find(|s| s.thread == thread && s.iteration == iteration)
        {
            return FaultAction::Sleep(s.duration);
        }
        FaultAction::None
    }

    /// Apply the action in-place: sleeps block the calling thread; returns
    /// `true` when the thread must die.
    pub fn apply(&self, thread: usize, iteration: u64) -> bool {
        match self.action(thread, iteration) {
            FaultAction::None => false,
            FaultAction::Sleep(d) => {
                std::thread::sleep(d);
                false
            }
            FaultAction::Fail => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_never_acts() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        for t in 0..4 {
            for i in 0..10 {
                assert_eq!(p.action(t, i), FaultAction::None);
            }
        }
    }

    #[test]
    fn sleep_targets_exact_thread_and_iteration() {
        let p = FaultPlan::none().sleep_at(2, 5, Duration::from_millis(10));
        assert_eq!(p.action(2, 5), FaultAction::Sleep(Duration::from_millis(10)));
        assert_eq!(p.action(2, 4), FaultAction::None);
        assert_eq!(p.action(1, 5), FaultAction::None);
    }

    #[test]
    fn fail_beats_sleep() {
        let p = FaultPlan::none()
            .sleep_at(0, 1, Duration::from_secs(1))
            .fail_at(0, 1);
        assert_eq!(p.action(0, 1), FaultAction::Fail);
    }

    #[test]
    fn fail_first_k_schedules_k_threads() {
        let p = FaultPlan::fail_first_k(3);
        assert_eq!(p.failing_threads(), 3);
        assert_eq!(p.action(0, 1), FaultAction::Fail);
        assert_eq!(p.action(2, 1), FaultAction::Fail);
        assert_eq!(p.action(3, 1), FaultAction::None);
        assert_eq!(p.action(0, 0), FaultAction::None);
    }

    #[test]
    fn apply_sleep_actually_sleeps() {
        let p = FaultPlan::none().sleep_at(0, 0, Duration::from_millis(25));
        let t0 = std::time::Instant::now();
        assert!(!p.apply(0, 0));
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn apply_fail_returns_true() {
        let p = FaultPlan::none().fail_at(1, 2);
        assert!(p.apply(1, 2));
        assert!(!p.apply(1, 1));
    }
}
