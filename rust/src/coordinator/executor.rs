//! Scoped worker pool with a DNF watchdog.
//!
//! Every parallel variant funnels through [`run_workers`]: spawn `p` workers
//! (paper §2.2's "limited set of p threads"), monitor from the calling
//! thread, and — when a `dnf_timeout` is configured — abort any registered
//! barriers and raise the shared stop flag if the run wedges. That is what
//! turns "a failed thread deadlocks the Barrier algorithm" (Fig 9) into a
//! recordable DNF instead of a hung benchmark harness.

use crate::sync::barrier::SenseBarrier;
use crate::sync::shim::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Outcome of a pool run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolOutcome {
    /// The watchdog fired: the run did not finish on its own.
    pub dnf: bool,
}

/// Spawn `threads` workers running `work(tid, stop)`; monitor from the
/// calling thread.
///
/// * `stop` is a cooperative cancellation flag — workers must poll it in
///   their outer loop (non-blocking variants) so the watchdog can cut
///   livelocks (e.g. No-Sync waiting on a crashed peer's error slot).
/// * `barriers` are aborted on timeout so blocking variants unwind too.
/// * Worker panics propagate after all workers are joined.
pub fn run_workers<F>(
    threads: usize,
    dnf_timeout: Option<Duration>,
    barriers: &[&SenseBarrier],
    work: F,
) -> PoolOutcome
where
    F: Fn(usize, &AtomicBool) + Sync,
{
    assert!(threads > 0);
    let stop = AtomicBool::new(false);
    let finished = AtomicUsize::new(0);
    let dnf = AtomicBool::new(false);
    std::thread::scope(|s| {
        for tid in 0..threads {
            let work = &work;
            let stop = &stop;
            let finished = &finished;
            s.spawn(move || {
                work(tid, stop);
                finished.fetch_add(1, Ordering::AcqRel);
            });
        }
        if let Some(limit) = dnf_timeout {
            let deadline = Instant::now() + limit;
            while finished.load(Ordering::Acquire) < threads {
                if Instant::now() >= deadline {
                    dnf.store(true, Ordering::Release);
                    stop.store(true, Ordering::Release);
                    for b in barriers {
                        b.abort();
                    }
                    break;
                }
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        // scope joins all workers here; after an abort they unwind quickly
    });
    PoolOutcome { dnf: dnf.load(Ordering::Acquire) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_workers_run_with_distinct_ids() {
        let seen = AtomicUsize::new(0);
        let out = run_workers(4, None, &[], |tid, _stop| {
            seen.fetch_add(1 << tid, Ordering::SeqCst);
        });
        assert!(!out.dnf);
        assert_eq!(seen.load(Ordering::SeqCst), 0b1111);
    }

    #[test]
    fn watchdog_cuts_livelock_and_reports_dnf() {
        let out = run_workers(
            2,
            Some(Duration::from_millis(50)),
            &[],
            |tid, stop| {
                if tid == 0 {
                    return; // "crashed" worker
                }
                // live worker spins until the watchdog stops it
                while !stop.load(Ordering::Acquire) {
                    std::hint::spin_loop();
                    std::thread::yield_now();
                }
            },
        );
        assert!(out.dnf);
    }

    #[test]
    fn watchdog_aborts_barriers() {
        let barrier = SenseBarrier::new(2);
        let out = run_workers(
            2,
            Some(Duration::from_millis(50)),
            &[&barrier],
            |tid, _stop| {
                if tid == 0 {
                    return; // never arrives at the barrier
                }
                let mut w = barrier.waiter();
                let r = w.wait();
                assert!(r.is_aborted());
            },
        );
        assert!(out.dnf);
    }

    #[test]
    fn fast_completion_does_not_dnf() {
        let out = run_workers(3, Some(Duration::from_secs(5)), &[], |_tid, _stop| {
            std::thread::sleep(Duration::from_millis(5));
        });
        assert!(!out.dnf);
    }

    #[test]
    fn no_timeout_waits_for_everyone() {
        let counter = AtomicUsize::new(0);
        let out = run_workers(3, None, &[], |_tid, _stop| {
            std::thread::sleep(Duration::from_millis(20));
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert!(!out.dnf);
        assert_eq!(counter.load(Ordering::SeqCst), 3);
    }
}
