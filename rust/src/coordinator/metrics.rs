//! Per-run telemetry: per-thread iteration counters and phase timers.
//!
//! The paper reports per-variant iteration counts (Fig 7) and the speedup
//! argument hinges on *where time goes* (compute vs. barrier wait); this
//! module provides the shared counters the workers bump and the harness
//! reads.

use crate::sync::shim::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// One slot per worker thread; counters are relaxed (telemetry only).
pub struct RunMetrics {
    iterations: Vec<AtomicU64>,
    edges_processed: Vec<AtomicU64>,
    vertices_skipped: Vec<AtomicU64>,
    vertices_gathered: Vec<AtomicU64>,
    started: Instant,
}

impl RunMetrics {
    /// Zeroed counters for `threads` workers; the clock starts now.
    pub fn new(threads: usize) -> Self {
        Self {
            iterations: (0..threads).map(|_| AtomicU64::new(0)).collect(),
            edges_processed: (0..threads).map(|_| AtomicU64::new(0)).collect(),
            vertices_skipped: (0..threads).map(|_| AtomicU64::new(0)).collect(),
            vertices_gathered: (0..threads).map(|_| AtomicU64::new(0)).collect(),
            started: Instant::now(),
        }
    }

    /// Count one completed sweep for `thread`.
    #[inline]
    pub fn bump_iteration(&self, thread: usize) {
        // relaxed: monotonic telemetry counter; readers tolerate staleness
        self.iterations[thread].fetch_add(1, Ordering::Relaxed);
    }

    /// Count `count` edges processed by `thread`.
    #[inline]
    pub fn add_edges(&self, thread: usize, count: u64) {
        // relaxed: monotonic telemetry counter; readers tolerate staleness
        self.edges_processed[thread].fetch_add(count, Ordering::Relaxed);
    }

    /// Perforation variants count vertices they froze (node-level
    /// convergence savings).
    #[inline]
    pub fn add_skipped(&self, thread: usize, count: u64) {
        // relaxed: monotonic telemetry counter; readers tolerate staleness
        self.vertices_skipped[thread].fetch_add(count, Ordering::Relaxed);
    }

    /// Vertex updates this sweep actually computed — the work metric the
    /// frontier/delta kernels reduce (reported as
    /// [`crate::pagerank::PrResult::vertex_updates`]).
    #[inline]
    pub fn add_gathered(&self, thread: usize, count: u64) {
        // relaxed: monotonic telemetry counter; readers tolerate staleness
        self.vertices_gathered[thread].fetch_add(count, Ordering::Relaxed);
    }

    /// Vertex updates performed by one thread so far (the NonBlocking driver
    /// uses this to tell an empty frontier sweep from a real one).
    #[inline]
    pub fn gathered_by(&self, thread: usize) -> u64 {
        // relaxed: monotonic telemetry counter; readers tolerate staleness
        self.vertices_gathered[thread].load(Ordering::Relaxed)
    }

    /// Total vertex updates across all threads.
    pub fn total_gathered(&self) -> u64 {
        // relaxed: monotonic telemetry counter; readers tolerate staleness
        self.vertices_gathered.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// Per-thread sweep counts.
    pub fn iterations_per_thread(&self) -> Vec<u64> {
        // relaxed: monotonic telemetry counter; readers tolerate staleness
        self.iterations.iter().map(|a| a.load(Ordering::Relaxed)).collect()
    }

    /// Maximum sweep count over threads (thread-level iteration count).
    pub fn max_iterations(&self) -> u64 {
        self.iterations_per_thread().into_iter().max().unwrap_or(0)
    }

    /// Total edges processed across all threads.
    pub fn total_edges(&self) -> u64 {
        // relaxed: monotonic telemetry counter; readers tolerate staleness
        self.edges_processed.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// Total perforation-frozen vertices across all threads.
    pub fn total_skipped(&self) -> u64 {
        // relaxed: monotonic telemetry counter; readers tolerate staleness
        self.vertices_skipped.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// Seconds since the metrics were created.
    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_per_thread() {
        let m = RunMetrics::new(3);
        m.bump_iteration(0);
        m.bump_iteration(0);
        m.bump_iteration(2);
        m.add_edges(1, 100);
        m.add_edges(1, 50);
        m.add_skipped(2, 7);
        m.add_gathered(0, 5);
        m.add_gathered(2, 3);
        assert_eq!(m.iterations_per_thread(), vec![2, 0, 1]);
        assert_eq!(m.max_iterations(), 2);
        assert_eq!(m.total_edges(), 150);
        assert_eq!(m.total_skipped(), 7);
        assert_eq!(m.gathered_by(0), 5);
        assert_eq!(m.gathered_by(1), 0);
        assert_eq!(m.total_gathered(), 8);
    }

    #[test]
    fn concurrent_bumps_are_not_lost() {
        let m = RunMetrics::new(4);
        std::thread::scope(|s| {
            for t in 0..4 {
                let m = &m;
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.bump_iteration(t);
                        m.add_edges(t, 2);
                    }
                });
            }
        });
        assert_eq!(m.iterations_per_thread(), vec![1000; 4]);
        assert_eq!(m.total_edges(), 8000);
    }

    #[test]
    fn elapsed_grows() {
        let m = RunMetrics::new(1);
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(m.elapsed_secs() > 0.0);
    }
}
