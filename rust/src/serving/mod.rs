//! Epoch-snapshotted rank serving: concurrent `rank(v)` / `top_k(k)`
//! queries while a background recompute runs.
//!
//! The non-blocking engine exists so ranks can keep converging while the
//! world changes under them; this module is the read side of that story.
//! Scores are published as immutable [`RankSnapshot`]s behind an
//! `ArcSwap`-style atomic pointer (an `RwLock<Arc<_>>` here — the offline
//! build carries no `arc-swap` crate, and the read path only clones an
//! `Arc` under a momentary read lock, never blocking on a recompute):
//!
//! * a **reader** grabs the current `Arc<RankSnapshot>` and queries it for
//!   as long as it likes — the snapshot is immutable, so a concurrent
//!   publish can never tear it or shift its scores mid-read;
//! * a **writer** (the epoch step in [`ServingEngine::apply`]) mutates the
//!   graph, reconverges incrementally from the previous ranks
//!   ([`crate::engine::incremental`]), builds the next snapshot *fully*
//!   off to the side, and only then swaps the pointer.
//!
//! Every snapshot carries a self-checksum ([`RankSnapshot::verify`]) over
//! its epoch, scores, and precomputed descending order, so stress tests
//! can prove readers only ever observe fully-published snapshots.

use crate::graph::{Csr, GraphDelta, VertexId};
use crate::pagerank::{self, PrConfig, PrResult, Variant};
use anyhow::{bail, Result};
use crate::sync::shim::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Vertex ids ordered by descending rank. NaN scores (possible in a
/// non-converged No-Sync-Edge run) sort after every real number; ties
/// break by ascending vertex id. [`PrResult::top_k`] and the snapshot's
/// precomputed order both use this.
pub fn rank_descending(ranks: &[f64]) -> Vec<VertexId> {
    let mut idx: Vec<VertexId> = (0..ranks.len() as VertexId).collect();
    idx.sort_by(|&a, &b| {
        let (ra, rb) = (ranks[a as usize], ranks[b as usize]);
        // order NaN last regardless of sign-bit quirks of total_cmp
        match (ra.is_nan(), rb.is_nan()) {
            (true, true) => a.cmp(&b),
            (true, false) => std::cmp::Ordering::Greater,
            (false, true) => std::cmp::Ordering::Less,
            (false, false) => rb.total_cmp(&ra).then(a.cmp(&b)),
        }
    });
    idx
}

/// An immutable, fully-materialized score publication. Built entirely
/// before it becomes visible to any reader; once a reader holds the
/// `Arc`, nothing about it can change.
#[derive(Debug)]
pub struct RankSnapshot {
    epoch: u64,
    ranks: Vec<f64>,
    /// Vertex ids by descending rank, so `top_k` is an O(k) slice.
    order: Vec<VertexId>,
    checksum: u64,
}

/// FNV-1a over the epoch, every rank's bit pattern, and the order array —
/// deterministic, so [`RankSnapshot::verify`] can recompute it exactly.
fn snapshot_checksum(epoch: u64, ranks: &[f64], order: &[VertexId]) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01B3;
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ epoch;
    for &r in ranks {
        h = (h ^ r.to_bits()).wrapping_mul(PRIME);
    }
    for &v in order {
        h = (h ^ v as u64).wrapping_mul(PRIME);
    }
    h
}

impl RankSnapshot {
    fn build(epoch: u64, ranks: Vec<f64>) -> Self {
        let order = rank_descending(&ranks);
        let checksum = snapshot_checksum(epoch, &ranks, &order);
        Self { epoch, ranks, order, checksum }
    }

    /// The publication epoch (0 for the pre-bootstrap empty snapshot).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Score of vertex `v`, or `None` when `v` is out of range.
    pub fn rank(&self, v: VertexId) -> Option<f64> {
        self.ranks.get(v as usize).copied()
    }

    /// The full score array of this epoch.
    pub fn ranks(&self) -> &[f64] {
        &self.ranks
    }

    /// The `k` best-ranked vertices, descending (O(k) — the order is
    /// precomputed at publish time).
    pub fn top_k(&self, k: usize) -> Vec<(VertexId, f64)> {
        self.order
            .iter()
            .take(k)
            .map(|&v| (v, self.ranks[v as usize]))
            .collect()
    }

    /// Number of vertices covered.
    pub fn len(&self) -> usize {
        self.ranks.len()
    }

    /// Is this the empty (zero-vertex) snapshot?
    pub fn is_empty(&self) -> bool {
        self.ranks.is_empty()
    }

    /// Recompute the checksum and compare: `true` iff the snapshot is
    /// internally consistent. A torn or partially-published snapshot
    /// cannot pass; the concurrency stress tests assert this on every
    /// read.
    pub fn verify(&self) -> bool {
        snapshot_checksum(self.epoch, &self.ranks, &self.order) == self.checksum
    }
}

/// The atomic publication point: readers clone the current
/// [`RankSnapshot`] `Arc`; writers install fully-built snapshots at
/// convergence epochs. Cheap to share (`Arc<RankServer>`) between the
/// serving loop and any number of query threads.
#[derive(Debug)]
pub struct RankServer {
    current: RwLock<Arc<RankSnapshot>>,
    epoch: AtomicU64,
    queries: AtomicU64,
}

impl Default for RankServer {
    fn default() -> Self {
        Self::new()
    }
}

impl RankServer {
    /// A server holding the empty epoch-0 snapshot.
    pub fn new() -> Self {
        Self {
            current: RwLock::new(Arc::new(RankSnapshot::build(0, Vec::new()))),
            epoch: AtomicU64::new(0),
            queries: AtomicU64::new(0),
        }
    }

    /// Publish a new score array, returning its epoch. The snapshot —
    /// scores, descending order, checksum — is built entirely before the
    /// pointer swap, and the swap itself is guarded to be monotonic: if a
    /// slower concurrent publisher drew an earlier epoch, its stale
    /// snapshot is discarded rather than rolling the service back.
    pub fn publish(&self, ranks: Vec<f64>) -> u64 {
        let epoch = self.epoch.fetch_add(1, Ordering::AcqRel) + 1;
        let snapshot = Arc::new(RankSnapshot::build(epoch, ranks));
        let mut cur = self.current.write().expect("rank server lock poisoned");
        if snapshot.epoch > cur.epoch {
            *cur = snapshot;
        }
        epoch
    }

    /// The current snapshot. Readers hold it as long as they like; a
    /// concurrent publish simply swaps the pointer for *future* readers.
    pub fn snapshot(&self) -> Arc<RankSnapshot> {
        Arc::clone(&self.current.read().expect("rank server lock poisoned"))
    }

    /// Epoch of the most recently published snapshot.
    pub fn epoch(&self) -> u64 {
        self.snapshot().epoch
    }

    /// Point query against the current snapshot.
    pub fn rank(&self, v: VertexId) -> Option<f64> {
        // relaxed: telemetry counter only
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.snapshot().rank(v)
    }

    /// Top-k query against the current snapshot.
    pub fn top_k(&self, k: usize) -> Vec<(VertexId, f64)> {
        // relaxed: telemetry counter only
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.snapshot().top_k(k)
    }

    /// Total `rank`/`top_k` queries answered since construction.
    pub fn queries_served(&self) -> u64 {
        // relaxed: telemetry counter only
        self.queries.load(Ordering::Relaxed)
    }
}

/// Telemetry for one [`ServingEngine::apply`] epoch step.
#[derive(Debug, Clone)]
pub struct EpochStats {
    /// Epoch the reconverged scores were published as.
    pub epoch: u64,
    /// Touched vertices the frontier was seeded from.
    pub touched: usize,
    /// Solver iterations of the incremental reconvergence.
    pub iterations: u64,
    /// Vertex updates the reconvergence cost (the incremental saving
    /// metric — compare against a cold run's `iterations × n`).
    pub vertex_updates: u64,
    /// Did the reconvergence hit the threshold (vs the iteration cap)?
    pub converged: bool,
    /// Wall time of the mutation + reconvergence, in seconds.
    pub elapsed_secs: f64,
    /// Edge count of the mutated graph.
    pub edges: usize,
}

/// The evolve-query-reconverge loop: owns the current graph and warm
/// ranks, publishes every converged epoch through its [`RankServer`].
///
/// ```text
///   bootstrap: cold frontier solve  ──► publish epoch 1
///   apply(δ):  mutate CSR ──► seed frontier ──► warm reconverge
///              ──► publish epoch e+1          (readers query throughout)
/// ```
pub struct ServingEngine {
    graph: Csr,
    variant: Variant,
    cfg: PrConfig,
    server: Arc<RankServer>,
    warm: Vec<f64>,
}

impl ServingEngine {
    /// Cold-start a serving engine: run `variant` to convergence on
    /// `graph` and publish the result as epoch 1. Only the frontier
    /// variants can reconverge incrementally, so anything else is
    /// rejected here rather than on the first `apply`.
    pub fn bootstrap(graph: Csr, variant: Variant, cfg: PrConfig) -> Result<ServingEngine> {
        if !matches!(variant, Variant::Frontier | Variant::FrontierPcpm) {
            bail!("serving requires an incremental variant (frontier or frontier-pcpm), got {variant}");
        }
        cfg.validate()?;
        let cold = pagerank::run(&graph, variant, &cfg)?;
        let server = Arc::new(RankServer::new());
        server.publish(cold.ranks.clone());
        Ok(ServingEngine { graph, variant, cfg, server, warm: cold.ranks })
    }

    /// Handle to the query side; clone it into reader threads.
    pub fn server(&self) -> Arc<RankServer> {
        Arc::clone(&self.server)
    }

    /// The graph as of the most recent epoch.
    pub fn graph(&self) -> &Csr {
        &self.graph
    }

    /// The most recently published epoch.
    pub fn epoch(&self) -> u64 {
        self.server.epoch()
    }

    /// One epoch step: apply `delta`, reconverge incrementally from the
    /// previous ranks, publish the new scores. Readers keep querying the
    /// previous snapshot until the publish lands; a capped (unconverged)
    /// reconvergence still publishes its best-known scores, flagged in
    /// the returned stats.
    pub fn apply(&mut self, delta: &GraphDelta) -> Result<EpochStats> {
        let run = crate::engine::incremental::mutate_and_reconverge(
            &self.graph,
            delta,
            self.variant,
            &self.cfg,
            &self.warm,
        )?;
        let PrResult { ranks, iterations, converged, vertex_updates, elapsed, .. } = run.result;
        let epoch = self.server.publish(ranks.clone());
        self.graph = run.graph;
        self.warm = ranks;
        Ok(EpochStats {
            epoch,
            touched: run.touched,
            iterations,
            vertex_updates,
            converged,
            elapsed_secs: elapsed.as_secs_f64(),
            edges: self.graph.num_edges(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic;

    fn cfg() -> PrConfig {
        PrConfig { threads: 2, threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn rank_descending_orders_with_nan_last() {
        assert_eq!(rank_descending(&[0.3, f64::NAN, 0.5, 0.2]), vec![2, 0, 3, 1]);
        assert_eq!(rank_descending(&[]), Vec::<VertexId>::new());
        // ties break by vertex id
        assert_eq!(rank_descending(&[0.5, 0.5, 0.9]), vec![2, 0, 1]);
    }

    #[test]
    fn server_publish_and_query() {
        let s = RankServer::new();
        assert_eq!(s.epoch(), 0);
        assert!(s.snapshot().is_empty());
        let e = s.publish(vec![0.1, 0.7, 0.2]);
        assert_eq!(e, 1);
        assert_eq!(s.epoch(), 1);
        assert_eq!(s.rank(1), Some(0.7));
        assert_eq!(s.rank(9), None);
        assert_eq!(s.top_k(2), vec![(1, 0.7), (2, 0.2)]);
        assert_eq!(s.queries_served(), 3);
        assert!(s.snapshot().verify());
    }

    #[test]
    fn held_snapshot_survives_later_publishes() {
        let s = RankServer::new();
        s.publish(vec![1.0, 2.0]);
        let held = s.snapshot();
        s.publish(vec![9.0, 8.0]);
        // the old snapshot is frozen; the server moved on
        assert_eq!(held.epoch(), 1);
        assert_eq!(held.rank(0), Some(1.0));
        assert!(held.verify());
        assert_eq!(s.epoch(), 2);
        assert_eq!(s.rank(0), Some(9.0));
    }

    #[test]
    fn monotonic_guard_discards_stale_publish() {
        // Simulate a slow publisher that drew its epoch first but installs
        // last: the guard must keep the newer snapshot.
        let s = RankServer::new();
        let stale_epoch = s.epoch.fetch_add(1, Ordering::AcqRel) + 1;
        let fresh = s.publish(vec![5.0]);
        assert!(fresh > stale_epoch);
        let stale = Arc::new(RankSnapshot::build(stale_epoch, vec![1.0]));
        {
            let mut cur = s.current.write().unwrap();
            if stale.epoch > cur.epoch {
                *cur = stale;
            }
        }
        assert_eq!(s.rank(0), Some(5.0), "stale snapshot must not roll back");
    }

    #[test]
    fn engine_bootstrap_rejects_non_incremental_variants() {
        let g = synthetic::cycle(8);
        let err = ServingEngine::bootstrap(g, Variant::Barrier, cfg());
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("frontier"));
    }

    #[test]
    fn engine_epoch_steps_track_oracle() {
        let g = synthetic::web_replica(300, 5, 41);
        let mut engine = ServingEngine::bootstrap(g, Variant::Frontier, cfg()).unwrap();
        assert_eq!(engine.epoch(), 1);
        let server = engine.server();
        for step in 0..3u64 {
            let delta = GraphDelta::random(engine.graph(), 5, 2, 100 + step);
            let stats = engine.apply(&delta).unwrap();
            assert_eq!(stats.epoch, 2 + step);
            assert!(stats.converged);
            assert!(stats.touched > 0);
            let oracle =
                pagerank::run(engine.graph(), Variant::Barrier, &cfg()).unwrap();
            let snap = server.snapshot();
            assert!(snap.verify());
            let l1 = crate::pagerank::convergence::l1_norm(snap.ranks(), &oracle.ranks);
            assert!(l1 < 1e-6, "epoch {}: l1 {l1}", stats.epoch);
        }
        assert_eq!(engine.epoch(), 4);
    }

    #[test]
    fn concurrent_readers_see_only_verified_snapshots() {
        let g = synthetic::web_replica(250, 5, 13);
        let mut engine = ServingEngine::bootstrap(g, Variant::Frontier, cfg()).unwrap();
        let server = engine.server();
        let done = crate::sync::shim::atomic::AtomicBool::new(false);
        std::thread::scope(|s| {
            for _ in 0..3 {
                let server = Arc::clone(&server);
                let done = &done;
                s.spawn(move || {
                    let mut last_epoch = 0;
                    while !done.load(Ordering::Acquire) {
                        let snap = server.snapshot();
                        assert!(snap.verify(), "torn snapshot observed");
                        assert!(
                            snap.epoch() >= last_epoch,
                            "epoch went backwards: {} < {last_epoch}",
                            snap.epoch()
                        );
                        last_epoch = snap.epoch();
                        server.rank(0);
                        server.top_k(3);
                    }
                });
            }
            for step in 0..4u64 {
                let delta = GraphDelta::random(engine.graph(), 8, 4, 500 + step);
                engine.apply(&delta).unwrap();
            }
            done.store(true, Ordering::Release);
        });
        assert!(server.queries_served() > 0);
        assert_eq!(server.epoch(), 5);
    }
}
