//! In-tree property-testing mini-framework.
//!
//! The offline build image has no `proptest`/`quickcheck`, so this module
//! provides the same methodology at the scale this project needs:
//! seeded generators ([`Gen`]), a runner ([`check`]) that executes a
//! property over many generated cases, and greedy shrinking for failures
//! ([`Shrink`]). Deterministic by construction — a failing case prints the
//! seed and the shrunken input, and re-running reproduces it exactly.
//!
//! ```no_run
//! use pagerank_nb::testkit::{check, Config, IntRange};
//!
//! check(Config::default().cases(200), IntRange::new(0, 1000), |&n| {
//!     // property: doubling then halving is identity
//!     (n * 2) / 2 == n
//! });
//! ```

use crate::util::rng::Xoshiro256pp;

/// Runner configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Generated cases per property.
    pub cases: usize,
    /// RNG seed (override with `PAGERANK_NB_PT_SEED`).
    pub seed: u64,
    /// Cap on shrinking iterations.
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        // PAGERANK_NB_PT_SEED overrides for reproduction of CI failures.
        let seed = std::env::var("PAGERANK_NB_PT_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Self { cases: 100, seed, max_shrink_steps: 500 }
    }
}

impl Config {
    /// Set the case count.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Set the seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// A seeded generator of values plus a shrinking strategy.
pub trait Gen {
    /// The generated value type.
    type Value: std::fmt::Debug;
    /// Produce one value from the seeded RNG.
    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Value;
    /// Candidate smaller inputs, most aggressive first. Default: no shrink.
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `property` over `cfg.cases` generated values; panics with the seed
/// and the (shrunken) counterexample on failure.
pub fn check<G: Gen>(cfg: Config, gen: G, property: impl Fn(&G::Value) -> bool) {
    let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed);
    for case in 0..cfg.cases {
        let value = gen.generate(&mut rng);
        if !property(&value) {
            let shrunk = shrink_loop(&cfg, &gen, value, &property);
            panic!(
                "property failed (seed {}, case {case}): counterexample {shrunk:?}",
                cfg.seed
            );
        }
    }
}

fn shrink_loop<G: Gen>(
    cfg: &Config,
    gen: &G,
    mut failing: G::Value,
    property: &impl Fn(&G::Value) -> bool,
) -> G::Value {
    let mut steps = 0;
    'outer: while steps < cfg.max_shrink_steps {
        for candidate in gen.shrink(&failing) {
            steps += 1;
            if !property(&candidate) {
                failing = candidate;
                continue 'outer;
            }
            if steps >= cfg.max_shrink_steps {
                break;
            }
        }
        break;
    }
    failing
}

// ---------------------------------------------------------------------------
// Stock generators
// ---------------------------------------------------------------------------

/// Uniform integer in `[lo, hi]`, shrinking toward `lo`.
pub struct IntRange {
    lo: i64,
    hi: i64,
}

impl IntRange {
    /// The inclusive range `[lo, hi]`.
    pub fn new(lo: i64, hi: i64) -> Self {
        assert!(lo <= hi);
        Self { lo, hi }
    }
}

impl Gen for IntRange {
    type Value = i64;

    fn generate(&self, rng: &mut Xoshiro256pp) -> i64 {
        self.lo + rng.next_below((self.hi - self.lo + 1) as u64) as i64
    }

    fn shrink(&self, &v: &i64) -> Vec<i64> {
        let mut out = Vec::new();
        if v > self.lo {
            out.push(self.lo);
            let mid = self.lo + (v - self.lo) / 2;
            if mid != self.lo && mid != v {
                out.push(mid);
            }
            if v - 1 != mid {
                out.push(v - 1);
            }
        }
        out
    }
}

/// Pair of independent generators.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B>
where
    A::Value: Clone,
    B::Value: Clone,
{
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, (a, b): &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(a).into_iter().map(|a2| (a2, b.clone())).collect();
        out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
        out
    }
}

/// Random directed edge list over `0..max_n` vertices, shrinking by
/// dropping edges. The workhorse for graph-invariant properties.
pub struct EdgeList {
    /// Maximum vertex count.
    pub max_n: usize,
    /// Maximum edge count.
    pub max_m: usize,
}

impl Gen for EdgeList {
    type Value = (usize, Vec<(u32, u32)>);

    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Value {
        let n = rng.range(1, self.max_n.max(2));
        let m = rng.range(0, self.max_m.max(1));
        let edges = (0..m)
            .map(|_| {
                (
                    rng.next_below(n as u64) as u32,
                    rng.next_below(n as u64) as u32,
                )
            })
            .collect();
        (n, edges)
    }

    fn shrink(&self, (n, edges): &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if !edges.is_empty() {
            // halve the edge list, then drop one edge at a time (front)
            out.push((*n, edges[..edges.len() / 2].to_vec()));
            out.push((*n, edges[1..].to_vec()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(Config::default().cases(50), IntRange::new(0, 100), |&n| n >= 0);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        check(Config::default().cases(50), IntRange::new(0, 100), |&n| n < 95);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Catch the panic and inspect the message: for "n < 50" the minimal
        // failing case reachable by our shrinker should be ≤ 60.
        let r = std::panic::catch_unwind(|| {
            check(Config::default().cases(200), IntRange::new(0, 1000), |&n| n < 50);
        });
        let msg = match r {
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_else(|| "?".into()),
            Ok(()) => panic!("property should have failed"),
        };
        let num: i64 = msg
            .rsplit_once("counterexample ")
            .and_then(|(_, s)| s.trim().parse().ok())
            .expect("message carries counterexample");
        assert!((50..=60).contains(&num), "shrunk to {num}; msg: {msg}");
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let collect = |seed| {
            let mut rng = Xoshiro256pp::seed_from_u64(seed);
            let g = IntRange::new(0, 1_000_000);
            (0..20).map(|_| g.generate(&mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(collect(9), collect(9));
        assert_ne!(collect(9), collect(10));
    }

    #[test]
    fn edge_list_generator_is_well_formed() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let g = EdgeList { max_n: 50, max_m: 200 };
        for _ in 0..100 {
            let (n, edges) = g.generate(&mut rng);
            assert!(n >= 1);
            for (u, v) in edges {
                assert!((u as usize) < n && (v as usize) < n);
            }
        }
    }

    #[test]
    fn pair_generator_shrinks_both_sides() {
        let p = Pair(IntRange::new(0, 10), IntRange::new(0, 10));
        let shr = p.shrink(&(5, 7));
        assert!(shr.iter().any(|&(a, b)| a < 5 && b == 7));
        assert!(shr.iter().any(|&(a, b)| a == 5 && b < 7));
    }
}
