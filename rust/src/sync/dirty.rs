//! Lock-free per-vertex dirty bitmap — the frontier substrate for the
//! delta-scheduled kernels ([`crate::engine::frontier`]).
//!
//! One bit per vertex, packed into `AtomicU64` words. Writers (any thread
//! whose rank moved past the delta threshold) mark a vertex's out-neighbours
//! with [`DirtyFlags::set`]; the partition owner drains its own vertex range
//! with [`DirtyFlags::drain_range`], which claims every set bit in a word
//! with a single `fetch_and` — so a drain and concurrent sets never lose an
//! update: a bit set after the claim simply survives into the next sweep.
//!
//! Memory ordering: `set` is an `AcqRel` read-modify-write, so the rank
//! stores a publisher issued *before* marking are visible to the owner that
//! subsequently claims the bit (`drain_range` claims with `AcqRel` too; RMW
//! chains extend the release sequence). Rank cells themselves stay relaxed,
//! exactly like the No-Sync family — a stale read only delays, never
//! corrupts, convergence (Lemma 1 of the source paper).

use crate::graph::VertexId;
use crate::sync::shim::atomic::{AtomicU64, Ordering};
use std::ops::Range;

/// A fixed-capacity atomic bitmap over vertex ids `0..len`.
pub struct DirtyFlags {
    words: Vec<AtomicU64>,
    len: usize,
}

impl DirtyFlags {
    /// All bits clear.
    pub fn new_clear(len: usize) -> Self {
        let words = (0..len.div_ceil(64)).map(|_| AtomicU64::new(0)).collect();
        Self { words, len }
    }

    /// All `len` bits set (the initial frontier: every vertex is dirty).
    /// Bits past `len` in the last word stay clear so counts are exact.
    pub fn new_set(len: usize) -> Self {
        let full = len / 64;
        let tail = len % 64;
        let mut words: Vec<AtomicU64> = (0..full).map(|_| AtomicU64::new(!0)).collect();
        if tail > 0 {
            words.push(AtomicU64::new((1u64 << tail) - 1));
        }
        Self { words, len }
    }

    /// Bitmap capacity in vertices.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the bitmap covers zero vertices.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mark vertex `v` dirty. Returns `true` if this call set the bit (it
    /// was clear), `false` if it was already set.
    ///
    /// Always the `fetch_or` — no test-and-test-and-set fast path. The
    /// obvious TTAS optimization (relaxed load, early-return when the bit
    /// reads as set) is *unsound* here: the load may observe a stale "set"
    /// from before a concurrent `drain_range` claimed the word, so the
    /// early return would skip a mark whose bit is in fact clear — and the
    /// drain that cleared it may have gathered the vertex *before* this
    /// publisher stored its new rank, leaving the update unpropagated
    /// forever (a correctness loss, not a delay). The RMW always operates
    /// on the latest value in the modification order, so a mark landing
    /// after a claim simply survives into the next sweep.
    #[inline]
    pub fn set(&self, v: VertexId) -> bool {
        let (w, bit) = (v as usize / 64, 1u64 << (v as usize % 64));
        self.words[w].fetch_or(bit, Ordering::AcqRel) & bit == 0
    }

    /// Claim (atomically clear) vertex `v`'s bit. Returns `true` when this
    /// call found it set — the caller now owns gathering `v` this sweep.
    /// The single-vertex dual of [`DirtyFlags::drain_range`]'s per-word
    /// claim, used by the work-list scheduler to re-validate popped ids:
    /// an id whose bit was already claimed (say, by an overflow bitmap
    /// scan) returns `false` and is skipped, so a vertex is never gathered
    /// twice in one sweep. Same `AcqRel` publication contract as the drain.
    #[inline]
    pub fn claim(&self, v: VertexId) -> bool {
        let (w, bit) = (v as usize / 64, 1u64 << (v as usize % 64));
        self.words[w].fetch_and(!bit, Ordering::AcqRel) & bit != 0
    }

    /// Bulk-mark every vertex in `range` dirty — one `fetch_or` per 64
    /// vertices instead of a per-vertex [`DirtyFlags::set`] loop. Used by
    /// [`crate::engine::incremental::seed_frontier`] for consecutive runs
    /// of touched vertices. No transition report: bulk seeding happens
    /// before workers race on the bitmap.
    pub fn set_range(&self, range: Range<VertexId>) {
        let (start, end) = (range.start as usize, range.end as usize);
        if start >= end {
            return;
        }
        let first_word = start / 64;
        let last_word = (end - 1) / 64;
        for w in first_word..=last_word {
            let lo = (w * 64).max(start);
            let hi = ((w + 1) * 64).min(end);
            let width = hi - lo;
            let mask: u64 = if width == 64 {
                !0
            } else {
                ((1u64 << width) - 1) << (lo - w * 64)
            };
            self.words[w].fetch_or(mask, Ordering::AcqRel);
        }
    }

    /// Is vertex `v` currently marked?
    #[inline]
    pub fn is_set(&self, v: VertexId) -> bool {
        let (w, bit) = (v as usize / 64, 1u64 << (v as usize % 64));
        self.words[w].load(Ordering::Acquire) & bit != 0
    }

    /// Number of set bits (diagnostics / tests; O(len / 64)).
    pub fn count_set(&self) -> usize {
        self.words
            .iter()
            .map(|w| w.load(Ordering::Acquire).count_ones() as usize)
            .sum()
    }

    /// True when any bit in `range` is set. Non-destructive — unlike
    /// [`DirtyFlags::drain_range`] nothing is claimed — so a coordinator can
    /// ask "does this shard need a sweep?" without disturbing the frontier
    /// (the out-of-core scheduler, [`crate::engine::ooc`]). One `Acquire`
    /// load per 64 vertices; a concurrent set may be missed by this probe
    /// (it lands in the modification order after the load) but is seen by
    /// the next one — the same delay-not-loss guarantee the drain gives.
    pub fn any_in_range(&self, range: Range<VertexId>) -> bool {
        let (start, end) = (range.start as usize, range.end as usize);
        if start >= end {
            return false;
        }
        let first_word = start / 64;
        let last_word = (end - 1) / 64;
        (first_word..=last_word).any(|w| {
            let lo = (w * 64).max(start);
            let hi = ((w + 1) * 64).min(end);
            let width = hi - lo;
            let mask: u64 = if width == 64 {
                !0
            } else {
                ((1u64 << width) - 1) << (lo - w * 64)
            };
            self.words[w].load(Ordering::Acquire) & mask != 0
        })
    }

    /// Claim-and-visit every set bit in `range`, in ascending order.
    ///
    /// Claims all of a word's in-range bits with one `fetch_and`, then calls
    /// `f` for each claimed vertex. Bits set concurrently after the claim
    /// are untouched and will be seen by the next drain. Returns the number
    /// of vertices visited. Zero-scan words cost one load.
    pub fn drain_range(&self, range: Range<VertexId>, mut f: impl FnMut(VertexId)) -> u64 {
        let (start, end) = (range.start as usize, range.end as usize);
        if start >= end {
            return 0;
        }
        let mut visited = 0u64;
        let first_word = start / 64;
        let last_word = (end - 1) / 64;
        for w in first_word..=last_word {
            let lo = (w * 64).max(start);
            let hi = ((w + 1) * 64).min(end);
            let width = hi - lo;
            let mask: u64 = if width == 64 {
                !0
            } else {
                ((1u64 << width) - 1) << (lo - w * 64)
            };
            if self.words[w].load(Ordering::Acquire) & mask == 0 {
                continue; // fast path: nothing pending in this word
            }
            let mut claimed = self.words[w].fetch_and(!mask, Ordering::AcqRel) & mask;
            while claimed != 0 {
                let b = claimed.trailing_zeros() as usize;
                claimed &= claimed - 1;
                visited += 1;
                f((w * 64 + b) as VertexId);
            }
        }
        visited
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn new_set_counts_exactly_len() {
        for n in [0usize, 1, 63, 64, 65, 130] {
            let d = DirtyFlags::new_set(n);
            assert_eq!(d.count_set(), n, "n={n}");
            assert_eq!(d.len(), n);
        }
        assert!(DirtyFlags::new_set(0).is_empty());
    }

    #[test]
    fn set_reports_transition() {
        let d = DirtyFlags::new_clear(100);
        assert!(!d.is_set(70));
        assert!(d.set(70));
        assert!(!d.set(70), "second set must report already-set");
        assert!(d.is_set(70));
        assert_eq!(d.count_set(), 1);
    }

    #[test]
    fn drain_visits_only_the_range_in_order() {
        let d = DirtyFlags::new_set(200);
        let mut seen = Vec::new();
        let n = d.drain_range(60..130, |v| seen.push(v));
        assert_eq!(n, 70);
        assert_eq!(seen, (60u32..130).collect::<Vec<_>>());
        // outside the range untouched, inside cleared
        assert!(d.is_set(59));
        assert!(d.is_set(130));
        assert!(!d.is_set(60));
        assert!(!d.is_set(129));
        assert_eq!(d.count_set(), 130);
        assert_eq!(d.drain_range(60..130, |_| ()), 0);
    }

    #[test]
    fn any_in_range_probes_without_claiming() {
        let d = DirtyFlags::new_clear(300);
        assert!(!d.any_in_range(0..300));
        d.set(130);
        assert!(d.any_in_range(0..300));
        assert!(d.any_in_range(130..131));
        assert!(d.any_in_range(64..192), "word-spanning range");
        assert!(!d.any_in_range(0..130));
        assert!(!d.any_in_range(131..300));
        assert!(!d.any_in_range(10..10), "empty range");
        // probing never claims: the bit is still there for the drain
        assert!(d.is_set(130));
        assert_eq!(d.drain_range(0..300, |v| assert_eq!(v, 130)), 1);
        assert!(!d.any_in_range(0..300));
    }

    #[test]
    fn claim_clears_exactly_one_bit_once() {
        let d = DirtyFlags::new_clear(128);
        assert!(!d.claim(70), "clear bit cannot be claimed");
        d.set(70);
        d.set(71);
        assert!(d.claim(70));
        assert!(!d.claim(70), "second claim must lose");
        assert!(d.is_set(71), "neighbouring bit untouched");
        assert_eq!(d.count_set(), 1);
    }

    #[test]
    fn set_range_marks_word_spanning_runs() {
        let d = DirtyFlags::new_clear(300);
        d.set_range(60..130);
        assert_eq!(d.count_set(), 70);
        assert!(!d.is_set(59));
        assert!(d.is_set(60));
        assert!(d.is_set(129));
        assert!(!d.is_set(130));
        d.set_range(10..10); // empty range is a no-op
        assert_eq!(d.count_set(), 70);
        d.set_range(0..300);
        assert_eq!(d.count_set(), 300, "full range marks everything");
        // equivalent to the per-vertex loop
        let loopy = DirtyFlags::new_clear(300);
        for v in 60..130 {
            loopy.set(v);
        }
        let mut a = Vec::new();
        let mut b = Vec::new();
        let fresh = DirtyFlags::new_clear(300);
        fresh.set_range(60..130);
        fresh.drain_range(0..300, |v| a.push(v));
        loopy.drain_range(0..300, |v| b.push(v));
        assert_eq!(a, b);
    }

    #[test]
    fn drain_empty_range_is_zero() {
        let d = DirtyFlags::new_set(64);
        assert_eq!(d.drain_range(10..10, |_| panic!("must not visit")), 0);
    }

    #[test]
    fn concurrent_sets_are_never_lost() {
        // Setters mark every vertex once; a draining owner sweeps its range
        // until quiet. Every marked vertex must be drained exactly once.
        let n = if cfg!(miri) { 512usize } else { 4096 };
        let d = Arc::new(DirtyFlags::new_clear(n));
        let drained =
            Arc::new((0..n).map(|_| AtomicU64::new(0)).collect::<Vec<_>>());
        std::thread::scope(|s| {
            for t in 0..4 {
                let d = Arc::clone(&d);
                s.spawn(move || {
                    let mut v = t;
                    while v < n {
                        d.set(v as VertexId);
                        v += 4;
                    }
                });
            }
            for half in 0..2 {
                let d = Arc::clone(&d);
                let drained = Arc::clone(&drained);
                s.spawn(move || {
                    let range = (half * n / 2) as VertexId..((half + 1) * n / 2) as VertexId;
                    let mut total = 0u64;
                    while total < (n / 2) as u64 {
                        total += d.drain_range(range.clone(), |v| {
                            drained[v as usize].fetch_add(1, Ordering::Relaxed);
                        });
                        std::thread::yield_now();
                    }
                });
            }
        });
        for (v, c) in drained.iter().enumerate() {
            assert_eq!(
                c.load(Ordering::Relaxed),
                1,
                "vertex {v} drained wrong number of times"
            );
        }
        assert_eq!(d.count_set(), 0);
    }

    /// Regression stress for the mark-vs-drain race: `set` must never be
    /// skipped because of a stale observation of the word (the removed TTAS
    /// fast path could early-return against a bit a concurrent
    /// `drain_range` had already claimed). A publisher bumps a value and
    /// then marks; the consumer drains and snapshots the value. After the
    /// publisher finishes, the final mark must still be pending (or already
    /// consumed at the final value) — i.e. the last published value is
    /// always observed.
    #[test]
    fn final_mark_survives_concurrent_drains() {
        const ROUNDS: u64 = if cfg!(miri) { 300 } else { 20_000 };
        let d = Arc::new(DirtyFlags::new_clear(64));
        let published = Arc::new(AtomicU64::new(0));
        let observed = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            {
                let (d, published) = (Arc::clone(&d), Arc::clone(&published));
                s.spawn(move || {
                    for i in 1..=ROUNDS {
                        published.store(i, Ordering::Release);
                        d.set(7);
                    }
                });
            }
            {
                let (d, published, observed) =
                    (Arc::clone(&d), Arc::clone(&published), Arc::clone(&observed));
                s.spawn(move || {
                    // Deadline-bounded so a reintroduced lost-mark bug
                    // fails with a message instead of wedging the test
                    // runner (normal completion is milliseconds).
                    let deadline =
                        std::time::Instant::now() + std::time::Duration::from_secs(30);
                    while observed.load(Ordering::Relaxed) < ROUNDS {
                        assert!(
                            std::time::Instant::now() < deadline,
                            "mark-vs-drain race lost the final mark: observed {} of {ROUNDS}",
                            observed.load(Ordering::Relaxed)
                        );
                        d.drain_range(0..64, |v| {
                            assert_eq!(v, 7);
                            observed.store(published.load(Ordering::Acquire), Ordering::Relaxed);
                        });
                        std::thread::yield_now();
                    }
                });
            }
        });
        // the consumer loop only exits once a drain observed the final
        // published value — a lost final mark trips its deadline assert
        assert_eq!(observed.load(Ordering::Relaxed), ROUNDS);
    }
}
