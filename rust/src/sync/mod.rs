//! Synchronization substrate for the PageRank variants.
//!
//! The paper's C++ implementation relies on POSIX threads, pthread barriers,
//! benign data races on `std::vector<double>`, and 128-bit CAS objects. This
//! module rebuilds each primitive with defined semantics in the Rust memory
//! model:
//!
//! * [`barrier::SenseBarrier`] — a sense-reversing spin barrier with an abort
//!   hook, standing in for `pthread_barrier_t` (and letting the fault-
//!   injection harness observe stuck barriers instead of deadlocking).
//! * [`atomics::AtomicF64`] — relaxed atomic `f64` cells replacing the
//!   paper's benign-race `vector<double>` reads/writes.
//! * [`cas_cell`] — the versioned rank cells and CAS-object protocol used by
//!   the wait-free Barrier-Helper algorithm (Algorithm 6).
//! * [`dirty::DirtyFlags`] — a lock-free per-vertex dirty bitmap, the
//!   frontier substrate of the delta-scheduled kernels (ours, after Blanco
//!   et al.'s delayed-async scheduling; not a paper primitive).
//! * [`worklist::WorkList`] — a fixed-capacity lock-free MPMC ring of
//!   vertex ids, the sparse-frontier alternative to scanning the bitmap
//!   (ours; claim-based, deduplicated through `DirtyFlags`).
//!
//! The [`RankCell`] and [`PhaseBarrier`] traits are the engine-facing
//! surface: [`crate::engine`] snapshots rank storage and reads barrier
//! telemetry through them without knowing whether a kernel uses plain
//! atomic cells or the wait-free CAS protocol.

pub mod atomics;
pub mod barrier;
pub mod cas_cell;
pub mod dirty;
pub mod shim;
pub mod worklist;

pub use dirty::DirtyFlags;
pub use worklist::WorkList;

/// Engine-facing view of one rank cell. Implemented by the plain
/// [`atomics::AtomicF64`] and by the wait-free
/// [`cas_cell::VersionedCell`], so the engine can seed and snapshot rank
/// storage independently of the commit protocol.
pub trait RankCell {
    /// Current rank value.
    fn value(&self) -> f64;
    /// Unversioned (single-threaded setup) overwrite.
    fn reset(&self, x: f64);
}

/// Snapshot any rank-cell storage into a plain `Vec<f64>`.
pub fn snapshot_cells<C: RankCell>(cells: &[C]) -> Vec<f64> {
    cells.iter().map(RankCell::value).collect()
}

/// Engine-facing surface of a phase barrier: the driver needs to abort one
/// on DNF and to report cumulative wait time, nothing else.
pub trait PhaseBarrier {
    /// Unblock every current and future waiter (DNF unwinding).
    fn abort(&self);
    /// Total thread-seconds spent waiting at this barrier.
    fn total_wait_secs(&self) -> f64;
}

#[cfg(test)]
mod tests {
    use super::atomics::AtomicF64;
    use super::cas_cell::VersionedCell;
    use super::*;

    #[test]
    fn snapshot_cells_spans_both_storage_kinds() {
        let plain: Vec<AtomicF64> = (0..3).map(|i| AtomicF64::new(i as f64)).collect();
        assert_eq!(snapshot_cells(&plain), vec![0.0, 1.0, 2.0]);

        let versioned: Vec<VersionedCell> =
            (0..3).map(|i| VersionedCell::new(i as f64 * 0.5)).collect();
        assert_eq!(snapshot_cells(&versioned), vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn reset_works_through_the_trait() {
        let c = AtomicF64::new(1.0);
        RankCell::reset(&c, 2.5);
        assert_eq!(RankCell::value(&c), 2.5);

        let v = VersionedCell::new(1.0);
        assert!(v.try_advance(0, 9.0));
        RankCell::reset(&v, 0.25);
        assert_eq!(v.read(), (0, 0.25));
    }
}
