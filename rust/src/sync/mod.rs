//! Synchronization substrate for the PageRank variants.
//!
//! The paper's C++ implementation relies on POSIX threads, pthread barriers,
//! benign data races on `std::vector<double>`, and 128-bit CAS objects. This
//! module rebuilds each primitive with defined semantics in the Rust memory
//! model:
//!
//! * [`barrier::SenseBarrier`] — a sense-reversing spin barrier with an abort
//!   hook, standing in for `pthread_barrier_t` (and letting the fault-
//!   injection harness observe stuck barriers instead of deadlocking).
//! * [`atomics::AtomicF64`] — relaxed atomic `f64` cells replacing the
//!   paper's benign-race `vector<double>` reads/writes.
//! * [`cas_cell`] — the versioned rank cells and CAS-object protocol used by
//!   the wait-free Barrier-Helper algorithm (Algorithm 6).

pub mod atomics;
pub mod barrier;
pub mod cas_cell;
