//! Atomic `f64` cells.
//!
//! The paper's No-Sync algorithm deliberately allows concurrent reads of a
//! rank while one thread writes it ("read-write conflicts but not
//! write-write conflicts", §4.3), relying on the x86 behaviour of aligned
//! 8-byte stores. In Rust that exact pattern on `&mut [f64]` would be UB, so
//! the shared rank vector is a `[AtomicF64]` with `Relaxed` ordering — the
//! compiled code on x86-64 is the identical `mov`, but the semantics are
//! defined on every platform.

use crate::sync::shim::atomic::{AtomicU64, Ordering};

/// An `f64` stored as its bit pattern in an `AtomicU64`.
#[repr(transparent)]
#[derive(Debug, Default)]
pub struct AtomicF64(AtomicU64);

impl AtomicF64 {
    /// A cell holding `x`.
    #[inline]
    pub fn new(x: f64) -> Self {
        Self(AtomicU64::new(x.to_bits()))
    }

    /// Relaxed load — the No-Sync read path. A torn read is impossible
    /// (8-byte atomic); the value may be from the current or a neighbouring
    /// iteration, which is exactly the relaxation Lemma 1 reasons about.
    #[inline]
    pub fn load(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    /// Relaxed store — the single-writer update path.
    #[inline]
    pub fn store(&self, x: f64) {
        self.0.store(x.to_bits(), Ordering::Relaxed)
    }

    /// Acquire load, for cross-iteration handoffs where the reader must also
    /// observe writes preceding the store (wait-free helper bookkeeping).
    #[inline]
    pub fn load_acquire(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Acquire))
    }

    /// Release store, pairing with [`Self::load_acquire`].
    #[inline]
    pub fn store_release(&self, x: f64) {
        self.0.store(x.to_bits(), Ordering::Release)
    }

    /// CAS on the exact bit pattern (used by fetch_max below and by the
    /// wait-free global-error merge).
    #[inline]
    pub fn compare_exchange_bits(&self, current: f64, new: f64) -> Result<f64, f64> {
        self.0
            .compare_exchange(
                current.to_bits(),
                new.to_bits(),
                Ordering::AcqRel,
                Ordering::Acquire,
            )
            .map(f64::from_bits)
            .map_err(f64::from_bits)
    }

    /// Atomically `self = max(self, x)`; returns the previous value.
    /// Lock-free: CAS loop, at most as many retries as concurrent increases.
    pub fn fetch_max(&self, x: f64) -> f64 {
        let mut cur = self.load_acquire();
        loop {
            if cur >= x {
                return cur;
            }
            match self.compare_exchange_bits(cur, x) {
                Ok(prev) => return prev,
                Err(actual) => cur = actual,
            }
        }
    }
}

impl crate::sync::RankCell for AtomicF64 {
    fn value(&self) -> f64 {
        self.load()
    }

    fn reset(&self, x: f64) {
        self.store(x)
    }
}

/// Allocate a shared rank vector initialized to `x`.
pub fn atomic_vec(n: usize, x: f64) -> Vec<AtomicF64> {
    (0..n).map(|_| AtomicF64::new(x)).collect()
}

/// Allocate a shared rank vector seeded from an existing score array —
/// the warm-start path of the incremental kernels
/// ([`crate::engine::incremental`]).
pub fn atomic_vec_from(vals: &[f64]) -> Vec<AtomicF64> {
    vals.iter().map(|&x| AtomicF64::new(x)).collect()
}

/// Snapshot a shared rank vector into a plain `Vec<f64>`.
pub fn snapshot(v: &[AtomicF64]) -> Vec<f64> {
    v.iter().map(|a| a.load()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn roundtrip_bits() {
        let a = AtomicF64::new(0.0);
        for x in [0.0, -0.0, 1.5, f64::MIN_POSITIVE, 1e300, -7.25] {
            a.store(x);
            assert_eq!(a.load().to_bits(), x.to_bits());
        }
    }

    #[test]
    fn nan_roundtrip_preserves_bits() {
        let a = AtomicF64::new(f64::NAN);
        assert!(a.load().is_nan());
    }

    #[test]
    fn fetch_max_sequential() {
        let a = AtomicF64::new(1.0);
        assert_eq!(a.fetch_max(0.5), 1.0);
        assert_eq!(a.load(), 1.0);
        assert_eq!(a.fetch_max(2.0), 1.0);
        assert_eq!(a.load(), 2.0);
    }

    #[test]
    fn fetch_max_concurrent_takes_global_max() {
        // Miri explores this with full state tracking; keep its workload
        // small enough to finish while still crossing threads.
        let per: usize = if cfg!(miri) { 50 } else { 1000 };
        let a = Arc::new(AtomicF64::new(f64::NEG_INFINITY));
        std::thread::scope(|s| {
            for t in 0..8usize {
                let a = Arc::clone(&a);
                s.spawn(move || {
                    for i in 0..per {
                        a.fetch_max((t * per + i) as f64);
                    }
                });
            }
        });
        assert_eq!(a.load(), (8 * per - 1) as f64);
    }

    #[test]
    fn concurrent_store_load_no_tearing() {
        // Writers alternate between two bit patterns whose halves differ;
        // readers must only ever observe one of the two.
        let iters: usize = if cfg!(miri) { 200 } else { 20_000 };
        let a = Arc::new(AtomicF64::new(f64::from_bits(0xAAAA_AAAA_AAAA_AAAA)));
        let p1 = f64::from_bits(0xAAAA_AAAA_AAAA_AAAA);
        let p2 = f64::from_bits(0x5555_5555_5555_5555);
        std::thread::scope(|s| {
            let w = Arc::clone(&a);
            s.spawn(move || {
                for i in 0..iters {
                    w.store(if i % 2 == 0 { p1 } else { p2 });
                }
            });
            for _ in 0..2 {
                let r = Arc::clone(&a);
                s.spawn(move || {
                    for _ in 0..iters {
                        let bits = r.load().to_bits();
                        assert!(
                            bits == p1.to_bits() || bits == p2.to_bits(),
                            "torn read: {bits:#x}"
                        );
                    }
                });
            }
        });
    }

    #[test]
    fn snapshot_matches_stores() {
        let v = atomic_vec(4, 0.25);
        v[2].store(9.0);
        assert_eq!(snapshot(&v), vec![0.25, 0.25, 9.0, 0.25]);
    }
}
