//! The one place this crate touches `std::sync::atomic`.
//!
//! Every module imports its atomics, spin hints, and (in tests) spawned
//! threads from here instead of `std`. In a normal build the re-exports
//! below compile away to the `std` items — zero cost, identical codegen.
//! With `--features pallas-model` they route to the vendored
//! [`model_lite`] checker instead: the same types become schedule points
//! with bounded-staleness relaxed-memory semantics inside a
//! `model_lite::check` execution (and transparent `std` fallbacks
//! outside one), which is what lets `rust/tests/model/` exhaustively
//! model-check the `sync/` protocols without forking their source.
//!
//! `scripts/audit-unsafe.sh` enforces the funnel: any `std::sync::atomic`
//! import outside this file fails CI.

#[cfg(not(feature = "pallas-model"))]
pub mod atomic {
    //! Re-export of `std::sync::atomic` (normal builds).
    pub use std::sync::atomic::{
        AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering,
    };
}

#[cfg(feature = "pallas-model")]
pub mod atomic {
    //! Model-checked atomics (`--features pallas-model` builds).
    pub use model_lite::atomic::{
        AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering,
    };
}

#[cfg(not(feature = "pallas-model"))]
pub mod hint {
    //! Re-export of `std::hint::spin_loop` (normal builds).
    pub use std::hint::spin_loop;
}

#[cfg(feature = "pallas-model")]
pub mod hint {
    //! Spin hint as a yielding schedule point (model builds).
    pub use model_lite::hint::spin_loop;
}

#[cfg(not(feature = "pallas-model"))]
pub mod thread {
    //! Re-export of the `std::thread` subset the sync layer uses.
    pub use std::thread::{spawn, yield_now, JoinHandle};
}

#[cfg(feature = "pallas-model")]
pub mod thread {
    //! Model-scheduled threads (model builds).
    pub use model_lite::thread::{spawn, yield_now, JoinHandle};
}
