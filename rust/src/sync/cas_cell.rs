//! CAS-object cells for the wait-free Barrier-Helper algorithm (Alg 6).
//!
//! The paper's C++ implementation uses three CAS-able descriptor structs
//! (`PrCASObj`, `ThreadCASObj`, `GlobalCASObj`), relying on double-width
//! (128-bit) atomics. Stable Rust has no portable `AtomicU128`, so each
//! descriptor is rebuilt from 64-bit primitives with equivalent protocol
//! guarantees:
//!
//! * [`VersionedCell`] ≙ `PrCASObj { itrNum, rank }` — a per-vertex rank
//!   cell whose version counter *is* the iteration number. Commit uses a
//!   seqlock-style even/odd protocol: the CAS on the version word decides
//!   the unique winner for an iteration; losers (helpers that computed the
//!   same deterministic value) simply move on.
//! * [`PackedProgress`] ≙ `ThreadCASObj { itrNum, currNode }` — a thread's
//!   progress descriptor packed `iter:u32 | node:u32` into one `AtomicU64`
//!   so helpers can atomically claim the next vertex of a stalled thread.
//! * [`crate::sync::atomics::AtomicF64::fetch_max`] handles the error
//!   fields (`thErr`, global `err`): max-merge is idempotent, so duplicated
//!   helper updates are harmless.
//!
//! **Fault model.** Between a winner's version-CAS and its value publish
//! there is a two-store commit window; a thread dying *inside* that window
//! could wedge readers of that one cell. The paper's own fault injection
//! (and ours, see `coordinator::faults`) kills threads only at iteration
//! boundaries, outside the window; on hardware with `cmpxchg16b` the window
//! closes entirely. DESIGN.md §Hardware-Adaptation records this substitution.

use crate::sync::shim::atomic::{AtomicU64, Ordering};

/// A versioned `f64` cell: `(iteration, value)` with single-winner commits.
///
/// Version word encoding: `2*iter` = stable at `iter`, `2*iter + 1` =
/// commit for `iter -> iter+1` in flight.
#[derive(Debug)]
pub struct VersionedCell {
    version: AtomicU64,
    value: AtomicU64, // f64 bits
}

impl VersionedCell {
    /// A stable cell at iteration 0 holding `value`.
    pub fn new(value: f64) -> Self {
        Self {
            version: AtomicU64::new(0),
            value: AtomicU64::new(value.to_bits()),
        }
    }

    /// Consistent read: `(iteration, value)`. Spins (with yield) while a
    /// commit is in flight — bounded by the commit window (two stores).
    pub fn read(&self) -> (u64, f64) {
        let mut spins = 0u32;
        loop {
            let v1 = self.version.load(Ordering::Acquire);
            if v1 % 2 == 0 {
                let val = f64::from_bits(self.value.load(Ordering::Acquire));
                let v2 = self.version.load(Ordering::Acquire);
                if v1 == v2 {
                    return (v1 / 2, val);
                }
            }
            spins += 1;
            if spins < 32 {
                crate::sync::shim::hint::spin_loop();
            } else {
                crate::sync::shim::thread::yield_now();
            }
        }
    }

    /// Value only (callers that already know the iteration is stable).
    pub fn read_value(&self) -> f64 {
        self.read().1
    }

    /// Current iteration number.
    pub fn iteration(&self) -> u64 {
        self.version.load(Ordering::Acquire) / 2
    }

    /// Attempt to commit `value` as the rank for `expected_iter + 1`
    /// (i.e. advance the cell from `expected_iter`). Exactly one concurrent
    /// caller with the same `expected_iter` wins; all others get `false`.
    ///
    /// In Algorithm 6 every contender computed the same deterministic value
    /// from the frozen previous-iteration array, so losing is not an error —
    /// the vertex is simply already done.
    pub fn try_advance(&self, expected_iter: u64, value: f64) -> bool {
        let stable = expected_iter * 2;
        if self
            .version
            .compare_exchange(stable, stable + 1, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return false;
        }
        self.value.store(value.to_bits(), Ordering::Release);
        self.version.store(stable + 2, Ordering::Release);
        true
    }

    /// Non-versioned reset (single-threaded setup only).
    pub fn reset(&self, value: f64) {
        self.value.store(value.to_bits(), Ordering::Release);
        self.version.store(0, Ordering::Release);
    }
}

impl crate::sync::RankCell for VersionedCell {
    fn value(&self) -> f64 {
        self.read_value()
    }

    fn reset(&self, x: f64) {
        VersionedCell::reset(self, x)
    }
}

/// `ThreadCASObj`: a thread's `(iteration, next_vertex)` progress word.
///
/// Helpers CAS this forward to claim work items of a stalled thread; the
/// single winner per `(iter, node)` pair prevents duplicated *claims* (the
/// computation itself is idempotent anyway).
#[derive(Debug)]
pub struct PackedProgress(AtomicU64);

impl PackedProgress {
    /// Initial progress word at `(iter, node)`.
    pub fn new(iter: u32, node: u32) -> Self {
        Self(AtomicU64::new(Self::pack(iter, node)))
    }

    #[inline]
    fn pack(iter: u32, node: u32) -> u64 {
        ((iter as u64) << 32) | node as u64
    }

    #[inline]
    fn unpack(word: u64) -> (u32, u32) {
        ((word >> 32) as u32, word as u32)
    }

    /// Current `(iteration, node)` claim (acquire).
    pub fn load(&self) -> (u32, u32) {
        Self::unpack(self.0.load(Ordering::Acquire))
    }

    /// CAS from an observed `(iter, node)` to a new one. Returns whether the
    /// caller was the winner.
    pub fn try_advance(&self, from: (u32, u32), to: (u32, u32)) -> bool {
        self.0
            .compare_exchange(
                Self::pack(from.0, from.1),
                Self::pack(to.0, to.1),
                Ordering::AcqRel,
                Ordering::Acquire,
            )
            .is_ok()
    }

    /// Unconditional store (setup / owner-only paths).
    pub fn store(&self, iter: u32, node: u32) {
        self.0.store(Self::pack(iter, node), Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::shim::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn versioned_cell_single_thread_lifecycle() {
        let c = VersionedCell::new(0.5);
        assert_eq!(c.read(), (0, 0.5));
        assert!(c.try_advance(0, 1.5));
        assert_eq!(c.read(), (1, 1.5));
        // Re-advancing from the stale iteration fails.
        assert!(!c.try_advance(0, 9.9));
        assert_eq!(c.read(), (1, 1.5));
        assert!(c.try_advance(1, 2.5));
        assert_eq!(c.read(), (2, 2.5));
    }

    #[test]
    fn versioned_cell_exactly_one_winner() {
        const T: usize = 8;
        const ROUNDS: u64 = if cfg!(miri) { 6 } else { 50 };
        for round in 0..ROUNDS {
            let c = Arc::new(VersionedCell::new(0.0));
            // bring cell to iteration `round`
            for i in 0..round {
                assert!(c.try_advance(i, i as f64));
            }
            let wins = Arc::new(AtomicUsize::new(0));
            std::thread::scope(|s| {
                for _ in 0..T {
                    let c = Arc::clone(&c);
                    let wins = Arc::clone(&wins);
                    s.spawn(move || {
                        if c.try_advance(round, 42.0) {
                            wins.fetch_add(1, Ordering::SeqCst);
                        }
                    });
                }
            });
            assert_eq!(wins.load(Ordering::SeqCst), 1);
            assert_eq!(c.read(), (round + 1, 42.0));
        }
    }

    #[test]
    fn versioned_cell_readers_see_consistent_pairs() {
        // Writers advance with value == iteration; readers must never see a
        // mismatched (iter, value) pair.
        let iters: u64 = if cfg!(miri) { 200 } else { 10_000 };
        let c = Arc::new(VersionedCell::new(0.0));
        std::thread::scope(|s| {
            let w = Arc::clone(&c);
            s.spawn(move || {
                for i in 0..iters {
                    assert!(w.try_advance(i, (i + 1) as f64));
                }
            });
            for _ in 0..2 {
                let r = Arc::clone(&c);
                s.spawn(move || {
                    for _ in 0..iters {
                        let (iter, val) = r.read();
                        assert_eq!(val, iter as f64, "inconsistent cell read");
                    }
                });
            }
        });
    }

    #[test]
    fn packed_progress_roundtrip() {
        let p = PackedProgress::new(3, 17);
        assert_eq!(p.load(), (3, 17));
        assert!(p.try_advance((3, 17), (3, 18)));
        assert_eq!(p.load(), (3, 18));
        assert!(!p.try_advance((3, 17), (3, 19)), "stale CAS must fail");
        p.store(4, 0);
        assert_eq!(p.load(), (4, 0));
    }

    #[test]
    fn packed_progress_extreme_values() {
        let p = PackedProgress::new(u32::MAX, u32::MAX);
        assert_eq!(p.load(), (u32::MAX, u32::MAX));
    }

    #[test]
    fn packed_progress_concurrent_claims_are_unique() {
        // T threads race to claim nodes 0..N in order; each node must be
        // claimed exactly once.
        const N: u32 = if cfg!(miri) { 100 } else { 2000 };
        const T: usize = 4;
        let p = Arc::new(PackedProgress::new(0, 0));
        let claims: Arc<Vec<AtomicUsize>> =
            Arc::new((0..N).map(|_| AtomicUsize::new(0)).collect());
        std::thread::scope(|s| {
            for _ in 0..T {
                let p = Arc::clone(&p);
                let claims = Arc::clone(&claims);
                s.spawn(move || loop {
                    let (iter, node) = p.load();
                    if node >= N {
                        break;
                    }
                    if p.try_advance((iter, node), (iter, node + 1)) {
                        claims[node as usize].fetch_add(1, Ordering::SeqCst);
                    }
                });
            }
        });
        for (i, c) in claims.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "node {i} claimed != once");
        }
    }
}
