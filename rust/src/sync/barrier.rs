//! Sense-reversing spin barrier — the blocking substrate of Algorithms 1–2.
//!
//! `std::sync::Barrier` would work for the happy path, but the paper's
//! evaluation (Figs 8–9) injects *sleeping* and *failed* threads and observes
//! what barrier-based algorithms do: they stall. To reproduce that without
//! deadlocking the test harness, this barrier supports **abort**: when the
//! fault injector marks a participant dead, every current and future waiter
//! unblocks with [`BarrierWait::Aborted`] and the executor records the run as
//! DNF. The barrier also exposes its arrival counter so the telemetry layer
//! can measure time-at-barrier (the quantity the paper's speedup argument is
//! about).

use crate::sync::shim::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// Outcome of a [`SenseBarrier::wait`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BarrierWait {
    /// All parties arrived; this thread was the last one in.
    Leader,
    /// All parties arrived; another thread was the leader.
    Member,
    /// The barrier was aborted (a participant failed); computation should
    /// unwind.
    Aborted,
}

impl BarrierWait {
    /// Was this an abort?
    pub fn is_aborted(self) -> bool {
        matches!(self, BarrierWait::Aborted)
    }
}

/// Sense-reversing centralized barrier.
pub struct SenseBarrier {
    parties: usize,
    /// Number of parties still to arrive in the current phase.
    count: AtomicUsize,
    /// Global sense: flips each completed phase.
    sense: AtomicBool,
    aborted: AtomicBool,
    /// Cumulative nanoseconds all threads have spent spinning at this
    /// barrier (telemetry; relaxed counter, approximate by design).
    wait_nanos: AtomicU64,
}

impl SenseBarrier {
    /// A barrier for `parties` participants.
    pub fn new(parties: usize) -> Self {
        assert!(parties > 0, "barrier needs at least one party");
        Self {
            parties,
            count: AtomicUsize::new(parties),
            sense: AtomicBool::new(false),
            aborted: AtomicBool::new(false),
            wait_nanos: AtomicU64::new(0),
        }
    }

    /// Number of participants.
    pub fn parties(&self) -> usize {
        self.parties
    }

    /// Make a per-thread waiter handle (holds the thread-local sense).
    pub fn waiter(&self) -> Waiter<'_> {
        Waiter { barrier: self, local_sense: false }
    }

    /// Abort the barrier: unblock everyone, now and forever.
    pub fn abort(&self) {
        self.aborted.store(true, Ordering::Release);
    }

    /// Has the barrier been aborted?
    pub fn is_aborted(&self) -> bool {
        self.aborted.load(Ordering::Acquire)
    }

    /// Total time threads have spent waiting here, in seconds.
    pub fn total_wait_secs(&self) -> f64 {
        self.wait_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }
}

impl crate::sync::PhaseBarrier for SenseBarrier {
    fn abort(&self) {
        SenseBarrier::abort(self)
    }

    fn total_wait_secs(&self) -> f64 {
        SenseBarrier::total_wait_secs(self)
    }
}

/// Per-thread handle carrying the local sense bit.
pub struct Waiter<'b> {
    barrier: &'b SenseBarrier,
    local_sense: bool,
}

impl Waiter<'_> {
    /// Arrive at the barrier and wait for the phase to complete.
    ///
    /// Spin strategy: short `spin_loop` bursts, then `yield_now` — the
    /// reproduction host may have fewer cores than threads (the paper used
    /// 56 hardware threads), so pure spinning would livelock a timesliced
    /// run.
    pub fn wait(&mut self) -> BarrierWait {
        let b = self.barrier;
        if b.is_aborted() {
            return BarrierWait::Aborted;
        }
        self.local_sense = !self.local_sense;
        let my_sense = self.local_sense;
        if b.count.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last arrival: reset and release the phase.
            b.count.store(b.parties, Ordering::Release);
            b.sense.store(my_sense, Ordering::Release);
            return BarrierWait::Leader;
        }
        let start = std::time::Instant::now();
        let mut spins = 0u32;
        while b.sense.load(Ordering::Acquire) != my_sense {
            if b.is_aborted() {
                b.wait_nanos
                    .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
                return BarrierWait::Aborted;
            }
            spins += 1;
            if spins < 64 {
                crate::sync::shim::hint::spin_loop();
            } else {
                crate::sync::shim::thread::yield_now();
            }
        }
        b.wait_nanos
            .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
        BarrierWait::Member
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn single_party_never_blocks() {
        let b = SenseBarrier::new(1);
        let mut w = b.waiter();
        for _ in 0..100 {
            assert_eq!(w.wait(), BarrierWait::Leader);
        }
    }

    #[test]
    fn phases_are_synchronized() {
        // Classic barrier test: no thread may enter phase k+1 while another
        // is still in phase k.
        const T: usize = 4;
        const PHASES: usize = 50;
        let b = Arc::new(SenseBarrier::new(T));
        let phase_counts: Arc<Vec<AtomicUsize>> =
            Arc::new((0..PHASES).map(|_| AtomicUsize::new(0)).collect());
        std::thread::scope(|s| {
            for _ in 0..T {
                let b = Arc::clone(&b);
                let pc = Arc::clone(&phase_counts);
                s.spawn(move || {
                    let mut w = b.waiter();
                    for p in 0..PHASES {
                        pc[p].fetch_add(1, Ordering::SeqCst);
                        let r = w.wait();
                        assert!(!r.is_aborted());
                        // After the barrier, everyone must have bumped p.
                        assert_eq!(pc[p].load(Ordering::SeqCst), T, "phase {p} leaked");
                    }
                });
            }
        });
    }

    #[test]
    fn exactly_one_leader_per_phase() {
        const T: usize = 3;
        let b = Arc::new(SenseBarrier::new(T));
        let leaders = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..T {
                let b = Arc::clone(&b);
                let leaders = Arc::clone(&leaders);
                s.spawn(move || {
                    let mut w = b.waiter();
                    for _ in 0..20 {
                        if w.wait() == BarrierWait::Leader {
                            leaders.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                });
            }
        });
        assert_eq!(leaders.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn abort_unblocks_waiters() {
        let b = Arc::new(SenseBarrier::new(2));
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || {
            let mut w = b2.waiter();
            w.wait() // only 1 of 2 parties: blocks until abort
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        b.abort();
        assert_eq!(h.join().unwrap(), BarrierWait::Aborted);
        // And future waits return immediately.
        let mut w = b.waiter();
        assert_eq!(w.wait(), BarrierWait::Aborted);
    }

    #[test]
    fn wait_time_telemetry_accumulates() {
        let b = Arc::new(SenseBarrier::new(2));
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || {
            let mut w = b2.waiter();
            w.wait();
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        let mut w = b.waiter();
        w.wait();
        h.join().unwrap();
        // The early arriver waited ~30ms.
        assert!(b.total_wait_secs() >= 0.02, "wait {}", b.total_wait_secs());
    }
}
