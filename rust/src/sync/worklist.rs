//! Claim-based work-list frontier: a fixed-capacity lock-free MPMC ring of
//! vertex ids.
//!
//! The bitmap frontier ([`crate::sync::DirtyFlags`]) costs O(n/64) per
//! sweep no matter how sparse the active set is; once a partition's
//! frontier drops to a handful of vertices, scanning megabytes of clean
//! words dominates. The work-list inverts that: marking a vertex also
//! enqueues its id on the owner partition's ring, and the owner pops
//! instead of scanning — O(active) per sweep.
//!
//! This is the bounded MPMC queue of Vyukov's design: each slot carries a
//! sequence number; producers claim a slot by CAS on `tail` and publish
//! with a `Release` store of `seq = pos + 1`, consumers claim by CAS on
//! `head` once they observe that sequence and retire the slot with
//! `seq = pos + capacity`. Full and empty are detected from the sequence
//! lag without locking. The ring never blocks: `push` on a full ring
//! returns `false` (the frontier scheduler then falls back to a bitmap
//! scan — the bitmap stays the ground truth, so overflow loses telemetry,
//! never marks).
//!
//! Deduplication is *not* the ring's job: the frontier enqueues a vertex
//! only when its [`DirtyFlags::set`](crate::sync::DirtyFlags::set)
//! transition reports the bit was clear, and consumers re-validate every
//! pop against the bitmap with
//! [`DirtyFlags::claim`](crate::sync::DirtyFlags::claim) — so a vertex is
//! queued at most once per sweep and a stale entry (already claimed by an
//! overflow scan) is skipped, never double-gathered.

use crate::graph::VertexId;
use crate::sync::shim::atomic::{AtomicU32, AtomicUsize, Ordering};

/// One ring slot: the Vyukov sequence word plus the payload.
struct Slot {
    seq: AtomicUsize,
    val: AtomicU32,
}

/// A fixed-capacity lock-free MPMC ring of vertex ids.
pub struct WorkList {
    slots: Vec<Slot>,
    mask: usize,
    head: AtomicUsize,
    tail: AtomicUsize,
    peak: AtomicUsize,
}

impl WorkList {
    /// A ring holding at least `cap` entries (rounded up to a power of two,
    /// minimum 2).
    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.max(2).next_power_of_two();
        let slots =
            (0..cap).map(|i| Slot { seq: AtomicUsize::new(i), val: AtomicU32::new(0) }).collect();
        Self {
            slots,
            mask: cap - 1,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
        }
    }

    /// Ring capacity (always a power of two).
    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Approximate current occupancy (exact when quiescent).
    pub fn len(&self) -> usize {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Relaxed);
        tail.saturating_sub(head)
    }

    /// Approximately empty (exact when quiescent).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Peak occupancy ever observed by a successful `push` (telemetry;
    /// monotone, approximate under contention).
    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::Relaxed) as u64
    }

    /// Enqueue `v`. Returns `false` when the ring is full — the caller
    /// falls back to the bitmap scan; nothing is lost because the bitmap
    /// mark always precedes the enqueue attempt.
    pub fn push(&self, v: VertexId) -> bool {
        let mut pos = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let lag = seq as isize - pos as isize;
            if lag == 0 {
                match self.tail.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        slot.val.store(v, Ordering::Relaxed);
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        let occupancy =
                            pos.wrapping_add(1).saturating_sub(self.head.load(Ordering::Relaxed));
                        self.peak.fetch_max(occupancy, Ordering::Relaxed);
                        return true;
                    }
                    Err(current) => pos = current,
                }
            } else if lag < 0 {
                // The slot still holds an unconsumed entry from one lap
                // ago: the ring is full.
                return false;
            } else {
                pos = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Dequeue the oldest entry, `None` when the ring is empty.
    pub fn pop(&self) -> Option<VertexId> {
        let mut pos = self.head.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let lag = seq as isize - pos.wrapping_add(1) as isize;
            if lag == 0 {
                match self.head.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let v = slot.val.load(Ordering::Relaxed);
                        // retire the slot for the producers' next lap
                        slot.seq.store(pos.wrapping_add(self.mask + 1), Ordering::Release);
                        return Some(v);
                    }
                    Err(current) => pos = current,
                }
            } else if lag < 0 {
                return None;
            } else {
                pos = self.head.load(Ordering::Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::shim::atomic::AtomicU64;
    use crate::sync::DirtyFlags;
    use std::sync::Arc;

    #[test]
    fn fifo_order_single_thread() {
        let q = WorkList::with_capacity(8);
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
        for v in [3u32, 1, 4, 1, 5] {
            assert!(q.push(v));
        }
        assert_eq!(q.len(), 5);
        for v in [3u32, 1, 4, 1, 5] {
            assert_eq!(q.pop(), Some(v));
        }
        assert_eq!(q.pop(), None);
        assert!(q.peak() >= 5);
    }

    #[test]
    fn full_ring_rejects_then_recovers_across_wraparound() {
        let q = WorkList::with_capacity(4);
        assert_eq!(q.capacity(), 4);
        for v in 0..4u32 {
            assert!(q.push(v));
        }
        assert!(!q.push(99), "full ring must reject, not overwrite");
        assert_eq!(q.pop(), Some(0));
        assert!(q.push(4), "freed slot is reusable");
        // drain across the wrap boundary several laps
        for lap in 0..5u32 {
            while q.pop().is_some() {}
            for v in 0..4u32 {
                assert!(q.push(lap * 10 + v));
            }
        }
        let mut drained = Vec::new();
        while let Some(v) = q.pop() {
            drained.push(v);
        }
        assert_eq!(drained, vec![40, 41, 42, 43]);
    }

    #[test]
    fn tiny_capacities_are_clamped() {
        assert_eq!(WorkList::with_capacity(0).capacity(), 2);
        assert_eq!(WorkList::with_capacity(3).capacity(), 4);
    }

    /// The satellite stress test: racing producers and consumers over a
    /// ring much smaller than the id space — every id must come out exactly
    /// once, none lost, none duplicated.
    #[test]
    fn concurrent_claim_enqueue_loses_and_duplicates_nothing() {
        const PRODUCERS: usize = 4;
        const PER_PRODUCER: usize = if cfg!(miri) { 256 } else { 8_192 };
        let n = PRODUCERS * PER_PRODUCER;
        let q = Arc::new(WorkList::with_capacity(1024));
        let seen: Arc<Vec<AtomicU64>> = Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
        let popped = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for p in 0..PRODUCERS {
                let q = Arc::clone(&q);
                s.spawn(move || {
                    for i in 0..PER_PRODUCER {
                        let v = (p * PER_PRODUCER + i) as VertexId;
                        while !q.push(v) {
                            std::thread::yield_now();
                        }
                    }
                });
            }
            for _ in 0..2 {
                let q = Arc::clone(&q);
                let seen = Arc::clone(&seen);
                let popped = Arc::clone(&popped);
                s.spawn(move || loop {
                    match q.pop() {
                        Some(v) => {
                            seen[v as usize].fetch_add(1, Ordering::Relaxed);
                            if popped.fetch_add(1, Ordering::Relaxed) + 1 == n {
                                return;
                            }
                        }
                        None => {
                            if popped.load(Ordering::Relaxed) >= n {
                                return;
                            }
                            std::thread::yield_now();
                        }
                    }
                });
            }
        });
        for (v, c) in seen.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "vertex {v} popped wrong count");
        }
        assert_eq!(q.pop(), None);
    }

    /// The frontier's dedup contract: enqueue only on a `DirtyFlags::set`
    /// transition, validate pops with `claim` — racing markers of the same
    /// vertices never produce a duplicate gather.
    #[test]
    fn dirty_guard_dedups_racing_markers() {
        let n = 1_000usize;
        let q = Arc::new(WorkList::with_capacity(2048));
        let dirty = Arc::new(DirtyFlags::new_clear(n));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let q = Arc::clone(&q);
                let dirty = Arc::clone(&dirty);
                s.spawn(move || {
                    for v in 0..n as VertexId {
                        if dirty.set(v) {
                            assert!(q.push(v), "capacity covers every unique id");
                        }
                    }
                });
            }
        });
        assert_eq!(q.len(), n, "exactly one enqueue per vertex");
        let mut gathered = 0usize;
        while let Some(v) = q.pop() {
            assert!(dirty.claim(v), "each queued vertex claims its bit once");
            gathered += 1;
        }
        assert_eq!(gathered, n);
        assert_eq!(dirty.count_set(), 0);
    }

    /// The out-of-core claim protocol under stress: per rotation a
    /// coordinator probes shard ranges with the non-destructive
    /// `any_in_range`, enqueues the dirty shard ids, and K racing workers
    /// claim them off the ring and drain their ranges. Every dirty shard
    /// must be claimed by exactly one worker per rotation and every set bit
    /// drained exactly once — the exclusivity the parallel shard
    /// coordinator's correctness rests on (and the race TSan watches for).
    #[test]
    fn concurrent_shard_claims_are_exclusive_and_complete() {
        const SHARDS: usize = 16;
        const SHARD_LEN: usize = 64;
        const WORKERS: usize = 4;
        const ROTATIONS: usize = if cfg!(miri) { 3 } else { 50 };
        let n = SHARDS * SHARD_LEN;
        let range = |s: usize| (s * SHARD_LEN) as VertexId..((s + 1) * SHARD_LEN) as VertexId;
        let q = WorkList::with_capacity(SHARDS);
        let dirty = DirtyFlags::new_clear(n);
        let claims: Vec<AtomicU64> = (0..SHARDS).map(|_| AtomicU64::new(0)).collect();
        let drained = AtomicUsize::new(0);
        for _rotation in 0..ROTATIONS {
            dirty.set_range(0..n as VertexId);
            drained.store(0, Ordering::Relaxed);
            let mut queued = 0usize;
            for s in 0..SHARDS {
                if dirty.any_in_range(range(s)) {
                    assert!(q.push(s as VertexId), "ring sized to hold every shard");
                    queued += 1;
                }
            }
            assert_eq!(queued, SHARDS, "a fully-set bitmap queues every shard");
            std::thread::scope(|scope| {
                for _ in 0..WORKERS {
                    let q = &q;
                    let dirty = &dirty;
                    let claims = &claims;
                    let drained = &drained;
                    scope.spawn(move || {
                        while let Some(shard) = q.pop() {
                            claims[shard as usize].fetch_add(1, Ordering::Relaxed);
                            let mut bits = 0usize;
                            dirty.drain_range(range(shard as usize), |_| bits += 1);
                            drained.fetch_add(bits, Ordering::Relaxed);
                        }
                    });
                }
            });
            assert_eq!(
                drained.load(Ordering::Relaxed),
                n,
                "every set bit drained exactly once per rotation"
            );
            assert_eq!(dirty.count_set(), 0, "rotation must leave the bitmap empty");
            assert_eq!(q.pop(), None, "rotation must leave the ring empty");
        }
        for (s, c) in claims.iter().enumerate() {
            assert_eq!(
                c.load(Ordering::Relaxed),
                ROTATIONS as u64,
                "shard {s} must be claimed exactly once per rotation"
            );
        }
    }
}
