//! # pagerank-nb — Non-Blocking PageRank for Massive Graphs
//!
//! A production-grade reproduction of *"An Improved and Optimized Practical
//! Non-Blocking PageRank Algorithm for Massive Graphs"* (Eedi, Karra, Peri,
//! Ranabothu, Utkoor — 2021), built as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution: the
//!   blocking (`Barrier`, `Barrier-Edge`), non-blocking (`No-Sync`,
//!   `No-Sync-Edge`), approximated (`*-Opt` loop-perforation) and wait-free
//!   (`Barrier-Helper`) parallel PageRank variants, the CSR graph substrate
//!   they run on, static partitioning, fault injection and the experiment
//!   harness that regenerates every figure in the paper's evaluation.
//! * **Layer 2 (python/compile/model.py)** — the per-block rank update as a
//!   JAX computation, AOT-lowered once to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — the gather/accumulate hot-spot
//!   as a Pallas kernel (ELL tile layout), validated against a pure-jnp
//!   oracle and lowered into the same HLO artifact.
//!
//! The [`runtime`] module loads those artifacts through PJRT so the Rust
//! coordinator can execute the XLA compute path natively
//! ([`pagerank::Variant::XlaBlock`]); Python never runs on the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use pagerank_nb::graph::synthetic;
//! use pagerank_nb::pagerank::{self, PrConfig, Variant};
//!
//! // A scale-free web-like graph with ~10k vertices.
//! let g = synthetic::web_replica(10_000, 8, 42);
//! let cfg = PrConfig { threads: 4, ..PrConfig::default() };
//! let result = pagerank::run(&g, Variant::NoSync, &cfg).unwrap();
//! println!("converged in {} iterations", result.iterations);
//! ```
//!
//! See `examples/` for end-to-end drivers, `rust/benches/` for the
//! figure-by-figure reproduction harness, and `docs/architecture.md` for a
//! guided tour of the engine internals (kernel dispatch, PCPM bins, the
//! frontier/dirty-bitmap data flow, and the incremental/serving layer).

#![warn(missing_docs)]
#![deny(unsafe_op_in_unsafe_fn)]

pub mod cli;
pub mod coordinator;
pub mod engine;
pub mod graph;
pub mod harness;
pub mod pagerank;
pub mod runtime;
pub mod serving;
pub mod sync;
pub mod testkit;
pub mod util;

/// Damping factor used throughout the paper (and Page et al. 1999).
pub const DAMPING: f64 = 0.85;

/// The paper's convergence threshold is `1e-16`; at f64 resolution that is
/// unreachable for per-vertex deltas on graphs with `n >= ~1e4` vertices
/// (ranks are `O(1/n)` and `1e-16` is below one ulp of intermediate sums),
/// so the library defaults to `1e-10` and treats the threshold as a config
/// knob. EXPERIMENTS.md quantifies the difference.
pub const DEFAULT_THRESHOLD: f64 = 1e-10;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
