//! Convergence bookkeeping shared by all variants.
//!
//! The paper distinguishes three convergence levels (§4):
//! * **algorithm-level** — one global error, all partitions must drop below
//!   the threshold in the same iteration (Barrier family, Wait-Free);
//! * **thread-level** — each thread merges the *latest visible* per-thread
//!   errors and exits on its own (No-Sync family);
//! * **node-level** — individual vertices freeze early (the `*-Opt`
//!   perforation variants).
//!
//! This module provides the shared error boards for the first two plus the
//! L1-norm metric of Figs 5–6.

use crate::sync::atomics::AtomicF64;
use crossbeam_utils::CachePadded;

/// Per-thread error slots, cache-padded: threads publish their local max
/// delta here every iteration, and (in thread-level convergence) read each
/// other's slots to decide termination. False sharing on this array was a
/// measurable cost before padding — see EXPERIMENTS.md §Perf.
pub struct ErrorBoard {
    slots: Vec<CachePadded<AtomicF64>>,
}

impl ErrorBoard {
    /// All slots start at `f64::INFINITY` ("not yet converged"), so a thread
    /// cannot observe a spuriously-converged peer before that peer's first
    /// publish.
    pub fn new(threads: usize) -> Self {
        Self {
            slots: (0..threads)
                .map(|_| CachePadded::new(AtomicF64::new(f64::INFINITY)))
                .collect(),
        }
    }

    /// Store `thread`'s local max delta (release).
    #[inline]
    pub fn publish(&self, thread: usize, err: f64) {
        self.slots[thread].store_release(err);
    }

    /// Load `thread`'s last published error (acquire).
    #[inline]
    pub fn read(&self, thread: usize) -> f64 {
        self.slots[thread].load_acquire()
    }

    /// Max across all slots — the paper's `localErr` merge (Alg 3 lines
    /// 17-19) and the Barrier global-error update (Alg 1 lines 20-22).
    #[inline]
    pub fn global_max(&self) -> f64 {
        let mut m: f64 = 0.0;
        for s in &self.slots {
            m = m.max(s.load_acquire());
        }
        m
    }

    /// Number of slots (= threads).
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when there are no slots.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

/// `Σ_u |a_u - b_u|` — the accuracy metric the paper reports against the
/// sequential ranks (Figs 5–6).
pub fn l1_norm(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "rank vectors must have equal length");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

/// Max absolute per-vertex difference (∞-norm), used by tests.
pub fn linf_norm(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn board_starts_unconverged() {
        let b = ErrorBoard::new(3);
        assert_eq!(b.global_max(), f64::INFINITY);
    }

    #[test]
    fn publish_and_merge() {
        let b = ErrorBoard::new(3);
        b.publish(0, 0.5);
        b.publish(1, 0.25);
        b.publish(2, 0.75);
        assert_eq!(b.global_max(), 0.75);
        assert_eq!(b.read(1), 0.25);
        b.publish(2, 0.1);
        assert_eq!(b.global_max(), 0.5);
    }

    #[test]
    fn norms() {
        let a = [0.25, 0.25, 0.5];
        let b = [0.2, 0.3, 0.5];
        assert!((l1_norm(&a, &b) - 0.1).abs() < 1e-15);
        assert!((linf_norm(&a, &b) - 0.05).abs() < 1e-15);
        assert_eq!(l1_norm(&a, &a), 0.0);
    }

    #[test]
    #[should_panic(expected = "equal length")]
    fn l1_rejects_length_mismatch() {
        l1_norm(&[1.0], &[1.0, 2.0]);
    }
}
