//! Algorithm 4 — No-Sync-Edge: the barrier-free version of the three-phase
//! edge-centric model, as an engine kernel.
//!
//! Per §4.4 this variant removes all three barriers from Algorithm 2: the
//! engine's NonBlocking driver runs each thread's pull (`gather`), merges
//! errors, then pushes its new contributions (`scatter`) — all
//! unsynchronized. Contributions read during a pull can therefore be an
//! arbitrary mix of iterations.
//!
//! The paper reports (and this reproduction confirms — see
//! `integration_variants.rs` and Fig 1/2 benches) that the variant **does
//! not reliably converge on web-like datasets**: a contribution written
//! pre-pull can be overwritten mid-pull, so the pulled sum is not any convex
//! combination Lemma 1 covers. The iteration cap turns non-convergence into
//! `converged = false` instead of a hang.

use crate::engine::{inv_out_degrees, Kernel, SyncMode, WorkerCtx};
use crate::graph::{Csr, Partitions};
use crate::pagerank::{amplify_work, PrConfig};
use crate::sync::atomics::{atomic_vec, snapshot, AtomicF64};
use anyhow::Result;

/// Algorithm 4: edge-centric push with no barriers (may not converge, end of sect. 4.4).
pub struct NoSyncEdgeKernel<'g> {
    g: &'g Csr,
    parts: Partitions,
    inv_out: Vec<f64>,
    pr: Vec<AtomicF64>,
    contributions: Vec<AtomicF64>,
    base: f64,
    d: f64,
    work_amplify: u32,
}

/// Registry builder for [`Variant::NoSyncEdge`](crate::pagerank::Variant).
pub fn kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    let n = g.num_vertices();
    let inv_out = inv_out_degrees(g);
    let contributions = atomic_vec(g.num_edges(), 0.0);
    // Seed the contribution list from the uniform initial ranks so the first
    // pull phase sees iteration-0 data.
    for u in 0..n as u32 {
        let c = (1.0 / n as f64) * inv_out[u as usize];
        for e in g.out_slot_range(u) {
            contributions[g.offset_list[e]].store(c);
        }
    }
    Ok(Box::new(NoSyncEdgeKernel {
        g,
        parts: parts.clone(),
        inv_out,
        pr: atomic_vec(n, 1.0 / n as f64),
        contributions,
        base: (1.0 - cfg.damping) / n as f64,
        d: cfg.damping,
        work_amplify: cfg.work_amplify,
    }))
}

impl Kernel for NoSyncEdgeKernel<'_> {
    fn sync_mode(&self) -> SyncMode {
        SyncMode::NonBlocking
    }

    /// Pull phase (Alg 4 lines 5-13).
    fn gather(&self, ctx: &WorkerCtx<'_>) -> f64 {
        let mut local_err: f64 = 0.0;
        let mut edges = 0u64;
        for u in self.parts.range(ctx.tid) {
            let previous = self.pr[u as usize].load();
            let mut sum = 0.0;
            for slot in self.g.in_slot_range(u) {
                sum += self.contributions[slot].load();
                amplify_work(self.work_amplify);
            }
            edges += self.g.in_degree(u) as u64;
            let new = self.base + self.d * sum;
            self.pr[u as usize].store(new);
            local_err = local_err.max((new - previous).abs());
        }
        ctx.metrics.add_edges(ctx.tid, edges);
        ctx.metrics.add_gathered(ctx.tid, self.parts.range(ctx.tid).len() as u64);
        local_err
    }

    /// Push phase (Alg 4 lines 19-27): publish new contributions. The
    /// NonBlocking driver runs this right after the error merge.
    fn scatter(&self, ctx: &WorkerCtx<'_>) {
        for u in self.parts.range(ctx.tid) {
            if self.g.out_degree(u) == 0 {
                continue;
            }
            let contribution = self.pr[u as usize].load() * self.inv_out[u as usize];
            for e in self.g.out_slot_range(u) {
                self.contributions[self.g.offset_list[e]].store(contribution);
            }
        }
    }

    fn ranks(&self) -> Vec<f64> {
        snapshot(&self.pr)
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::synthetic;
    use crate::pagerank::{self, seq, PrConfig, Variant};

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn single_thread_converges_to_sequential() {
        // Without concurrency the push/pull interleaving is deterministic
        // and exact.
        let g = synthetic::cycle(24);
        let c = cfg(1);
        let r = pagerank::run(&g, Variant::NoSyncEdge, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-9, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn converges_on_synthetic_rmat() {
        // §4.4: "resulted in better speedups … on our synthetic datasets".
        let g = synthetic::d_series(1, 500, 3); // small D10 replica
        let c = PrConfig { threshold: 1e-9, ..cfg(4) };
        let r = pagerank::run(&g, Variant::NoSyncEdge, &c).unwrap();
        // Converged or not, ranks must stay finite and positive.
        assert!(r.ranks.iter().all(|x| x.is_finite() && *x >= 0.0));
        if r.converged {
            let (sr, _, _) = seq::solve(&g, &c);
            assert!(r.l1_norm(&sr) < 1e-4, "l1 {}", r.l1_norm(&sr));
        }
    }

    #[test]
    fn iteration_cap_prevents_hang() {
        // Even if the variant refuses to converge, the cap bounds the run.
        let g = synthetic::web_replica(500, 7, 19);
        let c = PrConfig { max_iterations: 50, threshold: 1e-14, ..cfg(4) };
        let t0 = std::time::Instant::now();
        let r = pagerank::run(&g, Variant::NoSyncEdge, &c).unwrap();
        assert!(t0.elapsed() < std::time::Duration::from_secs(60));
        assert!(r.iterations <= 50);
    }

    #[test]
    fn contribution_seeding_matches_first_barrier_edge_iteration() {
        // One capped iteration on one thread equals one Barrier-Edge
        // iteration (same seeded contributions).
        let g = synthetic::star(12);
        let c = PrConfig { max_iterations: 1, ..cfg(1) };
        let ns = pagerank::run(&g, Variant::NoSyncEdge, &c).unwrap();
        let be = pagerank::run(&g, Variant::BarrierEdge, &c).unwrap();
        assert!(
            crate::pagerank::convergence::linf_norm(&ns.ranks, &be.ranks) < 1e-15
        );
    }
}
