//! Algorithm 4 — No-Sync-Edge: the barrier-free version of the three-phase
//! edge-centric model.
//!
//! Per §4.4 this variant removes all three barriers from Algorithm 2: each
//! thread pulls from the contribution list, merges errors, then pushes its
//! new contributions — all unsynchronized. Contributions read during a pull
//! can therefore be an arbitrary mix of iterations.
//!
//! The paper reports (and this reproduction confirms — see
//! `integration_variants.rs` and Fig 1/2 benches) that the variant **does
//! not reliably converge on web-like datasets**: a contribution written
//! pre-pull can be overwritten mid-pull, so the pulled sum is not any convex
//! combination Lemma 1 covers. The iteration cap turns non-convergence into
//! `converged = false` instead of a hang.

use crate::coordinator::executor::run_workers;
use crate::coordinator::metrics::RunMetrics;
use crate::graph::{Csr, Partitions};
use crate::pagerank::barrier::{empty_result, inv_out_degrees};
use crate::pagerank::convergence::ErrorBoard;
use crate::pagerank::{amplify_work, PrConfig, PrResult, Variant};
use crate::sync::atomics::{atomic_vec, snapshot};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Run Algorithm 4.
pub fn run(g: &Csr, cfg: &PrConfig, parts: &Partitions) -> PrResult {
    let n = g.num_vertices();
    let threads = cfg.threads;
    if n == 0 {
        return empty_result(Variant::NoSyncEdge, threads);
    }
    let d = cfg.damping;
    let base = (1.0 - d) / n as f64;
    let inv_out = inv_out_degrees(g);

    let pr = atomic_vec(n, 1.0 / n as f64);
    let contributions = atomic_vec(g.num_edges(), 0.0);
    // Seed the contribution list from the uniform initial ranks so the first
    // pull phase sees iteration-0 data.
    for u in 0..n as u32 {
        let c = (1.0 / n as f64) * inv_out[u as usize];
        for e in g.out_slot_range(u) {
            contributions[g.offset_list[e]].store(c);
        }
    }

    let board = ErrorBoard::new(threads);
    let metrics = RunMetrics::new(threads);
    let capped = AtomicBool::new(false);

    let start = Instant::now();
    let outcome = run_workers(threads, cfg.dnf_timeout, &[], |tid, stop| {
        let range = parts.range(tid);
        let mut iter = 0u64;
        // confirmation-sweep counter; see nosync.rs for the rationale
        let mut calm = 0u32;
        loop {
            if stop.load(Ordering::Acquire) {
                return;
            }
            if cfg.faults.apply(tid, iter) {
                return;
            }
            // Pull phase (Alg 4 lines 5-13).
            let mut local_err: f64 = 0.0;
            let mut edges = 0u64;
            for u in range.clone() {
                let previous = pr[u as usize].load();
                let mut sum = 0.0;
                for slot in g.in_slot_range(u) {
                    sum += contributions[slot].load();
                    amplify_work(cfg.work_amplify);
                }
                edges += g.in_degree(u) as u64;
                let new = base + d * sum;
                pr[u as usize].store(new);
                local_err = local_err.max((new - previous).abs());
            }
            metrics.add_edges(tid, edges);
            iter += 1;
            metrics.bump_iteration(tid);
            board.publish(tid, local_err);
            let merged = board.global_max();
            // Push phase (Alg 4 lines 19-27): publish new contributions.
            for u in range.clone() {
                let od = g.out_degree(u);
                if od == 0 {
                    continue;
                }
                let contribution = pr[u as usize].load() * inv_out[u as usize];
                for e in g.out_slot_range(u) {
                    contributions[g.offset_list[e]].store(contribution);
                }
            }
            if merged <= cfg.threshold {
                calm += 1;
                if calm >= 2 {
                    return;
                }
            } else {
                calm = 0;
            }
            if iter >= cfg.max_iterations {
                capped.store(true, Ordering::Release);
                return;
            }
            std::thread::yield_now();
        }
    });

    PrResult {
        variant: Variant::NoSyncEdge,
        ranks: snapshot(&pr),
        iterations: metrics.max_iterations(),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed: start.elapsed(),
        converged: !capped.load(Ordering::Acquire) && !outcome.dnf,
        barrier_wait_secs: 0.0,
        dnf: outcome.dnf,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic;
    use crate::pagerank::{self, seq};

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn single_thread_converges_to_sequential() {
        // Without concurrency the push/pull interleaving is deterministic
        // and exact.
        let g = synthetic::cycle(24);
        let c = cfg(1);
        let r = pagerank::run(&g, Variant::NoSyncEdge, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-9, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn converges_on_synthetic_rmat() {
        // §4.4: "resulted in better speedups … on our synthetic datasets".
        let g = synthetic::d_series(1, 500, 3); // small D10 replica
        let c = PrConfig { threshold: 1e-9, ..cfg(4) };
        let r = pagerank::run(&g, Variant::NoSyncEdge, &c).unwrap();
        // Converged or not, ranks must stay finite and positive.
        assert!(r.ranks.iter().all(|x| x.is_finite() && *x >= 0.0));
        if r.converged {
            let (sr, _, _) = seq::solve(&g, &c);
            assert!(r.l1_norm(&sr) < 1e-4, "l1 {}", r.l1_norm(&sr));
        }
    }

    #[test]
    fn iteration_cap_prevents_hang() {
        // Even if the variant refuses to converge, the cap bounds the run.
        let g = synthetic::web_replica(500, 7, 19);
        let c = PrConfig { max_iterations: 50, threshold: 1e-14, ..cfg(4) };
        let t0 = std::time::Instant::now();
        let r = pagerank::run(&g, Variant::NoSyncEdge, &c).unwrap();
        assert!(t0.elapsed() < std::time::Duration::from_secs(60));
        assert!(r.iterations <= 50);
    }

    #[test]
    fn contribution_seeding_matches_first_barrier_edge_iteration() {
        // One capped iteration on one thread equals one Barrier-Edge
        // iteration (same seeded contributions).
        let g = synthetic::star(12);
        let c = PrConfig { max_iterations: 1, ..cfg(1) };
        let ns = pagerank::run(&g, Variant::NoSyncEdge, &c).unwrap();
        let be = pagerank::run(&g, Variant::BarrierEdge, &c).unwrap();
        assert!(
            crate::pagerank::convergence::linf_norm(&ns.ranks, &be.ranks) < 1e-15
        );
    }
}
