//! Algorithm 2 — Barrier-Edge: the three-phase edge-centric baseline from
//! Panyala et al. [7].
//!
//! * **Phase I (push)** — each vertex writes `pr(u)/outdeg(u)` into the
//!   contribution slot of each out-link (via the precomputed
//!   `offset_list`, so every edge has a dedicated slot: no write conflicts).
//! * **Phase II (pull)** — each vertex sums its in-slots and applies Eq. 1.
//! * **Phase III** — global error merge.
//!
//! Barriers separate all three phases. Compared to Algorithm 1 the gather
//! becomes a *contiguous* read over the contribution list — better spatial
//! locality, bought with an extra `m`-sized array and one more barrier per
//! iteration (the trade the paper's Fig 1/2 evaluates).

use crate::coordinator::executor::run_workers;
use crate::coordinator::metrics::RunMetrics;
use crate::graph::{Csr, Partitions};
use crate::pagerank::barrier::{empty_result, inv_out_degrees};
use crate::pagerank::convergence::ErrorBoard;
use crate::pagerank::{amplify_work, PrConfig, PrResult, Variant};
use crate::sync::atomics::{atomic_vec, snapshot};
use crate::sync::barrier::SenseBarrier;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Run Algorithm 2.
pub fn run(g: &Csr, cfg: &PrConfig, parts: &Partitions) -> PrResult {
    let n = g.num_vertices();
    let threads = cfg.threads;
    if n == 0 {
        return empty_result(Variant::BarrierEdge, threads);
    }
    let d = cfg.damping;
    let base = (1.0 - d) / n as f64;
    let inv_out = inv_out_degrees(g);

    // One rank array suffices: Phase I reads ranks (iteration i-1 values),
    // Phase II overwrites them (iteration i) — the barrier between the
    // phases separates the two uses, and the old value needed for the error
    // is read locally before the store. (The paper keeps an explicit
    // prev_pr and copies in Phase III; the single-array form is numerically
    // identical and halves the copy traffic — see EXPERIMENTS.md §Perf.)
    let pr = atomic_vec(n, 1.0 / n as f64);
    let contributions = atomic_vec(g.num_edges(), 0.0);
    let board = ErrorBoard::new(threads);
    let barrier = SenseBarrier::new(threads);
    let metrics = RunMetrics::new(threads);
    let converged = AtomicBool::new(false);

    let start = Instant::now();
    let outcome = run_workers(threads, cfg.dnf_timeout, &[&barrier], |tid, stop| {
        let mut waiter = barrier.waiter();
        let range = parts.range(tid);
        let mut iter = 0u64;
        loop {
            if stop.load(Ordering::Acquire) {
                return;
            }
            if cfg.faults.apply(tid, iter) {
                return;
            }
            // Phase I: push contributions along out-links.
            for u in range.clone() {
                let od = g.out_degree(u);
                if od == 0 {
                    continue;
                }
                let contribution = pr[u as usize].load() * inv_out[u as usize];
                for e in g.out_slot_range(u) {
                    contributions[g.offset_list[e]].store(contribution);
                }
            }
            if waiter.wait().is_aborted() {
                return; // ── barrier (Phase I)
            }
            // Phase II: pull from the contribution list.
            let mut thr_err: f64 = 0.0;
            let mut edges = 0u64;
            for u in range.clone() {
                let mut sum = 0.0;
                for slot in g.in_slot_range(u) {
                    sum += contributions[slot].load();
                    amplify_work(cfg.work_amplify);
                }
                edges += g.in_degree(u) as u64;
                let prev = pr[u as usize].load();
                let new = base + d * sum;
                pr[u as usize].store(new);
                thr_err = thr_err.max((prev - new).abs());
            }
            metrics.add_edges(tid, edges);
            board.publish(tid, thr_err);
            if waiter.wait().is_aborted() {
                return; // ── barrier (Phase II)
            }
            // Phase III: global error merge (every thread computes the same
            // max — cheaper than electing thread 0 and barriering again).
            let global_err = board.global_max();
            if waiter.wait().is_aborted() {
                return; // ── barrier (Phase III)
            }
            iter += 1;
            metrics.bump_iteration(tid);
            if global_err <= cfg.threshold {
                converged.store(true, Ordering::Release);
                return;
            }
            if iter >= cfg.max_iterations {
                return;
            }
        }
    });

    PrResult {
        variant: Variant::BarrierEdge,
        ranks: snapshot(&pr),
        iterations: metrics.max_iterations(),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed: start.elapsed(),
        converged: converged.load(Ordering::Acquire) && !outcome.dnf,
        barrier_wait_secs: barrier.total_wait_secs(),
        dnf: outcome.dnf,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{synthetic, PartitionPolicy};
    use crate::pagerank::{self, seq};

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn matches_sequential_on_cycle() {
        let g = synthetic::cycle(30);
        let c = cfg(3);
        let r = pagerank::run(&g, Variant::BarrierEdge, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-10);
    }

    #[test]
    fn matches_sequential_on_web_replica() {
        let g = synthetic::web_replica(700, 6, 23);
        let c = cfg(4);
        let r = pagerank::run(&g, Variant::BarrierEdge, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-9, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn handles_dangling_vertices() {
        let g = synthetic::chain(20); // tail vertex has outdeg 0
        let c = cfg(2);
        let r = pagerank::run(&g, Variant::BarrierEdge, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-10);
    }

    #[test]
    fn matches_vertex_centric_barrier_exactly_in_iterations() {
        // Same synchronous schedule → same iteration count as Algorithm 1.
        let g = synthetic::social_replica(400, 6, 9);
        let c = cfg(2);
        let edge = pagerank::run(&g, Variant::BarrierEdge, &c).unwrap();
        let vert = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        assert_eq!(edge.iterations, vert.iterations);
        assert!(
            crate::pagerank::convergence::linf_norm(&edge.ranks, &vert.ranks) < 1e-12
        );
    }

    #[test]
    fn edge_balanced_partitioning_correct() {
        let g = synthetic::web_replica(500, 8, 31);
        let c = PrConfig { partition: PartitionPolicy::EdgeBalanced, ..cfg(4) };
        let r = pagerank::run(&g, Variant::BarrierEdge, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-9);
    }
}
