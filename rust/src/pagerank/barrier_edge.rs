//! Algorithm 2 — Barrier-Edge: the three-phase edge-centric baseline from
//! Panyala et al. [7], as an engine kernel.
//!
//! * **scatter (push)** — each vertex writes `pr(u)/outdeg(u)` into the
//!   contribution slot of each out-link (via the precomputed
//!   `offset_list`, so every edge has a dedicated slot: no write conflicts).
//! * **gather (pull)** — each vertex sums its in-slots and applies Eq. 1.
//! * the engine's third phase merges the global error.
//!
//! The Blocking driver (with `pre_scatter`) separates all three with
//! barriers. Compared to Algorithm 1 the gather becomes a *contiguous* read
//! over the contribution list — better spatial locality, bought with an
//! extra `m`-sized array and one more barrier per iteration (the trade the
//! paper's Fig 1/2 evaluates).

use crate::engine::{inv_out_degrees, Kernel, SyncMode, WorkerCtx};
use crate::graph::{Csr, Partitions};
use crate::pagerank::{amplify_work, PrConfig};
use crate::sync::atomics::{atomic_vec, snapshot, AtomicF64};
use anyhow::Result;

/// Algorithm 2: edge-centric push/pull with barrier-separated phases.
pub struct BarrierEdgeKernel<'g> {
    g: &'g Csr,
    parts: Partitions,
    inv_out: Vec<f64>,
    // One rank array suffices: the push phase reads ranks (iteration i-1
    // values), the pull phase overwrites them (iteration i) — the barrier
    // between the phases separates the two uses, and the old value needed
    // for the error is read locally before the store. (The paper keeps an
    // explicit prev_pr and copies in Phase III; the single-array form is
    // numerically identical and halves the copy traffic — see
    // EXPERIMENTS.md §Perf.)
    pr: Vec<AtomicF64>,
    contributions: Vec<AtomicF64>,
    base: f64,
    d: f64,
    work_amplify: u32,
}

/// Registry builder for [`Variant::BarrierEdge`](crate::pagerank::Variant).
pub fn kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    let n = g.num_vertices();
    Ok(Box::new(BarrierEdgeKernel {
        g,
        parts: parts.clone(),
        inv_out: inv_out_degrees(g),
        pr: atomic_vec(n, 1.0 / n as f64),
        contributions: atomic_vec(g.num_edges(), 0.0),
        base: (1.0 - cfg.damping) / n as f64,
        d: cfg.damping,
        work_amplify: cfg.work_amplify,
    }))
}

impl Kernel for BarrierEdgeKernel<'_> {
    fn sync_mode(&self) -> SyncMode {
        SyncMode::Blocking { pre_scatter: true }
    }

    /// Push contributions along out-links (Alg 2 lines 8-13).
    fn scatter(&self, ctx: &WorkerCtx<'_>) {
        for u in self.parts.range(ctx.tid) {
            if self.g.out_degree(u) == 0 {
                continue;
            }
            let contribution = self.pr[u as usize].load() * self.inv_out[u as usize];
            for e in self.g.out_slot_range(u) {
                self.contributions[self.g.offset_list[e]].store(contribution);
            }
        }
    }

    /// Pull from the contribution list (Alg 2 lines 16-23).
    fn gather(&self, ctx: &WorkerCtx<'_>) -> f64 {
        let mut thr_err: f64 = 0.0;
        let mut edges = 0u64;
        for u in self.parts.range(ctx.tid) {
            let mut sum = 0.0;
            for slot in self.g.in_slot_range(u) {
                sum += self.contributions[slot].load();
                amplify_work(self.work_amplify);
            }
            edges += self.g.in_degree(u) as u64;
            let prev = self.pr[u as usize].load();
            let new = self.base + self.d * sum;
            self.pr[u as usize].store(new);
            thr_err = thr_err.max((prev - new).abs());
        }
        ctx.metrics.add_edges(ctx.tid, edges);
        ctx.metrics.add_gathered(ctx.tid, self.parts.range(ctx.tid).len() as u64);
        thr_err
    }

    fn ranks(&self) -> Vec<f64> {
        snapshot(&self.pr)
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::{synthetic, PartitionPolicy};
    use crate::pagerank::{self, seq, PrConfig, Variant};

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn matches_sequential_on_cycle() {
        let g = synthetic::cycle(30);
        let c = cfg(3);
        let r = pagerank::run(&g, Variant::BarrierEdge, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-10);
    }

    #[test]
    fn matches_sequential_on_web_replica() {
        let g = synthetic::web_replica(700, 6, 23);
        let c = cfg(4);
        let r = pagerank::run(&g, Variant::BarrierEdge, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-9, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn handles_dangling_vertices() {
        let g = synthetic::chain(20); // tail vertex has outdeg 0
        let c = cfg(2);
        let r = pagerank::run(&g, Variant::BarrierEdge, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-10);
    }

    #[test]
    fn matches_vertex_centric_barrier_exactly_in_iterations() {
        // Same synchronous schedule → same iteration count as Algorithm 1.
        let g = synthetic::social_replica(400, 6, 9);
        let c = cfg(2);
        let edge = pagerank::run(&g, Variant::BarrierEdge, &c).unwrap();
        let vert = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        assert_eq!(edge.iterations, vert.iterations);
        assert!(
            crate::pagerank::convergence::linf_norm(&edge.ranks, &vert.ranks) < 1e-12
        );
    }

    #[test]
    fn edge_balanced_partitioning_correct() {
        let g = synthetic::web_replica(500, 8, 31);
        let c = PrConfig { partition: PartitionPolicy::EdgeBalanced, ..cfg(4) };
        let r = pagerank::run(&g, Variant::BarrierEdge, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-9);
    }
}
