//! `XlaBlock` — the three-layer integration variant.
//!
//! The Layer-1 Pallas kernel (`python/compile/kernels/pagerank_step.py`)
//! computes the ELL-format gather `Σ_k w[u,k] · pr[idx[u,k]]`; the Layer-2
//! JAX model wraps it into a full PageRank step; `make artifacts` lowers it
//! to HLO text per shape bucket; and this module is Layer 3: it converts the
//! CSR graph into the padded ELL layout, picks the smallest artifact bucket
//! that fits, and drives the power iteration with convergence checks in
//! Rust. Python is never on this path.
//!
//! The artifacts are f32 (the TPU-native width the kernel tiles for), so the
//! effective convergence floor is ~1e-6 — `run` clamps the configured
//! threshold accordingly and documents the delta in EXPERIMENTS.md.

use crate::graph::{Csr, VertexId};
use crate::pagerank::{PrConfig, PrResult, Variant};
use crate::runtime::{artifacts, Engine};
use anyhow::{bail, Context, Result};
use std::time::Instant;

/// f32 convergence floor: thresholds below this are clamped.
pub const F32_THRESHOLD_FLOOR: f64 = 1e-6;

/// The padded ELL image of a graph, matching an artifact bucket.
#[derive(Debug, Clone)]
pub struct EllLayout {
    /// ELL neighbour indices, row-major `n_bucket x k_bucket`.
    pub indices: Vec<i32>,
    /// Per-slot contribution weights matching `indices`.
    pub weights: Vec<f32>,
    /// bucket rows (≥ graph vertices)
    pub n_bucket: usize,
    /// bucket lanes (≥ graph max in-degree)
    pub k_bucket: usize,
    /// real vertex count
    pub n_actual: usize,
}

impl EllLayout {
    /// Build the `[n_bucket × k_bucket]` padded in-neighbour table with
    /// damping folded into the weights: `w[u,k] = d / outdeg(v)`.
    /// Padded slots point at vertex 0 with weight 0 (contribute nothing).
    pub fn build(g: &Csr, damping: f64, n_bucket: usize, k_bucket: usize) -> Result<Self> {
        let n = g.num_vertices();
        if n_bucket < n {
            bail!("bucket rows {n_bucket} < graph vertices {n}");
        }
        let max_k = (0..n as VertexId).map(|u| g.in_degree(u)).max().unwrap_or(0);
        if k_bucket < max_k {
            bail!("bucket lanes {k_bucket} < max in-degree {max_k}");
        }
        let mut indices = vec![0i32; n_bucket * k_bucket];
        let mut weights = vec![0f32; n_bucket * k_bucket];
        for u in 0..n as VertexId {
            let row = u as usize * k_bucket;
            for (j, &v) in g.in_neighbors(u).iter().enumerate() {
                indices[row + j] = v as i32;
                let od = g.out_degree(v);
                debug_assert!(od > 0, "in-neighbour must have an out-edge");
                weights[row + j] = (damping / od as f64) as f32;
            }
        }
        Ok(Self { indices, weights, n_bucket, k_bucket, n_actual: n })
    }
}

/// Run PageRank through the AOT-compiled XLA step artifact.
pub fn run(g: &Csr, cfg: &PrConfig, engine: &Engine) -> Result<PrResult> {
    cfg.validate()?;
    let n = g.num_vertices();
    let start = Instant::now();
    if n == 0 {
        return Ok(PrResult::empty(Variant::XlaBlock, cfg.threads));
    }
    let max_k = (0..n as VertexId).map(|u| g.in_degree(u)).max().unwrap_or(0);
    let dir = artifacts::default_dir();
    let step = engine
        .load_best_ell(&dir, n, max_k.max(1))
        .context("selecting ELL artifact bucket")?;
    let layout = EllLayout::build(g, cfg.damping, step.spec.n, step.spec.k)?;

    let base = ((1.0 - cfg.damping) / n as f64) as f32;
    let threshold = cfg.threshold.max(F32_THRESHOLD_FLOOR) as f32;
    let mut pr = vec![1.0f32 / n as f32; layout.n_bucket];
    // padded rows start at 0 so their (unread) trajectories stay at `base`
    for slot in pr.iter_mut().skip(n) {
        *slot = 0.0;
    }

    let mut iterations = 0u64;
    let mut converged = false;
    while iterations < cfg.max_iterations {
        let next = step.run_ell(&layout.indices, &layout.weights, &pr, base)?;
        let mut err = 0f32;
        for u in 0..n {
            err = err.max((next[u] - pr[u]).abs());
        }
        pr = next;
        iterations += 1;
        if err <= threshold {
            converged = true;
            break;
        }
    }

    let ranks: Vec<f64> = pr[..n].iter().map(|&x| x as f64).collect();
    Ok(PrResult {
        variant: Variant::XlaBlock,
        ranks,
        iterations,
        per_thread_iterations: vec![iterations],
        elapsed: start.elapsed(),
        converged,
        barrier_wait_secs: 0.0,
        vertex_updates: iterations * n as u64,
        frontier_switches: 0,
        worklist_peak: 0,
        dnf: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic;

    // End-to-end execution against the compiled artifact lives in
    // rust/tests/integration_runtime.rs (requires `make artifacts`). Here:
    // the layout builder, which is pure Rust.

    #[test]
    fn ell_layout_shapes_and_padding() {
        let g = synthetic::star(5); // hub in-degree 4
        let l = EllLayout::build(&g, 0.85, 8, 4).unwrap();
        assert_eq!(l.indices.len(), 32);
        assert_eq!(l.weights.len(), 32);
        // hub row: 4 in-neighbours (leaves, outdeg 1 → weight d)
        for j in 0..4 {
            assert!((l.weights[j] - 0.85).abs() < 1e-6);
        }
        // padded rows all zero-weight
        for row in 5..8 {
            for j in 0..4 {
                assert_eq!(l.weights[row * 4 + j], 0.0);
                assert_eq!(l.indices[row * 4 + j], 0);
            }
        }
    }

    #[test]
    fn ell_layout_weight_values() {
        // 0→1, 0→2 (outdeg 2): weight to each target is d/2.
        let g = crate::graph::GraphBuilder::new(3)
            .edges(&[(0, 1), (0, 2)])
            .build("w");
        let l = EllLayout::build(&g, 0.85, 4, 2).unwrap();
        let row1 = &l.weights[2..4];
        assert!((row1[0] - 0.425).abs() < 1e-6);
    }

    #[test]
    fn ell_layout_rejects_small_bucket() {
        let g = synthetic::star(10);
        assert!(EllLayout::build(&g, 0.85, 4, 16).is_err()); // rows too few
        assert!(EllLayout::build(&g, 0.85, 16, 2).is_err()); // lanes too few
    }

    #[test]
    fn ell_column_mass_equals_damping() {
        // Each non-dangling source v scatters d/outdeg(v) to each of its
        // outdeg(v) targets, so its total scattered weight is exactly d.
        let g = synthetic::web_replica(300, 5, 3);
        let n = g.num_vertices();
        let maxk = (0..n as u32).map(|u| g.in_degree(u)).max().unwrap();
        let l = EllLayout::build(&g, 0.85, n, maxk).unwrap();
        let mut mass = vec![0f64; n];
        for (slot, &w) in l.weights.iter().enumerate() {
            if w != 0.0 {
                mass[l.indices[slot] as usize] += w as f64;
            }
        }
        for v in 0..n as u32 {
            if g.out_degree(v) > 0 {
                assert!(
                    (mass[v as usize] - 0.85).abs() < 1e-4,
                    "source {v} scatters {}",
                    mass[v as usize]
                );
            }
        }
    }
}
