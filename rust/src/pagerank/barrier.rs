//! Algorithm 1 — the Barrier baseline (the paper's rendition of the
//! STIC-D [11] baseline).
//!
//! Two-phase iteration with a barrier after each phase:
//!
//! * **Phase I** — each thread computes `pr(u)` for its partition from the
//!   previous-iteration array and records its local max delta.
//! * **Phase II** — the global error is merged and `prev ← pr`.
//!
//! Both arrays are shared `AtomicF64` vectors; within an iteration the
//! phases make every access single-writer, so all loads/stores are relaxed.
//! Every thread must arrive at both barriers every iteration — the property
//! the non-blocking variants exist to remove.

use crate::coordinator::executor::run_workers;
use crate::coordinator::metrics::RunMetrics;
use crate::graph::{Csr, Partitions, VertexId};
use crate::pagerank::convergence::ErrorBoard;
use crate::pagerank::{amplify_work, PrConfig, PrResult, Variant};
use crate::sync::atomics::{atomic_vec, snapshot};
use crate::sync::barrier::SenseBarrier;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Reciprocal out-degrees, shared by every variant's inner loop (hoists the
/// per-edge division out of Eq. 1).
pub(crate) fn inv_out_degrees(g: &Csr) -> Vec<f64> {
    (0..g.num_vertices() as VertexId)
        .map(|v| {
            let od = g.out_degree(v);
            if od == 0 {
                0.0
            } else {
                1.0 / od as f64
            }
        })
        .collect()
}

pub(crate) fn empty_result(variant: Variant, threads: usize) -> PrResult {
    PrResult {
        variant,
        ranks: Vec::new(),
        iterations: 0,
        per_thread_iterations: vec![0; threads],
        elapsed: std::time::Duration::ZERO,
        converged: true,
        barrier_wait_secs: 0.0,
        dnf: false,
    }
}

/// Run Algorithm 1.
pub fn run(g: &Csr, cfg: &PrConfig, parts: &Partitions) -> PrResult {
    let n = g.num_vertices();
    let threads = cfg.threads;
    if n == 0 {
        return empty_result(Variant::Barrier, threads);
    }
    let d = cfg.damping;
    let base = (1.0 - d) / n as f64;
    let inv_out = inv_out_degrees(g);

    let pr = atomic_vec(n, 0.0);
    let prev = atomic_vec(n, 1.0 / n as f64);
    let board = ErrorBoard::new(threads);
    let barrier = SenseBarrier::new(threads);
    let metrics = RunMetrics::new(threads);
    let converged = AtomicBool::new(false);

    let start = Instant::now();
    let outcome = run_workers(threads, cfg.dnf_timeout, &[&barrier], |tid, stop| {
        let mut waiter = barrier.waiter();
        let range = parts.range(tid);
        let mut iter = 0u64;
        loop {
            if stop.load(Ordering::Acquire) {
                return;
            }
            if cfg.faults.apply(tid, iter) {
                return; // injected crash: never arrives at the barrier again
            }
            // Phase I: compute this partition from `prev`.
            let mut thr_err: f64 = 0.0;
            let mut edges = 0u64;
            for u in range.clone() {
                let mut sum = 0.0;
                for &v in g.in_neighbors(u) {
                    // SAFETY: CSR validation bounds every endpoint (§Perf).
                    sum += unsafe {
                        prev.get_unchecked(v as usize).load()
                            * inv_out.get_unchecked(v as usize)
                    };
                    amplify_work(cfg.work_amplify);
                }
                edges += g.in_degree(u) as u64;
                let new = base + d * sum;
                thr_err = thr_err.max((new - prev[u as usize].load()).abs());
                pr[u as usize].store(new);
            }
            metrics.add_edges(tid, edges);
            board.publish(tid, thr_err);
            if waiter.wait().is_aborted() {
                return; // ── Barrier Sync Checkpoint (Phase I)
            }
            // Phase II: merge global error, prev ← pr for this partition.
            let global_err = board.global_max();
            for u in range.clone() {
                prev[u as usize].store(pr[u as usize].load());
            }
            if waiter.wait().is_aborted() {
                return; // ── Barrier Sync Checkpoint (Phase II)
            }
            iter += 1;
            metrics.bump_iteration(tid);
            if global_err <= cfg.threshold {
                converged.store(true, Ordering::Release);
                return;
            }
            if iter >= cfg.max_iterations {
                return;
            }
        }
    });

    PrResult {
        variant: Variant::Barrier,
        ranks: snapshot(&prev),
        iterations: metrics.max_iterations(),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed: start.elapsed(),
        converged: converged.load(Ordering::Acquire) && !outcome.dnf,
        barrier_wait_secs: barrier.total_wait_secs(),
        dnf: outcome.dnf,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{synthetic, PartitionPolicy};
    use crate::pagerank::{self, seq};

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn matches_sequential_on_cycle() {
        let g = synthetic::cycle(40);
        let c = cfg(4);
        let r = run(&g, &c, &Partitions::new(&g, 4, PartitionPolicy::VertexBalanced));
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-10, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn matches_sequential_on_web_replica() {
        let g = synthetic::web_replica(800, 6, 17);
        let c = cfg(3);
        let r = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        assert!(r.converged);
        let (sr, seq_iters, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-9, "l1 {}", r.l1_norm(&sr));
        // Barrier is synchronous: iteration count equals sequential.
        assert_eq!(r.iterations, seq_iters);
    }

    #[test]
    fn single_thread_degenerates_to_sequential() {
        let g = synthetic::star(30);
        let c = cfg(1);
        let r = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-12);
    }

    #[test]
    fn more_threads_than_vertices() {
        let g = synthetic::cycle(3);
        let c = cfg(8);
        let r = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-10);
    }

    #[test]
    fn iteration_cap_respected() {
        let g = synthetic::web_replica(400, 5, 2);
        let c = PrConfig { max_iterations: 3, ..cfg(2) };
        let r = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        assert!(!r.converged);
        assert_eq!(r.iterations, 3);
    }

    #[test]
    fn edge_balanced_partitioning_also_correct() {
        let g = synthetic::web_replica(600, 7, 5);
        let c = PrConfig { partition: PartitionPolicy::EdgeBalanced, ..cfg(4) };
        let r = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-9);
    }
}
