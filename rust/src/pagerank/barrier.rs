//! Algorithm 1 — the Barrier baseline (the paper's rendition of the
//! STIC-D [11] baseline), as an engine kernel.
//!
//! Two-phase iteration, scheduled by the engine's Blocking driver with a
//! barrier after each phase:
//!
//! * **gather** — each thread computes `pr(u)` for its partition from the
//!   previous-iteration array and returns its local max delta.
//! * **commit** — after the global error merge, `prev ← pr`.
//!
//! Both arrays are shared `AtomicF64` vectors; within an iteration the
//! phases make every access single-writer, so all loads/stores are relaxed.
//! Every thread must arrive at both barriers every iteration — the property
//! the non-blocking variants exist to remove.

use crate::engine::{inv_out_degrees, Kernel, SyncMode, WorkerCtx};
use crate::graph::{Csr, Partitions};
use crate::pagerank::{amplify_work, PrConfig};
use crate::sync::atomics::{atomic_vec, snapshot, AtomicF64};
use anyhow::Result;

/// Algorithm 1: barrier-synchronized vertex-centric pull kernel.
pub struct BarrierKernel<'g> {
    g: &'g Csr,
    parts: Partitions,
    inv_out: Vec<f64>,
    pr: Vec<AtomicF64>,
    prev: Vec<AtomicF64>,
    base: f64,
    d: f64,
    work_amplify: u32,
}

/// Registry builder for [`Variant::Barrier`](crate::pagerank::Variant).
pub fn kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    let n = g.num_vertices();
    Ok(Box::new(BarrierKernel {
        g,
        parts: parts.clone(),
        inv_out: inv_out_degrees(g),
        pr: atomic_vec(n, 0.0),
        prev: atomic_vec(n, 1.0 / n as f64),
        base: (1.0 - cfg.damping) / n as f64,
        d: cfg.damping,
        work_amplify: cfg.work_amplify,
    }))
}

impl Kernel for BarrierKernel<'_> {
    fn sync_mode(&self) -> SyncMode {
        SyncMode::Blocking { pre_scatter: false }
    }

    /// Phase I: compute this partition from `prev`.
    fn gather(&self, ctx: &WorkerCtx<'_>) -> f64 {
        let mut thr_err: f64 = 0.0;
        let mut edges = 0u64;
        for u in self.parts.range(ctx.tid) {
            let mut sum = 0.0;
            for &v in self.g.in_neighbors(u) {
                // SAFETY: CSR validation bounds every endpoint (§Perf).
                sum += unsafe {
                    self.prev.get_unchecked(v as usize).load()
                        * self.inv_out.get_unchecked(v as usize)
                };
                amplify_work(self.work_amplify);
            }
            edges += self.g.in_degree(u) as u64;
            let new = self.base + self.d * sum;
            thr_err = thr_err.max((new - self.prev[u as usize].load()).abs());
            self.pr[u as usize].store(new);
        }
        ctx.metrics.add_edges(ctx.tid, edges);
        ctx.metrics.add_gathered(ctx.tid, self.parts.range(ctx.tid).len() as u64);
        thr_err
    }

    /// Phase II: `prev ← pr` for this partition.
    fn commit(&self, ctx: &WorkerCtx<'_>) {
        for u in self.parts.range(ctx.tid) {
            self.prev[u as usize].store(self.pr[u as usize].load());
        }
    }

    fn ranks(&self) -> Vec<f64> {
        snapshot(&self.prev)
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::{synthetic, PartitionPolicy};
    use crate::pagerank::{self, seq, PrConfig, Variant};

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn matches_sequential_on_cycle() {
        let g = synthetic::cycle(40);
        let c = cfg(4);
        let r = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-10, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn matches_sequential_on_web_replica() {
        let g = synthetic::web_replica(800, 6, 17);
        let c = cfg(3);
        let r = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        assert!(r.converged);
        let (sr, seq_iters, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-9, "l1 {}", r.l1_norm(&sr));
        // Barrier is synchronous: iteration count equals sequential.
        assert_eq!(r.iterations, seq_iters);
    }

    #[test]
    fn single_thread_degenerates_to_sequential() {
        let g = synthetic::star(30);
        let c = cfg(1);
        let r = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-12);
    }

    #[test]
    fn more_threads_than_vertices() {
        let g = synthetic::cycle(3);
        let c = cfg(8);
        let r = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-10);
    }

    #[test]
    fn iteration_cap_respected() {
        let g = synthetic::web_replica(400, 5, 2);
        let c = PrConfig { max_iterations: 3, ..cfg(2) };
        let r = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        assert!(!r.converged);
        assert_eq!(r.iterations, 3);
    }

    #[test]
    fn edge_balanced_partitioning_also_correct() {
        let g = synthetic::web_replica(600, 7, 5);
        let c = PrConfig { partition: PartitionPolicy::EdgeBalanced, ..cfg(4) };
        let r = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-9);
    }

    #[test]
    fn barrier_wait_telemetry_reported() {
        let g = synthetic::web_replica(500, 6, 11);
        let r = pagerank::run(&g, Variant::Barrier, &cfg(4)).unwrap();
        assert!(r.converged);
        // Four workers over dozens of iterations: the non-leader arrivals
        // at each phase barrier must have accumulated some wait time —
        // 0.0 would mean the engine lost the telemetry in the refactor.
        assert!(r.barrier_wait_secs > 0.0, "wait {}", r.barrier_wait_secs);
    }
}
