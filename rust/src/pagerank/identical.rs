//! `*-Identical` variants: Algorithm 1 / Algorithm 3 augmented with the
//! STIC-D identical-node technique (paper §3 [11], evaluated as
//! Barriers-Identical / No-Sync-Identical in Figs 1–2), as one engine
//! kernel with two sync modes.
//!
//! Vertices with the same in-neighbour set provably share a PageRank, so
//! each equivalence class is computed once (at its representative) and the
//! value is broadcast to the members — eliminating
//! [`IdenticalClasses::redundant_vertices`] rank computations per iteration.
//! Class detection is a preprocessing step, included in the reported wall
//! time (as in the source papers): the engine starts the clock before the
//! kernel builder runs.

use crate::engine::{inv_out_degrees, Kernel, SyncMode, WorkerCtx};
use crate::graph::identical::IdenticalClasses;
use crate::graph::{Csr, Partitions};
use crate::pagerank::{amplify_work, PrConfig};
use crate::sync::atomics::{atomic_vec, snapshot, AtomicF64};
use anyhow::Result;

/// Split `count` class ids into `threads` contiguous chunks, balanced by
/// the per-class `load` (in-degree of the representative — the gather cost).
pub(crate) fn split_classes(
    loads: &[usize],
    threads: usize,
) -> Vec<std::ops::Range<usize>> {
    let count = loads.len();
    let total: usize = loads.iter().sum();
    let target = (total as f64 / threads as f64).max(1.0);
    let mut bounds = vec![0usize];
    let mut acc = 0usize;
    for (i, &l) in loads.iter().enumerate() {
        acc += l;
        let cuts = bounds.len() - 1;
        let remaining = count - (i + 1);
        if cuts < threads - 1
            && (acc as f64 >= target * bounds.len() as f64 || remaining == threads - 1 - cuts)
        {
            bounds.push(i + 1);
        }
    }
    while bounds.len() < threads {
        bounds.push(count);
    }
    bounds.push(count);
    (0..threads).map(|i| bounds[i]..bounds[i + 1]).collect()
}

/// STIC-D identical-vertex kernel: one gather per class representative.
pub struct IdenticalKernel<'g> {
    g: &'g Csr,
    blocking: bool,
    classes: IdenticalClasses,
    chunks: Vec<std::ops::Range<usize>>,
    inv_out: Vec<f64>,
    pr: Vec<AtomicF64>,
    /// Only allocated in blocking mode (Alg 1 keeps two arrays; Alg 3's
    /// in-place update needs one).
    prev: Vec<AtomicF64>,
    base: f64,
    d: f64,
    work_amplify: u32,
}

fn build<'g>(g: &'g Csr, cfg: &PrConfig, blocking: bool) -> IdenticalKernel<'g> {
    let n = g.num_vertices();
    let classes = IdenticalClasses::compute(g);
    let loads: Vec<usize> = classes
        .representatives
        .iter()
        .map(|&r| g.in_degree(r).max(1))
        .collect();
    let chunks = split_classes(&loads, cfg.threads);
    IdenticalKernel {
        g,
        blocking,
        classes,
        chunks,
        inv_out: inv_out_degrees(g),
        pr: atomic_vec(n, 1.0 / n as f64),
        prev: if blocking { atomic_vec(n, 1.0 / n as f64) } else { Vec::new() },
        base: (1.0 - cfg.damping) / n as f64,
        d: cfg.damping,
        work_amplify: cfg.work_amplify,
    }
}

/// Registry builder for Barriers-Identical (Algorithm 1 over class
/// representatives).
pub fn barrier_kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    _parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    Ok(Box::new(build(g, cfg, true)))
}

/// Registry builder for No-Sync-Identical (Algorithm 3 over class
/// representatives).
pub fn nosync_kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    _parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    Ok(Box::new(build(g, cfg, false)))
}

impl IdenticalKernel<'_> {
    #[inline]
    fn read(&self, u: usize) -> f64 {
        if self.blocking {
            self.prev[u].load()
        } else {
            self.pr[u].load()
        }
    }
}

impl Kernel for IdenticalKernel<'_> {
    fn sync_mode(&self) -> SyncMode {
        if self.blocking {
            SyncMode::Blocking { pre_scatter: false }
        } else {
            SyncMode::NonBlocking
        }
    }

    /// Compute each class once at its representative, broadcast to members.
    fn gather(&self, ctx: &WorkerCtx<'_>) -> f64 {
        let mut local_err: f64 = 0.0;
        for c in self.chunks[ctx.tid].clone() {
            let rep = self.classes.representatives[c];
            let previous = self.read(rep as usize);
            let mut sum = 0.0;
            for &v in self.g.in_neighbors(rep) {
                sum += self.read(v as usize) * self.inv_out[v as usize];
                amplify_work(self.work_amplify);
            }
            let new = self.base + self.d * sum;
            local_err = local_err.max((new - previous).abs());
            // broadcast to the whole class
            for &m in &self.classes.members[c] {
                self.pr[m as usize].store(new);
            }
        }
        // one rank computation per class — the STIC-D savings show up here
        ctx.metrics.add_gathered(ctx.tid, self.chunks[ctx.tid].len() as u64);
        local_err
    }

    /// Blocking hand-off: `prev ← pr` for this chunk's class members.
    fn commit(&self, ctx: &WorkerCtx<'_>) {
        for c in self.chunks[ctx.tid].clone() {
            for &m in &self.classes.members[c] {
                self.prev[m as usize].store(self.pr[m as usize].load());
            }
        }
    }

    fn ranks(&self) -> Vec<f64> {
        snapshot(&self.pr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic;
    use crate::pagerank::{self, seq, Variant};

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn split_classes_covers_all() {
        let loads = vec![3, 1, 4, 1, 5, 9, 2, 6];
        let chunks = split_classes(&loads, 3);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].start, 0);
        assert_eq!(chunks.last().unwrap().end, 8);
        for w in chunks.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
    }

    #[test]
    fn split_more_threads_than_classes() {
        let chunks = split_classes(&[1, 1], 5);
        assert_eq!(chunks.len(), 5);
        let covered: usize = chunks.iter().map(|c| c.len()).sum();
        assert_eq!(covered, 2);
    }

    #[test]
    fn barrier_identical_matches_sequential_on_star() {
        // star: all leaves form one identical class — big savings, same ranks.
        let g = synthetic::star(40);
        let c = cfg(3);
        let r = pagerank::run(&g, Variant::BarrierIdentical, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-9, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn nosync_identical_matches_sequential_on_web() {
        let g = synthetic::web_replica(700, 6, 29);
        let c = cfg(4);
        let r = pagerank::run(&g, Variant::NoSyncIdentical, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-7, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn identical_members_share_final_rank_exactly() {
        let g = synthetic::web_replica(500, 5, 37);
        let classes = IdenticalClasses::compute(&g);
        let r = pagerank::run(&g, Variant::BarrierIdentical, &cfg(2)).unwrap();
        for (c, ms) in classes.members.iter().enumerate() {
            let rep_rank = r.ranks[classes.representatives[c] as usize];
            for &m in ms {
                assert_eq!(
                    r.ranks[m as usize], rep_rank,
                    "class {c} member {m} diverged"
                );
            }
        }
    }

    #[test]
    fn works_when_every_vertex_is_its_own_class() {
        let g = synthetic::cycle(30);
        let c = cfg(2);
        let r = pagerank::run(&g, Variant::NoSyncIdentical, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-9);
    }
}
