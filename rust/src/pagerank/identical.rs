//! `*-Identical` variants: Algorithm 1 / Algorithm 3 augmented with the
//! STIC-D identical-node technique (paper §3 [11], evaluated as
//! Barriers-Identical / No-Sync-Identical in Figs 1–2).
//!
//! Vertices with the same in-neighbour set provably share a PageRank, so
//! each equivalence class is computed once (at its representative) and the
//! value is broadcast to the members — eliminating
//! [`IdenticalClasses::redundant_vertices`] rank computations per iteration.
//! Class detection is a preprocessing step, included in the reported wall
//! time (as in the source papers).

use crate::coordinator::executor::run_workers;
use crate::coordinator::metrics::RunMetrics;
use crate::graph::identical::IdenticalClasses;
use crate::graph::{Csr, Partitions};
use crate::pagerank::barrier::{empty_result, inv_out_degrees};
use crate::pagerank::convergence::ErrorBoard;
use crate::pagerank::{amplify_work, PrConfig, PrResult, Variant};
use crate::sync::atomics::{atomic_vec, snapshot};
use crate::sync::barrier::SenseBarrier;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Split `count` class ids into `threads` contiguous chunks, balanced by
/// the per-class `load` (in-degree of the representative — the gather cost).
pub(crate) fn split_classes(
    loads: &[usize],
    threads: usize,
) -> Vec<std::ops::Range<usize>> {
    let count = loads.len();
    let total: usize = loads.iter().sum();
    let target = (total as f64 / threads as f64).max(1.0);
    let mut bounds = vec![0usize];
    let mut acc = 0usize;
    for (i, &l) in loads.iter().enumerate() {
        acc += l;
        let cuts = bounds.len() - 1;
        let remaining = count - (i + 1);
        if cuts < threads - 1
            && (acc as f64 >= target * bounds.len() as f64 || remaining == threads - 1 - cuts)
        {
            bounds.push(i + 1);
        }
    }
    while bounds.len() < threads {
        bounds.push(count);
    }
    bounds.push(count);
    (0..threads).map(|i| bounds[i]..bounds[i + 1]).collect()
}

/// Barriers-Identical: Algorithm 1 over class representatives.
pub fn run_barrier(g: &Csr, cfg: &PrConfig, _parts: &Partitions) -> PrResult {
    run_impl(g, cfg, Variant::BarrierIdentical)
}

/// No-Sync-Identical: Algorithm 3 over class representatives.
pub fn run_nosync(g: &Csr, cfg: &PrConfig, _parts: &Partitions) -> PrResult {
    run_impl(g, cfg, Variant::NoSyncIdentical)
}

fn run_impl(g: &Csr, cfg: &PrConfig, variant: Variant) -> PrResult {
    let n = g.num_vertices();
    let threads = cfg.threads;
    if n == 0 {
        return empty_result(variant, threads);
    }
    let start = Instant::now();
    let classes = IdenticalClasses::compute(g);
    let d = cfg.damping;
    let base = (1.0 - d) / n as f64;
    let inv_out = inv_out_degrees(g);

    let loads: Vec<usize> = classes
        .representatives
        .iter()
        .map(|&r| g.in_degree(r).max(1))
        .collect();
    let chunks = split_classes(&loads, threads);

    let blocking = variant == Variant::BarrierIdentical;
    let pr = atomic_vec(n, 1.0 / n as f64);
    // `prev` is only used by the blocking variant (Alg 1 keeps two arrays;
    // Alg 3's in-place update needs one).
    let prev = if blocking { atomic_vec(n, 1.0 / n as f64) } else { Vec::new() };
    let read = |u: usize| -> f64 {
        if blocking {
            prev[u].load()
        } else {
            pr[u].load()
        }
    };

    let board = ErrorBoard::new(threads);
    let barrier = SenseBarrier::new(threads);
    let metrics = RunMetrics::new(threads);
    let converged = AtomicBool::new(false);
    let capped = AtomicBool::new(false);

    let outcome = run_workers(
        threads,
        cfg.dnf_timeout,
        &[&barrier],
        |tid, stop| {
            let mut waiter = barrier.waiter();
            let chunk = chunks[tid].clone();
            let mut iter = 0u64;
            // confirmation-sweep counter (non-blocking path only); see
            // nosync.rs for the staleness rationale
            let mut calm = 0u32;
            loop {
                if stop.load(Ordering::Acquire) {
                    return;
                }
                if cfg.faults.apply(tid, iter) {
                    return;
                }
                let mut local_err: f64 = 0.0;
                for c in chunk.clone() {
                    let rep = classes.representatives[c];
                    let previous = read(rep as usize);
                    let mut sum = 0.0;
                    for &v in g.in_neighbors(rep) {
                        sum += read(v as usize) * inv_out[v as usize];
                        amplify_work(cfg.work_amplify);
                    }
                    let new = base + d * sum;
                    local_err = local_err.max((new - previous).abs());
                    // broadcast to the whole class
                    for &m in &classes.members[c] {
                        pr[m as usize].store(new);
                    }
                }
                board.publish(tid, local_err);
                iter += 1;
                metrics.bump_iteration(tid);
                if blocking {
                    if waiter.wait().is_aborted() {
                        return;
                    }
                    let global_err = board.global_max();
                    for c in chunk.clone() {
                        for &m in &classes.members[c] {
                            prev[m as usize].store(pr[m as usize].load());
                        }
                    }
                    if waiter.wait().is_aborted() {
                        return;
                    }
                    if global_err <= cfg.threshold {
                        converged.store(true, Ordering::Release);
                        return;
                    }
                } else {
                    let merged = board.global_max();
                    if merged <= cfg.threshold {
                        calm += 1;
                        if calm >= 2 {
                            return;
                        }
                    } else {
                        calm = 0;
                    }
                    std::thread::yield_now();
                }
                if iter >= cfg.max_iterations {
                    capped.store(true, Ordering::Release);
                    return;
                }
            }
        },
    );

    let done = if blocking {
        converged.load(Ordering::Acquire)
    } else {
        !capped.load(Ordering::Acquire)
    };
    PrResult {
        variant,
        ranks: snapshot(&pr),
        iterations: metrics.max_iterations(),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed: start.elapsed(),
        converged: done && !outcome.dnf,
        barrier_wait_secs: barrier.total_wait_secs(),
        dnf: outcome.dnf,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic;
    use crate::pagerank::{self, seq};

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn split_classes_covers_all() {
        let loads = vec![3, 1, 4, 1, 5, 9, 2, 6];
        let chunks = split_classes(&loads, 3);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].start, 0);
        assert_eq!(chunks.last().unwrap().end, 8);
        for w in chunks.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
    }

    #[test]
    fn split_more_threads_than_classes() {
        let chunks = split_classes(&[1, 1], 5);
        assert_eq!(chunks.len(), 5);
        let covered: usize = chunks.iter().map(|c| c.len()).sum();
        assert_eq!(covered, 2);
    }

    #[test]
    fn barrier_identical_matches_sequential_on_star() {
        // star: all leaves form one identical class — big savings, same ranks.
        let g = synthetic::star(40);
        let c = cfg(3);
        let r = pagerank::run(&g, Variant::BarrierIdentical, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-9, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn nosync_identical_matches_sequential_on_web() {
        let g = synthetic::web_replica(700, 6, 29);
        let c = cfg(4);
        let r = pagerank::run(&g, Variant::NoSyncIdentical, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-7, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn identical_members_share_final_rank_exactly() {
        let g = synthetic::web_replica(500, 5, 37);
        let classes = IdenticalClasses::compute(&g);
        let r = pagerank::run(&g, Variant::BarrierIdentical, &cfg(2)).unwrap();
        for (c, ms) in classes.members.iter().enumerate() {
            let rep_rank = r.ranks[classes.representatives[c] as usize];
            for &m in ms {
                assert_eq!(
                    r.ranks[m as usize], rep_rank,
                    "class {c} member {m} diverged"
                );
            }
        }
    }

    #[test]
    fn works_when_every_vertex_is_its_own_class() {
        let g = synthetic::cycle(30);
        let c = cfg(2);
        let r = pagerank::run(&g, Variant::NoSyncIdentical, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(r.l1_norm(&sr) < 1e-9);
    }
}
