//! The paper's PageRank algorithm family, decomposed into engine kernels.
//!
//! Every program is a thin [`crate::engine::Kernel`] — the per-iteration
//! math — scheduled by the unified engine under a
//! [`crate::engine::SyncMode`]:
//!
//! | Variant                | Alg | Kernel (module)         | SyncMode                 | Convergence level        |
//! |------------------------|-----|-------------------------|--------------------------|--------------------------|
//! | `Sequential`           | —   | `seq`                   | Sequential               | algorithm                |
//! | `Barrier`              | 1   | `barrier`               | Blocking                 | algorithm                |
//! | `BarrierIdentical`     | 1+[11] | `identical`          | Blocking                 | algorithm                |
//! | `BarrierEdge`          | 2   | `barrier_edge`          | Blocking + pre-scatter   | algorithm                |
//! | `BarrierOpt`           | 5   | `perforation`           | Blocking                 | node + algorithm         |
//! | `WaitFree`             | 6   | `waitfree`              | Helping                  | algorithm (wait-free)    |
//! | `NoSync`               | 3   | `nosync`                | NonBlocking              | thread                   |
//! | `NoSyncIdentical`      | 3+[11] | `identical`          | NonBlocking              | thread                   |
//! | `NoSyncEdge`           | 4   | `nosync_edge`           | NonBlocking + scatter    | thread (may not converge)|
//! | `NoSyncOpt`            | 5   | `perforation`           | NonBlocking              | node + thread            |
//! | `NoSyncOptIdentical`   | 5+[11] | `perforation`        | NonBlocking              | node + thread            |
//! | `Pcpm`                 | —   | `engine::pcpm`          | Blocking + pre-scatter   | algorithm                |
//! | `Frontier`             | —   | `engine::frontier`      | NonBlocking (frontier)   | thread                   |
//! | `FrontierPcpm`         | —   | `engine::frontier`      | NonBlocking (frontier)   | thread                   |
//! | `XlaBlock`             | —   | `xla_block` (no kernel) | — (PJRT engine)          | algorithm                |
//!
//! The kernel supplies `scatter`/`gather`/`commit` hooks; the engine owns
//! worker lifecycle (spawn, partition pinning, fault-plan application, DNF
//! watchdog), termination detection at every level (algorithm, thread,
//! node, wait-free helping), and [`PrResult`] telemetry assembly. Dispatch
//! goes through the single table in [`crate::engine::REGISTRY`]; `XlaBlock`
//! requires a loaded [`crate::runtime::Engine`] and is dispatched through
//! [`run_with_engine`] instead.

pub mod barrier;
pub mod barrier_edge;
pub mod convergence;
pub mod identical;
pub mod nosync;
pub mod nosync_edge;
pub mod perforation;
pub mod seq;
pub mod waitfree;
pub mod xla_block;

use crate::coordinator::faults::FaultPlan;
use crate::graph::{Csr, PartitionPolicy};
use anyhow::{bail, Result};
use std::time::Duration;

/// Which algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Single-threaded oracle (the Eq. 1 fixed point).
    Sequential,
    /// Algorithm 1: barrier-synchronized vertex-centric pull.
    Barrier,
    /// Algorithm 1 + STIC-D identical-vertex elimination.
    BarrierIdentical,
    /// Algorithm 2: barrier-synchronized edge-centric push/pull.
    BarrierEdge,
    /// Algorithm 5, blocking: loop-perforation approximation.
    BarrierOpt,
    /// Algorithm 6: wait-free CAS-helping.
    WaitFree,
    /// Algorithm 3: barrier-free vertex-centric pull.
    NoSync,
    /// Algorithm 3 + identical-vertex elimination.
    NoSyncIdentical,
    /// Algorithm 4: barrier-free edge-centric push (may not converge, sect. 4.4).
    NoSyncEdge,
    /// Algorithm 5, non-blocking: loop perforation.
    NoSyncOpt,
    /// Algorithm 5 + identical-vertex elimination.
    NoSyncOptIdentical,
    /// Partition-centric scatter-gather (Lakhotia et al.) — ours, on top of
    /// the unified engine; not one of the paper's programs.
    Pcpm,
    /// Frontier/delta-scheduled non-blocking kernel (delayed-async per
    /// Blanco et al., arXiv:2110.01409): gathers only vertices whose
    /// in-neighbourhood changed by more than the delta threshold. Ours.
    Frontier,
    /// Frontier scheduling with PCPM-style propagation: changed vertices
    /// scatter their contribution through the partition bins instead of
    /// readers pulling the full rank array. Ours.
    FrontierPcpm,
    /// Dense/ELL PageRank steps compiled via XLA (needs `make artifacts`).
    XlaBlock,
}

impl Variant {
    /// Every CPU variant of the paper, in the order its figures list
    /// programs.
    pub const ALL_CPU: [Variant; 11] = [
        Variant::Sequential,
        Variant::Barrier,
        Variant::BarrierIdentical,
        Variant::BarrierEdge,
        Variant::BarrierOpt,
        Variant::WaitFree,
        Variant::NoSync,
        Variant::NoSyncIdentical,
        Variant::NoSyncEdge,
        Variant::NoSyncOpt,
        Variant::NoSyncOptIdentical,
    ];

    /// Every engine-dispatched mode: the paper's eleven CPU variants plus
    /// the partition-centric and frontier/delta modes.
    pub const ALL_MODES: [Variant; 14] = [
        Variant::Sequential,
        Variant::Barrier,
        Variant::BarrierIdentical,
        Variant::BarrierEdge,
        Variant::BarrierOpt,
        Variant::WaitFree,
        Variant::NoSync,
        Variant::NoSyncIdentical,
        Variant::NoSyncEdge,
        Variant::NoSyncOpt,
        Variant::NoSyncOptIdentical,
        Variant::Pcpm,
        Variant::Frontier,
        Variant::FrontierPcpm,
    ];

    /// The paper's parallel variants (everything CPU but `Sequential`).
    pub fn parallel_cpu() -> impl Iterator<Item = Variant> {
        Self::ALL_CPU.into_iter().filter(|v| *v != Variant::Sequential)
    }

    /// Parallel variants plus the engine-native modes (partition-centric
    /// and frontier/delta) — what the harness sweeps so every
    /// variant×dataset experiment also covers them.
    pub fn parallel_modes() -> impl Iterator<Item = Variant> {
        Self::parallel_cpu().chain([Variant::Pcpm, Variant::Frontier, Variant::FrontierPcpm])
    }

    /// Does this variant use barriers (blocking synchronization)?
    pub fn is_blocking(self) -> bool {
        matches!(
            self,
            Variant::Barrier
                | Variant::BarrierIdentical
                | Variant::BarrierEdge
                | Variant::BarrierOpt
                | Variant::Pcpm
        )
    }

    /// Is this a non-blocking (lock-free / wait-free) variant?
    pub fn is_non_blocking(self) -> bool {
        matches!(
            self,
            Variant::WaitFree
                | Variant::NoSync
                | Variant::NoSyncIdentical
                | Variant::NoSyncEdge
                | Variant::NoSyncOpt
                | Variant::NoSyncOptIdentical
                | Variant::Frontier
                | Variant::FrontierPcpm
        )
    }

    /// Uses the loop-perforation approximation (Alg 5)? Those variants trade
    /// L1-norm for speed (Figs 5–6).
    pub fn is_approximate(self) -> bool {
        matches!(
            self,
            Variant::BarrierOpt | Variant::NoSyncOpt | Variant::NoSyncOptIdentical
        )
    }

    /// Canonical display name, as used in the paper's figures.
    pub fn name(self) -> &'static str {
        match self {
            Variant::Sequential => "Sequential",
            Variant::Barrier => "Barrier",
            Variant::BarrierIdentical => "Barrier-Identical",
            Variant::BarrierEdge => "Barrier-Edge",
            Variant::BarrierOpt => "Barrier-Opt",
            Variant::WaitFree => "Wait-Free",
            Variant::NoSync => "No-Sync",
            Variant::NoSyncIdentical => "No-Sync-Identical",
            Variant::NoSyncEdge => "No-Sync-Edge",
            Variant::NoSyncOpt => "No-Sync-Opt",
            Variant::NoSyncOptIdentical => "No-Sync-Opt-Identical",
            Variant::Pcpm => "PCPM",
            Variant::Frontier => "Frontier",
            Variant::FrontierPcpm => "Frontier-PCPM",
            Variant::XlaBlock => "XLA-Block",
        }
    }

    /// Parse a CLI variant name (case/underscore tolerant).
    pub fn parse(s: &str) -> Result<Variant> {
        let norm = s.to_ascii_lowercase().replace(['_', ' '], "-");
        Ok(match norm.as_str() {
            "seq" | "sequential" => Variant::Sequential,
            "barrier" | "barriers" => Variant::Barrier,
            "barrier-identical" | "barriers-identical" => Variant::BarrierIdentical,
            "barrier-edge" | "barriers-edge" => Variant::BarrierEdge,
            "barrier-opt" | "barriers-opt" => Variant::BarrierOpt,
            "wait-free" | "waitfree" | "barrier-helper" => Variant::WaitFree,
            "no-sync" | "nosync" => Variant::NoSync,
            "no-sync-identical" | "nosync-identical" => Variant::NoSyncIdentical,
            "no-sync-edge" | "nosync-edge" => Variant::NoSyncEdge,
            "no-sync-opt" | "nosync-opt" => Variant::NoSyncOpt,
            "no-sync-opt-identical" | "nosync-opt-identical" => Variant::NoSyncOptIdentical,
            "pcpm" | "partition-centric" => Variant::Pcpm,
            "frontier" | "delta" | "frontier-delta" => Variant::Frontier,
            "frontier-pcpm" | "delta-pcpm" => Variant::FrontierPcpm,
            "xla-block" | "xla" => Variant::XlaBlock,
            _ => bail!("unknown variant '{s}'"),
        })
    }
}

impl std::fmt::Display for Variant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Which update-bin layout the PCPM kernels run on (CLI: `--pcpm-layout`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PcpmLayout {
    /// One value slot per `(source vertex, destination partition)` group —
    /// the Lakhotia-style compressed stream
    /// ([`crate::graph::CompressedBins::new`]). Default.
    Compressed,
    /// One value slot per edge — the pre-compression layout, kept as the
    /// ablation baseline ([`crate::graph::CompressedBins::new_per_edge`]).
    Slots,
}

impl std::fmt::Display for PcpmLayout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PcpmLayout::Compressed => f.write_str("compressed"),
            PcpmLayout::Slots => f.write_str("slots"),
        }
    }
}

impl PcpmLayout {
    /// Parse a `--pcpm-layout` value.
    pub fn parse(s: &str) -> Result<PcpmLayout> {
        match s.to_ascii_lowercase().as_str() {
            "compressed" | "stream" => Ok(PcpmLayout::Compressed),
            "slots" | "per-edge" | "uncompressed" => Ok(PcpmLayout::Slots),
            other => bail!("--pcpm-layout must be compressed|slots, got '{other}'"),
        }
    }
}

/// How the frontier kernels discover dirty vertices (CLI:
/// `--frontier-sched`). Scheduling changes *how* the frontier is found,
/// never *which* vertices are gathered: every mode processes exactly the
/// start-of-sweep frontier snapshot in ascending vertex order, so a
/// single-threaded run is bit-identical across all three.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrontierSched {
    /// Scan the dirty bitmap word-by-word every sweep (the PR-4 baseline;
    /// O(n/64) per sweep regardless of how sparse the frontier is).
    Bitmap,
    /// Claim-based work-list: marked vertices are enqueued on a per-owner
    /// MPMC ring ([`crate::sync::WorkList`]) and the owner pops instead of
    /// scanning. Falls back to a bitmap scan on ring overflow.
    Worklist,
    /// Per-sweep choice: bitmap scan while the active fraction is dense,
    /// work-list once it drops below one vertex per bitmap word.
    Hybrid,
}

impl std::fmt::Display for FrontierSched {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrontierSched::Bitmap => f.write_str("bitmap"),
            FrontierSched::Worklist => f.write_str("worklist"),
            FrontierSched::Hybrid => f.write_str("hybrid"),
        }
    }
}

impl FrontierSched {
    /// Parse a `--frontier-sched` value.
    pub fn parse(s: &str) -> Result<FrontierSched> {
        match s.to_ascii_lowercase().as_str() {
            "bitmap" | "scan" => Ok(FrontierSched::Bitmap),
            "worklist" | "work-list" | "queue" => Ok(FrontierSched::Worklist),
            "hybrid" | "auto" => Ok(FrontierSched::Hybrid),
            other => bail!("--frontier-sched must be bitmap|worklist|hybrid, got '{other}'"),
        }
    }
}

/// Run configuration.
#[derive(Debug, Clone)]
pub struct PrConfig {
    /// Dampening parameter `d` (paper: 0.85).
    pub damping: f64,
    /// Convergence threshold on the max per-vertex delta. The paper states
    /// `1e-16`; see [`crate::DEFAULT_THRESHOLD`] for why the default is
    /// `1e-10`.
    pub threshold: f64,
    /// Safety cap (No-Sync-Edge "does not converge for particular types of
    /// datasets", §4.4 — the cap turns that into `converged = false`).
    pub max_iterations: u64,
    /// Worker thread count `p`.
    pub threads: usize,
    /// How to split the vertex set across workers.
    pub partition: PartitionPolicy,
    /// Loop-perforation cutoff factor: a vertex whose delta is non-zero and
    /// below `threshold * perforation_factor` is frozen (Alg 5 uses
    /// `threshold * 1e-5`, i.e. the paper's `1e-21` at threshold `1e-16`).
    pub perforation_factor: f64,
    /// Frontier scheduling push cutoff: a vertex re-marks its out-neighbours
    /// only when its rank moved more than this since its last push. `0.0`
    /// (the default) means "derive from the convergence threshold" — see
    /// [`PrConfig::resolved_delta_threshold`]. Only the `Frontier*` variants
    /// read it. CLI: `--delta-threshold`.
    pub delta_threshold: f64,
    /// Autotune the frontier push cutoff from the observed residual decay
    /// (Blanco et al.'s delayed-async schedule): the cutoff starts at
    /// [`PrConfig::resolved_delta_threshold`] and is tightened when the
    /// global residual stalls / loosened when it decays fast, clamped to
    /// `[threshold/100, threshold*10]` so the un-propagated residual bound
    /// `delta / (1 - d)` stays far inside the 1e-6-vs-Barrier equivalence
    /// budget. Only the `Frontier*` variants read it.
    /// CLI: `--delta-threshold auto`.
    pub delta_auto: bool,
    /// How the frontier kernels discover dirty vertices (bitmap scan,
    /// claim-based work-list, or the density-switched hybrid). Only the
    /// `Frontier*` variants read it. CLI: `--frontier-sched`.
    pub frontier_sched: FrontierSched,
    /// NUMA worker-placement policy ([`crate::engine::topology`]): `Off`
    /// leaves threads floating, `Pin` binds node-contiguous worker blocks
    /// (and therefore contiguous partition/vertex ranges) to their node's
    /// CPUs with a first-touch pre-pass, `Interleave` round-robins workers
    /// across nodes. Single-node hosts fall back gracefully. CLI: `--numa`.
    pub numa: crate::engine::topology::Placement,
    /// Synthetic extra work per edge (spin iterations through
    /// `std::hint::black_box`) so scheduling effects dominate on hosts with
    /// fewer cores than the paper's 56; numerics are unaffected. 0 = off.
    pub work_amplify: u32,
    /// PCPM source-partition batch: the graph is cut into
    /// `threads × pcpm_batch` partitions and each worker scatters its
    /// `pcpm_batch` partitions before switching to gather, so the gather
    /// accumulator covers a partition small enough to stay cache-resident
    /// (Lakhotia et al. §4). `1` (default) reproduces one-partition-per-
    /// thread. Only `Variant::Pcpm` reads it. CLI: `--pcpm-batch`.
    pub pcpm_batch: usize,
    /// Update-bin layout for the PCPM kernels (compressed value stream vs
    /// the per-edge baseline). CLI: `--pcpm-layout`.
    pub pcpm_layout: PcpmLayout,
    /// Fault-injection schedule (sleeps / failures) for Figs 8–9.
    pub faults: FaultPlan,
    /// Watchdog: abort the run (DNF) if it exceeds this wall-clock bound.
    /// Blocking variants with failed threads would otherwise hang forever.
    pub dnf_timeout: Option<Duration>,
}

impl Default for PrConfig {
    fn default() -> Self {
        Self {
            damping: crate::DAMPING,
            threshold: crate::DEFAULT_THRESHOLD,
            max_iterations: 10_000,
            threads: 4,
            partition: PartitionPolicy::VertexBalanced,
            perforation_factor: 1e-5,
            delta_threshold: 0.0,
            delta_auto: false,
            frontier_sched: FrontierSched::Bitmap,
            numa: crate::engine::topology::Placement::Off,
            work_amplify: 0,
            pcpm_batch: 1,
            pcpm_layout: PcpmLayout::Compressed,
            faults: FaultPlan::none(),
            dnf_timeout: None,
        }
    }
}

impl PrConfig {
    /// Check ranges; every entry point calls this before running.
    pub fn validate(&self) -> Result<()> {
        if !(0.0..1.0).contains(&self.damping) {
            bail!("damping must be in [0, 1)");
        }
        if self.threshold <= 0.0 {
            bail!("threshold must be positive");
        }
        if self.threads == 0 {
            bail!("need at least one thread");
        }
        if self.threads > 64 {
            // Wait-free global descriptor uses a 64-bit completion bitmask.
            bail!("at most 64 threads supported");
        }
        if !self.delta_threshold.is_finite() || self.delta_threshold < 0.0 {
            bail!("delta-threshold must be a finite non-negative number");
        }
        if self.pcpm_batch == 0 {
            bail!("pcpm-batch must be at least 1");
        }
        // The threads × pcpm_batch ≤ 1024 bin-grid bound is enforced where
        // the grid is actually allocated (`engine::pcpm::kernel`) — every
        // other variant ignores the knob, and rejecting it globally would
        // contradict the CLI's "ignored for {variant}" note.
        Ok(())
    }

    /// The effective frontier push cutoff: the explicit `delta_threshold`
    /// when set, else `threshold / 10`. Keeping the cutoff a decade under
    /// the convergence threshold bounds the un-propagated residual per
    /// vertex by `delta / (1 - d)` — far inside the accuracy the
    /// equivalence tests demand (L1 ≤ 1e-6 vs the barrier schedule).
    pub fn resolved_delta_threshold(&self) -> f64 {
        if self.delta_threshold > 0.0 {
            self.delta_threshold
        } else {
            self.threshold * 0.1
        }
    }
}

/// Outcome of a PageRank run.
#[derive(Debug, Clone)]
pub struct PrResult {
    /// Which algorithm produced this result.
    pub variant: Variant,
    /// Final rank vector (sums to roughly 1).
    pub ranks: Vec<f64>,
    /// Iterations until termination. For thread-level convergence this is
    /// the *maximum* over threads; per-thread counts are in
    /// `per_thread_iterations`.
    pub iterations: u64,
    /// Sweep count per worker thread.
    pub per_thread_iterations: Vec<u64>,
    /// Wall-clock time including kernel construction.
    pub elapsed: Duration,
    /// False when the iteration cap or the DNF watchdog fired.
    pub converged: bool,
    /// Total thread-seconds spent waiting at barriers (0 for non-blocking).
    pub barrier_wait_secs: f64,
    /// Total vertex updates computed across all threads — the work metric
    /// frontier/delta scheduling reduces. `0` for kernels that don't
    /// instrument their gather (see `RunMetrics::add_gathered`).
    pub vertex_updates: u64,
    /// Frontier-scheduler telemetry: how many times a partition switched
    /// between bitmap-scan and work-list discovery (`--frontier-sched
    /// hybrid`; includes each partition's initial seeding scan). `0` for
    /// non-frontier kernels and pure bitmap scheduling.
    pub frontier_switches: u64,
    /// Frontier-scheduler telemetry: peak work-list queue occupancy over
    /// all partitions. `0` when the work-list was never engaged.
    pub worklist_peak: u64,
    /// Was the run aborted by the watchdog (thread failure wedged it)?
    pub dnf: bool,
}

impl PrResult {
    /// The trivial result for an empty graph (every variant short-circuits
    /// through the engine before spawning workers).
    pub fn empty(variant: Variant, threads: usize) -> PrResult {
        PrResult {
            variant,
            ranks: Vec::new(),
            iterations: 0,
            per_thread_iterations: vec![0; threads],
            elapsed: Duration::ZERO,
            converged: true,
            barrier_wait_secs: 0.0,
            vertex_updates: 0,
            frontier_switches: 0,
            worklist_peak: 0,
            dnf: false,
        }
    }

    /// L1 distance to a reference rank vector (the paper's accuracy metric,
    /// Figs 5–6).
    pub fn l1_norm(&self, reference: &[f64]) -> f64 {
        convergence::l1_norm(&self.ranks, reference)
    }

    /// Indices of the top-k ranked vertices, descending. NaN ranks (possible
    /// in a non-converged No-Sync-Edge run) sort below every real number
    /// instead of panicking — the ordering is
    /// [`crate::serving::rank_descending`], shared with the snapshot
    /// serving layer.
    pub fn top_k(&self, k: usize) -> Vec<(u32, f64)> {
        let mut idx = crate::serving::rank_descending(&self.ranks);
        idx.truncate(k);
        idx.into_iter().map(|u| (u, self.ranks[u as usize])).collect()
    }
}

/// Burn configurable extra cycles without perturbing the value. The paper's
/// testbed has 56 hardware threads; on small CI hosts the gather loop is too
/// short for scheduling effects to be visible, so benches optionally amplify
/// per-edge work. `black_box` keeps the loop from being optimized away.
#[inline(always)]
pub(crate) fn amplify_work(k: u32) {
    for i in 0..k {
        std::hint::black_box(i);
    }
}

/// Run a CPU variant on `g` through the unified engine (kernel dispatch via
/// [`crate::engine::REGISTRY`]).
pub fn run(g: &Csr, variant: Variant, cfg: &PrConfig) -> Result<PrResult> {
    crate::engine::run(g, variant, cfg)
}

/// Run any variant, including `XlaBlock` (which executes the AOT-compiled
/// JAX/Pallas artifact through the PJRT engine).
pub fn run_with_engine(
    g: &Csr,
    variant: Variant,
    cfg: &PrConfig,
    engine: &crate::runtime::Engine,
) -> Result<PrResult> {
    match variant {
        Variant::XlaBlock => xla_block::run(g, cfg, engine),
        _ => run(g, variant, cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parse_roundtrip() {
        // Every engine mode (the paper's eleven plus PCPM) and the XLA
        // variant round-trip through their display names.
        for v in Variant::ALL_MODES {
            assert_eq!(Variant::parse(v.name()).unwrap(), v);
        }
        assert_eq!(Variant::parse(Variant::XlaBlock.name()).unwrap(), Variant::XlaBlock);
        assert_eq!(Variant::parse("nosync").unwrap(), Variant::NoSync);
        assert_eq!(Variant::parse("barrier_helper").unwrap(), Variant::WaitFree);
        assert_eq!(Variant::parse("pcpm").unwrap(), Variant::Pcpm);
        assert_eq!(Variant::parse("partition-centric").unwrap(), Variant::Pcpm);
        assert_eq!(Variant::parse("partition_centric").unwrap(), Variant::Pcpm);
        assert_eq!(Variant::parse("frontier").unwrap(), Variant::Frontier);
        assert_eq!(Variant::parse("delta").unwrap(), Variant::Frontier);
        assert_eq!(Variant::parse("frontier-pcpm").unwrap(), Variant::FrontierPcpm);
        assert_eq!(Variant::parse("frontier_pcpm").unwrap(), Variant::FrontierPcpm);
        assert_eq!(Variant::parse("xla").unwrap(), Variant::XlaBlock);
        assert!(Variant::parse("bogus").is_err());
    }

    #[test]
    fn classification_is_consistent() {
        for v in Variant::ALL_MODES {
            assert!(
                !(v.is_blocking() && v.is_non_blocking()),
                "{v} cannot be both"
            );
        }
        assert!(Variant::Barrier.is_blocking());
        assert!(Variant::Pcpm.is_blocking());
        assert!(Variant::NoSync.is_non_blocking());
        assert!(Variant::WaitFree.is_non_blocking());
        assert!(Variant::Frontier.is_non_blocking());
        assert!(Variant::FrontierPcpm.is_non_blocking());
        assert!(Variant::NoSyncOpt.is_approximate());
        assert!(!Variant::NoSync.is_approximate());
        assert!(!Variant::Pcpm.is_approximate());
        assert!(!Variant::Frontier.is_approximate());
    }

    #[test]
    fn config_validation() {
        assert!(PrConfig::default().validate().is_ok());
        assert!(PrConfig { damping: 1.0, ..Default::default() }.validate().is_err());
        assert!(PrConfig { threads: 0, ..Default::default() }.validate().is_err());
        assert!(PrConfig { threads: 65, ..Default::default() }.validate().is_err());
        assert!(PrConfig { threshold: 0.0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn pcpm_knobs_validate_and_parse() {
        assert_eq!(PrConfig::default().pcpm_batch, 1);
        assert_eq!(PrConfig::default().pcpm_layout, PcpmLayout::Compressed);
        assert!(PrConfig { pcpm_batch: 0, ..Default::default() }.validate().is_err());
        assert!(PrConfig { pcpm_batch: 8, ..Default::default() }.validate().is_ok());
        // the bin-grid bound is a pcpm-kernel concern, not a global one
        // (see engine::pcpm tests); validate() must accept this for the
        // variants that ignore the knob
        assert!(
            PrConfig { threads: 64, pcpm_batch: 17, ..Default::default() }
                .validate()
                .is_ok()
        );
        assert_eq!(PcpmLayout::parse("compressed").unwrap(), PcpmLayout::Compressed);
        assert_eq!(PcpmLayout::parse("slots").unwrap(), PcpmLayout::Slots);
        assert_eq!(PcpmLayout::parse("per-edge").unwrap(), PcpmLayout::Slots);
        assert!(PcpmLayout::parse("zip").is_err());
        assert_eq!(PcpmLayout::Compressed.to_string(), "compressed");
    }

    #[test]
    fn placement_and_sched_knobs_parse_and_default() {
        use crate::engine::topology::Placement;
        let cfg = PrConfig::default();
        assert_eq!(cfg.numa, Placement::Off);
        assert_eq!(cfg.frontier_sched, FrontierSched::Bitmap);
        assert!(!cfg.delta_auto);
        assert!(cfg.validate().is_ok());
        assert!(
            PrConfig { delta_auto: true, ..PrConfig::default() }.validate().is_ok(),
            "auto tuning needs no explicit cutoff"
        );
        assert_eq!(FrontierSched::parse("bitmap").unwrap(), FrontierSched::Bitmap);
        assert_eq!(FrontierSched::parse("worklist").unwrap(), FrontierSched::Worklist);
        assert_eq!(FrontierSched::parse("work-list").unwrap(), FrontierSched::Worklist);
        assert_eq!(FrontierSched::parse("hybrid").unwrap(), FrontierSched::Hybrid);
        assert!(FrontierSched::parse("magic").is_err());
        assert_eq!(FrontierSched::Hybrid.to_string(), "hybrid");
        assert_eq!(Placement::parse("off").unwrap(), Placement::Off);
        assert_eq!(Placement::parse("pin").unwrap(), Placement::Pin);
        assert_eq!(Placement::parse("interleave").unwrap(), Placement::Interleave);
        assert!(Placement::parse("sideways").is_err());
        assert_eq!(Placement::Interleave.to_string(), "interleave");
    }

    #[test]
    fn all_cpu_lists_eleven() {
        assert_eq!(Variant::ALL_CPU.len(), 11);
        assert_eq!(Variant::parallel_cpu().count(), 10);
        assert_eq!(Variant::ALL_MODES.len(), 14);
        assert_eq!(Variant::parallel_modes().count(), 13);
    }

    #[test]
    fn delta_threshold_validation_and_resolution() {
        let auto = PrConfig::default();
        assert!(auto.validate().is_ok());
        assert!((auto.resolved_delta_threshold() - auto.threshold * 0.1).abs() < 1e-30);
        let explicit = PrConfig { delta_threshold: 1e-4, ..PrConfig::default() };
        assert_eq!(explicit.resolved_delta_threshold(), 1e-4);
        assert!(PrConfig { delta_threshold: -1.0, ..PrConfig::default() }.validate().is_err());
        assert!(
            PrConfig { delta_threshold: f64::NAN, ..PrConfig::default() }.validate().is_err()
        );
    }

    #[test]
    fn top_k_is_nan_robust() {
        let r = PrResult {
            variant: Variant::NoSyncEdge,
            ranks: vec![0.3, f64::NAN, 0.5, 0.2],
            iterations: 1,
            per_thread_iterations: vec![1],
            elapsed: Duration::ZERO,
            converged: false,
            barrier_wait_secs: 0.0,
            vertex_updates: 0,
            frontier_switches: 0,
            worklist_peak: 0,
            dnf: false,
        };
        let top = r.top_k(3);
        assert_eq!(top[0].0, 2);
        assert_eq!(top[1].0, 0);
        assert_eq!(top[2].0, 3);
        // NaN sorts last, and asking for more than len never panics
        let all = r.top_k(10);
        assert_eq!(all.len(), 4);
        assert_eq!(all[3].0, 1);
        assert!(all[3].1.is_nan());
    }
}
