//! Sequential PageRank — the baseline every speedup and L1-norm in the
//! paper is measured against (§5.3: "speed-up is calculated using the ratio
//! of Sequential execution time vs. Parallel execution time").
//!
//! Classic two-array power iteration over the pull direction, Eq. 1:
//! `pr(u) = (1-d)/n + d · Σ_{(v,u) ∈ E} prev(v)/outdeg(v)`, terminating when
//! the max per-vertex delta drops below the threshold.

use crate::engine::{Kernel, SyncMode, WorkerCtx};
use crate::graph::{Csr, Partitions, VertexId};
use crate::pagerank::{PrConfig, PrResult, Variant};
use anyhow::Result;

/// The Sequential "kernel": [`SyncMode::Sequential`] hands the whole solve
/// back to [`solve`], keeping the oracle bit-stable while still dispatching
/// through the engine registry like every other variant.
pub struct SequentialKernel<'g> {
    g: &'g Csr,
    cfg: PrConfig,
}

/// Registry builder for [`Variant::Sequential`].
pub fn kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    _parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    Ok(Box::new(SequentialKernel { g, cfg: cfg.clone() }))
}

impl Kernel for SequentialKernel<'_> {
    fn sync_mode(&self) -> SyncMode {
        SyncMode::Sequential
    }

    fn gather(&self, _ctx: &WorkerCtx<'_>) -> f64 {
        0.0 // never scheduled: Sequential mode runs through solve()
    }

    fn ranks(&self) -> Vec<f64> {
        Vec::new() // solve() returns the ranks directly
    }

    fn solve(&self) -> Option<(Vec<f64>, u64, bool)> {
        Some(solve(self.g, &self.cfg))
    }
}

/// Run the sequential baseline. Thin wrapper over the engine dispatch —
/// the `PrResult` assembly lives in one place (`driver::run_sequential`).
pub fn run(g: &Csr, cfg: &PrConfig) -> PrResult {
    crate::pagerank::run(g, Variant::Sequential, cfg).expect("sequential dispatch")
}

/// Core solver, also used directly by tests and by the XLA-path comparison.
pub fn solve(g: &Csr, cfg: &PrConfig) -> (Vec<f64>, u64, bool) {
    let n = g.num_vertices();
    if n == 0 {
        return (Vec::new(), 0, true);
    }
    let d = cfg.damping;
    let base = (1.0 - d) / n as f64;
    let mut prev = vec![1.0 / n as f64; n];
    let mut pr = vec![0.0f64; n];
    // Precompute 1/outdeg to keep the inner loop division-free (perf: the
    // paper's Eq. 1 divides per edge; hoisting is numerics-identical here
    // because each vertex's reciprocal is a single rounding).
    let inv_out: Vec<f64> = (0..n as VertexId)
        .map(|v| {
            let od = g.out_degree(v);
            if od == 0 {
                0.0
            } else {
                1.0 / od as f64
            }
        })
        .collect();

    // Per-iteration contribution array: contrib[v] = prev[v] / outdeg(v).
    // Folding the two random-access streams (prev + inv_out) into one
    // halves the cache misses of the gather — the loop is memory-bound, so
    // this is the single biggest lever (see EXPERIMENTS.md §Perf). The
    // products are identical to computing them inside the gather, so the
    // numerics are bit-exact.
    let mut contrib = vec![0.0f64; n];
    let mut iterations = 0u64;
    let mut converged = false;
    while iterations < cfg.max_iterations {
        for v in 0..n {
            contrib[v] = prev[v] * inv_out[v];
        }
        let mut err: f64 = 0.0;
        for u in 0..n as VertexId {
            let mut sum = 0.0;
            for &v in g.in_neighbors(u) {
                // SAFETY: CSR validation guarantees every edge endpoint is
                // < n = contrib.len(); the bounds check was measurable in
                // this loop (§Perf).
                sum += unsafe { *contrib.get_unchecked(v as usize) };
                crate::pagerank::amplify_work(cfg.work_amplify);
            }
            let new = base + d * sum;
            err = err.max((new - prev[u as usize]).abs());
            pr[u as usize] = new;
        }
        std::mem::swap(&mut pr, &mut prev);
        iterations += 1;
        if err <= cfg.threshold {
            converged = true;
            break;
        }
    }
    // after the final swap, `prev` holds the newest ranks
    (prev, iterations, converged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic;
    use crate::pagerank::PrConfig;

    fn cfg() -> PrConfig {
        PrConfig { threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn cycle_is_uniform() {
        let g = synthetic::cycle(10);
        let r = run(&g, &cfg());
        assert!(r.converged);
        for &x in &r.ranks {
            assert!((x - 0.1).abs() < 1e-9, "cycle rank {x}");
        }
    }

    #[test]
    fn complete_graph_is_uniform() {
        let g = synthetic::complete(8);
        let r = run(&g, &cfg());
        assert!(r.converged);
        for &x in &r.ranks {
            assert!((x - 0.125).abs() < 1e-9);
        }
    }

    #[test]
    fn star_matches_closed_form() {
        // hub 0, leaves 1..n-1. Fixed point:
        //   h = (1-d)/n + d*(n-1)*l_in   where each leaf sends pr(leaf)/1
        //   l = (1-d)/n + d*h/(n-1)
        let n = 6usize;
        let d = crate::DAMPING;
        let g = synthetic::star(n);
        let r = run(&g, &cfg());
        assert!(r.converged);
        let nf = n as f64;
        let k = nf - 1.0;
        // closed form: h = (1-d)/n * (1 + d*k) / (1 - d^2)
        let h = (1.0 - d) / nf * (1.0 + d * k) / (1.0 - d * d);
        let l = (1.0 - d) / nf + d * h / k;
        assert!((r.ranks[0] - h).abs() < 1e-9, "hub {} vs {}", r.ranks[0], h);
        for leaf in 1..n {
            assert!((r.ranks[leaf] - l).abs() < 1e-9);
        }
    }

    #[test]
    fn chain_ranks_increase_downstream() {
        let g = synthetic::chain(5);
        let r = run(&g, &cfg());
        assert!(r.converged);
        // vertex 0 has no in-links: minimum rank; each later vertex
        // accumulates damped mass from its predecessor... but 4 is dangling
        // (keeps receiving from 3). Ranks must be strictly increasing except
        // where mass leaks. Check monotone 0..4.
        for i in 1..5 {
            assert!(
                r.ranks[i] > r.ranks[i - 1] - 1e-15,
                "chain not monotone at {i}: {:?}",
                r.ranks
            );
        }
    }

    #[test]
    fn rank_sum_without_dangling_is_one() {
        let g = synthetic::cycle(64);
        let r = run(&g, &cfg());
        let sum: f64 = r.ranks.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "sum {sum}");
    }

    #[test]
    fn rank_sum_with_dangling_leaks() {
        let g = synthetic::chain(10); // vertex 9 dangles
        let r = run(&g, &cfg());
        let sum: f64 = r.ranks.iter().sum();
        assert!(sum < 1.0, "dangling mass should leak, sum {sum}");
        assert!(sum > 0.0);
    }

    #[test]
    fn iteration_cap_reports_unconverged() {
        let g = synthetic::web_replica(500, 6, 3);
        let r = run(&g, &PrConfig { max_iterations: 2, ..cfg() });
        assert!(!r.converged);
        assert_eq!(r.iterations, 2);
    }

    #[test]
    fn empty_graph() {
        let g = crate::graph::GraphBuilder::new(0).build("nil");
        let r = run(&g, &cfg());
        assert!(r.converged);
        assert!(r.ranks.is_empty());
    }

    #[test]
    fn damping_zero_gives_uniform() {
        let g = synthetic::web_replica(300, 5, 1);
        let r = run(&g, &PrConfig { damping: 0.0, ..cfg() });
        let n = g.num_vertices() as f64;
        for &x in &r.ranks {
            assert!((x - 1.0 / n).abs() < 1e-12);
        }
    }
}
