//! Algorithm 5 — loop perforation (Sidiroglou-Douskos et al. [6], applied
//! to PageRank per Panyala et al. [7]): the `*-Opt` approximate variants,
//! as engine kernels.
//!
//! A vertex whose rank delta is non-zero but below
//! `threshold * perforation_factor` (the paper freezes at `1e-21` with a
//! `1e-16` threshold, i.e. `factor = 1e-5`) is marked converged at the
//! *node level* and skipped in all later iterations. Skipping trades
//! accuracy (non-zero L1-norm vs. sequential, Figs 5–6) for speed — frozen
//! vertices stop costing gather work entirely.
//!
//! Three variants, matching the paper's program list:
//! * [`barrier_opt_kernel`]  — Algorithm 1 + perforation (algorithm + node
//!   convergence; Blocking mode);
//! * [`nosync_opt_kernel`]   — Algorithm 3 + perforation (thread + node;
//!   NonBlocking mode);
//! * [`nosync_opt_identical_kernel`] — additionally computes only one vertex
//!   per identical-class (all three techniques composed).

use crate::engine::{inv_out_degrees, Kernel, SyncMode, WorkerCtx};
use crate::graph::identical::IdenticalClasses;
use crate::graph::{Csr, Partitions};
use crate::pagerank::identical::split_classes;
use crate::pagerank::{amplify_work, PrConfig};
use crate::sync::atomics::{atomic_vec, snapshot, AtomicF64};
use anyhow::Result;
use crate::sync::shim::atomic::{AtomicBool, Ordering};

/// Vertex-level perforated kernel (Barrier-Opt / No-Sync-Opt).
pub struct PerforatedKernel<'g> {
    g: &'g Csr,
    blocking: bool,
    parts: Partitions,
    inv_out: Vec<f64>,
    pr: Vec<AtomicF64>,
    /// Blocking mode only (two-array Jacobi schedule).
    prev: Vec<AtomicF64>,
    /// Node-level convergence marks (Alg 5's threshold_check array).
    frozen: Vec<AtomicBool>,
    base: f64,
    d: f64,
    cutoff: f64,
    work_amplify: u32,
}

fn build<'g>(g: &'g Csr, cfg: &PrConfig, parts: &Partitions, blocking: bool) -> PerforatedKernel<'g> {
    let n = g.num_vertices();
    PerforatedKernel {
        g,
        blocking,
        parts: parts.clone(),
        inv_out: inv_out_degrees(g),
        pr: atomic_vec(n, 1.0 / n as f64),
        prev: if blocking { atomic_vec(n, 1.0 / n as f64) } else { Vec::new() },
        frozen: (0..n).map(|_| AtomicBool::new(false)).collect(),
        base: (1.0 - cfg.damping) / n as f64,
        d: cfg.damping,
        cutoff: cfg.threshold * cfg.perforation_factor,
        work_amplify: cfg.work_amplify,
    }
}

/// Registry builder for Barrier-Opt (Algorithm 5 over Algorithm 1).
pub fn barrier_opt_kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    Ok(Box::new(build(g, cfg, parts, true)))
}

/// Registry builder for No-Sync-Opt (Algorithm 5 over Algorithm 3).
pub fn nosync_opt_kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    Ok(Box::new(build(g, cfg, parts, false)))
}

impl PerforatedKernel<'_> {
    #[inline]
    fn read(&self, u: usize) -> f64 {
        if self.blocking {
            self.prev[u].load()
        } else {
            self.pr[u].load()
        }
    }
}

impl Kernel for PerforatedKernel<'_> {
    fn sync_mode(&self) -> SyncMode {
        if self.blocking {
            SyncMode::Blocking { pre_scatter: false }
        } else {
            SyncMode::NonBlocking
        }
    }

    fn gather(&self, ctx: &WorkerCtx<'_>) -> f64 {
        let mut local_err: f64 = 0.0;
        let mut skipped = 0u64;
        for u in self.parts.range(ctx.tid) {
            let ui = u as usize;
            // Alg 5 line 6: skip nodes marked converged.
            // relaxed: freeze flags are monotone hints — a stale read only
            // delays the skip by one sweep, mirroring the paper's benign races
            if self.frozen[ui].load(Ordering::Relaxed) {
                skipped += 1;
                continue;
            }
            let previous = self.read(ui);
            let mut sum = 0.0;
            for &v in self.g.in_neighbors(u) {
                sum += self.read(v as usize) * self.inv_out[v as usize];
                amplify_work(self.work_amplify);
            }
            let new = self.base + self.d * sum;
            self.pr[ui].store(new);
            let delta = (new - previous).abs();
            local_err = local_err.max(delta);
            // Alg 5 line 11: freeze nodes with a tiny non-zero delta.
            if delta != 0.0 && delta < self.cutoff {
                // relaxed: monotone hint, see the load above
                self.frozen[ui].store(true, Ordering::Relaxed);
            }
        }
        ctx.metrics.add_skipped(ctx.tid, skipped);
        ctx.metrics
            .add_gathered(ctx.tid, self.parts.range(ctx.tid).len() as u64 - skipped);
        local_err
    }

    fn commit(&self, ctx: &WorkerCtx<'_>) {
        for u in self.parts.range(ctx.tid) {
            self.prev[u as usize].store(self.pr[u as usize].load());
        }
    }

    fn ranks(&self) -> Vec<f64> {
        snapshot(&self.pr)
    }
}

/// No-Sync-Opt-Identical: perforation + identical-classes + no barriers —
/// the most aggressive program in Figs 1–2. Freezing happens per *class*.
pub struct PerforatedIdenticalKernel<'g> {
    g: &'g Csr,
    classes: IdenticalClasses,
    chunks: Vec<std::ops::Range<usize>>,
    inv_out: Vec<f64>,
    pr: Vec<AtomicF64>,
    frozen: Vec<AtomicBool>,
    base: f64,
    d: f64,
    cutoff: f64,
    work_amplify: u32,
}

/// Registry builder for No-Sync-Opt-Identical.
pub fn nosync_opt_identical_kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    _parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    let n = g.num_vertices();
    let classes = IdenticalClasses::compute(g);
    let loads: Vec<usize> = classes
        .representatives
        .iter()
        .map(|&r| g.in_degree(r).max(1))
        .collect();
    let chunks = split_classes(&loads, cfg.threads);
    let frozen = (0..classes.num_classes()).map(|_| AtomicBool::new(false)).collect();
    Ok(Box::new(PerforatedIdenticalKernel {
        g,
        classes,
        chunks,
        inv_out: inv_out_degrees(g),
        pr: atomic_vec(n, 1.0 / n as f64),
        frozen,
        base: (1.0 - cfg.damping) / n as f64,
        d: cfg.damping,
        cutoff: cfg.threshold * cfg.perforation_factor,
        work_amplify: cfg.work_amplify,
    }))
}

impl Kernel for PerforatedIdenticalKernel<'_> {
    fn sync_mode(&self) -> SyncMode {
        SyncMode::NonBlocking
    }

    fn gather(&self, ctx: &WorkerCtx<'_>) -> f64 {
        let mut local_err: f64 = 0.0;
        let mut skipped = 0u64;
        let mut gathered = 0u64;
        for c in self.chunks[ctx.tid].clone() {
            // relaxed: monotone freeze hint (same contract as Alg 5 above)
            if self.frozen[c].load(Ordering::Relaxed) {
                skipped += self.classes.members[c].len() as u64;
                continue;
            }
            gathered += 1;
            let rep = self.classes.representatives[c];
            let previous = self.pr[rep as usize].load();
            let mut sum = 0.0;
            for &v in self.g.in_neighbors(rep) {
                sum += self.pr[v as usize].load() * self.inv_out[v as usize];
                amplify_work(self.work_amplify);
            }
            let new = self.base + self.d * sum;
            for &m in &self.classes.members[c] {
                self.pr[m as usize].store(new);
            }
            let delta = (new - previous).abs();
            local_err = local_err.max(delta);
            if delta != 0.0 && delta < self.cutoff {
                // relaxed: monotone hint, see the load above
                self.frozen[c].store(true, Ordering::Relaxed);
            }
        }
        ctx.metrics.add_skipped(ctx.tid, skipped);
        ctx.metrics.add_gathered(ctx.tid, gathered);
        local_err
    }

    fn ranks(&self) -> Vec<f64> {
        snapshot(&self.pr)
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::synthetic;
    use crate::pagerank::{self, seq, PrConfig, Variant};

    fn cfg(threads: usize) -> PrConfig {
        // threshold loose enough that perforation (cutoff = thr * 1e-5)
        // actually triggers before global convergence on f64.
        PrConfig { threads, threshold: 1e-8, ..PrConfig::default() }
    }

    #[test]
    fn barrier_opt_close_to_sequential() {
        let g = synthetic::web_replica(600, 6, 3);
        let c = cfg(3);
        let r = pagerank::run(&g, Variant::BarrierOpt, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        // approximate: small but typically non-zero L1
        assert!(r.l1_norm(&sr) < 1e-3, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn nosync_opt_close_to_sequential() {
        let g = synthetic::web_replica(600, 6, 4);
        let c = cfg(4);
        let r = pagerank::run(&g, Variant::NoSyncOpt, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-3, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn nosync_opt_identical_close_to_sequential() {
        let g = synthetic::web_replica(600, 6, 5);
        let c = cfg(4);
        let r = pagerank::run(&g, Variant::NoSyncOptIdentical, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-3, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn perforation_converges_on_fixtures() {
        let c = cfg(2);
        for g in [synthetic::cycle(40), synthetic::star(40), synthetic::chain(40)] {
            for v in [Variant::BarrierOpt, Variant::NoSyncOpt, Variant::NoSyncOptIdentical] {
                let r = pagerank::run(&g, v, &c).unwrap();
                assert!(r.converged, "{v} on {}", g.name);
                assert!(r.ranks.iter().all(|x| x.is_finite() && *x > 0.0));
            }
        }
    }

    #[test]
    fn tighter_factor_freezes_less_and_is_more_accurate() {
        let g = synthetic::web_replica(800, 6, 6);
        let loose = PrConfig { perforation_factor: 1e-1, ..cfg(2) };
        let tight = PrConfig { perforation_factor: 1e-7, ..cfg(2) };
        let (sr, _, _) = seq::solve(&g, &cfg(2));
        let rl = pagerank::run(&g, Variant::BarrierOpt, &loose).unwrap();
        let rt = pagerank::run(&g, Variant::BarrierOpt, &tight).unwrap();
        assert!(
            rt.l1_norm(&sr) <= rl.l1_norm(&sr) + 1e-12,
            "tight {} vs loose {}",
            rt.l1_norm(&sr),
            rl.l1_norm(&sr)
        );
    }
}
