//! Algorithm 5 — loop perforation (Sidiroglou-Douskos et al. [6], applied
//! to PageRank per Panyala et al. [7]): the `*-Opt` approximate variants.
//!
//! A vertex whose rank delta is non-zero but below
//! `threshold * perforation_factor` (the paper freezes at `1e-21` with a
//! `1e-16` threshold, i.e. `factor = 1e-5`) is marked converged at the
//! *node level* and skipped in all later iterations. Skipping trades
//! accuracy (non-zero L1-norm vs. sequential, Figs 5–6) for speed — frozen
//! vertices stop costing gather work entirely.
//!
//! Three variants, matching the paper's program list:
//! * [`run_barrier_opt`]  — Algorithm 1 + perforation (algorithm + node
//!   convergence);
//! * [`run_nosync_opt`]   — Algorithm 3 + perforation (thread + node);
//! * [`run_nosync_opt_identical`] — additionally computes only one vertex
//!   per identical-class (all three techniques composed).

use crate::coordinator::executor::run_workers;
use crate::coordinator::metrics::RunMetrics;
use crate::graph::identical::IdenticalClasses;
use crate::graph::{Csr, Partitions};
use crate::pagerank::barrier::{empty_result, inv_out_degrees};
use crate::pagerank::convergence::ErrorBoard;
use crate::pagerank::identical::split_classes;
use crate::pagerank::{amplify_work, PrConfig, PrResult, Variant};
use crate::sync::atomics::{atomic_vec, snapshot};
use crate::sync::barrier::SenseBarrier;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Barrier-Opt (Algorithm 5 over Algorithm 1).
pub fn run_barrier_opt(g: &Csr, cfg: &PrConfig, parts: &Partitions) -> PrResult {
    run_vertex_impl(g, cfg, parts, Variant::BarrierOpt)
}

/// No-Sync-Opt (Algorithm 5 over Algorithm 3).
pub fn run_nosync_opt(g: &Csr, cfg: &PrConfig, parts: &Partitions) -> PrResult {
    run_vertex_impl(g, cfg, parts, Variant::NoSyncOpt)
}

fn run_vertex_impl(g: &Csr, cfg: &PrConfig, parts: &Partitions, variant: Variant) -> PrResult {
    let n = g.num_vertices();
    let threads = cfg.threads;
    if n == 0 {
        return empty_result(variant, threads);
    }
    let blocking = variant == Variant::BarrierOpt;
    let d = cfg.damping;
    let base = (1.0 - d) / n as f64;
    let cutoff = cfg.threshold * cfg.perforation_factor;
    let inv_out = inv_out_degrees(g);

    let pr = atomic_vec(n, 1.0 / n as f64);
    let prev = if blocking { atomic_vec(n, 1.0 / n as f64) } else { Vec::new() };
    // node-level convergence marks (Alg 5's threshold_check array)
    let frozen: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();

    let board = ErrorBoard::new(threads);
    let barrier = SenseBarrier::new(threads);
    let metrics = RunMetrics::new(threads);
    let converged = AtomicBool::new(false);
    let capped = AtomicBool::new(false);

    let start = Instant::now();
    let outcome = run_workers(threads, cfg.dnf_timeout, &[&barrier], |tid, stop| {
        let mut waiter = barrier.waiter();
        let range = parts.range(tid);
        let mut iter = 0u64;
        // confirmation-sweep counter (non-blocking path only); see nosync.rs
        let mut calm = 0u32;
        loop {
            if stop.load(Ordering::Acquire) {
                return;
            }
            if cfg.faults.apply(tid, iter) {
                return;
            }
            let mut local_err: f64 = 0.0;
            let mut skipped = 0u64;
            for u in range.clone() {
                let ui = u as usize;
                // Alg 5 line 6: skip nodes marked converged.
                if frozen[ui].load(Ordering::Relaxed) {
                    skipped += 1;
                    continue;
                }
                let previous = if blocking { prev[ui].load() } else { pr[ui].load() };
                let mut sum = 0.0;
                for &v in g.in_neighbors(u) {
                    let r = if blocking { prev[v as usize].load() } else { pr[v as usize].load() };
                    sum += r * inv_out[v as usize];
                    amplify_work(cfg.work_amplify);
                }
                let new = base + d * sum;
                pr[ui].store(new);
                let delta = (new - previous).abs();
                local_err = local_err.max(delta);
                // Alg 5 line 11: freeze nodes with a tiny non-zero delta.
                if delta != 0.0 && delta < cutoff {
                    frozen[ui].store(true, Ordering::Relaxed);
                }
            }
            metrics.add_skipped(tid, skipped);
            board.publish(tid, local_err);
            iter += 1;
            metrics.bump_iteration(tid);
            if blocking {
                if waiter.wait().is_aborted() {
                    return;
                }
                let global_err = board.global_max();
                for u in range.clone() {
                    prev[u as usize].store(pr[u as usize].load());
                }
                if waiter.wait().is_aborted() {
                    return;
                }
                if global_err <= cfg.threshold {
                    converged.store(true, Ordering::Release);
                    return;
                }
            } else {
                let merged = board.global_max();
                if merged <= cfg.threshold {
                    calm += 1;
                    if calm >= 2 {
                        return;
                    }
                } else {
                    calm = 0;
                }
                std::thread::yield_now();
            }
            if iter >= cfg.max_iterations {
                capped.store(true, Ordering::Release);
                return;
            }
        }
    });

    let done = if blocking {
        converged.load(Ordering::Acquire)
    } else {
        !capped.load(Ordering::Acquire)
    };
    PrResult {
        variant,
        ranks: snapshot(&pr),
        iterations: metrics.max_iterations(),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed: start.elapsed(),
        converged: done && !outcome.dnf,
        barrier_wait_secs: barrier.total_wait_secs(),
        dnf: outcome.dnf,
    }
}

/// No-Sync-Opt-Identical: perforation + identical-classes + no barriers —
/// the most aggressive program in Figs 1–2.
pub fn run_nosync_opt_identical(g: &Csr, cfg: &PrConfig, _parts: &Partitions) -> PrResult {
    let n = g.num_vertices();
    let threads = cfg.threads;
    if n == 0 {
        return empty_result(Variant::NoSyncOptIdentical, threads);
    }
    let start = Instant::now();
    let classes = IdenticalClasses::compute(g);
    let d = cfg.damping;
    let base = (1.0 - d) / n as f64;
    let cutoff = cfg.threshold * cfg.perforation_factor;
    let inv_out = inv_out_degrees(g);

    let loads: Vec<usize> = classes
        .representatives
        .iter()
        .map(|&r| g.in_degree(r).max(1))
        .collect();
    let chunks = split_classes(&loads, threads);

    let pr = atomic_vec(n, 1.0 / n as f64);
    let frozen: Vec<AtomicBool> =
        (0..classes.num_classes()).map(|_| AtomicBool::new(false)).collect();

    let board = ErrorBoard::new(threads);
    let metrics = RunMetrics::new(threads);
    let capped = AtomicBool::new(false);

    let outcome = run_workers(threads, cfg.dnf_timeout, &[], |tid, stop| {
        let chunk = chunks[tid].clone();
        let mut iter = 0u64;
        let mut calm = 0u32; // confirmation sweeps; see nosync.rs
        loop {
            if stop.load(Ordering::Acquire) {
                return;
            }
            if cfg.faults.apply(tid, iter) {
                return;
            }
            let mut local_err: f64 = 0.0;
            let mut skipped = 0u64;
            for c in chunk.clone() {
                if frozen[c].load(Ordering::Relaxed) {
                    skipped += classes.members[c].len() as u64;
                    continue;
                }
                let rep = classes.representatives[c];
                let previous = pr[rep as usize].load();
                let mut sum = 0.0;
                for &v in g.in_neighbors(rep) {
                    sum += pr[v as usize].load() * inv_out[v as usize];
                    amplify_work(cfg.work_amplify);
                }
                let new = base + d * sum;
                for &m in &classes.members[c] {
                    pr[m as usize].store(new);
                }
                let delta = (new - previous).abs();
                local_err = local_err.max(delta);
                if delta != 0.0 && delta < cutoff {
                    frozen[c].store(true, Ordering::Relaxed);
                }
            }
            metrics.add_skipped(tid, skipped);
            board.publish(tid, local_err);
            iter += 1;
            metrics.bump_iteration(tid);
            let merged = board.global_max();
            if merged <= cfg.threshold {
                calm += 1;
                if calm >= 2 {
                    return;
                }
            } else {
                calm = 0;
            }
            if iter >= cfg.max_iterations {
                capped.store(true, Ordering::Release);
                return;
            }
            std::thread::yield_now();
        }
    });

    PrResult {
        variant: Variant::NoSyncOptIdentical,
        ranks: snapshot(&pr),
        iterations: metrics.max_iterations(),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed: start.elapsed(),
        converged: !capped.load(Ordering::Acquire) && !outcome.dnf,
        barrier_wait_secs: 0.0,
        dnf: outcome.dnf,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic;
    use crate::pagerank::{self, seq};

    fn cfg(threads: usize) -> PrConfig {
        // threshold loose enough that perforation (cutoff = thr * 1e-5)
        // actually triggers before global convergence on f64.
        PrConfig { threads, threshold: 1e-8, ..PrConfig::default() }
    }

    #[test]
    fn barrier_opt_close_to_sequential() {
        let g = synthetic::web_replica(600, 6, 3);
        let c = cfg(3);
        let r = pagerank::run(&g, Variant::BarrierOpt, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        // approximate: small but typically non-zero L1
        assert!(r.l1_norm(&sr) < 1e-3, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn nosync_opt_close_to_sequential() {
        let g = synthetic::web_replica(600, 6, 4);
        let c = cfg(4);
        let r = pagerank::run(&g, Variant::NoSyncOpt, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-3, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn nosync_opt_identical_close_to_sequential() {
        let g = synthetic::web_replica(600, 6, 5);
        let c = cfg(4);
        let r = pagerank::run(&g, Variant::NoSyncOptIdentical, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-3, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn perforation_converges_on_fixtures() {
        let c = cfg(2);
        for g in [synthetic::cycle(40), synthetic::star(40), synthetic::chain(40)] {
            for v in [Variant::BarrierOpt, Variant::NoSyncOpt, Variant::NoSyncOptIdentical] {
                let r = pagerank::run(&g, v, &c).unwrap();
                assert!(r.converged, "{v} on {}", g.name);
                assert!(r.ranks.iter().all(|x| x.is_finite() && *x > 0.0));
            }
        }
    }

    #[test]
    fn tighter_factor_freezes_less_and_is_more_accurate() {
        let g = synthetic::web_replica(800, 6, 6);
        let loose = PrConfig { perforation_factor: 1e-1, ..cfg(2) };
        let tight = PrConfig { perforation_factor: 1e-7, ..cfg(2) };
        let (sr, _, _) = seq::solve(&g, &cfg(2));
        let rl = pagerank::run(&g, Variant::BarrierOpt, &loose).unwrap();
        let rt = pagerank::run(&g, Variant::BarrierOpt, &tight).unwrap();
        assert!(
            rt.l1_norm(&sr) <= rl.l1_norm(&sr) + 1e-12,
            "tight {} vs loose {}",
            rt.l1_norm(&sr),
            rl.l1_norm(&sr)
        );
    }
}
