//! Algorithm 6 — the Wait-Free "Barrier-Helper" variant.
//!
//! Threads that finish their own partition **help** stalled peers instead of
//! waiting: every vertex of every partition is eventually computed by
//! *someone*, so a sleeping thread costs nothing (Fig 8) and a crashed
//! thread cannot prevent completion (Fig 9) — the properties the paper's
//! case studies demonstrate.
//!
//! ## Protocol (adapted from the paper's CAS objects; see
//! [`crate::sync::cas_cell`] for the 64-bit reconstruction)
//!
//! * Each vertex is a [`VersionedCell`] whose version *is* its iteration
//!   count (the paper's `PrCASObj`). Any thread may compute a vertex's next
//!   value; `try_advance(iter, value)` admits exactly one winner per
//!   iteration, so duplicated helper work is harmless.
//! * Each partition has a [`PackedProgress`] descriptor `(iter, offset)`
//!   (the paper's `ThreadCASObj`). Helpers **compute first, then CAS the
//!   cursor forward** — a stalled claimer can never strand a vertex.
//! * Per-iteration errors live in a preallocated `err_by_iter` array
//!   (`fetch_max`-merged, idempotent — the paper's `GlobalCASObj.err`
//!   without any reset race).
//! * The iteration of the *system* is the minimum over partition
//!   descriptors; termination is decided from the completed iteration's
//!   error and published through a `done` flag (the paper's
//!   `GlobalCASObj.check` completion set, reformulated so helpers can
//!   finish the bookkeeping of dead threads too).
//!
//! Like the paper's No-Sync (and unlike its Alg 6), ranks are updated in
//! place: all contenders for a vertex in iteration `i` read neighbours that
//! are at iteration `i-1` or `i`, the same relaxation Lemma 1 covers, and
//! the cell CAS keeps exactly one committed value per (vertex, iteration).

use crate::coordinator::executor::run_workers;
use crate::coordinator::metrics::RunMetrics;
use crate::graph::{Csr, Partitions, VertexId};
use crate::pagerank::barrier::{empty_result, inv_out_degrees};
use crate::pagerank::{amplify_work, PrConfig, PrResult, Variant};
use crate::sync::atomics::AtomicF64;
use crate::sync::cas_cell::{PackedProgress, VersionedCell};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

struct Shared<'g> {
    g: &'g Csr,
    inv_out: Vec<f64>,
    cells: Vec<VersionedCell>,
    progress: Vec<PackedProgress>,
    ranges: Vec<std::ops::Range<VertexId>>,
    err_by_iter: Vec<AtomicF64>,
    done: AtomicBool,
    converged: AtomicBool,
    /// Nanoseconds from run start to the `done` decision. Fig 8 measures
    /// *algorithmic* completion: a thread that is still napping after
    /// helpers finished its work must not count against the variant.
    completion_nanos: std::sync::atomic::AtomicU64,
    started: Instant,
    base: f64,
    d: f64,
    threshold: f64,
    max_iterations: u64,
    work_amplify: u32,
}

impl Shared<'_> {
    /// Compute-and-commit one vertex for iteration `iter` (0-based: the
    /// transition from version `iter` to `iter+1`). Safe to call from any
    /// thread, any number of times.
    fn process_vertex(&self, u: VertexId) {
        let cell = &self.cells[u as usize];
        let (iter, previous) = cell.read();
        let mut sum = 0.0;
        for &v in self.g.in_neighbors(u) {
            sum += self.cells[v as usize].read_value() * self.inv_out[v as usize];
            amplify_work(self.work_amplify);
        }
        let new = self.base + self.d * sum;
        // Publish the delta before committing the cell so a completed
        // iteration always has its full error on record.
        let delta = (new - previous).abs();
        self.err_by_iter[iter as usize].fetch_max(delta);
        cell.try_advance(iter, new); // losing means someone else committed
    }

    /// Drive partition `t` through iteration `iter` (helping-safe).
    /// Returns when the partition's descriptor has moved past `iter`.
    fn drive_partition(&self, t: usize, stop: &AtomicBool) {
        let range = &self.ranges[t];
        let len = range.len() as u32;
        loop {
            if self.done.load(Ordering::Acquire) || stop.load(Ordering::Acquire) {
                return;
            }
            let (iter, off) = self.progress[t].load();
            if u64::from(iter) >= self.max_iterations {
                return; // cap: also bounds the err_by_iter index space
            }
            if off >= len {
                // partition finished its current iteration; roll the
                // descriptor to the next one
                self.progress[t].try_advance((iter, off), (iter + 1, 0));
                return;
            }
            let u = range.start + off;
            // Compute first (idempotent), then claim the cursor step. If the
            // CAS fails another helper advanced it — retry from the fresh
            // descriptor.
            if self.cells[u as usize].iteration() <= iter as u64 {
                self.process_vertex(u);
            }
            self.progress[t].try_advance((iter, off), (iter, off + 1));
        }
    }

    /// System iteration = min over partition descriptors.
    fn min_iter(&self) -> u32 {
        (0..self.progress.len())
            .map(|t| self.progress[t].load().0)
            .min()
            .unwrap_or(0)
    }

    /// Check termination after iteration `completed` finished everywhere.
    fn try_finish(&self) {
        let min = self.min_iter();
        if min == 0 {
            return;
        }
        let completed = min - 1;
        let err = self.err_by_iter[completed as usize].load_acquire();
        if err <= self.threshold {
            self.converged.store(true, Ordering::Release);
            self.finish();
        } else if u64::from(min) >= self.max_iterations {
            self.finish();
        }
    }

    fn finish(&self) {
        if !self.done.swap(true, Ordering::AcqRel) {
            let nanos = self.started.elapsed().as_nanos() as u64;
            self.completion_nanos.store(nanos.max(1), Ordering::Release);
        }
    }
}

/// Run Algorithm 6.
pub fn run(g: &Csr, cfg: &PrConfig, parts: &Partitions) -> PrResult {
    let n = g.num_vertices();
    let threads = cfg.threads;
    if n == 0 {
        return empty_result(Variant::WaitFree, threads);
    }
    let start = Instant::now();
    // err_by_iter is preallocated (one slot per iteration, no reset races),
    // so the effective cap is clamped: 100k iterations is far beyond any
    // practical convergence and keeps the allocation under 1 MiB.
    let max_iterations = cfg.max_iterations.min(100_000);
    let shared = Shared {
        g,
        inv_out: inv_out_degrees(g),
        cells: (0..n).map(|_| VersionedCell::new(1.0 / n as f64)).collect(),
        progress: (0..threads).map(|_| PackedProgress::new(0, 0)).collect(),
        ranges: (0..threads).map(|t| parts.range(t)).collect(),
        err_by_iter: (0..=max_iterations as usize)
            .map(|_| AtomicF64::new(0.0))
            .collect(),
        done: AtomicBool::new(false),
        converged: AtomicBool::new(false),
        completion_nanos: std::sync::atomic::AtomicU64::new(0),
        started: start,
        base: (1.0 - cfg.damping) / n as f64,
        d: cfg.damping,
        threshold: cfg.threshold,
        max_iterations,
        work_amplify: cfg.work_amplify,
    };
    let metrics = RunMetrics::new(threads);
    let outcome = run_workers(threads, cfg.dnf_timeout, &[], |tid, stop| {
        let mut iter = 0u64;
        while !shared.done.load(Ordering::Acquire) && !stop.load(Ordering::Acquire) {
            if cfg.faults.apply(tid, iter) {
                return; // crash — helpers will absorb this partition
            }
            // 1. Own partition first (computePR(threadId, threadId, …)).
            shared.drive_partition(tid, stop);
            metrics.bump_iteration(tid);
            // 2. Help every partition still behind the frontier
            //    (computePR(thr, threadId, …) for notCompletePR(thr)).
            let my_iter = shared.progress[tid].load().0;
            for t in 0..threads {
                if t != tid && shared.progress[t].load().0 < my_iter {
                    shared.drive_partition(t, stop);
                }
            }
            // 3. Global bookkeeping: advance/terminate if the frontier moved
            //    (UpdateGlobalVariable for self and for lagging peers).
            shared.try_finish();
            iter = u64::from(shared.progress[tid].load().0);
        }
    });

    let ranks: Vec<f64> = shared.cells.iter().map(|c| c.read_value()).collect();
    // Algorithmic completion time when recorded; wall-clock join otherwise.
    let completion = shared.completion_nanos.load(Ordering::Acquire);
    let elapsed = if completion > 0 {
        std::time::Duration::from_nanos(completion)
    } else {
        start.elapsed()
    };
    PrResult {
        variant: Variant::WaitFree,
        ranks,
        iterations: u64::from(shared.min_iter()),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed,
        converged: shared.converged.load(Ordering::Acquire) && !outcome.dnf,
        barrier_wait_secs: 0.0,
        dnf: outcome.dnf,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::faults::FaultPlan;
    use crate::graph::synthetic;
    use crate::pagerank::{self, seq};
    use std::time::Duration;

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn matches_sequential_on_cycle() {
        let g = synthetic::cycle(36);
        let c = cfg(3);
        let r = pagerank::run(&g, Variant::WaitFree, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-8, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn matches_sequential_on_web_replica() {
        let g = synthetic::web_replica(700, 6, 47);
        let c = cfg(4);
        let r = pagerank::run(&g, Variant::WaitFree, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-7, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn single_thread_works() {
        let g = synthetic::star(20);
        let c = cfg(1);
        let r = pagerank::run(&g, Variant::WaitFree, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-8);
    }

    #[test]
    fn survives_thread_failure() {
        // The defining property (Fig 9): a crashed thread's partition is
        // completed by helpers and the run still converges.
        let g = synthetic::web_replica(400, 6, 53);
        let c = PrConfig {
            faults: FaultPlan::none().fail_at(0, 1),
            dnf_timeout: Some(Duration::from_secs(120)),
            ..cfg(4)
        };
        let r = pagerank::run(&g, Variant::WaitFree, &c).unwrap();
        assert!(!r.dnf, "wait-free must not wedge on failure");
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-7, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn survives_majority_failures() {
        let g = synthetic::cycle(60);
        let c = PrConfig {
            faults: FaultPlan::fail_first_k(3),
            dnf_timeout: Some(Duration::from_secs(120)),
            ..cfg(4)
        };
        let r = pagerank::run(&g, Variant::WaitFree, &c).unwrap();
        assert!(!r.dnf);
        assert!(r.converged);
        for &x in &r.ranks {
            assert!((x - 1.0 / 60.0).abs() < 1e-8);
        }
    }

    #[test]
    fn sleeping_thread_does_not_stall_completion() {
        // Fig 8 shape: helpers absorb the sleeper's partition, so the run
        // finishes in far less time than the sleep.
        let g = synthetic::web_replica(300, 5, 59);
        let sleep = Duration::from_secs(3);
        let c = PrConfig {
            faults: FaultPlan::none().sleep_at(0, 1, sleep),
            dnf_timeout: Some(Duration::from_secs(120)),
            ..cfg(4)
        };
        let r = pagerank::run(&g, Variant::WaitFree, &c).unwrap();
        assert!(r.converged);
        // Algorithmic completion (PrResult::elapsed) must beat the nap by a
        // wide margin: helpers absorbed the sleeper's partition.
        assert!(
            r.elapsed < sleep / 2,
            "wait-free stalled on sleeper: {:?}",
            r.elapsed
        );
    }

    #[test]
    fn every_vertex_reaches_the_same_iteration() {
        let g = synthetic::web_replica(300, 5, 61);
        let c = cfg(3);
        let r = pagerank::run(&g, Variant::WaitFree, &c).unwrap();
        assert!(r.converged);
        assert!(r.iterations > 0);
    }
}
