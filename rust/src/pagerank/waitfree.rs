//! Algorithm 6 — the Wait-Free "Barrier-Helper" variant, as a thin kernel
//! over the engine-owned helping protocol.
//!
//! The whole CAS-object machinery (versioned rank cells, per-partition
//! progress descriptors, preallocated per-iteration error merge, and the
//! helping/termination loop) lives in [`crate::engine::helping`]; this
//! module only builds the state and exposes it through the
//! [`Kernel::helping`] hook so the engine's Helping driver can schedule it.
//! See the `helping` module docs for the protocol and the fault model.

use crate::engine::helping::HelpingState;
use crate::engine::{Kernel, SyncMode, WorkerCtx};
use crate::graph::{Csr, Partitions};
use crate::pagerank::PrConfig;
use anyhow::Result;

/// Algorithm 6: wait-free CAS-helping kernel (state in [`HelpingState`]).
pub struct WaitFreeKernel<'g> {
    state: HelpingState<'g>,
}

/// Registry builder for [`Variant::WaitFree`](crate::pagerank::Variant).
pub fn kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    Ok(Box::new(WaitFreeKernel { state: HelpingState::new(g, cfg, parts) }))
}

impl Kernel for WaitFreeKernel<'_> {
    fn sync_mode(&self) -> SyncMode {
        SyncMode::Helping
    }

    fn gather(&self, _ctx: &WorkerCtx<'_>) -> f64 {
        0.0 // never scheduled: the Helping driver runs HelpingState directly
    }

    fn ranks(&self) -> Vec<f64> {
        self.state.ranks()
    }

    fn helping(&self) -> Option<&HelpingState<'_>> {
        Some(&self.state)
    }
}

#[cfg(test)]
mod tests {
    use crate::coordinator::faults::FaultPlan;
    use crate::graph::synthetic;
    use crate::pagerank::{self, seq, PrConfig, Variant};
    use std::time::Duration;

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    #[test]
    fn matches_sequential_on_cycle() {
        let g = synthetic::cycle(36);
        let c = cfg(3);
        let r = pagerank::run(&g, Variant::WaitFree, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-8, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn matches_sequential_on_web_replica() {
        let g = synthetic::web_replica(700, 6, 47);
        let c = cfg(4);
        let r = pagerank::run(&g, Variant::WaitFree, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-7, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn single_thread_works() {
        let g = synthetic::star(20);
        let c = cfg(1);
        let r = pagerank::run(&g, Variant::WaitFree, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-8);
    }

    #[test]
    fn survives_thread_failure() {
        // The defining property (Fig 9): a crashed thread's partition is
        // completed by helpers and the run still converges.
        let g = synthetic::web_replica(400, 6, 53);
        let c = PrConfig {
            faults: FaultPlan::none().fail_at(0, 1),
            dnf_timeout: Some(Duration::from_secs(120)),
            ..cfg(4)
        };
        let r = pagerank::run(&g, Variant::WaitFree, &c).unwrap();
        assert!(!r.dnf, "wait-free must not wedge on failure");
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.l1_norm(&sr) < 1e-7, "l1 {}", r.l1_norm(&sr));
    }

    #[test]
    fn survives_majority_failures() {
        let g = synthetic::cycle(60);
        let c = PrConfig {
            faults: FaultPlan::fail_first_k(3),
            dnf_timeout: Some(Duration::from_secs(120)),
            ..cfg(4)
        };
        let r = pagerank::run(&g, Variant::WaitFree, &c).unwrap();
        assert!(!r.dnf);
        assert!(r.converged);
        for &x in &r.ranks {
            assert!((x - 1.0 / 60.0).abs() < 1e-8);
        }
    }

    #[test]
    fn sleeping_thread_does_not_stall_completion() {
        // Fig 8 shape: helpers absorb the sleeper's partition, so the run
        // finishes in far less time than the sleep.
        let g = synthetic::web_replica(300, 5, 59);
        let sleep = Duration::from_secs(3);
        let c = PrConfig {
            faults: FaultPlan::none().sleep_at(0, 1, sleep),
            dnf_timeout: Some(Duration::from_secs(120)),
            ..cfg(4)
        };
        let r = pagerank::run(&g, Variant::WaitFree, &c).unwrap();
        assert!(r.converged);
        // Algorithmic completion (PrResult::elapsed) must beat the nap by a
        // wide margin: helpers absorbed the sleeper's partition.
        assert!(
            r.elapsed < sleep / 2,
            "wait-free stalled on sleeper: {:?}",
            r.elapsed
        );
    }

    #[test]
    fn every_vertex_reaches_the_same_iteration() {
        let g = synthetic::web_replica(300, 5, 61);
        let c = cfg(3);
        let r = pagerank::run(&g, Variant::WaitFree, &c).unwrap();
        assert!(r.converged);
        assert!(r.iterations > 0);
    }
}
