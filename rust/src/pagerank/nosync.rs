//! Algorithm 3 — No-Sync: the paper's core non-blocking contribution.
//!
//! Differences from Algorithm 1, exactly as §4.3 describes:
//!
//! 1. **No barriers.** Threads run their partitions at their own pace;
//!    a rank read may come from the current or a neighbouring iteration
//!    (the relaxation Lemma 1 proves convergent, and Lemma 2 proves
//!    fixed-point-identical to sequential).
//! 2. **No previous-rank array.** With iteration-level dependencies gone,
//!    updates are in place — halving rank-array memory traffic.
//! 3. **Thread-level convergence.** Each thread merges the freshest visible
//!    per-thread errors ([`ErrorBoard`]) and exits on its own; no global
//!    agreement step exists.
//!
//! Each rank cell has a single writer (its partition owner); concurrent
//! readers are fine ([`crate::sync::atomics::AtomicF64`] — relaxed loads,
//! never torn).

use crate::coordinator::executor::run_workers;
use crate::coordinator::metrics::RunMetrics;
use crate::graph::{Csr, Partitions};
use crate::pagerank::barrier::{empty_result, inv_out_degrees};
use crate::pagerank::convergence::ErrorBoard;
use crate::pagerank::{amplify_work, PrConfig, PrResult, Variant};
use crate::sync::atomics::{atomic_vec, snapshot};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Run Algorithm 3.
pub fn run(g: &Csr, cfg: &PrConfig, parts: &Partitions) -> PrResult {
    let n = g.num_vertices();
    let threads = cfg.threads;
    if n == 0 {
        return empty_result(Variant::NoSync, threads);
    }
    let d = cfg.damping;
    let base = (1.0 - d) / n as f64;
    let inv_out = inv_out_degrees(g);

    let pr = atomic_vec(n, 1.0 / n as f64);
    let board = ErrorBoard::new(threads);
    let metrics = RunMetrics::new(threads);
    let capped = AtomicBool::new(false);

    let start = Instant::now();
    let outcome = run_workers(threads, cfg.dnf_timeout, &[], |tid, stop| {
        let range = parts.range(tid);
        let mut iter = 0u64;
        // Consecutive iterations with every visible error ≤ threshold. The
        // paper's Alg 3 exits on the first such observation; on hosts with
        // fewer cores than threads a descheduled peer can hold a stale-calm
        // slot, so we demand a confirmation sweep (two consecutive calm
        // iterations) — the second sweep re-validates this partition against
        // any updates that landed in between. See DESIGN.md §Substitutions.
        let mut calm = 0u32;
        loop {
            if stop.load(Ordering::Acquire) {
                return;
            }
            if cfg.faults.apply(tid, iter) {
                return; // crash: error slot stays stale, peers keep spinning
            }
            let mut local_err: f64 = 0.0;
            let mut edges = 0u64;
            for u in range.clone() {
                let mut tmp = 0.0;
                let previous = pr[u as usize].load();
                for &v in g.in_neighbors(u) {
                    // SAFETY: CSR validation bounds every endpoint by n
                    // (= pr.len() = inv_out.len()); the checks cost ~10%
                    // in this memory-bound gather (§Perf).
                    tmp += unsafe {
                        pr.get_unchecked(v as usize).load()
                            * inv_out.get_unchecked(v as usize)
                    };
                    amplify_work(cfg.work_amplify);
                }
                edges += g.in_degree(u) as u64;
                let new = base + d * tmp;
                pr[u as usize].store(new);
                local_err = local_err.max((new - previous).abs());
            }
            metrics.add_edges(tid, edges);
            iter += 1;
            metrics.bump_iteration(tid);
            board.publish(tid, local_err);
            // Thread-level convergence: merge own error with the freshest
            // visible values from every peer (Alg 3 lines 16-19). Peers may
            // still be mid-iteration — that partial view is the point.
            let merged = board.global_max();
            if merged <= cfg.threshold {
                calm += 1;
                if calm >= 2 {
                    return;
                }
            } else {
                calm = 0;
            }
            if iter >= cfg.max_iterations {
                capped.store(true, Ordering::Release);
                return;
            }
            // Cooperative fairness: on oversubscribed hosts a spinning
            // thread can starve its peers for whole timeslices, inflating
            // staleness far beyond what the paper's 56 hardware threads
            // ever see. One yield per sweep keeps sweeps interleaved.
            std::thread::yield_now();
        }
    });

    PrResult {
        variant: Variant::NoSync,
        ranks: snapshot(&pr),
        iterations: metrics.max_iterations(),
        per_thread_iterations: metrics.iterations_per_thread(),
        elapsed: start.elapsed(),
        converged: !capped.load(Ordering::Acquire) && !outcome.dnf,
        barrier_wait_secs: 0.0,
        dnf: outcome.dnf,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic;
    use crate::pagerank::{self, convergence, seq};

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    /// Lemma 2 experimentally: the async fixed point matches sequential to
    /// within the threshold regime (paper: L1 ≤ threshold/10 at 1e-16; we
    /// verify L1 well under 10·threshold·n slack and usually ~0).
    #[test]
    fn lemma2_fixed_point_matches_sequential() {
        let g = synthetic::web_replica(900, 6, 41);
        let c = cfg(4);
        let r = pagerank::run(&g, Variant::NoSync, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        let l1 = r.l1_norm(&sr);
        assert!(l1 < 1e-7, "async fixed point drifted: L1 {l1}");
    }

    #[test]
    fn single_thread_matches_sequential_exactly() {
        // With one thread the relaxation disappears (Gauss–Seidel order):
        // values still converge to the same fixed point.
        let g = synthetic::star(25);
        let c = cfg(1);
        let r = pagerank::run(&g, Variant::NoSync, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(convergence::linf_norm(&r.ranks, &sr) < 1e-10);
    }

    #[test]
    fn converges_on_all_fixture_families() {
        let c = cfg(3);
        for g in [
            synthetic::cycle(60),
            synthetic::chain(60),
            synthetic::star(60),
            synthetic::complete(20),
            synthetic::road_replica(400, 3),
        ] {
            let r = pagerank::run(&g, Variant::NoSync, &c).unwrap();
            assert!(r.converged, "{} did not converge", g.name);
            let (sr, _, _) = seq::solve(&g, &c);
            assert!(r.l1_norm(&sr) < 1e-7, "{} l1 {}", g.name, r.l1_norm(&sr));
        }
    }

    /// The paper's Fig 7 observation: in-place async updates propagate rank
    /// mass faster, so No-Sync needs no MORE iterations than the barrier
    /// schedule (usually fewer).
    #[test]
    fn iterations_not_more_than_barrier() {
        let g = synthetic::web_replica(600, 6, 2);
        let c = cfg(4);
        let ns = pagerank::run(&g, Variant::NoSync, &c).unwrap();
        let ba = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        // +2 covers No-Sync's confirmation sweeps; the in-place update still
        // converges in (far) fewer "real" iterations.
        assert!(
            ns.iterations <= ba.iterations + 2,
            "No-Sync {} iters vs Barrier {}",
            ns.iterations,
            ba.iterations
        );
    }

    #[test]
    fn per_thread_iterations_may_differ() {
        let g = synthetic::web_replica(600, 8, 6);
        let r = pagerank::run(&g, Variant::NoSync, &cfg(4)).unwrap();
        assert_eq!(r.per_thread_iterations.len(), 4);
        assert!(r.per_thread_iterations.iter().all(|&i| i > 0));
    }

    #[test]
    fn iteration_cap_reports_unconverged() {
        let g = synthetic::web_replica(400, 6, 8);
        let c = PrConfig { max_iterations: 2, ..cfg(2) };
        let r = pagerank::run(&g, Variant::NoSync, &c).unwrap();
        assert!(!r.converged);
    }
}
