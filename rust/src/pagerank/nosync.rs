//! Algorithm 3 — No-Sync: the paper's core non-blocking contribution, as an
//! engine kernel.
//!
//! Differences from Algorithm 1, exactly as §4.3 describes:
//!
//! 1. **No barriers.** The engine's NonBlocking driver lets threads run
//!    their partitions at their own pace; a rank read may come from the
//!    current or a neighbouring iteration (the relaxation Lemma 1 proves
//!    convergent, and Lemma 2 proves fixed-point-identical to sequential).
//! 2. **No previous-rank array.** With iteration-level dependencies gone,
//!    updates are in place — halving rank-array memory traffic.
//! 3. **Thread-level convergence.** The driver merges the freshest visible
//!    per-thread errors ([`crate::pagerank::convergence::ErrorBoard`]) and
//!    each thread exits on its own; no global agreement step exists.
//!
//! Each rank cell has a single writer (its partition owner); concurrent
//! readers are fine ([`crate::sync::atomics::AtomicF64`] — relaxed loads,
//! never torn).

use crate::engine::{inv_out_degrees, Kernel, SyncMode, WorkerCtx};
use crate::graph::{Csr, Partitions};
use crate::pagerank::{amplify_work, PrConfig};
use crate::sync::atomics::{atomic_vec, snapshot, AtomicF64};
use anyhow::Result;

/// Algorithm 3: vertex-centric pull with no barriers.
pub struct NoSyncKernel<'g> {
    g: &'g Csr,
    parts: Partitions,
    inv_out: Vec<f64>,
    pr: Vec<AtomicF64>,
    base: f64,
    d: f64,
    work_amplify: u32,
}

/// Registry builder for [`Variant::NoSync`](crate::pagerank::Variant).
pub fn kernel<'g>(
    g: &'g Csr,
    cfg: &PrConfig,
    parts: &Partitions,
) -> Result<Box<dyn Kernel + 'g>> {
    let n = g.num_vertices();
    Ok(Box::new(NoSyncKernel {
        g,
        parts: parts.clone(),
        inv_out: inv_out_degrees(g),
        pr: atomic_vec(n, 1.0 / n as f64),
        base: (1.0 - cfg.damping) / n as f64,
        d: cfg.damping,
        work_amplify: cfg.work_amplify,
    }))
}

impl Kernel for NoSyncKernel<'_> {
    fn sync_mode(&self) -> SyncMode {
        SyncMode::NonBlocking
    }

    /// One in-place sweep over this partition (Alg 3 lines 5-15).
    fn gather(&self, ctx: &WorkerCtx<'_>) -> f64 {
        let mut local_err: f64 = 0.0;
        let mut edges = 0u64;
        for u in self.parts.range(ctx.tid) {
            let mut tmp = 0.0;
            let previous = self.pr[u as usize].load();
            for &v in self.g.in_neighbors(u) {
                // SAFETY: CSR validation bounds every endpoint by n
                // (= pr.len() = inv_out.len()); the checks cost ~10%
                // in this memory-bound gather (§Perf).
                tmp += unsafe {
                    self.pr.get_unchecked(v as usize).load()
                        * self.inv_out.get_unchecked(v as usize)
                };
                amplify_work(self.work_amplify);
            }
            edges += self.g.in_degree(u) as u64;
            let new = self.base + self.d * tmp;
            self.pr[u as usize].store(new);
            local_err = local_err.max((new - previous).abs());
        }
        ctx.metrics.add_edges(ctx.tid, edges);
        ctx.metrics.add_gathered(ctx.tid, self.parts.range(ctx.tid).len() as u64);
        local_err
    }

    fn ranks(&self) -> Vec<f64> {
        snapshot(&self.pr)
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::synthetic;
    use crate::pagerank::{self, convergence, seq, PrConfig, Variant};

    fn cfg(threads: usize) -> PrConfig {
        PrConfig { threads, threshold: 1e-12, ..PrConfig::default() }
    }

    /// Lemma 2 experimentally: the async fixed point matches sequential to
    /// within the threshold regime (paper: L1 ≤ threshold/10 at 1e-16; we
    /// verify L1 well under 10·threshold·n slack and usually ~0).
    #[test]
    fn lemma2_fixed_point_matches_sequential() {
        let g = synthetic::web_replica(900, 6, 41);
        let c = cfg(4);
        let r = pagerank::run(&g, Variant::NoSync, &c).unwrap();
        assert!(r.converged);
        let (sr, _, _) = seq::solve(&g, &c);
        let l1 = r.l1_norm(&sr);
        assert!(l1 < 1e-7, "async fixed point drifted: L1 {l1}");
    }

    #[test]
    fn single_thread_matches_sequential_exactly() {
        // With one thread the relaxation disappears (Gauss–Seidel order):
        // values still converge to the same fixed point.
        let g = synthetic::star(25);
        let c = cfg(1);
        let r = pagerank::run(&g, Variant::NoSync, &c).unwrap();
        let (sr, _, _) = seq::solve(&g, &c);
        assert!(r.converged);
        assert!(convergence::linf_norm(&r.ranks, &sr) < 1e-10);
    }

    #[test]
    fn converges_on_all_fixture_families() {
        let c = cfg(3);
        for g in [
            synthetic::cycle(60),
            synthetic::chain(60),
            synthetic::star(60),
            synthetic::complete(20),
            synthetic::road_replica(400, 3),
        ] {
            let r = pagerank::run(&g, Variant::NoSync, &c).unwrap();
            assert!(r.converged, "{} did not converge", g.name);
            let (sr, _, _) = seq::solve(&g, &c);
            assert!(r.l1_norm(&sr) < 1e-7, "{} l1 {}", g.name, r.l1_norm(&sr));
        }
    }

    /// The paper's Fig 7 observation: in-place async updates propagate rank
    /// mass faster, so No-Sync needs no MORE iterations than the barrier
    /// schedule (usually fewer).
    #[test]
    fn iterations_not_more_than_barrier() {
        let g = synthetic::web_replica(600, 6, 2);
        let c = cfg(4);
        let ns = pagerank::run(&g, Variant::NoSync, &c).unwrap();
        let ba = pagerank::run(&g, Variant::Barrier, &c).unwrap();
        // +2 covers No-Sync's confirmation sweeps; the in-place update still
        // converges in (far) fewer "real" iterations.
        assert!(
            ns.iterations <= ba.iterations + 2,
            "No-Sync {} iters vs Barrier {}",
            ns.iterations,
            ba.iterations
        );
    }

    #[test]
    fn per_thread_iterations_may_differ() {
        let g = synthetic::web_replica(600, 8, 6);
        let r = pagerank::run(&g, Variant::NoSync, &cfg(4)).unwrap();
        assert_eq!(r.per_thread_iterations.len(), 4);
        assert!(r.per_thread_iterations.iter().all(|&i| i > 0));
    }

    #[test]
    fn iteration_cap_reports_unconverged() {
        let g = synthetic::web_replica(400, 6, 8);
        let c = PrConfig { max_iterations: 2, ..cfg(2) };
        let r = pagerank::run(&g, Variant::NoSync, &c).unwrap();
        assert!(!r.converged);
    }
}
