//! Benchmark-trajectory recording and the CI regression gate.
//!
//! `pagerank-nb bench-ci` runs every registered engine variant — plus the
//! PCPM layout/batching ablation rows (`PCPM-slots`, `Frontier-PCPM-slots`,
//! `PCPM-batch4`), the frontier-scheduling rows (`Frontier-worklist`: the
//! claim-based work-list scheduler; `Frontier-auto-delta`: the
//! residual-driven push-cutoff tuner — both from
//! [`crate::engine::frontier`]), the incremental-reconvergence rows (`Frontier-incr`,
//! `Frontier-PCPM-incr`: warm-started convergence of a random mutation
//! batch, see [`crate::engine::incremental`]), and the out-of-core rows
//! (`OOC-mem-s4`, `OOC-mmap-s1`, `OOC-mmap-s4`: the shard coordinator of
//! [`crate::engine::ooc`] over in-memory vs mmap-backed storage, isolating
//! rotation overhead from storage cost; `OOC-par-k2`, `OOC-par-k4`: the
//! same mmap 4-shard schedule swept by 2/4 parallel claim-ring workers) —
//! on the scaled-down CI datasets, writes a
//! `BENCH_ci.json` report (per-variant wall time, normalized time,
//! iteration count, vertex updates), and —
//! given a committed baseline — fails when a variant regresses beyond the
//! allowed budget. Timing is normalized *within the run* against the
//! Sequential row of the same dataset (`rel = secs / seq_secs`), so the
//! gate compares schedules, not host generations: a slower CI machine moves
//! every row together and leaves `rel` unchanged.
//!
//! The JSON schema is documented in `docs/benchmarking.md`. The parser here
//! is a minimal recursive-descent JSON reader (the build image is offline —
//! no serde), tolerant of unknown keys so the schema can grow.

use crate::coordinator::host::HostInfo;
use crate::graph::{synthetic, Csr};
use crate::harness::bench::BenchRunner;
use crate::pagerank::{self, FrontierSched, PcpmLayout, PrConfig, PrResult, Variant};
use crate::util::report::{json_escape, json_f64};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::time::Duration;

/// One (dataset, variant) measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRow {
    /// CI replica name.
    pub dataset: String,
    /// Variant (or ablation-row) label.
    pub variant: String,
    /// Median wall-clock seconds over the sample runs.
    pub secs: f64,
    /// `secs / sequential secs` on the same dataset in the same run — the
    /// host-neutral number the gate compares.
    pub rel: f64,
    /// Iterations until termination (max over threads).
    pub iterations: u64,
    /// Total vertex gathers across threads (`0` = kernel not instrumented).
    pub vertex_updates: u64,
    /// Did the run converge?
    pub converged: bool,
    /// False excludes this row from the regression gate: a baseline row
    /// seeded offline (never measured on a bench host) sits in the file
    /// for coverage but must not fail real runs against invented numbers.
    /// Measured reports always record `true`; the JSON key is optional and
    /// defaults to `true` so existing baselines keep gating unchanged.
    pub gated: bool,
}

/// A full `BENCH_ci.json` document.
#[derive(Debug, Clone)]
pub struct BenchReport {
    /// Report schema version ([`SCHEMA_VERSION`]).
    pub schema: u64,
    /// Dataset divisor the replicas were built at.
    pub scale: usize,
    /// Worker thread count.
    pub threads: usize,
    /// Timed samples per measurement.
    pub samples: usize,
    /// Host description string.
    pub host: String,
    /// One row per `(dataset, variant)` measurement.
    pub rows: Vec<BenchRow>,
}

/// Current `BENCH_ci.json` schema version.
pub const SCHEMA_VERSION: u64 = 1;

/// Floor for the Sequential median `rel` normalizes against: below one
/// microsecond the "measurement" is timer noise, and dividing by it would
/// turn scheduler jitter into thousand-x rel swings.
pub const MIN_SEQ_SECS: f64 = 1e-6;

impl BenchReport {
    /// The row for `(dataset, variant)`, if measured.
    pub fn find(&self, dataset: &str, variant: &str) -> Option<&BenchRow> {
        self.rows.iter().find(|r| r.dataset == dataset && r.variant == variant)
    }

    /// Serialize to the `BENCH_ci.json` format.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"schema\": {},\n", self.schema));
        s.push_str(&format!("  \"scale\": {},\n", self.scale));
        s.push_str(&format!("  \"threads\": {},\n", self.threads));
        s.push_str(&format!("  \"samples\": {},\n", self.samples));
        s.push_str(&format!("  \"host\": {},\n", json_escape(&self.host)));
        s.push_str("  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"dataset\": {}, \"variant\": {}, \"secs\": {}, \"rel\": {}, \
                 \"iterations\": {}, \"vertex_updates\": {}, \"converged\": {}{}}}{}\n",
                json_escape(&r.dataset),
                json_escape(&r.variant),
                json_f64(r.secs),
                json_f64(r.rel),
                r.iterations,
                r.vertex_updates,
                r.converged,
                // `gated` defaults true on parse; only the exception is worth bytes
                if r.gated { "" } else { ", \"gated\": false" },
                if i + 1 < self.rows.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Parse a report written by [`BenchReport::to_json`].
    pub fn from_json(text: &str) -> Result<BenchReport> {
        let v = Json::parse(text)?;
        let obj = v.as_object().context("BENCH json root must be an object")?;
        let num =
            |k: &str, d: f64| obj.get(k).and_then(Json::as_f64).unwrap_or(d);
        let mut rows = Vec::new();
        // "rows" must be present (possibly empty): silently accepting a
        // missing/mistyped key would turn a hand-edit typo in the baseline
        // into a report that trivially gates nothing.
        let rows_v = obj.get("rows").context("BENCH json missing 'rows'")?;
        let Json::Array(raw) = rows_v else {
            bail!("BENCH json 'rows' must be an array");
        };
        for r in raw {
            let ro = r.as_object().context("rows[] entries must be objects")?;
            let s = |k: &str| -> Result<String> {
                ro.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .with_context(|| format!("row missing string field '{k}'"))
            };
            // numeric fields may be null (a DNF run has no finite time)
            let f = |k: &str| ro.get(k).and_then(Json::as_f64);
            rows.push(BenchRow {
                dataset: s("dataset")?,
                variant: s("variant")?,
                secs: f("secs").unwrap_or(f64::INFINITY),
                rel: f("rel").unwrap_or(f64::INFINITY),
                iterations: f("iterations").unwrap_or(0.0) as u64,
                vertex_updates: f("vertex_updates").unwrap_or(0.0) as u64,
                converged: ro
                    .get("converged")
                    .and_then(Json::as_bool)
                    .unwrap_or(false),
                gated: ro.get("gated").and_then(Json::as_bool).unwrap_or(true),
            });
        }
        Ok(BenchReport {
            schema: num("schema", 1.0) as u64,
            scale: num("scale", 0.0) as usize,
            threads: num("threads", 0.0) as usize,
            samples: num("samples", 0.0) as usize,
            host: obj
                .get("host")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            rows,
        })
    }
}

/// The scaled-down CI dataset pair: one skewed web-class replica (where the
/// frontier schedule shines) and one high-diameter road-class replica
/// (where it is stressed). Sizes follow Table 1 at `1/divisor` scale.
pub fn ci_datasets(divisor: usize, seed: u64) -> Vec<(&'static str, Csr)> {
    // floor the sizes so an absurd divisor still yields runnable graphs
    vec![
        ("webStanford", synthetic::web_replica((281_903 / divisor).max(8), 8, seed)),
        ("roaditalyosm", synthetic::road_replica((6_686_493 / divisor).max(16), seed + 8)),
    ]
}

/// Run every registered engine variant on the CI datasets and collect the
/// trajectory rows. `Sequential` is measured first per dataset and anchors
/// the normalized column.
pub fn run_ci_bench(
    divisor: usize,
    threads: usize,
    samples: usize,
    seed: u64,
) -> Result<BenchReport> {
    let runner = BenchRunner::new(samples, 1);
    let cfg = PrConfig {
        threads,
        max_iterations: 2_000,
        dnf_timeout: Some(Duration::from_secs(60)),
        ..PrConfig::default()
    };
    // Reject bad input (e.g. --threads 65) with a clean error here; the
    // per-run .expect()s below can then only fire on internal bugs.
    cfg.validate()?;
    let mut rows = Vec::new();
    for (name, g) in ci_datasets(divisor, seed) {
        let (seq_m, seq_probe): (_, PrResult) = runner.measure_with("seq", || {
            let r = pagerank::run(&g, Variant::Sequential, &cfg).expect("sequential run");
            (r.elapsed.as_secs_f64(), r)
        });
        // `rel` divides by this number. A zero / non-finite Sequential
        // median would make every rel inf/NaN and the gate vacuously pass
        // — that is a measurement failure, not a benchmark result, so it
        // is a hard error. A merely *tiny* median (micro-benchmark-sized
        // CI datasets) is clamped to a floor and flagged: the rows still
        // record, but the log says the normalization is noise-dominated.
        let raw_seq = seq_m.summary.median;
        if !raw_seq.is_finite() || raw_seq <= 0.0 {
            bail!(
                "bench-ci: Sequential on {name} measured {raw_seq} s — cannot \
                 normalize 'rel' and the regression gate would be vacuous; \
                 check the timer or enlarge the dataset (--scale)"
            );
        }
        let seq_secs = if raw_seq < MIN_SEQ_SECS {
            eprintln!(
                "warning: Sequential on {name} took only {raw_seq:.3e} s — \
                 'rel' is normalized against the {MIN_SEQ_SECS:.0e} s floor; \
                 timings at this scale are noise-dominated"
            );
            MIN_SEQ_SECS
        } else {
            raw_seq
        };
        // Samples stay finite even for a DNF run (the watchdog bounds its
        // wall time) — Summary's percentile math cannot handle infinities.
        // A DNF on ANY run (warmup included) poisons the median, so it
        // marks the whole row DNF (`secs` becomes the JSON `null` below)
        // instead of silently inflating `rel`.
        let measure = |v: Variant, vcfg: &PrConfig| -> (f64, PrResult) {
            let mut any_dnf = false;
            let (m, r) = runner.measure_with(v.name(), || {
                let r = pagerank::run(&g, v, vcfg).expect("variant run");
                any_dnf |= r.dnf;
                (r.elapsed.as_secs_f64(), r)
            });
            let secs = if any_dnf { f64::INFINITY } else { m.summary.median };
            (secs, r)
        };
        let mut record = |label: &str, secs: f64, probe: &PrResult| {
            rows.push(BenchRow {
                dataset: name.to_string(),
                variant: label.to_string(),
                secs,
                rel: secs / seq_secs,
                iterations: probe.iterations,
                vertex_updates: probe.vertex_updates,
                converged: probe.converged && secs.is_finite(),
                gated: true,
            });
        };
        for v in Variant::ALL_MODES {
            let (secs, probe) = if v == Variant::Sequential {
                // the row keeps the honest measurement; only `rel` divides
                // by the (possibly clamped) `seq_secs`
                (raw_seq, seq_probe.clone())
            } else {
                measure(v, &cfg)
            };
            record(v.name(), secs, &probe);
        }
        // Layout / batching ablation rows: the default rows above run the
        // compressed PCPM stream; these record the per-edge baseline and a
        // batched scatter so the trajectory tracks what the compression
        // and batching actually buy on the CI datasets.
        let extras = [
            (
                Variant::Pcpm,
                "PCPM-slots",
                PrConfig { pcpm_layout: PcpmLayout::Slots, ..cfg.clone() },
            ),
            (
                Variant::FrontierPcpm,
                "Frontier-PCPM-slots",
                PrConfig { pcpm_layout: PcpmLayout::Slots, ..cfg.clone() },
            ),
            (Variant::Pcpm, "PCPM-batch4", PrConfig { pcpm_batch: 4, ..cfg.clone() }),
            // frontier-scheduling ablations: the claim-based work-list
            // sweep and the residual-driven delta-threshold tuner
            (
                Variant::Frontier,
                "Frontier-worklist",
                PrConfig { frontier_sched: FrontierSched::Worklist, ..cfg.clone() },
            ),
            (
                Variant::Frontier,
                "Frontier-auto-delta",
                PrConfig { delta_auto: true, ..cfg.clone() },
            ),
        ];
        for (v, label, vcfg) in &extras {
            let (secs, probe) = measure(*v, vcfg);
            record(label, secs, &probe);
        }
        // Incremental ablation rows: mutate the graph with a small random
        // edge batch, then measure the frontier kernels reconverging the
        // delta from the already-converged ranks. `vertex_updates` here is
        // the incremental work metric the property suite holds strictly
        // below a cold recompute; `rel` tracks reconvergence wall time
        // against the same dataset's cold Sequential anchor.
        {
            use crate::graph::GraphDelta;
            let batch = (g.num_edges() / 200).clamp(2, 512);
            let delta = GraphDelta::random(&g, batch, batch / 2, seed ^ 0xD17A);
            let applied = g.apply_delta(&delta).expect("random delta applies");
            let warm = &seq_probe.ranks;
            let incr = [
                (Variant::Frontier, "Frontier-incr"),
                (Variant::FrontierPcpm, "Frontier-PCPM-incr"),
            ];
            for (v, label) in incr {
                let mut any_dnf = false;
                let (m, probe) = runner.measure_with(label, || {
                    let r = crate::engine::incremental::reconverge(
                        &applied.graph,
                        v,
                        &cfg,
                        warm,
                        &applied.touched,
                    )
                    .expect("incremental reconverge");
                    any_dnf |= r.dnf;
                    (r.elapsed.as_secs_f64(), r)
                });
                let secs = if any_dnf { f64::INFINITY } else { m.summary.median };
                record(label, secs, &probe);
            }
        }
        // Out-of-core ablation rows: the same graph swept through the
        // shard coordinator. `OOC-mem-s4` isolates the rotation overhead
        // (owned storage, 4 shards); `OOC-mmap-s1` isolates the mmap
        // storage cost (no sharding); `OOC-mmap-s4` is the full sequential
        // out-of-core path; `OOC-par-k2`/`OOC-par-k4` sweep the same
        // 4-shard mmap schedule with 2 and 4 claim-ring workers — the rows
        // that show parallel shard sweeps beating the sequential rotation
        // wall-clock. The v2 cache is written and mapped once outside the
        // timed closure — materializing it is a gen-step cost, not a
        // per-run one.
        {
            let dir = std::env::temp_dir().join("pagerank_nb_bench_ci");
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("creating {}", dir.display()))?;
            let spill = dir.join(format!("{name}-{}.bin", std::process::id()));
            crate::graph::io::save_binary(&g, &spill)?;
            let mapped = crate::graph::io::map_binary(&spill)?;
            let ooc_rows: [(&str, &Csr, usize, usize); 5] = [
                ("OOC-mem-s4", &g, 4, 1),
                ("OOC-mmap-s1", &mapped, 1, 1),
                ("OOC-mmap-s4", &mapped, 4, 1),
                ("OOC-par-k2", &mapped, 4, 2),
                ("OOC-par-k4", &mapped, 4, 4),
            ];
            for (label, graph, shards, workers) in ooc_rows {
                let mut any_dnf = false;
                let (m, probe) = runner.measure_with(label, || {
                    let r = crate::engine::ooc::run_sharded_workers(
                        graph, &cfg, shards, workers,
                    )
                    .expect("out-of-core run");
                    any_dnf |= r.dnf;
                    (r.elapsed.as_secs_f64(), r)
                });
                let secs = if any_dnf { f64::INFINITY } else { m.summary.median };
                record(label, secs, &probe);
            }
        }
    }
    Ok(BenchReport {
        schema: SCHEMA_VERSION,
        scale: divisor,
        threads,
        samples,
        host: HostInfo::detect().describe(),
        rows,
    })
}

/// Gate: compare `current` against `baseline` and return one message per
/// regression (empty = gate passes).
///
/// Rules, per (dataset, variant) row present in **both** reports with a
/// converged, gated baseline (`"gated": false` rows are offline-seeded
/// placeholders that have never been measured — they are skipped until a
/// `--seed-baseline` refresh replaces them with real numbers):
/// * normalized time may grow to `base.rel * (1 + max_regress) + 1.0`
///   (the absolute slack absorbs scheduler noise, which dominates in the
///   millisecond regime the scaled-down CI graphs run in);
/// * iterations may grow to `base.iterations * (1 + max_regress) + 8`
///   (non-blocking schedules jitter by a few confirmation sweeps);
/// * a variant that converged in the baseline must still converge
///   (`No-Sync-Edge` is exempt: §4.4 documents its instability);
/// * a non-finite `rel` on either side of a gated pair is itself a failure
///   — inf/NaN would otherwise satisfy every budget vacuously.
///
/// Rows only in one report (new variants, retired datasets) are not gated.
///
/// Reports recorded under a different schema, dataset scale, or thread
/// count are **incomparable** — rel and iteration counts shift with graph
/// size and parallelism — so no row is gated (see [`comparable`]; the CLI
/// warns when it skips for this reason).
pub fn compare(current: &BenchReport, baseline: &BenchReport, max_regress: f64) -> Vec<String> {
    let mut regressions = Vec::new();
    if !comparable(current, baseline) {
        return regressions;
    }
    for base in &baseline.rows {
        let Some(cur) = current.find(&base.dataset, &base.variant) else {
            continue;
        };
        if !base.gated {
            continue; // offline placeholder, never measured: nothing to hold
        }
        if !base.converged {
            continue; // baseline itself was unstable here: nothing to hold
        }
        if !cur.converged {
            // Exempt No-Sync-Edge entirely: §4.4 documents its instability,
            // and a capped/DNF run would also trip the rel/iteration
            // budgets below, so no check may apply to this row.
            if base.variant != Variant::NoSyncEdge.name() {
                regressions.push(format!(
                    "{}/{}: no longer converges (baseline did)",
                    base.dataset, base.variant
                ));
            }
            continue;
        }
        // Non-finite rel on either side makes every budget below vacuous
        // (inf > inf is false, inf * anything is inf) — surface it as a
        // hard failure instead of a silent pass.
        if !base.rel.is_finite() {
            regressions.push(format!(
                "{}/{}: baseline rel is not finite — the baseline is corrupt \
                 (a DNF row marked converged?); refresh it (docs/benchmarking.md)",
                base.dataset, base.variant
            ));
            continue;
        }
        if !cur.rel.is_finite() {
            regressions.push(format!(
                "{}/{}: normalized time is not finite (baseline {:.3}x) — \
                 the run produced no usable timing for a converged row",
                base.dataset, base.variant, base.rel
            ));
            continue;
        }
        let rel_budget = base.rel * (1.0 + max_regress) + 1.0;
        if cur.rel > rel_budget {
            regressions.push(format!(
                "{}/{}: normalized time {:.3}x vs sequential, budget {:.3}x (baseline {:.3}x)",
                base.dataset, base.variant, cur.rel, rel_budget, base.rel
            ));
        }
        let iter_budget =
            (base.iterations as f64 * (1.0 + max_regress)).round() as u64 + 8;
        if cur.iterations > iter_budget {
            regressions.push(format!(
                "{}/{}: {} iterations, budget {} (baseline {})",
                base.dataset, base.variant, cur.iterations, iter_budget, base.iterations
            ));
        }
    }
    regressions
}

/// Were the two reports produced under the same measurement conditions?
/// (An empty baseline is trivially comparable — there is nothing to gate.)
pub fn comparable(current: &BenchReport, baseline: &BenchReport) -> bool {
    baseline.rows.is_empty()
        || (baseline.schema == current.schema
            && baseline.scale == current.scale
            && baseline.threads == current.threads)
}

/// Minimal JSON value — just enough to read our own reports back.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Array(Vec<Json>),
    /// An object, keys sorted.
    Object(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing characters at byte {pos}");
        }
        Ok(v)
    }

    /// The object's map, if this is an object.
    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(m) => Some(m),
            _ => None,
        }
    }

    /// The number, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The string, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else {
        bail!("unexpected end of input");
    };
    match c {
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Object(map));
            }
            loop {
                skip_ws(b, pos);
                let Json::Str(key) = parse_value(b, pos)? else {
                    bail!("object key must be a string (byte {pos})");
                };
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    bail!("expected ':' at byte {pos}");
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                map.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(&b',') => *pos += 1,
                    Some(&b'}') => {
                        *pos += 1;
                        return Ok(Json::Object(map));
                    }
                    _ => bail!("expected ',' or '}}' at byte {pos}"),
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Array(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(&b',') => *pos += 1,
                    Some(&b']') => {
                        *pos += 1;
                        return Ok(Json::Array(items));
                    }
                    _ => bail!("expected ',' or ']' at byte {pos}"),
                }
            }
        }
        b'"' => {
            *pos += 1;
            let mut s = String::new();
            loop {
                let Some(&c) = b.get(*pos) else {
                    bail!("unterminated string");
                };
                *pos += 1;
                match c {
                    b'"' => return Ok(Json::Str(s)),
                    b'\\' => {
                        let Some(&e) = b.get(*pos) else {
                            bail!("unterminated escape");
                        };
                        *pos += 1;
                        match e {
                            b'"' => s.push('"'),
                            b'\\' => s.push('\\'),
                            b'/' => s.push('/'),
                            b'n' => s.push('\n'),
                            b't' => s.push('\t'),
                            b'r' => s.push('\r'),
                            b'b' => s.push('\u{8}'),
                            b'f' => s.push('\u{c}'),
                            b'u' => {
                                if *pos + 4 > b.len() {
                                    bail!("truncated \\u escape");
                                }
                                let hex = std::str::from_utf8(&b[*pos..*pos + 4])
                                    .ok()
                                    .and_then(|h| u32::from_str_radix(h, 16).ok())
                                    .context("bad \\u escape")?;
                                *pos += 4;
                                // Our writer never emits surrogate pairs
                                // (non-BMP chars go out as raw UTF-8);
                                // reject rather than silently corrupt.
                                if (0xD800..=0xDFFF).contains(&hex) {
                                    bail!("surrogate \\u escapes unsupported");
                                }
                                s.push(char::from_u32(hex).unwrap_or('\u{fffd}'));
                            }
                            other => bail!("unknown escape '\\{}'", other as char),
                        }
                    }
                    c => {
                        // Re-assemble multi-byte UTF-8 sequences.
                        if c < 0x80 {
                            s.push(c as char);
                        } else {
                            let start = *pos - 1;
                            let width = match c {
                                0xC0..=0xDF => 2,
                                0xE0..=0xEF => 3,
                                _ => 4,
                            };
                            if start + width > b.len() {
                                bail!("truncated UTF-8 sequence");
                            }
                            let chunk = std::str::from_utf8(&b[start..start + width])
                                .context("invalid UTF-8 in string")?;
                            s.push_str(chunk);
                            *pos = start + width;
                        }
                    }
                }
            }
        }
        b't' => expect_lit(b, pos, "true", Json::Bool(true)),
        b'f' => expect_lit(b, pos, "false", Json::Bool(false)),
        b'n' => expect_lit(b, pos, "null", Json::Null),
        _ => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let s = std::str::from_utf8(&b[start..*pos]).unwrap_or("");
            s.parse::<f64>()
                .map(Json::Num)
                .with_context(|| format!("bad number '{s}' at byte {start}"))
        }
    }
}

fn expect_lit(b: &[u8], pos: &mut usize, lit: &str, val: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(val)
    } else {
        bail!("expected '{lit}' at byte {pos}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_report() -> BenchReport {
        // Tiny graphs (1/20000 scale) keep this an actual end-to-end run of
        // every registered variant while staying inside the test budget;
        // the OnceLock shares the single run across every test that needs
        // a report instead of re-benching per test.
        static REPORT: std::sync::OnceLock<BenchReport> = std::sync::OnceLock::new();
        REPORT
            .get_or_init(|| run_ci_bench(20_000, 2, 1, 7).expect("ci bench run"))
            .clone()
    }

    #[test]
    fn report_covers_every_mode_on_every_dataset() {
        let r = tiny_report();
        // every engine mode plus the three layout/batching ablation rows,
        // the two frontier-scheduling rows, the two
        // incremental-reconvergence rows, and the five out-of-core rows
        // (three sequential, two parallel-worker)
        assert_eq!(r.rows.len(), 2 * (Variant::ALL_MODES.len() + 12));
        for v in Variant::ALL_MODES {
            for ds in ["webStanford", "roaditalyosm"] {
                let row = r.find(ds, v.name()).unwrap_or_else(|| panic!("{ds}/{v}"));
                assert!(row.rel >= 0.0);
            }
        }
        for label in [
            "PCPM-slots",
            "Frontier-PCPM-slots",
            "PCPM-batch4",
            "Frontier-worklist",
            "Frontier-auto-delta",
            "Frontier-incr",
            "Frontier-PCPM-incr",
            "OOC-mem-s4",
            "OOC-mmap-s1",
            "OOC-mmap-s4",
            "OOC-par-k2",
            "OOC-par-k4",
        ] {
            for ds in ["webStanford", "roaditalyosm"] {
                let row = r.find(ds, label).unwrap_or_else(|| panic!("{ds}/{label}"));
                assert!(row.rel >= 0.0, "{ds}/{label}");
            }
        }
        // incremental rows reconverge a non-empty seeded frontier, so they
        // do real (instrumented) work and settle — the strict
        // fewer-than-cold property is covered by the incremental suite
        for ds in ["webStanford", "roaditalyosm"] {
            for label in ["Frontier-incr", "Frontier-PCPM-incr"] {
                let row = r.find(ds, label).unwrap();
                assert!(row.converged, "{ds}/{label}");
                assert!(row.vertex_updates >= 1, "{ds}/{label}");
            }
        }
        // the layout only changes the value-stream width, never the
        // synchronous schedule: identical work telemetry per dataset
        for ds in ["webStanford", "roaditalyosm"] {
            let compressed = r.find(ds, "PCPM").unwrap();
            let slots = r.find(ds, "PCPM-slots").unwrap();
            assert_eq!(compressed.vertex_updates, slots.vertex_updates, "{ds}");
            assert_eq!(compressed.iterations, slots.iterations, "{ds}");
        }
        // frontier rows carry the work metric the schedule is about
        let f = r.find("roaditalyosm", "Frontier").unwrap();
        assert!(f.vertex_updates > 0);
        // the scheduling ablations settle like the bitmap default does
        for ds in ["webStanford", "roaditalyosm"] {
            for label in ["Frontier-worklist", "Frontier-auto-delta"] {
                let row = r.find(ds, label).unwrap();
                assert!(row.converged, "{ds}/{label}");
                assert!(row.vertex_updates > 0, "{ds}/{label}");
            }
        }
        // out-of-core rows: the sequential (K=1) coordinator is
        // deterministic, so the mmap and in-memory runs at the same shard
        // count do identical work; the parallel rows interleave shard
        // sweeps nondeterministically, so they are only held to settling
        // with real instrumented work
        for ds in ["webStanford", "roaditalyosm"] {
            for label in [
                "OOC-mem-s4",
                "OOC-mmap-s1",
                "OOC-mmap-s4",
                "OOC-par-k2",
                "OOC-par-k4",
            ] {
                let row = r.find(ds, label).unwrap();
                assert!(row.converged, "{ds}/{label}");
                assert!(row.vertex_updates > 0, "{ds}/{label}");
            }
            let mem = r.find(ds, "OOC-mem-s4").unwrap();
            let mmap = r.find(ds, "OOC-mmap-s4").unwrap();
            assert_eq!(mem.vertex_updates, mmap.vertex_updates, "{ds}");
            assert_eq!(mem.iterations, mmap.iterations, "{ds}");
        }
    }

    #[test]
    fn json_roundtrip_preserves_rows() {
        let r = tiny_report();
        let parsed = BenchReport::from_json(&r.to_json()).expect("parse back");
        assert_eq!(parsed.schema, SCHEMA_VERSION);
        assert_eq!(parsed.rows.len(), r.rows.len());
        for (a, b) in r.rows.iter().zip(&parsed.rows) {
            assert_eq!(a.dataset, b.dataset);
            assert_eq!(a.variant, b.variant);
            assert_eq!(a.iterations, b.iterations);
            assert_eq!(a.vertex_updates, b.vertex_updates);
            assert_eq!(a.converged, b.converged);
            if a.rel.is_finite() {
                assert!((a.rel - b.rel).abs() < 1e-9 * a.rel.abs().max(1.0));
            } else {
                assert!(!b.rel.is_finite(), "null rel must parse back non-finite");
            }
        }
    }

    #[test]
    fn self_comparison_passes_and_regressions_trip() {
        let r = tiny_report();
        assert!(compare(&r, &r, 0.25).is_empty(), "a run must not regress vs itself");

        // manufacture a 2x normalized-time regression and a convergence loss
        let mut bad = r.clone();
        if let Some(row) = bad.rows.iter_mut().find(|x| x.variant == "No-Sync") {
            row.rel = row.rel * 2.0 + 1.0;
        }
        if let Some(row) = bad.rows.iter_mut().find(|x| x.variant == "Frontier") {
            row.converged = false;
        }
        let msgs = compare(&bad, &r, 0.25);
        assert!(
            msgs.iter().any(|m| m.contains("No-Sync") && m.contains("normalized time")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("Frontier") && m.contains("no longer converges")),
            "{msgs:?}"
        );
    }

    /// Regression: a non-finite `rel` used to satisfy every budget
    /// vacuously (inf > inf is false). Both a corrupt baseline and a
    /// timing-less current row must now trip the gate loudly.
    #[test]
    fn non_finite_rel_trips_the_gate_instead_of_passing() {
        let r = tiny_report();
        let poison = |report: &mut BenchReport| {
            let row = report
                .rows
                .iter_mut()
                .find(|x| x.variant == "Barrier" && x.converged)
                .expect("a converged Barrier row");
            row.rel = f64::INFINITY;
        };
        let mut bad_base = r.clone();
        poison(&mut bad_base);
        let msgs = compare(&r, &bad_base, 0.25);
        assert!(
            msgs.iter().any(|m| m.contains("Barrier") && m.contains("baseline is corrupt")),
            "{msgs:?}"
        );
        let mut bad_cur = r.clone();
        poison(&mut bad_cur);
        let msgs = compare(&bad_cur, &r, 0.25);
        assert!(
            msgs.iter().any(|m| m.contains("Barrier") && m.contains("not finite")),
            "{msgs:?}"
        );
    }

    /// Every converged row of a real run must carry a finite, non-negative
    /// rel — the normalization hard-errors rather than emitting inf/NaN.
    #[test]
    fn converged_rows_always_have_finite_rel() {
        let r = tiny_report();
        for row in r.rows.iter().filter(|row| row.converged) {
            assert!(
                row.rel.is_finite() && row.rel >= 0.0,
                "{}/{}: rel {}",
                row.dataset,
                row.variant,
                row.rel
            );
            assert!(row.secs.is_finite(), "{}/{}", row.dataset, row.variant);
        }
    }

    /// An offline-seeded `"gated": false` baseline row must never fail the
    /// gate, however badly the live run diverges from its invented numbers,
    /// and the flag must survive a JSON round-trip (it is only serialized
    /// when false).
    #[test]
    fn ungated_baseline_rows_are_skipped() {
        let r = tiny_report();
        let mut base = r.clone();
        let mut marked = 0;
        for row in base.rows.iter_mut().filter(|x| x.variant == "Frontier-worklist") {
            // budgets no real run could hold — only `gated: false` spares them
            row.gated = false;
            row.rel = 0.0;
            row.iterations = 0;
            row.converged = true;
            marked += 1;
        }
        assert!(marked > 0, "tiny report must carry Frontier-worklist rows");
        let base = BenchReport::from_json(&base.to_json()).expect("round-trip");
        for row in base.rows.iter().filter(|x| x.variant == "Frontier-worklist") {
            assert!(!row.gated, "gated flag must survive the JSON round-trip");
        }
        assert!(
            base.rows.iter().filter(|x| x.variant != "Frontier-worklist").all(|x| x.gated),
            "omitted key must parse back as gated"
        );
        assert!(compare(&r, &base, 0.25).is_empty(), "ungated rows must not gate");
    }

    #[test]
    fn mismatched_scale_skips_gating() {
        let r = tiny_report();
        let mut other = r.clone();
        other.scale *= 2;
        if let Some(row) = other.rows.iter_mut().find(|x| x.variant == "No-Sync") {
            row.rel = row.rel * 10.0 + 5.0; // would trip the gate if compared
        }
        assert!(!comparable(&other, &r), "different scale must be incomparable");
        assert!(compare(&other, &r, 0.25).is_empty());
    }

    #[test]
    fn empty_baseline_gates_nothing() {
        let r = tiny_report();
        let empty = BenchReport {
            schema: SCHEMA_VERSION,
            scale: 0,
            threads: 0,
            samples: 0,
            host: String::new(),
            rows: Vec::new(),
        };
        assert!(compare(&r, &empty, 0.25).is_empty());
    }

    #[test]
    fn report_without_rows_key_is_rejected() {
        assert!(BenchReport::from_json(r#"{"schema": 1}"#).is_err());
        assert!(BenchReport::from_json(r#"{"schema": 1, "rows": {}}"#).is_err());
        assert!(BenchReport::from_json(r#"{"schema": 1, "rows": []}"#).is_ok());
    }

    #[test]
    fn parser_handles_escapes_and_rejects_garbage() {
        let v = Json::parse(r#"{"a": "x\"y\n", "b": [1, 2.5e-3, true, null]}"#).unwrap();
        let o = v.as_object().unwrap();
        assert_eq!(o.get("a").and_then(Json::as_str), Some("x\"y\n"));
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nope").is_err());
    }
}
