//! Benchmark harness: the in-tree mini-criterion timing runner and the
//! figure-by-figure experiment drivers that regenerate the paper's
//! evaluation section (Figs 1–9, Table 1).
//!
//! Every `rust/benches/*.rs` target is a thin wrapper over one
//! [`experiments`] driver, so `cargo bench` and
//! `pagerank-nb bench <exp-id>` produce the same tables.

pub mod bench;
pub mod experiments;
pub mod trajectory;

pub use bench::{BenchRunner, Measurement};
