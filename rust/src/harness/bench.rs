//! Mini-criterion: warmup + sampled wall-clock measurement with summary
//! statistics. The offline image carries no `criterion` crate; this runner
//! reproduces the part of its methodology the harness needs — N timed
//! samples after a warmup, median/MAD reporting, and environment overrides
//! for quick vs. thorough runs.
//!
//! Env knobs (read once per runner):
//! * `PAGERANK_NB_BENCH_SAMPLES` — samples per measurement (default 5)
//! * `PAGERANK_NB_BENCH_WARMUP`  — warmup runs (default 1)
//! * `PAGERANK_NB_SCALE`         — dataset divisor for replica datasets
//!   (default 200: Table-1 replicas at 1/200 scale fit CI hosts; read once
//!   per process and logged so CI output records the effective size)

use crate::util::stats::Summary;
use std::sync::OnceLock;
use std::time::Instant;

/// One named measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Measurement label.
    pub name: String,
    /// Timing summary over the samples.
    pub summary: Summary,
}

impl Measurement {
    /// Median seconds per run.
    pub fn secs(&self) -> f64 {
        self.summary.median
    }
}

/// Timing runner.
#[derive(Debug, Clone)]
pub struct BenchRunner {
    /// Timed samples per measurement.
    pub samples: usize,
    /// Untimed warmup runs.
    pub warmup: usize,
}

impl Default for BenchRunner {
    fn default() -> Self {
        Self {
            samples: env_usize("PAGERANK_NB_BENCH_SAMPLES", 5).max(1),
            warmup: env_usize("PAGERANK_NB_BENCH_WARMUP", 1),
        }
    }
}

impl BenchRunner {
    /// Runner with explicit sample/warmup counts (samples floors at 1).
    pub fn new(samples: usize, warmup: usize) -> Self {
        Self { samples: samples.max(1), warmup }
    }

    /// Time `f` (seconds per run) with warmup; `f` may return a value to
    /// keep the optimizer honest (it is black-boxed).
    pub fn measure<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        Measurement { name: name.to_string(), summary: Summary::from_samples(&samples) }
    }

    /// Measure a run that reports its own duration (e.g. [`crate::pagerank::PrResult::elapsed`]
    /// — algorithmic completion rather than wall clock, needed for Fig 8).
    pub fn measure_reported(
        &self,
        name: &str,
        mut f: impl FnMut() -> f64,
    ) -> Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            samples.push(f());
        }
        Measurement { name: name.to_string(), summary: Summary::from_samples(&samples) }
    }

    /// Like [`Self::measure_reported`], but each run also yields a value
    /// and the last *sampled* one is returned alongside the measurement —
    /// so non-timing columns (iterations, vertex updates, convergence)
    /// come from a run that was actually measured, with no extra probe run.
    pub fn measure_with<T>(
        &self,
        name: &str,
        mut f: impl FnMut() -> (f64, T),
    ) -> (Measurement, T) {
        let mut last: Option<T> = None;
        let m = self.measure_reported(name, || {
            let (secs, value) = f();
            last = Some(value);
            secs
        });
        (m, last.expect("measure_with: samples >= 1 always yields a value"))
    }
}

/// Dataset divisor for Table-1 replicas (`PAGERANK_NB_SCALE`, default 200).
///
/// Read from the environment exactly once per process (`OnceLock`) and
/// logged on first use, so CI output records which dataset size actually
/// ran — later env changes within the process are deliberately ignored.
pub fn dataset_divisor() -> usize {
    static DIVISOR: OnceLock<usize> = OnceLock::new();
    *DIVISOR.get_or_init(|| {
        let d = env_usize("PAGERANK_NB_SCALE", 200).max(1);
        eprintln!("dataset scale: 1/{d} of Table-1 sizes (PAGERANK_NB_SCALE={d})");
        d
    })
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_produces_sane_summary() {
        let r = BenchRunner::new(4, 1);
        let m = r.measure("sleep", || {
            std::thread::sleep(std::time::Duration::from_millis(3))
        });
        assert_eq!(m.summary.n, 4);
        assert!(m.secs() >= 0.003, "median {}", m.secs());
        assert!(m.secs() < 0.5);
    }

    #[test]
    fn measure_reported_uses_given_values() {
        let mut x = 0.0;
        let r = BenchRunner::new(3, 0);
        let m = r.measure_reported("fake", || {
            x += 1.0;
            x
        });
        assert_eq!(m.summary.n, 3);
        assert_eq!(m.summary.median, 2.0);
    }

    #[test]
    fn measure_with_returns_last_sampled_value() {
        let mut calls = 0u32;
        let r = BenchRunner::new(3, 1);
        let (m, last) = r.measure_with("counted", || {
            calls += 1;
            (calls as f64, calls)
        });
        assert_eq!(m.summary.n, 3);
        // 1 warmup + 3 samples; the returned value is from the last sample
        assert_eq!(last, 4);
    }

    #[test]
    fn divisor_defaults_positive_and_is_stable() {
        let first = dataset_divisor();
        assert!(first >= 1);
        // OnceLock: repeated calls return the cached value
        assert_eq!(dataset_divisor(), first);
    }
}
