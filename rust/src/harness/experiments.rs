//! Experiment drivers — one per table/figure in the paper's evaluation
//! (§5.3). Each driver returns [`Table`]s that mirror the rows/series the
//! paper plots; `cargo bench` targets and `pagerank-nb bench <id>` both call
//! through [`run_experiment`].
//!
//! Scaling: replicas are built at `1/divisor` of Table 1's sizes
//! (`PAGERANK_NB_SCALE`, default 200) and thread counts adapt to the host —
//! the *shapes* (who wins, who fails to converge, what survives faults) are
//! the reproduction target; EXPERIMENTS.md records both sides.

use crate::coordinator::faults::FaultPlan;
use crate::coordinator::host::HostInfo;
use crate::graph::synthetic::{self, table1};
use crate::graph::{Csr, PartitionPolicy};
use crate::harness::bench::{dataset_divisor, BenchRunner};
use crate::pagerank::{self, PcpmLayout, PrConfig, PrResult, Variant};
use crate::util::report::{Cell, Table};
use anyhow::{bail, Result};
use std::time::Duration;

/// Shared experiment context.
pub struct Ctx {
    /// Host description (embedded in report notes).
    pub host: HostInfo,
    /// Dataset divisor vs Table-1 sizes.
    pub divisor: usize,
    /// Worker thread count.
    pub threads: usize,
    /// Timing runner.
    pub runner: BenchRunner,
    /// Dataset seed.
    pub seed: u64,
}

impl Default for Ctx {
    fn default() -> Self {
        let host = HostInfo::detect();
        // The paper pins 56 threads; on hosts with very few cores we still
        // oversubscribe to ≥4 so barrier-vs-nosync scheduling effects exist
        // at all (a 1-thread "parallel" run has nothing to synchronize).
        let threads = host.default_threads().max(4);
        Self {
            host,
            divisor: dataset_divisor(),
            threads,
            runner: BenchRunner::default(),
            seed: 42,
        }
    }
}

impl Ctx {
    fn config(&self) -> PrConfig {
        PrConfig {
            threads: self.threads,
            max_iterations: 2_000,
            // Non-convergent variants (No-Sync-Edge on web graphs) and
            // crashed-thread scenarios must end in bounded time.
            dnf_timeout: Some(Duration::from_secs(60)),
            ..PrConfig::default()
        }
    }

    /// The "standard datasets" subset used for Fig 1 (one per Table-1
    /// class, sized for repeated timing runs).
    fn standard_datasets(&self) -> Vec<Csr> {
        let d = self.divisor;
        let s = self.seed;
        vec![
            synthetic::web_replica(281_903 / d, 8, s),          // webStanford
            synthetic::web_replica(875_713 / d, 6, s + 3),      // webGoogle
            synthetic::social_replica(75_879 / d.min(40), 7, s + 4), // socEpinions1
            synthetic::social_replica(77_360 / d.min(40), 12, s + 5), // Slashdot0811
            synthetic::road_replica(6_686_493 / d, s + 8),      // roaditalyosm
        ]
    }

    fn standard_names(&self) -> Vec<&'static str> {
        vec!["webStanford", "webGoogle", "socEpinions1", "Slashdot0811", "roaditalyosm"]
    }

    fn d_series(&self) -> Vec<Csr> {
        (1..=7)
            .map(|i| synthetic::d_series(i, self.divisor, self.seed))
            .collect()
    }

    fn web_stanford(&self) -> Csr {
        synthetic::web_replica(281_903 / self.divisor, 8, self.seed)
    }

    fn d70(&self) -> Csr {
        synthetic::d_series(7, self.divisor, self.seed)
    }
}

/// All experiment ids, in paper order.
pub const ALL_EXPERIMENTS: [&str; 12] = [
    "table1", "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9",
    "xla", "ablation",
];

/// Dispatch an experiment id.
pub fn run_experiment(id: &str, ctx: &Ctx) -> Result<Vec<Table>> {
    Ok(match id {
        "table1" => vec![table1_datasets(ctx)],
        "fig1" => vec![fig1_standard(ctx)],
        "fig2" => vec![fig2_synthetic(ctx)],
        "fig3" => vec![fig3_threads(ctx, true)],
        "fig4" => vec![fig3_threads(ctx, false)],
        "fig5" => vec![fig5_l1(ctx, true)],
        "fig6" => vec![fig5_l1(ctx, false)],
        "fig7" => vec![fig7_iterations(ctx)],
        "fig8" => vec![fig8_sleep(ctx)],
        "fig9" => vec![fig9_failures(ctx)],
        "xla" => vec![xla_runtime(ctx)?],
        "ablation" => ablation(ctx),
        other => bail!("unknown experiment '{other}' (try one of {ALL_EXPERIMENTS:?})"),
    })
}

// ---------------------------------------------------------------------------
// Table 1
// ---------------------------------------------------------------------------

/// Table 1: dataset inventory — paper sizes vs. generated replica sizes.
pub fn table1_datasets(ctx: &Ctx) -> Table {
    let mut t = Table::new(
        format!("Table 1 — datasets (replicas at 1/{} scale)", ctx.divisor),
        &[
            "dataset", "category", "paper |V|", "paper |E|", "replica |V|", "replica |E|",
            "replica MiB",
        ],
    );
    for spec in table1() {
        let g = (spec.build)(ctx.divisor, ctx.seed);
        t.push_row(vec![
            spec.name.into(),
            spec.category.to_string().into(),
            (spec.paper_vertices as i64).into(),
            (spec.paper_edges as i64).into(),
            g.num_vertices().into(),
            g.num_edges().into(),
            (g.memory_bytes() as f64 / (1024.0 * 1024.0)).into(),
        ]);
    }
    t.note(ctx.host.describe());
    t.note("replicas preserve each class's degree topology; real SNAP files load via `pagerank-nb run --graph <path>`");
    t
}

// ---------------------------------------------------------------------------
// Figs 1-2: speedup vs program
// ---------------------------------------------------------------------------

fn speedup_row(
    ctx: &Ctx,
    g: &Csr,
    cfg: &PrConfig,
    seq_secs: f64,
    variant: Variant,
) -> (Cell, bool) {
    let m = ctx.runner.measure_reported(variant.name(), || {
        let r = pagerank::run(g, variant, cfg).expect("variant run");
        if r.dnf {
            f64::INFINITY
        } else {
            r.elapsed.as_secs_f64()
        }
    });
    // converged status from one extra (untimed) run record
    let probe = pagerank::run(g, variant, cfg).expect("probe run");
    let secs = m.summary.median;
    if !secs.is_finite() {
        (Cell::Dnf, false)
    } else {
        ((seq_secs / secs).into(), probe.converged)
    }
}

fn speedup_table(ctx: &Ctx, title: &str, names: &[&str], graphs: &[Csr]) -> Table {
    let cfg = ctx.config();
    let mut headers: Vec<String> = vec!["dataset".into(), "seq (s)".into()];
    for v in Variant::parallel_modes() {
        headers.push(format!("{v} (x)"));
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(title, &hdr_refs);
    for (name, g) in names.iter().zip(graphs) {
        let seq = ctx.runner.measure_reported("seq", || {
            pagerank::run(g, Variant::Sequential, &cfg)
                .expect("seq")
                .elapsed
                .as_secs_f64()
        });
        let seq_secs = seq.summary.median;
        let mut row: Vec<Cell> = vec![(*name).into(), seq_secs.into()];
        let mut nonconverged: Vec<String> = Vec::new();
        for v in Variant::parallel_modes() {
            let (cell, converged) = speedup_row(ctx, g, &cfg, seq_secs, v);
            if !converged {
                nonconverged.push(v.name().to_string());
            }
            row.push(cell);
        }
        if !nonconverged.is_empty() {
            t.note(format!("{name}: did not converge: {}", nonconverged.join(", ")));
        }
        t.push_row(row);
    }
    t.note(format!("{} · {} threads", ctx.host.describe(), ctx.threads));
    t.note("paper shape: No-Sync family > Barrier family everywhere; No-Sync-Edge unreliable on web-like graphs");
    t.note("PCPM (ours): partition-centric scatter-gather on the unified engine — synchronous schedule, streaming bins");
    t
}

/// Fig 1: speedup vs programs on standard datasets, fixed threads.
pub fn fig1_standard(ctx: &Ctx) -> Table {
    let graphs = ctx.standard_datasets();
    speedup_table(ctx, "Fig 1 — Speed-Up vs Programs (standard datasets)", &ctx.standard_names(), &graphs)
}

/// Fig 2: speedup vs programs on the synthetic D-series.
pub fn fig2_synthetic(ctx: &Ctx) -> Table {
    let graphs = ctx.d_series();
    let names = ["D10", "D20", "D30", "D40", "D50", "D60", "D70"];
    speedup_table(ctx, "Fig 2 — Speed-Up vs Programs (synthetic datasets)", &names, &graphs)
}

// ---------------------------------------------------------------------------
// Figs 3-4: speedup vs thread count
// ---------------------------------------------------------------------------

/// Figs 3/4: thread sweep on webStanford (fig 3) or D70 (fig 4).
pub fn fig3_threads(ctx: &Ctx, web: bool) -> Table {
    let g = if web { ctx.web_stanford() } else { ctx.d70() };
    let (fig, name) = if web { ("Fig 3", "webStanford") } else { ("Fig 4", "D70") };
    let sweep = ctx.host.thread_sweep();
    let variants = [Variant::Barrier, Variant::BarrierEdge, Variant::NoSync, Variant::WaitFree];
    let mut headers: Vec<String> = vec!["threads".into()];
    headers.extend(variants.iter().map(|v| format!("{v} (x)")));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        format!("{fig} — Speed-Up with varying threads ({name})"),
        &hdr_refs,
    );
    let base_cfg = ctx.config();
    let seq_secs = ctx
        .runner
        .measure_reported("seq", || {
            pagerank::run(&g, Variant::Sequential, &base_cfg)
                .expect("seq")
                .elapsed
                .as_secs_f64()
        })
        .summary
        .median;
    for threads in sweep {
        let cfg = PrConfig { threads, ..base_cfg.clone() };
        let mut row: Vec<Cell> = vec![threads.into()];
        for v in variants {
            let m = ctx.runner.measure_reported(v.name(), || {
                pagerank::run(&g, v, &cfg).expect("run").elapsed.as_secs_f64()
            });
            row.push((seq_secs / m.summary.median).into());
        }
        t.push_row(row);
    }
    t.note(ctx.host.describe());
    t.note("paper shape: No-Sync keeps scaling with threads; Barrier flattens (wait time grows)");
    t
}

// ---------------------------------------------------------------------------
// Figs 5-6: speedup + L1-norm
// ---------------------------------------------------------------------------

/// Figs 5/6: per-program speedup and L1-norm vs sequential ranks.
pub fn fig5_l1(ctx: &Ctx, web: bool) -> Table {
    let g = if web { ctx.web_stanford() } else { ctx.d70() };
    let (fig, name) = if web { ("Fig 5", "webStanford") } else { ("Fig 6", "D70") };
    let cfg = ctx.config();
    let mut t = Table::new(
        format!("{fig} — Speed-Up and L1-norm ({name})"),
        &["program", "time (s)", "speedup (x)", "L1-norm", "converged"],
    );
    let seq_run = pagerank::run(&g, Variant::Sequential, &cfg).expect("seq");
    let seq_secs = ctx
        .runner
        .measure_reported("seq", || {
            pagerank::run(&g, Variant::Sequential, &cfg)
                .expect("seq")
                .elapsed
                .as_secs_f64()
        })
        .summary
        .median;
    t.push_row(vec![
        "Sequential".into(),
        seq_secs.into(),
        1.0.into(),
        0.0.into(),
        "yes".into(),
    ]);
    for v in Variant::parallel_modes() {
        let m = ctx.runner.measure_reported(v.name(), || {
            pagerank::run(&g, v, &cfg).expect("run").elapsed.as_secs_f64()
        });
        let probe = pagerank::run(&g, v, &cfg).expect("probe");
        let secs = m.summary.median;
        t.push_row(vec![
            v.name().into(),
            secs.into(),
            (seq_secs / secs).into(),
            probe.l1_norm(&seq_run.ranks).into(),
            if probe.converged { "yes" } else { "no" }.into(),
        ]);
    }
    t.note(format!("{} · {} threads", ctx.host.describe(), ctx.threads));
    t.note("paper shape: exact variants at L1 ≈ 0; *-Opt (perforated) trade L1 for speed");
    t
}

// ---------------------------------------------------------------------------
// Fig 7: iterations to convergence
// ---------------------------------------------------------------------------

/// Fig 7: iterations per program on the synthetic datasets.
pub fn fig7_iterations(ctx: &Ctx) -> Table {
    let graphs = ctx.d_series();
    let names = ["D10", "D20", "D30", "D40", "D50", "D60", "D70"];
    let cfg = ctx.config();
    let variants: Vec<Variant> = Variant::ALL_MODES.to_vec();
    let mut headers: Vec<String> = vec!["dataset".into()];
    headers.extend(variants.iter().map(|v| v.name().to_string()));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Fig 7 — Program vs # iterations (synthetic datasets)", &hdr_refs);
    for (name, g) in names.iter().zip(&graphs) {
        let mut row: Vec<Cell> = vec![(*name).into()];
        for &v in &variants {
            let r = pagerank::run(g, v, &cfg).expect("run");
            if r.converged {
                row.push((r.iterations as i64).into());
            } else {
                row.push(Cell::Str(format!("{}+", r.iterations)));
            }
        }
        t.push_row(row);
    }
    t.note("paper shape: No-Sync variants converge in fewer iterations than Barrier variants (thread-level convergence + in-place updates)");
    t
}

// ---------------------------------------------------------------------------
// Fig 8: sleeping threads
// ---------------------------------------------------------------------------

/// Fig 8: execution time as one thread sleeps longer. Wait-Free stays flat;
/// Barrier and No-Sync grow with the sleep.
pub fn fig8_sleep(ctx: &Ctx) -> Table {
    let g = ctx.web_stanford();
    let variants = [Variant::Barrier, Variant::NoSync, Variant::WaitFree];
    let sleeps_ms = [0u64, 100, 250, 500, 1000, 2000];
    let mut headers: Vec<String> = vec!["sleep (ms)".into()];
    headers.extend(variants.iter().map(|v| format!("{v} (s)")));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Fig 8 — Execution time with increasing sleep", &hdr_refs);
    let base = ctx.config();
    for ms in sleeps_ms {
        let mut row: Vec<Cell> = vec![(ms as i64).into()];
        for v in variants {
            let cfg = PrConfig {
                faults: if ms == 0 {
                    FaultPlan::none()
                } else {
                    FaultPlan::none().sleep_at(0, 1, Duration::from_millis(ms))
                },
                dnf_timeout: Some(Duration::from_secs(120)),
                // No-Sync's live threads sweep through the nap; don't let
                // the iteration cap truncate that (the Fig-8 behaviour).
                max_iterations: 5_000_000,
                ..base.clone()
            };
            let m = ctx.runner.measure_reported(v.name(), || {
                pagerank::run(&g, v, &cfg).expect("run").elapsed.as_secs_f64()
            });
            row.push(m.summary.median.into());
        }
        t.push_row(row);
    }
    t.note(format!("thread 0 sleeps at iteration 1 · {} threads", ctx.threads));
    t.note("paper shape: Wait-Free flat (helpers absorb the sleeper); Barrier and No-Sync grow ~linearly with the sleep");
    t
}

// ---------------------------------------------------------------------------
// Fig 9: failing threads
// ---------------------------------------------------------------------------

/// Fig 9: execution time vs number of failed threads. Only Wait-Free
/// completes; everything else is DNF.
pub fn fig9_failures(ctx: &Ctx) -> Table {
    let g = ctx.web_stanford();
    let variants = [Variant::Barrier, Variant::BarrierEdge, Variant::NoSync, Variant::WaitFree];
    let max_kill = (ctx.threads - 1).min(3);
    let mut headers: Vec<String> = vec!["failed threads".into()];
    headers.extend(variants.iter().map(|v| format!("{v} (s)")));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Fig 9 — Execution time with failed threads", &hdr_refs);
    let base = ctx.config();
    for k in 0..=max_kill {
        let mut row: Vec<Cell> = vec![k.into()];
        for v in variants {
            let cfg = PrConfig {
                faults: FaultPlan::fail_first_k(k),
                // Short watchdog: a wedged variant is the expected outcome,
                // not something to wait a minute for.
                dnf_timeout: Some(Duration::from_secs(10)),
                ..base.clone()
            };
            let r = pagerank::run(&g, v, &cfg).expect("run");
            if r.dnf || !r.converged {
                row.push(Cell::Dnf);
            } else {
                row.push(r.elapsed.as_secs_f64().into());
            }
        }
        t.push_row(row);
    }
    t.note(format!("threads fail at the end of iteration 0 · {} threads total", ctx.threads));
    t.note("paper shape: only Wait-Free finishes under failures; its time grows as fewer live threads do all the work");
    t
}

// ---------------------------------------------------------------------------
// XLA runtime (ours)
// ---------------------------------------------------------------------------

/// Three-layer integration: the AOT Pallas/JAX artifact vs the Rust
/// sequential solver — numerics agreement and per-step latency.
pub fn xla_runtime(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "XLA path — AOT Pallas/JAX artifact vs Rust sequential",
        &["graph", "n", "bucket", "xla iters", "xla time (s)", "seq time (s)", "L1(xla, seq)"],
    );
    let dir = crate::runtime::artifacts::default_dir();
    let specs = crate::runtime::ArtifactSpec::discover(&dir)?;
    if specs.is_empty() {
        t.note(format!(
            "NO ARTIFACTS in {} — run `make artifacts` first; experiment skipped",
            dir.display()
        ));
        return Ok(t);
    }
    let engine = crate::runtime::Engine::cpu()?;
    let cfg = PrConfig {
        threads: 1,
        threshold: 1e-7,
        ..PrConfig::default()
    };
    let graphs = vec![
        synthetic::cycle(64),
        synthetic::star(100),
        synthetic::web_replica(600, 6, ctx.seed),
        synthetic::road_replica(900, ctx.seed),
    ];
    for g in &graphs {
        let xla: PrResult = pagerank::run_with_engine(g, Variant::XlaBlock, &cfg, &engine)?;
        let seq = pagerank::run(g, Variant::Sequential, &cfg)?;
        let max_k = (0..g.num_vertices() as u32).map(|u| g.in_degree(u)).max().unwrap_or(0);
        let bucket = crate::runtime::ArtifactSpec::best_ell(&specs, g.num_vertices(), max_k.max(1))
            .map(|s| format!("n{}k{}", s.n, s.k))
            .unwrap_or_else(|| "-".into());
        t.push_row(vec![
            g.name.clone().into(),
            g.num_vertices().into(),
            bucket.into(),
            (xla.iterations as i64).into(),
            xla.elapsed.as_secs_f64().into(),
            seq.elapsed.as_secs_f64().into(),
            xla.l1_norm(&seq.ranks).into(),
        ]);
    }
    t.note("artifact: Pallas ELL gather kernel (interpret=True) lowered via JAX to HLO text, executed through PJRT");
    t.note("f32 artifact ⇒ L1 agreement bounded by ~1e-5·n; Python is not on this path");
    Ok(t)
}

// ---------------------------------------------------------------------------
// Ablations (ours)
// ---------------------------------------------------------------------------

/// Design ablations: partition policy, perforation factor, barrier wait share.
pub fn ablation(ctx: &Ctx) -> Vec<Table> {
    let g = ctx.web_stanford();
    let base = ctx.config();

    // (a) partition policy — one blocking, one non-blocking, plus the
    // engine-native modes (the "pcpm row": partition policy × mode)
    let mut a = Table::new(
        "Ablation A — partition policy (vertex- vs edge-balanced)",
        &["variant", "vertex-balanced (s)", "edge-balanced (s)", "edge-balanced gain"],
    );
    for v in [Variant::Barrier, Variant::NoSync, Variant::Pcpm, Variant::Frontier] {
        let tv = ctx
            .runner
            .measure_reported("vb", || {
                let cfg = PrConfig { partition: PartitionPolicy::VertexBalanced, ..base.clone() };
                pagerank::run(&g, v, &cfg).expect("run").elapsed.as_secs_f64()
            })
            .summary
            .median;
        let te = ctx
            .runner
            .measure_reported("eb", || {
                let cfg = PrConfig { partition: PartitionPolicy::EdgeBalanced, ..base.clone() };
                pagerank::run(&g, v, &cfg).expect("run").elapsed.as_secs_f64()
            })
            .summary
            .median;
        a.push_row(vec![v.name().into(), tv.into(), te.into(), (tv / te).into()]);
    }
    a.note("web replicas are skewed: edge-balanced partitions should help the barrier variant most (its critical path is the slowest partition)");

    // (b) perforation factor sweep
    let mut b = Table::new(
        "Ablation B — perforation factor (No-Sync-Opt)",
        &["factor", "time (s)", "L1-norm", "iterations"],
    );
    let seq = pagerank::run(&g, Variant::Sequential, &base).expect("seq");
    for factor in [1e-2, 1e-4, 1e-5, 1e-6, 1e-8] {
        let cfg = PrConfig { perforation_factor: factor, threshold: 1e-8, ..base.clone() };
        let m = ctx.runner.measure_reported("opt", || {
            pagerank::run(&g, Variant::NoSyncOpt, &cfg).expect("run").elapsed.as_secs_f64()
        });
        let probe = pagerank::run(&g, Variant::NoSyncOpt, &cfg).expect("probe");
        b.push_row(vec![
            Cell::Str(format!("{factor:.0e}")),
            m.summary.median.into(),
            probe.l1_norm(&seq.ranks).into(),
            (probe.iterations as i64).into(),
        ]);
    }
    b.note("larger factor ⇒ more vertices frozen earlier ⇒ faster + larger L1 (the paper fixes factor = 1e-5)");

    // (d) STIC-D preprocessing potential per dataset class
    let mut d = Table::new(
        "Ablation D — STIC-D preprocessing savings per dataset class",
        &["dataset", "vertices", "identical savings", "chain links", "SCCs", "largest SCC"],
    );
    let class_graphs = vec![
        ("webStanford", ctx.web_stanford()),
        ("socEpinions1", synthetic::social_replica(75_879 / ctx.divisor.min(40), 7, ctx.seed + 4)),
        ("roaditalyosm", synthetic::road_replica(6_686_493 / ctx.divisor, ctx.seed + 8)),
        ("D10", synthetic::d_series(1, ctx.divisor, ctx.seed)),
    ];
    for (name, g) in &class_graphs {
        let ident = crate::graph::identical::IdenticalClasses::compute(g);
        let chains = crate::graph::chains::ChainSet::compute(g);
        let scc = crate::graph::scc::SccDecomposition::compute(g);
        let largest = scc.members.iter().map(|m| m.len()).max().unwrap_or(0);
        d.push_row(vec![
            (*name).into(),
            g.num_vertices().into(),
            ident.savings_ratio().into(),
            chains.eliminated_vertices().into(),
            scc.num_components().into(),
            largest.into(),
        ]);
    }
    d.note("identical-node and chain techniques target different classes: web graphs have identical pages, road networks have chains; SCC counts bound the condensation-order technique");

    // (e) sweep scheduling and PCPM bin layout: full sweeps vs
    // frontier/delta gathering, and the compressed value stream vs the
    // per-edge baseline (plus source-partition batching)
    let mut e = Table::new(
        "Ablation E — sweep scheduling and PCPM bin layout",
        &["variant", "time (s)", "iterations", "vertex updates", "L1 vs seq"],
    );
    let seq_sched = pagerank::run(&g, Variant::Sequential, &base).expect("seq");
    let pcpm_cfg = |layout: PcpmLayout, batch: usize| PrConfig {
        pcpm_layout: layout,
        pcpm_batch: batch,
        ..base.clone()
    };
    let schedule_rows: Vec<(String, Variant, PrConfig)> = vec![
        ("No-Sync".into(), Variant::NoSync, base.clone()),
        ("Frontier".into(), Variant::Frontier, base.clone()),
        (
            "Frontier-PCPM (compressed)".into(),
            Variant::FrontierPcpm,
            pcpm_cfg(PcpmLayout::Compressed, 1),
        ),
        (
            "Frontier-PCPM (per-edge slots)".into(),
            Variant::FrontierPcpm,
            pcpm_cfg(PcpmLayout::Slots, 1),
        ),
        ("PCPM (compressed)".into(), Variant::Pcpm, pcpm_cfg(PcpmLayout::Compressed, 1)),
        ("PCPM (per-edge slots)".into(), Variant::Pcpm, pcpm_cfg(PcpmLayout::Slots, 1)),
        (
            "PCPM (compressed, batch 4)".into(),
            Variant::Pcpm,
            pcpm_cfg(PcpmLayout::Compressed, 4),
        ),
    ];
    for (label, v, cfg) in &schedule_rows {
        let (m, probe): (_, PrResult) = ctx.runner.measure_with(label, || {
            let r = pagerank::run(&g, *v, cfg).expect("run");
            (r.elapsed.as_secs_f64(), r)
        });
        e.push_row(vec![
            label.clone().into(),
            m.summary.median.into(),
            (probe.iterations as i64).into(),
            (probe.vertex_updates as i64).into(),
            probe.l1_norm(&seq_sched.ranks).into(),
        ]);
    }
    e.note("frontier gathers only vertices whose in-neighbourhood changed past the delta threshold (delayed-async, Blanco et al.); 'vertex updates' is the total gather count across threads — the work the schedule removes");
    e.note("compressed = one value slot per (vertex, destination partition) group, static u32 destination stream (Lakhotia et al.); per-edge slots = the pre-compression baseline; batch 4 = each worker scatters 4 finer source partitions before gathering");

    // (c) barrier wait share vs threads
    let mut c = Table::new(
        "Ablation C — time at barriers (Barrier variant)",
        &["threads", "run time (s)", "total barrier wait (thread-s)", "wait share"],
    );
    for threads in ctx.host.thread_sweep() {
        let cfg = PrConfig { threads, ..base.clone() };
        let r = pagerank::run(&g, Variant::Barrier, &cfg).expect("run");
        let run_secs = r.elapsed.as_secs_f64();
        let share = r.barrier_wait_secs / (run_secs * threads as f64).max(1e-12);
        c.push_row(vec![
            threads.into(),
            run_secs.into(),
            r.barrier_wait_secs.into(),
            share.into(),
        ]);
    }
    c.note("the wait share is the speedup ceiling the No-Sync variants remove");

    vec![a, b, c, d, e]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny ctx so driver tests stay fast.
    fn tiny_ctx() -> Ctx {
        Ctx {
            divisor: 2_000,
            threads: 2,
            runner: BenchRunner::new(1, 0),
            seed: 7,
            ..Ctx::default()
        }
    }

    #[test]
    fn table1_has_19_rows() {
        let t = table1_datasets(&tiny_ctx());
        assert_eq!(t.rows.len(), 19);
    }

    #[test]
    fn fig7_reports_each_dataset() {
        let ctx = Ctx { divisor: 20_000, ..tiny_ctx() };
        let t = fig7_iterations(&ctx);
        assert_eq!(t.rows.len(), 7);
        // every engine mode (paper's eleven + PCPM) gets a column
        assert_eq!(t.headers.len(), 1 + Variant::ALL_MODES.len());
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run_experiment("fig99", &tiny_ctx()).is_err());
    }

    #[test]
    fn fig9_marks_blocking_variants_dnf() {
        let ctx = Ctx { divisor: 20_000, ..tiny_ctx() };
        let t = fig9_failures(&ctx);
        // row for k=1: Barrier column must be DNF, Wait-Free must not.
        let row = &t.rows[1];
        assert_eq!(row[1], Cell::Dnf, "Barrier should DNF under failure");
        assert_ne!(row[4], Cell::Dnf, "Wait-Free must complete");
    }
}
