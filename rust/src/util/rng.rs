//! Deterministic pseudo-random number generation.
//!
//! The offline build image carries no `rand` crate, so the generators the
//! substrate needs (graph generation, property-test case generation, workload
//! shuffling) are implemented here: [`SplitMix64`] for seeding and
//! [`Xoshiro256pp`] (xoshiro256++, Blackman & Vigna) as the workhorse.
//! Both are tiny, fast, and — critically for reproducibility of every figure
//! in EXPERIMENTS.md — fully deterministic for a given seed across platforms.

/// SplitMix64: the recommended seeder for xoshiro-family generators.
///
/// Passes BigCrush when used directly; we use it to expand a single `u64`
/// seed into the 256-bit xoshiro state and for cheap one-off streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Start the stream at `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 — general purpose 64-bit generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 as recommended by the authors.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        // All-zero state is invalid; SplitMix64 cannot produce four zero
        // outputs in a row from any seed, but guard anyway.
        if s == [0; 4] {
            return Self { s: [1, 2, 3, 4] };
        }
        Self { s }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)` using the high 53 bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in `[0, bound)` without modulo bias (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm for
    /// small `k`, shuffle-prefix otherwise).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            return all;
        }
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.next_below((j + 1) as u64) as usize;
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public-domain
        // implementation (Vigna).
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn xoshiro_deterministic_per_seed() {
        let mut a = Xoshiro256pp::seed_from_u64(99);
        let mut b = Xoshiro256pp::seed_from_u64(99);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256pp::seed_from_u64(100);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_unbiased_bounds() {
        let mut r = Xoshiro256pp::seed_from_u64(3);
        for bound in [1u64, 2, 3, 7, 100, u64::MAX] {
            for _ in 0..100 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn next_below_rough_uniformity() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.next_below(10) as usize] += 1;
        }
        for &c in &counts {
            // expect ~10_000 each; allow 10% slack
            assert!((9_000..=11_000).contains(&c), "count {c} out of range");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(21);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle did nothing");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Xoshiro256pp::seed_from_u64(5);
        for (n, k) in [(10, 3), (100, 99), (1000, 10), (5, 5), (1, 1), (10, 0)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::BTreeSet<_> = s.iter().copied().collect();
            assert_eq!(set.len(), k, "duplicates in sample");
            assert!(s.iter().all(|&i| i < n));
        }
    }
}
