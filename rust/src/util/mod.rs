//! Small self-contained utilities: a deterministic PRNG family (the offline
//! build has no `rand` crate), report/table emitters, simple statistics and
//! human-readable formatting helpers.

pub mod fmt;
pub mod report;
pub mod rng;
pub mod stats;
