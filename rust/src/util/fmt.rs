//! Human-readable formatting helpers for the CLI and bench reports.

/// Format a duration in seconds adaptively: `1.234 s`, `12.3 ms`, `456 µs`.
pub fn duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.1} µs", secs * 1e6)
    } else {
        format!("{:.0} ns", secs * 1e9)
    }
}

/// Format a count with thousands separators: `12_345_678`.
pub fn count(n: u64) -> String {
    let digits = n.to_string();
    let mut out = String::with_capacity(digits.len() + digits.len() / 3);
    for (i, c) in digits.chars().enumerate() {
        if i > 0 && (digits.len() - i) % 3 == 0 {
            out.push('_');
        }
        out.push(c);
    }
    out
}

/// Format bytes adaptively: `1.5 GiB`, `23.4 MiB`, …
pub fn bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = b as f64;
    if b >= KIB * KIB * KIB {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    } else if b >= KIB * KIB {
        format!("{:.2} MiB", b / (KIB * KIB))
    } else if b >= KIB {
        format!("{:.1} KiB", b / KIB)
    } else {
        format!("{b:.0} B")
    }
}

/// Format a speedup factor: `12.3x`.
pub fn speedup(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.2}x")
    } else {
        "DNF".to_string()
    }
}

/// Format a small float in scientific notation when needed.
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1e-3 && x.abs() < 1e4 {
        format!("{x:.6}")
    } else {
        format!("{x:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn durations() {
        assert_eq!(duration(1.5), "1.500 s");
        assert_eq!(duration(0.0123), "12.300 ms");
        assert_eq!(duration(45.6e-6), "45.6 µs");
        assert_eq!(duration(320e-9), "320 ns");
    }

    #[test]
    fn counts() {
        assert_eq!(count(0), "0");
        assert_eq!(count(999), "999");
        assert_eq!(count(1000), "1_000");
        assert_eq!(count(68993773), "68_993_773");
    }

    #[test]
    fn bytes_fmt() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2048), "2.0 KiB");
        assert_eq!(bytes(30 * 1024 * 1024), "30.00 MiB");
    }

    #[test]
    fn speedup_fmt() {
        assert_eq!(speedup(10.0), "10.00x");
        assert_eq!(speedup(f64::INFINITY), "DNF");
    }

    #[test]
    fn sci_fmt() {
        assert_eq!(sci(0.0), "0");
        assert_eq!(sci(1e-12), "1.000e-12");
        assert!(sci(0.5).starts_with("0.5"));
    }
}
