//! Summary statistics over timing samples — the numerical core of the
//! in-tree mini-criterion ([`crate::harness::bench`]).

/// Summary of a sample set (times in seconds, or any positive metric).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Middle sample (mean of the middle two when even).
    pub median: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Median absolute deviation — robust spread estimate.
    pub mad: f64,
}

impl Summary {
    /// Compute a summary; `samples` need not be sorted. Panics on empty input.
    pub fn from_samples(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::from_samples on empty slice");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = percentile_sorted(&sorted, 50.0);
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = percentile_sorted(&devs, 50.0);
        Self {
            n,
            mean,
            median,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            mad,
        }
    }

    /// Relative standard deviation (coefficient of variation), in percent.
    pub fn rsd_pct(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            100.0 * self.stddev / self.mean
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice. `p` in `[0, 100]`.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean of positive values (used for cross-dataset speedups).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        // sample stddev of 1..5 is sqrt(2.5)
        assert!((s.stddev - 2.5f64.sqrt()).abs() < 1e-12);
        assert!((s.mad - 1.0).abs() < 1e-12);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::from_samples(&[7.5]);
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.median, 7.5);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.mad, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile_sorted(&v, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile_sorted(&v, 100.0) - 40.0).abs() < 1e-12);
        assert!((percentile_sorted(&v, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_matches_closed_form() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn rsd_of_constant_samples_is_zero() {
        let s = Summary::from_samples(&[3.0, 3.0, 3.0]);
        assert_eq!(s.rsd_pct(), 0.0);
    }
}
