//! Structured experiment reports with markdown / CSV / JSON emitters.
//!
//! The offline image carries no serde, so serialization is hand-rolled; the
//! emitters cover exactly what the harness needs: rectangular tables with a
//! title, column headers and string/number cells, mirroring the rows/series
//! of each figure and table in the paper.

use std::fmt::Write as _;
use std::path::Path;

/// A single cell value.
#[derive(Debug, Clone, PartialEq)]
pub enum Cell {
    /// Text.
    Str(String),
    /// Integer, rendered without decimals.
    Int(i64),
    /// Float, rendered fixed or scientific by magnitude.
    Float(f64),
    /// "did not finish" — used when a blocking variant hangs under failures.
    Dnf,
}

impl Cell {
    /// Human-readable rendering (used by the markdown and CSV emitters).
    pub fn render(&self) -> String {
        match self {
            Cell::Str(s) => s.clone(),
            Cell::Int(i) => i.to_string(),
            Cell::Float(x) => {
                if x.abs() >= 1e-3 && x.abs() < 1e7 || *x == 0.0 {
                    format!("{x:.4}")
                } else {
                    format!("{x:.4e}")
                }
            }
            Cell::Dnf => "DNF".to_string(),
        }
    }

    fn render_json(&self) -> String {
        match self {
            Cell::Str(s) => json_escape(s),
            Cell::Int(i) => i.to_string(),
            Cell::Float(x) => json_f64(*x),
            Cell::Dnf => "\"DNF\"".to_string(),
        }
    }
}

/// JSON number formatting for `f64`: Display (shortest round-trip) when
/// finite, `null` otherwise — JSON has no Infinity/NaN literals. Shared
/// with the benchmark trajectory writer ([`crate::harness::trajectory`]).
pub(crate) fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

impl From<&str> for Cell {
    fn from(s: &str) -> Self {
        Cell::Str(s.to_string())
    }
}
impl From<String> for Cell {
    fn from(s: String) -> Self {
        Cell::Str(s)
    }
}
impl From<i64> for Cell {
    fn from(i: i64) -> Self {
        Cell::Int(i)
    }
}
impl From<usize> for Cell {
    fn from(i: usize) -> Self {
        Cell::Int(i as i64)
    }
}
impl From<f64> for Cell {
    fn from(x: f64) -> Self {
        if x.is_finite() {
            Cell::Float(x)
        } else {
            Cell::Dnf
        }
    }
}

/// Quote + escape a string for JSON output (shared with the benchmark
/// trajectory writer in [`crate::harness::trajectory`]).
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// A rectangular report table (one per figure/table reproduction).
#[derive(Debug, Clone)]
pub struct Table {
    /// Table title (rendered as a heading).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Row-major cells; every row matches `headers` in width.
    pub rows: Vec<Vec<Cell>>,
    /// Free-form notes rendered under the table (assumptions, host info).
    pub notes: Vec<String>,
}

impl Table {
    /// Empty table with the given title and headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Append a row; panics if its width differs from the headers.
    pub fn push_row(&mut self, cells: Vec<Cell>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {} in table '{}'",
            cells.len(),
            self.headers.len(),
            self.title
        );
        self.rows.push(cells);
    }

    /// Append a free-form note.
    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// GitHub-flavored markdown rendering with aligned columns.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        let rendered: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| r.iter().map(|c| c.render()).collect())
            .collect();
        for row in &rendered {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let _ = write!(out, "|");
        for (h, w) in self.headers.iter().zip(&widths) {
            let _ = write!(out, " {h:<w$} |");
        }
        let _ = writeln!(out);
        let _ = write!(out, "|");
        for w in &widths {
            let _ = write!(out, "{:-<width$}|", "", width = w + 2);
        }
        let _ = writeln!(out);
        for row in &rendered {
            let _ = write!(out, "|");
            for (cell, w) in row.iter().zip(&widths) {
                let _ = write!(out, " {cell:<w$} |");
            }
            let _ = writeln!(out);
        }
        for n in &self.notes {
            let _ = writeln!(out, "\n> {n}");
        }
        out
    }

    /// RFC-4180-ish CSV (quotes only when needed).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(&c.render())).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// JSON: `{"title": ..., "headers": [...], "rows": [[...]], "notes": [...]}`.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        let _ = writeln!(out, "  \"title\": {},", json_escape(&self.title));
        let _ = writeln!(
            out,
            "  \"headers\": [{}],",
            self.headers.iter().map(|h| json_escape(h)).collect::<Vec<_>>().join(", ")
        );
        out.push_str("  \"rows\": [\n");
        for (i, row) in self.rows.iter().enumerate() {
            let cells: Vec<String> = row.iter().map(|c| c.render_json()).collect();
            let comma = if i + 1 == self.rows.len() { "" } else { "," };
            let _ = writeln!(out, "    [{}]{}", cells.join(", "), comma);
        }
        out.push_str("  ],\n");
        let _ = writeln!(
            out,
            "  \"notes\": [{}]",
            self.notes.iter().map(|n| json_escape(n)).collect::<Vec<_>>().join(", ")
        );
        out.push('}');
        out
    }

    /// Write markdown + CSV + JSON next to each other under `dir/<stem>.*`.
    pub fn write_all(&self, dir: &Path, stem: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.md")), self.to_markdown())?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        std::fs::write(dir.join(format!("{stem}.json")), self.to_json())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Fig X", &["program", "speedup"]);
        t.push_row(vec!["No-Sync".into(), 12.5.into()]);
        t.push_row(vec!["Barrier".into(), Cell::Dnf]);
        t.note("host: test");
        t
    }

    #[test]
    fn markdown_contains_rows_and_notes() {
        let md = sample().to_markdown();
        assert!(md.contains("### Fig X"));
        assert!(md.contains("No-Sync"));
        assert!(md.contains("DNF"));
        assert!(md.contains("> host: test"));
        // header separator present
        assert!(md.contains("|--"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("t", &["a", "b"]);
        t.push_row(vec!["x,y".into(), "say \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    fn json_well_formed_ish() {
        let j = sample().to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"title\": \"Fig X\""));
        assert!(j.contains("\"DNF\""));
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut t = Table::new("t", &["a", "b"]);
        t.push_row(vec!["only-one".into()]);
    }

    #[test]
    fn json_escape_control_chars() {
        assert_eq!(json_escape("a\nb"), "\"a\\nb\"");
        assert_eq!(json_escape("q\"q"), "\"q\\\"q\"");
    }
}
