//! Structural graph statistics for dataset reports (Table 1) and for the
//! workload characterization in EXPERIMENTS.md.

use crate::graph::{Csr, VertexId};

/// Degree-distribution and connectivity summary of a graph.
#[derive(Debug, Clone)]
pub struct GraphStats {
    /// Vertex count.
    pub vertices: usize,
    /// Directed edge count.
    pub edges: usize,
    /// Vertices with no out-edges.
    pub dangling: usize,
    /// Largest in-degree.
    pub max_in_degree: usize,
    /// Largest out-degree.
    pub max_out_degree: usize,
    /// Mean out-degree.
    pub mean_degree: f64,
    /// Gini coefficient of the in-degree distribution (0 = uniform,
    /// → 1 = extreme hub concentration). Web replicas should be ≫ road
    /// replicas.
    pub in_degree_gini: f64,
    /// Estimated CSR memory footprint in bytes.
    pub memory_bytes: u64,
}

impl GraphStats {
    /// Compute the stats in one pass over the CSR.
    pub fn compute(g: &Csr) -> Self {
        let n = g.num_vertices();
        let mut in_degs: Vec<usize> = (0..n as VertexId).map(|u| g.in_degree(u)).collect();
        let max_in = in_degs.iter().copied().max().unwrap_or(0);
        let max_out = (0..n as VertexId).map(|u| g.out_degree(u)).max().unwrap_or(0);
        in_degs.sort_unstable();
        let total: usize = in_degs.iter().sum();
        let gini = if total == 0 || n == 0 {
            0.0
        } else {
            // Gini = (2*Σ i*x_i)/(n*Σ x_i) - (n+1)/n, with 1-based i over
            // the sorted values.
            let weighted: f64 = in_degs
                .iter()
                .enumerate()
                .map(|(i, &x)| (i + 1) as f64 * x as f64)
                .sum();
            2.0 * weighted / (n as f64 * total as f64) - (n as f64 + 1.0) / n as f64
        };
        Self {
            vertices: n,
            edges: g.num_edges(),
            dangling: g.dangling_count(),
            max_in_degree: max_in,
            max_out_degree: max_out,
            mean_degree: g.num_edges() as f64 / n.max(1) as f64,
            in_degree_gini: gini,
            memory_bytes: g.memory_bytes(),
        }
    }
}

/// Histogram of in-degrees in power-of-two buckets (for degree-distribution
/// plots in reports).
pub fn in_degree_histogram(g: &Csr) -> Vec<(usize, usize)> {
    let mut buckets: Vec<usize> = Vec::new();
    for u in 0..g.num_vertices() as VertexId {
        let d = g.in_degree(u);
        let b = if d == 0 { 0 } else { (usize::BITS - d.leading_zeros()) as usize };
        if buckets.len() <= b {
            buckets.resize(b + 1, 0);
        }
        buckets[b] += 1;
    }
    buckets
        .into_iter()
        .enumerate()
        .map(|(b, c)| (if b == 0 { 0 } else { 1 << (b - 1) }, c))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic;

    #[test]
    fn stats_on_cycle_are_uniform() {
        let s = GraphStats::compute(&synthetic::cycle(20));
        assert_eq!(s.vertices, 20);
        assert_eq!(s.edges, 20);
        assert_eq!(s.dangling, 0);
        assert_eq!(s.max_in_degree, 1);
        assert!((s.mean_degree - 1.0).abs() < 1e-12);
        assert!(s.in_degree_gini.abs() < 1e-9, "uniform should be gini 0");
    }

    #[test]
    fn web_gini_exceeds_road_gini() {
        let web = GraphStats::compute(&synthetic::web_replica(3000, 8, 1));
        let road = GraphStats::compute(&synthetic::road_replica(3000, 1));
        assert!(
            web.in_degree_gini > road.in_degree_gini + 0.2,
            "web {} vs road {}",
            web.in_degree_gini,
            road.in_degree_gini
        );
    }

    #[test]
    fn histogram_counts_all_vertices() {
        let g = synthetic::web_replica(1000, 6, 2);
        let h = in_degree_histogram(&g);
        assert_eq!(h.iter().map(|&(_, c)| c).sum::<usize>(), g.num_vertices());
    }

    #[test]
    fn star_max_degrees() {
        let s = GraphStats::compute(&synthetic::star(11));
        assert_eq!(s.max_in_degree, 10);
        assert_eq!(s.max_out_degree, 10);
    }
}
