//! R-MAT recursive graph generator (Chakrabarti, Zhan, Faloutsos 2004) —
//! the generator behind the paper's synthetic datasets D10…D70 (§5.2,
//! Table 1).
//!
//! Each edge is placed by recursively descending an adjacency-matrix
//! quadtree with probabilities `(a, b, c, d)`; the classic skew
//! `(0.45, 0.22, 0.22, 0.11)` yields the power-law in/out degree
//! distributions real web graphs show. Isolated vertices are compacted away
//! afterwards, which is why Table 1's D10 lists 491,550 vertices for a
//! requested 2^19-ish id space with 10^6 edges — our generator reproduces
//! that compaction.

use crate::graph::{Csr, GraphBuilder, VertexId};
use crate::util::rng::Xoshiro256pp;

/// R-MAT parameters.
#[derive(Debug, Clone, Copy)]
pub struct RmatParams {
    /// Quadrant probabilities; must be positive and sum to 1.
    pub a: f64,
    /// Top-right quadrant probability.
    pub b: f64,
    /// Bottom-left quadrant probability.
    pub c: f64,
    /// Per-level multiplicative noise on the quadrant probabilities
    /// (0 = none), as used by Graph500 to avoid exact self-similarity.
    pub noise: f64,
    /// Drop self-loops and duplicate edges.
    pub simple: bool,
    /// Compact away isolated vertices (ids with no incident edge).
    pub compact: bool,
}

impl Default for RmatParams {
    fn default() -> Self {
        Self { a: 0.45, b: 0.22, c: 0.22, noise: 0.1, simple: true, compact: true }
    }
}

impl RmatParams {
    /// The implied fourth-quadrant probability `1 - a - b - c`.
    pub fn d(&self) -> f64 {
        1.0 - self.a - self.b - self.c
    }

    /// Check that the probabilities and noise are in range.
    pub fn validate(&self) -> Result<(), String> {
        let d = self.d();
        if self.a <= 0.0 || self.b <= 0.0 || self.c <= 0.0 || d <= 0.0 {
            return Err("rmat probabilities must be positive and sum < 1".into());
        }
        if !(0.0..=0.5).contains(&self.noise) {
            return Err("noise must be in [0, 0.5]".into());
        }
        Ok(())
    }
}

/// Generate an R-MAT graph with `2^scale` vertex id slots and `edges` edges.
pub fn generate(scale: u32, edges: usize, params: RmatParams, seed: u64) -> Csr {
    params.validate().expect("invalid RMAT params");
    assert!(scale >= 1 && scale < 32, "scale out of range");
    let n = 1usize << scale;
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut list: Vec<(VertexId, VertexId)> = Vec::with_capacity(edges);
    while list.len() < edges {
        let (u, v) = place_edge(scale, params, &mut rng);
        if params.simple && u == v {
            continue;
        }
        list.push((u, v));
    }
    if params.simple {
        list.sort_unstable();
        list.dedup();
        // Top up after dedup so the edge count matches the request — the
        // paper's D-series have exact edge counts (e.g. D10: 999,999).
        // Batched: generate the shortfall, merge, re-dedup. (A per-edge
        // `Vec::insert` top-up is quadratic — it was 95% of figure-pipeline
        // wall time before this batching; see EXPERIMENTS.md §Perf.)
        while list.len() < edges {
            let need = edges - list.len();
            let mut extra = Vec::with_capacity(need * 2);
            while extra.len() < need * 2 {
                let (u, v) = place_edge(scale, params, &mut rng);
                if u != v {
                    extra.push((u, v));
                }
            }
            list.extend(extra);
            list.sort_unstable();
            list.dedup();
        }
        list.truncate(edges);
    }

    let (n, list) = if params.compact { compact(n, list) } else { (n, list) };
    GraphBuilder::new(n)
        .edges(&list)
        .build(&format!("rmat-s{scale}-m{edges}"))
}

fn place_edge(scale: u32, p: RmatParams, rng: &mut Xoshiro256pp) -> (VertexId, VertexId) {
    let (mut u, mut v) = (0u64, 0u64);
    for _ in 0..scale {
        // multiplicative noise, renormalized
        let na = p.a * (1.0 - p.noise + 2.0 * p.noise * rng.next_f64());
        let nb = p.b * (1.0 - p.noise + 2.0 * p.noise * rng.next_f64());
        let nc = p.c * (1.0 - p.noise + 2.0 * p.noise * rng.next_f64());
        let nd = p.d() * (1.0 - p.noise + 2.0 * p.noise * rng.next_f64());
        let total = na + nb + nc + nd;
        let r = rng.next_f64() * total;
        let (du, dv) = if r < na {
            (0, 0)
        } else if r < na + nb {
            (0, 1)
        } else if r < na + nb + nc {
            (1, 0)
        } else {
            (1, 1)
        };
        u = (u << 1) | du;
        v = (v << 1) | dv;
    }
    (u as VertexId, v as VertexId)
}

/// Remove isolated vertex ids, remapping densely (stable order).
fn compact(n: usize, list: Vec<(VertexId, VertexId)>) -> (usize, Vec<(VertexId, VertexId)>) {
    let mut used = vec![false; n];
    for &(u, v) in &list {
        used[u as usize] = true;
        used[v as usize] = true;
    }
    let mut remap = vec![VertexId::MAX; n];
    let mut next: VertexId = 0;
    for (i, &u) in used.iter().enumerate() {
        if u {
            remap[i] = next;
            next += 1;
        }
    }
    let list = list
        .into_iter()
        .map(|(u, v)| (remap[u as usize], remap[v as usize]))
        .collect();
    (next as usize, list)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = generate(10, 5000, RmatParams::default(), 42);
        let b = generate(10, 5000, RmatParams::default(), 42);
        assert_eq!(a, b);
        let c = generate(10, 5000, RmatParams::default(), 43);
        assert_ne!(a, c);
    }

    #[test]
    fn exact_edge_count_with_dedup() {
        let g = generate(9, 4000, RmatParams::default(), 1);
        assert_eq!(g.num_edges(), 4000);
        assert_eq!(g.validate(), Ok(()));
    }

    #[test]
    fn simple_graph_has_no_self_loops_or_dups() {
        let g = generate(8, 2000, RmatParams::default(), 3);
        let mut seen = std::collections::HashSet::new();
        for u in 0..g.num_vertices() as u32 {
            for &v in g.out_neighbors(u) {
                assert_ne!(u, v, "self loop");
                assert!(seen.insert((u, v)), "duplicate edge ({u},{v})");
            }
        }
    }

    #[test]
    fn compaction_removes_isolated_vertices() {
        let g = generate(12, 3000, RmatParams::default(), 5);
        // With 4096 slots and only 3000 edges, skew guarantees isolated ids;
        // compaction must leave none.
        for u in 0..g.num_vertices() as u32 {
            assert!(
                g.out_degree(u) > 0 || g.in_degree(u) > 0,
                "vertex {u} isolated after compaction"
            );
        }
        assert!(g.num_vertices() < 4096);
    }

    #[test]
    fn skew_produces_heavy_tail() {
        // a=0.45 concentrates edges on low ids: max out-degree should far
        // exceed the mean.
        let g = generate(12, 40_000, RmatParams { noise: 0.0, ..Default::default() }, 9);
        let mean = g.num_edges() as f64 / g.num_vertices() as f64;
        let max = (0..g.num_vertices() as u32).map(|u| g.out_degree(u)).max().unwrap();
        assert!(
            max as f64 > 8.0 * mean,
            "expected heavy tail: max {max}, mean {mean:.2}"
        );
    }

    #[test]
    fn invalid_params_rejected() {
        assert!(RmatParams { a: 0.5, b: 0.3, c: 0.3, ..Default::default() }
            .validate()
            .is_err());
        assert!(RmatParams { noise: 0.9, ..Default::default() }.validate().is_err());
    }
}
