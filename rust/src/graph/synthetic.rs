//! Synthetic datasets: closed-form fixtures for correctness tests, replica
//! generators for the paper's Table-1 dataset classes, and the D10–D70
//! R-MAT series.
//!
//! The SNAP files themselves are not redistributable inside this offline
//! image, so every real-world dataset is replaced by a *replica* with the
//! same class-defining topology (degree skew, diameter, reciprocity) at a
//! configurable scale — see DESIGN.md "Substitutions". The real files load
//! through [`crate::graph::io::load_edge_list`] unchanged if present.

use crate::graph::rmat::{self, RmatParams};
use crate::graph::{Csr, GraphBuilder, VertexId};
use crate::util::rng::Xoshiro256pp;

// ---------------------------------------------------------------------------
// Closed-form fixtures (used heavily by unit & property tests)
// ---------------------------------------------------------------------------

/// Directed chain `0 → 1 → … → n-1`.
pub fn chain(n: usize) -> Csr {
    let edges: Vec<(VertexId, VertexId)> =
        (0..n.saturating_sub(1)).map(|i| (i as VertexId, i as VertexId + 1)).collect();
    GraphBuilder::new(n).edges(&edges).build(&format!("chain-{n}"))
}

/// Directed cycle `0 → 1 → … → n-1 → 0`. PageRank is uniform `1/n`.
pub fn cycle(n: usize) -> Csr {
    assert!(n >= 2);
    let edges: Vec<(VertexId, VertexId)> =
        (0..n).map(|i| (i as VertexId, ((i + 1) % n) as VertexId)).collect();
    GraphBuilder::new(n).edges(&edges).build(&format!("cycle-{n}"))
}

/// Star: leaves `1..n` all point at hub `0`, hub points at all leaves.
/// Closed-form: `pr(hub) = (1-d)/n + d·(n-1)·pr(leaf)`,
/// `pr(leaf) = (1-d)/n + d·pr(hub)/(n-1)`.
pub fn star(n: usize) -> Csr {
    assert!(n >= 2);
    let mut edges = Vec::with_capacity(2 * (n - 1));
    for i in 1..n as VertexId {
        edges.push((i, 0));
        edges.push((0, i));
    }
    GraphBuilder::new(n).edges(&edges).build(&format!("star-{n}"))
}

/// Complete directed graph (no self loops). PageRank is uniform `1/n`.
pub fn complete(n: usize) -> Csr {
    assert!(n >= 2);
    let mut edges = Vec::with_capacity(n * (n - 1));
    for u in 0..n as VertexId {
        for v in 0..n as VertexId {
            if u != v {
                edges.push((u, v));
            }
        }
    }
    GraphBuilder::new(n).edges(&edges).build(&format!("complete-{n}"))
}

/// Erdős–Rényi G(n, m) directed graph (simple).
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> Csr {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut set = std::collections::BTreeSet::new();
    assert!(m <= n * (n - 1), "too many edges for simple graph");
    while set.len() < m {
        let u = rng.next_below(n as u64) as VertexId;
        let v = rng.next_below(n as u64) as VertexId;
        if u != v {
            set.insert((u, v));
        }
    }
    let edges: Vec<_> = set.into_iter().collect();
    GraphBuilder::new(n).edges(&edges).build(&format!("er-{n}-{m}"))
}

// ---------------------------------------------------------------------------
// Table-1 replica generators
// ---------------------------------------------------------------------------

/// Web-graph replica: strong R-MAT skew (many pages, few hubs), low
/// reciprocity — the webStanford / webGoogle family.
pub fn web_replica(target_vertices: usize, avg_out_degree: usize, seed: u64) -> Csr {
    let scale = scale_for(target_vertices);
    let edges = target_vertices * avg_out_degree;
    let params = RmatParams { a: 0.57, b: 0.19, c: 0.19, noise: 0.1, ..Default::default() };
    let mut g = rmat::generate(scale, edges, params, seed);
    g.name = format!("web-replica-{target_vertices}");
    g
}

/// Social-network replica: milder skew, higher reciprocity (friend links go
/// both ways ~30% of the time) — the soc-Epinions / Slashdot family.
pub fn social_replica(target_vertices: usize, avg_out_degree: usize, seed: u64) -> Csr {
    let scale = scale_for(target_vertices);
    let base_edges = target_vertices * avg_out_degree * 7 / 10;
    let params = RmatParams { a: 0.45, b: 0.22, c: 0.22, noise: 0.1, ..Default::default() };
    let base = rmat::generate(scale, base_edges, params, seed);
    // add reciprocal edges for ~30% of links
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0x50C1A1);
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(base.num_edges() * 13 / 10);
    for u in 0..base.num_vertices() as VertexId {
        for &v in base.out_neighbors(u) {
            edges.push((u, v));
            if rng.chance(0.3) {
                edges.push((v, u));
            }
        }
    }
    GraphBuilder::new(base.num_vertices())
        .dedup(true)
        .edges(&edges)
        .build(&format!("social-replica-{target_vertices}"))
}

/// Road-network replica: a 2-D lattice with bidirectional street segments,
/// 1% long-range shortcuts (highways) and 3% random deletions — near-uniform
/// degree ≈ 4 and huge diameter, like roaditaly / germanyosm.
pub fn road_replica(target_vertices: usize, seed: u64) -> Csr {
    let side = (target_vertices as f64).sqrt().round().max(2.0) as usize;
    let n = side * side;
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let at = |r: usize, c: usize| (r * side + c) as VertexId;
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(4 * n);
    for r in 0..side {
        for c in 0..side {
            if c + 1 < side && !rng.chance(0.03) {
                edges.push((at(r, c), at(r, c + 1)));
                edges.push((at(r, c + 1), at(r, c)));
            }
            if r + 1 < side && !rng.chance(0.03) {
                edges.push((at(r, c), at(r + 1, c)));
                edges.push((at(r + 1, c), at(r, c)));
            }
        }
    }
    let shortcuts = n / 100;
    for _ in 0..shortcuts {
        let u = rng.next_below(n as u64) as VertexId;
        let v = rng.next_below(n as u64) as VertexId;
        if u != v {
            edges.push((u, v));
            edges.push((v, u));
        }
    }
    GraphBuilder::new(n)
        .dedup(true)
        .edges(&edges)
        .build(&format!("road-replica-{n}"))
}

/// The paper's D-series: RMAT graphs targeting `k * 10^6` edges at full
/// scale (Table 1: D10 has 10^6 edges & 491,550 vertices … D70 has 7·10^6
/// edges & 3,222,209 vertices). `divisor` scales the series down for CI
/// hosts; vertex/edge ratios are preserved.
pub fn d_series(index: u32, divisor: usize, seed: u64) -> Csr {
    assert!((1..=7).contains(&index), "D-series index 1..=7 (D10..D70)");
    assert!(divisor >= 1);
    let edges = (index as usize * 1_000_000 - 1) / divisor;
    // Table 1 shows ~0.49 vertices per edge for D10 declining to ~0.46 for
    // D70; an id space of ~edges/1.3 with compaction reproduces that.
    let scale = scale_for(edges / 2);
    let mut g = rmat::generate(scale, edges, RmatParams::default(), seed + index as u64);
    g.name = format!("D{}0{}", index, if divisor == 1 { String::new() } else { format!("/{divisor}") });
    g
}

fn scale_for(target_vertices: usize) -> u32 {
    let mut scale = 1u32;
    while (1usize << scale) < target_vertices {
        scale += 1;
    }
    scale
}

// ---------------------------------------------------------------------------
// Table-1 registry
// ---------------------------------------------------------------------------

/// Dataset category, mirroring Table 1's sections.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Category {
    /// Web graphs (skewed in-degree).
    Web,
    /// Social networks (heavier degree tail).
    Social,
    /// Road networks (high diameter, near-uniform degree).
    Road,
    /// Synthetic R-MAT graphs (the d-series).
    Synthetic,
}

impl std::fmt::Display for Category {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Category::Web => "Web Graphs",
            Category::Social => "Social Networks",
            Category::Road => "Road Networks",
            Category::Synthetic => "Synthetic Graphs",
        };
        f.write_str(s)
    }
}

/// One Table-1 row: the paper's dataset and the replica that stands in.
pub struct DatasetSpec {
    /// Dataset name as printed in Table 1.
    pub name: &'static str,
    /// Table-1 section this dataset belongs to.
    pub category: Category,
    /// Vertex count reported by the paper.
    pub paper_vertices: u64,
    /// Edge count reported by the paper.
    pub paper_edges: u64,
    /// Build the replica at `1/divisor` of the paper's size.
    pub build: fn(divisor: usize, seed: u64) -> Csr,
}

macro_rules! spec {
    ($name:literal, $cat:expr, $v:expr, $e:expr, $builder:expr) => {
        DatasetSpec {
            name: $name,
            category: $cat,
            paper_vertices: $v,
            paper_edges: $e,
            build: $builder,
        }
    };
}

/// The full Table-1 inventory. Replicas match each dataset's
/// vertices/edges ratio at `paper_size / divisor`.
pub fn table1() -> Vec<DatasetSpec> {
    vec![
        spec!("webStanford", Category::Web, 281_903, 2_312_497, |d, s| {
            web_replica(281_903 / d, 8, s)
        }),
        spec!("webNotreDame", Category::Web, 325_729, 1_497_134, |d, s| {
            web_replica(325_729 / d, 5, s.wrapping_add(1))
        }),
        spec!("webBerkStan", Category::Web, 685_230, 7_600_595, |d, s| {
            web_replica(685_230 / d, 11, s.wrapping_add(2))
        }),
        spec!("webGoogle", Category::Web, 875_713, 5_105_039, |d, s| {
            web_replica(875_713 / d, 6, s.wrapping_add(3))
        }),
        spec!("socEpinions1", Category::Social, 75_879, 508_837, |d, s| {
            social_replica(75_879 / d, 7, s.wrapping_add(4))
        }),
        spec!("Slashdot0811", Category::Social, 77_360, 905_468, |d, s| {
            social_replica(77_360 / d, 12, s.wrapping_add(5))
        }),
        spec!("Slashdot0902", Category::Social, 82_168, 948_464, |d, s| {
            social_replica(82_168 / d, 12, s.wrapping_add(6))
        }),
        spec!("socLiveJournal1", Category::Social, 4_847_571, 68_993_773, |d, s| {
            social_replica(4_847_571 / d, 14, s.wrapping_add(7))
        }),
        spec!("roaditalyosm", Category::Road, 6_686_493, 7_013_978, |d, s| {
            road_replica(6_686_493 / d, s.wrapping_add(8))
        }),
        spec!("greatbritainosm", Category::Road, 7_700_000, 8_200_000, |d, s| {
            road_replica(7_700_000 / d, s.wrapping_add(9))
        }),
        spec!("asiaosm", Category::Road, 12_000_000, 12_700_000, |d, s| {
            road_replica(12_000_000 / d, s.wrapping_add(10))
        }),
        spec!("germanyosm", Category::Road, 11_500_000, 12_400_000, |d, s| {
            road_replica(11_500_000 / d, s.wrapping_add(11))
        }),
        spec!("D10", Category::Synthetic, 491_550, 999_999, |d, s| d_series(1, d, s)),
        spec!("D20", Category::Synthetic, 954_225, 1_999_999, |d, s| d_series(2, d, s)),
        spec!("D30", Category::Synthetic, 1_400_539, 2_999_999, |d, s| d_series(3, d, s)),
        spec!("D40", Category::Synthetic, 1_871_477, 3_999_999, |d, s| d_series(4, d, s)),
        spec!("D50", Category::Synthetic, 2_303_074, 4_999_999, |d, s| d_series(5, d, s)),
        spec!("D60", Category::Synthetic, 2_759_417, 5_999_999, |d, s| d_series(6, d, s)),
        spec!("D70", Category::Synthetic, 3_222_209, 6_999_999, |d, s| d_series(7, d, s)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_shape() {
        let g = chain(5);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.out_degree(4), 0);
        assert_eq!(g.in_degree(0), 0);
        assert_eq!(g.dangling_count(), 1);
    }

    #[test]
    fn cycle_uniform_degrees() {
        let g = cycle(6);
        for u in 0..6u32 {
            assert_eq!(g.out_degree(u), 1);
            assert_eq!(g.in_degree(u), 1);
        }
    }

    #[test]
    fn star_shape() {
        let g = star(5);
        assert_eq!(g.out_degree(0), 4);
        assert_eq!(g.in_degree(0), 4);
        for leaf in 1..5u32 {
            assert_eq!(g.out_degree(leaf), 1);
            assert_eq!(g.in_degree(leaf), 1);
        }
    }

    #[test]
    fn complete_shape() {
        let g = complete(4);
        assert_eq!(g.num_edges(), 12);
        for u in 0..4u32 {
            assert_eq!(g.out_degree(u), 3);
            assert_eq!(g.in_degree(u), 3);
        }
    }

    #[test]
    fn erdos_renyi_exact_m_simple() {
        let g = erdos_renyi(50, 200, 3);
        assert_eq!(g.num_edges(), 200);
        assert_eq!(g.validate(), Ok(()));
    }

    #[test]
    fn web_replica_is_skewed() {
        let g = web_replica(2000, 8, 1);
        let mean = g.num_edges() as f64 / g.num_vertices() as f64;
        let max_in = (0..g.num_vertices() as u32).map(|u| g.in_degree(u)).max().unwrap();
        assert!(max_in as f64 > 5.0 * mean, "web replica not skewed enough");
    }

    #[test]
    fn road_replica_low_degree_high_n() {
        let g = road_replica(2500, 2);
        let max_out = (0..g.num_vertices() as u32).map(|u| g.out_degree(u)).max().unwrap();
        assert!(max_out <= 8, "road max degree {max_out} too high");
        let mean = g.num_edges() as f64 / g.num_vertices() as f64;
        assert!((1.0..5.0).contains(&mean));
    }

    #[test]
    fn social_replica_has_reciprocity() {
        let g = social_replica(1000, 8, 5);
        let mut recip = 0usize;
        let mut total = 0usize;
        for u in 0..g.num_vertices() as u32 {
            for &v in g.out_neighbors(u) {
                total += 1;
                if g.out_neighbors(v).contains(&u) {
                    recip += 1;
                }
            }
        }
        let ratio = recip as f64 / total.max(1) as f64;
        assert!(ratio > 0.2, "reciprocity {ratio:.2} too low for social replica");
    }

    #[test]
    fn d_series_scales_down() {
        let g = d_series(1, 100, 7);
        assert_eq!(g.num_edges(), 9999);
        assert_eq!(g.validate(), Ok(()));
        assert!(g.name.starts_with("D10"));
    }

    #[test]
    fn table1_registry_complete() {
        let t = table1();
        assert_eq!(t.len(), 19);
        assert_eq!(t.iter().filter(|s| s.category == Category::Web).count(), 4);
        assert_eq!(t.iter().filter(|s| s.category == Category::Social).count(), 4);
        assert_eq!(t.iter().filter(|s| s.category == Category::Road).count(), 4);
        assert_eq!(t.iter().filter(|s| s.category == Category::Synthetic).count(), 7);
    }

    #[test]
    fn table1_builders_run_at_small_scale() {
        for spec in table1() {
            let g = (spec.build)(1000, 42);
            assert!(g.num_vertices() > 0, "{} empty", spec.name);
            assert_eq!(g.validate(), Ok(()), "{} invalid", spec.name);
        }
    }
}
