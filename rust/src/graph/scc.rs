//! Strongly-connected-component decomposition — STIC-D technique 1
//! (Garg & Kothapalli [11], described in the paper's §3).
//!
//! PageRank distributes over the condensation DAG: the rank of an SCC
//! depends only on upstream components, so components can be solved in
//! topological order, each as a much smaller PageRank instance with fixed
//! inflow from already-solved predecessors. [`SccDecomposition`] computes
//! the components (iterative Tarjan — explicit stack, safe for
//! million-vertex road replicas) and a topological order of the
//! condensation; [`solve_by_scc`] is the reference level-order solver used
//! by the `ablation` bench to quantify the technique on our replicas.

use crate::graph::{Csr, VertexId};

/// SCC labelling + condensation topological order.
#[derive(Debug, Clone)]
pub struct SccDecomposition {
    /// `comp_of[u]` — component id per vertex. Ids are in **reverse
    /// topological order of discovery** (Tarjan property): an edge
    /// `u → v` across components has `comp_of[u] > comp_of[v]`.
    pub comp_of: Vec<u32>,
    /// Members per component.
    pub members: Vec<Vec<VertexId>>,
}

impl SccDecomposition {
    /// Iterative Tarjan over the out-adjacency.
    pub fn compute(g: &Csr) -> Self {
        let n = g.num_vertices();
        const UNSET: u32 = u32::MAX;
        let mut index = vec![UNSET; n];
        let mut lowlink = vec![0u32; n];
        let mut on_stack = vec![false; n];
        let mut comp_of = vec![UNSET; n];
        let mut stack: Vec<VertexId> = Vec::new();
        let mut members: Vec<Vec<VertexId>> = Vec::new();
        let mut next_index = 0u32;

        // Explicit DFS frame: (vertex, next out-edge offset to visit).
        let mut frames: Vec<(VertexId, usize)> = Vec::new();
        for root in 0..n as VertexId {
            if index[root as usize] != UNSET {
                continue;
            }
            frames.push((root, 0));
            index[root as usize] = next_index;
            lowlink[root as usize] = next_index;
            next_index += 1;
            stack.push(root);
            on_stack[root as usize] = true;

            while let Some(&mut (u, ref mut ei)) = frames.last_mut() {
                let out = g.out_neighbors(u);
                if *ei < out.len() {
                    let v = out[*ei];
                    *ei += 1;
                    if index[v as usize] == UNSET {
                        index[v as usize] = next_index;
                        lowlink[v as usize] = next_index;
                        next_index += 1;
                        stack.push(v);
                        on_stack[v as usize] = true;
                        frames.push((v, 0));
                    } else if on_stack[v as usize] {
                        lowlink[u as usize] = lowlink[u as usize].min(index[v as usize]);
                    }
                } else {
                    frames.pop();
                    if let Some(&mut (parent, _)) = frames.last_mut() {
                        lowlink[parent as usize] =
                            lowlink[parent as usize].min(lowlink[u as usize]);
                    }
                    if lowlink[u as usize] == index[u as usize] {
                        // u is an SCC root: pop the component.
                        let cid = members.len() as u32;
                        let mut comp = Vec::new();
                        loop {
                            let w = stack.pop().expect("tarjan stack underflow");
                            on_stack[w as usize] = false;
                            comp_of[w as usize] = cid;
                            comp.push(w);
                            if w == u {
                                break;
                            }
                        }
                        members.push(comp);
                    }
                }
            }
        }
        Self { comp_of, members }
    }

    /// Number of strongly-connected components.
    pub fn num_components(&self) -> usize {
        self.members.len()
    }

    /// Components in topological order (sources first): Tarjan emits them
    /// in reverse topological order, so this is just id-descending.
    pub fn topological_order(&self) -> impl Iterator<Item = u32> + '_ {
        (0..self.members.len() as u32).rev()
    }

    /// Check: every cross-component edge goes from a later-emitted to an
    /// earlier-emitted component (i.e. respects topological order).
    pub fn verify(&self, g: &Csr) -> Result<(), String> {
        for u in 0..g.num_vertices() as VertexId {
            for &v in g.out_neighbors(u) {
                let (cu, cv) = (self.comp_of[u as usize], self.comp_of[v as usize]);
                if cu != cv && cu < cv {
                    return Err(format!("edge {u}->{v} violates condensation order"));
                }
            }
        }
        if self.comp_of.iter().any(|&c| c == u32::MAX) {
            return Err("vertex without component".into());
        }
        Ok(())
    }
}

/// PageRank solved component-by-component in topological order; the
/// single-component solve is plain power iteration restricted to the
/// component with frozen inflow. Matches the global solver to `threshold`.
pub fn solve_by_scc(g: &Csr, damping: f64, threshold: f64, max_iters: u64) -> (Vec<f64>, u64) {
    let n = g.num_vertices();
    if n == 0 {
        return (Vec::new(), 0);
    }
    let scc = SccDecomposition::compute(g);
    let base = (1.0 - damping) / n as f64;
    let inv_out: Vec<f64> = (0..n as VertexId)
        .map(|v| {
            let od = g.out_degree(v);
            if od == 0 {
                0.0
            } else {
                1.0 / od as f64
            }
        })
        .collect();
    let mut pr = vec![1.0 / n as f64; n];
    let mut total_iters = 0u64;
    for cid in scc.topological_order() {
        let comp = &scc.members[cid as usize];
        // Inflow from other components is fixed (they are already solved
        // or, being downstream, do not feed this component).
        let mut iters = 0u64;
        loop {
            let mut err: f64 = 0.0;
            // Jacobi step restricted to the component.
            let snapshot: Vec<f64> = comp.iter().map(|&u| pr[u as usize]).collect();
            for (i, &u) in comp.iter().enumerate() {
                let mut sum = 0.0;
                for &v in g.in_neighbors(u) {
                    let r = if scc.comp_of[v as usize] == cid {
                        // intra-component: use the snapshot (Jacobi)
                        let j = comp.iter().position(|&w| w == v).unwrap();
                        snapshot[j]
                    } else {
                        pr[v as usize]
                    };
                    sum += r * inv_out[v as usize];
                }
                let new = base + damping * sum;
                err = err.max((new - snapshot[i]).abs());
                pr[u as usize] = new;
            }
            iters += 1;
            if err <= threshold || iters >= max_iters {
                break;
            }
        }
        total_iters = total_iters.max(iters);
    }
    (pr, total_iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{synthetic, GraphBuilder};

    #[test]
    fn cycle_is_one_component() {
        let g = synthetic::cycle(10);
        let scc = SccDecomposition::compute(&g);
        assert_eq!(scc.num_components(), 1);
        scc.verify(&g).unwrap();
    }

    #[test]
    fn chain_is_all_singletons() {
        let g = synthetic::chain(10);
        let scc = SccDecomposition::compute(&g);
        assert_eq!(scc.num_components(), 10);
        scc.verify(&g).unwrap();
    }

    #[test]
    fn two_cycles_with_bridge() {
        // cycle {0,1,2} → bridge → cycle {3,4}
        let g = GraphBuilder::new(5)
            .edges(&[(0, 1), (1, 2), (2, 0), (2, 3), (3, 4), (4, 3)])
            .build("bridge");
        let scc = SccDecomposition::compute(&g);
        assert_eq!(scc.num_components(), 2);
        scc.verify(&g).unwrap();
        // topological order: the {0,1,2} component precedes {3,4}
        let order: Vec<u32> = scc.topological_order().collect();
        let c012 = scc.comp_of[0];
        let c34 = scc.comp_of[3];
        let pos = |c: u32| order.iter().position(|&x| x == c).unwrap();
        assert!(pos(c012) < pos(c34));
    }

    #[test]
    fn verify_on_random_graphs() {
        for seed in 0..5 {
            let g = synthetic::web_replica(600, 5, seed);
            let scc = SccDecomposition::compute(&g);
            scc.verify(&g).unwrap();
        }
    }

    #[test]
    fn scc_solver_matches_global_solver() {
        use crate::pagerank::{seq, PrConfig};
        for g in [
            synthetic::chain(40),
            synthetic::star(30),
            synthetic::web_replica(400, 5, 9),
        ] {
            let cfg = PrConfig { threshold: 1e-12, ..PrConfig::default() };
            let (want, _, _) = seq::solve(&g, &cfg);
            let (got, _) = solve_by_scc(&g, cfg.damping, 1e-13, 10_000);
            let l1: f64 = got.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum();
            assert!(l1 < 1e-8, "{}: L1 {l1}", g.name);
        }
    }

    #[test]
    fn deep_recursion_safe() {
        // 50k-vertex chain would blow a recursive Tarjan's stack.
        let g = synthetic::chain(50_000);
        let scc = SccDecomposition::compute(&g);
        assert_eq!(scc.num_components(), 50_000);
    }
}
