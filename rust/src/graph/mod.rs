//! Graph substrate: the CSR representation the paper's algorithms consume,
//! builders and loaders for real datasets (SNAP edge lists), synthetic
//! generators (RMAT and Table-1 replica families), static partitioning and
//! the identical-node preprocessing from STIC-D.

pub mod builder;
pub mod chains;
pub mod csr;
pub mod delta;
pub mod identical;
pub mod io;
pub mod partition;
pub mod properties;
pub mod rmat;
pub mod scc;
pub mod synthetic;

pub use builder::GraphBuilder;
pub use csr::{Csr, GraphStore};
pub use io::map_binary;
pub use delta::{AppliedDelta, GraphDelta};
pub use partition::{CompressedBins, PartitionPolicy, Partitions};

/// Vertex id type. `u32` halves the memory traffic of the gather loop versus
/// `usize` — the hot path is memory-bound, so this matters (see
/// EXPERIMENTS.md §Perf).
pub type VertexId = u32;
