//! Static load allocation: split the vertex set into `p` contiguous
//! partitions, one per thread (paper §4.1: "vertices are divided into p
//! equal-sized partitions … static load allocation").
//!
//! Two policies:
//! * [`PartitionPolicy::VertexBalanced`] — the paper's scheme: equal vertex
//!   counts regardless of degree.
//! * [`PartitionPolicy::EdgeBalanced`] — equal *work* (in-edges), which the
//!   ablation bench (`benches/ablation.rs`) compares against; on skewed
//!   graphs this is what keeps barrier variants from being dragged down by
//!   one heavy partition.

use crate::graph::{Csr, VertexId};

/// How to split the vertex set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionPolicy {
    /// Equal vertex counts per partition.
    VertexBalanced,
    /// Roughly equal out-edge counts per partition.
    EdgeBalanced,
}

impl std::fmt::Display for PartitionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PartitionPolicy::VertexBalanced => f.write_str("vertex-balanced"),
            PartitionPolicy::EdgeBalanced => f.write_str("edge-balanced"),
        }
    }
}

/// The result: `p` contiguous half-open vertex ranges covering `0..n`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partitions {
    bounds: Vec<usize>, // len p+1, bounds[0]=0, bounds[p]=n
    /// The policy these bounds were computed under.
    pub policy: PartitionPolicy,
}

/// Ceil-spread of `n` vertices over `p` parts: the first `n % p` parts get
/// one extra vertex. Shared by the vertex-balanced policy and the
/// edge-balanced fallback for edgeless graphs.
fn vertex_spread(n: usize, p: usize) -> Vec<usize> {
    let mut bounds = Vec::with_capacity(p + 1);
    bounds.push(0);
    let base = n / p;
    let extra = n % p;
    let mut at = 0;
    for i in 0..p {
        at += base + usize::from(i < extra);
        bounds.push(at);
    }
    bounds
}

impl Partitions {
    /// Partition `g` into `p` ranges under `policy`.
    ///
    /// Total for degenerate inputs: `p = 0` is clamped to one partition
    /// (the stats below must never panic on caller mistakes), `n = 0`
    /// yields `p` empty ranges, and an edge-balanced split of an edgeless
    /// graph falls back to the vertex spread — the greedy prefix cut has no
    /// edge mass to chase and would otherwise pile every vertex into the
    /// head partition and leave singleton tails.
    pub fn new(g: &Csr, p: usize, policy: PartitionPolicy) -> Self {
        let p = p.max(1);
        let n = g.num_vertices();
        let m = g.num_edges();
        let bounds = match policy {
            PartitionPolicy::VertexBalanced => vertex_spread(n, p),
            PartitionPolicy::EdgeBalanced if m == 0 => vertex_spread(n, p),
            PartitionPolicy::EdgeBalanced => {
                // Greedy prefix cut at ~m/p in-edges per part. The pull-
                // direction work of vertex u is its in-degree.
                let target = (m as f64 / p as f64).max(1.0);
                let mut bounds = Vec::with_capacity(p + 1);
                bounds.push(0);
                let mut acc = 0usize;
                let mut cuts_made = 0usize;
                for u in 0..n {
                    acc += g.in_degree(u as VertexId);
                    // leave enough vertices for remaining cuts
                    let remaining_cuts = p - 1 - cuts_made;
                    let remaining_vertices = n - (u + 1);
                    if cuts_made < p - 1
                        && (acc as f64 >= target * (cuts_made + 1) as f64
                            || remaining_vertices == remaining_cuts)
                    {
                        bounds.push(u + 1);
                        cuts_made += 1;
                    }
                }
                while bounds.len() < p {
                    bounds.push(n);
                }
                bounds.push(n);
                bounds
            }
        };
        debug_assert_eq!(bounds.len(), p + 1);
        Self { bounds, policy }
    }

    /// Number of partitions `p`.
    pub fn count(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Vertex range of partition `i`.
    pub fn range(&self, i: usize) -> std::ops::Range<VertexId> {
        self.bounds[i] as VertexId..self.bounds[i + 1] as VertexId
    }

    /// Which partition owns vertex `u` (binary search).
    pub fn owner(&self, u: VertexId) -> usize {
        match self.bounds.binary_search(&(u as usize)) {
            Ok(i) => i.min(self.count() - 1),
            Err(i) => i - 1,
        }
    }

    /// In-edge work per partition (for imbalance reporting).
    pub fn edge_loads(&self, g: &Csr) -> Vec<usize> {
        (0..self.count())
            .map(|i| self.range(i).map(|u| g.in_degree(u)).sum())
            .collect()
    }

    /// max/mean edge-load imbalance factor (1.0 = perfect). Total: an
    /// edgeless or empty graph has nothing to imbalance and reports 1.0.
    pub fn imbalance(&self, g: &Csr) -> f64 {
        let loads = self.edge_loads(g);
        let max = loads.iter().copied().max().unwrap_or(0) as f64;
        let mean = loads.iter().sum::<usize>() as f64 / loads.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// Entry flag in [`CompressedBins`]' destination stream: set on the first
/// destination a source vertex contributes to a bin — i.e. "advance to the
/// next slot of the value stream before applying this entry".
pub const GROUP_FLAG: u32 = 1 << 31;

/// Compressed update-bin layout for partition-centric scatter-gather
/// (PCPM), after Lakhotia et al., *"Accelerating PageRank using
/// Partition-Centric Processing"*.
///
/// Every edge `(u → v)` is grouped by `(source partition, destination
/// partition)`. The destination indices never change between iterations,
/// so they are built **once** into a static `u32` stream; only the *values*
/// are (re)written at runtime, into a dense value stream with one slot per
/// `(source vertex, destination partition)` group — a vertex with many
/// out-edges into the same partition writes its contribution a single time
/// instead of once per edge. The scatter phase of [`crate::engine::pcpm`]
/// therefore streams at most `min(outdeg, p)` stores per vertex (each bin's
/// writes are sequential), and the gather phase replays a bin as a
/// sequential `(dest, value)` merge: an entry with [`GROUP_FLAG`] set pulls
/// the next value slot, every entry adds the current value to its decoded
/// destination.
///
/// [`CompressedBins::new_per_edge`] builds the same streams *without* the
/// per-vertex dedup — one value slot per edge, every entry flagged. That is
/// the old one-slot-per-edge layout expressed in the new format, kept as
/// the ablation baseline (`--pcpm-layout slots`).
///
/// Within one `(src, dst)` bin, entries follow ascending source-vertex
/// order — the same order the stable counting sort gives
/// `Csr::in_neighbors` — so a PCPM gather accumulates bit-identically to
/// the vertex-centric pull regardless of layout or partition count.
#[derive(Debug, Clone)]
pub struct CompressedBins {
    parts: usize,
    dedup: bool,
    /// `dst_ranges[src * parts + dst]` — that bin's slice of `dst_stream`.
    dst_ranges: Vec<std::ops::Range<usize>>,
    /// One entry per edge, grouped by bin: destination vertex id, with
    /// [`GROUP_FLAG`] marking the start of a new value group.
    dst_stream: Vec<u32>,
    /// `value_ranges[src * parts + dst]` — that bin's slice of the value
    /// stream (allocated by the kernels; this struct only owns the layout).
    value_ranges: Vec<std::ops::Range<usize>>,
    num_values: usize,
    /// Per-vertex slice bounds into `push_slots` (len n+1).
    push_offsets: Vec<usize>,
    /// For each vertex, in first-encounter order of its destination
    /// partitions (edge order when not deduped): the value-stream slot it
    /// writes during scatter.
    push_slots: Vec<usize>,
}

impl CompressedBins {
    /// Compressed layout: one value slot per `(vertex, destination
    /// partition)` group. O(m log p) (one owner lookup per edge), done once
    /// per run.
    pub fn new(g: &Csr, parts: &Partitions) -> Self {
        Self::build(g, parts, true)
    }

    /// Uncompressed baseline: one value slot per edge (the pre-compression
    /// bin layout, in stream form).
    pub fn new_per_edge(g: &Csr, parts: &Partitions) -> Self {
        Self::build(g, parts, false)
    }

    fn build(g: &Csr, parts: &Partitions, dedup: bool) -> Self {
        let p = parts.count();
        let n = g.num_vertices();
        let m = g.num_edges();
        assert!(
            n < GROUP_FLAG as usize,
            "vertex ids must leave the group-flag bit free (n < 2^31)"
        );
        // Pass 1: per-bin edge and value-group counts, per-vertex group
        // counts. `last_u` detects a vertex revisiting a bin (its edges are
        // walked consecutively, so one stamp per bin suffices even when the
        // adjacency interleaves destination partitions).
        let mut edge_counts = vec![0usize; p * p];
        let mut value_counts = vec![0usize; p * p];
        let mut push_offsets = vec![0usize; n + 1];
        let mut last_u = vec![VertexId::MAX; p * p];
        for src_part in 0..p {
            for u in parts.range(src_part) {
                let mut groups = 0usize;
                for &v in g.out_neighbors(u) {
                    let key = src_part * p + parts.owner(v);
                    edge_counts[key] += 1;
                    if !dedup || last_u[key] != u {
                        last_u[key] = u;
                        value_counts[key] += 1;
                        groups += 1;
                    }
                }
                push_offsets[u as usize + 1] = groups;
            }
        }
        for i in 0..n {
            push_offsets[i + 1] += push_offsets[i];
        }
        let mut dst_starts = vec![0usize; p * p + 1];
        let mut value_starts = vec![0usize; p * p + 1];
        for i in 0..p * p {
            dst_starts[i + 1] = dst_starts[i] + edge_counts[i];
            value_starts[i + 1] = value_starts[i] + value_counts[i];
        }
        let num_values = value_starts[p * p];
        let dst_ranges: Vec<std::ops::Range<usize>> =
            (0..p * p).map(|i| dst_starts[i]..dst_starts[i + 1]).collect();
        let value_ranges: Vec<std::ops::Range<usize>> =
            (0..p * p).map(|i| value_starts[i]..value_starts[i + 1]).collect();

        // Pass 2: fill the streams. Partitions tile 0..n in ascending
        // order, so `push_slots` is filled in ascending vertex order and
        // lines up with the prefix-summed `push_offsets`.
        let mut dst_cursor = dst_starts[..p * p].to_vec();
        let mut value_cursor = value_starts[..p * p].to_vec();
        let mut dst_stream = vec![0u32; m];
        let mut push_slots = vec![0usize; num_values];
        let mut push_at = 0usize;
        last_u.fill(VertexId::MAX);
        for src_part in 0..p {
            for u in parts.range(src_part) {
                for &v in g.out_neighbors(u) {
                    let key = src_part * p + parts.owner(v);
                    let first = !dedup || last_u[key] != u;
                    if first {
                        last_u[key] = u;
                        push_slots[push_at] = value_cursor[key];
                        push_at += 1;
                        value_cursor[key] += 1;
                    }
                    dst_stream[dst_cursor[key]] = v | if first { GROUP_FLAG } else { 0 };
                    dst_cursor[key] += 1;
                }
            }
        }
        debug_assert_eq!(push_at, num_values);
        Self {
            parts: p,
            dedup,
            dst_ranges,
            dst_stream,
            value_ranges,
            num_values,
            push_offsets,
            push_slots,
        }
    }

    /// Partition count per axis of the bin grid.
    pub fn num_partitions(&self) -> usize {
        self.parts
    }

    /// Destination-stream entries (= number of edges).
    pub fn num_edges(&self) -> usize {
        self.dst_stream.len()
    }

    /// Value-stream slots the kernels must allocate. Equals `num_edges` for
    /// the per-edge layout; at most that (usually far less on graphs with
    /// locality) when deduped.
    pub fn num_values(&self) -> usize {
        self.num_values
    }

    /// Was this layout built with per-(vertex, partition) dedup?
    pub fn is_deduped(&self) -> bool {
        self.dedup
    }

    /// Destination-stream range of the `(src, dst)` bin.
    pub fn dst_range(&self, src: usize, dst: usize) -> std::ops::Range<usize> {
        self.dst_ranges[src * self.parts + dst].clone()
    }

    /// Value-stream range of the `(src, dst)` bin.
    pub fn value_range(&self, src: usize, dst: usize) -> std::ops::Range<usize> {
        self.value_ranges[src * self.parts + dst].clone()
    }

    /// The `(src, dst)` bin's destination entries (decode with
    /// [`CompressedBins::decode`]).
    #[inline]
    pub fn entries(&self, src: usize, dst: usize) -> &[u32] {
        &self.dst_stream[self.dst_range(src, dst)]
    }

    /// Split a destination-stream entry into (destination vertex, does this
    /// entry start a new value group).
    #[inline]
    pub fn decode(entry: u32) -> (VertexId, bool) {
        (entry & !GROUP_FLAG, entry & GROUP_FLAG != 0)
    }

    /// The value-stream slots vertex `u` writes during scatter, one per
    /// value group (empty iff `u` has no out-edges).
    #[inline]
    pub fn push_slots(&self, u: VertexId) -> &[usize] {
        &self.push_slots[self.push_offsets[u as usize]..self.push_offsets[u as usize + 1]]
    }

    /// For each in-edge slot of the CSR (the pull-direction edge array),
    /// the value-stream slot its source vertex scatters into — this is what
    /// lets a frontier gather read one vertex's in-contributions straight
    /// out of the value stream ([`crate::engine::frontier`]). `parts` must
    /// be the same partitioning the layout was built with.
    pub fn in_value_slots(&self, g: &Csr, parts: &Partitions) -> Vec<usize> {
        assert_eq!(parts.count(), self.parts, "partitioning mismatch");
        let n = g.num_vertices();
        let mut map = vec![0usize; g.num_edges()];
        let mut cursor: Vec<usize> =
            (0..n).map(|v| g.in_slot_range(v as VertexId).start).collect();
        // First-encounter bookkeeping per destination partition, stamped
        // with the current source so it resets for free between vertices.
        let mut stamp = vec![VertexId::MAX; self.parts];
        let mut slot_of = vec![0usize; self.parts];
        for u in 0..n as VertexId {
            let slots = self.push_slots(u);
            let mut gi = 0usize;
            for &v in g.out_neighbors(u) {
                let slot = if self.dedup {
                    let dp = parts.owner(v);
                    if stamp[dp] != u {
                        stamp[dp] = u;
                        slot_of[dp] = slots[gi];
                        gi += 1;
                    }
                    slot_of[dp]
                } else {
                    let s = slots[gi];
                    gi += 1;
                    s
                };
                map[cursor[v as usize]] = slot;
                cursor[v as usize] += 1;
            }
            debug_assert_eq!(gi, slots.len());
        }
        map
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{synthetic, GraphBuilder};

    fn check_cover(p: &Partitions, n: usize) {
        let mut seen = vec![false; n];
        for i in 0..p.count() {
            for u in p.range(i) {
                assert!(!seen[u as usize], "vertex {u} in two partitions");
                seen[u as usize] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "not all vertices covered");
    }

    #[test]
    fn vertex_balanced_covers_and_balances() {
        let g = synthetic::cycle(103);
        let p = Partitions::new(&g, 8, PartitionPolicy::VertexBalanced);
        check_cover(&p, 103);
        let sizes: Vec<usize> = (0..8).map(|i| p.range(i).len()).collect();
        assert!(sizes.iter().all(|&s| s == 12 || s == 13), "{sizes:?}");
    }

    #[test]
    fn more_partitions_than_vertices() {
        let g = synthetic::cycle(3);
        let p = Partitions::new(&g, 8, PartitionPolicy::VertexBalanced);
        check_cover(&p, 3);
        assert_eq!(p.count(), 8); // some ranges empty, but all valid
    }

    #[test]
    fn edge_balanced_covers_all() {
        let g = synthetic::web_replica(3000, 8, 11);
        for parts in [1, 2, 4, 7, 16] {
            let p = Partitions::new(&g, parts, PartitionPolicy::EdgeBalanced);
            check_cover(&p, g.num_vertices());
        }
    }

    #[test]
    fn edge_balanced_beats_vertex_balanced_on_skew() {
        let g = synthetic::web_replica(5000, 8, 3);
        let vb = Partitions::new(&g, 8, PartitionPolicy::VertexBalanced);
        let eb = Partitions::new(&g, 8, PartitionPolicy::EdgeBalanced);
        assert!(
            eb.imbalance(&g) <= vb.imbalance(&g) + 1e-9,
            "edge-balanced {} should not exceed vertex-balanced {}",
            eb.imbalance(&g),
            vb.imbalance(&g)
        );
    }

    #[test]
    fn owner_matches_ranges() {
        let g = synthetic::cycle(50);
        let p = Partitions::new(&g, 7, PartitionPolicy::VertexBalanced);
        for i in 0..p.count() {
            for u in p.range(i) {
                assert_eq!(p.owner(u), i, "vertex {u}");
            }
        }
    }

    #[test]
    fn single_partition() {
        let g = synthetic::cycle(10);
        let p = Partitions::new(&g, 1, PartitionPolicy::EdgeBalanced);
        assert_eq!(p.range(0), 0..10);
        assert_eq!(p.imbalance(&g), 1.0);
    }

    /// Regression (degenerate inputs): an empty graph must partition, report
    /// stats, and answer ownership queries without panicking — under both
    /// policies.
    #[test]
    fn empty_graph_partitions_are_total() {
        let g = GraphBuilder::new(0).build("nil");
        for policy in [PartitionPolicy::VertexBalanced, PartitionPolicy::EdgeBalanced] {
            let p = Partitions::new(&g, 4, policy);
            assert_eq!(p.count(), 4, "{policy}");
            assert!((0..4).all(|i| p.range(i).is_empty()), "{policy}");
            assert_eq!(p.edge_loads(&g), vec![0; 4], "{policy}");
            assert_eq!(p.imbalance(&g), 1.0, "{policy}");
        }
    }

    /// Regression: edge-balanced on an edgeless graph (m = 0) used to chase
    /// a phantom edge target and pile every vertex into degenerate cuts; it
    /// must fall back to the vertex spread.
    #[test]
    fn edgeless_graph_edge_balanced_spreads_vertices() {
        let g = GraphBuilder::new(10).build("isolated");
        let p = Partitions::new(&g, 4, PartitionPolicy::EdgeBalanced);
        check_cover(&p, 10);
        let sizes: Vec<usize> = (0..4).map(|i| p.range(i).len()).collect();
        assert!(sizes.iter().all(|&s| s == 2 || s == 3), "{sizes:?}");
        assert_eq!(p.imbalance(&g), 1.0);
    }

    /// Regression: `p = 0` (a caller bug) clamps to one partition instead
    /// of panicking deep inside the stats.
    #[test]
    fn zero_partitions_clamps_to_one() {
        let g = synthetic::cycle(5);
        for policy in [PartitionPolicy::VertexBalanced, PartitionPolicy::EdgeBalanced] {
            let p = Partitions::new(&g, 0, policy);
            assert_eq!(p.count(), 1, "{policy}");
            assert_eq!(p.range(0), 0..5, "{policy}");
            assert_eq!(p.owner(3), 0, "{policy}");
            assert!(p.imbalance(&g).is_finite(), "{policy}");
        }
    }

    fn layouts(g: &Csr, parts: &Partitions) -> [CompressedBins; 2] {
        [CompressedBins::new(g, parts), CompressedBins::new_per_edge(g, parts)]
    }

    #[test]
    fn bins_tile_every_edge_exactly_once() {
        let g = synthetic::web_replica(500, 6, 13);
        for threads in [1, 2, 5] {
            let parts = Partitions::new(&g, threads, PartitionPolicy::VertexBalanced);
            for bins in layouts(&g, &parts) {
                assert_eq!(bins.num_edges(), g.num_edges());
                // the (src, dst) dst-stream ranges tile 0..m without gaps
                // or overlap, and likewise the value ranges tile 0..values
                let mut covered = vec![false; g.num_edges()];
                let mut vcovered = vec![false; bins.num_values()];
                for src in 0..bins.num_partitions() {
                    for dst in 0..bins.num_partitions() {
                        for slot in bins.dst_range(src, dst) {
                            assert!(!covered[slot], "slot {slot} in two bins");
                            covered[slot] = true;
                        }
                        for slot in bins.value_range(src, dst) {
                            assert!(!vcovered[slot], "value slot {slot} in two bins");
                            vcovered[slot] = true;
                        }
                    }
                }
                assert!(covered.iter().all(|&b| b));
                assert!(vcovered.iter().all(|&b| b));
            }
        }
    }

    #[test]
    fn group_flags_match_value_ranges() {
        let g = synthetic::social_replica(300, 5, 7);
        let parts = Partitions::new(&g, 4, PartitionPolicy::EdgeBalanced);
        for bins in layouts(&g, &parts) {
            for src in 0..4 {
                for dst in 0..4 {
                    let flags = bins
                        .entries(src, dst)
                        .iter()
                        .filter(|&&e| CompressedBins::decode(e).1)
                        .count();
                    assert_eq!(
                        flags,
                        bins.value_range(src, dst).len(),
                        "({src},{dst}): one value slot per flagged entry"
                    );
                    // a non-empty bin must start with a group flag
                    if let Some(&first) = bins.entries(src, dst).first() {
                        assert!(CompressedBins::decode(first).1, "({src},{dst})");
                    }
                }
            }
        }
    }

    #[test]
    fn bin_destinations_belong_to_the_bin_partition() {
        let g = synthetic::web_replica(400, 7, 3);
        let parts = Partitions::new(&g, 3, PartitionPolicy::VertexBalanced);
        for bins in layouts(&g, &parts) {
            for src in 0..3 {
                for dst in 0..3 {
                    for &e in bins.entries(src, dst) {
                        let (v, _) = CompressedBins::decode(e);
                        assert_eq!(parts.owner(v), dst);
                    }
                }
            }
        }
    }

    #[test]
    fn push_slots_are_a_bijection_onto_the_value_stream() {
        let g = synthetic::social_replica(300, 5, 7);
        let parts = Partitions::new(&g, 4, PartitionPolicy::EdgeBalanced);
        for bins in layouts(&g, &parts) {
            let mut seen = vec![false; bins.num_values()];
            for u in 0..g.num_vertices() as VertexId {
                let slots = bins.push_slots(u);
                if bins.is_deduped() {
                    // one slot per distinct destination partition
                    let mut dps: Vec<usize> =
                        g.out_neighbors(u).iter().map(|&v| parts.owner(v)).collect();
                    dps.sort_unstable();
                    dps.dedup();
                    assert_eq!(slots.len(), dps.len(), "vertex {u}");
                } else {
                    assert_eq!(slots.len(), g.out_degree(u), "vertex {u}");
                }
                for (k, &slot) in slots.iter().enumerate() {
                    assert!(!seen[slot], "value slot {slot} claimed twice (u={u}, k={k})");
                    seen[slot] = true;
                    // the slot lies in one of u's (owner(u), *) bins
                    let src = parts.owner(u);
                    let owned = (0..bins.num_partitions())
                        .any(|dst| bins.value_range(src, dst).contains(&slot));
                    assert!(owned, "vertex {u} slot {slot} outside its source row");
                }
            }
            assert!(seen.iter().all(|&b| b));
        }
    }

    #[test]
    fn per_edge_layout_has_one_value_per_edge_and_dedup_no_more() {
        let g = synthetic::web_replica(400, 6, 29);
        let parts = Partitions::new(&g, 4, PartitionPolicy::VertexBalanced);
        let compressed = CompressedBins::new(&g, &parts);
        let per_edge = CompressedBins::new_per_edge(&g, &parts);
        assert_eq!(per_edge.num_values(), g.num_edges());
        assert!(compressed.num_values() <= per_edge.num_values());
        // on a multi-edge-per-partition graph the dedup must actually bite
        let distinct: usize = (0..g.num_vertices() as VertexId)
            .map(|u| {
                let mut dps: Vec<usize> =
                    g.out_neighbors(u).iter().map(|&v| parts.owner(v)).collect();
                dps.sort_unstable();
                dps.dedup();
                dps.len()
            })
            .sum();
        assert_eq!(compressed.num_values(), distinct);
    }

    /// Replaying scatter + gather through the streams must reproduce the
    /// vertex-centric pull sums exactly — for both layouts.
    #[test]
    fn stream_replay_matches_pull_sums() {
        let g = synthetic::web_replica(400, 6, 29);
        for threads in [1, 3, 4] {
            let parts = Partitions::new(&g, threads, PartitionPolicy::VertexBalanced);
            for bins in layouts(&g, &parts) {
                // scatter: vertex u contributes (u+1) to each of its slots
                let mut values = vec![0.0f64; bins.num_values()];
                for u in 0..g.num_vertices() as VertexId {
                    for &slot in bins.push_slots(u) {
                        values[slot] = (u + 1) as f64;
                    }
                }
                // gather: replay every bin into an accumulator
                let mut acc = vec![0.0f64; g.num_vertices()];
                let p = bins.num_partitions();
                for dst in 0..p {
                    for src in 0..p {
                        let vr = bins.value_range(src, dst);
                        let mut vi = vr.start;
                        let mut val = 0.0;
                        for &e in bins.entries(src, dst) {
                            let (v, fresh) = CompressedBins::decode(e);
                            if fresh {
                                val = values[vi];
                                vi += 1;
                            }
                            acc[v as usize] += val;
                        }
                        assert_eq!(vi, vr.end, "bin ({src},{dst}) value walk");
                    }
                }
                // reference: direct pull over in-neighbours
                for v in 0..g.num_vertices() as VertexId {
                    let want: f64 =
                        g.in_neighbors(v).iter().map(|&u| (u + 1) as f64).sum();
                    assert_eq!(acc[v as usize], want, "vertex {v}");
                }
            }
        }
    }

    #[test]
    fn in_value_slots_land_on_the_sources_slot() {
        let g = synthetic::web_replica(400, 6, 29);
        for threads in [1, 3, 4] {
            let parts = Partitions::new(&g, threads, PartitionPolicy::VertexBalanced);
            for bins in layouts(&g, &parts) {
                let map = bins.in_value_slots(&g, &parts);
                assert_eq!(map.len(), g.num_edges());
                // scatter a recognizable value per source, then check every
                // vertex's in-slots read back exactly its in-neighbours
                let mut values = vec![0.0f64; bins.num_values()];
                for u in 0..g.num_vertices() as VertexId {
                    for &slot in bins.push_slots(u) {
                        values[slot] = (u + 1) as f64;
                    }
                }
                for v in 0..g.num_vertices() as VertexId {
                    for (s, &u) in g.in_slot_range(v).zip(g.in_neighbors(v)) {
                        assert_eq!(
                            values[map[s]],
                            (u + 1) as f64,
                            "in-slot {s} of vertex {v}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn bins_within_a_pair_preserve_source_order() {
        // The bit-exactness contract with the vertex-centric pull: entries
        // in one (src, dst) bin follow ascending source order. Recover each
        // entry's source by replaying the group walk against push_slots.
        let g = synthetic::social_replica(200, 6, 21);
        let parts = Partitions::new(&g, 3, PartitionPolicy::VertexBalanced);
        for bins in layouts(&g, &parts) {
            // value slot -> source vertex
            let mut slot_src = vec![0 as VertexId; bins.num_values()];
            for u in 0..g.num_vertices() as VertexId {
                for &slot in bins.push_slots(u) {
                    slot_src[slot] = u;
                }
            }
            for src in 0..3 {
                for dst in 0..3 {
                    let vr = bins.value_range(src, dst);
                    let mut vi = vr.start;
                    let mut cur = None;
                    let mut last: Option<VertexId> = None;
                    for &e in bins.entries(src, dst) {
                        let (_, fresh) = CompressedBins::decode(e);
                        if fresh {
                            cur = Some(slot_src[vi]);
                            vi += 1;
                        }
                        let s = cur.expect("bin starts with a group flag");
                        if let Some(prev) = last {
                            assert!(prev <= s, "({src},{dst}) unsorted: {prev} > {s}");
                        }
                        last = Some(s);
                    }
                }
            }
        }
    }
}
