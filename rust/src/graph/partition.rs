//! Static load allocation: split the vertex set into `p` contiguous
//! partitions, one per thread (paper §4.1: "vertices are divided into p
//! equal-sized partitions … static load allocation").
//!
//! Two policies:
//! * [`PartitionPolicy::VertexBalanced`] — the paper's scheme: equal vertex
//!   counts regardless of degree.
//! * [`PartitionPolicy::EdgeBalanced`] — equal *work* (in-edges), which the
//!   ablation bench (`benches/ablation.rs`) compares against; on skewed
//!   graphs this is what keeps barrier variants from being dragged down by
//!   one heavy partition.

use crate::graph::{Csr, VertexId};

/// How to split the vertex set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionPolicy {
    VertexBalanced,
    EdgeBalanced,
}

impl std::fmt::Display for PartitionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PartitionPolicy::VertexBalanced => f.write_str("vertex-balanced"),
            PartitionPolicy::EdgeBalanced => f.write_str("edge-balanced"),
        }
    }
}

/// The result: `p` contiguous half-open vertex ranges covering `0..n`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partitions {
    bounds: Vec<usize>, // len p+1, bounds[0]=0, bounds[p]=n
    pub policy: PartitionPolicy,
}

impl Partitions {
    /// Partition `g` into `p` ranges under `policy`.
    pub fn new(g: &Csr, p: usize, policy: PartitionPolicy) -> Self {
        assert!(p > 0, "need at least one partition");
        let n = g.num_vertices();
        let mut bounds = Vec::with_capacity(p + 1);
        match policy {
            PartitionPolicy::VertexBalanced => {
                // ceil-spread: first (n % p) parts get one extra vertex
                bounds.push(0);
                let base = n / p;
                let extra = n % p;
                let mut at = 0;
                for i in 0..p {
                    at += base + usize::from(i < extra);
                    bounds.push(at);
                }
            }
            PartitionPolicy::EdgeBalanced => {
                // Greedy prefix cut at ~m/p in-edges per part. The pull-
                // direction work of vertex u is its in-degree.
                let m = g.num_edges();
                let target = (m as f64 / p as f64).max(1.0);
                bounds.push(0);
                let mut acc = 0usize;
                let mut cuts_made = 0usize;
                for u in 0..n {
                    acc += g.in_degree(u as VertexId);
                    // leave enough vertices for remaining cuts
                    let remaining_cuts = p - 1 - cuts_made;
                    let remaining_vertices = n - (u + 1);
                    if cuts_made < p - 1
                        && (acc as f64 >= target * (cuts_made + 1) as f64
                            || remaining_vertices == remaining_cuts)
                    {
                        bounds.push(u + 1);
                        cuts_made += 1;
                    }
                }
                while bounds.len() < p {
                    bounds.push(n);
                }
                bounds.push(n);
            }
        }
        debug_assert_eq!(bounds.len(), p + 1);
        Self { bounds, policy }
    }

    pub fn count(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Vertex range of partition `i`.
    pub fn range(&self, i: usize) -> std::ops::Range<VertexId> {
        self.bounds[i] as VertexId..self.bounds[i + 1] as VertexId
    }

    /// Which partition owns vertex `u` (binary search).
    pub fn owner(&self, u: VertexId) -> usize {
        match self.bounds.binary_search(&(u as usize)) {
            Ok(i) => i.min(self.count() - 1),
            Err(i) => i - 1,
        }
    }

    /// In-edge work per partition (for imbalance reporting).
    pub fn edge_loads(&self, g: &Csr) -> Vec<usize> {
        (0..self.count())
            .map(|i| self.range(i).map(|u| g.in_degree(u)).sum())
            .collect()
    }

    /// max/mean edge-load imbalance factor (1.0 = perfect).
    pub fn imbalance(&self, g: &Csr) -> f64 {
        let loads = self.edge_loads(g);
        let max = *loads.iter().max().unwrap() as f64;
        let mean = loads.iter().sum::<usize>() as f64 / loads.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// Update-bin layout for partition-centric scatter-gather (PCPM).
///
/// Groups every edge `(u → v)` by `(source partition, destination
/// partition)`. The scatter phase of [`crate::engine::pcpm`] streams a
/// thread's contributions into its own row of bins (sequential writes per
/// bin); the gather phase merges exactly the column of bins destined for its
/// partition (sequential reads, partition-local accumulator writes).
///
/// Within one `(src, dst)` bin, slots follow ascending source-vertex order —
/// the same order the stable counting sort gives `Csr::in_neighbors` — so a
/// PCPM gather accumulates bit-identically to the vertex-centric pull.
#[derive(Debug, Clone)]
pub struct PartitionBins {
    parts: usize,
    /// `bin_ranges[src * parts + dst]` — slot range of that bin.
    bin_ranges: Vec<std::ops::Range<usize>>,
    /// Destination vertex per bin slot.
    bin_dst: Vec<VertexId>,
    /// Out-edge index (into `Csr::out_edges` order) → bin slot.
    scatter_slots: Vec<usize>,
}

impl PartitionBins {
    /// Compute the bin layout of `g` under `parts`. O(m log p) (one owner
    /// lookup per edge), done once per run.
    pub fn new(g: &Csr, parts: &Partitions) -> Self {
        let p = parts.count();
        let m = g.num_edges();
        let mut counts = vec![0usize; p * p];
        for src_part in 0..p {
            for u in parts.range(src_part) {
                for &v in g.out_neighbors(u) {
                    counts[src_part * p + parts.owner(v)] += 1;
                }
            }
        }
        let mut starts = vec![0usize; p * p + 1];
        for i in 0..p * p {
            starts[i + 1] = starts[i] + counts[i];
        }
        let bin_ranges: Vec<std::ops::Range<usize>> =
            (0..p * p).map(|i| starts[i]..starts[i + 1]).collect();
        let mut cursor: Vec<usize> = starts[..p * p].to_vec();
        let mut bin_dst = vec![0 as VertexId; m];
        let mut scatter_slots = vec![0usize; m];
        for src_part in 0..p {
            for u in parts.range(src_part) {
                for e in g.out_slot_range(u) {
                    let v = g.out_edges[e];
                    let key = src_part * p + parts.owner(v);
                    let slot = cursor[key];
                    cursor[key] += 1;
                    bin_dst[slot] = v;
                    scatter_slots[e] = slot;
                }
            }
        }
        Self { parts: p, bin_ranges, bin_dst, scatter_slots }
    }

    pub fn num_partitions(&self) -> usize {
        self.parts
    }

    /// Total bin slots (= number of edges).
    pub fn num_slots(&self) -> usize {
        self.bin_dst.len()
    }

    /// Slot range of the `(src, dst)` bin.
    pub fn range(&self, src: usize, dst: usize) -> std::ops::Range<usize> {
        self.bin_ranges[src * self.parts + dst].clone()
    }

    /// Destination vertex of a bin slot.
    #[inline]
    pub fn dst(&self, slot: usize) -> VertexId {
        self.bin_dst[slot]
    }

    /// Bin slot written by out-edge `e` (an index into `Csr::out_edges`).
    #[inline]
    pub fn scatter_slot(&self, e: usize) -> usize {
        self.scatter_slots[e]
    }

    /// For each in-edge slot of the CSR (the pull-direction edge array),
    /// the bin slot its source vertex scatters into — this is what lets a
    /// frontier gather read one vertex's in-contributions straight out of
    /// the bins ([`crate::engine::frontier`]). The cursor walk pairs each
    /// of `v`'s in-slots with exactly one out-edge targeting `v`: a
    /// bijection, which is all a gather *sum* needs (order-independent).
    pub fn in_gather_slots(&self, g: &Csr) -> Vec<usize> {
        let n = g.num_vertices();
        let mut map = vec![0usize; g.num_edges()];
        let mut cursor: Vec<usize> =
            (0..n).map(|v| g.in_slot_range(v as VertexId).start).collect();
        for u in 0..n as VertexId {
            for e in g.out_slot_range(u) {
                let v = g.out_edges[e] as usize;
                map[cursor[v]] = self.scatter_slot(e);
                cursor[v] += 1;
            }
        }
        map
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic;

    fn check_cover(p: &Partitions, n: usize) {
        let mut seen = vec![false; n];
        for i in 0..p.count() {
            for u in p.range(i) {
                assert!(!seen[u as usize], "vertex {u} in two partitions");
                seen[u as usize] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "not all vertices covered");
    }

    #[test]
    fn vertex_balanced_covers_and_balances() {
        let g = synthetic::cycle(103);
        let p = Partitions::new(&g, 8, PartitionPolicy::VertexBalanced);
        check_cover(&p, 103);
        let sizes: Vec<usize> = (0..8).map(|i| p.range(i).len()).collect();
        assert!(sizes.iter().all(|&s| s == 12 || s == 13), "{sizes:?}");
    }

    #[test]
    fn more_partitions_than_vertices() {
        let g = synthetic::cycle(3);
        let p = Partitions::new(&g, 8, PartitionPolicy::VertexBalanced);
        check_cover(&p, 3);
        assert_eq!(p.count(), 8); // some ranges empty, but all valid
    }

    #[test]
    fn edge_balanced_covers_all() {
        let g = synthetic::web_replica(3000, 8, 11);
        for parts in [1, 2, 4, 7, 16] {
            let p = Partitions::new(&g, parts, PartitionPolicy::EdgeBalanced);
            check_cover(&p, g.num_vertices());
        }
    }

    #[test]
    fn edge_balanced_beats_vertex_balanced_on_skew() {
        let g = synthetic::web_replica(5000, 8, 3);
        let vb = Partitions::new(&g, 8, PartitionPolicy::VertexBalanced);
        let eb = Partitions::new(&g, 8, PartitionPolicy::EdgeBalanced);
        assert!(
            eb.imbalance(&g) <= vb.imbalance(&g) + 1e-9,
            "edge-balanced {} should not exceed vertex-balanced {}",
            eb.imbalance(&g),
            vb.imbalance(&g)
        );
    }

    #[test]
    fn owner_matches_ranges() {
        let g = synthetic::cycle(50);
        let p = Partitions::new(&g, 7, PartitionPolicy::VertexBalanced);
        for i in 0..p.count() {
            for u in p.range(i) {
                assert_eq!(p.owner(u), i, "vertex {u}");
            }
        }
    }

    #[test]
    fn single_partition() {
        let g = synthetic::cycle(10);
        let p = Partitions::new(&g, 1, PartitionPolicy::EdgeBalanced);
        assert_eq!(p.range(0), 0..10);
        assert_eq!(p.imbalance(&g), 1.0);
    }

    #[test]
    fn bins_cover_every_edge_exactly_once() {
        let g = synthetic::web_replica(500, 6, 13);
        for threads in [1, 2, 5] {
            let parts = Partitions::new(&g, threads, PartitionPolicy::VertexBalanced);
            let bins = PartitionBins::new(&g, &parts);
            assert_eq!(bins.num_slots(), g.num_edges());
            // the (src, dst) ranges tile 0..m without gaps or overlap
            let mut covered = vec![false; g.num_edges()];
            for src in 0..bins.num_partitions() {
                for dst in 0..bins.num_partitions() {
                    for slot in bins.range(src, dst) {
                        assert!(!covered[slot], "slot {slot} in two bins");
                        covered[slot] = true;
                    }
                }
            }
            assert!(covered.iter().all(|&b| b));
        }
    }

    #[test]
    fn scatter_slots_are_a_bijection_onto_the_right_bins() {
        let g = synthetic::social_replica(300, 5, 7);
        let parts = Partitions::new(&g, 4, PartitionPolicy::EdgeBalanced);
        let bins = PartitionBins::new(&g, &parts);
        let mut seen = vec![false; bins.num_slots()];
        for u in 0..g.num_vertices() as VertexId {
            let src_part = parts.owner(u);
            for e in g.out_slot_range(u) {
                let slot = bins.scatter_slot(e);
                assert!(!seen[slot], "slot {slot} claimed twice");
                seen[slot] = true;
                let v = g.out_edges[e];
                assert_eq!(bins.dst(slot), v);
                // the slot lies in the (owner(u), owner(v)) bin
                let r = bins.range(src_part, parts.owner(v));
                assert!(r.contains(&slot), "edge {u}->{v} slot {slot} outside {r:?}");
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn bin_destinations_belong_to_the_bin_partition() {
        let g = synthetic::web_replica(400, 7, 3);
        let parts = Partitions::new(&g, 3, PartitionPolicy::VertexBalanced);
        let bins = PartitionBins::new(&g, &parts);
        for src in 0..3 {
            for dst in 0..3 {
                for slot in bins.range(src, dst) {
                    assert_eq!(parts.owner(bins.dst(slot)), dst);
                }
            }
        }
    }

    #[test]
    fn in_gather_slots_is_a_bijection_landing_on_own_destination() {
        let g = synthetic::web_replica(400, 6, 29);
        for threads in [1, 3, 4] {
            let parts = Partitions::new(&g, threads, PartitionPolicy::VertexBalanced);
            let bins = PartitionBins::new(&g, &parts);
            let map = bins.in_gather_slots(&g);
            assert_eq!(map.len(), g.num_edges());
            // bijection onto the bin slots
            let mut seen = vec![false; bins.num_slots()];
            for &slot in &map {
                assert!(!seen[slot], "bin slot {slot} mapped twice");
                seen[slot] = true;
            }
            assert!(seen.iter().all(|&b| b));
            // each vertex's in-slots map to slots whose destination is it
            for v in 0..g.num_vertices() as VertexId {
                for s in g.in_slot_range(v) {
                    assert_eq!(bins.dst(map[s]), v, "in-slot {s} of vertex {v}");
                }
            }
        }
    }

    #[test]
    fn bins_within_a_pair_preserve_source_order() {
        // The bit-exactness contract with the vertex-centric pull: slots in
        // one (src, dst) bin follow ascending source order.
        let g = synthetic::social_replica(200, 6, 21);
        let parts = Partitions::new(&g, 3, PartitionPolicy::VertexBalanced);
        let bins = PartitionBins::new(&g, &parts);
        // reconstruct source of each slot
        let mut slot_src = vec![0 as VertexId; bins.num_slots()];
        for u in 0..g.num_vertices() as VertexId {
            for e in g.out_slot_range(u) {
                slot_src[bins.scatter_slot(e)] = u;
            }
        }
        for src in 0..3 {
            for dst in 0..3 {
                let srcs: Vec<VertexId> =
                    bins.range(src, dst).map(|s| slot_src[s]).collect();
                assert!(srcs.windows(2).all(|w| w[0] <= w[1]), "({src},{dst}) unsorted");
            }
        }
    }
}
