//! Dataset I/O.
//!
//! * [`load_edge_list`] reads the SNAP plain-text edge-list format the
//!   paper's Table-1 datasets ship in (`# comment` headers, one
//!   whitespace-separated `src dst` pair per line, arbitrary vertex ids that
//!   get densified).
//! * [`load_adjacency`] reads the adjacency-list format of [21]
//!   (`u k v1 … vk` per line, optional `n m` header).
//! * [`save_binary`] / [`load_binary`] / [`map_binary`] provide the **v2**
//!   binary cache: a 32-byte header (`PRNBCSR2`, name length, `n`, `m`),
//!   the dataset name, then the five CSR arrays as little-endian sections
//!   each starting on a 64-byte boundary. Offset arrays are stored as
//!   `u64`, edge arrays as `u32`. Because every section offset — and hence
//!   the exact file size — is a pure function of the three header counts,
//!   a single length check both rejects every truncated/corrupt prefix
//!   cleanly *and* caps all allocations by the real file size before any
//!   happen. The 64-byte section alignment is what makes [`map_binary`]
//!   possible: the sections are reinterpreted in place from a page-aligned
//!   memory map, giving a zero-copy [`Csr`] whose arrays the OS pages in on
//!   demand — the storage layer of the out-of-core path
//!   ([`crate::engine::ooc`]).
//!
//! v1 caches (`PRNBCSR1`: unaligned, allocation-unsafe header) are detected
//! and rejected with a migration hint — regenerate with `pagerank-nb gen`
//! or re-save through [`save_binary`].

use crate::graph::csr::GraphStore;
use crate::graph::{Csr, GraphBuilder, VertexId};
use anyhow::{anyhow, bail, ensure, Context, Result};
use mmap_lite::Mmap;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Arc;

/// Densify the next raw id: the dense id equals the number already
/// assigned. Guards the `u32` vertex-id space — more than
/// [`VertexId::MAX`] distinct raw ids would otherwise silently wrap and
/// alias distinct vertices.
fn next_dense_id(assigned: usize) -> Result<VertexId> {
    ensure!(
        assigned < VertexId::MAX as usize,
        "edge list has more than {} distinct vertex ids — vertex ids are u32, \
         so densifying further would overflow and alias vertices",
        VertexId::MAX
    );
    Ok(assigned as VertexId)
}

/// Load a SNAP-style edge list. Vertex ids are densified (SNAP files skip
/// ids); duplicate edges and self-loops are removed to match the paper's
/// simple-graph preprocessing. Fails cleanly when the file names more than
/// `u32::MAX` distinct vertices.
pub fn load_edge_list(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening edge list {}", path.display()))?;
    let reader = BufReader::new(f);
    let mut remap: HashMap<u64, VertexId> = HashMap::new();
    let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
    let mut densify = |raw: u64| -> Result<VertexId> {
        if let Some(&id) = remap.get(&raw) {
            return Ok(id);
        }
        let id = next_dense_id(remap.len())?;
        remap.insert(raw, id);
        Ok(id)
    };
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (a, b) = match (it.next(), it.next()) {
            (Some(a), Some(b)) => (a, b),
            _ => bail!("line {}: expected `src dst`", lineno + 1),
        };
        let u: u64 = a.parse().with_context(|| format!("line {}: bad src", lineno + 1))?;
        let v: u64 = b.parse().with_context(|| format!("line {}: bad dst", lineno + 1))?;
        let u = densify(u)?;
        let v = densify(v)?;
        edges.push((u, v));
    }
    let n = remap.len();
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "edge-list".into());
    Ok(GraphBuilder::new(n).dedup(true).edges(&edges).build(&name))
}

/// Load the adjacency-list format of Luo & Liu [21]: each line
/// `u k v1 v2 … vk` lists `u`'s out-neighbours; the first content line may
/// be an `n m` header.
///
/// Header disambiguation: a 2-token first line `a b` is ambiguous between
/// the header `n m` and a degree-0 vertex line `u 0`. When `b == 0` it is
/// read as the vertex line — a data interpretation never silently drops a
/// vertex, which the old always-a-header rule did. When `b > 0` a data
/// reading would be malformed (degree `b` with zero neighbours listed), so
/// it must be the header — and it is then verified against the parsed
/// file: the declared edge count must match and every named vertex must
/// fall below the declared `n`, otherwise the load fails instead of
/// guessing.
pub fn load_adjacency(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening adjacency list {}", path.display()))?;
    let reader = BufReader::new(f);
    let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
    let mut max_v: u64 = 0;
    let mut saw_vertex = false;
    let mut first_content = true;
    let mut header: Option<(u64, u64)> = None;
    let mut declared_edges: u64 = 0;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let nums: Vec<u64> = line
            .split_whitespace()
            .map(|t| t.parse::<u64>())
            .collect::<std::result::Result<_, _>>()
            .with_context(|| format!("line {}: non-numeric token", lineno + 1))?;
        if std::mem::take(&mut first_content) && nums.len() == 2 && nums[1] > 0 {
            // see the doc comment: `a b` with b > 0 can only be the header
            header = Some((nums[0], nums[1]));
            continue;
        }
        let u = nums[0];
        max_v = max_v.max(u);
        saw_vertex = true;
        let k = if nums.len() >= 2 { nums[1] as usize } else { 0 };
        if nums.len() != k + 2 {
            bail!(
                "line {}: declared degree {} but {} listed",
                lineno + 1,
                k,
                nums.len().saturating_sub(2)
            );
        }
        declared_edges += k as u64;
        for &v in &nums[2..] {
            max_v = max_v.max(v);
            edges.push((u as VertexId, v as VertexId));
        }
    }
    let mut n = if saw_vertex { max_v + 1 } else { 0 };
    if let Some((hn, hm)) = header {
        ensure!(
            hm == declared_edges,
            "header declares {hm} edges but the file lists {declared_edges} — \
             either the header is wrong or the first line was a malformed vertex line"
        );
        ensure!(
            hn >= n,
            "header declares {hn} vertices but the file names vertex {max_v}"
        );
        n = hn; // the header may declare trailing isolated vertices
    }
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "adjacency".into());
    Ok(GraphBuilder::new(n as usize).dedup(true).edges(&edges).build(&name))
}

/// v2 binary cache magic (current format; 64-byte-aligned sections).
const MAGIC_V2: &[u8; 8] = b"PRNBCSR2";
/// v1 magic — recognized only to produce the migration error.
const MAGIC_V1: &[u8; 8] = b"PRNBCSR1";
/// Fixed header: magic + `name_len` + `n` + `m`, all `u64` LE.
const HEADER_BYTES: u64 = 32;
/// Every array section starts on this boundary, so a page-aligned map can
/// reinterpret the section bytes in place for any element type used.
const SECTION_ALIGN: u64 = 64;

/// One section's placement inside a v2 file.
#[derive(Debug, Clone, Copy)]
struct Span {
    /// Byte offset of the section start (64-byte aligned).
    at: u64,
    /// Element count.
    elems: u64,
}

/// Byte layout of a v2 file — a pure function of the header counts, so the
/// expected total size is known before touching anything past the header.
#[derive(Debug, Clone, Copy)]
struct V2Layout {
    out_offsets: Span,
    out_edges: Span,
    in_offsets: Span,
    in_edges: Span,
    offset_list: Span,
    /// Exact file size in bytes.
    total: u64,
}

fn align_up(x: u64) -> Result<u64> {
    x.checked_add(SECTION_ALIGN - 1)
        .map(|y| y & !(SECTION_ALIGN - 1))
        .ok_or_else(|| anyhow!("binary graph layout overflows u64"))
}

fn v2_layout(name_len: u64, n: u64, m: u64) -> Result<V2Layout> {
    let overflow =
        || anyhow!("binary graph header counts overflow (name_len {name_len}, n {n}, m {m})");
    let offsets_elems = n.checked_add(1).ok_or_else(overflow)?;
    let offsets_bytes = offsets_elems.checked_mul(8).ok_or_else(overflow)?;
    let edges_bytes_u32 = m.checked_mul(4).ok_or_else(overflow)?;
    let edges_bytes_u64 = m.checked_mul(8).ok_or_else(overflow)?;
    let mut at = align_up(HEADER_BYTES.checked_add(name_len).ok_or_else(overflow)?)?;
    let out_offsets = Span { at, elems: offsets_elems };
    at = align_up(at.checked_add(offsets_bytes).ok_or_else(overflow)?)?;
    let out_edges = Span { at, elems: m };
    at = align_up(at.checked_add(edges_bytes_u32).ok_or_else(overflow)?)?;
    let in_offsets = Span { at, elems: offsets_elems };
    at = align_up(at.checked_add(offsets_bytes).ok_or_else(overflow)?)?;
    let in_edges = Span { at, elems: m };
    at = align_up(at.checked_add(edges_bytes_u32).ok_or_else(overflow)?)?;
    let offset_list = Span { at, elems: m };
    let total = at.checked_add(edges_bytes_u64).ok_or_else(overflow)?;
    Ok(V2Layout { out_offsets, out_edges, in_offsets, in_edges, offset_list, total })
}

/// Parsed v2 header counts plus the derived layout, checked against the
/// actual file length — the single gate that both rejects every truncated
/// prefix and bounds all subsequent allocations.
struct V2Header {
    name_len: usize,
    n: usize,
    m: usize,
    layout: V2Layout,
}

fn parse_v2_header(header: &[u8; 32], file_len: u64, what: &Path) -> Result<V2Header> {
    let magic = &header[0..8];
    if magic == MAGIC_V1 {
        bail!(
            "{}: v1 binary cache (PRNBCSR1) is no longer supported — \
             regenerate it with `pagerank-nb gen` or re-save the graph \
             (save_binary now writes the 64-byte-aligned v2 format)",
            what.display()
        );
    }
    if magic != MAGIC_V2 {
        bail!("{}: not a pagerank-nb binary graph", what.display());
    }
    let word = |i: usize| u64::from_le_bytes(header[8 * i..8 * i + 8].try_into().unwrap());
    let (name_len, n, m) = (word(1), word(2), word(3));
    let layout = v2_layout(name_len, n, m)?;
    ensure!(
        layout.total == file_len,
        "{}: binary graph truncated or corrupt — header (n {n}, m {m}, \
         name {name_len}B) implies exactly {} bytes, file has {file_len}",
        what.display(),
        layout.total
    );
    // file_len fits usize on every supported target once this passes; the
    // casts below are bounded by it, so no count can demand an allocation
    // beyond what the file actually contains.
    let fits = |x: u64| -> Result<usize> {
        usize::try_from(x).map_err(|_| {
            anyhow!("{}: graph exceeds this platform's address space", what.display())
        })
    };
    Ok(V2Header { name_len: fits(name_len)?, n: fits(n)?, m: fits(m)?, layout })
}

/// Write the v2 binary cache format.
pub fn save_binary(g: &Csr, path: &Path) -> Result<()> {
    let name = g.name.as_bytes();
    let layout = v2_layout(name.len() as u64, g.num_vertices() as u64, g.num_edges() as u64)?;
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC_V2)?;
    w.write_all(&(name.len() as u64).to_le_bytes())?;
    w.write_all(&(g.num_vertices() as u64).to_le_bytes())?;
    w.write_all(&(g.num_edges() as u64).to_le_bytes())?;
    w.write_all(name)?;
    let mut written = HEADER_BYTES + name.len() as u64;
    let mut pad_to = |w: &mut BufWriter<std::fs::File>, written: &mut u64, at: u64| -> Result<()> {
        debug_assert!(at >= *written);
        for _ in *written..at {
            w.write_all(&[0u8])?;
        }
        *written = at;
        Ok(())
    };
    pad_to(&mut w, &mut written, layout.out_offsets.at)?;
    written += write_usizes(&mut w, &g.out_offsets)?;
    pad_to(&mut w, &mut written, layout.out_edges.at)?;
    written += write_u32s(&mut w, &g.out_edges)?;
    pad_to(&mut w, &mut written, layout.in_offsets.at)?;
    written += write_usizes(&mut w, &g.in_offsets)?;
    pad_to(&mut w, &mut written, layout.in_edges.at)?;
    written += write_u32s(&mut w, &g.in_edges)?;
    pad_to(&mut w, &mut written, layout.offset_list.at)?;
    written += write_usizes(&mut w, &g.offset_list)?;
    debug_assert_eq!(written, layout.total);
    w.flush()?;
    Ok(())
}

/// Read the v2 binary cache into an owned (heap-resident) [`Csr`],
/// validating the result. Truncated or corrupt files fail cleanly: the
/// header-implied size must match the file exactly before anything is
/// allocated or parsed.
pub fn load_binary(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let file_len = f.metadata()?.len();
    let mut r = BufReader::new(f);
    let mut header = [0u8; 32];
    r.read_exact(&mut header)
        .with_context(|| format!("{}: binary graph shorter than its header", path.display()))?;
    let h = parse_v2_header(&header, file_len, path)?;
    let mut name_bytes = vec![0u8; h.name_len];
    r.read_exact(&mut name_bytes)?;
    let name = String::from_utf8(name_bytes).context("graph name not utf-8")?;
    let out_offsets = read_usizes_at(&mut r, h.layout.out_offsets)?;
    let out_edges = read_u32s_at(&mut r, h.layout.out_edges)?;
    let in_offsets = read_usizes_at(&mut r, h.layout.in_offsets)?;
    let in_edges = read_u32s_at(&mut r, h.layout.in_edges)?;
    let offset_list = read_usizes_at(&mut r, h.layout.offset_list)?;
    // from_stores + explicit validate (not from_parts): the data is
    // untrusted, so corruption must surface as this error on every build
    // profile, never as a debug assertion.
    let g = Csr::from_stores(
        h.n,
        out_offsets.into(),
        out_edges.into(),
        in_offsets.into(),
        in_edges.into(),
        offset_list.into(),
        name,
    );
    g.validate()
        .map_err(|e| anyhow!("{}: corrupt binary graph: {e}", path.display()))?;
    Ok(g)
}

/// Memory-map the v2 binary cache and return a zero-copy [`Csr`] whose five
/// arrays alias the mapped sections — the OS pages them in on demand, so
/// graphs larger than RAM stay runnable ([`crate::engine::ooc`]).
///
/// The mapped graph passes the same full [`Csr::validate`] as the owned
/// loader before it is returned: kernels index the CSR with unchecked
/// loads on the strength of that check, so it must hold for on-disk bytes
/// too (the validation scan is sequential and streams cleanly through the
/// page cache).
///
/// Requires a 64-bit little-endian host — the on-disk sections are LE
/// `u64`/`u32` reinterpreted in place.
pub fn map_binary(path: &Path) -> Result<Csr> {
    ensure!(
        cfg!(target_endian = "little") && std::mem::size_of::<usize>() == 8,
        "mmap-backed graph storage requires a 64-bit little-endian host \
         (the v2 sections are reinterpreted in place); use the owned loader"
    );
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let map = Arc::new(
        Mmap::map(&f).with_context(|| format!("memory-mapping {}", path.display()))?,
    );
    let bytes: &[u8] = &map;
    ensure!(
        bytes.len() >= HEADER_BYTES as usize,
        "{}: binary graph shorter than its header",
        path.display()
    );
    let header: [u8; 32] = bytes[..32].try_into().expect("length checked");
    let h = parse_v2_header(&header, bytes.len() as u64, path)?;
    let name = String::from_utf8(bytes[32..32 + h.name_len].to_vec())
        .context("graph name not utf-8")?;
    let store_usize = |s: Span| -> Result<GraphStore<usize>> {
        GraphStore::mapped(Arc::clone(&map), s.at as usize, s.elems as usize)
            .map_err(anyhow::Error::msg)
    };
    let store_u32 = |s: Span| -> Result<GraphStore<VertexId>> {
        GraphStore::mapped(Arc::clone(&map), s.at as usize, s.elems as usize)
            .map_err(anyhow::Error::msg)
    };
    let g = Csr::from_stores(
        h.n,
        store_usize(h.layout.out_offsets)?,
        store_u32(h.layout.out_edges)?,
        store_usize(h.layout.in_offsets)?,
        store_u32(h.layout.in_edges)?,
        store_usize(h.layout.offset_list)?,
        name,
    );
    g.validate()
        .map_err(|e| anyhow!("{}: corrupt binary graph: {e}", path.display()))?;
    Ok(g)
}

fn write_usizes<W: Write>(w: &mut W, xs: &[usize]) -> Result<u64> {
    for &x in xs {
        w.write_all(&(x as u64).to_le_bytes())?;
    }
    Ok(xs.len() as u64 * 8)
}

fn write_u32s<W: Write>(w: &mut W, xs: &[u32]) -> Result<u64> {
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(xs.len() as u64 * 4)
}

fn read_usizes_at<R: Read + Seek>(r: &mut R, span: Span) -> Result<Vec<usize>> {
    r.seek(SeekFrom::Start(span.at))?;
    // the count was already bounded by the exact-file-size check
    let mut out = Vec::with_capacity(span.elems as usize);
    let mut b = [0u8; 8];
    for _ in 0..span.elems {
        r.read_exact(&mut b)?;
        out.push(u64::from_le_bytes(b) as usize);
    }
    Ok(out)
}

fn read_u32s_at<R: Read + Seek>(r: &mut R, span: Span) -> Result<Vec<u32>> {
    r.seek(SeekFrom::Start(span.at))?;
    let mut out = Vec::with_capacity(span.elems as usize);
    let mut b = [0u8; 4];
    for _ in 0..span.elems {
        r.read_exact(&mut b)?;
        out.push(u32::from_le_bytes(b));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("pagerank_nb_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn edge_list_roundtrip_with_comments_and_gaps() {
        let p = tmpfile("snap.txt");
        std::fs::write(
            &p,
            "# Directed graph\n# FromNodeId ToNodeId\n10 20\n20 30\n30 10\n10 30\n\n",
        )
        .unwrap();
        let g = load_edge_list(&p).unwrap();
        assert_eq!(g.num_vertices(), 3); // ids densified
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.validate(), Ok(()));
    }

    #[test]
    fn edge_list_dedups() {
        let p = tmpfile("dups.txt");
        std::fs::write(&p, "0 1\n0 1\n1 1\n").unwrap();
        let g = load_edge_list(&p).unwrap();
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn edge_list_rejects_garbage() {
        let p = tmpfile("bad.txt");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(load_edge_list(&p).is_err());
        std::fs::write(&p, "0\n").unwrap();
        assert!(load_edge_list(&p).is_err());
    }

    /// The id-space guard itself (4 billion distinct ids won't fit in a test
    /// fixture): the last assignable dense id is `u32::MAX - 1`, one more
    /// must fail instead of wrapping.
    #[test]
    fn dense_id_overflow_guard() {
        assert_eq!(next_dense_id(0).unwrap(), 0);
        assert_eq!(next_dense_id(VertexId::MAX as usize - 1).unwrap(), VertexId::MAX - 1);
        let err = next_dense_id(VertexId::MAX as usize).unwrap_err().to_string();
        assert!(err.contains("distinct vertex ids"), "{err}");
    }

    #[test]
    fn adjacency_format() {
        let p = tmpfile("adj.txt");
        std::fs::write(&p, "0 2 1 2\n1 1 2\n2 0\n").unwrap();
        let g = load_adjacency(&p).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.out_degree(2), 0);
    }

    #[test]
    fn adjacency_rejects_wrong_degree() {
        let p = tmpfile("adjbad.txt");
        std::fs::write(&p, "0 3 1 2\n").unwrap();
        assert!(load_adjacency(&p).is_err());
    }

    /// Regression: a first line `u 0` (vertex `u`, out-degree 0) used to be
    /// swallowed as an `n m` header, silently dropping the vertex.
    #[test]
    fn adjacency_first_line_degree_zero_vertex_is_kept() {
        let p = tmpfile("adjdeg0.txt");
        std::fs::write(&p, "7 0\n").unwrap();
        let g = load_adjacency(&p).unwrap();
        assert_eq!(g.num_vertices(), 8, "vertex 7 must not be dropped");
        assert_eq!(g.num_edges(), 0);

        std::fs::write(&p, "0 0\n1 1 0\n").unwrap();
        let g = load_adjacency(&p).unwrap();
        assert_eq!(g.num_vertices(), 2);
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.out_degree(0), 0);
    }

    #[test]
    fn adjacency_header_accepted_when_consistent() {
        let p = tmpfile("adjheader.txt");
        std::fs::write(&p, "3 3\n0 2 1 2\n1 1 2\n2 0\n").unwrap();
        let g = load_adjacency(&p).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);

        // the header may declare trailing isolated vertices
        std::fs::write(&p, "5 1\n0 1 1\n").unwrap();
        let g = load_adjacency(&p).unwrap();
        assert_eq!(g.num_vertices(), 5);
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn adjacency_inconsistent_header_rejected() {
        let p = tmpfile("adjbadheader.txt");
        // declares 9 edges, lists 1
        std::fs::write(&p, "2 9\n0 1 1\n").unwrap();
        let err = load_adjacency(&p).unwrap_err().to_string();
        assert!(err.contains("header declares 9 edges"), "{err}");
        // declares 1 vertex, names vertex 5
        std::fs::write(&p, "1 1\n0 1 5\n").unwrap();
        let err = load_adjacency(&p).unwrap_err().to_string();
        assert!(err.contains("names vertex 5"), "{err}");
    }

    #[test]
    fn binary_roundtrip_preserves_graph() {
        let g = crate::graph::synthetic::web_replica(500, 4, 7);
        let p = tmpfile("g.bin");
        save_binary(&g, &p).unwrap();
        let g2 = load_binary(&p).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let p = tmpfile("notagraph.bin");
        std::fs::write(&p, b"NOTMAGIC________________________").unwrap();
        assert!(load_binary(&p).is_err());
        assert!(map_binary(&p).is_err());
    }

    #[test]
    fn v1_cache_rejected_with_migration_hint() {
        let p = tmpfile("v1.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PRNBCSR1");
        bytes.extend_from_slice(&4u64.to_le_bytes()); // name_len
        bytes.extend_from_slice(b"tiny");
        bytes.extend_from_slice(&0u64.to_le_bytes()); // n
        bytes.extend_from_slice(&0u64.to_le_bytes()); // m
        std::fs::write(&p, bytes).unwrap();
        for load in [load_binary as fn(&Path) -> Result<Csr>, map_binary] {
            let err = load(&p).unwrap_err().to_string();
            assert!(err.contains("v1 binary cache"), "{err}");
            assert!(err.contains("pagerank-nb gen"), "migration hint missing: {err}");
        }
    }

    #[test]
    fn sections_are_64_byte_aligned() {
        let layout = v2_layout(11, 97, 331).unwrap();
        for span in [
            layout.out_offsets,
            layout.out_edges,
            layout.in_offsets,
            layout.in_edges,
            layout.offset_list,
        ] {
            assert_eq!(span.at % SECTION_ALIGN, 0, "{span:?}");
        }
        let g = crate::graph::synthetic::web_replica(300, 5, 3);
        let p = tmpfile("aligned.bin");
        save_binary(&g, &p).unwrap();
        let on_disk = std::fs::metadata(&p).unwrap().len();
        let expect = v2_layout(
            g.name.len() as u64,
            g.num_vertices() as u64,
            g.num_edges() as u64,
        )
        .unwrap();
        assert_eq!(on_disk, expect.total, "writer and layout must agree exactly");
    }

    #[test]
    fn header_counts_cannot_demand_absurd_allocations() {
        // a 32-byte file whose header claims u64::MAX vertices: the layout
        // math must fail (or the size check must), never an allocation
        let p = tmpfile("absurd.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V2);
        bytes.extend_from_slice(&0u64.to_le_bytes()); // name_len
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // n
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // m
        std::fs::write(&p, &bytes).unwrap();
        assert!(load_binary(&p).is_err());
        assert!(map_binary(&p).is_err());
        // a plausible-but-false header: claims 1e6 vertices in a 32-byte file
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V2);
        bytes.extend_from_slice(&0u64.to_le_bytes());
        bytes.extend_from_slice(&1_000_000u64.to_le_bytes());
        bytes.extend_from_slice(&5_000_000u64.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = load_binary(&p).unwrap_err().to_string();
        assert!(err.contains("truncated or corrupt"), "{err}");
    }

    /// Property: EVERY truncated prefix of a valid v2 file fails cleanly —
    /// no panic, no multi-GB allocation, just an error.
    #[test]
    fn every_truncated_prefix_fails_cleanly() {
        let g = crate::graph::synthetic::web_replica(40, 3, 5);
        let full_path = tmpfile("fuzzfull.bin");
        save_binary(&g, &full_path).unwrap();
        let full = std::fs::read(&full_path).unwrap();
        assert!(load_binary(&full_path).is_ok());
        let p = tmpfile("fuzzprefix.bin");
        for cut in 0..full.len() {
            std::fs::write(&p, &full[..cut]).unwrap();
            assert!(load_binary(&p).is_err(), "prefix of {cut} bytes must not load");
            assert!(map_binary(&p).is_err(), "prefix of {cut} bytes must not map");
        }
    }

    /// Corruption *inside* a right-sized file (bad offsets / endpoints) must
    /// come back as the validation error, not a panic — on both loaders.
    #[test]
    fn bit_flipped_body_fails_validation_cleanly() {
        let g = crate::graph::synthetic::web_replica(60, 4, 9);
        let p = tmpfile("flipped.bin");
        save_binary(&g, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let body_start =
            v2_layout(g.name.len() as u64, g.num_vertices() as u64, g.num_edges() as u64)
                .unwrap()
                .out_offsets
                .at as usize;
        for (i, step) in [(body_start + 1, 7usize), (body_start + 3, 97)] {
            let mut corrupt = bytes.clone();
            let mut j = i;
            while j < corrupt.len() {
                corrupt[j] ^= 0xA5;
                j += step;
            }
            std::fs::write(&p, &corrupt).unwrap();
            assert!(load_binary(&p).is_err(), "corruption from byte {i} step {step}");
            assert!(map_binary(&p).is_err(), "corruption from byte {i} step {step}");
        }
        // restore and confirm the fixture itself was fine
        std::fs::write(&p, &bytes).unwrap();
        assert!(load_binary(&p).is_ok());
    }

    /// The tentpole equivalence: an mmapped graph is indistinguishable from
    /// its owned round-trip twin — same vertices, edges, neighbours, and
    /// `PartialEq` — while actually borrowing from the map.
    #[test]
    fn mmap_and_owned_loads_compare_equal() {
        let g = crate::graph::synthetic::web_replica(400, 5, 13);
        let p = tmpfile("mmap_eq.bin");
        save_binary(&g, &p).unwrap();
        let owned = load_binary(&p).unwrap();
        let mapped = map_binary(&p).unwrap();
        assert!(!owned.is_mapped());
        assert!(mapped.is_mapped());
        assert_eq!(owned, mapped);
        assert_eq!(mapped, g);
        assert_eq!(mapped.name, g.name);
        assert_eq!(mapped.num_vertices(), g.num_vertices());
        assert_eq!(mapped.num_edges(), g.num_edges());
        for u in (0..g.num_vertices() as VertexId).step_by(17) {
            assert_eq!(mapped.out_neighbors(u), g.out_neighbors(u), "vertex {u}");
            assert_eq!(mapped.in_neighbors(u), g.in_neighbors(u), "vertex {u}");
        }
        assert_eq!(mapped.validate(), Ok(()));
        // a clone of a mapped graph still aliases the map
        assert!(mapped.clone().is_mapped());
    }

    #[test]
    fn empty_graph_roundtrips_through_both_loaders() {
        let g = crate::graph::GraphBuilder::new(0).build("nil");
        let p = tmpfile("empty.bin");
        save_binary(&g, &p).unwrap();
        assert_eq!(load_binary(&p).unwrap().num_vertices(), 0);
        assert_eq!(map_binary(&p).unwrap().num_vertices(), 0);
    }
}
