//! Dataset I/O.
//!
//! * [`load_edge_list`] reads the SNAP plain-text edge-list format the
//!   paper's Table-1 datasets ship in (`# comment` headers, one
//!   whitespace-separated `src dst` pair per line, arbitrary vertex ids that
//!   get densified).
//! * [`load_adjacency`] reads the adjacency-list format of [21]
//!   (`u k v1 … vk` per line).
//! * [`save_binary`] / [`load_binary`] provide a fast binary cache so bench
//!   runs don't re-parse text (format: magic, counts, raw arrays, LE).

use crate::graph::{Csr, GraphBuilder, VertexId};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Load a SNAP-style edge list. Vertex ids are densified (SNAP files skip
/// ids); duplicate edges and self-loops are removed to match the paper's
/// simple-graph preprocessing.
pub fn load_edge_list(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening edge list {}", path.display()))?;
    let reader = BufReader::new(f);
    let mut remap: HashMap<u64, VertexId> = HashMap::new();
    let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
    let densify = |raw: u64, remap: &mut HashMap<u64, VertexId>| -> VertexId {
        let next = remap.len() as VertexId;
        *remap.entry(raw).or_insert(next)
    };
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (a, b) = match (it.next(), it.next()) {
            (Some(a), Some(b)) => (a, b),
            _ => bail!("line {}: expected `src dst`", lineno + 1),
        };
        let u: u64 = a.parse().with_context(|| format!("line {}: bad src", lineno + 1))?;
        let v: u64 = b.parse().with_context(|| format!("line {}: bad dst", lineno + 1))?;
        let u = densify(u, &mut remap);
        let v = densify(v, &mut remap);
        edges.push((u, v));
    }
    let n = remap.len();
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "edge-list".into());
    Ok(GraphBuilder::new(n).dedup(true).edges(&edges).build(&name))
}

/// Load the adjacency-list format of Luo & Liu [21]: each line
/// `u k v1 v2 … vk` lists `u`'s out-neighbours. First line may be `n m`.
pub fn load_adjacency(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening adjacency list {}", path.display()))?;
    let reader = BufReader::new(f);
    let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
    let mut max_v: u64 = 0;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let nums: Vec<u64> = line
            .split_whitespace()
            .map(|t| t.parse::<u64>())
            .collect::<std::result::Result<_, _>>()
            .with_context(|| format!("line {}: non-numeric token", lineno + 1))?;
        if lineno == 0 && nums.len() == 2 {
            // optional `n m` header
            max_v = max_v.max(nums[0].saturating_sub(1));
            continue;
        }
        if nums.is_empty() {
            continue;
        }
        let u = nums[0];
        max_v = max_v.max(u);
        let k = if nums.len() >= 2 { nums[1] as usize } else { 0 };
        if nums.len() != k + 2 {
            bail!("line {}: declared degree {} but {} listed", lineno + 1, k, nums.len().saturating_sub(2));
        }
        for &v in &nums[2..] {
            max_v = max_v.max(v);
            edges.push((u as VertexId, v as VertexId));
        }
    }
    let n = (max_v + 1) as usize;
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "adjacency".into());
    Ok(GraphBuilder::new(n).dedup(true).edges(&edges).build(&name))
}

const MAGIC: &[u8; 8] = b"PRNBCSR1";

/// Write the binary cache format.
pub fn save_binary(g: &Csr, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    let name = g.name.as_bytes();
    w.write_all(&(name.len() as u64).to_le_bytes())?;
    w.write_all(name)?;
    w.write_all(&(g.num_vertices() as u64).to_le_bytes())?;
    w.write_all(&(g.num_edges() as u64).to_le_bytes())?;
    write_usizes(&mut w, &g.out_offsets)?;
    write_u32s(&mut w, &g.out_edges)?;
    write_usizes(&mut w, &g.in_offsets)?;
    write_u32s(&mut w, &g.in_edges)?;
    write_usizes(&mut w, &g.offset_list)?;
    w.flush()?;
    Ok(())
}

/// Read the binary cache format (validates the result).
pub fn load_binary(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not a pagerank-nb binary graph", path.display());
    }
    let name_len = read_u64(&mut r)? as usize;
    let mut name_bytes = vec![0u8; name_len];
    r.read_exact(&mut name_bytes)?;
    let name = String::from_utf8(name_bytes).context("graph name not utf-8")?;
    let n = read_u64(&mut r)? as usize;
    let m = read_u64(&mut r)? as usize;
    let out_offsets = read_usizes(&mut r, n + 1)?;
    let out_edges = read_u32s(&mut r, m)?;
    let in_offsets = read_usizes(&mut r, n + 1)?;
    let in_edges = read_u32s(&mut r, m)?;
    let offset_list = read_usizes(&mut r, m)?;
    let g = Csr::from_parts(n, out_offsets, out_edges, in_offsets, in_edges, offset_list, name);
    g.validate().map_err(|e| anyhow::anyhow!("corrupt binary graph: {e}"))?;
    Ok(g)
}

fn write_usizes<W: Write>(w: &mut W, xs: &[usize]) -> Result<()> {
    for &x in xs {
        w.write_all(&(x as u64).to_le_bytes())?;
    }
    Ok(())
}

fn write_u32s<W: Write>(w: &mut W, xs: &[u32]) -> Result<()> {
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_usizes<R: Read>(r: &mut R, count: usize) -> Result<Vec<usize>> {
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        out.push(read_u64(r)? as usize);
    }
    Ok(out)
}

fn read_u32s<R: Read>(r: &mut R, count: usize) -> Result<Vec<u32>> {
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        out.push(u32::from_le_bytes(b));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("pagerank_nb_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn edge_list_roundtrip_with_comments_and_gaps() {
        let p = tmpfile("snap.txt");
        std::fs::write(
            &p,
            "# Directed graph\n# FromNodeId ToNodeId\n10 20\n20 30\n30 10\n10 30\n\n",
        )
        .unwrap();
        let g = load_edge_list(&p).unwrap();
        assert_eq!(g.num_vertices(), 3); // ids densified
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.validate(), Ok(()));
    }

    #[test]
    fn edge_list_dedups() {
        let p = tmpfile("dups.txt");
        std::fs::write(&p, "0 1\n0 1\n1 1\n").unwrap();
        let g = load_edge_list(&p).unwrap();
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn edge_list_rejects_garbage() {
        let p = tmpfile("bad.txt");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(load_edge_list(&p).is_err());
        std::fs::write(&p, "0\n").unwrap();
        assert!(load_edge_list(&p).is_err());
    }

    #[test]
    fn adjacency_format() {
        let p = tmpfile("adj.txt");
        std::fs::write(&p, "0 2 1 2\n1 1 2\n2 0\n").unwrap();
        let g = load_adjacency(&p).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.out_degree(2), 0);
    }

    #[test]
    fn adjacency_rejects_wrong_degree() {
        let p = tmpfile("adjbad.txt");
        std::fs::write(&p, "0 3 1 2\n").unwrap();
        assert!(load_adjacency(&p).is_err());
    }

    #[test]
    fn binary_roundtrip_preserves_graph() {
        let g = crate::graph::synthetic::web_replica(500, 4, 7);
        let p = tmpfile("g.bin");
        save_binary(&g, &p).unwrap();
        let g2 = load_binary(&p).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let p = tmpfile("notagraph.bin");
        std::fs::write(&p, b"NOTMAGIC________").unwrap();
        assert!(load_binary(&p).is_err());
    }
}
