//! Identical-node detection — the second STIC-D technique (Garg &
//! Kothapalli [11]) that the paper's `*-Identical` variants build on.
//!
//! Two vertices with the *same in-neighbour set* necessarily have the same
//! PageRank (Eq. 1 depends only on in-neighbours), so the rank is computed
//! once per equivalence class and broadcast to the other members, removing
//! redundant work. The variants in `pagerank::identical` consume the
//! [`IdenticalClasses`] produced here.
//!
//! Caveat reproduced from the source papers: classification must account for
//! *out-degree-dependent* contributions only through the neighbours, so the
//! in-neighbour *multiset* (we use the sorted list, which CSR construction
//! makes canonical) is the class key.

use crate::graph::{Csr, VertexId};
use std::collections::HashMap;

/// Partition of the vertex set into identical-PageRank classes.
#[derive(Debug, Clone)]
pub struct IdenticalClasses {
    /// `class_of[u]` — dense class id for each vertex.
    pub class_of: Vec<u32>,
    /// One representative vertex per class (the smallest member).
    pub representatives: Vec<VertexId>,
    /// Members per class, representative first.
    pub members: Vec<Vec<VertexId>>,
}

impl IdenticalClasses {
    /// Group vertices by identical in-neighbour sets.
    ///
    /// O(n + m) hashing of each vertex's sorted in-list. Vertices with no
    /// in-neighbours form one class (they all hold rank `(1-d)/n`).
    pub fn compute(g: &Csr) -> Self {
        let n = g.num_vertices();
        let mut class_of = vec![u32::MAX; n];
        let mut representatives: Vec<VertexId> = Vec::new();
        let mut members: Vec<Vec<VertexId>> = Vec::new();
        // Key: sorted in-neighbour list. CSR in-lists are sorted by source
        // already (counting-sort order), so the slice is canonical.
        let mut index: HashMap<&[VertexId], u32> = HashMap::new();
        for u in 0..n as VertexId {
            let key = g.in_neighbors(u);
            match index.get(key) {
                Some(&c) => {
                    class_of[u as usize] = c;
                    members[c as usize].push(u);
                }
                None => {
                    let c = representatives.len() as u32;
                    index.insert(key, c);
                    class_of[u as usize] = c;
                    representatives.push(u);
                    members.push(vec![u]);
                }
            }
        }
        Self { class_of, representatives, members }
    }

    /// Number of identical-vertex classes.
    pub fn num_classes(&self) -> usize {
        self.representatives.len()
    }

    /// Count of vertices whose computation is eliminated (non-representative
    /// members).
    pub fn redundant_vertices(&self) -> usize {
        self.class_of.len() - self.num_classes()
    }

    /// Fraction of vertices eliminated — the savings knob the paper's
    /// `*-Identical` variants exploit.
    pub fn savings_ratio(&self) -> f64 {
        self.redundant_vertices() as f64 / self.class_of.len().max(1) as f64
    }

    /// Check soundness: every member of a class has the same in-list as its
    /// representative. Used by the property suite.
    pub fn verify(&self, g: &Csr) -> Result<(), String> {
        for (c, ms) in self.members.iter().enumerate() {
            let rep = self.representatives[c];
            let key = g.in_neighbors(rep);
            for &u in ms {
                if g.in_neighbors(u) != key {
                    return Err(format!("vertex {u} misclassified into class {c}"));
                }
                if self.class_of[u as usize] != c as u32 {
                    return Err(format!("class_of[{u}] inconsistent"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{synthetic, GraphBuilder};

    #[test]
    fn star_leaves_form_one_class() {
        // All leaves of a star have in-list {hub}.
        let g = synthetic::star(10);
        let cls = IdenticalClasses::compute(&g);
        // hub's in-list is all 9 leaves → unique class; 9 leaves share one.
        assert_eq!(cls.num_classes(), 2);
        assert_eq!(cls.redundant_vertices(), 8);
        cls.verify(&g).unwrap();
    }

    #[test]
    fn cycle_has_no_identical_nodes() {
        let g = synthetic::cycle(8);
        let cls = IdenticalClasses::compute(&g);
        assert_eq!(cls.num_classes(), 8);
        assert_eq!(cls.savings_ratio(), 0.0);
    }

    #[test]
    fn sources_share_a_class() {
        // 0→2, 1→3: vertices 0 and 1 have empty in-lists → same class.
        let g = GraphBuilder::new(4).edges(&[(0, 2), (1, 3)]).build("src");
        let cls = IdenticalClasses::compute(&g);
        assert_eq!(cls.class_of[0], cls.class_of[1]);
        assert_ne!(cls.class_of[2], cls.class_of[3]); // in-lists {0} vs {1}
        cls.verify(&g).unwrap();
    }

    #[test]
    fn fan_pattern_detected() {
        // u,v both fed by {0,1}: identical.
        let g = GraphBuilder::new(4)
            .edges(&[(0, 2), (1, 2), (0, 3), (1, 3)])
            .build("fan");
        let cls = IdenticalClasses::compute(&g);
        assert_eq!(cls.class_of[2], cls.class_of[3]);
        assert_eq!(cls.redundant_vertices(), 2); // {0,1} sources + {2,3}
    }

    #[test]
    fn representatives_are_smallest_members() {
        let g = synthetic::star(6);
        let cls = IdenticalClasses::compute(&g);
        for (c, ms) in cls.members.iter().enumerate() {
            assert_eq!(cls.representatives[c], *ms.iter().min().unwrap());
            assert_eq!(ms[0], cls.representatives[c]);
        }
    }

    #[test]
    fn verify_on_random_web_graph() {
        let g = synthetic::web_replica(2000, 6, 13);
        let cls = IdenticalClasses::compute(&g);
        cls.verify(&g).unwrap();
        // web graphs do contain identical pages — expect some savings
        assert!(cls.savings_ratio() > 0.0);
    }
}
