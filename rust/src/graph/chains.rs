//! Chain-node detection — STIC-D technique 3 (paper §3): "if a set of
//! nodes form a chain, each node has only one incoming edge and one
//! outgoing edge, the PageRank of a vertex with such a node is easy to
//! compute".
//!
//! Once the head of a chain is known, every subsequent link follows in
//! closed form:
//!
//! ```text
//! pr(c_{i+1}) = (1-d)/n + d · pr(c_i) / 1
//! ```
//!
//! so chain interiors can be excluded from the iteration and filled in with
//! one sweep at the end. [`ChainSet::compute`] finds maximal chains;
//! [`ChainSet::propagate`] performs the closed-form fill-in. The `ablation`
//! bench reports how much of each Table-1 replica is chain-compressible
//! (road networks: a lot; web graphs: little).

use crate::graph::{Csr, VertexId};

/// A maximal chain: `head` feeds `links[0]`, which feeds `links[1]`, …
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Chain {
    /// The vertex feeding the chain (not itself a chain node).
    pub head: VertexId,
    /// Interior chain vertices, in flow order. Each has in-degree 1 and
    /// out-degree 1.
    pub links: Vec<VertexId>,
}

/// All maximal chains of a graph.
#[derive(Debug, Clone)]
pub struct ChainSet {
    /// Every maximal chain found.
    pub chains: Vec<Chain>,
    /// `true` for vertices that are interior links of some chain.
    pub is_link: Vec<bool>,
}

impl ChainSet {
    /// A vertex is a chain link iff it has exactly one in-edge and one
    /// out-edge, and is not a self-loop.
    pub fn compute(g: &Csr) -> Self {
        let n = g.num_vertices();
        let link = |u: VertexId| -> bool {
            g.in_degree(u) == 1 && g.out_degree(u) == 1 && g.in_neighbors(u)[0] != u
        };
        let mut is_link = vec![false; n];
        for u in 0..n as VertexId {
            is_link[u as usize] = link(u);
        }
        let mut chains = Vec::new();
        let mut claimed = vec![false; n];
        for u in 0..n as VertexId {
            // chain starters: link whose predecessor is NOT a link
            if !is_link[u as usize] || claimed[u as usize] {
                continue;
            }
            let pred = g.in_neighbors(u)[0];
            if is_link[pred as usize] {
                continue; // interior, will be reached from its starter
            }
            let mut links = vec![u];
            claimed[u as usize] = true;
            let mut cur = u;
            loop {
                let next = g.out_neighbors(cur)[0];
                if !is_link[next as usize] || claimed[next as usize] {
                    break;
                }
                claimed[next as usize] = true;
                links.push(next);
                cur = next;
            }
            chains.push(Chain { head: pred, links });
        }
        Self { chains, is_link }
    }

    /// Number of vertices whose iteration work is eliminated.
    pub fn eliminated_vertices(&self) -> usize {
        self.chains.iter().map(|c| c.links.len()).sum()
    }

    /// Fraction of vertices eliminated by chain collapse.
    pub fn savings_ratio(&self, g: &Csr) -> f64 {
        self.eliminated_vertices() as f64 / g.num_vertices().max(1) as f64
    }

    /// Closed-form fill-in: given converged ranks for non-link vertices,
    /// rewrite every chain interior. `pr` is modified in place.
    pub fn propagate(&self, g: &Csr, pr: &mut [f64], damping: f64) {
        let n = g.num_vertices() as f64;
        let base = (1.0 - damping) / n;
        for chain in &self.chains {
            let head_out = g.out_degree(chain.head).max(1) as f64;
            let mut inflow = pr[chain.head as usize] / head_out;
            for &link in &chain.links {
                let r = base + damping * inflow;
                pr[link as usize] = r;
                inflow = r; // link out-degree is exactly 1
            }
        }
    }

    /// Soundness check for tests: every link vertex is claimed by at most
    /// one chain and really has (in, out) degree (1, 1).
    pub fn verify(&self, g: &Csr) -> Result<(), String> {
        let mut seen = vec![false; g.num_vertices()];
        for c in &self.chains {
            let mut prev = c.head;
            for &l in &c.links {
                if seen[l as usize] {
                    return Err(format!("vertex {l} in two chains"));
                }
                seen[l as usize] = true;
                if g.in_degree(l) != 1 || g.out_degree(l) != 1 {
                    return Err(format!("vertex {l} is not (1,1)-degree"));
                }
                if g.in_neighbors(l)[0] != prev {
                    return Err(format!("chain broken at {l}"));
                }
                prev = l;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{synthetic, GraphBuilder};
    use crate::pagerank::{seq, PrConfig};

    #[test]
    fn chain_graph_detected() {
        // 0→1→2→3→4: vertices 1..3 are links fed by head 0 (vertex 4 is
        // dangling: out-degree 0, not a link).
        let g = synthetic::chain(5);
        let cs = ChainSet::compute(&g);
        cs.verify(&g).unwrap();
        assert_eq!(cs.chains.len(), 1);
        assert_eq!(cs.chains[0].head, 0);
        assert_eq!(cs.chains[0].links, vec![1, 2, 3]);
    }

    #[test]
    fn cycle_has_no_chain_start() {
        // All vertices are (1,1) but there is no non-link head: the cycle
        // is not compressible by this technique.
        let g = synthetic::cycle(6);
        let cs = ChainSet::compute(&g);
        cs.verify(&g).unwrap();
        assert!(cs.chains.is_empty());
    }

    #[test]
    fn star_leaves_are_one_link_chains() {
        // Each leaf has exactly one in-edge (hub) and one out-edge (hub):
        // a 1-link chain headed by the hub, reconstructible in closed form.
        let g = synthetic::star(8);
        let cs = ChainSet::compute(&g);
        cs.verify(&g).unwrap();
        assert_eq!(cs.chains.len(), 7);
        assert_eq!(cs.eliminated_vertices(), 7);
        assert!(cs.chains.iter().all(|c| c.head == 0 && c.links.len() == 1));
        // and the closed-form fill-in reproduces the iterative leaf rank
        let cfg = PrConfig { threshold: 1e-13, ..PrConfig::default() };
        let (want, _, _) = seq::solve(&g, &cfg);
        let mut pr = want.clone();
        for leaf in 1..8 {
            pr[leaf] = -1.0;
        }
        cs.propagate(&g, &mut pr, cfg.damping);
        let l1: f64 = pr.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 < 1e-9, "star fill-in drifted: {l1}");
    }

    #[test]
    fn branch_terminates_chain() {
        // 0→1→2→3, plus 2→4: vertex 2 has out-degree 2, so the chain is
        // just [1]... and 3 starts no chain (its pred 2 is not a link, but
        // 3 itself is a link with in 1/out... 3 has out-degree 0 → not link.
        let g = GraphBuilder::new(5)
            .edges(&[(0, 1), (1, 2), (2, 3), (2, 4)])
            .build("branch");
        let cs = ChainSet::compute(&g);
        cs.verify(&g).unwrap();
        assert_eq!(cs.chains.len(), 1);
        assert_eq!(cs.chains[0].links, vec![1]);
    }

    #[test]
    fn propagate_matches_iterative_solution() {
        // long chain hanging off a cycle: solve with seq, zero out the
        // interior, reconstruct with propagate, compare.
        let mut edges = vec![(0u32, 1u32), (1, 2), (2, 0)]; // cycle head
        for i in 2..30u32 {
            edges.push((i, i + 1)); // chain 3..30 fed by 2
        }
        let g = GraphBuilder::new(31).edges(&edges).build("cyclechain");
        let cfg = PrConfig { threshold: 1e-13, ..PrConfig::default() };
        let (want, _, _) = seq::solve(&g, &cfg);
        let cs = ChainSet::compute(&g);
        cs.verify(&g).unwrap();
        assert!(cs.eliminated_vertices() >= 25);
        let mut pr = want.clone();
        for c in &cs.chains {
            for &l in &c.links {
                pr[l as usize] = -1.0; // poison
            }
        }
        cs.propagate(&g, &mut pr, cfg.damping);
        let l1: f64 = pr.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 < 1e-9, "closed-form fill-in drifted: {l1}");
    }

    #[test]
    fn road_replicas_are_not_chain_heavy_but_valid() {
        let g = synthetic::road_replica(900, 5);
        let cs = ChainSet::compute(&g);
        cs.verify(&g).unwrap();
        // grid vertices have degree ~4; only deleted-edge corridors chain
        assert!(cs.savings_ratio(&g) < 0.5);
    }
}
