//! Edge-batch mutations over the immutable [`Csr`] — the graph side of the
//! incremental PageRank path.
//!
//! A [`GraphDelta`] collects edge insertions and deletions; applying it
//! yields a *new* CSR (the base graph is never modified, so in-flight
//! readers and epoch snapshots stay valid) plus the set of **touched
//! vertices** — every endpoint of a mutated edge. [`crate::engine::incremental`]
//! seeds the frontier dirty bitmap with the touched vertices and their
//! out-neighbourhoods, so the `Frontier`/`Frontier-PCPM` kernels converge
//! only the delta instead of recomputing from scratch (asynchronous
//! iteration restarts from any warm point — Kollias et al.,
//! arXiv:cs/0606047).
//!
//! ## Rebuild strategy
//!
//! `apply_delta` splices the forward adjacency: the runs of *untouched*
//! sources are block-copied verbatim (one `extend_from_slice` per maximal
//! run), and only the touched sources' runs are rebuilt — deletions
//! filtered out in place, insertions appended in batch order, preserving
//! the builder's stable source-grouped edge order (the bit-exactness
//! contract [`crate::graph::CompressedBins`] relies on). The transpose and
//! the push→pull `offset_list` shift globally when any in-run changes
//! length, so they are rebuilt with the same O(n + m) counting-sort pass
//! as [`crate::graph::GraphBuilder`]. `CompressedBins` scatter plans are
//! *not* patched here: they are rebuilt per run by the kernel constructor
//! against the new CSR, and the warm-start path re-seeds the whole value
//! stream from the previous ranks so the first sweeps still touch only the
//! seeded frontier (see `engine::frontier`).
//!
//! ## Semantics
//!
//! * Insertions append one edge occurrence each; parallel edges are
//!   allowed, exactly as in [`crate::graph::GraphBuilder`].
//! * Deletions are multiset removals: each `delete(u, v)` removes **one**
//!   occurrence of `(u, v)`, and deleting an edge the graph (minus earlier
//!   deletes in the same batch) does not contain is an error.
//! * The vertex count is fixed: endpoints must be `< num_vertices()`.
//! * Degree bookkeeping (and therefore the dangling set,
//!   [`Csr::dangling_count`]) follows from the rebuilt offsets — deleting a
//!   vertex's last out-edge makes it dangling, inserting from a dangling
//!   vertex un-dangles it.

use crate::graph::{Csr, VertexId};
use crate::util::rng::Xoshiro256pp;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A batch of edge insertions and deletions to apply to a [`Csr`].
#[derive(Debug, Clone, Default)]
pub struct GraphDelta {
    inserts: Vec<(VertexId, VertexId)>,
    deletes: Vec<(VertexId, VertexId)>,
}

impl GraphDelta {
    /// An empty batch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue an edge insertion `u → v`.
    pub fn insert(&mut self, u: VertexId, v: VertexId) -> &mut Self {
        self.inserts.push((u, v));
        self
    }

    /// Queue the removal of one occurrence of the edge `u → v`.
    pub fn delete(&mut self, u: VertexId, v: VertexId) -> &mut Self {
        self.deletes.push((u, v));
        self
    }

    /// Queued insertions, in batch order.
    pub fn inserts(&self) -> &[(VertexId, VertexId)] {
        &self.inserts
    }

    /// Queued deletions, in batch order.
    pub fn deletes(&self) -> &[(VertexId, VertexId)] {
        &self.deletes
    }

    /// Total number of queued mutations.
    pub fn len(&self) -> usize {
        self.inserts.len() + self.deletes.len()
    }

    /// Is the batch empty?
    pub fn is_empty(&self) -> bool {
        self.inserts.is_empty() && self.deletes.is_empty()
    }

    /// A deterministic random mutation batch against `g`: `inserts` fresh
    /// edges between uniform non-equal endpoints plus up to `deletes`
    /// removals of *distinct existing* edges (clamped to the edge count, so
    /// the multiset-deletion contract of [`Csr::apply_delta`] always
    /// holds). Used by the `serve` scenario driver and the bench-ci
    /// incremental ablation rows.
    pub fn random(g: &Csr, inserts: usize, deletes: usize, seed: u64) -> GraphDelta {
        let n = g.num_vertices();
        let m = g.num_edges();
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut delta = GraphDelta::new();
        if n >= 2 {
            for _ in 0..inserts {
                let u = rng.next_below(n as u64) as VertexId;
                let mut v = rng.next_below(n as u64) as VertexId;
                if v == u {
                    v = (v + 1) % n as VertexId;
                }
                delta.insert(u, v);
            }
        }
        for e in rng.sample_indices(m, deletes.min(m)) {
            // Map the flat edge index back to (source, target): the source
            // is the last vertex whose offset run starts at or before `e`.
            let u = g.out_offsets.partition_point(|&off| off <= e) - 1;
            delta.delete(u as VertexId, g.out_edges[e]);
        }
        delta
    }
}

/// The outcome of [`Csr::apply_delta`]: the mutated graph plus the sorted,
/// deduplicated set of vertices whose adjacency changed (every endpoint of
/// an inserted or deleted edge).
#[derive(Debug, Clone)]
pub struct AppliedDelta {
    /// The new graph; the base CSR is untouched.
    pub graph: Csr,
    /// Endpoints of every mutated edge, ascending and deduplicated — the
    /// frontier seed for [`crate::engine::incremental::reconverge`].
    pub touched: Vec<VertexId>,
}

impl Csr {
    /// Apply an edge batch, producing a new graph and the touched-vertex
    /// set. See the [module docs](crate::graph::delta) for semantics and
    /// the rebuild strategy; errors on out-of-range endpoints or deletion
    /// of a missing edge, leaving nothing partially applied.
    pub fn apply_delta(&self, delta: &GraphDelta) -> Result<AppliedDelta> {
        let n = self.num_vertices();
        for &(u, v) in delta.inserts().iter().chain(delta.deletes()) {
            if u as usize >= n || v as usize >= n {
                bail!(
                    "delta edge ({u}, {v}) out of range for {n}-vertex graph '{}'",
                    self.name
                );
            }
        }
        // Remaining multiset of deletions, decremented as matches are found.
        let mut pending_del: BTreeMap<(VertexId, VertexId), usize> = BTreeMap::new();
        for &(u, v) in delta.deletes() {
            *pending_del.entry((u, v)).or_insert(0) += 1;
        }
        // Insertions grouped by source, preserving batch order within each.
        let mut ins_by_src: BTreeMap<VertexId, Vec<VertexId>> = BTreeMap::new();
        for &(u, v) in delta.inserts() {
            ins_by_src.entry(u).or_default().push(v);
        }
        let touched_src: std::collections::BTreeSet<VertexId> = delta
            .inserts()
            .iter()
            .chain(delta.deletes())
            .map(|&(u, _)| u)
            .collect();

        // Forward CSR: splice the touched runs, block-copy the rest.
        let new_m = (self.num_edges() + delta.inserts().len())
            .checked_sub(delta.deletes().len())
            .unwrap_or(0);
        let mut out_offsets = Vec::with_capacity(n + 1);
        out_offsets.push(0usize);
        let mut out_edges: Vec<VertexId> = Vec::with_capacity(new_m);
        let mut u = 0 as VertexId;
        while (u as usize) < n {
            if touched_src.contains(&u) {
                for &v in self.out_neighbors(u) {
                    if let Some(c) = pending_del.get_mut(&(u, v)) {
                        if *c > 0 {
                            *c -= 1;
                            continue; // this occurrence is deleted
                        }
                    }
                    out_edges.push(v);
                }
                if let Some(ins) = ins_by_src.get(&u) {
                    out_edges.extend_from_slice(ins);
                }
                out_offsets.push(out_edges.len());
                u += 1;
            } else {
                // Maximal untouched span [u, span_end): one block copy.
                let mut span_end = u + 1;
                while (span_end as usize) < n && !touched_src.contains(&span_end) {
                    span_end += 1;
                }
                out_edges.extend_from_slice(
                    &self.out_edges
                        [self.out_offsets[u as usize]..self.out_offsets[span_end as usize]],
                );
                let base = out_offsets[u as usize] as i64
                    - self.out_offsets[u as usize] as i64;
                for w in u..span_end {
                    out_offsets.push((self.out_offsets[w as usize + 1] as i64 + base) as usize);
                }
                u = span_end;
            }
        }
        if let Some(((du, dv), _)) = pending_del.iter().find(|(_, &c)| c > 0) {
            bail!(
                "delta deletes edge ({du}, {dv}) which graph '{}' does not contain \
                 (or not that many times)",
                self.name
            );
        }
        debug_assert_eq!(out_edges.len(), new_m);

        // Transpose + offset_list: the same counting-sort pass as the
        // builder — in-offsets shift globally whenever any in-run changes,
        // so a targeted patch would still be O(n + m).
        let m = out_edges.len();
        let mut in_offsets = vec![0usize; n + 1];
        for &v in &out_edges {
            in_offsets[v as usize + 1] += 1;
        }
        for i in 0..n {
            in_offsets[i + 1] += in_offsets[i];
        }
        let mut in_edges = vec![0 as VertexId; m];
        let mut offset_list = vec![0usize; m];
        {
            let mut cursor = in_offsets[..n].to_vec();
            for s in 0..n {
                for e in out_offsets[s]..out_offsets[s + 1] {
                    let v = out_edges[e] as usize;
                    in_edges[cursor[v]] = s as VertexId;
                    offset_list[e] = cursor[v];
                    cursor[v] += 1;
                }
            }
        }

        let mut touched: Vec<VertexId> = delta
            .inserts()
            .iter()
            .chain(delta.deletes())
            .flat_map(|&(a, b)| [a, b])
            .collect();
        touched.sort_unstable();
        touched.dedup();

        Ok(AppliedDelta {
            graph: Csr::from_parts(
                n,
                out_offsets,
                out_edges,
                in_offsets,
                in_edges,
                offset_list,
                self.name.clone(),
            ),
            touched,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{synthetic, GraphBuilder};

    /// Reference result: rebuild from scratch with the surviving edges in
    /// source-grouped order plus the insertions appended per source — the
    /// exact order `apply_delta` promises, so the CSRs must be identical.
    fn rebuilt_reference(base: &Csr, delta: &GraphDelta) -> Csr {
        let n = base.num_vertices();
        let mut pending: BTreeMap<(VertexId, VertexId), usize> = BTreeMap::new();
        for &(u, v) in delta.deletes() {
            *pending.entry((u, v)).or_insert(0) += 1;
        }
        let mut b = GraphBuilder::new(n);
        for u in 0..n as VertexId {
            for &v in base.out_neighbors(u) {
                if let Some(c) = pending.get_mut(&(u, v)) {
                    if *c > 0 {
                        *c -= 1;
                        continue;
                    }
                }
                b.edge(u, v);
            }
            for &(s, t) in delta.inserts().iter().filter(|&&(s, _)| s == u) {
                b.edge(s, t);
            }
        }
        b.build(&base.name)
    }

    #[test]
    fn insert_and_delete_roundtrip_matches_rebuild() {
        let base = synthetic::web_replica(300, 5, 11);
        let mut delta = GraphDelta::new();
        delta.insert(0, 7).insert(7, 0).insert(299, 1);
        // delete three existing edges
        for &u in &[3 as VertexId, 50, 120] {
            if base.out_degree(u) > 0 {
                delta.delete(u, base.out_neighbors(u)[0]);
            }
        }
        let applied = base.apply_delta(&delta).unwrap();
        assert_eq!(applied.graph.validate(), Ok(()));
        assert_eq!(applied.graph, rebuilt_reference(&base, &delta));
        assert_eq!(
            applied.graph.num_edges(),
            base.num_edges() + delta.inserts().len() - delta.deletes().len()
        );
        // touched = endpoints, sorted + deduped
        assert!(applied.touched.windows(2).all(|w| w[0] < w[1]));
        assert!(applied.touched.contains(&0) && applied.touched.contains(&7));
    }

    #[test]
    fn untouched_adjacency_is_preserved_verbatim() {
        let base = synthetic::web_replica(200, 4, 3);
        let mut delta = GraphDelta::new();
        delta.insert(5, 6);
        let applied = base.apply_delta(&delta).unwrap();
        for u in 0..200 as VertexId {
            if u != 5 {
                assert_eq!(
                    applied.graph.out_neighbors(u),
                    base.out_neighbors(u),
                    "vertex {u}"
                );
            }
        }
        assert_eq!(applied.graph.out_degree(5), base.out_degree(5) + 1);
        assert_eq!(*applied.graph.out_neighbors(5).last().unwrap(), 6);
    }

    #[test]
    fn multiset_deletion_removes_one_occurrence_per_delete() {
        let base = GraphBuilder::new(3).edges(&[(0, 1), (0, 1), (0, 2)]).build("multi");
        let mut one = GraphDelta::new();
        one.delete(0, 1);
        let g1 = base.apply_delta(&one).unwrap().graph;
        assert_eq!(g1.out_neighbors(0), &[1, 2]);
        let mut two = GraphDelta::new();
        two.delete(0, 1).delete(0, 1);
        let g2 = base.apply_delta(&two).unwrap().graph;
        assert_eq!(g2.out_neighbors(0), &[2]);
        let mut three = GraphDelta::new();
        three.delete(0, 1).delete(0, 1).delete(0, 1);
        assert!(base.apply_delta(&three).is_err(), "only two occurrences exist");
    }

    #[test]
    fn deleting_missing_edge_or_out_of_range_errors() {
        let base = synthetic::cycle(10);
        let mut missing = GraphDelta::new();
        missing.delete(0, 5); // cycle only has 0 → 1
        assert!(base.apply_delta(&missing).is_err());
        let mut oob = GraphDelta::new();
        oob.insert(0, 10);
        assert!(base.apply_delta(&oob).is_err());
        let mut oob2 = GraphDelta::new();
        oob2.delete(10, 0);
        assert!(base.apply_delta(&oob2).is_err());
    }

    #[test]
    fn delete_to_dangling_and_back() {
        let base = synthetic::chain(3); // 0→1→2, vertex 2 dangles
        assert_eq!(base.dangling_count(), 1);
        let mut cut = GraphDelta::new();
        cut.delete(1, 2);
        let g = base.apply_delta(&cut).unwrap().graph;
        assert_eq!(g.dangling_count(), 2, "vertex 1 lost its only out-edge");
        assert_eq!(g.out_degree(1), 0);
        let mut heal = GraphDelta::new();
        heal.insert(2, 0);
        let g2 = base.apply_delta(&heal).unwrap().graph;
        assert_eq!(g2.dangling_count(), 0, "vertex 2 un-dangled");
    }

    #[test]
    fn empty_delta_is_identity() {
        let base = synthetic::web_replica(150, 4, 9);
        let applied = base.apply_delta(&GraphDelta::new()).unwrap();
        assert_eq!(applied.graph, base);
        assert!(applied.touched.is_empty());
    }

    #[test]
    fn insert_into_edgeless_graph() {
        let base = GraphBuilder::new(4).build("blank");
        let mut delta = GraphDelta::new();
        delta.insert(0, 1).insert(1, 2).insert(2, 3);
        let applied = base.apply_delta(&delta).unwrap();
        assert_eq!(applied.graph.validate(), Ok(()));
        assert_eq!(applied.graph.num_edges(), 3);
        assert_eq!(applied.graph.out_neighbors(1), &[2]);
        assert_eq!(applied.touched, vec![0, 1, 2, 3]);
    }

    #[test]
    fn random_batches_always_apply_cleanly() {
        for seed in 0..8u64 {
            let base = synthetic::web_replica(120, 4, seed + 1);
            let delta = GraphDelta::random(&base, 10, 6, seed);
            assert!(!delta.is_empty());
            assert_eq!(delta.len(), delta.inserts().len() + delta.deletes().len());
            let applied = base.apply_delta(&delta).unwrap();
            assert_eq!(applied.graph.validate(), Ok(()), "seed {seed}");
            assert_eq!(applied.graph, rebuilt_reference(&base, &delta), "seed {seed}");
        }
    }

    #[test]
    fn random_on_tiny_graphs_is_safe() {
        let one = GraphBuilder::new(1).build("one");
        let d = GraphDelta::random(&one, 5, 5, 1);
        assert!(d.inserts().is_empty(), "no non-loop edge exists on 1 vertex");
        assert!(one.apply_delta(&d).is_ok());
        let zero = GraphBuilder::new(0).build("zero");
        assert!(zero.apply_delta(&GraphDelta::random(&zero, 3, 3, 1)).is_ok());
    }
}
