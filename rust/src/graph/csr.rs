//! Compressed Sparse Row graph representation.
//!
//! The paper's algorithms need *both* directions:
//!
//! * vertex-centric pull (Algorithms 1, 3, 6): for each `u`, iterate the
//!   **in-neighbours** `v` with `(v, u) ∈ E` and read `pr(v)/outdeg(v)`;
//! * edge-centric push (Algorithms 2, 4): for each `u`, iterate the
//!   **out-links** and scatter contributions.
//!
//! So [`Csr`] stores a forward (out) CSR, a transposed (in) CSR, the
//! out-degree array, and — for the edge-centric contribution-list variants —
//! the *offset list* mapping each out-edge of `u` to the slot in the
//! destination's in-list (`offsetList` in Algorithm 2 line 11).
//!
//! Each of the five arrays lives in a [`GraphStore`]: either an owned `Vec`
//! (the builder / loader path) or a span borrowed zero-copy from a shared
//! page-aligned memory map of the v2 binary cache
//! ([`crate::graph::io::map_binary`]). `GraphStore` derefs to `[T]`, so
//! every kernel reads the graph identically regardless of where the bytes
//! actually reside — RAM or the page cache.

use crate::graph::VertexId;
use mmap_lite::Mmap;
use std::ops::Deref;
use std::sync::Arc;

/// Backing storage for one CSR array: an owned `Vec<T>` or a typed span of
/// a shared read-only memory map. Derefs to `[T]` — indexing, slicing, and
/// iteration work exactly as on a `Vec`, so consumers never branch on the
/// storage kind.
///
/// Mapped spans are constructed only by the v2 binary loader
/// ([`crate::graph::io::map_binary`]), which checks bounds and alignment
/// before handing the span out; cloning a mapped store clones the `Arc` on
/// the underlying map, not the bytes.
pub struct GraphStore<T: Copy + 'static> {
    repr: Repr<T>,
}

enum Repr<T> {
    Owned(Vec<T>),
    Mapped {
        map: Arc<Mmap>,
        /// Byte offset of the span inside the map (64-byte aligned by the
        /// v2 format, so always aligned for `T`).
        offset: usize,
        /// Span length in elements of `T`.
        len: usize,
    },
}

impl<T: Copy + 'static> GraphStore<T> {
    /// Wrap heap-owned storage.
    pub fn owned(values: Vec<T>) -> Self {
        Self { repr: Repr::Owned(values) }
    }

    /// Borrow `len` elements of `T` starting at byte `offset` of `map`.
    ///
    /// Checked construction: the span must lie inside the map and `offset`
    /// must be aligned for `T` (the map base is page-aligned, so the byte
    /// offset alone decides alignment). Only instantiated at `T = usize` /
    /// `T = u32` — plain old data valid for any bit pattern — which is what
    /// makes the reinterpreting [`Deref`] sound.
    pub(crate) fn mapped(map: Arc<Mmap>, offset: usize, len: usize) -> Result<Self, String> {
        let bytes = len
            .checked_mul(std::mem::size_of::<T>())
            .ok_or_else(|| "mapped span length overflows".to_string())?;
        let end = offset
            .checked_add(bytes)
            .ok_or_else(|| "mapped span end overflows".to_string())?;
        if end > map.len() {
            return Err(format!(
                "mapped span {offset}..{end} exceeds map length {}",
                map.len()
            ));
        }
        if offset % std::mem::align_of::<T>() != 0 {
            return Err(format!(
                "mapped span offset {offset} not aligned to {}",
                std::mem::align_of::<T>()
            ));
        }
        Ok(Self { repr: Repr::Mapped { map, offset, len } })
    }

    /// True when the bytes live in a memory map rather than on the heap.
    pub fn is_mapped(&self) -> bool {
        matches!(self.repr, Repr::Mapped { .. })
    }

    /// The elements as a slice (same as dereferencing).
    pub fn as_slice(&self) -> &[T] {
        self
    }

    /// Hint the OS to read ahead the pages backing elements
    /// `start..end` (`madvise(MADV_WILLNEED)` on the underlying map).
    /// Clamped to the span; a no-op for heap-owned storage, where the
    /// elements are already resident.
    pub fn advise_willneed(&self, start: usize, end: usize) {
        if let Repr::Mapped { map, offset, len } = &self.repr {
            let end = end.min(*len);
            if start >= end {
                return;
            }
            let esz = std::mem::size_of::<T>();
            map.advise_willneed(offset + start * esz, (end - start) * esz);
        }
    }
}

impl<T: Copy + 'static> Deref for GraphStore<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        match &self.repr {
            Repr::Owned(v) => v,
            // SAFETY: `mapped` checked that `offset` is aligned for `T` and
            // that `len` elements fit inside the map, the map is immutable
            // and lives as long as `self` (Arc), and `T` is restricted to
            // plain-old-data types valid for any bit pattern.
            Repr::Mapped { map, offset, len } => unsafe {
                std::slice::from_raw_parts(
                    map.as_slice().as_ptr().add(*offset).cast::<T>(),
                    *len,
                )
            },
        }
    }
}

impl<T: Copy + 'static> From<Vec<T>> for GraphStore<T> {
    fn from(values: Vec<T>) -> Self {
        Self::owned(values)
    }
}

impl<T: Copy + 'static> Clone for GraphStore<T> {
    fn clone(&self) -> Self {
        match &self.repr {
            Repr::Owned(v) => Self { repr: Repr::Owned(v.clone()) },
            Repr::Mapped { map, offset, len } => Self {
                repr: Repr::Mapped { map: Arc::clone(map), offset: *offset, len: *len },
            },
        }
    }
}

impl<T: Copy + PartialEq + 'static> PartialEq for GraphStore<T> {
    /// Storage kinds compare as equal when their *elements* are equal — an
    /// mmap-backed graph equals its owned round-trip twin.
    fn eq(&self, other: &Self) -> bool {
        **self == **other
    }
}

impl<T: Copy + std::fmt::Debug + 'static> std::fmt::Debug for GraphStore<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        std::fmt::Debug::fmt(&**self, f)
    }
}

/// Immutable CSR graph (directed).
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    n: usize,
    /// Out-adjacency. `out_edges[out_offsets[u]..out_offsets[u+1]]` are the
    /// targets of `u`'s out-links.
    pub out_offsets: GraphStore<usize>,
    /// Flattened out-adjacency targets (indexed through `out_offsets`).
    pub out_edges: GraphStore<VertexId>,
    /// In-adjacency (the transpose). `in_edges[in_offsets[u]..in_offsets[u+1]]`
    /// are the sources pointing at `u`.
    pub in_offsets: GraphStore<usize>,
    /// Flattened in-adjacency sources (indexed through `in_offsets`).
    pub in_edges: GraphStore<VertexId>,
    /// `offset_list[e]`, for `e` indexing `out_edges`, is the position in
    /// `in_edges` (equivalently: in the contribution list) that edge writes
    /// to. This is what lets the push phase of Barrier-Edge store each
    /// contribution where the pull phase of the destination will read it.
    pub offset_list: GraphStore<usize>,
    /// Human-readable dataset name (propagated into reports).
    pub name: String,
}

impl Csr {
    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Number of directed edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.out_edges.len()
    }

    /// Number of out-edges of `u`.
    #[inline]
    pub fn out_degree(&self, u: VertexId) -> usize {
        self.out_offsets[u as usize + 1] - self.out_offsets[u as usize]
    }

    /// Number of in-edges of `u`.
    #[inline]
    pub fn in_degree(&self, u: VertexId) -> usize {
        self.in_offsets[u as usize + 1] - self.in_offsets[u as usize]
    }

    /// Out-neighbours of `u`.
    #[inline]
    pub fn out_neighbors(&self, u: VertexId) -> &[VertexId] {
        &self.out_edges[self.out_offsets[u as usize]..self.out_offsets[u as usize + 1]]
    }

    /// In-neighbours of `u` (sources of edges into `u`).
    #[inline]
    pub fn in_neighbors(&self, u: VertexId) -> &[VertexId] {
        &self.in_edges[self.in_offsets[u as usize]..self.in_offsets[u as usize + 1]]
    }

    /// Range of `u`'s slots in the in-edge array — the contribution-list
    /// span the edge-centric variants read in their pull phase.
    #[inline]
    pub fn in_slot_range(&self, u: VertexId) -> std::ops::Range<usize> {
        self.in_offsets[u as usize]..self.in_offsets[u as usize + 1]
    }

    /// Range of `u`'s out-edge indices (indexes `out_edges`/`offset_list`).
    #[inline]
    pub fn out_slot_range(&self, u: VertexId) -> std::ops::Range<usize> {
        self.out_offsets[u as usize]..self.out_offsets[u as usize + 1]
    }

    /// Vertices with no out-links (dangling): their rank mass leaks in the
    /// paper's formulation (Eq. 1 has no dangling-mass term).
    pub fn dangling_count(&self) -> usize {
        (0..self.n as VertexId).filter(|&u| self.out_degree(u) == 0).count()
    }

    /// Approximate in-memory footprint in bytes (used by Table 1 replica
    /// size reporting).
    pub fn memory_bytes(&self) -> u64 {
        let usz = std::mem::size_of::<usize>() as u64;
        let vsz = std::mem::size_of::<VertexId>() as u64;
        (self.out_offsets.len() as u64 + self.in_offsets.len() as u64 + self.offset_list.len() as u64)
            * usz
            + (self.out_edges.len() as u64 + self.in_edges.len() as u64) * vsz
    }

    /// Internal consistency check (used by tests and the loader).
    pub fn validate(&self) -> Result<(), String> {
        if self.out_offsets.len() != self.n + 1 || self.in_offsets.len() != self.n + 1 {
            return Err("offset arrays must have n+1 entries".into());
        }
        if self.out_offsets[0] != 0 || self.in_offsets[0] != 0 {
            return Err("offsets must start at 0".into());
        }
        if *self.out_offsets.last().unwrap() != self.out_edges.len() {
            return Err("out_offsets tail != edge count".into());
        }
        if *self.in_offsets.last().unwrap() != self.in_edges.len() {
            return Err("in_offsets tail != edge count".into());
        }
        if self.out_edges.len() != self.in_edges.len() {
            return Err("in/out edge counts differ".into());
        }
        if self.offset_list.len() != self.out_edges.len() {
            return Err("offset_list length != edge count".into());
        }
        if !self.out_offsets.windows(2).all(|w| w[0] <= w[1])
            || !self.in_offsets.windows(2).all(|w| w[0] <= w[1])
        {
            return Err("offsets must be nondecreasing".into());
        }
        if self.out_edges.iter().any(|&v| v as usize >= self.n)
            || self.in_edges.iter().any(|&v| v as usize >= self.n)
        {
            return Err("edge endpoint out of range".into());
        }
        // offset_list correctness: edge e = (u -> v) must map into v's
        // in-slot range, and the slot must name u as the source.
        for u in 0..self.n as VertexId {
            for e in self.out_slot_range(u) {
                let v = self.out_edges[e];
                let slot = self.offset_list[e];
                if !self.in_slot_range(v).contains(&slot) {
                    return Err(format!("offset_list[{e}] outside target range"));
                }
                if self.in_edges[slot] != u {
                    return Err(format!("offset_list[{e}] slot names wrong source"));
                }
            }
        }
        Ok(())
    }

    /// True when the adjacency arrays are borrowed from a memory map (the
    /// out-of-core storage path) rather than heap-owned.
    pub fn is_mapped(&self) -> bool {
        self.out_offsets.is_mapped()
    }

    /// Read-ahead hint for the CSR pages a sweep of `range` touches: both
    /// offset arrays plus the adjacency spans they delimit. The out-of-core
    /// coordinator calls this for the *next* dirty shard while the current
    /// one gathers, overlapping its page-ins with compute. Purely advisory
    /// — a no-op on heap-owned graphs, and never changes what a sweep
    /// reads or computes.
    pub fn prefetch_vertex_range(&self, range: std::ops::Range<VertexId>) {
        if range.is_empty() || !self.is_mapped() {
            return;
        }
        let (s, e) = (range.start as usize, range.end as usize);
        self.out_offsets.advise_willneed(s, e + 1);
        self.in_offsets.advise_willneed(s, e + 1);
        self.out_edges.advise_willneed(self.out_offsets[s], self.out_offsets[e]);
        self.in_edges.advise_willneed(self.in_offsets[s], self.in_offsets[e]);
    }

    /// Construct from raw parts (used by the builder; validates in debug).
    pub(crate) fn from_parts(
        n: usize,
        out_offsets: Vec<usize>,
        out_edges: Vec<VertexId>,
        in_offsets: Vec<usize>,
        in_edges: Vec<VertexId>,
        offset_list: Vec<usize>,
        name: String,
    ) -> Self {
        let g = Self::from_stores(
            n,
            out_offsets.into(),
            out_edges.into(),
            in_offsets.into(),
            in_edges.into(),
            offset_list.into(),
            name,
        );
        debug_assert_eq!(g.validate(), Ok(()));
        g
    }

    /// Construct from pre-built stores (the mmap loader path). Unlike
    /// [`Csr::from_parts`] this does **not** validate even in debug — the
    /// caller is handing over untrusted on-disk data and must run
    /// [`Csr::validate`] itself before releasing the graph to kernels
    /// (which index it with `get_unchecked` on the strength of that check).
    pub(crate) fn from_stores(
        n: usize,
        out_offsets: GraphStore<usize>,
        out_edges: GraphStore<VertexId>,
        in_offsets: GraphStore<usize>,
        in_edges: GraphStore<VertexId>,
        offset_list: GraphStore<usize>,
        name: String,
    ) -> Self {
        Self { n, out_offsets, out_edges, in_offsets, in_edges, offset_list, name }
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::GraphBuilder;

    /// 4-cycle plus a chord: 0→1→2→3→0, 0→2.
    fn tiny() -> crate::graph::Csr {
        GraphBuilder::new(4)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)])
            .build("tiny")
    }

    #[test]
    fn degrees_and_neighbors() {
        let g = tiny();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 5);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.out_neighbors(0), &[1, 2]);
        assert_eq!(g.in_degree(2), 2);
        let mut inn = g.in_neighbors(2).to_vec();
        inn.sort_unstable();
        assert_eq!(inn, vec![0, 1]);
        assert_eq!(g.dangling_count(), 0);
    }

    #[test]
    fn validate_accepts_builder_output() {
        assert_eq!(tiny().validate(), Ok(()));
    }

    #[test]
    fn offset_list_connects_push_to_pull() {
        let g = tiny();
        // Scatter each edge's source id through offset_list, then check each
        // vertex's in-slot range received exactly its in-neighbours.
        let mut slots = vec![u32::MAX; g.num_edges()];
        for u in 0..g.num_vertices() as u32 {
            for e in g.out_slot_range(u) {
                slots[g.offset_list[e]] = u;
            }
        }
        for u in 0..g.num_vertices() as u32 {
            let received = &slots[g.in_slot_range(u)];
            let mut r = received.to_vec();
            r.sort_unstable();
            let mut expect = g.in_neighbors(u).to_vec();
            expect.sort_unstable();
            assert_eq!(r, expect, "vertex {u}");
        }
    }

    #[test]
    fn dangling_detected() {
        let g = GraphBuilder::new(3).edges(&[(0, 2), (1, 2)]).build("dangle");
        assert_eq!(g.dangling_count(), 1); // vertex 2 has no out-links
    }

    #[test]
    fn memory_bytes_positive() {
        assert!(tiny().memory_bytes() > 0);
    }

    mod graph_store {
        use crate::graph::csr::GraphStore;
        use mmap_lite::Mmap;
        use std::sync::Arc;

        /// A map whose bytes are `values` re-encoded natively — so the
        /// typed view must read back exactly `values` on any endianness.
        fn map_of(values: &[u32]) -> Arc<Mmap> {
            let dir = std::env::temp_dir().join("pagerank_nb_store_tests");
            std::fs::create_dir_all(&dir).unwrap();
            let p = dir.join(format!("store-{}-{:?}.bin", std::process::id(), values.len()));
            let bytes: Vec<u8> = values.iter().flat_map(|v| v.to_ne_bytes()).collect();
            std::fs::write(&p, bytes).unwrap();
            Arc::new(Mmap::map(&std::fs::File::open(&p).unwrap()).unwrap())
        }

        #[test]
        fn mapped_view_equals_owned() {
            let values = vec![7u32, 0, 42, u32::MAX, 5];
            let map = map_of(&values);
            let mapped = GraphStore::<u32>::mapped(Arc::clone(&map), 0, values.len()).unwrap();
            let owned = GraphStore::owned(values.clone());
            assert!(mapped.is_mapped());
            assert!(!owned.is_mapped());
            assert_eq!(mapped, owned, "storage kinds compare as elements");
            assert_eq!(&mapped[1..3], &values[1..3]);
            assert_eq!(mapped.as_slice(), &values[..]);
            // cloning a mapped store shares the map, not the bytes
            let twin = mapped.clone();
            assert_eq!(twin, mapped);
            assert!(twin.is_mapped());
        }

        #[test]
        fn advise_willneed_is_a_safe_hint_on_both_storage_kinds() {
            let values = vec![1u32, 2, 3];
            GraphStore::owned(values.clone()).advise_willneed(0, 3); // no-op
            let mapped = GraphStore::<u32>::mapped(map_of(&values), 0, 3).unwrap();
            mapped.advise_willneed(0, 3);
            mapped.advise_willneed(2, 99); // clamped to the span
            mapped.advise_willneed(3, 3); // empty range
            assert_eq!(mapped.as_slice(), &values[..], "advice must not disturb elements");
        }

        #[test]
        fn mapped_rejects_out_of_bounds_and_misaligned() {
            let map = map_of(&[1u32, 2, 3]);
            assert!(GraphStore::<u32>::mapped(Arc::clone(&map), 0, 4).is_err(), "past end");
            assert!(GraphStore::<u32>::mapped(Arc::clone(&map), 2, 2).is_err(), "misaligned");
            assert!(
                GraphStore::<u32>::mapped(Arc::clone(&map), 0, usize::MAX).is_err(),
                "length overflow"
            );
            assert!(GraphStore::<u32>::mapped(map, 4, 2).is_ok(), "aligned in-bounds span");
        }
    }
}
