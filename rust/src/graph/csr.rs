//! Compressed Sparse Row graph representation.
//!
//! The paper's algorithms need *both* directions:
//!
//! * vertex-centric pull (Algorithms 1, 3, 6): for each `u`, iterate the
//!   **in-neighbours** `v` with `(v, u) ∈ E` and read `pr(v)/outdeg(v)`;
//! * edge-centric push (Algorithms 2, 4): for each `u`, iterate the
//!   **out-links** and scatter contributions.
//!
//! So [`Csr`] stores a forward (out) CSR, a transposed (in) CSR, the
//! out-degree array, and — for the edge-centric contribution-list variants —
//! the *offset list* mapping each out-edge of `u` to the slot in the
//! destination's in-list (`offsetList` in Algorithm 2 line 11).

use crate::graph::VertexId;

/// Immutable CSR graph (directed).
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    n: usize,
    /// Out-adjacency. `out_edges[out_offsets[u]..out_offsets[u+1]]` are the
    /// targets of `u`'s out-links.
    pub out_offsets: Vec<usize>,
    /// Flattened out-adjacency targets (indexed through `out_offsets`).
    pub out_edges: Vec<VertexId>,
    /// In-adjacency (the transpose). `in_edges[in_offsets[u]..in_offsets[u+1]]`
    /// are the sources pointing at `u`.
    pub in_offsets: Vec<usize>,
    /// Flattened in-adjacency sources (indexed through `in_offsets`).
    pub in_edges: Vec<VertexId>,
    /// `offset_list[e]`, for `e` indexing `out_edges`, is the position in
    /// `in_edges` (equivalently: in the contribution list) that edge writes
    /// to. This is what lets the push phase of Barrier-Edge store each
    /// contribution where the pull phase of the destination will read it.
    pub offset_list: Vec<usize>,
    /// Human-readable dataset name (propagated into reports).
    pub name: String,
}

impl Csr {
    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Number of directed edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.out_edges.len()
    }

    /// Number of out-edges of `u`.
    #[inline]
    pub fn out_degree(&self, u: VertexId) -> usize {
        self.out_offsets[u as usize + 1] - self.out_offsets[u as usize]
    }

    /// Number of in-edges of `u`.
    #[inline]
    pub fn in_degree(&self, u: VertexId) -> usize {
        self.in_offsets[u as usize + 1] - self.in_offsets[u as usize]
    }

    /// Out-neighbours of `u`.
    #[inline]
    pub fn out_neighbors(&self, u: VertexId) -> &[VertexId] {
        &self.out_edges[self.out_offsets[u as usize]..self.out_offsets[u as usize + 1]]
    }

    /// In-neighbours of `u` (sources of edges into `u`).
    #[inline]
    pub fn in_neighbors(&self, u: VertexId) -> &[VertexId] {
        &self.in_edges[self.in_offsets[u as usize]..self.in_offsets[u as usize + 1]]
    }

    /// Range of `u`'s slots in the in-edge array — the contribution-list
    /// span the edge-centric variants read in their pull phase.
    #[inline]
    pub fn in_slot_range(&self, u: VertexId) -> std::ops::Range<usize> {
        self.in_offsets[u as usize]..self.in_offsets[u as usize + 1]
    }

    /// Range of `u`'s out-edge indices (indexes `out_edges`/`offset_list`).
    #[inline]
    pub fn out_slot_range(&self, u: VertexId) -> std::ops::Range<usize> {
        self.out_offsets[u as usize]..self.out_offsets[u as usize + 1]
    }

    /// Vertices with no out-links (dangling): their rank mass leaks in the
    /// paper's formulation (Eq. 1 has no dangling-mass term).
    pub fn dangling_count(&self) -> usize {
        (0..self.n as VertexId).filter(|&u| self.out_degree(u) == 0).count()
    }

    /// Approximate in-memory footprint in bytes (used by Table 1 replica
    /// size reporting).
    pub fn memory_bytes(&self) -> u64 {
        let usz = std::mem::size_of::<usize>() as u64;
        let vsz = std::mem::size_of::<VertexId>() as u64;
        (self.out_offsets.len() as u64 + self.in_offsets.len() as u64 + self.offset_list.len() as u64)
            * usz
            + (self.out_edges.len() as u64 + self.in_edges.len() as u64) * vsz
    }

    /// Internal consistency check (used by tests and the loader).
    pub fn validate(&self) -> Result<(), String> {
        if self.out_offsets.len() != self.n + 1 || self.in_offsets.len() != self.n + 1 {
            return Err("offset arrays must have n+1 entries".into());
        }
        if self.out_offsets[0] != 0 || self.in_offsets[0] != 0 {
            return Err("offsets must start at 0".into());
        }
        if *self.out_offsets.last().unwrap() != self.out_edges.len() {
            return Err("out_offsets tail != edge count".into());
        }
        if *self.in_offsets.last().unwrap() != self.in_edges.len() {
            return Err("in_offsets tail != edge count".into());
        }
        if self.out_edges.len() != self.in_edges.len() {
            return Err("in/out edge counts differ".into());
        }
        if self.offset_list.len() != self.out_edges.len() {
            return Err("offset_list length != edge count".into());
        }
        if !self.out_offsets.windows(2).all(|w| w[0] <= w[1])
            || !self.in_offsets.windows(2).all(|w| w[0] <= w[1])
        {
            return Err("offsets must be nondecreasing".into());
        }
        if self.out_edges.iter().any(|&v| v as usize >= self.n)
            || self.in_edges.iter().any(|&v| v as usize >= self.n)
        {
            return Err("edge endpoint out of range".into());
        }
        // offset_list correctness: edge e = (u -> v) must map into v's
        // in-slot range, and the slot must name u as the source.
        for u in 0..self.n as VertexId {
            for e in self.out_slot_range(u) {
                let v = self.out_edges[e];
                let slot = self.offset_list[e];
                if !self.in_slot_range(v).contains(&slot) {
                    return Err(format!("offset_list[{e}] outside target range"));
                }
                if self.in_edges[slot] != u {
                    return Err(format!("offset_list[{e}] slot names wrong source"));
                }
            }
        }
        Ok(())
    }

    /// Construct from raw parts (used by the builder; validates in debug).
    pub(crate) fn from_parts(
        n: usize,
        out_offsets: Vec<usize>,
        out_edges: Vec<VertexId>,
        in_offsets: Vec<usize>,
        in_edges: Vec<VertexId>,
        offset_list: Vec<usize>,
        name: String,
    ) -> Self {
        let g = Self { n, out_offsets, out_edges, in_offsets, in_edges, offset_list, name };
        debug_assert_eq!(g.validate(), Ok(()));
        g
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::GraphBuilder;

    /// 4-cycle plus a chord: 0→1→2→3→0, 0→2.
    fn tiny() -> crate::graph::Csr {
        GraphBuilder::new(4)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)])
            .build("tiny")
    }

    #[test]
    fn degrees_and_neighbors() {
        let g = tiny();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 5);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.out_neighbors(0), &[1, 2]);
        assert_eq!(g.in_degree(2), 2);
        let mut inn = g.in_neighbors(2).to_vec();
        inn.sort_unstable();
        assert_eq!(inn, vec![0, 1]);
        assert_eq!(g.dangling_count(), 0);
    }

    #[test]
    fn validate_accepts_builder_output() {
        assert_eq!(tiny().validate(), Ok(()));
    }

    #[test]
    fn offset_list_connects_push_to_pull() {
        let g = tiny();
        // Scatter each edge's source id through offset_list, then check each
        // vertex's in-slot range received exactly its in-neighbours.
        let mut slots = vec![u32::MAX; g.num_edges()];
        for u in 0..g.num_vertices() as u32 {
            for e in g.out_slot_range(u) {
                slots[g.offset_list[e]] = u;
            }
        }
        for u in 0..g.num_vertices() as u32 {
            let received = &slots[g.in_slot_range(u)];
            let mut r = received.to_vec();
            r.sort_unstable();
            let mut expect = g.in_neighbors(u).to_vec();
            expect.sort_unstable();
            assert_eq!(r, expect, "vertex {u}");
        }
    }

    #[test]
    fn dangling_detected() {
        let g = GraphBuilder::new(3).edges(&[(0, 2), (1, 2)]).build("dangle");
        assert_eq!(g.dangling_count(), 1); // vertex 2 has no out-links
    }

    #[test]
    fn memory_bytes_positive() {
        assert!(tiny().memory_bytes() > 0);
    }
}
