//! Edge-list → CSR conversion.
//!
//! Builds the forward CSR, the transpose, and the push→pull `offset_list`
//! in three counting-sort passes — O(n + m), no comparison sort, matching
//! the `ConvertCsr` preprocessing step every algorithm in the paper starts
//! with.

use crate::graph::{Csr, VertexId};

/// Incremental builder for directed graphs.
#[derive(Debug, Clone)]
pub struct GraphBuilder {
    n: usize,
    src: Vec<VertexId>,
    dst: Vec<VertexId>,
    dedup: bool,
}

impl GraphBuilder {
    /// A builder for a graph with `n` vertices and no edges yet.
    pub fn new(n: usize) -> Self {
        assert!(n <= VertexId::MAX as usize, "vertex count exceeds id width");
        Self { n, src: Vec::new(), dst: Vec::new(), dedup: false }
    }

    /// Remove duplicate edges and self-loops during `build` (SNAP web graphs
    /// contain both; the paper's CSR conversion keeps the graph simple).
    pub fn dedup(mut self, yes: bool) -> Self {
        self.dedup = yes;
        self
    }

    /// Add one directed edge `u -> v`.
    pub fn edge(&mut self, u: VertexId, v: VertexId) -> &mut Self {
        debug_assert!((u as usize) < self.n && (v as usize) < self.n);
        self.src.push(u);
        self.dst.push(v);
        self
    }

    /// Add a batch of directed edges.
    pub fn edges(mut self, list: &[(VertexId, VertexId)]) -> Self {
        self.src.reserve(list.len());
        self.dst.reserve(list.len());
        for &(u, v) in list {
            self.edge(u, v);
        }
        self
    }

    /// Edges added so far.
    pub fn edge_count(&self) -> usize {
        self.src.len()
    }

    /// Consume the builder and produce a validated [`Csr`].
    pub fn build(mut self, name: &str) -> Csr {
        let n = self.n;

        if self.dedup {
            self.dedup_in_place();
        }
        let m = self.src.len();

        // Pass 1: counting sort edges by source → forward CSR.
        let mut out_offsets = vec![0usize; n + 1];
        for &u in &self.src {
            out_offsets[u as usize + 1] += 1;
        }
        for i in 0..n {
            out_offsets[i + 1] += out_offsets[i];
        }
        let mut out_edges = vec![0 as VertexId; m];
        {
            let mut cursor = out_offsets[..n].to_vec();
            for i in 0..m {
                let u = self.src[i] as usize;
                out_edges[cursor[u]] = self.dst[i];
                cursor[u] += 1;
            }
        }

        // Pass 2: counting sort by destination → transpose, and record for
        // each forward edge slot which in-slot it landed in (offset_list).
        let mut in_offsets = vec![0usize; n + 1];
        for &v in &out_edges {
            in_offsets[v as usize + 1] += 1;
        }
        for i in 0..n {
            in_offsets[i + 1] += in_offsets[i];
        }
        let mut in_edges = vec![0 as VertexId; m];
        let mut offset_list = vec![0usize; m];
        {
            let mut cursor = in_offsets[..n].to_vec();
            for u in 0..n {
                for e in out_offsets[u]..out_offsets[u + 1] {
                    let v = out_edges[e] as usize;
                    in_edges[cursor[v]] = u as VertexId;
                    offset_list[e] = cursor[v];
                    cursor[v] += 1;
                }
            }
        }

        let g = Csr::from_parts(
            n,
            out_offsets,
            out_edges,
            in_offsets,
            in_edges,
            offset_list,
            name.to_string(),
        );
        debug_assert_eq!(g.validate(), Ok(()));
        g
    }

    fn dedup_in_place(&mut self) {
        let mut pairs: Vec<(VertexId, VertexId)> = self
            .src
            .iter()
            .zip(&self.dst)
            .filter(|(u, v)| u != v)
            .map(|(&u, &v)| (u, v))
            .collect();
        pairs.sort_unstable();
        pairs.dedup();
        self.src = pairs.iter().map(|p| p.0).collect();
        self.dst = pairs.iter().map(|p| p.1).collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new(3).build("empty");
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.validate(), Ok(()));
        assert_eq!(g.dangling_count(), 3);
    }

    #[test]
    fn single_vertex_no_edges() {
        let g = GraphBuilder::new(1).build("one");
        assert_eq!(g.num_vertices(), 1);
        assert_eq!(g.out_degree(0), 0);
    }

    #[test]
    fn parallel_edges_kept_without_dedup() {
        let g = GraphBuilder::new(2).edges(&[(0, 1), (0, 1)]).build("multi");
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.out_degree(0), 2);
    }

    #[test]
    fn dedup_removes_duplicates_and_self_loops() {
        let g = GraphBuilder::new(3)
            .dedup(true)
            .edges(&[(0, 1), (0, 1), (1, 1), (2, 0), (2, 0), (2, 2)])
            .build("dedup");
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.out_neighbors(0), &[1]);
        assert_eq!(g.out_neighbors(2), &[0]);
        assert_eq!(g.out_degree(1), 0);
    }

    #[test]
    fn transpose_is_consistent() {
        let edges = [(0u32, 1u32), (0, 2), (1, 2), (2, 0), (3, 2), (3, 0)];
        let g = GraphBuilder::new(4).edges(&edges).build("t");
        // every forward edge appears exactly once in the transpose
        let mut fwd: Vec<(u32, u32)> = Vec::new();
        for u in 0..4u32 {
            for &v in g.out_neighbors(u) {
                fwd.push((u, v));
            }
        }
        let mut rev: Vec<(u32, u32)> = Vec::new();
        for v in 0..4u32 {
            for &u in g.in_neighbors(v) {
                rev.push((u, v));
            }
        }
        fwd.sort_unstable();
        rev.sort_unstable();
        assert_eq!(fwd, rev);
    }

    #[test]
    fn out_neighbors_preserve_insertion_grouping() {
        // counting sort is stable in source order
        let g = GraphBuilder::new(3).edges(&[(0, 2), (0, 1), (1, 0)]).build("s");
        assert_eq!(g.out_neighbors(0), &[2, 1]);
    }

    #[test]
    fn validate_full_on_larger_random_graph() {
        use crate::util::rng::Xoshiro256pp;
        let mut r = Xoshiro256pp::seed_from_u64(1);
        let n = 500;
        let mut b = GraphBuilder::new(n);
        for _ in 0..5000 {
            b.edge(r.next_below(n as u64) as u32, r.next_below(n as u64) as u32);
        }
        let g = b.build("rand");
        assert_eq!(g.validate(), Ok(()));
    }
}
