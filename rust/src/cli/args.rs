//! Tiny `--flag value` argument parser.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// Parsed `--key value` pairs plus bare positionals.
#[derive(Debug, Default, Clone)]
pub struct ArgMap {
    flags: HashMap<String, String>,
    /// Arguments that were not `--key` flags, in order.
    pub positional: Vec<String>,
}

impl ArgMap {
    /// Parse `--key value` and `--switch` (value-less switches store `""`).
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' not supported");
                }
                // `--key=value` or `--key value` or switch
                if let Some((k, v)) = key.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.insert(key.to_string(), String::new());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Self { flags, positional })
    }

    /// Was `--key` present (with or without a value)?
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// The value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// The value of `--key`, or an error naming the flag.
    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).with_context(|| format!("missing required --{key}"))
    }

    /// Parse `--key`'s value, falling back to `default` when absent.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} '{s}': {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_pairs_positionals_and_switches() {
        let a = ArgMap::parse(&sv(&["fig1", "--threads", "4", "--all", "--out=reports"])).unwrap();
        assert_eq!(a.positional, vec!["fig1"]);
        assert_eq!(a.get("threads"), Some("4"));
        assert!(a.has("all"));
        assert_eq!(a.get("out"), Some("reports"));
    }

    #[test]
    fn get_parsed_with_default() {
        let a = ArgMap::parse(&sv(&["--threads", "8"])).unwrap();
        assert_eq!(a.get_parsed("threads", 1usize).unwrap(), 8);
        assert_eq!(a.get_parsed("samples", 5usize).unwrap(), 5);
        let bad = ArgMap::parse(&sv(&["--threads", "x"])).unwrap();
        assert!(bad.get_parsed("threads", 1usize).is_err());
    }

    #[test]
    fn require_errors_when_missing() {
        let a = ArgMap::parse(&sv(&[])).unwrap();
        assert!(a.require("graph").is_err());
    }

    #[test]
    fn negative_number_is_not_a_flag() {
        // values starting with '--' are treated as next flag; plain numbers ok
        let a = ArgMap::parse(&sv(&["--seed", "123"])).unwrap();
        assert_eq!(a.get("seed"), Some("123"));
    }
}
