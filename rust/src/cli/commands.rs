//! Subcommand implementations.

use crate::cli::ArgMap;
use crate::coordinator::host::HostInfo;
use crate::engine::topology::Placement;
use crate::graph::properties::GraphStats;
use crate::graph::synthetic::{self, table1};
use crate::graph::{io, Csr, PartitionPolicy};
use crate::harness::bench::BenchRunner;
use crate::harness::experiments::{self, Ctx, ALL_EXPERIMENTS};
use crate::pagerank::{self, FrontierSched, PcpmLayout, PrConfig, Variant};
use crate::util::fmt;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Resolve a `--graph` source: file path (.bin / edge list) or generator
/// spec like `web:10000:8`.
pub fn load_graph(src: &str, seed: u64) -> Result<Csr> {
    if src.contains(':') && !Path::new(src).exists() {
        return gen_from_spec(src, seed);
    }
    let path = Path::new(src);
    if !path.exists() {
        bail!("graph source '{src}' is neither a file nor a generator spec");
    }
    if path.extension().and_then(|e| e.to_str()) == Some("bin") {
        io::load_binary(path)
    } else {
        io::load_edge_list(path)
    }
}

/// Where a run's CSR arrays live: the heap, or a read-only memory map of
/// the v2 binary cache (the out-of-core storage path; `--storage mmap`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Storage {
    Memory,
    Mmap,
}

fn storage_from_args(args: &ArgMap) -> Result<Storage> {
    match args.get("storage").unwrap_or("memory") {
        "memory" | "mem" => Ok(Storage::Memory),
        "mmap" => Ok(Storage::Mmap),
        other => bail!("--storage must be memory|mmap, got '{other}'"),
    }
}

/// Resolve `--graph` honoring `--storage`. Under mmap a `.bin` source is
/// mapped in place (zero copy, nothing resident up front); any other source
/// — edge list or generator spec — is built owned, spilled to a v2 cache
/// under the temp dir, dropped, and re-mapped, so the run itself always
/// executes against the map.
fn load_graph_stored(src: &str, seed: u64, storage: Storage) -> Result<Csr> {
    if storage == Storage::Memory {
        return load_graph(src, seed);
    }
    let path = Path::new(src);
    if path.extension().and_then(|e| e.to_str()) == Some("bin") && path.exists() {
        return io::map_binary(path);
    }
    let owned = load_graph(src, seed)?;
    let dir = std::env::temp_dir().join("pagerank_nb_mmap");
    std::fs::create_dir_all(&dir)
        .with_context(|| format!("creating spill dir {}", dir.display()))?;
    let spill = dir.join(format!("{}-{}.bin", owned.name, std::process::id()));
    io::save_binary(&owned, &spill)?;
    drop(owned); // the heap copy is gone before the map is first touched
    io::map_binary(&spill)
}

fn gen_from_spec(spec: &str, seed: u64) -> Result<Csr> {
    let parts: Vec<&str> = spec.split(':').collect();
    let p = |i: usize| -> Result<usize> {
        parts
            .get(i)
            .with_context(|| format!("spec '{spec}' missing field {i}"))?
            .parse()
            .with_context(|| format!("bad number in spec '{spec}'"))
    };
    Ok(match parts[0] {
        "web" => synthetic::web_replica(p(1)?, p(2)?, seed),
        "social" => synthetic::social_replica(p(1)?, p(2)?, seed),
        "road" => synthetic::road_replica(p(1)?, seed),
        "rmat" => synthetic::d_series(1, 1, seed), // alias kept simple
        "d" => synthetic::d_series(p(1)? as u32, p(2)?, seed),
        "cycle" => synthetic::cycle(p(1)?),
        "star" => synthetic::star(p(1)?),
        "chain" => synthetic::chain(p(1)?),
        "er" => synthetic::erdos_renyi(p(1)?, p(2)?, seed),
        other => bail!("unknown generator '{other}' in spec '{spec}'"),
    })
}

fn config_from_args(args: &ArgMap) -> Result<PrConfig> {
    let host = HostInfo::detect();
    let partition = match args.get("partition").unwrap_or("vertex") {
        "vertex" => PartitionPolicy::VertexBalanced,
        "edge" => PartitionPolicy::EdgeBalanced,
        other => bail!("--partition must be vertex|edge, got '{other}'"),
    };
    let pcpm_layout = match args.get("pcpm-layout") {
        None => PcpmLayout::Compressed,
        Some(s) => PcpmLayout::parse(s)?,
    };
    let numa = match args.get("numa") {
        None => Placement::Off,
        Some(s) => Placement::parse(s)?,
    };
    let frontier_sched = match args.get("frontier-sched") {
        None => FrontierSched::Bitmap,
        Some(s) => FrontierSched::parse(s)?,
    };
    // `--delta-threshold auto` arms the residual-driven tuner; a number
    // fixes the push cutoff (0 = derive from the convergence threshold).
    let (delta_auto, delta_threshold) = match args.get("delta-threshold") {
        Some("auto") => (true, 0.0),
        _ => (false, args.get_parsed("delta-threshold", 0.0f64)?),
    };
    Ok(PrConfig {
        damping: args.get_parsed("damping", crate::DAMPING)?,
        threshold: args.get_parsed("threshold", crate::DEFAULT_THRESHOLD)?,
        max_iterations: args.get_parsed("iters", 10_000u64)?,
        threads: args.get_parsed("threads", host.default_threads())?,
        partition,
        delta_threshold,
        delta_auto,
        // frontier sweep scheduling + worker placement (see engine docs)
        frontier_sched,
        numa,
        // partition-centric knobs: source-partition batch + bin layout
        pcpm_batch: args.get_parsed("pcpm-batch", 1usize)?,
        pcpm_layout,
        ..PrConfig::default()
    })
}

/// Resolve the dataset divisor: an explicit `--scale` wins; otherwise the
/// (once-per-process, logged) `PAGERANK_NB_SCALE` default. Taken lazily so
/// the env default is neither read nor logged when the flag already
/// decides the scale — the log line must name the size that actually ran.
fn scale_from_args(args: &ArgMap) -> Result<usize> {
    if args.has("scale") {
        Ok(args.get_parsed("scale", 1usize)?.max(1))
    } else {
        Ok(crate::harness::bench::dataset_divisor())
    }
}

/// Resolve the variant from `--mode` (execution mode, e.g. `pcpm` /
/// `partition-centric`) or `--algo` (`--mode standard` defers to `--algo`).
fn variant_from_args(args: &ArgMap) -> Result<Variant> {
    match args.get("mode") {
        Some(m) if !m.is_empty() && m != "standard" => Variant::parse(m),
        _ => Variant::parse(args.get("algo").unwrap_or("no-sync")),
    }
}

/// `run`: one algorithm on one graph; prints timing + top ranks. With
/// `--shards`/`--mem-budget` the run goes through the out-of-core shard
/// coordinator ([`crate::engine::ooc`]) instead of the thread engine;
/// `--ooc-workers K` sweeps K shards concurrently (default
/// `min(threads, shards)`).
pub fn cmd_run(args: &ArgMap) -> Result<()> {
    let seed = args.get_parsed("seed", 42u64)?;
    let storage = storage_from_args(args)?;
    let g = load_graph_stored(args.require("graph")?, seed, storage)?;
    let variant = variant_from_args(args)?;
    let cfg = config_from_args(args)?;
    let out_of_core = args.has("shards") || args.has("mem-budget");
    if cfg.pcpm_batch > 1 && variant != Variant::Pcpm {
        eprintln!(
            "note: --pcpm-batch only affects --mode pcpm; ignored for {variant}"
        );
    }
    if cfg.pcpm_layout != PcpmLayout::Compressed
        && !out_of_core
        && !matches!(variant, Variant::Pcpm | Variant::FrontierPcpm)
    {
        eprintln!(
            "note: --pcpm-layout only affects the pcpm modes; ignored for {variant}"
        );
    }
    println!(
        "graph '{}': {} vertices, {} edges{} · {} · {} threads",
        g.name,
        fmt::count(g.num_vertices() as u64),
        fmt::count(g.num_edges() as u64),
        if g.is_mapped() { " · mmap-backed" } else { "" },
        variant,
        cfg.threads
    );
    let r = if out_of_core {
        // Requested parallel sweep width. The default is min(threads,
        // shards); an explicit --ooc-workers above the shard count is
        // clamped by the coordinator (surplus workers could never claim a
        // shard). Resolved *before* the shard count because a budget-derived
        // schedule must fit K resident shards, not one.
        let workers_req = if args.has("ooc-workers") {
            let k = args.get_parsed("ooc-workers", 1usize)?;
            if k == 0 {
                bail!("--ooc-workers must be at least 1");
            }
            k
        } else {
            cfg.threads
        };
        let shards = if args.has("shards") {
            let s = args.get_parsed("shards", 1usize)?;
            if s == 0 {
                bail!("--shards must be at least 1");
            }
            s
        } else {
            let budget_mib: u64 = args.get_parsed("mem-budget", 0u64)?;
            if budget_mib == 0 {
                bail!("--mem-budget must be a positive number of MiB");
            }
            crate::engine::ooc::shards_for_budget(&g, budget_mib << 20, workers_req)?
        };
        let workers = workers_req.min(shards).max(1);
        if args.has("mode") || args.has("algo") {
            eprintln!(
                "note: out-of-core runs replay through Frontier-PCPM; --mode/--algo ignored"
            );
        }
        println!(
            "out-of-core: {shards} shard(s), {workers} worker(s), storage {}",
            if g.is_mapped() { "mmap" } else { "memory" }
        );
        crate::engine::ooc::run_sharded_workers(&g, &cfg, shards, workers)?
    } else if variant == Variant::XlaBlock {
        let engine = crate::runtime::Engine::cpu()?;
        pagerank::run_with_engine(&g, variant, &cfg, &engine)?
    } else {
        pagerank::run(&g, variant, &cfg)?
    };
    println!(
        "{}: {} in {} ({} iterations{}){}",
        r.variant,
        if r.converged { "converged" } else { "NOT converged" },
        fmt::duration(r.elapsed.as_secs_f64()),
        r.iterations,
        if r.vertex_updates > 0 {
            format!(", {} vertex updates", fmt::count(r.vertex_updates))
        } else {
            String::new()
        },
        if r.dnf { " [DNF]" } else { "" }
    );
    let k = args.get_parsed("top", 5usize)?;
    for (rank, (u, score)) in r.top_k(k).into_iter().enumerate() {
        println!("  #{:<2} vertex {:<10} pr = {}", rank + 1, u, fmt::sci(score));
    }
    Ok(())
}

/// `bench`: regenerate paper tables/figures.
pub fn cmd_bench(argv: &[String]) -> Result<()> {
    let args = ArgMap::parse(argv)?;
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let ids: Vec<&str> = if which == "all" {
        ALL_EXPERIMENTS.to_vec()
    } else {
        vec![which]
    };
    let out_dir = PathBuf::from(args.get("out").unwrap_or("reports"));
    let host = HostInfo::detect();
    let ctx = Ctx {
        divisor: scale_from_args(&args)?,
        // oversubscribe to ≥4 threads on small hosts (see Ctx::default)
        threads: args.get_parsed("threads", host.default_threads().max(4))?,
        runner: BenchRunner::new(
            args.get_parsed("samples", BenchRunner::default().samples)?,
            args.get_parsed("warmup", BenchRunner::default().warmup)?,
        ),
        seed: args.get_parsed("seed", 42u64)?,
        host,
    };
    for id in ids {
        eprintln!("── experiment {id} ──");
        let tables = experiments::run_experiment(id, &ctx)?;
        for (i, t) in tables.iter().enumerate() {
            println!("{}", t.to_markdown());
            let stem = if tables.len() == 1 {
                id.to_string()
            } else {
                format!("{id}_{}", (b'a' + i as u8) as char)
            };
            t.write_all(&out_dir, &stem)?;
        }
    }
    eprintln!("reports written to {}", out_dir.display());
    Ok(())
}

/// `bench-ci`: run every registered variant on the scaled-down CI datasets,
/// write the `BENCH_ci.json` trajectory report, and (when a baseline is
/// given) fail on any >`--max-regress` regression. `--require-baseline`
/// turns a missing/empty baseline into an error instead of a bootstrap
/// skip; `--seed-baseline` writes one from this run. See
/// docs/benchmarking.md.
pub fn cmd_bench_ci(args: &ArgMap) -> Result<()> {
    use crate::harness::trajectory::{self, BenchReport};
    let divisor = scale_from_args(args)?;
    let host = HostInfo::detect();
    let threads = args.get_parsed("threads", host.default_threads().max(4))?;
    let samples = args.get_parsed("samples", 3usize)?;
    let seed = args.get_parsed("seed", 42u64)?;
    eprintln!("── bench-ci: scale 1/{divisor}, {threads} threads, {samples} samples ──");
    let report = trajectory::run_ci_bench(divisor, threads, samples, seed)?;
    println!(
        "{:<14} {:<22} {:>10} {:>8} {:>8} {:>14} {:>6}",
        "dataset", "variant", "time (s)", "rel", "iters", "vertex-updates", "conv"
    );
    for r in &report.rows {
        println!(
            "{:<14} {:<22} {:>10} {:>8} {:>8} {:>14} {:>6}",
            r.dataset,
            r.variant,
            if r.secs.is_finite() { format!("{:.4}", r.secs) } else { "DNF".into() },
            if r.rel.is_finite() { format!("{:.2}x", r.rel) } else { "-".into() },
            r.iterations,
            if r.vertex_updates > 0 {
                fmt::count(r.vertex_updates)
            } else {
                "-".into() // kernel not instrumented (Wait-Free helping)
            },
            if r.converged { "yes" } else { "no" }
        );
    }
    let out = PathBuf::from(args.get("out").unwrap_or("BENCH_ci.json"));
    std::fs::write(&out, report.to_json())
        .with_context(|| format!("writing {}", out.display()))?;
    eprintln!("trajectory written to {}", out.display());

    if let Some(baseline_path) = args.get("baseline") {
        let max_regress = args.get_parsed("max-regress", 0.25f64)?;
        let baseline = if Path::new(baseline_path).exists() {
            let text = std::fs::read_to_string(baseline_path)
                .with_context(|| format!("reading {baseline_path}"))?;
            Some(
                BenchReport::from_json(&text)
                    .with_context(|| format!("parsing {baseline_path}"))?,
            )
        } else {
            None
        };
        // Bootstrap: no rows to hold this run against. With
        // `--seed-baseline` the just-measured report becomes the baseline
        // (written in place for the operator / CI artifact to commit), so
        // the gate stops passing vacuously on the very next run.
        let bootstrap = match &baseline {
            None => true,
            Some(b) => b.rows.is_empty(),
        };
        if bootstrap {
            // CI passes --require-baseline: its baseline is committed, so
            // finding it missing or empty means the file was corrupted or
            // accidentally emptied — silently skipping (or reseeding) the
            // gate would launder the damage into a green run.
            if args.has("require-baseline") {
                bail!(
                    "baseline {baseline_path} is {} but --require-baseline was \
                     given — restore the committed baseline or reseed it \
                     explicitly via the baseline-refresh workflow \
                     (docs/benchmarking.md)",
                    if baseline.is_some() { "empty" } else { "missing" }
                );
            }
            if args.has("seed-baseline") {
                std::fs::write(baseline_path, report.to_json())
                    .with_context(|| format!("seeding {baseline_path}"))?;
                eprintln!(
                    "baseline {baseline_path} seeded from this run ({} rows) — \
                     commit it to arm the regression gate (docs/benchmarking.md)",
                    report.rows.len()
                );
            } else {
                eprintln!(
                    "baseline {baseline_path} is {} — gate skipped (bootstrap; \
                     re-run with --seed-baseline to seed it from this run)",
                    if baseline.is_some() { "empty" } else { "missing" }
                );
            }
            return Ok(());
        }
        let baseline = baseline.expect("non-empty baseline checked above");
        if !trajectory::comparable(&report, &baseline) {
            eprintln!(
                "baseline {baseline_path} was recorded at scale 1/{}, {} threads \
                 (schema {}); this run used scale 1/{}, {} threads (schema {}) — \
                 incomparable, gate skipped. Refresh the baseline (docs/benchmarking.md).",
                baseline.scale,
                baseline.threads,
                baseline.schema,
                report.scale,
                report.threads,
                report.schema
            );
            return Ok(());
        }
        // One-sided rows are not gated, but must not vanish silently: a
        // renamed/removed variant would otherwise shed its protection
        // without a trace in the log.
        for b in &baseline.rows {
            if report.find(&b.dataset, &b.variant).is_none() {
                eprintln!(
                    "MISSING: baseline row {}/{} has no counterpart in this run — \
                     skipped by the gate (renamed/removed ablation?)",
                    b.dataset, b.variant
                );
            } else if !b.gated {
                eprintln!(
                    "UNGATED: baseline row {}/{} is an offline placeholder — \
                     skipped by the gate until a --seed-baseline refresh \
                     records real numbers (docs/benchmarking.md)",
                    b.dataset, b.variant
                );
            }
        }
        let regressions = trajectory::compare(&report, &baseline, max_regress);
        if regressions.is_empty() {
            // only rows present in BOTH reports were actually gated
            let gated = baseline
                .rows
                .iter()
                .filter(|r| {
                    r.gated && r.converged && report.find(&r.dataset, &r.variant).is_some()
                })
                .count();
            println!(
                "bench-trajectory gate: OK ({gated} baseline rows held within {:.0}%)",
                max_regress * 100.0
            );
        } else {
            for msg in &regressions {
                eprintln!("REGRESSION: {msg}");
            }
            bail!(
                "{} benchmark regression(s) beyond {:.0}% vs {baseline_path}",
                regressions.len(),
                max_regress * 100.0
            );
        }
    }
    Ok(())
}

/// `serve`: the evolve-query-reconverge scenario. Bootstrap a rank server
/// with a cold frontier solve, then per epoch apply a random edge batch,
/// reconverge incrementally from the previous ranks, and publish a fresh
/// snapshot — while reader threads hammer `rank`/`top_k` the whole time.
pub fn cmd_serve(args: &ArgMap) -> Result<()> {
    use crate::graph::GraphDelta;
    use crate::serving::ServingEngine;
    use crate::util::rng::Xoshiro256pp;
    use crate::sync::shim::atomic::{AtomicBool, Ordering};

    let seed = args.get_parsed("seed", 42u64)?;
    let g = load_graph(args.require("graph")?, seed)?;
    let variant = match args.get("mode") {
        None => Variant::Frontier,
        Some(m) => Variant::parse(m)?,
    };
    let cfg = config_from_args(args)?;
    let epochs = args.get_parsed("epochs", 4u64)?;
    let batch = args.get_parsed("batch", 32usize)?;
    let readers = args.get_parsed("readers", 2usize)?;
    let k = args.get_parsed("top", 3usize)?;
    println!(
        "serving '{}': {} vertices, {} edges · {} · {} threads · {} reader(s)",
        g.name,
        fmt::count(g.num_vertices() as u64),
        fmt::count(g.num_edges() as u64),
        variant,
        cfg.threads,
        readers
    );
    let mut engine = ServingEngine::bootstrap(g, variant, cfg)?;
    println!("epoch 1 (bootstrap): cold solve published");
    let server = engine.server();
    let done = AtomicBool::new(false);
    let outcome: Result<()> = std::thread::scope(|s| {
        for r in 0..readers {
            let server = engine.server();
            let done = &done;
            s.spawn(move || {
                let mut rng = Xoshiro256pp::seed_from_u64(seed ^ (r as u64 + 1));
                while !done.load(Ordering::Acquire) {
                    let snap = server.snapshot();
                    assert!(snap.verify(), "reader observed a torn snapshot");
                    if !snap.is_empty() {
                        server.rank(rng.next_below(snap.len() as u64) as u32);
                    }
                    server.top_k(k);
                    std::thread::yield_now();
                }
            });
        }
        let run = (|| -> Result<()> {
            for e in 0..epochs {
                let delta =
                    GraphDelta::random(engine.graph(), batch, batch / 2, seed + e + 1);
                let stats = engine.apply(&delta)?;
                println!(
                    "epoch {}: +{}/-{} edges · {} touched · {} iters · {} vertex updates \
                     · {} · {} edges now{}",
                    stats.epoch,
                    delta.inserts().len(),
                    delta.deletes().len(),
                    stats.touched,
                    stats.iterations,
                    fmt::count(stats.vertex_updates),
                    fmt::duration(stats.elapsed_secs),
                    fmt::count(stats.edges as u64),
                    if stats.converged { "" } else { " [NOT converged]" }
                );
            }
            Ok(())
        })();
        done.store(true, Ordering::Release);
        run
    });
    outcome?;
    println!(
        "served {} queries across {} epochs; final top-{k}:",
        fmt::count(server.queries_served()),
        engine.epoch()
    );
    for (rank, (u, score)) in server.top_k(k).into_iter().enumerate() {
        println!("  #{:<2} vertex {:<10} pr = {}", rank + 1, u, fmt::sci(score));
    }
    Ok(())
}

/// `gen`: materialize replica datasets to disk (binary + edge-list).
pub fn cmd_gen(args: &ArgMap) -> Result<()> {
    let out = PathBuf::from(args.require("out")?);
    std::fs::create_dir_all(&out)?;
    let divisor = scale_from_args(args)?;
    let seed = args.get_parsed("seed", 42u64)?;
    let wanted: Option<&str> = args.get("dataset");
    if wanted.is_none() && !args.has("all") {
        bail!("pass --all or --dataset NAME");
    }
    let mut count = 0;
    for spec in table1() {
        if let Some(w) = wanted {
            if !spec.name.eq_ignore_ascii_case(w) {
                continue;
            }
        }
        let g = (spec.build)(divisor, seed);
        let path = out.join(format!("{}.bin", spec.name));
        io::save_binary(&g, &path)?;
        println!(
            "{:<18} {:>9} vertices {:>10} edges -> {}",
            spec.name,
            fmt::count(g.num_vertices() as u64),
            fmt::count(g.num_edges() as u64),
            path.display()
        );
        count += 1;
    }
    if count == 0 {
        bail!("no dataset matched {:?}", wanted);
    }
    Ok(())
}

/// `info`: structural stats for a graph source.
pub fn cmd_info(args: &ArgMap) -> Result<()> {
    let seed = args.get_parsed("seed", 42u64)?;
    let g = load_graph(args.require("graph")?, seed)?;
    let s = GraphStats::compute(&g);
    println!("graph '{}'", g.name);
    println!("  vertices        {}", fmt::count(s.vertices as u64));
    println!("  edges           {}", fmt::count(s.edges as u64));
    println!("  dangling        {}", fmt::count(s.dangling as u64));
    println!("  mean degree     {:.2}", s.mean_degree);
    println!("  max in-degree   {}", fmt::count(s.max_in_degree as u64));
    println!("  max out-degree  {}", fmt::count(s.max_out_degree as u64));
    println!("  in-degree gini  {:.3}", s.in_degree_gini);
    println!("  memory          {}", fmt::bytes(s.memory_bytes));
    Ok(())
}

/// `validate`: run every CPU variant and check L1-norm against sequential.
pub fn cmd_validate(args: &ArgMap) -> Result<()> {
    let seed = args.get_parsed("seed", 42u64)?;
    let g = load_graph(args.require("graph")?, seed)?;
    let cfg = config_from_args(args)?;
    let seq = pagerank::run(&g, Variant::Sequential, &cfg)?;
    println!(
        "{:<24} {:>12} {:>8} {:>12} {:>10}",
        "variant", "time", "iters", "L1 vs seq", "status"
    );
    let mut failures = 0;
    for v in Variant::parallel_modes() {
        let r = pagerank::run(&g, v, &cfg)?;
        let l1 = r.l1_norm(&seq.ranks);
        // exact variants must match tightly; approximate ones loosely
        let bound = if v.is_approximate() { 1e-2 } else { 1e-6 };
        let ok = r.converged && l1 < bound;
        if !ok && v != Variant::NoSyncEdge {
            failures += 1;
        }
        println!(
            "{:<24} {:>12} {:>8} {:>12} {:>10}",
            v.name(),
            fmt::duration(r.elapsed.as_secs_f64()),
            r.iterations,
            fmt::sci(l1),
            if ok {
                "OK"
            } else if v == Variant::NoSyncEdge {
                "KNOWN-NC"
            } else {
                "FAIL"
            }
        );
    }
    if failures > 0 {
        bail!("{failures} variant(s) failed validation");
    }
    println!("all variants validated against sequential");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing_generates_graphs() {
        assert_eq!(load_graph("cycle:10", 1).unwrap().num_vertices(), 10);
        assert_eq!(load_graph("star:5", 1).unwrap().num_edges(), 8);
        assert!(load_graph("web:500:4", 1).unwrap().num_vertices() > 0);
        assert!(load_graph("er:100:300", 1).unwrap().num_edges() == 300);
    }

    #[test]
    fn bad_specs_error() {
        assert!(load_graph("warp:10", 1).is_err());
        assert!(load_graph("cycle:x", 1).is_err());
        assert!(load_graph("/no/such/file", 1).is_err());
    }

    #[test]
    fn mode_flag_selects_pcpm() {
        let a = ArgMap::parse(&["--mode".into(), "pcpm".into()]).unwrap();
        assert_eq!(variant_from_args(&a).unwrap(), Variant::Pcpm);
        let b = ArgMap::parse(&[
            "--mode".into(),
            "standard".into(),
            "--algo".into(),
            "barrier".into(),
        ])
        .unwrap();
        assert_eq!(variant_from_args(&b).unwrap(), Variant::Barrier);
        let c = ArgMap::parse(&["--algo".into(), "partition-centric".into()]).unwrap();
        assert_eq!(variant_from_args(&c).unwrap(), Variant::Pcpm);
        let d = ArgMap::parse(&["--mode".into(), "frontier".into()]).unwrap();
        assert_eq!(variant_from_args(&d).unwrap(), Variant::Frontier);
        let e = ArgMap::parse(&["--mode".into(), "frontier-pcpm".into()]).unwrap();
        assert_eq!(variant_from_args(&e).unwrap(), Variant::FrontierPcpm);
    }

    #[test]
    fn scale_flag_overrides_env_default() {
        let a = ArgMap::parse(&["--scale".into(), "400".into()]).unwrap();
        assert_eq!(scale_from_args(&a).unwrap(), 400);
        let zero = ArgMap::parse(&["--scale".into(), "0".into()]).unwrap();
        assert_eq!(scale_from_args(&zero).unwrap(), 1, "scale floors at 1");
        let none = ArgMap::parse(&[]).unwrap();
        assert!(scale_from_args(&none).unwrap() >= 1);
    }

    #[test]
    fn delta_threshold_flag_reaches_config() {
        let a = ArgMap::parse(&["--delta-threshold".into(), "1e-4".into()]).unwrap();
        let cfg = config_from_args(&a).unwrap();
        assert_eq!(cfg.delta_threshold, 1e-4);
        assert_eq!(cfg.resolved_delta_threshold(), 1e-4);
        let b = ArgMap::parse(&[]).unwrap();
        assert_eq!(config_from_args(&b).unwrap().delta_threshold, 0.0);
    }

    #[test]
    fn delta_threshold_auto_arms_the_tuner() {
        let a = ArgMap::parse(&["--delta-threshold".into(), "auto".into()]).unwrap();
        let cfg = config_from_args(&a).unwrap();
        assert!(cfg.delta_auto);
        assert_eq!(cfg.delta_threshold, 0.0, "auto starts from the derived cutoff");
        let fixed = ArgMap::parse(&["--delta-threshold".into(), "1e-5".into()]).unwrap();
        assert!(!config_from_args(&fixed).unwrap().delta_auto);
        let bad = ArgMap::parse(&["--delta-threshold".into(), "soon".into()]).unwrap();
        assert!(config_from_args(&bad).is_err(), "non-numeric, non-auto rejected");
    }

    #[test]
    fn numa_and_frontier_sched_flags_reach_config() {
        let a = ArgMap::parse(&[
            "--numa".into(),
            "pin".into(),
            "--frontier-sched".into(),
            "worklist".into(),
        ])
        .unwrap();
        let cfg = config_from_args(&a).unwrap();
        assert_eq!(cfg.numa, Placement::Pin);
        assert_eq!(cfg.frontier_sched, FrontierSched::Worklist);
        let defaults = config_from_args(&ArgMap::parse(&[]).unwrap()).unwrap();
        assert_eq!(defaults.numa, Placement::Off);
        assert_eq!(defaults.frontier_sched, FrontierSched::Bitmap);
        let hybrid =
            ArgMap::parse(&["--frontier-sched".into(), "hybrid".into()]).unwrap();
        assert_eq!(
            config_from_args(&hybrid).unwrap().frontier_sched,
            FrontierSched::Hybrid
        );
        let bad_numa = ArgMap::parse(&["--numa".into(), "far".into()]).unwrap();
        assert!(config_from_args(&bad_numa).is_err());
        let bad_sched =
            ArgMap::parse(&["--frontier-sched".into(), "stack".into()]).unwrap();
        assert!(config_from_args(&bad_sched).is_err());
    }

    #[test]
    fn pcpm_flags_reach_config() {
        let a = ArgMap::parse(&[
            "--pcpm-batch".into(),
            "4".into(),
            "--pcpm-layout".into(),
            "slots".into(),
        ])
        .unwrap();
        let cfg = config_from_args(&a).unwrap();
        assert_eq!(cfg.pcpm_batch, 4);
        assert_eq!(cfg.pcpm_layout, PcpmLayout::Slots);
        let defaults = config_from_args(&ArgMap::parse(&[]).unwrap()).unwrap();
        assert_eq!(defaults.pcpm_batch, 1);
        assert_eq!(defaults.pcpm_layout, PcpmLayout::Compressed);
        let bad =
            ArgMap::parse(&["--pcpm-layout".into(), "zip".into()]).unwrap();
        assert!(config_from_args(&bad).is_err());
    }

    #[test]
    fn storage_flag_parses() {
        let none = ArgMap::parse(&[]).unwrap();
        assert_eq!(storage_from_args(&none).unwrap(), Storage::Memory);
        let mm = ArgMap::parse(&["--storage".into(), "mmap".into()]).unwrap();
        assert_eq!(storage_from_args(&mm).unwrap(), Storage::Mmap);
        let mem = ArgMap::parse(&["--storage".into(), "mem".into()]).unwrap();
        assert_eq!(storage_from_args(&mem).unwrap(), Storage::Memory);
        let bad = ArgMap::parse(&["--storage".into(), "tape".into()]).unwrap();
        assert!(storage_from_args(&bad).is_err());
    }

    #[test]
    fn mmap_storage_spills_and_maps_any_source() {
        // generator spec: no .bin on disk, so the loader must spill + remap
        let mapped = load_graph_stored("web:300:4", 7, Storage::Mmap).unwrap();
        assert!(mapped.is_mapped());
        let owned = load_graph_stored("web:300:4", 7, Storage::Memory).unwrap();
        assert!(!owned.is_mapped());
        assert_eq!(mapped, owned, "storage must not change the graph");
        // an existing .bin is mapped in place
        let dir = std::env::temp_dir().join("pagerank_nb_cli_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("stored.bin");
        io::save_binary(&owned, &p).unwrap();
        let direct = load_graph_stored(p.to_str().unwrap(), 0, Storage::Mmap).unwrap();
        assert!(direct.is_mapped());
        assert_eq!(direct, owned);
    }

    #[test]
    fn ooc_worker_flags_run_end_to_end() {
        // --shards + --ooc-workers drive the parallel coordinator through
        // the real CLI path (flag parsing, clamping, result printing).
        let run = |argv: &[&str]| {
            let owned: Vec<String> = argv.iter().map(|s| s.to_string()).collect();
            cmd_run(&ArgMap::parse(&owned).unwrap())
        };
        run(&["--graph", "web:400:4", "--shards", "4", "--ooc-workers", "2"]).unwrap();
        // K above the shard count clamps instead of erroring
        run(&["--graph", "cycle:40", "--shards", "2", "--ooc-workers", "16"]).unwrap();
        // zero is rejected loudly
        let err = run(&["--graph", "cycle:40", "--shards", "2", "--ooc-workers", "0"])
            .unwrap_err()
            .to_string();
        assert!(err.contains("--ooc-workers"), "{err}");
        // a budget-derived schedule divides the budget by K before sizing
        // shards, so splitting 1 MiB this many ways cannot hold a shard of
        // even one vertex — the hint must surface, not a silent clamp
        let err = run(&[
            "--graph", "web:400:4", "--mem-budget", "1", "--ooc-workers", "999999",
        ])
        .unwrap_err()
        .to_string();
        assert!(err.contains("--ooc-workers") || err.contains("--mem-budget"), "{err}");
    }

    #[test]
    fn file_loading_roundtrip() {
        let g = synthetic::cycle(12);
        let dir = std::env::temp_dir().join("pagerank_nb_cli_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.bin");
        io::save_binary(&g, &p).unwrap();
        let loaded = load_graph(p.to_str().unwrap(), 0).unwrap();
        assert_eq!(loaded.num_vertices(), 12);
    }
}
